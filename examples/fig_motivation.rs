//! Reproduces the paper's motivating figures:
//!
//! * **Fig. 3** — AIBA: allocating input buses to highly associated input
//!   readings at the same time co-schedules their multiplications.
//! * **Fig. 4** — Mul-CI: multicasting a high-fanout input over two buses
//!   avoids the caching operation.
//! * **Fig. 5/6** — RID-AT: reconstructing the adder tree against the
//!   realized multiplication schedule reduces MCIDs.
//!
//! ```bash
//! cargo run --release --example fig_motivation
//! ```

use sparsemap::arch::StreamingCgra;
use sparsemap::config::Techniques;
use sparsemap::dfg::analysis::mii;
use sparsemap::dfg::build::build_sdfg;
use sparsemap::dfg::EdgeKind;
use sparsemap::sched::ridat::{reconstruct_adder_trees, schedule_adds_fixed};
use sparsemap::sched::sparsemap::schedule_at;
use sparsemap::sched::ResourceTables;
use sparsemap::sparse::SparseBlock;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let cgra = StreamingCgra::paper_default();

    // ---- Fig. 3: AIBA --------------------------------------------------
    // Channels c0/c2 share four kernels (the highest association); channel
    // order splits them across bus cycles, AIBA keeps them together.
    #[rustfmt::skip]
    let fig3 = SparseBlock::from_mask("fig3", 4, 4, vec![
        // k0     k1     k2     k3
        true,  true,  true,  true,  // c0
        true,  false, false, false, // c1
        true,  true,  true,  true,  // c2
        false, true,  false, false, // c3
    ])?;
    println!("Fig. 3 (AIBA): association(c0,c2) = {}", fig3.association(0, 2));
    let (g3, idx3) = build_sdfg(&fig3);
    // The paper's Fig. 3 bottleneck is input buses; emulate with a
    // 2-input-bus fabric so the 4 readings need two cycles.
    let narrow = StreamingCgra::new(4, 2, 8, 8);
    let ii_n = mii(&g3, &narrow);
    for (name, tech) in [
        ("channel order", Techniques { aiba: false, mul_ci: true, rid_at: true }),
        ("AIBA         ", Techniques::all()),
    ] {
        match schedule_at(&g3, &narrow, tech, ii_n) {
            Ok(s) => {
                let (r0, r2) = (idx3.read(0).unwrap(), idx3.read(2).unwrap());
                println!(
                    "  {name}: II={} MCIDs={} — c0 read at t={}, c2 read at t={} ({})",
                    s.ii,
                    s.mcids().len(),
                    s.t[r0],
                    s.t[r2],
                    if s.t[r0] == s.t[r2] { "co-scheduled ✓" } else { "split ✗" },
                );
            }
            Err(e) => println!("  {name}: fails at II={ii_n} ({e})"),
        }
    }

    // ---- Fig. 4: Mul-CI ------------------------------------------------
    // One input with 5 multiplications on a 4×4 PEA: one bus reaches only
    // 4 PEs.
    let fig4 = SparseBlock::from_mask("fig4", 1, 5, vec![true; 5])?;
    let (g4, _) = build_sdfg(&fig4);
    println!("\nFig. 4 (Mul-CI): input c0 with fanout 5 on a 4×4 PEA");
    for (name, tech) in [
        ("without Mul-CI", Techniques { aiba: true, mul_ci: false, rid_at: true }),
        ("with Mul-CI   ", Techniques::all()),
    ] {
        let s = schedule_at(&g4, &cgra, tech, 2)?;
        println!(
            "  {name}: input COPs={} (input-bus allocations: {})",
            s.input_cops(),
            s.g.reads().len(),
        );
    }

    // ---- Fig. 5/6: RID-AT ----------------------------------------------
    // The paper's exact setting: one kernel with 4 multiplications
    // scheduled at t = 0, 0, 1, 2 (Fig. 5(a)). Fixed balanced tree vs the
    // reconstructed tree.
    let fig5 = SparseBlock::from_mask("fig5", 4, 1, vec![true; 4])?;
    let count_mcids = |g: &sparsemap::dfg::SDfg, t: &[Option<usize>]| {
        g.edges()
            .iter()
            .filter(|e| e.kind == EdgeKind::Internal)
            .filter(|e| t[e.dst].unwrap() - t[e.src].unwrap() > 1)
            .count()
    };
    println!("\nFig. 5/6 (RID-AT): 1 kernel, muls scheduled at t = 0, 0, 1, 2");
    for fixed in [true, false] {
        let (mut g5, idx5) = build_sdfg(&fig5);
        let mut t = vec![None; g5.len()];
        let times = [0usize, 0, 1, 2];
        let mut tables = ResourceTables::new(&cgra, 4);
        for ch in 0..4 {
            let r = idx5.read(ch).unwrap();
            let m = idx5.mul(ch, 0).unwrap();
            t[r] = Some(times[ch]);
            t[m] = Some(times[ch]);
            tables.take_pe(times[ch], 1);
        }
        if fixed {
            schedule_adds_fixed(&g5, &mut t, &mut tables)?;
            println!("  fixed adder tree: MCIDs={}", count_mcids(&g5, &t));
        } else {
            reconstruct_adder_trees(&mut g5, &mut t, &mut tables, &[0], &cgra)?;
            println!("  RID-AT          : MCIDs={}", count_mcids(&g5, &t));
        }
    }
    Ok(())
}
