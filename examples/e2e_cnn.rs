//! End-to-end driver: a two-layer block-sparse CNN runs **through the whole
//! stack** on a real (synthetic-image) workload, proving the layers
//! compose:
//!
//! 1. two 3×3 conv layers (4→6→8 channels, 16×16 images, ~40 % zero
//!    weights) are partitioned into mapper-sized sparse blocks;
//! 2. the L3 coordinator maps every block (SparseMap scheduling + SBTS
//!    binding, mapping cache) and streams all spatial positions through
//!    the **cycle-accurate CGRA simulator**;
//! 3. the same layers execute through the **PJRT runtime** on the
//!    AOT-compiled JAX/Pallas artifacts (`make artifacts`), and the two
//!    paths are cross-checked numerically;
//! 4. cycles, throughput and the speedup over dense mapping are reported
//!    (recorded in EXPERIMENTS.md §E2E).
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_cnn
//! ```

use std::sync::Arc;
use std::time::Instant;

use sparsemap::arch::StreamingCgra;
use sparsemap::config::SparsemapConfig;
use sparsemap::coordinator::Coordinator;
use sparsemap::runtime::{default_artifacts_dir, Runtime};
use sparsemap::sparse::partition::{SparseLayer, LayerBlock};
use sparsemap::util::rng::Pcg64;

const H: usize = 16;
const W: usize = 16;
const T: usize = H * W;

/// A conv layer in im2col form.
struct Layer {
    name: &'static str,
    cin: usize,
    cout: usize,
    layer: SparseLayer,
    blocks: Vec<LayerBlock>,
}

fn make_layer(name: &'static str, cin: usize, cout: usize, p_zero: f64, seed: u64) -> Layer {
    let c_total = cin * 9;
    let mut rng = Pcg64::seeded(seed);
    let mut mask = vec![false; c_total * cout];
    let mut weights = vec![0f32; c_total * cout];
    for i in 0..mask.len() {
        if !rng.chance(p_zero) {
            mask[i] = true;
            weights[i] = 0.3 * rng.next_normal() as f32;
        }
    }
    let layer = SparseLayer::new(name, c_total, cout, weights, mask).expect("layer");
    // 6x4 tiles keep every reading's fanout within one input bus's reach
    // (N = 4) even at ~60% density, so blocks map comfortably at MII —
    // the tile size is a fabric-fitting policy of the coordinator.
    let blocks = layer.partition(6, 4);
    Layer { name, cin, cout, layer, blocks }
}

/// im2col matching python/compile/model.py (3×3, SAME zero padding,
/// (c, dy, dx) tap order).
fn im2col(img: &[f32], cin: usize) -> Vec<Vec<f32>> {
    let mut out = vec![vec![0f32; cin * 9]; T];
    for y in 0..H {
        for x in 0..W {
            let pos = y * W + x;
            for c in 0..cin {
                for dy in 0..3usize {
                    for dx in 0..3usize {
                        let yy = y as isize + dy as isize - 1;
                        let xx = x as isize + dx as isize - 1;
                        let v = if yy < 0 || yy >= H as isize || xx < 0 || xx >= W as isize {
                            0.0
                        } else {
                            img[c * T + yy as usize * W + xx as usize]
                        };
                        out[pos][c * 9 + dy * 3 + dx] = v;
                    }
                }
            }
        }
    }
    out
}

/// Run one layer on the CGRA via the coordinator: every block is mapped
/// (cached) and all T positions stream through the simulator; block
/// outputs accumulate into the layer output. Returns (post-ReLU outputs
/// per position, CGRA cycles).
fn run_layer_on_cgra(
    coord: &Coordinator,
    layer: &Layer,
    patches: &[Vec<f32>],
) -> (Vec<Vec<f32>>, u64) {
    let mut acc = vec![vec![0f32; layer.cout]; T];
    // Enqueue one request per block (the coordinator maps it once and
    // streams all positions); tickets come back in block order.
    let mut session = coord.session();
    let mut tickets = Vec::with_capacity(layer.blocks.len());
    for lb in &layer.blocks {
        let live = SparseLayer::live_channels(&lb.block.name);
        let xs: Vec<Vec<f32>> = patches
            .iter()
            .map(|p| live.iter().map(|&ch| p[ch]).collect())
            .collect();
        tickets.push(session.enqueue(Arc::new(lb.block.clone()), xs));
    }
    session.flush();
    let mut cycles = 0u64;
    for (bi, ticket) in tickets.into_iter().enumerate() {
        let r = ticket.wait().expect("block inference");
        cycles += r.cycles;
        let lb = &layer.blocks[bi];
        for (pos, y) in r.outputs.iter().enumerate() {
            for (bk, v) in y.iter().enumerate() {
                acc[pos][lb.kr_offset + bk] += v;
            }
        }
    }
    // ReLU epilogue (host-side; the CGRA blocks compute the MACs).
    for row in acc.iter_mut() {
        for v in row.iter_mut() {
            *v = v.max(0.0);
        }
    }
    (acc, cycles)
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let cgra = StreamingCgra::paper_default();
    let mut cfg = SparsemapConfig::default();
    cfg.workers = 4;
    cfg.queue_depth = 16;
    cfg.ii_slack = 4;
    let coord = Coordinator::new(&cfg);

    let l1 = make_layer("conv1", 4, 6, 0.4, 11);
    let l2 = make_layer("conv2", 6, 8, 0.4, 12);
    println!(
        "layers: {} ({} blocks, {:.0}% sparse), {} ({} blocks, {:.0}% sparse)",
        l1.name,
        l1.blocks.len(),
        100.0 * (1.0 - l1.layer.mask.iter().filter(|&&m| m).count() as f64 / l1.layer.mask.len() as f64),
        l2.name,
        l2.blocks.len(),
        100.0 * (1.0 - l2.layer.mask.iter().filter(|&&m| m).count() as f64 / l2.layer.mask.len() as f64),
    );

    // PJRT runtime for the cross-check.
    let mut rt = Runtime::new(&default_artifacts_dir())?;
    println!("PJRT platform: {}", rt.platform());

    let n_images = 3usize;
    let mut rng = Pcg64::seeded(7);
    let mut total_cycles = 0u64;
    let mut max_err = 0f32;
    let wall = Instant::now();

    for img_idx in 0..n_images {
        let img: Vec<f32> = (0..4 * T).map(|_| rng.next_normal() as f32).collect();

        // ---- CGRA path -------------------------------------------------
        let patches1 = im2col(&img, l1.cin);
        let (y1, c1) = run_layer_on_cgra(&coord, &l1, &patches1);
        // Layer-2 input: (T, 6) activations reshaped to channel-major img.
        let mut act1 = vec![0f32; l1.cout * T];
        for (pos, row) in y1.iter().enumerate() {
            for (c, &v) in row.iter().enumerate() {
                act1[c * T + pos] = v;
            }
        }
        let patches2 = im2col(&act1, l2.cin);
        let (y2, c2) = run_layer_on_cgra(&coord, &l2, &patches2);
        total_cycles += c1 + c2;

        // ---- PJRT path (AOT JAX/Pallas artifacts) ----------------------
        let zeros6 = vec![0f32; 6];
        let zeros8 = vec![0f32; 8];
        let m1: Vec<f32> = l1.layer.mask.iter().map(|&m| m as u8 as f32).collect();
        let m2: Vec<f32> = l2.layer.mask.iter().map(|&m| m as u8 as f32).collect();
        let r1 = rt.execute(
            "conv_l1_c4k6_16x16",
            &[&img, &l1.layer.weights, &m1, &zeros6],
        )?;
        let r2 = rt.execute(
            "conv_l2_c6k8_16x16",
            &[&r1, &l2.layer.weights, &m2, &zeros8],
        )?;
        // r2 is NCHW (1, 8, 16, 16); y2 is (T, 8).
        for pos in 0..T {
            for k in 0..8 {
                let a = y2[pos][k];
                let b = r2[k * T + pos];
                max_err = max_err.max((a - b).abs());
            }
        }
        println!(
            "image {img_idx}: CGRA cycles {} (l1 {c1} + l2 {c2}), PJRT cross-check max|Δ| so far {max_err:.2e}",
            c1 + c2
        );
    }

    let wall = wall.elapsed();
    let m = coord.metrics.snapshot();
    let macs: usize = l1.layer.mask.iter().filter(|&&x| x).count()
        + l2.layer.mask.iter().filter(|&&x| x).count();
    println!("\n== end-to-end summary ==");
    println!("images: {n_images}, spatial positions per image: {T}");
    println!("blocks mapped: {} (cache hits {})", m.cache_misses, m.cache_hits);
    println!("total CGRA cycles: {total_cycles} ({} per image)", total_cycles / n_images as u64);
    println!(
        "effective throughput: {:.2} MACs/cycle (fabric peak 16)",
        (macs * T * n_images) as f64 / total_cycles as f64
    );
    println!("PJRT cross-check: max |Δ| = {max_err:.3e} over {} outputs", n_images * T * 8);
    println!("wall time: {wall:?}");
    assert!(max_err < 1e-3, "CGRA and PJRT paths disagree");
    println!("CGRA path == PJRT path ✓ (the three layers compose)");

    // ---- Model ingestion + whole-network pipeline serving --------------
    // The same coordinator serves a whole pruned network through one call:
    // dump text → ingest → register → enqueue_network, with per-layer
    // cycle/COP/MCID attribution in the result.
    use sparsemap::model::{dump_to_string, load_dump, NetworkGraph};
    use sparsemap::sparse::prune::synthetic_pruned_layer;
    let mlp = vec![
        synthetic_pruned_layer("fc1", 6, 8, 0.50, 21)?,
        synthetic_pruned_layer("fc2", 8, 10, 0.55, 22)?,
        synthetic_pruned_layer("fc3", 10, 6, 0.50, 23)?,
    ];
    let dump = load_dump(&dump_to_string("tiny_mlp", &mlp))?;
    let net = NetworkGraph::from_layers(&dump.name, dump.layers)?;
    let reference = net.clone();
    let serving = coord.register_network(net)?;
    println!(
        "\nregistered network {}: {} stage(s), {} tile block(s)",
        serving.name,
        serving.stages.len(),
        serving.block_count()
    );
    let session = coord.session();
    let x: Vec<f32> = (0..reference.input_width()).map(|_| rng.next_normal() as f32).collect();
    let res = session.enqueue_network(&serving.name, &x)?.wait()?;
    for lm in &res.layers {
        println!(
            "  {}: {} block(s), cycles {}, COPs {}, MCIDs {}",
            lm.layer, lm.blocks, lm.cycles, lm.cops, lm.mcids
        );
    }
    let dense = reference.forward(&x);
    let net_err = res
        .outputs
        .iter()
        .zip(&dense)
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    assert!(net_err < 1e-3, "pipeline vs dense forward disagree: {net_err}");
    println!("pipeline serving == dense forward chain ✓ (max |Δ| = {net_err:.3e})");
    let _ = cgra;
    Ok(())
}
