//! Quickstart: map one sparse block with SparseMap, inspect the result,
//! and run it on the cycle-accurate CGRA simulator.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use sparsemap::arch::StreamingCgra;
use sparsemap::mapper::{map_block, MapperOptions};
use sparsemap::sim::simulate_and_check;
use sparsemap::sparse::gen::paper_blocks;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // The paper's evaluation machine: 4×4 PEA, 4 input / 4 output buses,
    // LRF capacity 8, GRF capacity 8.
    let cgra = StreamingCgra::paper_default();

    // "block1" from Table 2: a C4K6 sparse block with 26 operations.
    let nb = &paper_blocks()[0];
    println!("mapping {} (C{}K{}, {} nonzeros)…", nb.label, nb.block.c, nb.block.k, nb.block.nnz());

    let out = map_block(&nb.block, &cgra, &MapperOptions::sparsemap())?;
    println!(
        "  II = {} (MII {}), caching ops = {}, MCIDs = {}, speedup vs dense = {:.2}×",
        out.mapping.ii,
        out.mii,
        out.mapping.cops(),
        out.mapping.mcids(),
        out.speedup(&nb.block, &cgra),
    );

    // Execute 64 loop iterations on the simulated fabric and verify every
    // output against the reference forward pass.
    let res = simulate_and_check(&out.mapping, &nb.block, &cgra, 64, 42)?;
    println!(
        "  simulated {} iterations in {} cycles — throughput {:.3} it/cycle, PE util {:.0}%",
        res.iterations,
        res.cycles,
        res.throughput(),
        100.0 * res.pe_utilization(),
    );
    println!("  outputs verified against the reference ✓");
    Ok(())
}
