//! Design-space sweep (beyond the paper): how SparseMap's achieved II and
//! speedup move with the fabric geometry — the codesign question a
//! downstream user asks before committing to an array size.
//!
//! ```bash
//! cargo run --release --example design_space
//! ```

use sparsemap::arch::StreamingCgra;
use sparsemap::mapper::{map_block, MapperOptions};
use sparsemap::sparse::gen::{paper_blocks, wide_blocks};
use sparsemap::util::table::Table;

fn main() {
    let geometries = [(2usize, 2usize), (4, 4), (4, 8), (8, 8)];
    let mut t = Table::new(["block", "2x2 II(S)", "4x4 II(S)", "4x8 II(S)", "8x8 II(S)"]);
    let opts = MapperOptions::sparsemap();
    for nb in paper_blocks() {
        let mut cells = vec![nb.label.to_string()];
        for &(n, m) in &geometries {
            let cgra = StreamingCgra::new(n, m, 8, 8);
            match map_block(&nb.block, &cgra, &opts) {
                Ok(out) => cells.push(format!(
                    "{} ({:.2}x)",
                    out.mapping.ii,
                    out.speedup(&nb.block, &cgra)
                )),
                Err(_) => cells.push("fail".into()),
            }
        }
        t.row(cells);
    }
    println!("SparseMap across fabric geometries (II and speedup vs dense):\n{t}");
    println!("\nLarger fabrics buy lower II until the I/O buses (reads/writes per\ncycle) become the binding resource — exactly the paper's MII formula.");

    // The wide-kernel-axis class makes that tradeoff vivid: at k = 128 the
    // output buses (N per cycle) bind II long before the PEs do, so extra
    // rows pay off directly while extra columns barely move the needle.
    let wide_opts = MapperOptions::wide();
    let mut tw = Table::new(["block", "4x4 II(S)", "4x8 II(S)", "8x8 II(S)"]);
    for b in wide_blocks() {
        let mut cells = vec![b.name.clone()];
        for &(n, m) in &[(4usize, 4usize), (4, 8), (8, 8)] {
            let cgra = StreamingCgra::new(n, m, 8, 8);
            match map_block(&b, &cgra, &wide_opts) {
                Ok(out) => cells.push(format!(
                    "{} ({:.2}x)",
                    out.mapping.ii,
                    out.speedup(&b, &cgra)
                )),
                Err(_) => cells.push("fail".into()),
            }
        }
        tw.row(cells);
    }
    println!("\nWide blocks (k > 64 kernels / c > 64 channels):\n{tw}");
}
