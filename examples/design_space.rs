//! Design-space sweep (beyond the paper): how SparseMap's achieved II and
//! speedup move with the fabric geometry — the codesign question a
//! downstream user asks before committing to an array size.
//!
//! ```bash
//! cargo run --release --example design_space
//! ```

use sparsemap::arch::StreamingCgra;
use sparsemap::mapper::{map_block, MapperOptions};
use sparsemap::sparse::gen::paper_blocks;
use sparsemap::util::table::Table;

fn main() {
    let geometries = [(2usize, 2usize), (4, 4), (4, 8), (8, 8)];
    let mut t = Table::new(["block", "2x2 II(S)", "4x4 II(S)", "4x8 II(S)", "8x8 II(S)"]);
    let opts = MapperOptions::sparsemap();
    for nb in paper_blocks() {
        let mut cells = vec![nb.label.to_string()];
        for &(n, m) in &geometries {
            let cgra = StreamingCgra::new(n, m, 8, 8);
            match map_block(&nb.block, &cgra, &opts) {
                Ok(out) => cells.push(format!(
                    "{} ({:.2}x)",
                    out.mapping.ii,
                    out.speedup(&nb.block, &cgra)
                )),
                Err(_) => cells.push("fail".into()),
            }
        }
        t.row(cells);
    }
    println!("SparseMap across fabric geometries (II and speedup vs dense):\n{t}");
    println!("\nLarger fabrics buy lower II until the I/O buses (reads/writes per\ncycle) become the binding resource — exactly the paper's MII formula.");
}
