"""AOT path: every variant lowers to parseable, non-trivial HLO text and the
manifest agrees with the declared shapes."""

import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot, model


def test_block_entry_lowers_to_hlo_text():
    specs = (
        jax.ShapeDtypeStruct((64, 8), jnp.float32),
        jax.ShapeDtypeStruct((8, 8), jnp.float32),
        jax.ShapeDtypeStruct((8, 8), jnp.float32),
    )
    text = aot.to_hlo_text(jax.jit(model.make_block_entry()).lower(*specs))
    assert "HloModule" in text
    assert "f32[64,8]" in text and "f32[64,8]{1,0}" in text
    assert "dot" in text  # the MAC made it through


def test_conv_entry_lowers_to_hlo_text():
    specs = (
        jax.ShapeDtypeStruct((1, 4, 16, 16), jnp.float32),
        jax.ShapeDtypeStruct((36, 6), jnp.float32),
        jax.ShapeDtypeStruct((36, 6), jnp.float32),
        jax.ShapeDtypeStruct((6,), jnp.float32),
    )
    text = aot.to_hlo_text(jax.jit(model.make_conv_entry()).lower(*specs))
    assert "HloModule" in text
    assert "f32[1,6,16,16]" in text  # output shape present


def test_build_all_writes_manifest(tmp_path):
    rows = aot.build_all(str(tmp_path))
    assert len(rows) == len(aot.BLOCK_VARIANTS) + len(aot.CONV_VARIANTS)
    names = set()
    for name, fname, dtype, ins, out in rows:
        assert name not in names, "duplicate variant name"
        names.add(name)
        assert dtype == "f32"
        path = tmp_path / fname
        assert path.exists() and path.stat().st_size > 200
        head = path.read_text()[:4096]
        assert "HloModule" in head
        assert ins.count(";") >= 2  # >= 3 inputs per module


def test_manifest_shapes_match_variants(tmp_path):
    rows = aot.build_all(str(tmp_path))
    by_name = {r[0]: r for r in rows}
    for name, t, c, k in aot.BLOCK_VARIANTS:
        ins = by_name[name][3].split(";")
        assert ins[0] == f"{t}x{c}" and ins[1] == f"{c}x{k}"
        assert by_name[name][4] == f"{t}x{k}"
