"""L1 correctness: Pallas sparse-block kernel vs pure-jnp oracle.

hypothesis sweeps shapes/dtypes/sparsity; assert_allclose against ref.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import (
    bias_relu_ref,
    sparse_block_elementwise_ref,
    sparse_block_matmul_ref,
)
from compile.kernels.sparse_block import (
    bias_relu,
    sparse_block_matmul,
    vmem_bytes,
)

jax.config.update("jax_enable_x64", False)


def _case(seed, t, c, k, p_zero, dtype):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((t, c)).astype(dtype)
    w = rng.standard_normal((c, k)).astype(dtype)
    mask = (rng.random((c, k)) >= p_zero).astype(dtype)
    return jnp.asarray(x), jnp.asarray(w), jnp.asarray(mask)


TOL = {np.float32: 1e-5, np.float16: 2e-2}


@pytest.mark.parametrize("t,c,k", [(32, 4, 6), (64, 6, 6), (64, 8, 8), (256, 36, 6), (256, 54, 8)])
def test_kernel_matches_ref_paper_shapes(t, c, k):
    """Every AOT variant shape must match the oracle bit-tight."""
    x, w, mask = _case(0, t, c, k, 0.4, np.float32)
    got = sparse_block_matmul(x, w, mask)
    want = sparse_block_matmul_ref(x, w, mask)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=40, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    t_blocks=st.integers(1, 4),
    c=st.integers(1, 40),
    k=st.integers(1, 24),
    p_zero=st.floats(0.0, 1.0),
)
def test_kernel_matches_ref_hypothesis(seed, t_blocks, c, k, p_zero):
    """Property: for any shape/sparsity, kernel == oracle."""
    t = 32 * t_blocks
    x, w, mask = _case(seed, t, c, k, p_zero, np.float32)
    got = sparse_block_matmul(x, w, mask)
    want = sparse_block_matmul_ref(x, w, mask)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_kernel_f16_inputs(seed):
    """Reduced-precision activations still accumulate in f32."""
    x, w, mask = _case(seed, 32, 8, 8, 0.4, np.float16)
    got = np.asarray(sparse_block_matmul(x, w, mask), dtype=np.float32)
    want = np.asarray(sparse_block_matmul_ref(x, w, mask), dtype=np.float32)
    np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)


def test_matmul_oracle_equals_sdfg_semantics():
    """The matmul oracle is exactly the paper's zero-skipping dataflow."""
    x, w, mask = _case(7, 32, 8, 8, 0.5, np.float32)
    a = sparse_block_matmul_ref(x, w, mask)
    b = sparse_block_elementwise_ref(x, w, mask)
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)


def test_zero_mask_gives_zero_output():
    x, w, _ = _case(1, 32, 8, 8, 0.0, np.float32)
    mask = jnp.zeros_like(w)
    got = sparse_block_matmul(x, w, mask)
    assert np.all(np.asarray(got) == 0.0)


def test_full_mask_equals_dense_matmul():
    x, w, _ = _case(2, 64, 8, 8, 0.0, np.float32)
    mask = jnp.ones_like(w)
    got = sparse_block_matmul(x, w, mask)
    np.testing.assert_allclose(got, x @ w, rtol=1e-5, atol=1e-5)


def test_masked_entries_do_not_contribute():
    """Poison masked weights with NaN-free huge values; output unchanged."""
    x, w, mask = _case(3, 32, 6, 6, 0.4, np.float32)
    w_poison = jnp.where(mask == 0, 1e30, w)
    a = sparse_block_matmul(x, w, mask)
    b = sparse_block_matmul(x, w_poison, mask)
    np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-6)


def test_block_t_variants_agree():
    x, w, mask = _case(4, 128, 8, 8, 0.4, np.float32)
    a = sparse_block_matmul(x, w, mask, block_t=32)
    b = sparse_block_matmul(x, w, mask, block_t=64)
    c = sparse_block_matmul(x, w, mask, block_t=128)
    np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(a, c, rtol=1e-6, atol=1e-6)


def test_shape_validation():
    x, w, mask = _case(5, 32, 4, 6, 0.4, np.float32)
    with pytest.raises(ValueError):
        sparse_block_matmul(x, w[:, :5], mask)
    with pytest.raises(ValueError):
        sparse_block_matmul(x[:31], w, mask)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), k=st.integers(1, 16))
def test_bias_relu_matches_ref(seed, k):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((64, k)).astype(np.float32))
    b = jnp.asarray(rng.standard_normal((k,)).astype(np.float32))
    got = bias_relu(x, b)
    want = bias_relu_ref(x, b)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)
    assert np.all(np.asarray(got) >= 0.0)


def test_vmem_estimate_under_budget():
    """Largest paper block's working set must sit far under 16 MiB VMEM."""
    for t, c, k in [(64, 8, 8), (256, 54, 8)]:
        assert vmem_bytes(t, c, k) < 1 << 20  # < 1 MiB
