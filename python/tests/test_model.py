"""L2 correctness: conv layer (im2col + Pallas block matmul + fused epilogue)
vs jax.lax conv reference; im2col structural properties."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model


def _layer_case(seed, n, cin, h, w, cout, p_zero=0.4):
    rng = np.random.default_rng(seed)
    img = jnp.asarray(rng.standard_normal((n, cin, h, w)).astype(np.float32))
    wt = jnp.asarray(rng.standard_normal((cin * 9, cout)).astype(np.float32))
    mask = jnp.asarray((rng.random((cin * 9, cout)) >= p_zero).astype(np.float32))
    b = jnp.asarray(rng.standard_normal((cout,)).astype(np.float32))
    return img, wt, mask, b


@pytest.mark.parametrize("n,cin,h,w,cout", [(1, 4, 16, 16, 6), (1, 6, 16, 16, 8), (2, 3, 8, 16, 4)])
def test_conv_layer_matches_lax_ref(n, cin, h, w, cout):
    img, wt, mask, b = _layer_case(0, n, cin, h, w, cout)
    got = model.conv_layer_fwd(img, wt, mask, b)
    want = model.conv_layer_ref(img, wt, mask, b)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    cin=st.integers(1, 6),
    cout=st.integers(1, 8),
    p_zero=st.floats(0.0, 0.9),
)
def test_conv_layer_hypothesis(seed, cin, cout, p_zero):
    img, wt, mask, b = _layer_case(seed, 1, cin, 16, 16, cout, p_zero)
    got = model.conv_layer_fwd(img, wt, mask, b)
    want = model.conv_layer_ref(img, wt, mask, b)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_im2col_shape_and_center_column():
    img, _, _, _ = _layer_case(1, 2, 3, 8, 8, 4)
    patches = model.im2col(img, 3, 3)
    assert patches.shape == (2 * 8 * 8, 3 * 9)
    # The center tap (dy=1, dx=1) of channel c is the image itself.
    center = np.asarray(patches).reshape(2, 8, 8, 3, 9)[:, :, :, :, 4]
    np.testing.assert_allclose(center, np.transpose(np.asarray(img), (0, 2, 3, 1)))


def test_im2col_zero_padding_borders():
    img = jnp.ones((1, 1, 4, 4), dtype=jnp.float32)
    patches = np.asarray(model.im2col(img, 3, 3)).reshape(4, 4, 9)
    # Top-left pixel: taps reaching outside the image are zero.
    assert patches[0, 0, 0] == 0.0 and patches[0, 0, 4] == 1.0
    # Interior pixel: all 9 taps inside.
    assert np.all(patches[1, 1, :] == 1.0)


def test_sparse_block_fwd_is_kernel():
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((64, 8)).astype(np.float32))
    w = jnp.asarray(rng.standard_normal((8, 8)).astype(np.float32))
    mask = jnp.asarray((rng.random((8, 8)) >= 0.4).astype(np.float32))
    got = model.sparse_block_fwd(x, w, mask)
    np.testing.assert_allclose(got, x @ (w * mask), rtol=1e-5, atol=1e-5)
