"""L2 — JAX model of the compute the streaming CGRA accelerates.

The paper partitions a sparse conv layer into *sparse blocks*; each block
computes ``K`` output kernels from ``C`` input channels with a 0/1 weight
mask, streamed over all spatial positions.  This module expresses:

  * ``sparse_block_fwd`` — a single block over a stream of T positions
    (exactly the s-DFG the rust mapper schedules), built on the L1 Pallas
    kernel so both lower into one HLO module;
  * ``conv_layer_fwd`` — a full 3x3 block-sparse conv layer (im2col +
    blocked masked matmul + fused bias/ReLU) for the end-to-end example;
  * the AOT entry points used by ``aot.py``.

Everything here is build-time Python; the rust coordinator only ever sees
the lowered HLO text in ``artifacts/``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile.kernels.sparse_block import bias_relu, sparse_block_matmul


def sparse_block_fwd(x, w, mask):
    """One sparse block over a stream: ``(T, C) -> (T, K)``."""
    return sparse_block_matmul(x, w, mask)


def im2col(img, kh: int, kw: int):
    """NCHW image -> (N*H*W, C*kh*kw) patch matrix (SAME zero padding).

    This is the streaming-access transformation: the CGRA's data memories
    stream patch elements onto the input buses; here it linearizes the same
    access pattern for the MXU.
    """
    n, c, h, w = img.shape
    ph, pw = kh // 2, kw // 2
    padded = jnp.pad(img, ((0, 0), (0, 0), (ph, ph), (pw, pw)))
    cols = []
    for dy in range(kh):
        for dx in range(kw):
            cols.append(padded[:, :, dy : dy + h, dx : dx + w])
    # (kh*kw, N, C, H, W) -> (N, H, W, C, kh*kw) -> (N*H*W, C*kh*kw)
    stack = jnp.stack(cols, axis=0)
    stack = jnp.transpose(stack, (1, 3, 4, 2, 0))
    return stack.reshape(n * h * w, c * kh * kw)


def conv_layer_fwd(img, w, mask, b):
    """Block-sparse 2D conv layer with fused bias+ReLU.

    Args:
      img: ``(N, Cin, H, W)`` activations.
      w: ``(Cin*kh*kw, Cout)`` im2col-flattened weights.
      mask: same shape 0/1 sparsity pattern.
      b: ``(Cout,)`` bias.

    Returns:
      ``(N, Cout, H, W)`` post-ReLU activations.
    """
    n, cin, h, wd = img.shape
    patches = im2col(img, 3, 3)
    y = sparse_block_matmul(patches, w, mask)
    y = bias_relu(y, b)
    cout = w.shape[1]
    return jnp.transpose(y.reshape(n, h, wd, cout), (0, 3, 1, 2))


def conv_layer_ref(img, w, mask, b):
    """lax-conv reference for ``conv_layer_fwd`` (used in pytest)."""
    cout = w.shape[1]
    cin = img.shape[1]
    wm = (w * mask).reshape(cin, 3, 3, cout)  # matches im2col (c, dy, dx) order
    wm = jnp.transpose(wm, (3, 0, 1, 2))  # OIHW
    y = jax.lax.conv_general_dilated(
        img, wm, window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
    return jnp.maximum(y + b[None, :, None, None], 0.0)


# ---------------------------------------------------------------------------
# AOT entry points (shapes fixed at lowering time; see aot.py)
# ---------------------------------------------------------------------------

def make_block_entry():
    """Returns fn(x, w, mask) -> (y,) for a sparse block — 1-tuple output,
    matching the rust loader's ``to_tuple1`` convention."""

    def entry(x, w, mask):
        return (sparse_block_fwd(x, w, mask),)

    return entry


def make_conv_entry():
    """Returns fn(img, w, mask, b) -> (y,) for a conv layer."""

    def entry(img, w, mask, b):
        return (conv_layer_fwd(img, w, mask, b),)

    return entry
