"""L1 — Pallas kernel for the sparse-block MAC hot-spot.

The streaming CGRA in the paper executes, per loop iteration, one sparse
block: ``y[k] = sum_c w[c, k] * x[c]`` with zero-weight multiplications
skipped.  On TPU the analogous hot-spot is a masked (C, K) weight panel kept
resident in VMEM while activations stream through the MXU in (T_BLK, C)
tiles — ``BlockSpec`` plays the role the paper's input buses play in time
(the HBM->VMEM schedule), and the MXU systolic array plays the role of the
spatial PEA.  See DESIGN.md §Hardware-Adaptation.

The kernel is lowered with ``interpret=True`` everywhere in this repo: the
CPU PJRT plugin cannot run Mosaic custom-calls, and correctness (vs
``ref.py``) is the build-time contract.  Real-TPU performance is estimated
from the VMEM footprint / MXU-utilization analysis in DESIGN.md.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile along the streaming (spatial-position) dimension.  64 rows of
# bf16/f32 activations keep the tile MXU-shaped (the MXU is 128x128; a 64-row
# tile at C<=128 underfills it, but the paper's blocks are tiny — the win is
# keeping the masked weight panel resident across the whole stream).
DEFAULT_BLOCK_T = 32


def _masked_matmul_kernel(x_ref, w_ref, m_ref, o_ref):
    """One grid step: o = x_tile @ (w * mask).

    ``w * mask`` is recomputed per tile rather than materialized in HBM: the
    panel is tiny (<= 64x64) and fusing the mask keeps a single VMEM copy of
    the weights, mirroring the paper's pre-loading of nonzero weights into
    PE-local LRFs.
    """
    w = w_ref[...] * m_ref[...]
    o_ref[...] = jnp.dot(
        x_ref[...], w, preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_t", "interpret"))
def sparse_block_matmul(x, w, mask, *, block_t=DEFAULT_BLOCK_T, interpret=True):
    """Streamed sparse-block forward: ``(T, C) @ ((C, K) * mask) -> (T, K)``.

    Args:
      x: ``(T, C)`` activations — T streaming positions (the CGRA's loop
        iterations), C input channels (the block's input readings ``V_R``).
      w: ``(C, K)`` block weights — K kernels (the block's output writings
        ``V_W``).
      mask: ``(C, K)`` 0/1 sparsity pattern (nonzero == a multiplication node
        in the s-DFG).
      block_t: tile height along T; T must be divisible by it.
      interpret: must stay True off-TPU (see module docstring).

    Returns:
      ``(T, K)`` outputs with the same dtype as ``x``.
    """
    t, c = x.shape
    c2, k = w.shape
    if c != c2 or mask.shape != w.shape:
        raise ValueError(f"shape mismatch: x={x.shape} w={w.shape} mask={mask.shape}")
    if t % block_t != 0:
        raise ValueError(f"T={t} not divisible by block_t={block_t}")
    grid = (t // block_t,)
    return pl.pallas_call(
        _masked_matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_t, c), lambda i: (i, 0)),
            # Weight/mask panels are re-fetched per grid step by index-map
            # (0, 0) — Pallas keeps them VMEM-resident across steps.
            pl.BlockSpec((c, k), lambda i: (0, 0)),
            pl.BlockSpec((c, k), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_t, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, k), x.dtype),
        interpret=interpret,
    )(x, w, mask)


def _bias_act_kernel(x_ref, b_ref, o_ref):
    """Fused bias + ReLU epilogue tile."""
    o_ref[...] = jnp.maximum(x_ref[...] + b_ref[...], 0.0).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_t", "interpret"))
def bias_relu(x, b, *, block_t=DEFAULT_BLOCK_T, interpret=True):
    """Fused ``relu(x + b)`` over a ``(T, K)`` stream (layer epilogue)."""
    t, k = x.shape
    if b.shape != (k,):
        raise ValueError(f"bias shape {b.shape} != ({k},)")
    if t % block_t != 0:
        raise ValueError(f"T={t} not divisible by block_t={block_t}")
    return pl.pallas_call(
        _bias_act_kernel,
        grid=(t // block_t,),
        in_specs=[
            pl.BlockSpec((block_t, k), lambda i: (i, 0)),
            pl.BlockSpec((k,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_t, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, k), x.dtype),
        interpret=interpret,
    )(x, b)


def vmem_bytes(t_blk: int, c: int, k: int, dtype_bytes: int = 4) -> int:
    """Static VMEM footprint estimate for one grid step of the MAC kernel.

    x tile + w panel + mask panel + out tile (double-buffered inputs).
    Used by DESIGN.md's roofline discussion and the perf tests.
    """
    x_tile = t_blk * c * dtype_bytes
    panels = 2 * c * k * dtype_bytes
    out_tile = t_blk * k * dtype_bytes
    return 2 * (x_tile + panels) + out_tile
