"""Pure-jnp oracles for the Pallas kernels — the build-time correctness
contract.  Everything in here must be boring, obviously-correct jnp."""

from __future__ import annotations

import jax.numpy as jnp


def sparse_block_matmul_ref(x, w, mask):
    """``(T, C) @ ((C, K) * mask)`` with f32 accumulation."""
    wm = (w * mask).astype(jnp.float32)
    return jnp.dot(x.astype(jnp.float32), wm).astype(x.dtype)


def bias_relu_ref(x, b):
    """``relu(x + b)``."""
    return jnp.maximum(x + b[None, :], 0.0).astype(x.dtype)


def sparse_block_elementwise_ref(x, w, mask):
    """The s-DFG semantics, literally: per output kernel k, accumulate only
    the multiplications whose weight is nonzero.  Slow, used in tests to pin
    down that the matmul oracle equals the paper's zero-skipping dataflow."""
    t, c = x.shape
    _, k = w.shape
    out = jnp.zeros((t, k), dtype=jnp.float32)
    for kk in range(k):
        acc = jnp.zeros((t,), dtype=jnp.float32)
        for cc in range(c):
            acc = acc + jnp.where(
                mask[cc, kk] != 0, x[:, cc].astype(jnp.float32) * w[cc, kk], 0.0
            )
        out = out.at[:, kk].set(acc)
    return out.astype(x.dtype)
