"""AOT compile path: lower the L2 model (which embeds the L1 Pallas kernel,
interpret=True) to **HLO text** and write ``artifacts/``.

HLO *text* — not ``lowered.compile().serialize()`` and not a serialized
``HloModuleProto`` — is the interchange format: jax >= 0.5 emits protos with
64-bit instruction ids which xla_extension 0.5.1 (behind the rust ``xla``
0.1.6 crate) rejects; the text parser reassigns ids and round-trips cleanly.

Run once via ``make artifacts``; python never appears on the request path.

Outputs:
  artifacts/<name>.hlo.txt           one per model variant
  artifacts/manifest.tsv             name \t file \t dtype \t in-shapes \t out-shape
"""

from __future__ import annotations

import argparse
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _shape_str(shapes) -> str:
    return ";".join("x".join(str(d) for d in s) for s in shapes)


# Sparse-block AOT variants: one per distinct (C, K) appearing in the paper's
# Table 2, streamed in T=64-position chunks.  The rust runtime picks the
# variant matching the block and pads the position stream to a multiple of T.
BLOCK_VARIANTS = [
    ("sb_c4k6", 64, 4, 6),
    ("sb_c6k6", 64, 6, 6),
    ("sb_c8k8", 64, 8, 8),
    # im2col'd 3x3 conv blocks for the e2e CNN (Cin*9 -> Cout).
    ("sb_c36k6", 256, 36, 6),
    ("sb_c54k8", 256, 54, 8),
]

# Conv-layer AOT variants for the e2e example: (name, N, Cin, H, W, Cout).
CONV_VARIANTS = [
    ("conv_l1_c4k6_16x16", 1, 4, 16, 16, 6),
    ("conv_l2_c6k8_16x16", 1, 6, 16, 16, 8),
]


def build_all(out_dir: str) -> list[tuple[str, str, str, str, str]]:
    rows = []
    f32 = jnp.float32

    for name, t, c, k in BLOCK_VARIANTS:
        entry = model.make_block_entry()
        specs = (
            jax.ShapeDtypeStruct((t, c), f32),
            jax.ShapeDtypeStruct((c, k), f32),
            jax.ShapeDtypeStruct((c, k), f32),
        )
        text = to_hlo_text(jax.jit(entry).lower(*specs))
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        rows.append((name, fname, "f32",
                     _shape_str([s.shape for s in specs]), f"{t}x{k}"))

    for name, n, cin, h, w, cout in CONV_VARIANTS:
        entry = model.make_conv_entry()
        specs = (
            jax.ShapeDtypeStruct((n, cin, h, w), f32),
            jax.ShapeDtypeStruct((cin * 9, cout), f32),
            jax.ShapeDtypeStruct((cin * 9, cout), f32),
            jax.ShapeDtypeStruct((cout,), f32),
        )
        text = to_hlo_text(jax.jit(entry).lower(*specs))
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        rows.append((name, fname, "f32",
                     _shape_str([s.shape for s in specs]),
                     f"{n}x{cout}x{h}x{w}"))
    return rows


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifacts directory")
    args = ap.parse_args()
    out_dir = args.out
    os.makedirs(out_dir, exist_ok=True)
    rows = build_all(out_dir)
    with open(os.path.join(out_dir, "manifest.tsv"), "w") as f:
        for row in rows:
            f.write("\t".join(row) + "\n")
    for name, fname, _, ins, out in rows:
        print(f"aot: {name:24s} in=[{ins}] out={out} -> {fname}")
    print(f"aot: wrote {len(rows)} modules + manifest.tsv to {out_dir}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
