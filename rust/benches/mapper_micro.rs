//! Micro-benchmarks of every stage of the mapping pipeline plus the
//! simulator — the profile that drives the §Perf optimization loop in
//! EXPERIMENTS.md.
//!
//! ```bash
//! cargo bench --bench mapper_micro
//! ```

use sparsemap::arch::StreamingCgra;
use sparsemap::bind::{bind, conflict, mis, route, BusCostModel};
use sparsemap::config::Techniques;
use sparsemap::dfg::analysis::mii;
use sparsemap::dfg::build::build_sdfg;
use sparsemap::sched::{baseline, sparsemap as sm_sched};
use sparsemap::sim::simulate_and_check;
use sparsemap::sparse::gen::paper_blocks;
use sparsemap::util::bench::{black_box, BenchConfig, Bencher};

fn main() {
    let cgra = StreamingCgra::paper_default();
    let mut b = Bencher::with_config(BenchConfig {
        warmup_ns: 50_000_000,
        measure_ns: 300_000_000,
        samples: 8,
    });

    // Representative small (block1) and large (block5) workloads.
    for label in ["block1", "block5"] {
        let nb = paper_blocks().into_iter().find(|n| n.label == label).unwrap();
        let (g, _) = build_sdfg(&nb.block);
        let base = mii(&g, &cgra);

        b.bench(&format!("{label}/build_sdfg"), || {
            black_box(build_sdfg(&nb.block));
        });
        b.bench(&format!("{label}/schedule(sparsemap)"), || {
            let ii = if label == "block1" { base } else { base + 1 };
            black_box(sm_sched::schedule_at(&g, &cgra, Techniques::all(), ii).ok());
        });
        b.bench(&format!("{label}/schedule(baseline)"), || {
            black_box(baseline::schedule_at(&g, &cgra, base + 1).ok());
        });

        // A routable schedule for downstream stages.
        let s = (base..base + 3)
            .find_map(|ii| {
                let s = sm_sched::schedule_at(&g, &cgra, Techniques::all(), ii).ok()?;
                route::preallocate(&s, &cgra).ok()?;
                Some(s)
            })
            .expect("routable schedule");
        let plan = route::preallocate(&s, &cgra).unwrap();
        b.bench(&format!("{label}/route_preallocate"), || {
            black_box(route::preallocate(&s, &cgra).ok());
        });
        b.bench(&format!("{label}/conflict_graph"), || {
            black_box(conflict::build(&s, &cgra, &plan));
        });
        let cg = conflict::build(&s, &cgra, &plan);
        let routes: Vec<_> = (0..s.g.edges().len()).map(|i| plan.route(i)).collect();
        b.bench(&format!("{label}/sbts_solve"), || {
            let mut cost = BusCostModel::new(&s, &cg, &routes);
            black_box(mis::solve_with(&cg, 30_000, 42, &mut cost));
        });
        // The straight-line schedule above may not bind for the densest
        // blocks; bench the simulator on the mapper's (phase-④) result.
        let mapping = sparsemap::mapper::map_block(
            &nb.block,
            &cgra,
            &sparsemap::mapper::MapperOptions::sparsemap(),
        )
        .expect("map_block")
        .mapping;
        let _ = bind; // bind() itself is covered via sbts_solve above
        b.bench(&format!("{label}/simulate_64it"), || {
            black_box(simulate_and_check(&mapping, &nb.block, &cgra, 64, 7).unwrap());
        });
    }
}
