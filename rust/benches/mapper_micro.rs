//! Micro-benchmarks of every stage of the mapping pipeline plus the
//! simulator — the profile that drives the §Perf optimization loop in
//! EXPERIMENTS.md. Results are merged into `BENCH_mapper.json` at the
//! repo root so the perf trajectory is tracked across PRs.
//!
//! ```bash
//! cargo bench --bench mapper_micro
//! ```

use sparsemap::arch::StreamingCgra;
use sparsemap::bind::oracle;
use sparsemap::bind::{self, conflict, mis, route, BusCostModel, SecondaryCost};
use sparsemap::config::Techniques;
use sparsemap::dfg::analysis::{mii, AssociationMatrix};
use sparsemap::dfg::build::build_sdfg;
use sparsemap::dfg::oracle as dfg_oracle;
use sparsemap::mapper::{map_block, map_bundle, MapperOptions};
use sparsemap::sched::{baseline, sparsemap as sm_sched};
use sparsemap::sim::{
    execute_plan_lanes_with, simulate_and_check, simulate_fused, ExecPlan, ExecScratch,
    MemberSegment,
};
use sparsemap::sparse::gen::{fused3_bundle, paper_blocks, wide_blocks};
use sparsemap::sparse::SparseBlock;
use sparsemap::util::bench::{black_box, repo_root_path, BenchConfig, Bencher};
use sparsemap::util::rng::Pcg64;

fn main() {
    let cgra = StreamingCgra::paper_default();
    let mut b = Bencher::with_config(BenchConfig {
        warmup_ns: 50_000_000,
        measure_ns: 300_000_000,
        samples: 8,
    });

    // Representative small (block1) and large (block5) workloads.
    for label in ["block1", "block5"] {
        let nb = paper_blocks().into_iter().find(|n| n.label == label).unwrap();
        let (g, _) = build_sdfg(&nb.block);
        let base = mii(&g, &cgra);

        b.bench(&format!("{label}/build_sdfg"), || {
            black_box(build_sdfg(&nb.block));
        });
        // Association matrix on the k ≤ 64 inline fast path, vs the naive
        // set-based oracle — the regression guard for the KernelMask spill.
        b.bench(&format!("{label}/assoc_build"), || {
            black_box(AssociationMatrix::build(&g));
        });
        b.bench(&format!("{label}/assoc_build_naive"), || {
            black_box(dfg_oracle::build_naive(&g));
        });
        b.bench(&format!("{label}/schedule(sparsemap)"), || {
            let ii = if label == "block1" { base } else { base + 1 };
            black_box(sm_sched::schedule_at(&g, &cgra, Techniques::all(), ii).ok());
        });
        b.bench(&format!("{label}/schedule(baseline)"), || {
            black_box(baseline::schedule_at(&g, &cgra, base + 1).ok());
        });

        // A routable schedule for downstream stages.
        let s = (base..base + 3)
            .find_map(|ii| {
                let s = sm_sched::schedule_at(&g, &cgra, Techniques::all(), ii).ok()?;
                route::preallocate(&s, &cgra).ok()?;
                Some(s)
            })
            .expect("routable schedule");
        let plan = route::preallocate(&s, &cgra).unwrap();
        b.bench(&format!("{label}/route_preallocate"), || {
            black_box(route::preallocate(&s, &cgra).ok());
        });
        // Bucketed build vs the retired all-pairs oracle: the former must
        // scale with bucket sizes, the latter is O(nc²) in candidates —
        // this pair of rows is the trajectory evidence for the rewrite.
        b.bench(&format!("{label}/conflict_graph"), || {
            black_box(conflict::build(&s, &cgra, &plan));
        });
        b.bench(&format!("{label}/conflict_graph_naive"), || {
            black_box(oracle::build_naive(&s, &cgra, &plan));
        });
        // The reuse path the mapper actually runs: same graph, recycled
        // storage (graph + candidate buckets).
        let mut cg_scratch = conflict::ConflictGraph::empty();
        let mut bucket_scratch = conflict::BucketScratch::new();
        b.bench(&format!("{label}/conflict_graph_reused"), || {
            conflict::build_into(&s, &cgra, &plan, &mut cg_scratch, &mut bucket_scratch);
            black_box(cg_scratch.num_candidates());
        });
        let cg = conflict::build(&s, &cgra, &plan);
        let routes: Vec<_> = (0..s.g.edges().len()).map(|i| plan.route(i)).collect();
        // Secondary-objective cost model: dense slot-major array vs the
        // retired HashMap model, exercised through a full claim rebuild.
        let assign: Vec<usize> = cg.of_node.iter().map(|c| c[0]).collect();
        let mut dense_cost = BusCostModel::new(&s, &cg, &routes, &cgra);
        b.bench(&format!("{label}/bus_cost_reset_dense"), || {
            dense_cost.reset(&assign);
            black_box(dense_cost.total());
        });
        let mut hash_cost = oracle::HashBusCostModel::new(&s, &cg, &routes);
        b.bench(&format!("{label}/bus_cost_reset_hash"), || {
            hash_cost.reset(&assign);
            black_box(hash_cost.total());
        });
        b.bench(&format!("{label}/sbts_solve"), || {
            let mut cost = BusCostModel::new(&s, &cg, &routes, &cgra);
            black_box(mis::solve_with(&cg, 30_000, 42, &mut cost));
        });
        let mut solver_scratch = mis::SolverScratch::new();
        b.bench(&format!("{label}/sbts_solve_scratch"), || {
            let mut cost = BusCostModel::new(&s, &cg, &routes, &cgra);
            black_box(mis::solve_with_scratch(&cg, 30_000, 42, &mut cost, &mut solver_scratch));
        });
        // Full bind stage (route + conflict + SBTS + verify) against one
        // reusable arena — the per-attempt unit of the portfolio.
        let mut pool = bind::ScratchPool::new();
        b.bench(&format!("{label}/bind_with_scratch"), || {
            black_box(bind::bind_with(&s, &cgra, 30_000, 42, &mut pool).ok());
        });

        // Cold-start mapping: the coordinator's cache-miss path, sequential
        // vs portfolio (the deterministic parallel search; identical output,
        // latency is the point).
        let seq = MapperOptions::sparsemap().with_parallelism(1);
        b.bench(&format!("{label}/map_block_seq"), || {
            black_box(map_block(&nb.block, &cgra, &seq).ok());
        });
        let par = MapperOptions::sparsemap().with_parallelism(4);
        b.bench(&format!("{label}/map_block_par4"), || {
            black_box(map_block(&nb.block, &cgra, &par).ok());
        });

        let mapping = map_block(&nb.block, &cgra, &MapperOptions::sparsemap())
            .expect("map_block")
            .mapping;
        b.bench(&format!("{label}/simulate_64it"), || {
            black_box(simulate_and_check(&mapping, &nb.block, &cgra, 64, 7).unwrap());
        });
    }

    // Wide-kernel-axis rows: the KernelMask spill path (k > 64) and the
    // wide-block cold-start mapping, so the cost of lifting the 64-kernel
    // limit stays tracked in BENCH_mapper.json. Smaller budget — one wide
    // map_block is orders of magnitude above the micro rows.
    let mut bw = Bencher::with_config(BenchConfig {
        warmup_ns: 20_000_000,
        measure_ns: 120_000_000,
        samples: 4,
    });
    for wb in wide_blocks() {
        if !matches!(wb.name.as_str(), "wide_k128" | "wide_k256") {
            continue;
        }
        let (g, _) = build_sdfg(&wb);
        bw.bench(&format!("{}/assoc_build", wb.name), || {
            black_box(AssociationMatrix::build(&g));
        });
        bw.bench(&format!("{}/assoc_build_naive", wb.name), || {
            black_box(dfg_oracle::build_naive(&g));
        });
    }
    let wide = wide_blocks().into_iter().find(|wb| wb.name == "wide_k128").unwrap();
    let wide_opts = MapperOptions::wide().with_parallelism(4);
    bw.bench("wide_k128/map_block_par4", || {
        black_box(map_block(&wide, &cgra, &wide_opts).ok());
    });
    let wide_mapping = map_block(&wide, &cgra, &wide_opts).expect("wide_k128 maps").mapping;
    bw.bench("wide_k128/simulate_8it", || {
        black_box(simulate_and_check(&wide_mapping, &wide, &cgra, 8, 7).unwrap());
    });

    // Hot-bus query at a wide-class II: wide_k256's II ≈ k/4 makes the
    // dense bus array (II × 8 states) enormous while the hot set stays a
    // handful — the regime the incremental hot-bus index (PR 4) targets.
    // Dense row = incremental index; hash row = the oracle's rescan.
    let wide256 = wide_blocks().into_iter().find(|wb| wb.name == "wide_k256").unwrap();
    let (g256, _) = build_sdfg(&wide256);
    let base256 = mii(&g256, &cgra);
    let routable256 = (base256..base256 + 16).find_map(|ii| {
        let s = sm_sched::schedule_at(&g256, &cgra, Techniques::all(), ii).ok()?;
        let plan = route::preallocate(&s, &cgra).ok()?;
        Some((s, plan))
    });
    if let Some((s256, plan256)) = routable256 {
        let cg256 = conflict::build(&s256, &cgra, &plan256);
        let routes256: Vec<_> = (0..s256.g.edges().len()).map(|i| plan256.route(i)).collect();
        let assign256: Vec<usize> = cg256.of_node.iter().map(|c| c[0]).collect();
        let mut buf = Vec::new();
        let mut dense256 = BusCostModel::new(&s256, &cg256, &routes256, &cgra);
        dense256.reset(&assign256);
        bw.bench("wide_k256/bus_hot_scan_dense", || {
            buf.clear();
            dense256.hot_nodes_into(&assign256, &mut buf);
            black_box(buf.len());
        });
        let mut hash256 = oracle::HashBusCostModel::new(&s256, &cg256, &routes256);
        hash256.reset(&assign256);
        bw.bench("wide_k256/bus_hot_scan_hash", || {
            buf.clear();
            hash256.hot_nodes_into(&assign256, &mut buf);
            black_box(buf.len());
        });
    } else {
        eprintln!("wide_k256: no routable schedule in II slack — hot-scan rows skipped");
    }

    // Fused-bundle rows: the canonical three-small-block bundle's
    // cold-start mapping and a fused simulation advancing all members.
    let bundle = fused3_bundle();
    let fused_opts = MapperOptions::fused().with_parallelism(4);
    bw.bench("fused3/map_bundle_par4", || {
        black_box(map_bundle(&bundle, &cgra, &fused_opts).ok());
    });
    let fused_out = map_bundle(&bundle, &cgra, &fused_opts).expect("fused3 maps");
    let mut rng = Pcg64::seeded(7);
    let streams: Vec<Vec<Vec<f32>>> = bundle
        .blocks
        .iter()
        .map(|blk| {
            (0..8)
                .map(|_| (0..blk.c).map(|_| rng.next_normal() as f32).collect())
                .collect()
        })
        .collect();
    let members: Vec<&SparseBlock> = bundle.blocks.iter().map(|b| b.as_ref()).collect();
    let xs: Vec<&[Vec<f32>]> = streams.iter().map(|s| s.as_slice()).collect();
    bw.bench("fused3/simulate_8it", || {
        black_box(
            simulate_fused(&fused_out.mapping, &fused_out.tags, &members, &cgra, &xs).unwrap(),
        );
    });
    // Plan compilation: the one-time cost the coordinator pays at
    // registration to serve every later window off the compiled backend.
    bw.bench("fused3/plan_compile", || {
        black_box(ExecPlan::for_outcome(&fused_out, &cgra).unwrap());
    });
    // The PlanOp sweep in isolation, scalar vs 8-wide lanes, through one
    // pooled scratch (the worker steady state): lanes1 vs lanes8 is the
    // microarchitectural win of evaluating the window's iterations as
    // contiguous lanes instead of one at a time.
    let fused_plan = ExecPlan::for_outcome(&fused_out, &cgra).unwrap();
    let batches: Vec<Vec<MemberSegment<'_>>> = members
        .iter()
        .zip(&streams)
        .map(|(blk, s)| vec![MemberSegment { block: *blk, xs: s.as_slice() }])
        .collect();
    let mut scratch = ExecScratch::new();
    bw.bench("fused3/plan_sweep_lanes1", || {
        black_box(
            execute_plan_lanes_with(&fused_plan, &members, &batches, 1, &mut scratch).unwrap(),
        );
    });
    bw.bench("fused3/plan_sweep_lanes8", || {
        black_box(
            execute_plan_lanes_with(&fused_plan, &members, &batches, 8, &mut scratch).unwrap(),
        );
    });
    b.results.extend(bw.results);

    let json = repo_root_path("BENCH_mapper.json");
    match b.write_json(&json) {
        Ok(()) => println!("\nwrote {json}"),
        Err(e) => eprintln!("\nfailed to write {json}: {e}"),
    }
}
