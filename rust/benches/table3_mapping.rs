//! Bench: regenerates the paper's **Table 2** (block features) and
//! **Table 3** (mapping comparison, baselines [6][12] vs SparseMap) and
//! times the full mapping pipeline per block.
//!
//! ```bash
//! cargo bench --bench table3_mapping
//! ```
//!
//! Paper reference rows (Table 3): SparseMap reaches the MII in the first
//! mapping attempt for every block; the baselines fail "block5"/"block7"
//! outright and pay 40 COPs / 63 MCIDs vs SparseMap's 3 / 34.

use sparsemap::arch::StreamingCgra;
use sparsemap::mapper::{map_block, MapperOptions};
use sparsemap::report;
use sparsemap::sparse::gen::paper_blocks;
use sparsemap::util::bench::{BenchConfig, Bencher};

fn main() {
    let cgra = StreamingCgra::paper_default();

    println!("== Table 2: block features ==\n{}\n", report::table2());

    println!("== Table 3: mapping result comparison ==");
    let (table, base_rows, sm_rows) = report::table3(&cgra);
    println!("{table}\n");
    let (bc, bm) = report::totals(&base_rows);
    let (sc, sm) = report::totals(&sm_rows);
    println!(
        "totals (first attempts): baseline |C|={bc} |M|={bm} → sparsemap |C|={sc} |M|={sm} \
         (COPs ↓{:.1}%, MCIDs ↓{:.1}%)",
        100.0 * (1.0 - sc as f64 / bc.max(1) as f64),
        100.0 * (1.0 - sm as f64 / bm.max(1) as f64),
    );
    println!("paper: COPs 40 → 3 (↓92.5%), MCIDs 63 → 34 (↓46.0%)\n");

    // Timing: end-to-end map_block per paper block (the compile-path hot
    // loop of the coordinator).
    println!("== mapping latency (schedule + route + CG + SBTS + verify) ==");
    let mut b = Bencher::with_config(BenchConfig {
        warmup_ns: 10_000_000,
        measure_ns: 100_000_000,
        samples: 3,
    });
    let opts = MapperOptions::sparsemap();
    for nb in paper_blocks() {
        b.bench(&format!("map/{}", nb.label), || {
            let _ = map_block(&nb.block, &cgra, &opts);
        });
    }
}
