//! Bench: regenerates the paper's **Table 4** — the ablation of AIBA,
//! Mul-CI and RID-AT over the seven evaluation blocks.
//!
//! ```bash
//! cargo bench --bench table4_ablation
//! ```
//!
//! Paper reference: Mul-CI removes nearly all COPs; RID-AT then cuts the
//! remaining MCIDs roughly in half (e.g. block5: 23 → 13 → 8).

use sparsemap::arch::StreamingCgra;
use sparsemap::report;

fn main() {
    let cgra = StreamingCgra::paper_default();
    println!("== Table 4: impact of technique combinations ==");
    let (table, rows) = report::table4(&cgra);
    println!("{table}\n");
    let names = ["AIBA", "AIBA+Mul-CI", "AIBA+Mul-CI+RID-AT"];
    for (name, rows) in names.iter().zip(&rows) {
        let cops: usize = rows.iter().filter_map(|r| r.cops0).sum();
        let mcids: usize = rows.iter().filter_map(|r| r.mcids0).sum();
        let fails = rows.iter().filter(|r| r.final_ii.is_none()).count();
        println!("{name:22}: total |C|={cops:3} |M|={mcids:3} failed blocks={fails}");
    }
}
