//! Bench: coordinator serving throughput — the end-to-end request path
//! (mapping cache + CGRA simulation) under a mixed-block request stream,
//! across worker counts. This is the system-level headline the paper's
//! throughput claim translates to on this testbed. Per-request wall time
//! is merged into `BENCH_mapper.json` alongside the mapper micro-benches.
//!
//! All traffic goes through the session/ticket API. The fused3 scenario
//! carries three rows: `per_request` (window size 1 — the old
//! per-member-serial semantics, one whole-bundle pass per request),
//! `batched_request` (default batching — requests amortize one lockstep
//! pass per window) and `window8` (one full 8-request window end to end);
//! the `window8_compiled` / `window8_lanes` twins price the plan path
//! scalar vs lane-vectorized on the same window shape (and `wide_k128`
//! gets its own `window8_lanes` row via a single-member bundle).
//! The sharded scenario prices the same window shape on a two-shard
//! topology (`window8_x2shards`: both pools serving concurrently) and the
//! cross-session window path (`cross_session_window8`: eight sessions
//! forming one shared window per round).
//!
//! ```bash
//! cargo bench --bench serving_throughput
//! ```

use std::sync::Arc;
use std::time::{Duration, Instant};

use sparsemap::config::{SimBackend, SparsemapConfig};
use sparsemap::coordinator::{Coordinator, ServeError, Ticket};
use sparsemap::sparse::gen::{fused3_bundle, paper_blocks, wide_blocks};
use sparsemap::sparse::SparseBlock;
use sparsemap::util::bench::{repo_root_path, write_json_merged, BenchResult};
use sparsemap::util::rng::Pcg64;
use sparsemap::util::stats::Summary;

fn main() {
    let blocks: Vec<Arc<_>> = paper_blocks()
        .into_iter()
        .take(4)
        .map(|nb| Arc::new(nb.block))
        .collect();

    let mut results: Vec<BenchResult> = Vec::new();
    for workers in [1usize, 2, 4, 8] {
        let cfg = SparsemapConfig { workers, queue_depth: 32, ..SparsemapConfig::default() };
        let coord = Coordinator::new(&cfg);
        let mut rng = Pcg64::seeded(1);

        // Cold-start request: first job against an empty mapping cache.
        // This spans enqueue → queue → map_block (cache miss) → a tiny
        // simulation → wait, i.e. the user-visible cache-miss request
        // latency; the isolated map_block cold-start numbers live in
        // mapper_micro (map_block_seq / map_block_par4).
        let t_cold = Instant::now();
        let mut session = coord.session();
        let xs = stream(&blocks[0], 4, 99);
        let _ = session.enqueue(Arc::clone(&blocks[0]), xs).wait();
        let cold = t_cold.elapsed();

        // Warm the rest of the mapping cache (compile path off the
        // steady-state measurement).
        for (i, block) in blocks.iter().enumerate().skip(1) {
            let xs = stream(block, 4, i as u64);
            let _ = session.enqueue(Arc::clone(block), xs).wait();
        }

        let n = 200u64;
        let iters = 32;
        let t0 = Instant::now();
        let mut tickets: Vec<Ticket> = Vec::with_capacity(n as usize);
        let mut collected = 0usize;
        for id in 0..n {
            let block = Arc::clone(&blocks[rng.index(blocks.len())]);
            let xs = stream(&block, iters, id);
            tickets.push(session.enqueue(block, xs));
            // Drain opportunistically to keep the pipeline full.
            if tickets.len() >= 16 {
                for t in tickets.drain(..8) {
                    let _ = t.wait();
                    collected += 1;
                }
            }
        }
        for t in tickets.drain(..) {
            let _ = t.wait();
            collected += 1;
        }
        let wall = t0.elapsed();
        let m = coord.metrics.snapshot();
        println!(
            "workers={workers}: {n} requests ({} iterations each) in {wall:?} → {:.0} req/s, \
             {:.2} Miter/s, mean latency {:.2} ms, cold-start request {:.2} ms (cache hits {})",
            iters,
            n as f64 / wall.as_secs_f64(),
            (n as f64 * iters as f64) / wall.as_secs_f64() / 1e6,
            m.total_latency_ns as f64 / 1e6 / n as f64,
            cold.as_secs_f64() * 1e3,
            m.cache_hits,
        );
        assert_eq!(collected, n as usize);

        let mut per_request = Summary::new();
        per_request.add(wall.as_nanos() as f64 / n as f64);
        results.push(BenchResult {
            name: format!("serving/workers={workers}/per_request"),
            summary: per_request,
            iters_per_sample: n,
        });
        let mut cold_summary = Summary::new();
        cold_summary.add(cold.as_nanos() as f64);
        results.push(BenchResult {
            name: format!("serving/workers={workers}/cold_start_request"),
            summary: cold_summary,
            iters_per_sample: 1,
        });
    }

    // Wide-block serving scenario: a k = 128 block (beyond the retired
    // 64-kernel mask limit) through the full request path. The cold-start
    // row is the wide mapping cost as a user sees it; the per-request row
    // is the steady-state (cache-hit) wide simulation cost.
    {
        let wide = Arc::new(
            wide_blocks().into_iter().find(|b| b.name == "wide_k128").expect("wide_k128"),
        );
        let wide_point = sparsemap::mapper::MapperOptions::wide();
        let mut cfg = SparsemapConfig { workers: 4, queue_depth: 32, ..SparsemapConfig::default() };
        cfg.mis_iterations = wide_point.mis_iterations;
        cfg.ii_slack = wide_point.ii_slack;
        // This scenario pins the scalar interpreter so the historical
        // per_request row keeps its meaning; the compiled-backend twin
        // below measures the same traffic on the plan path.
        cfg.sim_backend = SimBackend::Interpreter;
        let coord = Coordinator::new(&cfg);
        let mut session = coord.session();

        let t_cold = Instant::now();
        let xs = stream(&wide, 4, 99);
        let _ = session.enqueue(Arc::clone(&wide), xs).wait();
        let cold = t_cold.elapsed();

        let n = 48u64;
        let iters = 8;
        let t0 = Instant::now();
        let mut tickets: Vec<Ticket> = Vec::new();
        let mut collected = 0usize;
        for id in 0..n {
            let xs = stream(&wide, iters, id);
            tickets.push(session.enqueue(Arc::clone(&wide), xs));
            if tickets.len() >= 16 {
                for t in tickets.drain(..8) {
                    let _ = t.wait();
                    collected += 1;
                }
            }
        }
        for t in tickets.drain(..) {
            let _ = t.wait();
            collected += 1;
        }
        assert_eq!(collected, n as usize);
        let wall = t0.elapsed();
        println!(
            "wide_k128: {n} requests in {wall:?} → {:.0} req/s, cold-start {:.2} ms",
            n as f64 / wall.as_secs_f64(),
            cold.as_secs_f64() * 1e3,
        );

        let mut per_request = Summary::new();
        per_request.add(wall.as_nanos() as f64 / n as f64);
        results.push(BenchResult {
            name: "serving/wide_k128/per_request".into(),
            summary: per_request,
            iters_per_sample: n,
        });
        let mut cold_summary = Summary::new();
        cold_summary.add(cold.as_nanos() as f64);
        results.push(BenchResult {
            name: "serving/wide_k128/cold_start_request".into(),
            summary: cold_summary,
            iters_per_sample: 1,
        });

        // Compiled-backend twin: identical warm traffic served off the
        // execution plan (the default backend). per_request vs
        // per_request_compiled is the serving-tier speedup of sim::plan.
        {
            let mut ccfg = cfg.clone();
            ccfg.sim_backend = SimBackend::Compiled;
            // Pinned to the scalar sweep so this row keeps measuring the
            // plan rewrite alone; window8_lanes below prices the
            // lane-vectorized path on the same block.
            ccfg.sim_lanes = 1;
            let coord = Coordinator::new(&ccfg);
            let mut session = coord.session();
            let _ = session.enqueue(Arc::clone(&wide), stream(&wide, 4, 99)).wait();
            let t0 = Instant::now();
            let mut tickets: Vec<Ticket> = Vec::new();
            let mut collected = 0usize;
            for id in 0..n {
                let xs = stream(&wide, iters, id);
                tickets.push(session.enqueue(Arc::clone(&wide), xs));
                if tickets.len() >= 16 {
                    for t in tickets.drain(..8) {
                        let _ = t.wait();
                        collected += 1;
                    }
                }
            }
            for t in tickets.drain(..) {
                let _ = t.wait();
                collected += 1;
            }
            assert_eq!(collected, n as usize);
            let wall = t0.elapsed();
            println!(
                "wide_k128 (compiled): {n} requests in {wall:?} → {:.0} req/s",
                n as f64 / wall.as_secs_f64(),
            );
            let mut per_request = Summary::new();
            per_request.add(wall.as_nanos() as f64 / n as f64);
            results.push(BenchResult {
                name: "serving/wide_k128/per_request_compiled".into(),
                summary: per_request,
                iters_per_sample: n,
            });
        }

        // Lane-vectorized window twin: the same wide block batched into
        // 8-request windows and served off the plan at the default (auto)
        // lane width. Single-member bundles are legal, so the wide block
        // gets the same window amortization the fused bundle enjoys — one
        // PlanOp sweep covers the whole window's iterations in lanes.
        {
            let mut lcfg = cfg.clone();
            lcfg.sim_backend = SimBackend::Compiled;
            lcfg.batch_window_requests = 8;
            lcfg.batch_window_max = 0;
            let coord = Coordinator::new(&lcfg);
            coord.register_bundle(Arc::new(
                sparsemap::sparse::fuse::FusedBundle::new(vec![Arc::clone(&wide)]).unwrap(),
            ));
            let mut session = coord.session();
            // Warm the mapping off the measurement (wait seals the warm
            // request's window itself).
            let _ = session.enqueue(Arc::clone(&wide), stream(&wide, 2, 98)).wait();
            let rounds = 16u64;
            let t0 = Instant::now();
            for round in 0..rounds {
                let mut window: Vec<Ticket> = (0..8u64)
                    .map(|i| {
                        let xs = stream(&wide, iters, 500 + round * 8 + i);
                        session.enqueue(Arc::clone(&wide), xs)
                    })
                    .collect();
                for t in window.drain(..) {
                    let _ = t.wait();
                }
            }
            let wall = t0.elapsed();
            let m = coord.metrics.snapshot();
            println!(
                "wide_k128 window8 (lanes): {rounds} windows in {wall:?} → {:.2} ms/window \
                 (lane passes {})",
                wall.as_secs_f64() * 1e3 / rounds as f64,
                m.lane_windows,
            );
            let mut window8l = Summary::new();
            window8l.add(wall.as_nanos() as f64 / rounds as f64);
            results.push(BenchResult {
                name: "serving/wide_k128/window8_lanes".into(),
                summary: window8l,
                iters_per_sample: rounds,
            });
        }

        // Deadline pressure: the same warm wide traffic enqueued as one
        // burst with a per-request latency budget of 2x the steady-state
        // per-request time. The front of the queue serves; the tail
        // exceeds its budget while queued and is shed at pickup
        // (`DeadlineExceeded` — no simulation spent on it). The row is
        // wall time per request under that policy; the printed miss rate
        // is the interesting diagnostic.
        let budget_ns = (wall.as_nanos() as u64 / n).saturating_mul(2).max(1);
        let budget = Duration::from_nanos(budget_ns);
        let t0 = Instant::now();
        let tickets: Vec<Ticket> = (0..n)
            .map(|id| {
                let xs = stream(&wide, iters, n + id);
                session.enqueue_with_deadline(Arc::clone(&wide), xs, budget)
            })
            .collect();
        let mut served = 0usize;
        let mut missed = 0usize;
        for t in tickets {
            match t.wait() {
                Ok(_) => served += 1,
                Err(ServeError::DeadlineExceeded) => missed += 1,
                Err(e) => panic!("unexpected serving error under deadlines: {e}"),
            }
        }
        let wall = t0.elapsed();
        println!(
            "wide_k128 deadlines: {n} requests, budget {:.2} ms → {served} served, \
             {missed} shed ({:.0}% miss rate) in {wall:?}",
            budget.as_secs_f64() * 1e3,
            missed as f64 / n as f64 * 100.0,
        );
        let mut deadline_rate = Summary::new();
        deadline_rate.add(wall.as_nanos() as f64 / n as f64);
        results.push(BenchResult {
            name: "serving/wide_k128/deadline_miss_rate".into(),
            summary: deadline_rate,
            iters_per_sample: n,
        });
    }

    // Fused serving scenario: the canonical three-small-block bundle
    // resident in one fabric configuration. The cold-start row is the
    // bundle's one-shot fused mapping as a member request sees it; the
    // per_request row serves member traffic one window-of-1 at a time
    // (the pre-batching semantics: one whole-bundle pass per request);
    // the batched_request and window8 rows measure the same traffic
    // amortized through 8-request batching windows — the residency win
    // turned into a throughput win.
    {
        let bundle = Arc::new(fused3_bundle());
        let members: Vec<Arc<SparseBlock>> = bundle.blocks.clone();

        // --- window size 1: per-member-serial fused serving ------------
        let mut cfg = SparsemapConfig { workers: 4, queue_depth: 32, ..SparsemapConfig::default() };
        cfg.batch_window_requests = 1;
        let coord = Coordinator::new(&cfg);
        coord.register_bundle(Arc::clone(&bundle));
        let mut session = coord.session();

        let t_cold = Instant::now();
        let xs = stream(&members[0], 4, 99);
        let _ = session.enqueue(Arc::clone(&members[0]), xs).wait();
        let cold = t_cold.elapsed();

        let n = 120u64;
        let iters = 16;
        let t0 = Instant::now();
        let mut tickets: Vec<Ticket> = Vec::new();
        let mut collected = 0usize;
        for id in 0..n {
            let member = &members[(id as usize) % members.len()];
            tickets.push(session.enqueue(Arc::clone(member), stream(member, iters, id)));
            if tickets.len() >= 16 {
                for t in tickets.drain(..8) {
                    let _ = t.wait();
                    collected += 1;
                }
            }
        }
        for t in tickets.drain(..) {
            let _ = t.wait();
            collected += 1;
        }
        assert_eq!(collected, n as usize);
        let wall = t0.elapsed();
        let m = coord.metrics.snapshot();
        println!(
            "fused3 (window 1): {n} member requests in {wall:?} → {:.0} req/s, cold-start \
             {:.2} ms (cache misses {}, windows {})",
            n as f64 / wall.as_secs_f64(),
            cold.as_secs_f64() * 1e3,
            m.cache_misses,
            m.windows,
        );

        let mut per_request = Summary::new();
        per_request.add(wall.as_nanos() as f64 / n as f64);
        results.push(BenchResult {
            name: "serving/fused3/per_request".into(),
            summary: per_request,
            iters_per_sample: n,
        });
        let mut cold_summary = Summary::new();
        cold_summary.add(cold.as_nanos() as f64);
        results.push(BenchResult {
            name: "serving/fused3/cold_start_request".into(),
            summary: cold_summary,
            iters_per_sample: 1,
        });

        // --- 8-request batching windows --------------------------------
        let mut cfg = SparsemapConfig { workers: 4, queue_depth: 32, ..SparsemapConfig::default() };
        cfg.batch_window_requests = 8;
        cfg.batch_window_max = 0;
        // Pinned to the interpreter: batched_request and window8 keep
        // their historical meaning; window8_compiled below is the plan
        // path on the same window shape.
        cfg.sim_backend = SimBackend::Interpreter;
        let coord = Coordinator::new(&cfg);
        coord.register_bundle(Arc::clone(&bundle));
        let mut session = coord.session();
        // Warm the fused mapping off the measurement.
        let _ = session
            .enqueue(Arc::clone(&members[0]), stream(&members[0], 2, 98))
            .wait();

        let t0 = Instant::now();
        let mut tickets: Vec<Ticket> = Vec::new();
        for id in 0..n {
            let member = &members[(id as usize) % members.len()];
            tickets.push(session.enqueue(Arc::clone(member), stream(member, iters, id)));
        }
        session.flush();
        for t in tickets.drain(..) {
            let _ = t.wait();
        }
        let wall = t0.elapsed();
        let m = coord.metrics.snapshot();
        println!(
            "fused3 (batched): {n} member requests in {wall:?} → {:.0} req/s \
             ({} windows — one lockstep pass each)",
            n as f64 / wall.as_secs_f64(),
            m.windows,
        );
        let mut batched = Summary::new();
        batched.add(wall.as_nanos() as f64 / n as f64);
        results.push(BenchResult {
            name: "serving/fused3/batched_request".into(),
            summary: batched,
            iters_per_sample: n,
        });

        // One full 8-request window, end to end (enqueue → seal → one
        // fused pass → all 8 tickets resolved), averaged over rounds.
        let rounds = 16u64;
        let t0 = Instant::now();
        for round in 0..rounds {
            let mut window: Vec<Ticket> = (0..8u64)
                .map(|i| {
                    let member = &members[(i as usize) % members.len()];
                    let xs = stream(member, iters, round * 8 + i);
                    session.enqueue(Arc::clone(member), xs)
                })
                .collect();
            for t in window.drain(..) {
                let _ = t.wait();
            }
        }
        let wall = t0.elapsed();
        println!(
            "fused3 window8: {rounds} windows in {wall:?} → {:.2} ms/window",
            wall.as_secs_f64() * 1e3 / rounds as f64,
        );
        let mut window8 = Summary::new();
        window8.add(wall.as_nanos() as f64 / rounds as f64);
        results.push(BenchResult {
            name: "serving/fused3/window8".into(),
            summary: window8,
            iters_per_sample: rounds,
        });

        // Compiled-backend twin of window8: same bundle, same window
        // shape, served off the execution plan — pinned to the scalar
        // sweep (`sim_lanes = 1`) so the row keeps its historical meaning
        // now that serving defaults to the lane-vectorized sweep.
        {
            let mut ccfg = cfg.clone();
            ccfg.sim_backend = SimBackend::Compiled;
            ccfg.sim_lanes = 1;
            let coord = Coordinator::new(&ccfg);
            coord.register_bundle(Arc::clone(&bundle));
            let mut session = coord.session();
            let _ = session
                .enqueue(Arc::clone(&members[0]), stream(&members[0], 2, 98))
                .wait();
            let t0 = Instant::now();
            for round in 0..rounds {
                let mut window: Vec<Ticket> = (0..8u64)
                    .map(|i| {
                        let member = &members[(i as usize) % members.len()];
                        let xs = stream(member, iters, round * 8 + i);
                        session.enqueue(Arc::clone(member), xs)
                    })
                    .collect();
                for t in window.drain(..) {
                    let _ = t.wait();
                }
            }
            let wall = t0.elapsed();
            println!(
                "fused3 window8 (compiled): {rounds} windows in {wall:?} → {:.2} ms/window",
                wall.as_secs_f64() * 1e3 / rounds as f64,
            );
            let mut window8c = Summary::new();
            window8c.add(wall.as_nanos() as f64 / rounds as f64);
            results.push(BenchResult {
                name: "serving/fused3/window8_compiled".into(),
                summary: window8c,
                iters_per_sample: rounds,
            });
        }

        // Lane-vectorized twin of window8: the same traffic at the
        // default (auto) lane width. window8_compiled vs window8_lanes is
        // the sweep-vectorization win in isolation — same plan, same
        // window shape, the only difference is lanes.
        {
            let mut lcfg = cfg.clone();
            lcfg.sim_backend = SimBackend::Compiled;
            let coord = Coordinator::new(&lcfg);
            coord.register_bundle(Arc::clone(&bundle));
            let mut session = coord.session();
            let _ = session
                .enqueue(Arc::clone(&members[0]), stream(&members[0], 2, 98))
                .wait();
            let t0 = Instant::now();
            for round in 0..rounds {
                let mut window: Vec<Ticket> = (0..8u64)
                    .map(|i| {
                        let member = &members[(i as usize) % members.len()];
                        let xs = stream(member, iters, round * 8 + i);
                        session.enqueue(Arc::clone(member), xs)
                    })
                    .collect();
                for t in window.drain(..) {
                    let _ = t.wait();
                }
            }
            let wall = t0.elapsed();
            let m = coord.metrics.snapshot();
            println!(
                "fused3 window8 (lanes): {rounds} windows in {wall:?} → {:.2} ms/window \
                 (lane passes {})",
                wall.as_secs_f64() * 1e3 / rounds as f64,
                m.lane_windows,
            );
            let mut window8l = Summary::new();
            window8l.add(wall.as_nanos() as f64 / rounds as f64);
            results.push(BenchResult {
                name: "serving/fused3/window8_lanes".into(),
                summary: window8l,
                iters_per_sample: rounds,
            });
        }

        // Admission control under overload: one slow worker, a short
        // queue and a shed watermark, driven by a non-blocking
        // `try_enqueue` burst of mixed traffic — bundle members (always
        // admitted into their batching window: a window rides one queue
        // slot) interleaved with solo singles (shed first, with
        // `Overloaded`). The row is wall time per ADMITTED request — the
        // cost of the serving the coordinator actually accepted — and the
        // printed shed rate shows the watermark doing its job.
        let mut cfg = SparsemapConfig { workers: 1, queue_depth: 4, ..SparsemapConfig::default() };
        cfg.batch_window_requests = 3;
        cfg.shed_watermark = 3;
        let coord = Coordinator::new(&cfg);
        coord.register_bundle(Arc::clone(&bundle));
        let mut session = coord.session();
        // Warm both mappings (fused + solo) off the measurement.
        let _ = session
            .enqueue(Arc::clone(&members[0]), stream(&members[0], 2, 97))
            .wait();
        let _ = session.enqueue(Arc::clone(&blocks[0]), stream(&blocks[0], 2, 96)).wait();

        let n = 200u64;
        let t0 = Instant::now();
        let mut admitted: Vec<Ticket> = Vec::new();
        let mut shed = 0usize;
        for id in 0..n {
            let block = if id % 2 == 0 {
                Arc::clone(&members[(id as usize / 2) % members.len()])
            } else {
                Arc::clone(&blocks[0])
            };
            let xs = stream(&block, iters, id);
            match session.try_enqueue(block, xs) {
                Ok(t) => admitted.push(t),
                Err(ServeError::Overloaded) => shed += 1,
                Err(e) => panic!("unexpected admission error: {e}"),
            }
        }
        session.flush();
        let count = admitted.len();
        for t in admitted.drain(..) {
            let _ = t.wait();
        }
        let wall = t0.elapsed();
        let m = coord.metrics.snapshot();
        println!(
            "fused3 overload: {n} offered → {count} admitted, {shed} shed \
             ({:.0}% shed rate, metrics.shed {}) in {wall:?}",
            shed as f64 / n as f64 * 100.0,
            m.shed,
        );
        let mut shed_row = Summary::new();
        shed_row.add(wall.as_nanos() as f64 / count.max(1) as f64);
        results.push(BenchResult {
            name: "serving/fused3/shed_overload".into(),
            summary: shed_row,
            iters_per_sample: count.max(1) as u64,
        });
    }

    // Sharded serving scenario: the fused3 window shape on a two-shard
    // topology, pinned via `with_shard_count` so neither config nor the
    // `SPARSEMAP_SHARDS` override can move it. The bundle is resident on
    // one shard; the paper blocks register onto the sibling, and each
    // round drives one full 8-member window plus four solo requests so
    // BOTH pools serve concurrently — the row is wall time per round,
    // i.e. the cross-pool overlap win. cross_session_window8 forms each
    // 8-rider window from eight distinct sessions: window forming is a
    // property of the global enqueue order, and this row prices it.
    {
        let bundle = Arc::new(fused3_bundle());
        let members: Vec<Arc<SparseBlock>> = bundle.blocks.clone();
        let mut cfg = SparsemapConfig { workers: 2, queue_depth: 32, ..SparsemapConfig::default() };
        cfg.batch_window_requests = 8;
        cfg.batch_window_max = 0;
        let coord = Coordinator::with_shard_count(&cfg, 2);
        coord.register_bundle(Arc::clone(&bundle));
        for block in &blocks {
            coord.register_block(Arc::clone(block));
        }
        let mut session = coord.session();
        // Warm the fused and solo mappings off the measurement (wait
        // seals the warm request's window itself).
        let warm = stream(&members[0], 2, 98);
        let _ = session.enqueue(Arc::clone(&members[0]), warm).wait();
        for (i, block) in blocks.iter().enumerate() {
            let xs = stream(block, 2, 90 + i as u64);
            let _ = session.enqueue(Arc::clone(block), xs).wait();
        }

        let iters = 16;
        let rounds = 16u64;
        let t0 = Instant::now();
        for round in 0..rounds {
            let mut batch: Vec<Ticket> = (0..8u64)
                .map(|i| {
                    let member = &members[(i as usize) % members.len()];
                    let xs = stream(member, iters, round * 16 + i);
                    session.enqueue(Arc::clone(member), xs)
                })
                .collect();
            for i in 0..4u64 {
                let block = &blocks[(i as usize) % blocks.len()];
                let xs = stream(block, iters, round * 16 + 8 + i);
                batch.push(session.enqueue(Arc::clone(block), xs));
            }
            for t in batch.drain(..) {
                let _ = t.wait();
            }
        }
        let wall = t0.elapsed();
        let m = coord.metrics.snapshot();
        println!(
            "sharded x2 window8+solo: {rounds} rounds in {wall:?} → {:.2} ms/round \
             (per-shard windows: {:?})",
            wall.as_secs_f64() * 1e3 / rounds as f64,
            m.shards.iter().map(|s| s.windows).collect::<Vec<_>>(),
        );
        let mut sharded = Summary::new();
        sharded.add(wall.as_nanos() as f64 / rounds as f64);
        results.push(BenchResult {
            name: "serving/sharded/window8_x2shards".into(),
            summary: sharded,
            iters_per_sample: rounds,
        });

        // Cross-session window8: eight sessions, one member request each
        // per round, forming (and sealing) one shared window per round.
        let mut sessions: Vec<_> = (0..8).map(|_| coord.session()).collect();
        let t0 = Instant::now();
        for round in 0..rounds {
            let mut window: Vec<Ticket> = sessions
                .iter_mut()
                .enumerate()
                .map(|(i, s)| {
                    let member = &members[i % members.len()];
                    let xs = stream(member, iters, 1000 + round * 8 + i as u64);
                    s.enqueue(Arc::clone(member), xs)
                })
                .collect();
            for t in window.drain(..) {
                let _ = t.wait();
            }
        }
        let wall = t0.elapsed();
        println!(
            "sharded cross-session window8: {rounds} windows in {wall:?} → {:.2} ms/window",
            wall.as_secs_f64() * 1e3 / rounds as f64,
        );
        let mut cross = Summary::new();
        cross.add(wall.as_nanos() as f64 / rounds as f64);
        results.push(BenchResult {
            name: "serving/sharded/cross_session_window8".into(),
            summary: cross,
            iters_per_sample: rounds,
        });
    }

    // Whole-network pipeline serving scenario: the vgg_head preset
    // (3→64→64→128→128, k = 128 layers tiling into the wide-block class)
    // registered as a network and served end to end through
    // `enqueue_network`. The vgg_head_e2e row is one full pipeline pass
    // (gather → serve → scatter across all four stages, warm caches); the
    // per_layer row normalizes the same passes by stage count, the
    // apples-to-apples comparison against per-request rows.
    {
        let wide_point = sparsemap::mapper::MapperOptions::wide();
        let mut cfg = SparsemapConfig { workers: 4, queue_depth: 64, ..SparsemapConfig::default() };
        cfg.mis_iterations = wide_point.mis_iterations;
        cfg.ii_slack = wide_point.ii_slack;
        let coord = Coordinator::new(&cfg);
        let net = coord
            .register_network(sparsemap::model::vgg_head())
            .expect("register vgg_head");
        let session = coord.session();
        let mut rng = Pcg64::seeded(5);
        let input = |rng: &mut Pcg64| -> Vec<f32> {
            (0..net.input_width()).map(|_| rng.next_normal() as f32).collect()
        };
        // Warm every tile mapping off the measurement with one full pass.
        let x = input(&mut rng);
        let warm = session
            .enqueue_network(&net.name, &x)
            .expect("enqueue vgg_head")
            .wait()
            .expect("warm vgg_head pass");
        let stages = warm.layers.len() as u64;

        let passes = 6u64;
        let t0 = Instant::now();
        for _ in 0..passes {
            let x = input(&mut rng);
            let _ = session
                .enqueue_network(&net.name, &x)
                .expect("enqueue vgg_head")
                .wait()
                .expect("vgg_head pass");
        }
        let wall = t0.elapsed();
        println!(
            "network vgg_head: {passes} pipeline passes ({} tiles over {stages} stages) \
             in {wall:?} → {:.2} ms/pass",
            net.block_count(),
            wall.as_secs_f64() * 1e3 / passes as f64,
        );
        let mut e2e = Summary::new();
        e2e.add(wall.as_nanos() as f64 / passes as f64);
        results.push(BenchResult {
            name: "serving/network/vgg_head_e2e".into(),
            summary: e2e,
            iters_per_sample: passes,
        });
        let mut per_layer = Summary::new();
        per_layer.add(wall.as_nanos() as f64 / (passes * stages) as f64);
        results.push(BenchResult {
            name: "serving/network/per_layer".into(),
            summary: per_layer,
            iters_per_sample: passes * stages,
        });
    }

    let json = repo_root_path("BENCH_mapper.json");
    match write_json_merged(&json, &results) {
        Ok(()) => println!("\nwrote {json}"),
        Err(e) => eprintln!("\nfailed to write {json}: {e}"),
    }
}

fn stream(block: &sparsemap::sparse::SparseBlock, n: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Pcg64::seeded(seed);
    (0..n)
        .map(|_| (0..block.c).map(|_| rng.next_normal() as f32).collect())
        .collect()
}
