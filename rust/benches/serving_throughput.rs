//! Bench: coordinator serving throughput — the end-to-end request path
//! (mapping cache + CGRA simulation) under a mixed-block request stream,
//! across worker counts. This is the system-level headline the paper's
//! throughput claim translates to on this testbed.
//!
//! ```bash
//! cargo bench --bench serving_throughput
//! ```

use std::sync::Arc;
use std::time::Instant;

use sparsemap::config::SparsemapConfig;
use sparsemap::coordinator::{Coordinator, InferRequest};
use sparsemap::sparse::gen::paper_blocks;
use sparsemap::util::rng::Pcg64;

fn main() {
    let blocks: Vec<Arc<_>> = paper_blocks()
        .into_iter()
        .take(4)
        .map(|nb| Arc::new(nb.block))
        .collect();

    for workers in [1usize, 2, 4, 8] {
        let mut cfg = SparsemapConfig::default();
        cfg.workers = workers;
        cfg.queue_depth = 32;
        let coord = Coordinator::new(&cfg);
        let mut rng = Pcg64::seeded(1);

        // Warm the mapping cache (compile path off the measurement).
        for (id, block) in blocks.iter().enumerate() {
            let xs = stream(block, 4, id as u64);
            coord
                .submit(InferRequest { id: id as u64, block: Arc::clone(block), xs })
                .unwrap();
        }
        let _ = coord.collect(blocks.len());

        let n = 200u64;
        let iters = 32;
        let t0 = Instant::now();
        let mut submitted = 0u64;
        let mut collected = 0usize;
        for id in 0..n {
            let block = Arc::clone(&blocks[rng.index(blocks.len())]);
            let xs = stream(&block, iters, id);
            coord.submit(InferRequest { id, block, xs }).unwrap();
            submitted += 1;
            // Drain opportunistically to keep the pipeline full.
            if submitted % 16 == 0 {
                collected += coord.collect(8).len();
            }
        }
        collected += coord.collect(n as usize - collected).len();
        let wall = t0.elapsed();
        let m = coord.metrics.snapshot();
        println!(
            "workers={workers}: {n} requests ({} iterations each) in {wall:?} → {:.0} req/s, \
             {:.2} Miter/s, mean latency {:.2} ms (cache hits {})",
            iters,
            n as f64 / wall.as_secs_f64(),
            (n as f64 * iters as f64) / wall.as_secs_f64() / 1e6,
            m.total_latency_ns as f64 / 1e6 / n as f64,
            m.cache_hits,
        );
        assert_eq!(collected, n as usize);
    }
}

fn stream(block: &sparsemap::sparse::SparseBlock, n: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Pcg64::seeded(seed);
    (0..n)
        .map(|_| (0..block.c).map(|_| rng.next_normal() as f32).collect())
        .collect()
}
