//! Sparse-block generators.
//!
//! * [`random_block`] — the paper's random workload ("each weight zero with
//!   probability 0.4"), repaired so every channel and kernel stays alive.
//! * [`feature_block`] — deterministic construction of a block matching an
//!   exact Table-2 feature vector (nnz, N_FG4). Blocks 6/7 in the paper
//!   come from pruned VGG/AlexNet; we do not have those models, so we
//!   generate masks with the exact published statistics instead
//!   (substitution documented in DESIGN.md).
//! * [`paper_blocks`] — the seven evaluation blocks of Table 2.
//! * [`wide_blocks`] — the wide-kernel-axis workload class (k = 96, 128,
//!   256, plus c > 64): real CNN layers whose kernel counts exceed the
//!   64-bit inline fast path of the association analysis
//!   ([`crate::util::KernelMask`] spills to multi-word masks). Densities
//!   are chosen so every shape stays mappable on the paper's 4×4 fabric
//!   with modest II escalation; `wide_k128` is the end-to-end serving
//!   scenario exercised by `tests/wide_blocks.rs` and the wide bench rows.

use std::sync::Arc;

use crate::error::{Error, Result};
use crate::sparse::fuse::FusedBundle;
use crate::sparse::SparseBlock;
use crate::util::rng::Pcg64;

/// A named evaluation block together with its paper-reported features.
#[derive(Clone, Debug)]
pub struct NamedBlock {
    pub block: SparseBlock,
    /// The paper's label ("block1" …).
    pub label: &'static str,
    /// Expected features from Table 2 (validated in tests).
    pub expect_nnz: usize,
    pub expect_v_op: usize,
    pub expect_n_fg4: usize,
}

/// Random block: every weight zero with probability `p_zero`; the mask is
/// repaired so each channel and each kernel keeps at least one nonzero
/// (otherwise it would not appear in the block at all).
pub fn random_block(name: &str, c: usize, k: usize, p_zero: f64, seed: u64) -> SparseBlock {
    let mut rng = Pcg64::seeded(seed);
    let mut mask = vec![false; c * k];
    for m in mask.iter_mut() {
        *m = !rng.chance(p_zero);
    }
    // Repair empty rows/columns deterministically.
    for ch in 0..c {
        if (0..k).all(|kr| !mask[ch * k + kr]) {
            mask[ch * k + rng.index(k)] = true;
        }
    }
    for kr in 0..k {
        if (0..c).all(|ch| !mask[ch * k + kr]) {
            mask[rng.index(c) * k + kr] = true;
        }
    }
    SparseBlock::from_mask(name, c, k, mask).expect("sized mask")
}

/// Construct a block whose features match (nnz, n_fg4) exactly:
/// `n_fg4` channels get fanout ≥ 5, the rest fanout ≤ 4, all ≥ 1, summing
/// to `nnz`, every kernel non-empty. Column positions are seeded-random so
/// the association structure is non-trivial.
pub fn feature_block(
    name: &str,
    c: usize,
    k: usize,
    nnz: usize,
    n_fg4: usize,
    seed: u64,
) -> Result<SparseBlock> {
    if n_fg4 > c || k < 5 && n_fg4 > 0 {
        return Err(Error::Workload(format!(
            "infeasible features: c={c} k={k} n_fg4={n_fg4}"
        )));
    }
    let lo_cap = 4.min(k);
    let hi_min = 5.min(k);
    let min_nnz = n_fg4 * hi_min + (c - n_fg4);
    let max_nnz = n_fg4 * k + (c - n_fg4) * lo_cap;
    if nnz < min_nnz || nnz > max_nnz || nnz < k {
        return Err(Error::Workload(format!(
            "nnz={nnz} outside feasible [{min_nnz}, {max_nnz}] for c={c} k={k} n_fg4={n_fg4}"
        )));
    }
    // Distribute fanouts: start every hi row at 5 and every lo row at 1,
    // then spread the remainder (hi rows up to k, lo rows up to 4).
    let mut fanout = vec![0usize; c];
    for f in fanout.iter_mut().take(n_fg4) {
        *f = hi_min;
    }
    for f in fanout.iter_mut().skip(n_fg4) {
        *f = 1;
    }
    let mut rest = nnz - (n_fg4 * hi_min + (c - n_fg4));
    // Round-robin increments keep the distribution flat (deterministic).
    let mut idx = 0usize;
    let mut spun = 0usize;
    while rest > 0 {
        let cap = if idx < n_fg4 { k } else { lo_cap };
        if fanout[idx] < cap {
            fanout[idx] += 1;
            rest -= 1;
            spun = 0;
        } else {
            spun += 1;
            if spun > c {
                return Err(Error::Workload("fanout spread failed".into()));
            }
        }
        idx = (idx + 1) % c;
    }

    // Seeded search for column placement with every kernel non-empty.
    let mut rng = Pcg64::seeded(seed);
    for _attempt in 0..200 {
        let mut mask = vec![false; c * k];
        for ch in 0..c {
            for kr in rng.sample_indices(k, fanout[ch]) {
                mask[ch * k + kr] = true;
            }
        }
        let all_kernels = (0..k).all(|kr| (0..c).any(|ch| mask[ch * k + kr]));
        if all_kernels {
            let b = SparseBlock::from_mask(name, c, k, mask)?;
            debug_assert_eq!(b.nnz(), nnz);
            return Ok(b);
        }
    }
    Err(Error::Workload(format!(
        "no kernel-covering placement found for c={c} k={k} nnz={nnz} n_fg4={n_fg4}"
    )))
}

/// The seven evaluation blocks of Table 2, with the paper's exact feature
/// vectors. nnz is derived from `|V_OP| = 2·nnz − k`.
pub fn paper_blocks() -> Vec<NamedBlock> {
    // (label, c, k, v_op, n_fg4, seed)
    let spec: [(&'static str, usize, usize, usize, usize, u64); 7] = [
        ("block1", 4, 6, 26, 3, 101),
        ("block2", 4, 6, 26, 2, 210),
        ("block3", 6, 6, 36, 3, 303),
        ("block4", 4, 6, 32, 3, 404),
        ("block5", 8, 8, 58, 3, 505),
        ("block6", 8, 8, 40, 2, 606),
        ("block7", 8, 8, 58, 4, 737),
    ];
    spec.iter()
        .map(|&(label, c, k, v_op, n_fg4, seed)| {
            let nnz = (v_op + k) / 2;
            let block = feature_block(label, c, k, nnz, n_fg4, seed)
                .expect("paper block features are feasible");
            NamedBlock { block, label, expect_nnz: nnz, expect_v_op: v_op, expect_n_fg4: n_fg4 }
        })
        .collect()
}

/// The canonical fused bundle: the three c = 4 paper blocks (block1 /
/// block2 / block4) destined for one fabric configuration. The `fused3`
/// golden line, `tests/fusion_equivalence.rs` and the `fused3/*` bench
/// rows all pin exactly this bundle — they share this constructor so the
/// member set cannot silently drift apart between them.
pub fn fused3_bundle() -> FusedBundle {
    let members: Vec<Arc<SparseBlock>> = paper_blocks()
        .into_iter()
        .filter(|nb| matches!(nb.label, "block1" | "block2" | "block4"))
        .map(|nb| Arc::new(nb.block))
        .collect();
    debug_assert_eq!(members.len(), 3);
    FusedBundle::new(members).expect("canonical bundle members exist")
}

/// The wide-kernel-axis evaluation blocks: kernel counts past the 64-bit
/// inline mask (96 / 128 / 256) plus one block with c > 64 channels. The
/// names encode the wide axis. Deterministic (seeded [`random_block`]), so
/// tests, benches and golden snapshots all see identical masks.
///
/// Sparsities keep per-channel fanouts and per-kernel sizes small: the
/// point of this class is the *width* of the kernel axis (association
/// masks, index tables, output-bus pressure at II ≈ k/N), not dense
/// arithmetic volume.
pub fn wide_blocks() -> Vec<SparseBlock> {
    vec![
        random_block("wide_k96", 12, 96, 0.88, 9601),
        // Density/seed chosen so the block is PE-bound (MII above the
        // ⌈k/N⌉ output bound) and both occupancies relax within a few II
        // escalations — a mappable, representative wide layer rather than
        // a worst case.
        random_block("wide_k128", 32, 128, 0.92, 12804),
        random_block("wide_k256", 24, 256, 0.94, 25601),
        random_block("wide_c96", 96, 16, 0.90, 9602),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wide_blocks_shapes_and_liveness() {
        let blocks = wide_blocks();
        let want: [(&str, usize, usize); 4] = [
            ("wide_k96", 12, 96),
            ("wide_k128", 32, 128),
            ("wide_k256", 24, 256),
            ("wide_c96", 96, 16),
        ];
        assert_eq!(blocks.len(), want.len());
        for (b, &(name, c, k)) in blocks.iter().zip(&want) {
            assert_eq!(b.name, name);
            assert_eq!((b.c, b.k), (c, k), "{name}");
            for ch in 0..b.c {
                assert!(b.channel_fanout(ch) >= 1, "{name}: dead channel {ch}");
            }
            for kr in 0..b.k {
                assert!(b.kernel_size(kr) >= 1, "{name}: dead kernel {kr}");
            }
        }
        // Deterministic across calls (golden snapshots depend on it).
        assert_eq!(blocks, wide_blocks());
    }

    #[test]
    fn random_block_no_dead_rows_or_cols() {
        for seed in 0..20 {
            let b = random_block("r", 8, 8, 0.4, seed);
            for ch in 0..8 {
                assert!(b.channel_fanout(ch) >= 1, "dead channel at seed {seed}");
            }
            for kr in 0..8 {
                assert!(b.kernel_size(kr) >= 1, "dead kernel at seed {seed}");
            }
        }
    }

    #[test]
    fn random_block_sparsity_near_p() {
        // Large block so the repair step is negligible.
        let b = random_block("r", 64, 64, 0.4, 9);
        let f = b.features();
        assert!((f.sparsity - 0.4).abs() < 0.05, "sparsity={}", f.sparsity);
    }

    #[test]
    fn feature_block_exact() {
        let b = feature_block("x", 8, 8, 33, 3, 1).unwrap();
        let f = b.features();
        assert_eq!(f.nnz, 33);
        assert_eq!(f.n_fg4, 3);
        assert_eq!(f.v_r, 8);
        assert_eq!(f.v_w, 8);
        assert_eq!(f.v_op, 2 * 33 - 8);
    }

    #[test]
    fn feature_block_infeasible_rejected() {
        assert!(feature_block("x", 4, 6, 100, 0, 1).is_err());
        assert!(feature_block("x", 4, 6, 3, 0, 1).is_err()); // < k
        assert!(feature_block("x", 4, 6, 24, 5, 1).is_err()); // n_fg4 > c
    }

    #[test]
    fn paper_blocks_match_table2() {
        // Table 2 rows, in order: |V_OP|, |V_R|, |V_W|, N_FG4, sparsity.
        let want = [
            ("block1", 26, 4, 6, 3, 0.33),
            ("block2", 26, 4, 6, 2, 0.33),
            ("block3", 36, 6, 6, 3, 0.42),
            ("block4", 32, 4, 6, 3, 0.21),
            ("block5", 58, 8, 8, 3, 0.48),
            ("block6", 40, 8, 8, 2, 0.62),
            ("block7", 58, 8, 8, 4, 0.48),
        ];
        let blocks = paper_blocks();
        assert_eq!(blocks.len(), 7);
        for (nb, &(label, v_op, v_r, v_w, n_fg4, sparsity)) in blocks.iter().zip(&want) {
            let f = nb.block.features();
            assert_eq!(nb.label, label);
            assert_eq!(f.v_op, v_op, "{label} v_op");
            assert_eq!(f.v_r, v_r, "{label} v_r");
            assert_eq!(f.v_w, v_w, "{label} v_w");
            assert_eq!(f.n_fg4, n_fg4, "{label} n_fg4");
            assert!((f.sparsity - sparsity).abs() < 0.01, "{label} sparsity {}", f.sparsity);
        }
    }

    #[test]
    fn paper_blocks_deterministic() {
        let a = paper_blocks();
        let b = paper_blocks();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.block, y.block);
        }
    }
}
