//! Sparse CNN workloads: sparse blocks (the unit the mapper consumes),
//! feature extraction matching the paper's Table 2, generators, and the
//! partitioning of full conv layers into blocks.

pub mod fuse;
pub mod gen;
pub mod partition;
pub mod prune;

use crate::error::{Error, Result};

/// A sparse block: `k` output kernels computed from `c` input channels with
/// a 0/1 sparsity mask over the `c × k` weight matrix (paper §1: "each
/// block computes different channels from different kernels").
///
/// `mask[ch * k + kr]` / `weights[ch * k + kr]` are row-major over
/// (channel, kernel). A `true` mask entry is a multiplication in the s-DFG;
/// zero-weight multiplications are skipped entirely — that is the sparsity
/// the paper exploits.
#[derive(Clone, Debug, PartialEq)]
pub struct SparseBlock {
    pub name: String,
    pub c: usize,
    pub k: usize,
    pub mask: Vec<bool>,
    pub weights: Vec<f32>,
    /// Cached [`Self::mask_fingerprint`] value, computed once at
    /// construction. The mapper-relevant structure (shape + mask) is
    /// immutable after `from_mask` — post-construction mutation is limited
    /// to `name` and `weights` (see `partition`) — so the cache can never
    /// go stale. Private so the only construction path is `from_mask`;
    /// debug builds re-verify the cache on every access.
    fp: u64,
}

/// The Table-2 feature vector of a block.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct BlockFeatures {
    pub c: usize,
    pub k: usize,
    pub nnz: usize,
    /// Fraction of zero weights (`1 − nnz/(c·k)`).
    pub sparsity: f64,
    /// `|V_OP|` = multiplications + adder-tree additions = `2·nnz − k'`
    /// where `k'` is the number of non-empty kernels.
    pub v_op: usize,
    /// `|V_R|` = channels with at least one nonzero.
    pub v_r: usize,
    /// `|V_W|` = kernels with at least one nonzero.
    pub v_w: usize,
    /// Channels whose fanout (kernels touched) exceeds 4.
    pub n_fg4: usize,
}

impl SparseBlock {
    /// Build from an explicit mask (weights default to a deterministic
    /// ramp so functional simulation has interesting values).
    pub fn from_mask(name: &str, c: usize, k: usize, mask: Vec<bool>) -> Result<Self> {
        if mask.len() != c * k {
            return Err(Error::Workload(format!(
                "mask len {} != {}x{}",
                mask.len(),
                c,
                k
            )));
        }
        let weights = mask
            .iter()
            .enumerate()
            .map(|(i, &m)| {
                if m {
                    // Deterministic, nonzero, sign-alternating ramp.
                    let v = 0.25 + 0.5 * ((i % 7) as f32);
                    if i % 2 == 0 {
                        v
                    } else {
                        -v
                    }
                } else {
                    0.0
                }
            })
            .collect();
        let fp = fingerprint_of(c, k, &mask);
        Ok(SparseBlock { name: name.to_string(), c, k, mask, weights, fp })
    }

    #[inline]
    pub fn has_weight(&self, ch: usize, kr: usize) -> bool {
        self.mask[ch * self.k + kr]
    }

    #[inline]
    pub fn weight(&self, ch: usize, kr: usize) -> f32 {
        self.weights[ch * self.k + kr]
    }

    /// Number of nonzero weights.
    pub fn nnz(&self) -> usize {
        self.mask.iter().filter(|&&m| m).count()
    }

    /// Fanout of a channel: how many kernels consume it (row nonzeros).
    /// This is `|fanout(r)|` for the channel's input reading.
    pub fn channel_fanout(&self, ch: usize) -> usize {
        (0..self.k).filter(|&kr| self.has_weight(ch, kr)).count()
    }

    /// Multiplication count of a kernel (column nonzeros).
    pub fn kernel_size(&self, kr: usize) -> usize {
        (0..self.c).filter(|&ch| self.has_weight(ch, kr)).count()
    }

    /// Kernels consuming channel `ch`.
    pub fn kernels_of_channel(&self, ch: usize) -> Vec<usize> {
        (0..self.k).filter(|&kr| self.has_weight(ch, kr)).collect()
    }

    /// Channels feeding kernel `kr`.
    pub fn channels_of_kernel(&self, kr: usize) -> Vec<usize> {
        (0..self.c).filter(|&ch| self.has_weight(ch, kr)).collect()
    }

    /// **Association** of two channels (paper §2.1): the number of kernels
    /// requiring both simultaneously.
    pub fn association(&self, ch1: usize, ch2: usize) -> usize {
        (0..self.k)
            .filter(|&kr| self.has_weight(ch1, kr) && self.has_weight(ch2, kr))
            .count()
    }

    /// Table-2 feature extraction.
    pub fn features(&self) -> BlockFeatures {
        let nnz = self.nnz();
        let v_r = (0..self.c).filter(|&ch| self.channel_fanout(ch) > 0).count();
        let nonempty_kernels = (0..self.k).filter(|&kr| self.kernel_size(kr) > 0).count();
        let adds: usize = (0..self.k)
            .map(|kr| self.kernel_size(kr).saturating_sub(1))
            .sum();
        BlockFeatures {
            c: self.c,
            k: self.k,
            nnz,
            sparsity: 1.0 - nnz as f64 / (self.c * self.k) as f64,
            v_op: nnz + adds,
            v_r,
            v_w: nonempty_kernels,
            n_fg4: (0..self.c).filter(|&ch| self.channel_fanout(ch) > 4).count(),
        }
    }

    /// Operation count of the *dense* version of this block (every weight
    /// nonzero): `c·k` multiplications + `k·(c−1)` additions. Used for the
    /// speedup column of Table 3.
    pub fn dense_ops(&self) -> usize {
        self.c * self.k + self.k * (self.c - 1)
    }

    /// Reference forward: `y[kr] = Σ_ch x[ch]·w[ch,kr]` with zero skipping.
    /// The simulator's outputs and the PJRT-executed JAX artifact are both
    /// checked against this.
    pub fn forward(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.c);
        (0..self.k)
            .map(|kr| {
                (0..self.c)
                    .filter(|&ch| self.has_weight(ch, kr))
                    .map(|ch| x[ch] * self.weight(ch, kr))
                    .sum()
            })
            .collect()
    }

    /// Dense `(c × k)` weight matrix with zeros at masked positions,
    /// row-major — the layout the AOT'd JAX artifact expects.
    pub fn dense_weights(&self) -> Vec<f32> {
        self.weights.clone()
    }

    /// Mask as f32 0.0/1.0, row-major (the artifact's third input).
    pub fn mask_f32(&self) -> Vec<f32> {
        self.mask.iter().map(|&m| if m { 1.0 } else { 0.0 }).collect()
    }

    /// FNV-1a 64 fingerprint of the block's *structure*: shape plus the
    /// packed sparsity mask. A mapping depends on exactly this (weights
    /// only enter at simulation time), so two same-named, same-shaped
    /// blocks with different pruning patterns fingerprint apart — the
    /// coordinator keys its mapping cache on it, and fused-bundle keys
    /// ([`fuse::FusedBundle::fingerprint`]) build on it. Cached at
    /// construction, so the request path never rehashes the O(c·k/8) mask
    /// bytes.
    #[inline]
    pub fn mask_fingerprint(&self) -> u64 {
        // The cached value is only valid while (c, k, mask) stay what
        // `from_mask` saw; those fields are pub, so debug builds verify
        // the cache against a recompute to catch any in-place structure
        // mutation that would silently alias cache keys.
        debug_assert_eq!(
            self.fp,
            fingerprint_of(self.c, self.k, &self.mask),
            "{}: mask_fingerprint stale — (c, k, mask) mutated after from_mask",
            self.name
        );
        self.fp
    }
}

/// The fingerprint computation behind [`SparseBlock::mask_fingerprint`],
/// evaluated once per block in [`SparseBlock::from_mask`].
fn fingerprint_of(c: usize, k: usize, mask: &[bool]) -> u64 {
    let mut h = crate::util::Fnv64::new();
    h.eat_u64(c as u64);
    h.eat_u64(k as u64);
    for chunk in mask.chunks(8) {
        let mut byte = 0u8;
        for (i, &m) in chunk.iter().enumerate() {
            if m {
                byte |= 1 << i;
            }
        }
        h.eat(byte);
    }
    h.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> SparseBlock {
        // 3 channels × 2 kernels:
        //        k0 k1
        //   c0 [  1  0 ]
        //   c1 [  1  1 ]
        //   c2 [  0  1 ]
        SparseBlock::from_mask("toy", 3, 2, vec![true, false, true, true, false, true])
            .unwrap()
    }

    #[test]
    fn feature_extraction() {
        let b = toy();
        let f = b.features();
        assert_eq!(f.nnz, 4);
        assert_eq!(f.v_op, 4 + 2); // 4 muls + (2-1)+(2-1) adds
        assert_eq!(f.v_r, 3);
        assert_eq!(f.v_w, 2);
        assert_eq!(f.n_fg4, 0);
        assert!((f.sparsity - (1.0 - 4.0 / 6.0)).abs() < 1e-12);
    }

    #[test]
    fn association_counts_shared_kernels() {
        let b = toy();
        assert_eq!(b.association(0, 1), 1); // share k0
        assert_eq!(b.association(0, 2), 0);
        assert_eq!(b.association(1, 2), 1); // share k1
        assert_eq!(b.association(1, 1), 2); // self-association = fanout
    }

    #[test]
    fn forward_skips_zeros() {
        let b = toy();
        let x = [1.0, 10.0, 100.0];
        let y = b.forward(&x);
        let w = |ch: usize, kr: usize| b.weight(ch, kr);
        assert_eq!(y[0], 1.0 * w(0, 0) + 10.0 * w(1, 0));
        assert_eq!(y[1], 10.0 * w(1, 1) + 100.0 * w(2, 1));
    }

    #[test]
    fn masked_weights_are_zero() {
        let b = toy();
        assert_eq!(b.weight(0, 1), 0.0);
        assert_eq!(b.weight(2, 0), 0.0);
        assert!(b.weight(1, 1) != 0.0);
    }

    #[test]
    fn dense_ops_formula() {
        let b = toy();
        assert_eq!(b.dense_ops(), 3 * 2 + 2 * 2);
    }

    #[test]
    fn bad_mask_len_rejected() {
        assert!(SparseBlock::from_mask("bad", 2, 2, vec![true]).is_err());
    }

    #[test]
    fn mask_fingerprint_is_cached_and_matches_recompute() {
        let a = toy();
        assert_eq!(a.mask_fingerprint(), fingerprint_of(a.c, a.k, &a.mask));
        // The partitioner's post-construction edits (name, weights) leave
        // the structure untouched, so the cached value stays valid.
        let mut b = a.clone();
        b.name = "renamed".into();
        b.weights[0] = 99.0;
        assert_eq!(b.mask_fingerprint(), a.mask_fingerprint());
    }

    #[test]
    fn mask_fingerprint_separates_structure() {
        let a = toy();
        assert_eq!(a.mask_fingerprint(), toy().mask_fingerprint(), "deterministic");
        // Same shape, one flipped mask bit → different fingerprint.
        let b = SparseBlock::from_mask("toy", 3, 2, vec![true, true, true, true, false, true])
            .unwrap();
        assert_ne!(a.mask_fingerprint(), b.mask_fingerprint());
        // Same flat mask, transposed shape → different fingerprint.
        let c = SparseBlock::from_mask("toy", 2, 3, a.mask.clone()).unwrap();
        assert_ne!(a.mask_fingerprint(), c.mask_fingerprint());
    }
}
