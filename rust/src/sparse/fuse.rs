//! Multi-block fusion planning — the second half of the scale axis.
//!
//! Real pruned networks are dominated by *small* sparse blocks whose s-DFGs
//! leave most of a streaming CGRA's PEs and buses idle; reconfiguring the
//! fabric per block throws away the throughput the streaming architecture
//! exists to provide. A [`FusedBundle`] packs several small blocks so one
//! fabric configuration hosts all of them simultaneously: the bundle maps
//! once (shared II, per-block resources kept disjoint by the binder's
//! conflict buckets — see `crate::mapper::map_unit`) and every member block
//! is then served without reconfiguration.
//!
//! [`plan_bundles`] is the planner: deterministic greedy first-fit
//! bin-packing in input order, with each block's estimated PE/bus demand
//! (its `|V_OP|` / `|V_R|` / `|V_W|` node counts — exactly the quantities
//! the §4.1 MII bound consumes) accumulated per bundle and capped by the
//! combined-MII budget of [`FusionOptions`].

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::arch::StreamingCgra;
use crate::error::{Error, Result};
use crate::sparse::SparseBlock;
use crate::util::Fnv64;

/// Fusion planning knobs (the mapper carries a copy as
/// `MapperOptions::fusion`; `[mapper] max_fused_blocks` /
/// `[mapper] fusion_max_ii` in the config file).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FusionOptions {
    /// Maximum member blocks per bundle. `1` disables fusion entirely.
    pub max_blocks: usize,
    /// Combined-MII budget: a block joins a bundle only while the bundle's
    /// estimated MII (§4.1 bound over the summed node counts) stays at or
    /// below this. Larger budgets pack more work per configuration at the
    /// cost of a longer shared II.
    pub max_ii: usize,
}

impl FusionOptions {
    /// No fusion: every block is its own bundle.
    pub fn disabled() -> Self {
        FusionOptions { max_blocks: 1, max_ii: 0 }
    }
}

impl Default for FusionOptions {
    fn default() -> Self {
        // Up to four paper-scale small blocks fit a combined MII of 12 on
        // the 4×4 fabric with room for the slot-offset composition.
        FusionOptions { max_blocks: 4, max_ii: 12 }
    }
}

/// A bundle of sparse blocks destined for one fabric configuration.
/// Member order is the planner order and is part of the bundle's identity
/// (the composed graph, the mapping and the fingerprint all follow it).
#[derive(Clone, Debug)]
pub struct FusedBundle {
    /// `fused(<member>+<member>+…)` — diagnostic label carried into the
    /// composed s-DFG and error messages.
    pub name: String,
    pub blocks: Vec<Arc<SparseBlock>>,
}

impl FusedBundle {
    pub fn new(blocks: Vec<Arc<SparseBlock>>) -> Result<Self> {
        if blocks.is_empty() {
            return Err(Error::Workload("fusion bundle needs at least one block".into()));
        }
        let name = format!(
            "fused({})",
            blocks.iter().map(|b| b.name.as_str()).collect::<Vec<_>>().join("+")
        );
        Ok(FusedBundle { name, blocks })
    }

    pub fn len(&self) -> usize {
        self.blocks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.blocks.is_empty()
    }

    /// Combined-structure fingerprint: member count plus each member's
    /// cached [`SparseBlock::mask_fingerprint`], order-sensitive. The
    /// coordinator keys the shared fused mapping on it — two bundles with
    /// the same members in the same order share one cache entry no matter
    /// which member a request names.
    pub fn fingerprint(&self) -> u64 {
        let mut h = Fnv64::new();
        h.eat_u64(self.blocks.len() as u64);
        for b in &self.blocks {
            h.eat_u64(b.mask_fingerprint());
        }
        h.finish()
    }

    /// Index of the member whose mask fingerprint is `fp` (first match).
    pub fn member_index_of(&self, fp: u64) -> Option<usize> {
        self.blocks.iter().position(|b| b.mask_fingerprint() == fp)
    }

    /// Estimated MII of the whole bundle on `cgra`: the §4.1 resource
    /// bound over the members' summed node counts. Exact for pristine
    /// graphs (COPs are a scheduling artifact and excluded from MII).
    pub fn mii(&self, cgra: &StreamingCgra) -> usize {
        let (ops, reads, writes) = self.blocks.iter().fold((0, 0, 0), |acc, b| {
            let f = b.features();
            (acc.0 + f.v_op, acc.1 + f.v_r, acc.2 + f.v_w)
        });
        cgra.mii(ops, reads, writes)
    }
}

/// Thread-safe member-fingerprint → bundle routing table: how the serving
/// layer finds, at enqueue time, the fused bundle (and member index) a
/// block's traffic should batch into. Registration is last-writer-wins per
/// member fingerprint; deregistration is pointer-compared so a newer
/// bundle that re-claimed a member is left alone.
#[derive(Default)]
pub struct BundleRoutes {
    routes: Mutex<HashMap<u64, Arc<FusedBundle>>>,
}

impl BundleRoutes {
    pub fn new() -> Self {
        Self::default()
    }

    /// Route every member of `bundle` to it (replacing older claims).
    pub fn register(&self, bundle: Arc<FusedBundle>) {
        let mut routes = self.routes.lock().expect("bundle routes");
        for b in &bundle.blocks {
            routes.insert(b.mask_fingerprint(), Arc::clone(&bundle));
        }
    }

    /// The bundle (and member index inside it) serving mask fingerprint
    /// `fp`, if any.
    pub fn route(&self, fp: u64) -> Option<(Arc<FusedBundle>, usize)> {
        let routes = self.routes.lock().expect("bundle routes");
        let bundle = routes.get(&fp)?;
        let member = bundle
            .member_index_of(fp)
            .expect("routed bundles hold the member they are keyed by");
        Some((Arc::clone(bundle), member))
    }

    /// Drop `bundle`'s member routes. Pointer-compared (a newer bundle
    /// that re-claimed a member fingerprint keeps its route) and
    /// idempotent — every caller that sees the same bundle fail converges
    /// on the same deregistered state.
    pub fn deregister(&self, bundle: &Arc<FusedBundle>) {
        let mut routes = self.routes.lock().expect("bundle routes");
        for b in &bundle.blocks {
            if routes
                .get(&b.mask_fingerprint())
                .is_some_and(|r| Arc::ptr_eq(r, bundle))
            {
                routes.remove(&b.mask_fingerprint());
            }
        }
    }

    /// Number of routed member fingerprints.
    pub fn len(&self) -> usize {
        self.routes.lock().expect("bundle routes").len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Greedy first-fit fusion planning, deterministic in input order: each
/// block joins the first open bundle that stays within `opts.max_blocks`
/// members and the `opts.max_ii` combined-MII budget, else opens a new
/// bundle. Every block lands in exactly one bundle; blocks too large to
/// share a configuration come back as singletons (serve them unfused).
pub fn plan_bundles(
    blocks: &[Arc<SparseBlock>],
    cgra: &StreamingCgra,
    opts: &FusionOptions,
) -> Vec<FusedBundle> {
    struct Open {
        members: Vec<Arc<SparseBlock>>,
        ops: usize,
        reads: usize,
        writes: usize,
    }
    let mut open: Vec<Open> = Vec::new();
    for b in blocks {
        let f = b.features();
        let mut placed = false;
        if opts.max_blocks > 1 {
            for o in open.iter_mut() {
                if o.members.len() >= opts.max_blocks {
                    continue;
                }
                let mii =
                    cgra.mii(o.ops + f.v_op, o.reads + f.v_r, o.writes + f.v_w);
                if mii <= opts.max_ii {
                    o.members.push(Arc::clone(b));
                    o.ops += f.v_op;
                    o.reads += f.v_r;
                    o.writes += f.v_w;
                    placed = true;
                    break;
                }
            }
        }
        if !placed {
            open.push(Open {
                members: vec![Arc::clone(b)],
                ops: f.v_op,
                reads: f.v_r,
                writes: f.v_w,
            });
        }
    }
    open.into_iter()
        .map(|o| FusedBundle::new(o.members).expect("planner bundles are non-empty"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen::paper_blocks;

    fn small_three() -> Vec<Arc<SparseBlock>> {
        // The three c = 4 paper blocks (block1/2/4) — the canonical small set.
        paper_blocks()
            .into_iter()
            .filter(|nb| matches!(nb.label, "block1" | "block2" | "block4"))
            .map(|nb| Arc::new(nb.block))
            .collect()
    }

    #[test]
    fn bundle_identity_and_fingerprint() {
        let blocks = small_three();
        let a = FusedBundle::new(blocks.clone()).unwrap();
        assert_eq!(a.len(), 3);
        assert_eq!(a.name, "fused(block1+block2+block4)");
        assert_eq!(a.fingerprint(), FusedBundle::new(blocks.clone()).unwrap().fingerprint());
        // Order-sensitive.
        let mut rev = blocks.clone();
        rev.reverse();
        assert_ne!(a.fingerprint(), FusedBundle::new(rev).unwrap().fingerprint());
        // Member lookup by mask fingerprint.
        for (i, b) in blocks.iter().enumerate() {
            assert_eq!(a.member_index_of(b.mask_fingerprint()), Some(i));
        }
        assert_eq!(a.member_index_of(0xdead_beef), None);
        assert!(FusedBundle::new(Vec::new()).is_err());
    }

    #[test]
    fn combined_mii_is_bound_over_summed_counts() {
        let cgra = StreamingCgra::paper_default();
        let blocks = small_three();
        let bundle = FusedBundle::new(blocks.clone()).unwrap();
        // block1/2/4: v_op 26+26+32 = 84 → ⌈84/16⌉ = 6; reads 12 → 3;
        // writes 18 → 5. Bound = 6.
        assert_eq!(bundle.mii(&cgra), 6);
        for b in &blocks {
            let f = b.features();
            assert!(bundle.mii(&cgra) >= cgra.mii(f.v_op, f.v_r, f.v_w));
        }
    }

    #[test]
    fn planner_is_deterministic_first_fit() {
        let cgra = StreamingCgra::paper_default();
        let blocks: Vec<Arc<SparseBlock>> =
            paper_blocks().into_iter().map(|nb| Arc::new(nb.block)).collect();
        let opts = FusionOptions::default();
        let a = plan_bundles(&blocks, &cgra, &opts);
        let b = plan_bundles(&blocks, &cgra, &opts);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.name, y.name);
            assert_eq!(x.fingerprint(), y.fingerprint());
        }
        // Every block lands in exactly one bundle, in input order.
        let flat: Vec<&str> =
            a.iter().flat_map(|bu| bu.blocks.iter().map(|b| b.name.as_str())).collect();
        let want: Vec<&str> = blocks.iter().map(|b| b.name.as_str()).collect();
        assert_eq!(flat, want);
        // Budgets respected.
        for bu in &a {
            assert!(bu.len() <= opts.max_blocks);
            assert!(bu.len() == 1 || bu.mii(&cgra) <= opts.max_ii);
        }
    }

    #[test]
    fn bundle_routes_register_route_deregister() {
        let blocks = small_three();
        let routes = BundleRoutes::new();
        assert!(routes.is_empty());
        let b1 = Arc::new(FusedBundle::new(blocks[..2].to_vec()).unwrap());
        let b2 = Arc::new(FusedBundle::new(blocks[1..].to_vec()).unwrap());
        routes.register(Arc::clone(&b1));
        routes.register(Arc::clone(&b2)); // re-claims the shared member
        assert_eq!(routes.len(), 3);
        // Routing resolves both bundle and member index.
        let (bundle, member) = routes.route(blocks[0].mask_fingerprint()).unwrap();
        assert!(Arc::ptr_eq(&bundle, &b1));
        assert_eq!(member, 0);
        let (bundle, member) = routes.route(blocks[1].mask_fingerprint()).unwrap();
        assert!(Arc::ptr_eq(&bundle, &b2), "latest registration wins");
        assert_eq!(member, 0);
        assert!(routes.route(0xdead_beef).is_none());
        // Deregistering b1 leaves the shared member with b2 (pointer
        // compare), and is idempotent.
        routes.deregister(&b1);
        assert!(routes.route(blocks[0].mask_fingerprint()).is_none());
        assert!(routes
            .route(blocks[1].mask_fingerprint())
            .is_some_and(|(b, _)| Arc::ptr_eq(&b, &b2)));
        assert!(routes.route(blocks[2].mask_fingerprint()).is_some());
        routes.deregister(&b1);
        assert_eq!(routes.len(), 2);
    }

    #[test]
    fn planner_fuses_small_blocks_and_isolates_large() {
        let cgra = StreamingCgra::paper_default();
        let blocks: Vec<Arc<SparseBlock>> =
            paper_blocks().into_iter().map(|nb| Arc::new(nb.block)).collect();
        let plan = plan_bundles(&blocks, &cgra, &FusionOptions { max_blocks: 3, max_ii: 8 });
        assert!(
            plan.iter().any(|bu| bu.len() >= 2),
            "small paper blocks must fuse under an MII-8 budget"
        );
        // A tight budget forces singletons.
        let solo = plan_bundles(&blocks, &cgra, &FusionOptions { max_blocks: 3, max_ii: 1 });
        assert!(solo.iter().all(|bu| bu.len() == 1));
        // Disabled fusion: one bundle per block.
        let off = plan_bundles(&blocks, &cgra, &FusionOptions::disabled());
        assert_eq!(off.len(), blocks.len());
        assert!(off.iter().all(|bu| bu.len() == 1));
    }
}
