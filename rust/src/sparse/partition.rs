//! Partitioning a (block-)sparse conv layer into mapper-sized sparse
//! blocks (paper §1: "the sparse CNN is typically partitioned into multiple
//! sparse blocks which are handled in a predetermined order").
//!
//! A layer is an im2col-flattened weight matrix `(C_total × K_total)` with
//! a 0/1 mask. We tile it into blocks of at most `max_c` channels ×
//! `max_k` kernels; blocks in the same kernel-tile accumulate into the same
//! outputs, which the coordinator sums (the CGRA handles one block at a
//! time, exactly as in the paper).

use crate::error::{Error, Result};
use crate::sparse::SparseBlock;

/// A block cut out of a layer, with its placement inside the layer.
#[derive(Clone, Debug)]
pub struct LayerBlock {
    pub block: SparseBlock,
    /// First layer-channel covered by this block.
    pub ch_offset: usize,
    /// First layer-kernel covered.
    pub kr_offset: usize,
    /// Index of the kernel tile (blocks sharing it accumulate together).
    pub kr_tile: usize,
}

/// A sparse layer: flattened weights + mask.
#[derive(Clone, Debug)]
pub struct SparseLayer {
    pub name: String,
    pub c_total: usize,
    pub k_total: usize,
    pub weights: Vec<f32>,
    pub mask: Vec<bool>,
}

impl SparseLayer {
    pub fn new(
        name: &str,
        c_total: usize,
        k_total: usize,
        weights: Vec<f32>,
        mask: Vec<bool>,
    ) -> Result<Self> {
        if weights.len() != c_total * k_total || mask.len() != c_total * k_total {
            return Err(Error::Workload(format!(
                "layer '{name}': weights/mask size mismatch with {c_total}x{k_total}"
            )));
        }
        Ok(SparseLayer {
            name: name.to_string(),
            c_total,
            k_total,
            weights,
            mask,
        })
    }

    /// Dense reference forward for one input vector (layer semantics).
    pub fn forward(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.c_total);
        (0..self.k_total)
            .map(|kr| {
                (0..self.c_total)
                    .filter(|&ch| self.mask[ch * self.k_total + kr])
                    .map(|ch| x[ch] * self.weights[ch * self.k_total + kr])
                    .sum()
            })
            .collect()
    }

    /// Tile into blocks of at most `max_c × max_k`. Blocks that end up with
    /// an all-zero sub-mask are dropped (nothing to compute — this is the
    /// zero-block skipping a sparse accelerator performs). Channels with no
    /// nonzero inside a block are compacted out of it so the block's
    /// `|V_R|` reflects real input demands.
    pub fn partition(&self, max_c: usize, max_k: usize) -> Vec<LayerBlock> {
        assert!(max_c > 0 && max_k > 0);
        let mut out = Vec::new();
        let kr_tiles = self.k_total.div_ceil(max_k);
        for kt in 0..kr_tiles {
            let kr0 = kt * max_k;
            let kw = max_k.min(self.k_total - kr0);
            let mut ch0 = 0;
            while ch0 < self.c_total {
                let cw = max_c.min(self.c_total - ch0);
                // Collect live channels of this tile.
                let live: Vec<usize> = (ch0..ch0 + cw)
                    .filter(|&ch| {
                        (kr0..kr0 + kw).any(|kr| self.mask[ch * self.k_total + kr])
                    })
                    .collect();
                if !live.is_empty() {
                    let mut mask = Vec::with_capacity(live.len() * kw);
                    let mut weights = Vec::with_capacity(live.len() * kw);
                    for &ch in &live {
                        for kr in kr0..kr0 + kw {
                            mask.push(self.mask[ch * self.k_total + kr]);
                            weights.push(self.weights[ch * self.k_total + kr]);
                        }
                    }
                    let name = format!("{}_c{}k{}", self.name, ch0, kr0);
                    let mut block = SparseBlock::from_mask(&name, live.len(), kw, mask)
                        .expect("sized mask");
                    block.weights = weights;
                    out.push(LayerBlock {
                        block,
                        // ch_offset is only meaningful together with the
                        // live-channel list; we store the live channels in
                        // the block name order. For gather we keep them:
                        ch_offset: ch0,
                        kr_offset: kr0,
                        kr_tile: kt,
                    });
                    // Record live channels for gathering inputs.
                    out.last_mut().unwrap().block.name =
                        format!("{name}[{}]", join_idx(&live));
                }
                ch0 += cw;
            }
        }
        out
    }

    /// Live channels of a partitioned block, recovered for input gathering.
    pub fn live_channels(block_name: &str) -> Vec<usize> {
        let open = block_name.rfind('[').expect("partitioned block name");
        let close = block_name.rfind(']').expect("partitioned block name");
        block_name[open + 1..close]
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|s| s.parse().expect("channel index"))
            .collect()
    }
}

fn join_idx(v: &[usize]) -> String {
    v.iter().map(|i| i.to_string()).collect::<Vec<_>>().join(",")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn layer(c: usize, k: usize, p_zero: f64, seed: u64) -> SparseLayer {
        let mut rng = Pcg64::seeded(seed);
        let mask: Vec<bool> = (0..c * k).map(|_| !rng.chance(p_zero)).collect();
        let weights: Vec<f32> = mask
            .iter()
            .map(|&m| if m { rng.next_normal() as f32 } else { 0.0 })
            .collect();
        SparseLayer::new("L", c, k, weights, mask).unwrap()
    }

    #[test]
    fn partition_covers_every_nonzero_exactly_once() {
        let l = layer(20, 14, 0.4, 3);
        let blocks = l.partition(8, 8);
        let mut covered = vec![0usize; 20 * 14];
        for lb in &blocks {
            let live = SparseLayer::live_channels(&lb.block.name);
            assert_eq!(live.len(), lb.block.c);
            for (bi, &ch) in live.iter().enumerate() {
                for bk in 0..lb.block.k {
                    if lb.block.has_weight(bi, bk) {
                        covered[ch * 14 + (lb.kr_offset + bk)] += 1;
                    }
                }
            }
        }
        for ch in 0..20 {
            for kr in 0..14 {
                let want = l.mask[ch * 14 + kr] as usize;
                assert_eq!(covered[ch * 14 + kr], want, "at ({ch},{kr})");
            }
        }
    }

    #[test]
    fn blocks_respect_size_caps() {
        let l = layer(30, 17, 0.3, 5);
        for lb in l.partition(8, 8) {
            assert!(lb.block.c <= 8 && lb.block.k <= 8);
            assert!(lb.block.nnz() > 0);
        }
    }

    #[test]
    fn block_accumulation_equals_layer_forward() {
        let l = layer(20, 14, 0.4, 7);
        let blocks = l.partition(8, 8);
        let mut rng = Pcg64::seeded(11);
        let x: Vec<f32> = (0..20).map(|_| rng.next_normal() as f32).collect();
        let mut y = vec![0f32; 14];
        for lb in &blocks {
            let live = SparseLayer::live_channels(&lb.block.name);
            let xs: Vec<f32> = live.iter().map(|&ch| x[ch]).collect();
            let yb = lb.block.forward(&xs);
            for (bk, v) in yb.iter().enumerate() {
                y[lb.kr_offset + bk] += v;
            }
        }
        let want = l.forward(&x);
        for (a, b) in y.iter().zip(&want) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn empty_tiles_dropped() {
        let mask = vec![false; 8 * 4];
        let l = SparseLayer::new("Z", 8, 4, vec![0.0; 32], mask).unwrap();
        assert!(l.partition(4, 4).is_empty());
    }
}
