//! Magnitude pruning: the path that produces the paper's "sparse models of
//! VGGNet and AlexNet" blocks (block6/block7). A dense weight tensor is
//! pruned to a target sparsity by zeroing the smallest-magnitude weights,
//! then partitioned into mapper-sized sparse blocks.

use crate::error::{Error, Result};
use crate::sparse::partition::SparseLayer;
use crate::util::rng::Pcg64;

/// Prune a dense `(c_total × k_total)` weight matrix to `target_sparsity`
/// (fraction of zeros) by global magnitude thresholding.
pub fn magnitude_prune(
    name: &str,
    c_total: usize,
    k_total: usize,
    weights: &[f32],
    target_sparsity: f64,
) -> Result<SparseLayer> {
    if weights.len() != c_total * k_total {
        return Err(Error::Workload(format!(
            "prune '{name}': {} weights for {c_total}x{k_total}",
            weights.len()
        )));
    }
    if !(0.0..1.0).contains(&target_sparsity) {
        return Err(Error::Workload(format!(
            "prune '{name}': sparsity {target_sparsity} outside [0,1)"
        )));
    }
    if let Some(pos) = weights.iter().position(|w| w.is_nan()) {
        return Err(Error::Workload(format!(
            "prune '{name}': NaN weight at index {pos}"
        )));
    }
    let mut mags: Vec<f32> = weights.iter().map(|w| w.abs()).collect();
    mags.sort_by(f32::total_cmp);
    let cut = ((weights.len() as f64) * target_sparsity).floor() as usize;
    let threshold = if cut == 0 { -1.0 } else { mags[cut - 1] };
    let mask: Vec<bool> = weights.iter().map(|w| w.abs() > threshold).collect();
    let pruned: Vec<f32> = weights
        .iter()
        .zip(&mask)
        .map(|(&w, &m)| if m { w } else { 0.0 })
        .collect();
    SparseLayer::new(name, c_total, k_total, pruned, mask)
}

/// Generate a dense layer with a realistic (heavy-tailed) weight
/// distribution, prune it, and return the sparse layer — the synthetic
/// stand-in for a pruned VGG/AlexNet layer (DESIGN.md §Substitutions).
pub fn synthetic_pruned_layer(
    name: &str,
    c_total: usize,
    k_total: usize,
    target_sparsity: f64,
    seed: u64,
) -> Result<SparseLayer> {
    let mut rng = Pcg64::seeded(seed);
    // Product of two normals gives the heavier tail seen in trained nets.
    let weights: Vec<f32> = (0..c_total * k_total)
        .map(|_| (rng.next_normal() * rng.next_normal() * 0.5) as f32)
        .collect();
    magnitude_prune(name, c_total, k_total, &weights, target_sparsity)
}

/// Achieved sparsity of a layer.
pub fn sparsity(layer: &SparseLayer) -> f64 {
    1.0 - layer.mask.iter().filter(|&&m| m).count() as f64 / layer.mask.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prunes_to_target() {
        for target in [0.0, 0.3, 0.5, 0.8] {
            let l = synthetic_pruned_layer("p", 32, 16, target, 1).unwrap();
            let got = sparsity(&l);
            assert!(
                (got - target).abs() < 0.02,
                "target {target}, got {got}"
            );
        }
    }

    #[test]
    fn keeps_largest_magnitudes() {
        let weights: Vec<f32> = (1..=16).map(|i| i as f32).collect();
        let l = magnitude_prune("m", 4, 4, &weights, 0.5).unwrap();
        // The smallest 8 weights (1..=8) are zeroed.
        for (i, &w) in l.weights.iter().enumerate() {
            if i < 8 {
                assert_eq!(w, 0.0, "weight {i}");
            } else {
                assert_eq!(w, (i + 1) as f32);
            }
        }
    }

    #[test]
    fn pruned_layer_partitions_into_mappable_blocks() {
        use crate::arch::StreamingCgra;
        use crate::mapper::{map_block, MapperOptions};
        let l = synthetic_pruned_layer("vggish", 24, 12, 0.55, 7).unwrap();
        let blocks = l.partition(6, 4);
        assert!(!blocks.is_empty());
        let cgra = StreamingCgra::paper_default();
        let opts = MapperOptions::sparsemap();
        let mut ok = 0;
        for lb in &blocks {
            if map_block(&lb.block, &cgra, &opts).is_ok() {
                ok += 1;
            }
        }
        assert!(ok * 10 >= blocks.len() * 9, "{ok}/{} blocks mapped", blocks.len());
    }

    #[test]
    fn rejects_bad_input() {
        assert!(magnitude_prune("b", 2, 2, &[1.0; 3], 0.5).is_err());
        assert!(magnitude_prune("b", 2, 2, &[1.0; 4], 1.0).is_err());
    }

    #[test]
    fn rejects_nan_weights_without_panicking() {
        let weights = [1.0, f32::NAN, 3.0, 4.0];
        let err = magnitude_prune("nan", 2, 2, &weights, 0.5).unwrap_err();
        match err {
            Error::Workload(msg) => assert!(msg.contains("NaN"), "{msg}"),
            other => panic!("expected Workload error, got {other}"),
        }
        // Infinities are orderable and must still prune fine (total_cmp).
        let weights = [1.0, f32::INFINITY, -3.0, 0.5];
        let l = magnitude_prune("inf", 2, 2, &weights, 0.5).unwrap();
        assert_eq!(sparsity(&l), 0.5);
    }
}
