//! Output-writing scheduling (paper §4.1 ③).
//!
//! The scheduling distance between an output writing and its producer must
//! be exactly 1 (no buffer on output buses). If the output buses at
//! `t₂ + 1` are taken, a COP is inserted: it becomes the new producer of
//! the write and is tried at every later slot until a cycle with both a
//! free PE (for the COP) and a free output bus (for the write, one cycle
//! after) is found.

use crate::dfg::{EdgeKind, NodeKind, SDfg};
use crate::error::{Error, Result};
use crate::sched::ResourceTables;

/// Schedule all writes. Expects every PE op scheduled. Mutates `g` when
/// output COPs are needed.
pub fn schedule_writes(
    g: &mut SDfg,
    t: &mut Vec<Option<usize>>,
    tables: &mut ResourceTables,
) -> Result<()> {
    // Deterministic order: by producer time, then node id (kernels whose
    // result is ready first claim output buses first).
    let mut writes: Vec<(usize, usize)> = g
        .nodes()
        .filter(|&v| g.kind(v).is_write())
        .map(|v| {
            let prod = g.predecessors(v).next().expect("write has producer");
            (t[prod].expect("producer scheduled"), v)
        })
        .collect();
    writes.sort_unstable();

    let span = 4 * tables.ii + 4;
    for (t2, w) in writes {
        let t3 = t2 + 1;
        if tables.obus_free(t3) > 0 {
            t[w] = Some(t3);
            tables.take_obus(t3, 1);
            continue;
        }
        // Insert an output-side COP: v_a -> cop (internal), cop -> w (output).
        let mut placed = false;
        for tc in t3..t3 + span {
            if tables.pe_free(tc) > 0 && tables.obus_free(tc + 1) > 0 {
                let cop = g.add_node(NodeKind::Cop { for_read: false });
                t.push(None);
                let out_edge = g
                    .in_edges(w)
                    .map(|(i, _)| i)
                    .next()
                    .expect("write in-edge");
                let va = g.edge(out_edge).src;
                g.retarget_edge_src(out_edge, cop);
                g.add_edge(va, cop, EdgeKind::Internal);
                t[cop] = Some(tc);
                t[w] = Some(tc + 1);
                tables.take_pe(tc, 1);
                tables.take_obus(tc + 1, 1);
                placed = true;
                break;
            }
        }
        if !placed {
            return Err(Error::ScheduleFailed {
                block: g.name.clone(),
                reason: format!("no slot for output writing {w} (producer at {t2})"),
                ii_cap: tables.ii,
            });
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::StreamingCgra;
    use crate::dfg::build::build_sdfg;
    use crate::sparse::SparseBlock;

    /// 6 kernels all completing at t=1 on a machine with 4 output buses:
    /// 4 writes go out at t=2, the remaining 2 need COPs.
    #[test]
    fn overflow_writes_get_cops() {
        // 1 channel, 6 kernels, each kernel = single mul.
        let b = SparseBlock::from_mask("w6", 1, 6, vec![true; 6]).unwrap();
        let (mut g, _) = build_sdfg(&b);
        let cgra = StreamingCgra::paper_default();
        let ii = 2;
        let mut tables = ResourceTables::new(&cgra, ii);
        let mut t: Vec<Option<usize>> = vec![None; g.len()];
        for v in g.nodes() {
            match g.kind(v) {
                NodeKind::Read { .. } => t[v] = Some(1),
                NodeKind::Mul { .. } => {
                    t[v] = Some(1);
                    tables.take_pe(1, 1);
                }
                _ => {}
            }
        }
        schedule_writes(&mut g, &mut t, &mut tables).unwrap();
        g.validate().unwrap();
        assert_eq!(g.cops().len(), 2, "two writes overflow N=4 buses");
        // All writes scheduled with distance exactly 1 from their producer.
        for v in g.nodes() {
            if g.kind(v).is_write() {
                let p = g.predecessors(v).next().unwrap();
                assert_eq!(t[v].unwrap(), t[p].unwrap() + 1);
            }
        }
        // Output buses never oversubscribed per modulo slot.
        let mut occ = vec![0usize; ii];
        for v in g.nodes() {
            if g.kind(v).is_write() {
                occ[t[v].unwrap() % ii] += 1;
            }
        }
        assert!(occ.iter().all(|&o| o <= 4), "{occ:?}");
    }

    #[test]
    fn no_cop_when_buses_available() {
        let b = SparseBlock::from_mask("w2", 1, 2, vec![true, true]).unwrap();
        let (mut g, _) = build_sdfg(&b);
        let cgra = StreamingCgra::paper_default();
        let mut tables = ResourceTables::new(&cgra, 2);
        let mut t: Vec<Option<usize>> = vec![None; g.len()];
        for v in g.nodes() {
            if !g.kind(v).is_write() {
                t[v] = Some(0);
            }
        }
        schedule_writes(&mut g, &mut t, &mut tables).unwrap();
        assert_eq!(g.cops().len(), 0);
    }
}
