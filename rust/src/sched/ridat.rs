//! Adder-tree scheduling: RID-AT (paper §4.1 ② / Fig. 6) and the fixed-tree
//! ASAP policy used when RID-AT is disabled (baselines, ablation).
//!
//! RID-AT here is *routing-aware*: the paper's objective (4) minimizes the
//! MCID count because MCIDs are what the GRF must route (Fig. 3 shows GRF
//! routing capacity is the scarce resource). When pairing unaccumulated
//! operations this implementation therefore also tracks the GRF write-port
//! budget the MCIDs it creates will need, picks partners that avoid
//! same-modulo MCIDs (which are forced onto the GRF), and defers a pairing
//! by one cycle when that provably avoids an unroutable dependency.

use crate::arch::StreamingCgra;
use crate::dfg::{EdgeKind, NodeId, NodeKind, SDfg};
use crate::error::{Error, Result};
use crate::sched::ResourceTables;

/// How far past the last scheduled op we will search for a PE slot before
/// declaring the attempt failed (prevents unbounded loops at tiny IIs —
/// every modulo slot repeats after `ii` steps, so `4·ii` is generous).
fn search_span(ii: usize) -> usize {
    4 * ii + 4
}

/// Cost of creating an addition at `t1` over producers at `ta`/`tb`:
/// `(grf_overflow, grf_writes, mcids)` — lexicographically minimized.
fn pair_cost(
    ta: usize,
    tb: usize,
    t1: usize,
    ii: usize,
    grf_writes: &[usize],
    ports: usize,
) -> (usize, usize, usize) {
    let mut mcids = 0usize;
    // Classify each producer edge: dist 1 → free; MCID same-modulo → GRF
    // forced; MCID diff-modulo → LRF-eligible.
    let mut forced: Vec<usize> = Vec::with_capacity(2); // GRF write slots
    let mut eligible: Vec<usize> = Vec::with_capacity(2);
    for &tx in &[ta, tb] {
        let dist = t1 - tx;
        if dist <= 1 {
            continue;
        }
        mcids += 1;
        if dist % ii == 0 {
            forced.push((tx + 1) % ii);
        } else {
            eligible.push((tx + 1) % ii);
        }
    }
    // One LRF slot per consumer: drop the single most expensive eligible
    // write (the consumer sits on that producer's PE instead).
    if eligible.len() > 1 {
        // Keep the cheaper one as a GRF write.
        let (w0, w1) = (eligible[0], eligible[1]);
        let keep = if grf_writes[w0] <= grf_writes[w1] { w0 } else { w1 };
        forced.push(keep);
    }
    let mut over = 0usize;
    let mut writes = 0usize;
    let mut tally = vec![0usize; ii];
    for w in forced {
        tally[w] += 1;
        writes += 1;
        if grf_writes[w] + tally[w] > ports {
            over += 1;
        }
    }
    (over, writes, mcids)
}

/// Commit the GRF writes `pair_cost` predicted for a pairing.
fn commit_pair(
    ta: usize,
    tb: usize,
    t1: usize,
    ii: usize,
    grf_writes: &mut [usize],
) {
    let mut eligible: Vec<usize> = Vec::with_capacity(2);
    for &tx in &[ta, tb] {
        let dist = t1 - tx;
        if dist <= 1 {
            continue;
        }
        if dist % ii == 0 {
            grf_writes[(tx + 1) % ii] += 1;
        } else {
            eligible.push((tx + 1) % ii);
        }
    }
    if eligible.len() > 1 {
        let (w0, w1) = (eligible[0], eligible[1]);
        let keep = if grf_writes[w0] <= grf_writes[w1] { w0 } else { w1 };
        grf_writes[keep] += 1;
    }
}

/// Per-kernel reduction state during the global time-march.
struct KernelState {
    kr: usize,
    /// Unaccumulated ops, sorted by (time, id).
    unacc: Vec<(usize, NodeId)>,
    /// Additions still to be placed.
    adds_pool: Vec<NodeId>,
}

/// RID-AT over every kernel, *globally time-marched*: at each cycle `t1`
/// all kernels compete for the free PEs, pairings anchored by the oldest
/// unaccumulated op anywhere (its dependency distance grows every cycle we
/// wait). Per pairing the partner is chosen to minimize
/// `(GRF overflow, GRF writes, MCIDs)`, and a pairing that would overflow a
/// GRF write port is deferred one cycle when that provably avoids it.
///
/// Expects all muls scheduled, all adds unscheduled. Clears each kernel's
/// fixed tree, rebuilds it against the realized mul schedule, schedules the
/// adds and re-points each kernel's output edge at its new root.
pub fn reconstruct_adder_trees(
    g: &mut SDfg,
    t: &mut [Option<usize>],
    tables: &mut ResourceTables,
    kernels: &[usize],
    cgra: &StreamingCgra,
) -> Result<()> {
    let ii = tables.ii;
    let ports = cgra.grf_write_ports;
    // Shared GRF write-port pressure (mirrors the binder's pre-allocation).
    let mut grf_writes = vec![0usize; ii];

    let mut states: Vec<KernelState> = Vec::new();
    for &kr in kernels {
        let ops = g.kernel_ops(kr);
        let muls: Vec<NodeId> = ops
            .iter()
            .copied()
            .filter(|&v| matches!(g.kind(v), NodeKind::Mul { .. }))
            .collect();
        if muls.is_empty() {
            continue;
        }
        debug_assert!(muls.iter().all(|&m| t[m].is_some()), "RID-AT requires scheduled muls");
        let adds_pool: Vec<NodeId> = ops
            .iter()
            .copied()
            .filter(|&v| matches!(g.kind(v), NodeKind::Add { .. }))
            .collect();
        // Clear the fixed tree's wiring; the Output edge survives and is
        // re-pointed at the new root at the end.
        g.clear_internal_edges_among(&ops);
        let mut unacc: Vec<(usize, NodeId)> = muls.iter().map(|&m| (t[m].unwrap(), m)).collect();
        unacc.sort_unstable();
        states.push(KernelState { kr, unacc, adds_pool });
    }
    if states.is_empty() {
        return Ok(());
    }

    let t_min = states.iter().map(|k| k.unacc[0].0).min().unwrap();
    let t_max = states.iter().flat_map(|k| k.unacc.iter().map(|&(tm, _)| tm)).max().unwrap();
    let deadline = t_max + search_span(ii);

    let mut t0 = t_min;
    while states.iter().any(|k| k.unacc.len() > 1) {
        if t0 > deadline {
            return Err(Error::ScheduleFailed {
                block: g.name.clone(),
                reason: "RID-AT exceeded its PE-slot search horizon".into(),
                ii_cap: ii,
            });
        }
        let t1 = t0 + 1;
        // Commit pairings at t1, oldest anchor first across all kernels.
        // A kernel whose best pairing would overflow a GRF write port may
        // sit this cycle out (once per cycle, and only while its anchor is
        // younger than II cycles — beyond that waiting cannot change the
        // modulo classes any further).
        let mut deferred = vec![false; states.len()];
        loop {
            if tables.pe_free(t1) == 0 {
                break;
            }
            // Best proposal per kernel: anchor = kernel's oldest ready op.
            let mut best: Option<(usize, usize, (usize, usize, usize), usize)> = None;
            // (anchor_time, kernel_idx, cost, partner_j)
            for (ki, k) in states.iter().enumerate() {
                if deferred[ki] {
                    continue;
                }
                let ready = k.unacc.partition_point(|&(tm, _)| tm <= t0);
                if ready < 2 || k.adds_pool.is_empty() {
                    continue;
                }
                let (ta, _) = k.unacc[0];
                let (j, cost) = (1..ready)
                    .map(|j| (j, pair_cost(ta, k.unacc[j].0, t1, ii, &grf_writes, ports)))
                    .min_by_key(|&(j, c)| (c, std::cmp::Reverse(k.unacc[j].0), j))
                    .expect("ready >= 2");
                let key = (ta, ki, cost, j);
                if best.map_or(true, |b| (key.2 .0, key.0, key.2) < (b.2 .0, b.0, b.2)) {
                    best = Some(key);
                }
            }
            let Some((_, ki, cost, j, )) = best else { break };
            // Defer an overflowing pairing when the next cycle provably
            // avoids the overflow (waiting flips the dependency distance's
            // modulo class, often turning a forced-GRF MCID into an
            // LRF-routable one). GRF pressure only grows, so a kernel
            // cannot defer forever: either the later cost stays 0 and it
            // commits then, or it stops being 0 and the kernel commits now.
            if cost.0 > 0 && t1 < deadline && tables.pe_free(t1 + 1) > 0 {
                let k = &states[ki];
                let ready = k.unacc.partition_point(|&(tm, _)| tm <= t0);
                let (ta, _) = k.unacc[0];
                let later = (1..ready)
                    .map(|jj| pair_cost(ta, k.unacc[jj].0, t1 + 1, ii, &grf_writes, ports))
                    .min()
                    .unwrap();
                if later.0 == 0 {
                    deferred[ki] = true; // sit this cycle out
                    continue;
                }
            }
            let k = &mut states[ki];
            let (ta, a) = k.unacc.remove(0);
            let (tb, b) = k.unacc.remove(j - 1);
            let add = k.adds_pool.pop().expect("n-1 adds for n muls");
            g.add_edge(a, add, EdgeKind::Internal);
            g.add_edge(b, add, EdgeKind::Internal);
            t[add] = Some(t1);
            tables.take_pe(t1, 1);
            commit_pair(ta, tb, t1, ii, &mut grf_writes);
            let pos = k.unacc.partition_point(|&(tm, id)| (tm, id) < (t1, add));
            k.unacc.insert(pos, (t1, add));
        }
        t0 = t1;
    }

    // Re-point each kernel's output dependency at its new root.
    for k in &states {
        debug_assert!(k.adds_pool.is_empty(), "all adds consumed");
        let root = k.unacc[0].1;
        let write = g
            .nodes()
            .find(|&v| matches!(g.kind(v), NodeKind::Write { kr } if kr == k.kr))
            .expect("kernel has a write");
        let out_edge = g
            .in_edges(write)
            .map(|(i, _)| i)
            .next()
            .expect("write has an output in-edge");
        g.retarget_edge_src(out_edge, root);
    }
    Ok(())
}

/// Fixed-tree policy: schedule each kernel's existing adds ASAP (earliest
/// `t ≥ max(producers)+1` with a free modulo PE). This is what the baseline
/// compilers do — the tree wiring is never changed.
pub fn schedule_adds_fixed(
    g: &SDfg,
    t: &mut [Option<usize>],
    tables: &mut ResourceTables,
) -> Result<()> {
    let order = g.topo_order();
    for v in order {
        if !matches!(g.kind(v), NodeKind::Add { .. }) || t[v].is_some() {
            continue;
        }
        let lo = g
            .in_edges(v)
            .map(|(_, e)| {
                t[e.src].expect("producers scheduled before adds in topo order") + 1
            })
            .max()
            .expect("add has producers");
        let span = search_span(tables.ii);
        let Some(slot) = crate::sched::earliest_pe_slot(tables, lo, span) else {
            return Err(Error::ScheduleFailed {
                block: g.name.clone(),
                reason: format!("no PE slot for add {v} in [{lo}, {})", lo + span),
                ii_cap: tables.ii,
            });
        };
        t[v] = Some(slot);
        tables.take_pe(slot, 1);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::StreamingCgra;
    use crate::dfg::build::build_sdfg;
    use crate::sparse::SparseBlock;

    /// Fig. 5(a): one kernel with 4 multiplications scheduled at t=0,0,1,2.
    /// Fixed balanced tree gives ≥2 MCIDs; RID-AT gives ≤1 (Fig. 5(b)-(c)).
    fn fig5_graph() -> (SDfg, Vec<Option<usize>>, Vec<NodeId>) {
        let b = SparseBlock::from_mask("fig5", 4, 1, vec![true; 4]).unwrap();
        let (g, idx) = build_sdfg(&b);
        let mut t = vec![None; g.len()];
        let times = [0usize, 0, 1, 2];
        let mut muls = Vec::new();
        for ch in 0..4 {
            let r = idx.read(ch).unwrap();
            let m = idx.mul(ch, 0).unwrap();
            t[r] = Some(times[ch]);
            t[m] = Some(times[ch]);
            muls.push(m);
        }
        (g, t, muls)
    }

    fn count_mcids(g: &SDfg, t: &[Option<usize>]) -> usize {
        g.edges()
            .iter()
            .filter(|e| e.kind == crate::dfg::EdgeKind::Internal)
            .filter(|e| t[e.dst].unwrap() - t[e.src].unwrap() > 1)
            .count()
    }

    #[test]
    fn fig5_fixed_tree_has_mcids() {
        let (g, mut t, _) = fig5_graph();
        let cgra = StreamingCgra::paper_default();
        let mut tables = ResourceTables::new(&cgra, 4);
        schedule_adds_fixed(&g, &mut t, &mut tables).unwrap();
        assert!(count_mcids(&g, &t) >= 2);
    }

    #[test]
    fn fig5_ridat_strictly_beats_fixed_tree() {
        let (mut g, mut t, _) = fig5_graph();
        let cgra = StreamingCgra::paper_default();
        let mut tables = ResourceTables::new(&cgra, 4);

        let (g_fixed, t_fixed) = {
            let gf = g.clone();
            let mut tf = t.clone();
            let mut tb = ResourceTables::new(&cgra, 4);
            schedule_adds_fixed(&gf, &mut tf, &mut tb).unwrap();
            (gf, tf)
        };
        reconstruct_adder_trees(&mut g, &mut t, &mut tables, &[0], &cgra).unwrap();
        g.validate().unwrap();
        assert!(
            count_mcids(&g, &t) < count_mcids(&g_fixed, &t_fixed),
            "RID-AT must reduce MCIDs: {} vs fixed {}",
            count_mcids(&g, &t),
            count_mcids(&g_fixed, &t_fixed)
        );
        assert!(count_mcids(&g, &t) <= 1, "paper reports 1 MCID for Fig. 5(c)");
        // All adds scheduled.
        assert!(g.nodes().all(|v| t[v].is_some() || g.kind(v).is_write()));
    }

    #[test]
    fn ridat_preserves_tree_invariants() {
        for seed in 0..10 {
            let b = crate::sparse::gen::random_block("r", 8, 8, 0.4, seed);
            let (g0, idx) = build_sdfg(&b);
            let mut g = g0.clone();
            let cgra = StreamingCgra::paper_default();
            let ii = crate::dfg::analysis::mii(&g0, &cgra) + 1;
            let mut tables = ResourceTables::new(&cgra, ii);
            let mut t = vec![None; g.len()];
            // Schedule reads+muls greedily over spread times, respecting the
            // per-slot PE budget so the tables stay consistent.
            let mut tt = 0usize;
            for ch in 0..8 {
                if let Some(r) = idx.read(ch) {
                    let fan = g0.fanout_muls(r);
                    while tables.pe_free(tt) < fan.len() {
                        tt += 1;
                    }
                    t[r] = Some(tt);
                    for m in fan {
                        t[m] = Some(tt);
                        tables.take_pe(tt, 1);
                    }
                    tt = (tt + 1) % 3; // spread across early slots
                }
            }
            let kernels: Vec<usize> = (0..8).filter(|&k| b.kernel_size(k) > 0).collect();
            reconstruct_adder_trees(&mut g, &mut t, &mut tables, &kernels, &cgra).unwrap();
            g.validate().unwrap_or_else(|e| panic!("seed {seed}: {e}"));
            // Every add has exactly 2 producers scheduled strictly earlier.
            for v in g.nodes() {
                if matches!(g.kind(v), NodeKind::Add { .. }) {
                    let tv = t[v].unwrap();
                    for p in g.predecessors(v) {
                        assert!(t[p].unwrap() < tv, "seed {seed}");
                    }
                }
            }
        }
    }

    #[test]
    fn fixed_asap_respects_pe_budget() {
        let b = crate::sparse::gen::random_block("r", 8, 8, 0.3, 3);
        let (g, idx) = build_sdfg(&b);
        let cgra = StreamingCgra::paper_default();
        let ii = crate::dfg::analysis::mii(&g, &cgra) + 1;
        let mut tables = ResourceTables::new(&cgra, ii);
        let mut t = vec![None; g.len()];
        let mut tt = 0usize;
        for ch in 0..8 {
            if let Some(r) = idx.read(ch) {
                let fan = g.fanout_muls(r);
                while tables.pe_free(tt) < fan.len() {
                    tt += 1;
                }
                t[r] = Some(tt);
                for m in fan {
                    t[m] = Some(tt);
                    tables.take_pe(tt, 1);
                }
            }
        }
        let mut t2 = t.clone();
        schedule_adds_fixed(&g, &mut t2, &mut tables).unwrap();
        // Occupancy per slot within budget.
        let mut occ = vec![0usize; ii];
        for v in g.nodes() {
            if g.kind(v).is_pe_op() {
                occ[t2[v].unwrap() % ii] += 1;
            }
        }
        assert!(occ.iter().all(|&o| o <= 16), "{occ:?}");
    }

    #[test]
    fn pair_cost_prefers_fresh_partners() {
        // Producer at 0 and partners at 0 vs 3, add at 4, II=4: the stale
        // partner (dist 4, same modulo) costs a forced GRF write; the fresh
        // partner (dist 1) costs none.
        let grf = vec![0usize; 4];
        let stale = pair_cost(0, 0, 4, 4, &grf, 1);
        let fresh = pair_cost(0, 3, 4, 4, &grf, 1);
        assert!(fresh < stale, "{fresh:?} vs {stale:?}");
    }
}
