//! Modulo scheduling of s-DFGs (paper §3.2 and §4.1).
//!
//! Two schedulers share this module's [`ScheduledSDfg`] representation and
//! verification logic:
//! * [`sparsemap`] — Algorithm 1 (AIBA + Mul-CI + RID-AT), the paper's
//!   contribution;
//! * [`baseline`] — lifetime-sensitive modulo scheduling (Llosa [23]) with
//!   fixed adder trees and demand-order bus allocation, the policy the
//!   BusMap [6] / Zhao [12] baselines use.

pub mod baseline;
pub mod output;
pub mod ridat;
pub mod sparsemap;

use crate::arch::StreamingCgra;
use crate::dfg::{EdgeKind, NodeId, NodeKind, SDfg};
use crate::error::{Error, Result};

/// A scheduled s-DFG: the (possibly rewritten — COPs, multicast replicas,
/// reconstructed adder trees) graph plus a scheduling time per node.
#[derive(Clone, Debug)]
pub struct ScheduledSDfg {
    pub g: SDfg,
    pub ii: usize,
    /// Scheduling time `t(v)` per node.
    pub t: Vec<usize>,
}

/// One multi-cycle internal dependency: `(producer, consumer, distance)`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Mcid {
    pub src: NodeId,
    pub dst: NodeId,
    pub dist: usize,
}

impl ScheduledSDfg {
    /// Modulo scheduling time `m(v) = t(v) % II`.
    #[inline]
    pub fn m(&self, v: NodeId) -> usize {
        self.t[v] % self.ii
    }

    /// The MCID set (§3.1 Table 1): internal deps with distance > 1.
    pub fn mcids(&self) -> Vec<Mcid> {
        self.g
            .edges()
            .iter()
            .filter(|e| e.kind == EdgeKind::Internal)
            .filter_map(|e| {
                let dist = self.t[e.dst] - self.t[e.src];
                (dist > 1).then_some(Mcid { src: e.src, dst: e.dst, dist })
            })
            .collect()
    }

    /// Number of caching operations inserted (the `|C|` column of Table 3).
    pub fn cops(&self) -> usize {
        self.g.cops().len()
    }

    /// COPs caching input readings (Fig. 4(b) kind).
    pub fn input_cops(&self) -> usize {
        self.g
            .cops()
            .iter()
            .filter(|&&v| matches!(self.g.kind(v), NodeKind::Cop { for_read: true }))
            .count()
    }

    /// COPs buffering results for output writings (§4.1 ③ kind).
    pub fn output_cops(&self) -> usize {
        self.cops() - self.input_cops()
    }

    /// Schedule makespan (cycles from first read to last write of one
    /// iteration) — the pipeline depth.
    pub fn makespan(&self) -> usize {
        self.t.iter().copied().max().map_or(0, |m| m + 1)
    }

    /// Per-modulo-slot occupancy tables, recomputed from the schedule.
    pub fn occupancy(&self) -> Occupancy {
        let mut occ = Occupancy {
            reads: vec![0; self.ii],
            writes: vec![0; self.ii],
            pe_ops: vec![0; self.ii],
        };
        for v in self.g.nodes() {
            let m = self.m(v);
            match self.g.kind(v) {
                NodeKind::Read { .. } => occ.reads[m] += 1,
                NodeKind::Write { .. } => occ.writes[m] += 1,
                k if k.is_pe_op() => occ.pe_ops[m] += 1,
                _ => {}
            }
        }
        occ
    }

    /// Check every constraint of §3.2 (1)–(2) against `cgra`. Returns a
    /// descriptive error naming the first violated constraint.
    pub fn verify(&self, cgra: &StreamingCgra) -> Result<()> {
        self.g.validate()?;
        if self.t.len() != self.g.len() {
            return Err(Error::Workload("schedule/graph size mismatch".into()));
        }
        // (1) dependency timing.
        for e in self.g.edges() {
            let (t1, t2) = (self.t[e.src] as i64, self.t[e.dst] as i64);
            let ok = match e.kind {
                EdgeKind::Input => t2 == t1,
                EdgeKind::Output => t2 == t1 + 1,
                EdgeKind::Internal => t2 - t1 >= 1,
            };
            if !ok {
                return Err(Error::Workload(format!(
                    "dependency timing violated: {:?} {}@{} -> {}@{}",
                    e.kind, e.src, t1, e.dst, t2
                )));
            }
        }
        // (2) modulo resources.
        let occ = self.occupancy();
        for i in 0..self.ii {
            if occ.reads[i] > cgra.m {
                return Err(Error::Workload(format!(
                    "input buses oversubscribed at slot {i}: {} > {}",
                    occ.reads[i], cgra.m
                )));
            }
            if occ.writes[i] > cgra.n {
                return Err(Error::Workload(format!(
                    "output buses oversubscribed at slot {i}: {} > {}",
                    occ.writes[i], cgra.n
                )));
            }
            if occ.pe_ops[i] > cgra.num_pes() {
                return Err(Error::Workload(format!(
                    "PEs oversubscribed at slot {i}: {} > {}",
                    occ.pe_ops[i],
                    cgra.num_pes()
                )));
            }
        }
        Ok(())
    }
}

/// Occupancy per modulo slot (reads include multicast replicas; pe_ops
/// include COPs — exactly the left-hand sides of constraint (2)).
#[derive(Clone, Debug)]
pub struct Occupancy {
    pub reads: Vec<usize>,
    pub writes: Vec<usize>,
    pub pe_ops: Vec<usize>,
}

/// Modulo resource tables used while scheduling (`T_PE`, `T_I`, `T_O` of
/// Algorithm 1).
#[derive(Clone, Debug)]
pub struct ResourceTables {
    pub ii: usize,
    pub pe: Vec<usize>,
    pub ibus: Vec<usize>,
    pub obus: Vec<usize>,
    max_pe: usize,
    max_ibus: usize,
    max_obus: usize,
}

impl ResourceTables {
    pub fn new(cgra: &StreamingCgra, ii: usize) -> Self {
        ResourceTables {
            ii,
            pe: vec![0; ii],
            ibus: vec![0; ii],
            obus: vec![0; ii],
            max_pe: cgra.num_pes(),
            max_ibus: cgra.m,
            max_obus: cgra.n,
        }
    }

    #[inline]
    pub fn pe_free(&self, t: usize) -> usize {
        self.max_pe - self.pe[t % self.ii]
    }

    #[inline]
    pub fn ibus_free(&self, t: usize) -> usize {
        self.max_ibus - self.ibus[t % self.ii]
    }

    #[inline]
    pub fn obus_free(&self, t: usize) -> usize {
        self.max_obus - self.obus[t % self.ii]
    }

    pub fn take_pe(&mut self, t: usize, k: usize) {
        let m = t % self.ii;
        debug_assert!(self.pe[m] + k <= self.max_pe);
        self.pe[m] += k;
    }

    pub fn take_ibus(&mut self, t: usize, k: usize) {
        let m = t % self.ii;
        debug_assert!(self.ibus[m] + k <= self.max_ibus);
        self.ibus[m] += k;
    }

    pub fn take_obus(&mut self, t: usize, k: usize) {
        let m = t % self.ii;
        debug_assert!(self.obus[m] + k <= self.max_obus);
        self.obus[m] += k;
    }
}

/// Helper: earliest `t'` in `lo..lo+span` with a free PE slot.
pub(crate) fn earliest_pe_slot(tables: &ResourceTables, lo: usize, span: usize) -> Option<usize> {
    (lo..lo + span).find(|&t| tables.pe_free(t) > 0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfg::build::build_sdfg;
    use crate::sparse::SparseBlock;

    fn tiny() -> (SDfg, Vec<usize>) {
        // 2 channels, 1 kernel: r0,r1 -> m0,m1 -> a -> w.
        let b = SparseBlock::from_mask("tiny", 2, 1, vec![true, true]).unwrap();
        let (g, _) = build_sdfg(&b);
        // nodes: r0, r1, m0, m1, a, w (construction order).
        let t = vec![0, 0, 0, 0, 1, 2];
        (g, t)
    }

    #[test]
    fn verify_accepts_legal_schedule() {
        let (g, t) = tiny();
        let s = ScheduledSDfg { g, ii: 1, t };
        s.verify(&StreamingCgra::paper_default()).unwrap();
        assert_eq!(s.mcids().len(), 0);
        assert_eq!(s.cops(), 0);
        assert_eq!(s.makespan(), 3);
    }

    #[test]
    fn verify_rejects_input_distance() {
        let (g, mut t) = tiny();
        t[2] = 1; // mul not co-scheduled with its read
        let s = ScheduledSDfg { g, ii: 1, t };
        assert!(s.verify(&StreamingCgra::paper_default()).is_err());
    }

    #[test]
    fn verify_rejects_output_distance() {
        let (g, mut t) = tiny();
        t[5] = 3; // write 2 cycles after the root add
        let s = ScheduledSDfg { g, ii: 1, t };
        assert!(s.verify(&StreamingCgra::paper_default()).is_err());
    }

    #[test]
    fn verify_rejects_bus_oversubscription() {
        // 5 reads at the same slot on a 4-bus machine (II = 1 forces all
        // reads into one modulo slot).
        let b = SparseBlock::from_mask("wide", 5, 1, vec![true; 5]).unwrap();
        let (g, _) = build_sdfg(&b);
        let mut t = vec![0; g.len()];
        let order = g.topo_order();
        for v in order {
            let lo = g
                .in_edges(v)
                .map(|(_, e)| match e.kind {
                    EdgeKind::Input => t[e.src],
                    _ => t[e.src] + 1,
                })
                .max();
            if let Some(lo) = lo {
                t[v] = lo;
            }
        }
        let s = ScheduledSDfg { g, ii: 1, t };
        let err = s.verify(&StreamingCgra::paper_default()).unwrap_err();
        assert!(err.to_string().contains("input buses"), "{err}");
    }

    #[test]
    fn mcid_detection() {
        let (g, mut t) = tiny();
        // Stretch the add 3 cycles after the muls, write follows it.
        t[4] = 3;
        t[5] = 4;
        let s = ScheduledSDfg { g, ii: 4, t };
        s.verify(&StreamingCgra::paper_default()).unwrap();
        let mcids = s.mcids();
        assert_eq!(mcids.len(), 2); // both mul->add edges now have dist 3
        assert!(mcids.iter().all(|m| m.dist == 3));
    }

    #[test]
    fn resource_tables() {
        let cgra = StreamingCgra::paper_default();
        let mut rt = ResourceTables::new(&cgra, 2);
        assert_eq!(rt.pe_free(0), 16);
        rt.take_pe(0, 10);
        rt.take_pe(2, 6); // slot 0 again
        assert_eq!(rt.pe_free(0), 0);
        assert_eq!(rt.pe_free(1), 16);
        assert_eq!(earliest_pe_slot(&rt, 0, 4), Some(1));
    }
}
