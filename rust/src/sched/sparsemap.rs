//! SparseMap scheduling — Algorithm 1 of the paper.
//!
//! Iteratively allocates input buses to input readings and co-schedules
//! their fan-out multiplications, applying:
//! * **AIBA** (§2.1): pick the unscheduled reading most associated with the
//!   readings already allocated in the current cycle, so multiplications of
//!   the same kernels land together and adder trees stay shallow;
//! * **Mul-CI** (§2.2): when a reading's fanout exceeds one bus's reach
//!   (`N` PEs of its column), multicast it over extra input buses via the
//!   crossbar instead of burning a PE on a caching op;
//! * **SchedwithCaching**: the fallback — insert a COP that holds the value
//!   in a PE so the remaining multiplications can run in later cycles;
//! * **RID-AT** (§2.3): reconstruct the adder trees against the realized
//!   mul schedule ([`crate::sched::ridat`]).
//!
//! The attempt runs at a fixed II; [`crate::mapper`] escalates II on
//! failure (Algorithm 1 lines 23/27 — `II ← II + 1; goto 2`).

use crate::arch::StreamingCgra;
use crate::config::Techniques;
use crate::dfg::analysis::AssociationMatrix;
use crate::dfg::{EdgeKind, NodeId, NodeKind, SDfg};
use crate::error::{Error, Result};
use crate::sched::{output, ridat, ResourceTables, ScheduledSDfg};

/// One scheduling attempt at a fixed `ii`. `g0` is the pristine s-DFG (the
/// attempt clones it; COPs / multicast replicas / tree rewiring happen on
/// the clone).
pub fn schedule_at(
    g0: &SDfg,
    cgra: &StreamingCgra,
    tech: Techniques,
    ii: usize,
) -> Result<ScheduledSDfg> {
    let am = AssociationMatrix::build(g0);
    schedule_at_perturbed(g0, cgra, tech, ii, 0, &am)
}

/// [`schedule_at`] with a perturbation index: retry `k` rotates the AIBA
/// cycle-opener among the top candidates, giving the incomplete-mapping
/// handler (mapper phase ④) distinct schedules to rebind at the same II.
///
/// `am` is the association matrix of the *pristine* `g0` — it depends only
/// on the block structure, so the mapper builds it once per block and
/// shares it across the whole `(II, retry)` attempt lattice instead of
/// recomputing it per attempt.
pub fn schedule_at_perturbed(
    g0: &SDfg,
    cgra: &StreamingCgra,
    tech: Techniques,
    ii: usize,
    perturb: u64,
    am: &AssociationMatrix,
) -> Result<ScheduledSDfg> {
    let mut g = g0.clone();
    let mut t: Vec<Option<usize>> = vec![None; g.len()];
    let mut tables = ResourceTables::new(cgra, ii);

    schedule_reads_and_muls(&mut g, cgra, tech, ii, am, &mut t, &mut tables, perturb)?;

    // Adder trees: RID-AT or fixed ASAP (line 30).
    let kernels: Vec<usize> = g
        .nodes()
        .filter_map(|v| match g.kind(v) {
            NodeKind::Write { kr } => Some(kr),
            _ => None,
        })
        .collect();
    if tech.rid_at {
        ridat::reconstruct_adder_trees(&mut g, &mut t, &mut tables, &kernels, cgra)?;
    } else {
        ridat::schedule_adds_fixed(&g, &mut t, &mut tables)?;
    }

    // Output writings (line 31).
    output::schedule_writes(&mut g, &mut t, &mut tables)?;

    finish(g, ii, t, cgra)
}

/// Lines 4–29 of Algorithm 1.
#[allow(clippy::too_many_arguments)]
fn schedule_reads_and_muls(
    g: &mut SDfg,
    cgra: &StreamingCgra,
    tech: Techniques,
    ii: usize,
    am: &AssociationMatrix,
    t: &mut Vec<Option<usize>>,
    tables: &mut ResourceTables,
    perturb: u64,
) -> Result<()> {
    let mut u_r: Vec<NodeId> = g.reads();
    let horizon = 2 * ii * (u_r.len() + 1) + 16;
    let mut t_cur = 0usize;
    let fail = |g: &SDfg, reason: String| Error::ScheduleFailed {
        block: g.name.clone(),
        reason,
        ii_cap: ii,
    };

    // I/O data management, spread variant (perturbation bit 2 set): a
    // fully-packed cycle leaves no column bus for the adder trees' internal
    // transfers (the same physical buses carry both — conflict rule R2(2)),
    // so when the modulo bus budget has slack, keep one bus per cycle free
    // for routing. The default variant packs maximally (Algorithm 1 line
    // 6); the mapper's phase-④ retries switch this bit on when binding
    // fails. Expected allocations = readings + Mul-CI replicas.
    let expected_allocs: usize = u_r
        .iter()
        .map(|&r| {
            if tech.mul_ci {
                g.fanout_muls(r).len().div_ceil(cgra.input_bus_fanout())
            } else {
                1
            }
        })
        .sum();
    let spread = perturb & 0b100 != 0;
    let per_cycle_cap = if spread && ii * cgra.m >= expected_allocs + ii {
        cgra.m - 1
    } else {
        cgra.m
    };

    while !u_r.is_empty() {
        if t_cur > horizon {
            return Err(fail(g, "bus allocation exceeded horizon".into()));
        }
        // Line 6: no input bus left this cycle — advance time.
        if cgra.m - tables.ibus_free(t_cur) >= per_cycle_cap || tables.ibus_free(t_cur) == 0 {
            t_cur += 1;
            continue;
        }
        // Line 10: AIBA (or channel order when disabled).
        let r = pick_read(g, am, &u_r, t, t_cur, tech.aiba, perturb);
        u_r.retain(|&x| x != r);
        t[r] = Some(t_cur);
        tables.take_ibus(t_cur, 1);

        let fanout = g.fanout_muls(r);
        let n_fan = fanout.len();
        let bus_reach = cgra.input_bus_fanout();

        if n_fan <= tables.pe_free(t_cur) {
            if n_fan <= bus_reach {
                // Line 12–15: direct co-scheduling.
                for &m in &fanout {
                    t[m] = Some(t_cur);
                }
                tables.take_pe(t_cur, n_fan);
                continue;
            }
            // Line 17: Mul-CI (replicas respect the per-cycle bus cap).
            let bus_budget = per_cycle_cap - (cgra.m - tables.ibus_free(t_cur));
            if tech.mul_ci && try_mul_ci(g, cgra, r, &fanout, t, tables, t_cur, bus_budget) {
                continue;
            }
            // Line 20: caching fallback.
            if try_sched_with_caching(g, cgra, r, &fanout, t, tables, t_cur, ii) {
                continue;
            }
            return Err(fail(g, format!("read {r}: fanout {n_fan} unschedulable")));
        }
        // Line 24: not enough modulo PEs this cycle — cache and defer.
        if try_sched_with_caching(g, cgra, r, &fanout, t, tables, t_cur, ii) {
            continue;
        }
        return Err(fail(g, format!("read {r}: no PEs for fanout {n_fan}")));
    }
    Ok(())
}

/// AIBA (§2.1): among unscheduled readings pick the one with the highest
/// association to the readings already allocated at `t_cur`; first pick of
/// a cycle prefers the largest fanout (giving Mul-CI the emptiest PEA),
/// breaking ties on total association, then node id.
///
/// With `aiba == false` (ablations / baseline): plain channel order.
fn pick_read(
    g: &SDfg,
    am: &AssociationMatrix,
    u_r: &[NodeId],
    t: &[Option<usize>],
    t_cur: usize,
    aiba: bool,
    perturb: u64,
) -> NodeId {
    debug_assert!(!u_r.is_empty());
    if !aiba {
        return *u_r.iter().min().unwrap();
    }
    // Readings already allocated in this cycle (multicast replicas excluded
    // — they carry the same channel and would double-count association).
    let at_t: Vec<NodeId> = g
        .reads()
        .into_iter()
        .filter(|&x| {
            t[x] == Some(t_cur) && matches!(g.kind(x), NodeKind::Read { replica: 0, .. })
        })
        .collect();
    // Greedy clustering: maximize association with the readings already in
    // this cycle (ties: fanout, then total association, then channel). The
    // cycle opener (empty `at_t`) takes the largest fanout so Mul-CI sees
    // the emptiest PEA (§2.2: Mul-CI "indirectly guarantees the
    // effectiveness of AIBA").
    if at_t.is_empty() {
        // Cycle opener. Perturbation `k` (mapper phase ④) rotates among the
        // top-ranked openers so rebinding sees genuinely different
        // schedules at the same II.
        let mut ranked: Vec<NodeId> = u_r.to_vec();
        ranked.sort_by_key(|&r| {
            (
                std::cmp::Reverse(g.fanout_muls(r).len()),
                std::cmp::Reverse(am.total(r)),
                r,
            )
        });
        return ranked[((perturb & 0b11) as usize) % ranked.len()];
    }
    *u_r
        .iter()
        .max_by_key(|&&r| {
            let fan = g.fanout_muls(r).len() as i64;
            let gain = am.sum_with(r, &at_t) as i64;
            (gain, fan, am.total(r) as i64, -(r as i64))
        })
        .unwrap()
}

/// Mul-CI (§2.2): allocate extra input buses (crossbar multicast replicas)
/// so all `fanout` multiplications can be fed directly at `t_cur`.
/// Returns false (without mutating) when buses or PEs are insufficient.
#[allow(clippy::too_many_arguments)]
fn try_mul_ci(
    g: &mut SDfg,
    cgra: &StreamingCgra,
    r: NodeId,
    fanout: &[NodeId],
    t: &mut Vec<Option<usize>>,
    tables: &mut ResourceTables,
    t_cur: usize,
    bus_budget: usize,
) -> bool {
    let reach = cgra.input_bus_fanout();
    let buses_needed = fanout.len().div_ceil(reach);
    let extra = buses_needed - 1;
    if extra == 0
        || tables.ibus_free(t_cur) < extra
        || bus_budget < extra
        || tables.pe_free(t_cur) < fanout.len()
    {
        return false;
    }
    let NodeKind::Read { ch, .. } = g.kind(r) else { unreachable!("r is a read") };
    // Partition the fanout into bus groups of `reach`: group 0 keeps its
    // input dependency on `r`; each later group moves onto a fresh replica
    // reading (Fig. 4(c)-(d)).
    for (gi, group) in fanout.chunks(reach).enumerate().skip(1) {
        let replica = g.add_node(NodeKind::Read { ch, replica: gi });
        t.push(Some(t_cur));
        tables.take_ibus(t_cur, 1);
        for &m in group {
            let in_edge = g
                .in_edges(m)
                .find(|(_, e)| e.kind == EdgeKind::Input)
                .map(|(i, _)| i)
                .expect("mul has an input edge");
            g.retarget_edge_src(in_edge, replica);
        }
    }
    for &m in fanout {
        t[m] = Some(t_cur);
    }
    tables.take_pe(t_cur, fanout.len());
    true
}

/// SchedwithCaching: a COP grabs the value off the bus at `t_cur` (using
/// one of the bus's `N` reachable PEs) and re-exposes it for up to
/// `II − 1` following cycles. Direct multiplications are limited to
/// `N − 1` (the COP occupies one fan-out PE); deferred ones read the cache
/// through internal dependencies (distance > 1 ⇒ MCID).
#[allow(clippy::too_many_arguments)]
fn try_sched_with_caching(
    g: &mut SDfg,
    cgra: &StreamingCgra,
    r: NodeId,
    fanout: &[NodeId],
    t: &mut Vec<Option<usize>>,
    tables: &mut ResourceTables,
    t_cur: usize,
    ii: usize,
) -> bool {
    if tables.pe_free(t_cur) == 0 {
        return false;
    }
    // Plan first (no mutation until the whole fanout fits).
    let reach = cgra.input_bus_fanout();
    let direct_cap = (reach - 1).min(tables.pe_free(t_cur) - 1);
    let n_direct = direct_cap.min(fanout.len());
    let deferred = &fanout[n_direct..];
    // The cached value lives in the COP's PE until the next iteration
    // overwrites it: consumers must sit within (t_cur, t_cur + II).
    let mut use_slots: Vec<usize> = Vec::with_capacity(deferred.len());
    {
        let mut virt = tables.clone();
        virt.take_pe(t_cur, 1 + n_direct);
        for _ in deferred {
            let Some(slot) = crate::sched::earliest_pe_slot(&virt, t_cur + 1, ii.max(2) - 1)
            else {
                return false;
            };
            virt.take_pe(slot, 1);
            use_slots.push(slot);
        }
    }
    // Commit.
    let cop = g.add_node(NodeKind::Cop { for_read: true });
    t.push(Some(t_cur));
    tables.take_pe(t_cur, 1);
    // The COP consumes the bus value like a mul does (distance-0 input dep).
    g.add_edge(r, cop, EdgeKind::Input);
    for &m in &fanout[..n_direct] {
        t[m] = Some(t_cur);
    }
    tables.take_pe(t_cur, n_direct);
    for (&m, &slot) in deferred.iter().zip(&use_slots) {
        let in_edge = g
            .in_edges(m)
            .find(|(_, e)| e.kind == EdgeKind::Input)
            .map(|(i, _)| i)
            .expect("mul input edge");
        g.retarget_edge_src(in_edge, cop);
        g.set_edge_kind(in_edge, EdgeKind::Internal);
        t[m] = Some(slot);
        tables.take_pe(slot, 1);
    }
    true
}

/// Seal an attempt: all nodes scheduled, constraints verified.
fn finish(
    g: SDfg,
    ii: usize,
    t: Vec<Option<usize>>,
    cgra: &StreamingCgra,
) -> Result<ScheduledSDfg> {
    let name = g.name.clone();
    let t: Vec<usize> = t
        .into_iter()
        .enumerate()
        .map(|(v, x)| {
            x.ok_or_else(|| Error::ScheduleFailed {
                block: name.clone(),
                reason: format!("node {v} left unscheduled"),
                ii_cap: ii,
            })
        })
        .collect::<Result<_>>()?;
    let s = ScheduledSDfg { g, ii, t };
    s.verify(cgra)?;
    Ok(s)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfg::analysis::mii;
    use crate::dfg::build::build_sdfg;
    use crate::sparse::gen::{paper_blocks, random_block};
    use crate::sparse::SparseBlock;

    fn cgra() -> StreamingCgra {
        StreamingCgra::paper_default()
    }

    #[test]
    fn schedules_all_paper_blocks_at_or_near_mii() {
        // Some perturbation of Algorithm 1 must schedule every paper block
        // at MII (blocks with saturated output buses may need a different
        // opener); none may need more than MII+1.
        for nb in paper_blocks() {
            let (g, _) = build_sdfg(&nb.block);
            let am = AssociationMatrix::build(&g);
            let base = mii(&g, &cgra());
            let best = (base..=base + 1)
                .find_map(|ii| {
                    (0..8).find_map(|p| {
                        schedule_at_perturbed(&g, &cgra(), Techniques::all(), ii, p, &am).ok()
                    })
                })
                .unwrap_or_else(|| panic!("{}: unschedulable near MII", nb.label));
            best.verify(&cgra()).unwrap();
            assert!(best.ii <= base + 1, "{}: II {} vs MII {base}", nb.label, best.ii);
        }
    }

    #[test]
    fn full_techniques_beat_ablations_on_cops() {
        // Mul-CI should eliminate nearly all input-side COPs (Table 4).
        let mut cops_aiba = 0usize;
        let mut cops_full = 0usize;
        for nb in paper_blocks() {
            let (g, _) = build_sdfg(&nb.block);
            let base_ii = mii(&g, &cgra());
            // Give each variant slack: take the first II that schedules.
            let first_ok = |tech: Techniques| -> Option<ScheduledSDfg> {
                (base_ii..base_ii + 3)
                    .find_map(|ii| schedule_at(&g, &cgra(), tech, ii).ok())
            };
            if let (Some(a), Some(f)) =
                (first_ok(Techniques::aiba_only()), first_ok(Techniques::all()))
            {
                cops_aiba += a.cops();
                cops_full += f.cops();
            }
        }
        assert!(
            cops_full < cops_aiba,
            "Mul-CI must reduce total COPs: full={cops_full} aiba-only={cops_aiba}"
        );
    }

    #[test]
    fn mulci_avoids_cop_fig4() {
        // Fig. 4: one input with 5 multiplications on a 4x4 PEA.
        let b = SparseBlock::from_mask("fig4", 1, 5, vec![true; 5]).unwrap();
        let (g, _) = build_sdfg(&b);
        // With Mul-CI: no input COP, all muls at the read's time, 2 buses
        // used. (5 single-mul kernels also need one *output* COP on a
        // 4-output-bus machine — unrelated to Mul-CI.)
        let s = schedule_at(&g, &cgra(), Techniques::all(), 2).unwrap();
        assert_eq!(s.input_cops(), 0, "Mul-CI avoids the caching op");
        let reads = s.g.reads();
        assert_eq!(reads.len(), 2, "one replica allocated");
        // All 5 muls co-scheduled with the reading.
        for v in s.g.nodes() {
            if matches!(s.g.kind(v), NodeKind::Mul { .. }) {
                assert_eq!(s.t[v], s.t[reads[0]]);
            }
        }
        // Without Mul-CI: an input COP appears.
        let s2 = schedule_at(&g, &cgra(), Techniques::aiba_only(), 2).unwrap();
        assert_eq!(s2.input_cops(), 1, "caching op required without Mul-CI");
    }

    #[test]
    fn caching_defers_muls_within_ii_window() {
        let b = SparseBlock::from_mask("c6", 1, 6, vec![true; 6]).unwrap();
        let (g, _) = build_sdfg(&b);
        let s = schedule_at(&g, &cgra(), Techniques::aiba_only(), 3).unwrap();
        assert_eq!(s.cops(), 1);
        // Deferred muls read the cache within the II window.
        for e in s.g.edges() {
            if e.kind == EdgeKind::Internal
                && matches!(s.g.kind(e.src), NodeKind::Cop { for_read: true })
            {
                let d = s.t[e.dst] - s.t[e.src];
                assert!(d >= 1 && d < s.ii, "cache lifetime violated: {d}");
            }
        }
    }

    #[test]
    fn deterministic() {
        let b = random_block("d", 8, 8, 0.4, 5);
        let (g, _) = build_sdfg(&b);
        let a = schedule_at(&g, &cgra(), Techniques::all(), 4).unwrap();
        let b2 = schedule_at(&g, &cgra(), Techniques::all(), 4).unwrap();
        assert_eq!(a.t, b2.t);
    }

    #[test]
    fn aiba_reduces_mcids_vs_no_aiba() {
        // Aggregate over paper + random blocks, full pipeline: AIBA must
        // reduce total MCIDs, COPs and II escalations vs channel order.
        let mut blocks: Vec<_> = paper_blocks().into_iter().map(|nb| nb.block).collect();
        for seed in 0..24 {
            blocks.push(random_block(&format!("a{seed}"), 8, 8, 0.45, seed));
        }
        let run = |aiba: bool| -> (usize, usize, usize) {
            let tech = Techniques { aiba, mul_ci: true, rid_at: true };
            let (mut mcids, mut cops, mut escal) = (0usize, 0usize, 0usize);
            for b in &blocks {
                let (g, _) = build_sdfg(b);
                let base = mii(&g, &cgra());
                for ii in base..base + 3 {
                    if let Ok(s) = schedule_at(&g, &cgra(), tech, ii) {
                        mcids += s.mcids().len();
                        cops += s.cops();
                        escal += ii - base;
                        break;
                    }
                }
            }
            (mcids, cops, escal)
        };
        let (m1, c1, e1) = run(true);
        let (m0, c0, e0) = run(false);
        assert!(m1 < m0, "AIBA must reduce MCIDs: {m1} vs {m0}");
        assert!(c1 <= c0, "AIBA must not increase COPs: {c1} vs {c0}");
        assert!(e1 <= e0, "AIBA must not increase II escalations: {e1} vs {e0}");
    }

    #[test]
    fn aiba_groups_associated_channels() {
        // Channels c0/c2 share 4 kernels; c1 is a loner. On a machine with
        // 2 input buses, channel order splits the associated pair across
        // cycles; AIBA keeps them together.
        #[rustfmt::skip]
        let mask = vec![
            // k0     k1     k2     k3
            true,  true,  true,  true,  // c0
            true,  false, false, false, // c1
            true,  true,  true,  true,  // c2
            false, true,  false, false, // c3
        ];
        let b = SparseBlock::from_mask("assoc", 4, 4, mask).unwrap();
        let (g, idx) = build_sdfg(&b);
        let narrow = StreamingCgra::new(4, 2, 8, 8); // 2 input buses
        let ii = mii(&g, &narrow);
        let s = schedule_at(&g, &narrow, Techniques::all(), ii).unwrap();
        let (r0, r2) = (idx.read(0).unwrap(), idx.read(2).unwrap());
        assert_eq!(
            s.t[r0], s.t[r2],
            "AIBA must co-schedule the highly associated pair"
        );
    }
}
