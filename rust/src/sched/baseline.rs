//! Baseline scheduler: the policy of BusMap [6] and Zhao et al. [12], both
//! of which adopt the lifetime-sensitive modulo scheduling heuristic of
//! Llosa et al. [23] and are *unaware of irregular input data demands*
//! (paper §5.2). Concretely:
//!
//! * input buses are allocated in **demand order** (channel index), not by
//!   association — co-scheduling of associated readings is accidental;
//! * there is **no Mul-CI**: a reading whose fanout exceeds one bus's reach
//!   always pays a caching operation;
//! * adder trees are **fixed** (balanced, channel order) and scheduled
//!   ASAP with lifetime-minimizing placement — RID-AT does not exist;
//! * output writings use the same §4.1 ③ policy (it is forced by the
//!   architecture, not a SparseMap contribution).
//!
//! The paper reports both baselines reach identical mapping results
//! (§5.2), which is why a single implementation stands in for [6] and
//! [12].

use crate::arch::StreamingCgra;
use crate::dfg::{EdgeKind, NodeId, NodeKind, SDfg};
use crate::error::{Error, Result};
use crate::sched::{output, ridat, ResourceTables, ScheduledSDfg};

/// One baseline scheduling attempt at fixed `ii`.
pub fn schedule_at(g0: &SDfg, cgra: &StreamingCgra, ii: usize) -> Result<ScheduledSDfg> {
    let mut g = g0.clone();
    let mut t: Vec<Option<usize>> = vec![None; g.len()];
    let mut tables = ResourceTables::new(cgra, ii);

    let reads: Vec<NodeId> = {
        let mut r = g.reads();
        r.sort_unstable(); // channel construction order == demand order
        r
    };

    // Demand-order, I/O-unaware packing: readings claim buses as early as
    // possible (the heuristic [23] optimizes op lifetimes, not input-bus /
    // multiplication co-scheduling). A reading whose fanout cannot be
    // issued in its allocation cycle pays a caching op; only when not even
    // a COP fits (fewer than 2 free PEs) does the reading slip a cycle.
    let horizon = 2 * ii * (reads.len() + 1) + 16;
    let mut t_cur = 0usize;
    for r in reads {
        let fanout_len = g.fanout_muls(r).len();
        let reach = cgra.input_bus_fanout();
        let mut placed = false;
        while t_cur <= horizon {
            let bus_free = tables.ibus_free(t_cur) > 0;
            let direct = fanout_len <= reach && tables.pe_free(t_cur) >= fanout_len;
            let cop = tables.pe_free(t_cur) >= 2;
            if bus_free && (direct || cop) {
                t[r] = Some(t_cur);
                tables.take_ibus(t_cur, 1);
                schedule_fanout(&mut g, cgra, r, &mut t, &mut tables, t_cur, ii)?;
                placed = true;
                break;
            }
            t_cur += 1;
        }
        if !placed {
            return Err(Error::ScheduleFailed {
                block: g.name.clone(),
                reason: format!("no feasible slot for read {r}"),
                ii_cap: ii,
            });
        }
    }

    ridat::schedule_adds_fixed(&g, &mut t, &mut tables)?;
    output::schedule_writes(&mut g, &mut t, &mut tables)?;

    let name = g.name.clone();
    let t: Vec<usize> = t
        .into_iter()
        .enumerate()
        .map(|(v, x)| {
            x.ok_or_else(|| Error::ScheduleFailed {
                block: name.clone(),
                reason: format!("node {v} unscheduled"),
                ii_cap: ii,
            })
        })
        .collect::<Result<_>>()?;
    let s = ScheduledSDfg { g, ii, t };
    s.verify(cgra)?;
    Ok(s)
}

/// Schedule the fanout of `r` at its allocation time; overflow beyond the
/// bus reach (or beyond the cycle's PE budget) goes through a caching op —
/// the baseline has no multicast.
fn schedule_fanout(
    g: &mut SDfg,
    cgra: &StreamingCgra,
    r: NodeId,
    t: &mut Vec<Option<usize>>,
    tables: &mut ResourceTables,
    t_cur: usize,
    ii: usize,
) -> Result<()> {
    let fanout = g.fanout_muls(r);
    let reach = cgra.input_bus_fanout();
    let free = tables.pe_free(t_cur);
    if fanout.len() <= reach && fanout.len() <= free {
        for &m in &fanout {
            t[m] = Some(t_cur);
        }
        tables.take_pe(t_cur, fanout.len());
        return Ok(());
    }
    // Caching op: direct muls limited to reach-1 (COP takes a fanout PE).
    if free == 0 {
        return Err(Error::ScheduleFailed {
            block: g.name.clone(),
            reason: format!("no PE for caching op of read {r}"),
            ii_cap: ii,
        });
    }
    let n_direct = (reach - 1).min(free - 1).min(fanout.len());
    let cop = g.add_node(NodeKind::Cop { for_read: true });
    t.push(Some(t_cur));
    g.add_edge(r, cop, EdgeKind::Input);
    tables.take_pe(t_cur, 1);
    for &m in &fanout[..n_direct] {
        t[m] = Some(t_cur);
    }
    tables.take_pe(t_cur, n_direct);
    for &m in &fanout[n_direct..] {
        // The cached value survives II−1 cycles in the COP's PE.
        let Some(slot) = crate::sched::earliest_pe_slot(tables, t_cur + 1, ii.max(2) - 1)
        else {
            return Err(Error::ScheduleFailed {
                block: g.name.clone(),
                reason: format!("no PE slot for deferred mul {m}"),
                ii_cap: ii,
            });
        };
        let in_edge = g
            .in_edges(m)
            .find(|(_, e)| e.kind == EdgeKind::Input)
            .map(|(i, _)| i)
            .expect("mul input edge");
        g.retarget_edge_src(in_edge, cop);
        g.set_edge_kind(in_edge, EdgeKind::Internal);
        t[m] = Some(slot);
        tables.take_pe(slot, 1);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfg::analysis::mii;
    use crate::dfg::build::build_sdfg;
    use crate::sched::sparsemap;
    use crate::config::Techniques;
    use crate::sparse::gen::paper_blocks;

    fn cgra() -> StreamingCgra {
        StreamingCgra::paper_default()
    }

    /// First II (from MII) at which the baseline scheduler succeeds.
    fn first_ok(g: &SDfg, cap: usize) -> Option<ScheduledSDfg> {
        let base = mii(g, &cgra());
        (base..=base + cap).find_map(|ii| schedule_at(g, &cgra(), ii).ok())
    }

    #[test]
    fn baseline_schedules_paper_blocks_with_slack() {
        for nb in paper_blocks() {
            let (g, _) = build_sdfg(&nb.block);
            let s = first_ok(&g, 3).unwrap_or_else(|| panic!("{} unschedulable", nb.label));
            s.verify(&cgra()).unwrap();
        }
    }

    #[test]
    fn baseline_pays_cop_per_high_fanout_read() {
        // Every channel with fanout > 4 must cost the baseline one COP
        // (plus any output-side COPs).
        for nb in paper_blocks() {
            let (g, _) = build_sdfg(&nb.block);
            if let Some(s) = first_ok(&g, 3) {
                assert!(
                    s.cops() >= nb.expect_n_fg4,
                    "{}: {} COPs < N_FG4 {}",
                    nb.label,
                    s.cops(),
                    nb.expect_n_fg4
                );
            }
        }
    }

    #[test]
    fn sparsemap_dominates_baseline_on_cops_and_mcids() {
        // The headline claim (Table 3): SparseMap's totals are far below
        // the baseline's. Aggregate over all paper blocks.
        let (mut b_cops, mut b_mcids) = (0usize, 0usize);
        let (mut s_cops, mut s_mcids) = (0usize, 0usize);
        for nb in paper_blocks() {
            let (g, _) = build_sdfg(&nb.block);
            let base_ii = mii(&g, &cgra());
            if let Some(s) = first_ok(&g, 3) {
                b_cops += s.cops();
                b_mcids += s.mcids().len();
            }
            let sm = (base_ii..base_ii + 3)
                .find_map(|ii| {
                    sparsemap::schedule_at(&g, &cgra(), Techniques::all(), ii).ok()
                })
                .expect("sparsemap schedules");
            s_cops += sm.cops();
            s_mcids += sm.mcids().len();
        }
        assert!(s_cops * 4 <= b_cops, "COPs: sparsemap {s_cops} vs baseline {b_cops}");
        assert!(s_mcids < b_mcids, "MCIDs: sparsemap {s_mcids} vs baseline {b_mcids}");
    }

    #[test]
    fn deterministic() {
        let nb = &paper_blocks()[4];
        let (g, _) = build_sdfg(&nb.block);
        let a = first_ok(&g, 3).unwrap();
        let b = first_ok(&g, 3).unwrap();
        assert_eq!(a.t, b.t);
        assert_eq!(a.ii, b.ii);
    }
}
