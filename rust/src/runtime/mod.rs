//! PJRT runtime: loads the AOT-compiled JAX/Pallas artifacts (HLO text
//! produced by `python/compile/aot.py`) and executes them on the request
//! path via the `xla` crate's CPU client.
//!
//! HLO *text* is the interchange format — jax ≥ 0.5 emits HloModuleProtos
//! with 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see `/opt/xla-example/README.md`).
//!
//! Python never runs here: `Runtime` is self-contained once
//! `make artifacts` has produced `artifacts/*.hlo.txt` + `manifest.tsv`.
//!
//! ## Feature gating
//!
//! The `xla` crate is not part of the offline build. The real executor is
//! compiled only with `--features pjrt-xla` (which implies `pjrt`, after
//! wiring the `xla` dependency into `rust/Cargo.toml`); both the default
//! build and `--features pjrt` alone ship an API-compatible stub whose
//! `Runtime::new` fails with a clear message, so manifest handling, the
//! CLI and the examples all still compile — `cargo test --features pjrt`
//! is a CI-checked configuration — and the mapping/simulation path (the
//! paper's contribution) is fully exercised without XLA.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::error::{Error, Result};

/// One artifact's manifest row.
#[derive(Clone, Debug, PartialEq)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub dtype: String,
    /// Input shapes in declaration order.
    pub in_shapes: Vec<Vec<usize>>,
    pub out_shape: Vec<usize>,
}

impl ArtifactSpec {
    fn parse_shape(s: &str) -> Result<Vec<usize>> {
        s.split('x')
            .map(|d| {
                d.parse::<usize>()
                    .map_err(|_| Error::Runtime(format!("bad shape component '{d}'")))
            })
            .collect()
    }

    pub fn in_len(&self, i: usize) -> usize {
        self.in_shapes[i].iter().product()
    }

    pub fn out_len(&self) -> usize {
        self.out_shape.iter().product()
    }
}

/// Parse `manifest.tsv` (written by aot.py).
pub fn load_manifest(dir: &Path) -> Result<Vec<ArtifactSpec>> {
    let path = dir.join("manifest.tsv");
    let text = std::fs::read_to_string(&path).map_err(|e| {
        Error::Runtime(format!(
            "cannot read {} (run `make artifacts` first): {e}",
            path.display()
        ))
    })?;
    let mut specs = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let cols: Vec<&str> = line.split('\t').collect();
        if cols.len() != 5 {
            return Err(Error::Runtime(format!(
                "manifest line {}: expected 5 columns, got {}",
                lineno + 1,
                cols.len()
            )));
        }
        let in_shapes = cols[3]
            .split(';')
            .map(ArtifactSpec::parse_shape)
            .collect::<Result<Vec<_>>>()?;
        specs.push(ArtifactSpec {
            name: cols[0].to_string(),
            file: cols[1].to_string(),
            dtype: cols[2].to_string(),
            in_shapes,
            out_shape: ArtifactSpec::parse_shape(cols[4])?,
        });
    }
    Ok(specs)
}

/// A compiled module ready to execute.
#[cfg(feature = "pjrt-xla")]
struct LoadedModule {
    exe: xla::PjRtLoadedExecutable,
    spec: ArtifactSpec,
}

/// The PJRT runtime: one CPU client + lazily compiled modules.
#[cfg(feature = "pjrt-xla")]
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    specs: HashMap<String, ArtifactSpec>,
    modules: HashMap<String, LoadedModule>,
}

#[cfg(feature = "pjrt-xla")]
impl Runtime {
    /// Open the artifacts directory and index the manifest (no compilation
    /// happens until a module is first executed).
    pub fn new(artifacts_dir: &str) -> Result<Self> {
        let dir = PathBuf::from(artifacts_dir);
        let specs = load_manifest(&dir)?
            .into_iter()
            .map(|s| (s.name.clone(), s))
            .collect();
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime { client, dir, specs, modules: HashMap::new() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn artifact_names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.specs.keys().cloned().collect();
        v.sort();
        v
    }

    pub fn spec(&self, name: &str) -> Option<&ArtifactSpec> {
        self.specs.get(name)
    }

    fn ensure_compiled(&mut self, name: &str) -> Result<&LoadedModule> {
        if !self.modules.contains_key(name) {
            let spec = self
                .specs
                .get(name)
                .ok_or_else(|| Error::Runtime(format!("unknown artifact '{name}'")))?
                .clone();
            let path = self.dir.join(&spec.file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| Error::Runtime("non-utf8 path".into()))?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)?;
            crate::log_debug!("compiled artifact '{name}' from {}", path.display());
            self.modules.insert(name.to_string(), LoadedModule { exe, spec });
        }
        Ok(&self.modules[name])
    }

    /// Execute an artifact with flat f32 input buffers (shapes from the
    /// manifest). Returns the flat f32 output.
    pub fn execute(&mut self, name: &str, inputs: &[&[f32]]) -> Result<Vec<f32>> {
        // Validate against the spec first (better errors than XLA's).
        let spec = self
            .specs
            .get(name)
            .ok_or_else(|| Error::Runtime(format!("unknown artifact '{name}'")))?
            .clone();
        if inputs.len() != spec.in_shapes.len() {
            return Err(Error::Runtime(format!(
                "'{name}' expects {} inputs, got {}",
                spec.in_shapes.len(),
                inputs.len()
            )));
        }
        for (i, (buf, shape)) in inputs.iter().zip(&spec.in_shapes).enumerate() {
            let want: usize = shape.iter().product();
            if buf.len() != want {
                return Err(Error::Runtime(format!(
                    "'{name}' input {i}: {} elements, want {want} ({shape:?})",
                    buf.len()
                )));
            }
        }
        let module = self.ensure_compiled(name)?;
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .zip(&module.spec.in_shapes)
            .map(|(buf, shape)| {
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(buf).reshape(&dims).map_err(Error::from)
            })
            .collect::<Result<_>>()?;
        let result = module.exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True → 1-tuple output.
        let out = result.to_tuple1()?;
        let values = out.to_vec::<f32>()?;
        if values.len() != spec.out_len() {
            return Err(Error::Runtime(format!(
                "'{name}' returned {} elements, want {}",
                values.len(),
                spec.out_len()
            )));
        }
        Ok(values)
    }
}

/// Stub runtime for builds without the real executor (default, and
/// `--features pjrt` without `pjrt-xla`): same API surface so the CLI /
/// examples / integration tests compile; `new` indexes the manifest
/// (surfacing the usual "run `make artifacts`" error when absent) and then
/// reports that the executor is unavailable.
#[cfg(not(feature = "pjrt-xla"))]
pub struct Runtime {
    _dir: PathBuf,
    specs: HashMap<String, ArtifactSpec>,
}

#[cfg(not(feature = "pjrt-xla"))]
impl Runtime {
    pub fn new(artifacts_dir: &str) -> Result<Self> {
        let dir = PathBuf::from(artifacts_dir);
        // Keep manifest diagnostics identical to the real runtime, then
        // fail: there is no executor to run the artifacts on.
        let _specs: HashMap<String, ArtifactSpec> = load_manifest(&dir)?
            .into_iter()
            .map(|s| (s.name.clone(), s))
            .collect();
        Err(Error::Runtime(
            "PJRT runtime unavailable: built without the real executor \
             (wire the `xla` crate into rust/Cargo.toml and rebuild with \
             --features pjrt-xla)"
                .into(),
        ))
    }

    pub fn platform(&self) -> String {
        "unavailable (stub)".to_string()
    }

    pub fn artifact_names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.specs.keys().cloned().collect();
        v.sort();
        v
    }

    pub fn spec(&self, name: &str) -> Option<&ArtifactSpec> {
        self.specs.get(name)
    }

    pub fn execute(&mut self, _name: &str, _inputs: &[&[f32]]) -> Result<Vec<f32>> {
        Err(Error::Runtime("PJRT runtime unavailable (stub)".into()))
    }
}

/// Locate the artifacts directory: `$SPARSEMAP_ARTIFACTS`, else
/// `artifacts/` relative to the crate root or cwd.
pub fn default_artifacts_dir() -> String {
    if let Ok(d) = std::env::var("SPARSEMAP_ARTIFACTS") {
        return d;
    }
    for cand in ["artifacts", concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts")] {
        if Path::new(cand).join("manifest.tsv").exists() {
            return cand.to_string();
        }
    }
    "artifacts".to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn have_artifacts() -> bool {
        Path::new(&default_artifacts_dir()).join("manifest.tsv").exists()
    }

    #[test]
    fn manifest_parses() {
        if !have_artifacts() {
            eprintln!("skipping: no artifacts (run `make artifacts`)");
            return;
        }
        let specs = load_manifest(Path::new(&default_artifacts_dir())).unwrap();
        assert!(specs.len() >= 5);
        let sb = specs.iter().find(|s| s.name == "sb_c8k8").expect("sb_c8k8");
        assert_eq!(sb.in_shapes, vec![vec![64, 8], vec![8, 8], vec![8, 8]]);
        assert_eq!(sb.out_shape, vec![64, 8]);
    }

    #[test]
    fn stub_or_real_runtime_reports_clearly() {
        if !have_artifacts() {
            // Without artifacts both variants fail on the manifest.
            let err = Runtime::new("no/such/dir").unwrap_err();
            assert!(err.to_string().contains("make artifacts"), "{err}");
            return;
        }
        if cfg!(not(feature = "pjrt-xla")) {
            let err = Runtime::new(&default_artifacts_dir()).unwrap_err();
            assert!(err.to_string().contains("pjrt"), "{err}");
        }
    }

    #[test]
    fn executes_sparse_block_artifact() {
        if !have_artifacts() || cfg!(not(feature = "pjrt-xla")) {
            eprintln!("skipping: needs artifacts + the pjrt feature");
            return;
        }
        let mut rt = Runtime::new(&default_artifacts_dir()).unwrap();
        let spec = rt.spec("sb_c4k6").unwrap().clone();
        let (t, c) = (spec.in_shapes[0][0], spec.in_shapes[0][1]);
        let k = spec.in_shapes[1][1];
        let mut rng = crate::util::rng::Pcg64::seeded(3);
        let x: Vec<f32> = (0..t * c).map(|_| rng.next_normal() as f32).collect();
        let w: Vec<f32> = (0..c * k).map(|_| rng.next_normal() as f32).collect();
        let mask: Vec<f32> = (0..c * k).map(|_| (rng.chance(0.6)) as u8 as f32).collect();
        let y = rt.execute("sb_c4k6", &[&x, &w, &mask]).unwrap();
        assert_eq!(y.len(), t * k);
        // Check vs a direct computation.
        for row in 0..t {
            for kk in 0..k {
                let want: f32 = (0..c)
                    .map(|cc| x[row * c + cc] * w[cc * k + kk] * mask[cc * k + kk])
                    .sum();
                let got = y[row * k + kk];
                assert!((got - want).abs() < 1e-4, "({row},{kk}): {got} vs {want}");
            }
        }
    }

    #[test]
    fn rejects_bad_inputs() {
        if !have_artifacts() || cfg!(not(feature = "pjrt-xla")) {
            eprintln!("skipping: needs artifacts + the pjrt feature");
            return;
        }
        let mut rt = Runtime::new(&default_artifacts_dir()).unwrap();
        assert!(rt.execute("nope", &[]).is_err());
        let bad = vec![0f32; 3];
        assert!(rt.execute("sb_c4k6", &[&bad, &bad, &bad]).is_err());
    }
}
