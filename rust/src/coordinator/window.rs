//! Tickets, batching windows, and the coordinator-level dispatch state
//! that forms windows **across sessions**.
//!
//! A [`Ticket`] is the caller's result handle; its worker-side
//! [`TicketCompleter`] fulfills it exactly once (or resolves it
//! `WorkerGone` on drop, so a wait can never hang on a lost request).
//!
//! Window formation is global: every member request — whichever session
//! enqueued it — lands in [`DispatchState::window_enqueue`] under the
//! coordinator's dispatch lock, so concurrent short-lived sessions share
//! one lockstep pass instead of each sealing an underfull window. The
//! queue-order rule keeps serving deterministic: window contents are a
//! pure function of the global enqueue/cancel sequence plus the knobs
//! (`batch_window_requests` / `batch_window_max` /
//! `dispatch_lookahead`) — never of timing or worker count.

use std::sync::{Arc, Condvar, Mutex, Weak};
use std::time::{Duration, Instant};

use crate::config::SparsemapConfig;
use crate::sparse::fuse::FusedBundle;
use crate::sparse::SparseBlock;

use super::queue::{resolve_queue_closed, Job, JobQueue, WindowJob};
use super::{InferResult, ServeError};

/// Fused request batching knobs (see `[coordinator] batch_window_requests`
/// / `batch_window_max`).
#[derive(Clone, Copy, Debug)]
pub struct BatchOptions {
    /// A window seals once it holds this many member requests (`0`/`1` =
    /// every member request is its own window).
    pub window_requests: usize,
    /// Cap on a window's lockstep iteration count (max over members of
    /// the summed request stream lengths): a request that would push the
    /// window to the cap seals it *first* and starts a fresh one, so
    /// requests already aboard never pay an oversized rider's padding.
    /// `0` = uncapped.
    pub window_max_iters: usize,
}

impl BatchOptions {
    pub fn from_config(cfg: &SparsemapConfig) -> Self {
        BatchOptions {
            window_requests: cfg.batch_window_requests,
            window_max_iters: cfg.batch_window_max,
        }
    }
}

// ---------------------------------------------------------------------------
// Tickets

/// Resolution state shared between a [`Ticket`] and its worker-side
/// completer.
enum TicketInner {
    Pending,
    Done(std::result::Result<InferResult, ServeError>),
    /// `wait` consumed the result (tombstone — unreachable through the
    /// public API afterwards, since `wait` takes the ticket by value).
    Taken,
}

pub(crate) struct TicketState {
    inner: Mutex<TicketInner>,
    ready: Condvar,
}

impl TicketState {
    pub(crate) fn new() -> Arc<Self> {
        Arc::new(TicketState { inner: Mutex::new(TicketInner::Pending), ready: Condvar::new() })
    }

    /// First completion wins; later calls (e.g. the completer's drop guard
    /// after an explicit fulfill) are no-ops.
    fn complete(&self, res: std::result::Result<InferResult, ServeError>) {
        let mut inner = self.inner.lock().expect("ticket state");
        if matches!(&*inner, TicketInner::Pending) {
            *inner = TicketInner::Done(res);
            self.ready.notify_all();
        }
    }

    /// Block until the ticket is resolved (without consuming the result).
    pub(crate) fn wait_done(&self) {
        let mut inner = self.inner.lock().expect("ticket state");
        while matches!(&*inner, TicketInner::Pending) {
            inner = self.ready.wait(inner).expect("ticket state");
        }
    }

    /// Block until resolved, then take the result.
    fn take(&self) -> std::result::Result<InferResult, ServeError> {
        let mut inner = self.inner.lock().expect("ticket state");
        while matches!(&*inner, TicketInner::Pending) {
            inner = self.ready.wait(inner).expect("ticket state");
        }
        match std::mem::replace(&mut *inner, TicketInner::Taken) {
            TicketInner::Done(res) => res,
            // `wait` consumes the ticket, so a taken state cannot be
            // observed again through the public API.
            _ => Err(ServeError::WorkerGone),
        }
    }

    /// Non-blocking peek (clones the result, leaving it claimable).
    pub(crate) fn peek(&self) -> Option<std::result::Result<InferResult, ServeError>> {
        let inner = self.inner.lock().expect("ticket state");
        match &*inner {
            TicketInner::Done(res) => Some(res.clone()),
            _ => None,
        }
    }

    /// Block until resolved or `deadline`, whichever comes first. `Some`
    /// clones the result (leaving it claimable, like `peek`); `None`
    /// means the request is still in flight at the deadline.
    fn wait_until(
        &self,
        deadline: Instant,
    ) -> Option<std::result::Result<InferResult, ServeError>> {
        let mut inner = self.inner.lock().expect("ticket state");
        loop {
            if let TicketInner::Done(res) = &*inner {
                return Some(res.clone());
            }
            let left = deadline.checked_duration_since(Instant::now())?;
            let (guard, _) = self.ready.wait_timeout(inner, left).expect("ticket state");
            inner = guard;
        }
    }
}

/// Worker-side handle to a pending ticket: fulfills it exactly once, and
/// resolves it to [`ServeError::WorkerGone`] if dropped unfulfilled
/// (worker panic, queue teardown with jobs still aboard) so a `wait` can
/// never hang on a request the pool lost.
pub(crate) struct TicketCompleter {
    pub(crate) state: Arc<TicketState>,
}

impl TicketCompleter {
    pub(crate) fn fulfill(self, res: std::result::Result<InferResult, ServeError>) {
        self.state.complete(res);
        // Drop runs next and no-ops: completion is first-wins.
    }
}

impl Drop for TicketCompleter {
    fn drop(&mut self) {
        self.state.complete(Err(ServeError::WorkerGone));
    }
}

/// Handle to one enqueued request. Results are retrieved by ticket, in any
/// order — waiting also seals the request's batching window (if it is
/// still open) so a ticket can never block on a window nobody else would
/// close.
pub struct Ticket {
    pub(crate) id: u64,
    /// Coordinator-global request uid: windows now span sessions, so the
    /// session-scoped `id` is not unique inside a window — cancellation
    /// keys on this instead.
    pub(crate) uid: u64,
    pub(crate) block_name: String,
    pub(crate) state: Arc<TicketState>,
    pub(crate) window: Option<WindowHandle>,
}

impl Ticket {
    /// The request's id (session-scoped enqueue sequence number).
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Name of the block the request targets.
    pub fn block_name(&self) -> &str {
        &self.block_name
    }

    /// Block until the request resolves and take the result. Seals the
    /// request's batching window first if it is still open.
    pub fn wait(mut self) -> std::result::Result<InferResult, ServeError> {
        self.flush_window();
        self.state.take()
    }

    /// Non-blocking poll: `None` while the request is in flight, a clone
    /// of the result once resolved (the result stays claimable by `wait`).
    /// Also seals the request's still-open batching window — the poll
    /// would otherwise never turn `Some`.
    pub fn try_wait(&mut self) -> Option<std::result::Result<InferResult, ServeError>> {
        self.flush_window();
        self.state.peek()
    }

    /// Bounded wait: block until the request resolves or `timeout`
    /// elapses. Seals the request's still-open batching window first (like
    /// `wait`). `Some` clones the result, leaving it claimable by a later
    /// `wait`/`try_wait`; `None` means the request is still in flight —
    /// the ticket stays live and can be waited again.
    pub fn wait_timeout(
        &mut self,
        timeout: Duration,
    ) -> Option<std::result::Result<InferResult, ServeError>> {
        self.flush_window();
        let deadline = Instant::now().checked_add(timeout)?;
        self.state.wait_until(deadline)
    }

    fn flush_window(&mut self) {
        if let Some(w) = self.window.take() {
            w.flush();
        }
    }
}

impl Drop for Ticket {
    /// Dropping an unwaited ticket cancels its request if that request is
    /// still riding an open batching window: the request is withdrawn
    /// before the window seals, so abandoned work is never simulated.
    /// (A sealed or dispatched request rides along; its result is simply
    /// discarded.) `wait`/`try_wait`/`wait_timeout` take the window handle
    /// first, so a waited ticket never cancels.
    fn drop(&mut self) {
        if let Some(w) = self.window.take() {
            w.cancel(self.uid);
        }
    }
}

// ---------------------------------------------------------------------------
// Batching windows

/// A not-yet-dispatched batching window for one registered bundle.
pub(crate) struct WindowCell {
    bundle: Arc<FusedBundle>,
    requests: Vec<WindowRequest>,
    sealed: bool,
}

pub(crate) struct WindowRequest {
    /// Session-scoped id (what `InferResult::id` reports).
    pub(crate) id: u64,
    /// Coordinator-global uid — the cancellation key (windows span
    /// sessions, so session ids collide inside a window).
    pub(crate) uid: u64,
    /// Member index inside the bundle (resolved at enqueue time).
    pub(crate) member: usize,
    pub(crate) block: Arc<SparseBlock>,
    pub(crate) xs: Vec<Vec<f32>>,
    pub(crate) done: TicketCompleter,
    /// Shed (as `DeadlineExceeded`) at worker pickup once passed.
    pub(crate) deadline: Option<Instant>,
    /// Enqueue timestamp, for queue-span latency attribution.
    pub(crate) enqueued_at: Instant,
}

/// Shared handle to an open window: the dispatch state, the enqueueing
/// session and every member ticket hold one, and whoever seals first
/// dispatches. The owning shard's queue is held weakly so stray tickets
/// can never keep a worker pool alive past the coordinator's drop.
#[derive(Clone)]
pub(crate) struct WindowHandle {
    pub(crate) cell: Arc<Mutex<WindowCell>>,
    tx: Weak<JobQueue>,
}

impl WindowHandle {
    /// Seal the window (if still open and non-empty) and dispatch it as
    /// one job; on a closed queue every member ticket resolves to
    /// [`ServeError::QueueClosed`] instead of hanging.
    pub(crate) fn flush(&self) {
        let job = {
            let mut cell = self.cell.lock().expect("window cell");
            if cell.sealed || cell.requests.is_empty() {
                return;
            }
            cell.sealed = true;
            WindowJob {
                bundle: Arc::clone(&cell.bundle),
                requests: std::mem::take(&mut cell.requests),
            }
        };
        match self.tx.upgrade() {
            Some(queue) => {
                if let Err(job) = queue.send(Job::Window(job)) {
                    resolve_queue_closed(job);
                }
            }
            None => resolve_queue_closed(Job::Window(job)),
        }
    }

    /// Withdraw request `uid` if the window has not sealed yet (the
    /// cancellation path of a dropped unwaited [`Ticket`]). A sealed
    /// window is immutable: the request rides along and its result is
    /// discarded. Window contents stay a pure function of the global
    /// enqueue/cancel sequence.
    pub(crate) fn cancel(&self, uid: u64) {
        let mut cell = self.cell.lock().expect("window cell");
        if !cell.sealed {
            // The withdrawn completer resolves its (otherwise
            // unobservable) ticket state on drop.
            cell.requests.retain(|r| r.uid != uid);
        }
    }

    /// Whether the window has been sealed (dispatched or draining).
    pub(crate) fn is_sealed(&self) -> bool {
        self.cell.lock().expect("window cell").sealed
    }

    /// Requests currently riding the window (`0` once sealed — a sealed
    /// window's requests are in flight, not waiting on look-ahead).
    fn rider_count(&self) -> usize {
        let cell = self.cell.lock().expect("window cell");
        if cell.sealed {
            0
        } else {
            cell.requests.len()
        }
    }
}

/// Lockstep iteration count of the window's current contents, optionally
/// with one more candidate request aboard.
fn lockstep_len(cell: &WindowCell, extra: Option<&WindowRequest>) -> usize {
    let mut totals = vec![0usize; cell.bundle.len()];
    for r in cell.requests.iter().chain(extra) {
        totals[r.member] += r.xs.len();
    }
    totals.into_iter().max().unwrap_or(0)
}

/// Whether admitting `request` would push the window's lockstep iteration
/// count to (or past) `batch_window_max` — checked *before* admission so
/// requests already aboard never pay the oversized rider's padding.
fn would_exceed_cap(cell: &WindowCell, request: &WindowRequest, batching: &BatchOptions) -> bool {
    batching.window_max_iters > 0
        && lockstep_len(cell, Some(request)) >= batching.window_max_iters
}

/// Whether the window should seal now that its contents are final for
/// this enqueue: the request-count knob, or (for a window whose sole
/// request alone reaches it — a cap breach no split can avoid) the
/// iteration cap.
fn window_full(cell: &WindowCell, batching: &BatchOptions) -> bool {
    if cell.requests.len() >= batching.window_requests.max(1) {
        return true;
    }
    batching.window_max_iters > 0 && lockstep_len(cell, None) >= batching.window_max_iters
}

// ---------------------------------------------------------------------------
// Global dispatch state

/// The coordinator-level window former. ONE of these exists per
/// coordinator, behind the dispatch lock: every member request from every
/// session funnels through [`DispatchState::window_enqueue`], so windows
/// fill from the *global* request stream (the millions-of-users shape —
/// many short sessions, few requests each — shares lockstep passes it
/// never could when each session formed its own windows).
pub(crate) struct DispatchState {
    /// Open windows keyed by bundle fingerprint, in creation order (small
    /// linear map — one entry per actively-trafficked bundle).
    open: Vec<(u64, WindowHandle)>,
}

impl DispatchState {
    pub(crate) fn new() -> Self {
        DispatchState { open: Vec::new() }
    }

    /// Append a member request to its bundle's open window (creating one
    /// if none is open), sealing and dispatching the window when it fills.
    /// A request that would push the window's lockstep iteration count
    /// past `batch_window_max` seals the window *first* and starts a fresh
    /// one — members already aboard never pay unbounded padding for a
    /// late oversized rider. With `lookahead > 0`, windows holding more
    /// than `lookahead` total riding requests are sealed oldest-first
    /// after the push (bounded look-ahead: the dispatch loop never holds
    /// an unbounded backlog open hunting for a fuller window).
    pub(crate) fn window_enqueue(
        &mut self,
        tx: &Arc<JobQueue>,
        batching: &BatchOptions,
        lookahead: usize,
        bundle: Arc<FusedBundle>,
        request: WindowRequest,
    ) -> WindowHandle {
        let fp = bundle.fingerprint();
        loop {
            let handle = match self.open.iter().find(|(k, _)| *k == fp) {
                Some((_, h)) => h.clone(),
                None => {
                    let h = WindowHandle {
                        cell: Arc::new(Mutex::new(WindowCell {
                            bundle: Arc::clone(&bundle),
                            requests: Vec::new(),
                            sealed: false,
                        })),
                        tx: Arc::downgrade(tx),
                    };
                    self.open.push((fp, h.clone()));
                    h
                }
            };
            let full = {
                let mut cell = handle.cell.lock().expect("window cell");
                if cell.sealed {
                    // A concurrent `Ticket::wait` (tickets are `Send` and
                    // may be waited from any thread) sealed and dispatched
                    // this window between our lookup and this lock: forget
                    // the stale handle and open a fresh window. The seal
                    // decision and the push share one critical section, so
                    // a request can never land in an already-dispatched
                    // cell.
                    drop(cell);
                    self.open.retain(|(k, _)| *k != fp);
                    continue;
                }
                if !cell.requests.is_empty() && would_exceed_cap(&cell, &request, batching) {
                    drop(cell);
                    handle.flush();
                    self.open.retain(|(k, _)| *k != fp);
                    continue;
                }
                cell.requests.push(request);
                window_full(&cell, batching)
            };
            if full {
                handle.flush();
            } else {
                self.enforce_lookahead(lookahead);
            }
            // `request` is moved only on this returning path; every
            // `continue` above runs before the move, so the loop re-enters
            // with the request still in hand.
            return handle;
        }
    }

    /// Bounded look-ahead: while more than `lookahead` requests ride open
    /// windows, seal the oldest open window. `0` = unbounded (the
    /// default — windows wait for their seal triggers). Deterministic:
    /// runs under the dispatch lock, purely off the open-window contents.
    fn enforce_lookahead(&mut self, lookahead: usize) {
        if lookahead == 0 {
            return;
        }
        loop {
            self.open.retain(|(_, h)| !h.is_sealed());
            let riding: usize = self.open.iter().map(|(_, h)| h.rider_count()).sum();
            if riding <= lookahead || self.open.is_empty() {
                return;
            }
            let (_, oldest) = self.open.remove(0);
            oldest.flush();
        }
    }

    /// Seal and dispatch every open window, in creation order (shutdown).
    pub(crate) fn drain_open(&mut self) -> Vec<WindowHandle> {
        self.open.drain(..).map(|(_, h)| h).collect()
    }
}
