//! Serving metrics: lock-free global counters, per-request latency
//! percentiles, and — since the serving tier became sharded — a per-shard
//! counter block so a hot or dying shard is visible in a snapshot without
//! grepping logs.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::util::stats::Summary;

/// Aggregate counters (lock-free reads).
#[derive(Default)]
pub struct Metrics {
    /// Requests processed by the worker pool (each window member counts).
    pub jobs: AtomicU64,
    pub failures: AtomicU64,
    pub cache_hits: AtomicU64,
    pub cache_misses: AtomicU64,
    /// CGRA cycles charged: per-request pass totals for solo serving, ONE
    /// pass total per batching window for fused serving.
    pub total_cycles: AtomicU64,
    pub total_latency_ns: AtomicU64,
    /// Batching windows simulated (one fused lockstep pass each).
    pub windows: AtomicU64,
    /// Lockstep passes the lane-vectorized plan backend served (batched
    /// windows plus solo requests, which run as one-member windows).
    pub lane_windows: AtomicU64,
    /// Requests shed by admission control (`try_enqueue` → `Overloaded`);
    /// they never entered the queue, so they do not count as `jobs`.
    pub shed: AtomicU64,
    /// Requests whose deadline passed before a worker picked them up
    /// (resolved `DeadlineExceeded`; not counted as `failures` — a shed is
    /// a policy outcome, not a serving fault).
    pub deadline_expired: AtomicU64,
    /// Worker restarts: per-job `catch_unwind` recoveries plus supervisor
    /// thread respawns.
    pub worker_restarts: AtomicU64,
    /// Requests resolved `Poisoned` (their job identity crossed the panic
    /// quarantine threshold); also counted in `failures`.
    pub poisoned: AtomicU64,
    /// Whole-network pipelines resolved through `enqueue_network`
    /// (successes only; a stage failure fails the network ticket without
    /// counting here).
    pub networks_served: AtomicU64,
    /// Layer stages assembled by the network pipeline driver (each stage
    /// fans out into per-block requests that count as `jobs` normally).
    pub network_stages: AtomicU64,
    /// Per-request latency attribution, sampled at successful resolution.
    latency: Mutex<LatencyStats>,
    /// Per-shard counter blocks, attached once at coordinator
    /// construction (in shard-index order). Empty for a bare `Metrics`
    /// (unit tests that exercise the cache directly).
    shards: Mutex<Vec<Arc<ShardMetrics>>>,
}

/// Queue/service span samples behind `Metrics` (percentiles need retained
/// samples, so these live under a mutex rather than atomics).
#[derive(Default)]
struct LatencyStats {
    queue: Summary,
    service: Summary,
}

/// Percentile of a possibly-empty summary (`0` before the first sample —
/// `Summary::percentile` itself panics on empty input).
fn pct(s: &Summary, q: f64) -> f64 {
    if s.count() == 0 {
        0.0
    } else {
        s.percentile(q)
    }
}

impl Metrics {
    /// Record one resolved request's queueing and service spans.
    pub(crate) fn observe_latency(&self, queue_ns: u64, service_ns: u64) {
        if let Ok(mut l) = self.latency.lock() {
            l.queue.add(queue_ns as f64);
            l.service.add(service_ns as f64);
        }
    }

    /// Wire the per-shard counter blocks in (coordinator construction
    /// only; shard index = vector index).
    pub(crate) fn attach_shards(&self, shards: Vec<Arc<ShardMetrics>>) {
        if let Ok(mut s) = self.shards.lock() {
            *s = shards;
        }
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let (queue_ns_p50, queue_ns_p99, service_ns_p50, service_ns_p99) =
            match self.latency.lock() {
                Ok(l) => (
                    pct(&l.queue, 50.0),
                    pct(&l.queue, 99.0),
                    pct(&l.service, 50.0),
                    pct(&l.service, 99.0),
                ),
                Err(_) => (0.0, 0.0, 0.0, 0.0),
            };
        let shards = match self.shards.lock() {
            Ok(s) => s.iter().map(|m| m.snapshot()).collect(),
            Err(_) => Vec::new(),
        };
        MetricsSnapshot {
            jobs: self.jobs.load(Ordering::Relaxed),
            failures: self.failures.load(Ordering::Relaxed),
            cache_hits: self.cache_hits.load(Ordering::Relaxed),
            cache_misses: self.cache_misses.load(Ordering::Relaxed),
            total_cycles: self.total_cycles.load(Ordering::Relaxed),
            total_latency_ns: self.total_latency_ns.load(Ordering::Relaxed),
            windows: self.windows.load(Ordering::Relaxed),
            lane_windows: self.lane_windows.load(Ordering::Relaxed),
            shed: self.shed.load(Ordering::Relaxed),
            deadline_expired: self.deadline_expired.load(Ordering::Relaxed),
            worker_restarts: self.worker_restarts.load(Ordering::Relaxed),
            poisoned: self.poisoned.load(Ordering::Relaxed),
            networks_served: self.networks_served.load(Ordering::Relaxed),
            network_stages: self.network_stages.load(Ordering::Relaxed),
            queue_ns_p50,
            queue_ns_p99,
            service_ns_p50,
            service_ns_p99,
            shards,
        }
    }
}

/// One shard's counter block. The global `Metrics` counters keep their
/// exact pre-sharding semantics (they sum over shards); these split the
/// same events by owning shard so imbalance and per-pool death are
/// observable.
#[derive(Default)]
pub(crate) struct ShardMetrics {
    pub(crate) windows: AtomicU64,
    pub(crate) shed: AtomicU64,
    pub(crate) worker_restarts: AtomicU64,
    pub(crate) poisoned: AtomicU64,
    /// Queue-span samples for requests served by this shard's pool.
    queue: Mutex<Summary>,
}

impl ShardMetrics {
    /// Record one served request's queueing span against this shard.
    pub(crate) fn observe_queue(&self, queue_ns: u64) {
        if let Ok(mut q) = self.queue.lock() {
            q.add(queue_ns as f64);
        }
    }

    fn snapshot(&self) -> ShardSnapshot {
        let (queue_ns_p50, queue_ns_p99) = match self.queue.lock() {
            Ok(q) => (pct(&q, 50.0), pct(&q, 99.0)),
            Err(_) => (0.0, 0.0),
        };
        ShardSnapshot {
            windows: self.windows.load(Ordering::Relaxed),
            shed: self.shed.load(Ordering::Relaxed),
            worker_restarts: self.worker_restarts.load(Ordering::Relaxed),
            poisoned: self.poisoned.load(Ordering::Relaxed),
            queue_ns_p50,
            queue_ns_p99,
        }
    }
}

/// Point-in-time view of one shard's counters (`MetricsSnapshot::shards`,
/// indexed by shard id).
#[derive(Clone, Copy, Debug, Default)]
pub struct ShardSnapshot {
    /// Batching windows this shard's pool simulated.
    pub windows: u64,
    /// Requests shed by admission control at this shard's queue.
    pub shed: u64,
    /// Worker restarts (in-place recoveries + supervisor respawns) in
    /// this shard's pool.
    pub worker_restarts: u64,
    /// Requests this shard resolved `Poisoned`.
    pub poisoned: u64,
    /// p50/p99 over queueing spans of requests served by this shard
    /// (ns); `0.0` with no samples.
    pub queue_ns_p50: f64,
    pub queue_ns_p99: f64,
}

/// Point-in-time view of the coordinator's counters. No longer `Copy`
/// since it carries the per-shard vector; it stays cheap to `clone`.
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    pub jobs: u64,
    pub failures: u64,
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub total_cycles: u64,
    pub total_latency_ns: u64,
    pub windows: u64,
    /// Lockstep passes served on the lane-vectorized plan path (batched
    /// windows plus solo one-member windows). On the compiled backend
    /// with `sim_lanes` ≥ 0 (auto) this should track traffic — a
    /// persistent `0` under multi-iteration load means serving silently
    /// fell back to the scalar sweep.
    pub lane_windows: u64,
    pub shed: u64,
    pub deadline_expired: u64,
    pub worker_restarts: u64,
    pub poisoned: u64,
    /// Whole-network pipelines resolved through `enqueue_network`.
    pub networks_served: u64,
    /// Layer stages the network pipeline driver assembled.
    pub network_stages: u64,
    /// p50/p99 over per-request queueing spans (ns); `0.0` with no samples.
    pub queue_ns_p50: f64,
    pub queue_ns_p99: f64,
    /// p50/p99 over per-request service spans (ns); `0.0` with no samples.
    pub service_ns_p50: f64,
    pub service_ns_p99: f64,
    /// Per-shard counter blocks, indexed by shard id (empty only for a
    /// bare `Metrics` that was never attached to a coordinator).
    pub shards: Vec<ShardSnapshot>,
}
