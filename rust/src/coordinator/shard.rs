//! The shard layer: partitioning registered blocks and bundles across
//! `[coordinator] shards` worker pools (fabric instances), plus the
//! warm-start manifest a restarted coordinator pre-builds its mapping
//! caches from.
//!
//! ## Deterministic capacity-constrained assignment
//!
//! Each registered unit (a solo block or a whole fused bundle) is pinned
//! to one shard by a greedy pass over estimated PE/bus demand: the unit
//! goes to the shard whose accumulated `(v_op, v_r, v_w)` load — folded
//! through [`StreamingCgra::mii`], the same capacity model the fusion
//! planner packs bundles with — stays lowest after admission, ties
//! breaking on the lowest shard index. Registration order alone decides
//! the placement (no timing, no hashing of worker state), so a given
//! registration sequence produces the same shard map on every run and
//! every worker count. Unregistered ad-hoc traffic falls back to
//! `fingerprint % shards` — also deterministic.
//!
//! ## Warm-start manifest
//!
//! With `[coordinator] warm_start_path` set, every registration rewrites
//! a small line-oriented manifest of the registered units' mask
//! structures. On startup the coordinator replays the manifest —
//! re-registering the units and pre-building their mappings through the
//! normal single-flight cache path — so a restarted shard serves its
//! first real request from a warm cache instead of paying a cold-start
//! mapping storm. Mappings (and compiled plans) depend only on mask
//! structure; weights arrive with each request, so a pre-built entry is
//! simulation-identical to one built on demand.

use std::collections::HashMap;
use std::sync::Arc;

use crate::arch::StreamingCgra;
use crate::sparse::fuse::FusedBundle;
use crate::sparse::SparseBlock;

use super::metrics::ShardMetrics;
use super::pool::MappingCache;

/// Environment override for `[coordinator] shards` — same
/// warn-and-keep-config semantics as `SPARSEMAP_SIM_BACKEND` (CI runs the
/// suite under `SPARSEMAP_SHARDS=2` without patching every test's
/// config). An unparsable or zero value is ignored with a warning.
pub const SHARDS_ENV: &str = "SPARSEMAP_SHARDS";

/// Resolve the effective shard count: [`SHARDS_ENV`] wins over the config
/// knob when set; an invalid value keeps the configured count (the
/// override is an operational escape hatch — it must never brick a
/// coordinator that has a valid config).
pub(crate) fn effective_shards(configured: usize) -> usize {
    let configured = configured.max(1);
    match std::env::var(SHARDS_ENV) {
        Ok(raw) => match raw.parse::<usize>() {
            Ok(n) if n >= 1 => n,
            _ => {
                crate::log_warn!("ignoring {SHARDS_ENV}='{raw}': expected an integer >= 1");
                configured
            }
        },
        Err(_) => configured,
    }
}

/// The coordinator-side handle to one shard: its mapping cache (for
/// warm-start pre-builds) and its counter block. The shard's queue,
/// workers, supervisor and poison registry live behind the pool layer.
pub(crate) struct Shard {
    pub(crate) cache: Arc<MappingCache>,
    pub(crate) metrics: Arc<ShardMetrics>,
}

/// Estimated fabric demand of one registered unit, in the fusion
/// planner's units: summed `(v_op, v_r, v_w)` over the blocks involved.
pub(crate) fn block_demand(block: &SparseBlock) -> (usize, usize, usize) {
    let f = block.features();
    (f.v_op, f.v_r, f.v_w)
}

pub(crate) fn bundle_demand(bundle: &FusedBundle) -> (usize, usize, usize) {
    bundle.blocks.iter().fold((0, 0, 0), |acc, b| {
        let f = b.features();
        (acc.0 + f.v_op, acc.1 + f.v_r, acc.2 + f.v_w)
    })
}

/// Deterministic greedy shard assigner (see the module docs). Lives under
/// the coordinator's registry lock.
pub(crate) struct ShardAssigner {
    /// Accumulated `(ops, reads, writes)` demand per shard.
    loads: Vec<(usize, usize, usize)>,
    /// Fingerprint → owning shard, for every registered unit.
    map: HashMap<u64, usize>,
}

impl ShardAssigner {
    pub(crate) fn new(shards: usize) -> Self {
        ShardAssigner { loads: vec![(0, 0, 0); shards.max(1)], map: HashMap::new() }
    }

    pub(crate) fn shard_count(&self) -> usize {
        self.loads.len()
    }

    /// Pin `fp` to the shard whose post-admission MII load stays lowest
    /// (ties → lowest index); idempotent for an already-assigned unit.
    pub(crate) fn assign(
        &mut self,
        fp: u64,
        demand: (usize, usize, usize),
        cgra: &StreamingCgra,
    ) -> usize {
        if let Some(&s) = self.map.get(&fp) {
            return s;
        }
        let mut best = 0usize;
        let mut best_cost = usize::MAX;
        for (s, &(o, r, w)) in self.loads.iter().enumerate() {
            let cost = cgra.mii(o + demand.0, r + demand.1, w + demand.2);
            if cost < best_cost {
                best = s;
                best_cost = cost;
            }
        }
        let l = &mut self.loads[best];
        l.0 += demand.0;
        l.1 += demand.1;
        l.2 += demand.2;
        self.map.insert(fp, best);
        best
    }

    /// Owning shard of a registered unit, `None` for ad-hoc traffic.
    pub(crate) fn shard_of(&self, fp: u64) -> Option<usize> {
        self.map.get(&fp).copied()
    }
}

// ---------------------------------------------------------------------------
// Warm-start manifest

/// One replayable registration from the manifest, in file order.
pub(crate) enum ManifestUnit {
    Block(Arc<SparseBlock>),
    Bundle(Arc<FusedBundle>),
    /// A whole-network registration. Its tiles and bundles ride their own
    /// `block`/`bundle` lines (they replay through the normal cache
    /// pre-build path), so the network unit only restores the registry
    /// entry — which needs the full graph, weights included, to rebuild
    /// the serving stages.
    Network(crate::model::NetworkGraph),
}

const MANIFEST_HEADER: &str = "# sparsemap warm-start manifest v1";

fn mask_string(block: &SparseBlock) -> String {
    block.mask.iter().map(|&m| if m { '1' } else { '0' }).collect()
}

fn block_line(kw: &str, block: &SparseBlock) -> String {
    // Name goes last so block names may contain spaces.
    format!("{kw} {} {} {} {}", block.c, block.k, mask_string(block), block.name)
}

/// Serialize one network layer: `nlayer <c> <k> <max_c> <max_k> <mask01>
/// <w0> … <w{c*k-1}> <name…>`. Weights are f32 bit patterns (same
/// convention as the model-dump format) so a manifest round trip restores
/// the graph bit-identically; the name goes last, as everywhere else.
fn network_layer_line(nl: &crate::model::NetworkLayer) -> String {
    let l = &nl.layer;
    let mut out = format!("nlayer {} {} {} {} ", l.c_total, l.k_total, nl.max_c, nl.max_k);
    out.extend(l.mask.iter().map(|&m| if m { '1' } else { '0' }));
    for w in &l.weights {
        out.push_str(&format!(" 0x{:08x}", w.to_bits()));
    }
    out.push(' ');
    out.push_str(&l.name);
    out
}

/// Serialize the registered units. The whole file is rewritten on every
/// registration (registrations are rare and the manifest is small — a
/// few lines per unit; networks add a line per layer).
pub(crate) fn write_manifest(
    path: &str,
    blocks: &[Arc<SparseBlock>],
    bundles: &[Arc<FusedBundle>],
    networks: &[Arc<crate::model::NetworkGraph>],
) -> std::io::Result<()> {
    let mut out = String::from(MANIFEST_HEADER);
    out.push('\n');
    for b in blocks {
        out.push_str(&block_line("block", b));
        out.push('\n');
    }
    for bundle in bundles {
        out.push_str(&format!("bundle {}\n", bundle.len()));
        for m in &bundle.blocks {
            out.push_str(&block_line("member", m));
            out.push('\n');
        }
    }
    for net in networks {
        out.push_str(&format!("network {} {}\n", net.layers.len(), net.name));
        for nl in &net.layers {
            out.push_str(&network_layer_line(nl));
            out.push('\n');
        }
    }
    std::fs::write(path, out)
}

/// Parse the payload of a `block` / `member` line: `<c> <k> <mask01>
/// <name…>` (name last, may contain spaces).
fn parse_block_payload(rest: &str) -> Option<Arc<SparseBlock>> {
    let mut parts = rest.splitn(4, ' ');
    let c: usize = parts.next()?.trim().parse().ok()?;
    let k: usize = parts.next()?.trim().parse().ok()?;
    let mask_s = parts.next()?.trim();
    let name = parts.next()?;
    if mask_s.len() != c.checked_mul(k)? || !mask_s.chars().all(|ch| ch == '0' || ch == '1') {
        return None;
    }
    let mask: Vec<bool> = mask_s.chars().map(|ch| ch == '1').collect();
    SparseBlock::from_mask(name, c, k, mask).ok().map(Arc::new)
}

/// Parse the payload of an `nlayer` line (see [`network_layer_line`]).
/// Returns the rebuilt layer with its tile caps.
fn parse_network_layer_payload(
    rest: &str,
) -> Option<(crate::sparse::partition::SparseLayer, usize, usize)> {
    let mut parts = rest.splitn(5, ' ');
    let c: usize = parts.next()?.trim().parse().ok()?;
    let k: usize = parts.next()?.trim().parse().ok()?;
    let max_c: usize = parts.next()?.trim().parse().ok()?;
    let max_k: usize = parts.next()?.trim().parse().ok()?;
    let mut rest = parts.next()?;
    let n = c.checked_mul(k)?;
    let (mask_s, after_mask) = rest.split_once(' ')?;
    if mask_s.len() != n || !mask_s.bytes().all(|b| b == b'0' || b == b'1') {
        return None;
    }
    rest = after_mask;
    // Exactly c*k weight tokens, then the name (which may contain spaces).
    let mut weights = Vec::with_capacity(n);
    for _ in 0..n {
        let (tok, after) = rest.split_once(' ')?;
        let bits = u32::from_str_radix(tok.trim().strip_prefix("0x")?, 16).ok()?;
        weights.push(f32::from_bits(bits));
        rest = after;
    }
    let mask: Vec<bool> = mask_s.bytes().map(|b| b == b'1').collect();
    crate::sparse::partition::SparseLayer::new(rest, c, k, weights, mask)
        .ok()
        .map(|l| (l, max_c, max_k))
}

/// Load and parse the manifest at `path`. Malformed lines are skipped
/// with a warning — a half-written or stale manifest degrades warm-start
/// coverage, it never fails startup.
pub(crate) fn load_manifest(path: &str) -> std::io::Result<Vec<ManifestUnit>> {
    let text = std::fs::read_to_string(path)?;
    let mut units = Vec::new();
    let mut lines = text.lines().peekable();
    while let Some(line) = lines.next() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        if let Some(rest) = line.strip_prefix("block ") {
            match parse_block_payload(rest) {
                Some(b) => units.push(ManifestUnit::Block(b)),
                None => crate::log_warn!("warm-start manifest: skipping malformed line '{line}'"),
            }
        } else if let Some(rest) = line.strip_prefix("bundle ") {
            let Ok(n) = rest.trim().parse::<usize>() else {
                crate::log_warn!("warm-start manifest: skipping malformed line '{line}'");
                continue;
            };
            let mut members = Vec::with_capacity(n);
            for _ in 0..n {
                let member = lines
                    .next()
                    .and_then(|l| l.trim().strip_prefix("member "))
                    .and_then(parse_block_payload);
                match member {
                    Some(m) => members.push(m),
                    None => break,
                }
            }
            if members.len() != n {
                crate::log_warn!(
                    "warm-start manifest: bundle with {} of {n} parsable members; skipping",
                    members.len()
                );
                continue;
            }
            match FusedBundle::new(members) {
                Ok(bundle) => units.push(ManifestUnit::Bundle(Arc::new(bundle))),
                Err(e) => crate::log_warn!("warm-start manifest: skipping bundle ({e})"),
            }
        } else if let Some(rest) = line.strip_prefix("network ") {
            let Some((n_s, name)) = rest.split_once(' ') else {
                crate::log_warn!("warm-start manifest: skipping malformed line '{line}'");
                continue;
            };
            let Ok(n) = n_s.trim().parse::<usize>() else {
                crate::log_warn!("warm-start manifest: skipping malformed line '{line}'");
                continue;
            };
            let mut graph = crate::model::NetworkGraph::new(name);
            for _ in 0..n {
                let layer = lines
                    .next()
                    .and_then(|l| l.trim().strip_prefix("nlayer "))
                    .and_then(parse_network_layer_payload);
                match layer {
                    Some((layer, max_c, max_k)) => {
                        if let Err(e) = graph.push_layer(layer, max_c, max_k) {
                            crate::log_warn!("warm-start manifest: network '{name}': {e}");
                            break;
                        }
                    }
                    None => break,
                }
            }
            if n > 0 && graph.layers.len() == n {
                units.push(ManifestUnit::Network(graph));
            } else {
                crate::log_warn!(
                    "warm-start manifest: network '{name}' with {} of {n} parsable layers; \
                     skipping",
                    graph.layers.len()
                );
            }
        } else {
            crate::log_warn!("warm-start manifest: skipping unrecognized line '{line}'");
        }
    }
    Ok(units)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny(name: &str, c: usize, k: usize, mask: Vec<bool>) -> Arc<SparseBlock> {
        Arc::new(SparseBlock::from_mask(name, c, k, mask).unwrap())
    }

    #[test]
    fn assigner_is_deterministic_and_spreads_load() {
        let cgra = StreamingCgra::paper_default();
        let blocks: Vec<Arc<SparseBlock>> = (0..6)
            .map(|i| {
                tiny(
                    &format!("b{i}"),
                    2,
                    2,
                    vec![true, i % 2 == 0, true, i % 3 == 0],
                )
            })
            .collect();
        let run = || -> Vec<usize> {
            let mut a = ShardAssigner::new(3);
            blocks
                .iter()
                .map(|b| a.assign(b.mask_fingerprint(), block_demand(b), &cgra))
                .collect()
        };
        let first = run();
        assert_eq!(first, run(), "same registration order → same placement");
        // Equal-demand units round-robin across empty shards (lowest
        // index wins ties, then the loaded shard costs more).
        assert!(first.iter().any(|&s| s != first[0]), "load spreads past shard 0");
        // Idempotent: re-assigning a registered fingerprint keeps its shard.
        let mut a = ShardAssigner::new(3);
        let fp = blocks[0].mask_fingerprint();
        let s0 = a.assign(fp, block_demand(&blocks[0]), &cgra);
        assert_eq!(a.assign(fp, block_demand(&blocks[0]), &cgra), s0);
        assert_eq!(a.shard_of(fp), Some(s0));
        assert_eq!(a.shard_of(0xdead_beef), None);
    }

    #[test]
    fn manifest_round_trips_blocks_and_bundles() {
        let solo = tiny("solo block", 2, 3, vec![true, false, true, false, true, true]);
        let m1 = tiny("f1", 2, 2, vec![true, false, true, true]);
        let m2 = tiny("f2", 3, 2, vec![true, true, false, true, true, false]);
        let bundle = Arc::new(FusedBundle::new(vec![m1, m2]).unwrap());
        let path = std::env::temp_dir()
            .join(format!("sparsemap-manifest-roundtrip-{}.txt", std::process::id()));
        let path_s = path.to_str().unwrap().to_string();
        write_manifest(&path_s, &[Arc::clone(&solo)], &[Arc::clone(&bundle)], &[]).unwrap();
        let units = load_manifest(&path_s).unwrap();
        let _ = std::fs::remove_file(&path);
        assert_eq!(units.len(), 2);
        match &units[0] {
            ManifestUnit::Block(b) => {
                assert_eq!(b.name, "solo block", "names with spaces survive");
                assert_eq!((b.c, b.k), (2, 3));
                assert_eq!(b.mask_fingerprint(), solo.mask_fingerprint());
            }
            _ => panic!("first unit must be the solo block"),
        }
        match &units[1] {
            ManifestUnit::Bundle(b) => {
                assert_eq!(b.len(), 2);
                assert_eq!(b.fingerprint(), bundle.fingerprint(), "bundle identity survives");
            }
            _ => panic!("second unit must be the bundle"),
        }
    }

    #[test]
    fn manifest_skips_garbage_without_failing() {
        let path = std::env::temp_dir()
            .join(format!("sparsemap-manifest-garbage-{}.txt", std::process::id()));
        let path_s = path.to_str().unwrap().to_string();
        std::fs::write(
            &path,
            "# sparsemap warm-start manifest v1\n\
             block 2 2 10 half-a-mask\n\
             nonsense line\n\
             block 2 2 1011 good\n\
             bundle 2\n\
             member 2 2 1011 only-one\n",
        )
        .unwrap();
        let units = load_manifest(&path_s).unwrap();
        let _ = std::fs::remove_file(&path);
        // Only the well-formed block survives; the truncated bundle and
        // the short-mask block are skipped.
        assert_eq!(units.len(), 1);
        match &units[0] {
            ManifestUnit::Block(b) => assert_eq!(b.name, "good"),
            _ => panic!("expected the one good block"),
        }
    }

    #[test]
    fn manifest_round_trips_networks_bit_identically() {
        use crate::sparse::prune::synthetic_pruned_layer;
        let mut graph = crate::model::NetworkGraph::new("tiny net");
        graph
            .push_layer(synthetic_pruned_layer("conv a", 4, 6, 0.4, 71).unwrap(), 8, 8)
            .unwrap();
        graph
            .push_layer(synthetic_pruned_layer("conv b", 6, 5, 0.5, 72).unwrap(), 8, 8)
            .unwrap();
        let graph = Arc::new(graph);
        let path = std::env::temp_dir()
            .join(format!("sparsemap-manifest-network-{}.txt", std::process::id()));
        let path_s = path.to_str().unwrap().to_string();
        write_manifest(&path_s, &[], &[], &[Arc::clone(&graph)]).unwrap();
        let units = load_manifest(&path_s).unwrap();
        let _ = std::fs::remove_file(&path);
        assert_eq!(units.len(), 1);
        match &units[0] {
            ManifestUnit::Network(got) => {
                assert_eq!(got.name, "tiny net", "network names with spaces survive");
                assert_eq!(got.layers.len(), 2);
                for (g, w) in got.layers.iter().zip(&graph.layers) {
                    assert_eq!(g.layer.name, w.layer.name, "layer names with spaces survive");
                    assert_eq!((g.max_c, g.max_k), (w.max_c, w.max_k));
                    assert_eq!(g.layer.mask, w.layer.mask);
                    let gb: Vec<u32> = g.layer.weights.iter().map(|x| x.to_bits()).collect();
                    let wb: Vec<u32> = w.layer.weights.iter().map(|x| x.to_bits()).collect();
                    assert_eq!(gb, wb, "weights round-trip bit-identically");
                    assert_eq!(g.blocks.len(), w.blocks.len(), "re-partition matches");
                }
            }
            _ => panic!("expected the network unit"),
        }
    }

    #[test]
    fn manifest_skips_malformed_networks() {
        let path = std::env::temp_dir()
            .join(format!("sparsemap-manifest-badnet-{}.txt", std::process::id()));
        let path_s = path.to_str().unwrap().to_string();
        // Three broken networks (truncated, bad weight count, zero
        // layers) around one good block.
        std::fs::write(
            &path,
            "# sparsemap warm-start manifest v1\n\
             network 2 truncated\n\
             nlayer 1 2 8 8 11 0x3f800000 0x40000000 only layer\n\
             # filler: absorbed by the truncated network's layer scan\n\
             network 1 shortweights\n\
             nlayer 1 2 8 8 11 0x3f800000 lone\n\
             network 0 empty\n\
             block 2 2 1011 good\n",
        )
        .unwrap();
        let units = load_manifest(&path_s).unwrap();
        let _ = std::fs::remove_file(&path);
        assert_eq!(units.len(), 1);
        match &units[0] {
            ManifestUnit::Block(b) => assert_eq!(b.name, "good"),
            _ => panic!("expected only the good block to survive"),
        }
    }
}
