//! Whole-network pipeline serving: a registered [`NetworkGraph`] served
//! layer by layer through the normal request path.
//!
//! [`ServingNetwork`] is the registration-time form of a network: every
//! layer becomes a [`Stage`] whose partitioned blocks carry their
//! precomputed live-channel gather lists and kernel offsets. Serving
//! ([`crate::coordinator::ServeSession::enqueue_network`]) streams each
//! stage's assembled outputs into the next stage's member requests:
//!
//! 1. gather — each stage block reads its live channels out of the
//!    current activation vector (layer input for stage 0, the previous
//!    stage's assembled outputs after);
//! 2. serve — the blocks are enqueued as ordinary session requests, so
//!    mapping-cache reuse, fusion routing and batching windows all apply
//!    within a stage exactly as for ad-hoc traffic;
//! 3. scatter — block outputs accumulate into the stage's `k_total`-wide
//!    activation vector at each block's kernel offset, in partition
//!    order (deterministic — the assembled vector is a pure function of
//!    the stage input, so repeated runs are bit-identical).
//!
//! The resolved [`NetworkResult`] carries per-layer cycle/COP/MCID
//! attribution ([`LayerMetrics`]) on top of the per-ticket latency fields
//! every [`crate::coordinator::InferResult`] already has.

use std::sync::Arc;
use std::time::Instant;

use crate::coordinator::{Coordinator, ServeError, Ticket};
use crate::model::NetworkGraph;
use crate::sparse::partition::SparseLayer;
use crate::sparse::SparseBlock;

/// One layer of a registered network, in serving form.
#[derive(Debug)]
pub struct Stage {
    pub layer_name: String,
    pub c_total: usize,
    pub k_total: usize,
    pub blocks: Vec<StageBlock>,
}

/// One partitioned block of a stage, with its gather/scatter placement.
#[derive(Debug)]
pub struct StageBlock {
    pub block: Arc<SparseBlock>,
    /// Layer channels this block reads (gather list, ascending).
    pub live: Vec<usize>,
    /// First layer kernel this block's outputs accumulate into.
    pub kr_offset: usize,
}

/// A registered network: the graph it was built from (what the warm-start
/// manifest persists) plus its per-stage serving form.
#[derive(Debug)]
pub struct ServingNetwork {
    pub name: String,
    pub graph: Arc<NetworkGraph>,
    pub stages: Vec<Stage>,
}

impl ServingNetwork {
    pub(crate) fn build(graph: &Arc<NetworkGraph>) -> Self {
        let stages = graph
            .layers
            .iter()
            .map(|nl| Stage {
                layer_name: nl.layer.name.clone(),
                c_total: nl.layer.c_total,
                k_total: nl.layer.k_total,
                blocks: nl
                    .blocks
                    .iter()
                    .map(|lb| StageBlock {
                        block: Arc::new(lb.block.clone()),
                        live: SparseLayer::live_channels(&lb.block.name),
                        kr_offset: lb.kr_offset,
                    })
                    .collect(),
            })
            .collect();
        ServingNetwork { name: graph.name.clone(), graph: Arc::clone(graph), stages }
    }

    /// Channels the first stage consumes.
    pub fn input_width(&self) -> usize {
        self.stages.first().map_or(0, |s| s.c_total)
    }

    /// Kernels the last stage produces.
    pub fn output_width(&self) -> usize {
        self.stages.last().map_or(0, |s| s.k_total)
    }

    /// Total partitioned blocks across stages.
    pub fn block_count(&self) -> usize {
        self.stages.iter().map(|s| s.blocks.len()).sum()
    }

    /// Every stage block, in stage/partition order (the registration and
    /// fusion-planning population).
    pub(crate) fn all_blocks(&self) -> Vec<Arc<SparseBlock>> {
        self.stages
            .iter()
            .flat_map(|s| s.blocks.iter().map(|sb| Arc::clone(&sb.block)))
            .collect()
    }
}

/// Per-layer serving attribution inside a [`NetworkResult`].
#[derive(Clone, Debug)]
pub struct LayerMetrics {
    pub layer: String,
    /// Partitioned blocks this layer served through.
    pub blocks: usize,
    /// CGRA cycles charged across the layer's block requests (each a
    /// proportional share of its serving pass).
    pub cycles: u64,
    /// Caching operations summed over the mappings that served the
    /// layer's blocks.
    pub cops: usize,
    /// Multi-cycle internal dependencies summed over the serving mappings.
    pub mcids: usize,
    /// Slowest block request of the layer, enqueue → resolution (the
    /// stage assembles when its last block resolves).
    pub latency_ns: u64,
    /// Block requests served inside a multi-member fused configuration.
    pub fused_requests: usize,
}

/// The resolved answer of a whole-network pipeline request.
#[derive(Clone, Debug)]
pub struct NetworkResult {
    pub network: String,
    /// The final stage's assembled activation vector.
    pub outputs: Vec<f32>,
    /// Per-layer attribution, in stage order.
    pub layers: Vec<LayerMetrics>,
    /// Total CGRA cycles charged across all stages.
    pub cycles: u64,
    /// Wall nanoseconds from `enqueue_network` to resolution.
    pub latency_ns: u64,
}

/// Result handle for one in-flight network request. Stage 0 is enqueued
/// at creation; [`NetworkTicket::wait`] assembles each stage and streams
/// it into the next. Dropping an unwaited ticket abandons the remaining
/// stages (the already-enqueued block requests still resolve — enqueued
/// tickets always do — and cancel out of still-open windows).
pub struct NetworkTicket<'a> {
    coord: &'a Coordinator,
    net: Arc<ServingNetwork>,
    started: Instant,
    /// Index of the stage `pending` belongs to.
    stage: usize,
    /// In-flight block tickets of the current stage, in partition order.
    pending: Vec<Ticket>,
    layers: Vec<LayerMetrics>,
}

impl<'a> NetworkTicket<'a> {
    pub(crate) fn start(coord: &'a Coordinator, net: Arc<ServingNetwork>, x: &[f32]) -> Self {
        let started = Instant::now();
        let pending = enqueue_stage(coord, &net.stages[0], x);
        NetworkTicket { coord, net, started, stage: 0, pending, layers: Vec::new() }
    }

    /// The network this ticket runs.
    pub fn network(&self) -> &str {
        &self.net.name
    }

    /// Drive the remaining stages to completion and return the assembled
    /// result. Any failed block request fails the whole network with that
    /// request's [`ServeError`] (later stages are never enqueued).
    pub fn wait(mut self) -> std::result::Result<NetworkResult, ServeError> {
        loop {
            let stage = &self.net.stages[self.stage];
            self.coord.metrics.network_stages.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            let mut acc = vec![0f32; stage.k_total];
            let mut lm = LayerMetrics {
                layer: stage.layer_name.clone(),
                blocks: stage.blocks.len(),
                cycles: 0,
                cops: 0,
                mcids: 0,
                latency_ns: 0,
                fused_requests: 0,
            };
            let pending = std::mem::take(&mut self.pending);
            for (sb, ticket) in stage.blocks.iter().zip(pending) {
                let res = ticket.wait()?;
                // Each stage request carries exactly one iteration, so the
                // block's answer is its first (only) output vector.
                let y = res.outputs.first().map_or(&[][..], |v| v.as_slice());
                for (bk, &v) in y.iter().enumerate() {
                    acc[sb.kr_offset + bk] += v;
                }
                lm.cycles += res.cycles;
                lm.cops += res.cops;
                lm.mcids += res.mcids;
                lm.latency_ns = lm.latency_ns.max(res.latency_ns);
                if res.fused_members > 1 {
                    lm.fused_requests += 1;
                }
            }
            self.layers.push(lm);
            self.stage += 1;
            if self.stage == self.net.stages.len() {
                self.coord
                    .metrics
                    .networks_served
                    .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                return Ok(NetworkResult {
                    network: self.net.name.clone(),
                    cycles: self.layers.iter().map(|l| l.cycles).sum(),
                    latency_ns: self.started.elapsed().as_nanos() as u64,
                    layers: std::mem::take(&mut self.layers),
                    outputs: acc,
                });
            }
            self.pending = enqueue_stage(self.coord, &self.net.stages[self.stage], &acc);
        }
    }
}

/// Fan one stage out into per-block session requests: gather each block's
/// live channels from the stage input and enqueue a one-iteration request.
/// The throwaway session seals any batching windows the requests joined
/// when it drops, so a stage never deadlocks waiting on its own unsealed
/// window; windows still form normally (globally) within the stage and
/// with concurrent traffic.
fn enqueue_stage(coord: &Coordinator, stage: &Stage, input: &[f32]) -> Vec<Ticket> {
    debug_assert_eq!(input.len(), stage.c_total);
    let mut session = coord.session();
    stage
        .blocks
        .iter()
        .map(|sb| {
            let xs = vec![sb.live.iter().map(|&ch| input[ch]).collect::<Vec<f32>>()];
            session.enqueue(Arc::clone(&sb.block), xs)
        })
        .collect()
}
