//! The worker-pool layer, instantiated once **per shard**: the
//! single-flight LRU mapping cache, the poison registry, worker threads
//! with per-job `catch_unwind` + in-place retry, and the supervisor that
//! respawns hard-dead workers under the shard's restart budget (then
//! drains the shard's queue resolving every stranded ticket). Restart
//! budgets and poison quarantine are scoped per pool — one shard's
//! persistent fault can burn its own budget without dimming its
//! neighbours.
//!
//! The mapping builders ([`build_solo_mapping`] / [`build_bundle_mapping`])
//! are shared between the serve paths and the coordinator's warm-start
//! pre-build, so a manifest replay populates the cache through the exact
//! single-flight path a live request would.

use std::collections::{BTreeMap, HashMap};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

use crate::arch::StreamingCgra;
use crate::config::SimBackend;
use crate::error::{Error, Result};
use crate::mapper::{map_unit, MapOutcome, MapUnit, MapperOptions};
use crate::sim::{
    execute_plan_lanes_with, simulate, simulate_fused_batch, ExecPlan, ExecScratch,
    MemberSegment, SegmentSim,
};
use crate::sparse::fuse::{BundleRoutes, FusedBundle};
use crate::sparse::SparseBlock;

use super::metrics::{Metrics, ShardMetrics};
use super::queue::{job_width, resolve_worker_gone, Job, SingleJob, WindowJob};
use super::window::WindowRequest;
use super::{InferResult, ServeError};

// ---------------------------------------------------------------------------
// Mapping cache

/// A cached, servable mapping: a solo block's or a whole fused bundle's.
pub(crate) struct ServingMapping {
    pub(crate) outcome: MapOutcome,
    /// `Some` when the mapping hosts a bundle — carries the member blocks
    /// the simulator needs for the co-resident streams.
    pub(crate) bundle: Option<Arc<FusedBundle>>,
    /// Compiled execution plan for the mapping, built once under the same
    /// single-flight guard as the mapping itself and evicted with it.
    /// `None` when the backend knob selects the interpreter or when plan
    /// compilation failed (a loud, logged fallback — never a lost ticket).
    pub(crate) plan: Option<ExecPlan>,
}

/// State of one cache entry. `Building` marks a mapping in flight; waiters
/// sleep on the entry's condvar instead of holding any mutex the builder
/// needs.
pub(crate) enum EntryState {
    /// No mapping and no builder in flight.
    Empty,
    Building,
    Ready(Arc<ServingMapping>),
    /// The build failed; the sticky error lets queued waiters fail fast
    /// instead of serially re-running a deterministically failing mapping.
    /// With `failure_ttl = 0` the entry is already detached from the cache
    /// map (new requesters get a fresh entry and their own retry); under a
    /// TTL it stays resident and `retry_in` counts down the remaining
    /// fast-fails — the request that finds it at `1` rebuilds in place.
    Failed { reason: String, retry_in: u64 },
}

pub(crate) struct CacheEntry {
    pub(crate) state: Mutex<EntryState>,
    pub(crate) ready: Condvar,
    /// Monotonic use tick for LRU eviction (unique per touch; assigned
    /// under the cache-map lock so eviction order is race-free and the
    /// tick index can be maintained in lockstep).
    pub(crate) last_use: AtomicU64,
}

/// Unwind guard for the build phase: if the build closure fails or panics
/// (a mapper invariant violation), mark the entry `Failed`, wake waiters
/// so they fail fast instead of deadlocking on a forever-`Building` entry
/// (or serially re-running a deterministically failing mapping), and drop
/// the entry from the cache map — `Failed` entries must not be found by
/// new requesters, and a dead entry would otherwise pin capacity forever
/// (only `Ready` entries are LRU victims, see [`evict_lru`]). The removal
/// is pointer-compared so a newer same-key entry created by a later
/// requester is never clobbered.
struct BuildGuard<'a> {
    cache: &'a MappingCache,
    key: &'a str,
    entry: &'a Arc<CacheEntry>,
    armed: bool,
}

impl BuildGuard<'_> {
    fn disarm(&mut self) {
        self.armed = false;
    }

    /// Mark the entry failed with `reason` and wake waiters. Under a
    /// failure TTL the entry stays resident (the next requests fail fast
    /// while `retry_in` counts down, then one rebuilds in place; LRU can
    /// evict it meanwhile); with TTL `0` the failure is sticky and the
    /// entry detaches from the cache (map and tick index).
    fn fail(&mut self, reason: &str) {
        self.armed = false;
        let ttl = self.cache.failure_ttl;
        {
            let mut state = self.entry.state.lock().expect("cache entry");
            *state = EntryState::Failed {
                reason: reason.to_string(),
                retry_in: if ttl == 0 { u64::MAX } else { ttl },
            };
            self.entry.ready.notify_all();
        }
        if ttl > 0 {
            return;
        }
        // Entry lock released before the map lock — the same order as
        // every other path (the map lock is never held while waiting
        // on an entry, and evict_lru only try_locks entry states).
        let mut inner = self.cache.inner.lock().expect("cache map");
        if inner.map.get(self.key).is_some_and(|e| Arc::ptr_eq(e, self.entry)) {
            inner.map.remove(self.key);
            // The entry's latest tick is authoritative: every touch
            // restamps it under the map lock we are holding.
            let tick = self.entry.last_use.load(Ordering::Relaxed);
            let removed = inner.by_tick.remove(&tick);
            debug_assert_eq!(removed.as_deref(), Some(self.key));
        }
    }
}

impl Drop for BuildGuard<'_> {
    fn drop(&mut self) {
        if self.armed {
            // Panic unwind path; the error path calls `fail` explicitly
            // with the builder's own message.
            self.fail("mapping build panicked");
        }
    }
}

/// The cache's locked state: the key → entry map plus the tick-ordered
/// LRU index. Both are maintained together under one mutex — every touch
/// restamps the entry's tick and moves its index row, so eviction walks
/// the index in use order instead of scanning the whole map.
pub(crate) struct CacheInner {
    pub(crate) map: HashMap<String, Arc<CacheEntry>>,
    /// Use tick → key. Ticks are unique (assigned under this lock), so
    /// this is a total LRU order over the resident entries.
    pub(crate) by_tick: BTreeMap<u64, String>,
}

/// Single-flight, LRU-bounded mapping cache (one per shard). The outer
/// map is only ever locked for entry lookup/insert/evict — mapping
/// happens against the entry's own state mutex, and waiters for an
/// in-flight mapping sleep on the entry's `Condvar`.
pub(crate) struct MappingCache {
    pub(crate) inner: Mutex<CacheInner>,
    tick: AtomicU64,
    /// `0` = unbounded.
    capacity: usize,
    /// Retry-after budget for failed builds (`[coordinator] failure_ttl`):
    /// a `Failed` entry fast-fails the next `failure_ttl - 1` requests for
    /// its key, then the next one rebuilds in place. `0` = sticky forever
    /// (failures detach; only a fresh requester retries).
    failure_ttl: u64,
}

impl MappingCache {
    pub(crate) fn new(capacity: usize, failure_ttl: u64) -> Self {
        MappingCache {
            inner: Mutex::new(CacheInner { map: HashMap::new(), by_tick: BTreeMap::new() }),
            tick: AtomicU64::new(0),
            capacity,
            failure_ttl,
        }
    }

    /// Fetch `key`'s mapping, building it via `build` on a miss. Exactly
    /// one requester builds; concurrent requesters for the same key wait
    /// on the entry and share the result (counted as cache hits). On a
    /// build failure the entry turns sticky-`Failed` and leaves the map —
    /// the builder and every queued waiter report the error without
    /// re-running the (deterministic) mapping, while a later fresh
    /// requester gets a new entry and its own retry.
    pub(crate) fn get_or_map<F>(
        &self,
        key: &str,
        metrics: &Metrics,
        build: F,
    ) -> Result<(Arc<ServingMapping>, bool)>
    where
        F: FnOnce() -> Result<ServingMapping>,
    {
        let entry = {
            let mut inner = self.inner.lock().expect("cache map");
            // The use tick is assigned while the map is locked, so a
            // concurrent inserter can never observe (and evict) an entry
            // that has not been stamped yet — and the tick index moves in
            // the same critical section.
            let tick = self.tick.fetch_add(1, Ordering::Relaxed) + 1;
            match inner.map.get(key) {
                Some(e) => {
                    let e = Arc::clone(e);
                    let prev = e.last_use.swap(tick, Ordering::Relaxed);
                    // Reuse the removed key String — the hit path stays
                    // allocation-free.
                    let moved =
                        inner.by_tick.remove(&prev).unwrap_or_else(|| key.to_string());
                    debug_assert_eq!(moved, key);
                    inner.by_tick.insert(tick, moved);
                    e
                }
                None => {
                    // Loop, not a single evict: overshoot accumulated
                    // while entries were mid-build (unevictable) is
                    // reclaimed here once those entries turn Ready.
                    while self.capacity > 0
                        && inner.map.len() >= self.capacity
                        && evict_lru(&mut inner)
                    {}
                    let e = Arc::new(CacheEntry {
                        state: Mutex::new(EntryState::Empty),
                        ready: Condvar::new(),
                        last_use: AtomicU64::new(tick),
                    });
                    inner.map.insert(key.to_string(), Arc::clone(&e));
                    inner.by_tick.insert(tick, key.to_string());
                    e
                }
            }
        };

        let mut state = entry.state.lock().expect("cache entry");
        loop {
            match &mut *state {
                EntryState::Ready(m) => {
                    metrics.cache_hits.fetch_add(1, Ordering::Relaxed);
                    return Ok((Arc::clone(m), false));
                }
                EntryState::Building => {
                    state = entry.ready.wait(state).expect("cache entry");
                }
                // The builder failed; the mapping is deterministic, so
                // re-running it immediately would pay the whole attempt
                // lattice again for the same error — fail fast with the
                // builder's reason while the retry budget lasts. The
                // request that finds the budget at 1 falls through to
                // `Building` and rebuilds in place (failure TTL expired).
                EntryState::Failed { reason, retry_in } => {
                    if *retry_in <= 1 {
                        break;
                    }
                    *retry_in -= 1;
                    return Err(Error::Runtime(format!(
                        "mapping failed in a concurrent request: {reason}"
                    )));
                }
                EntryState::Empty => break,
            }
        }
        *state = EntryState::Building;
        drop(state);

        let mut unwind = BuildGuard { cache: self, key, entry: &entry, armed: true };
        let built = build();
        match built {
            Ok(m) => {
                // A miss is counted only when a fresh mapping actually
                // lands: a failed build followed by a fallback (e.g. the
                // fused → solo path) must not report two misses for one
                // request — failures have their own counter.
                metrics.cache_misses.fetch_add(1, Ordering::Relaxed);
                let m = Arc::new(m);
                let mut state = entry.state.lock().expect("cache entry");
                unwind.disarm();
                *state = EntryState::Ready(Arc::clone(&m));
                entry.ready.notify_all();
                Ok((m, true))
            }
            // Waiters fail fast on the sticky error; the detached entry
            // leaves the map so a *new* requester gets a fresh entry and
            // its own (deterministic) retry.
            Err(e) => {
                unwind.fail(&e.to_string());
                Err(e)
            }
        }
    }
}

/// Evict the least-recently-used *evictable* entry by walking the tick
/// index in use order — O(victim position in the index), not a full-map
/// scan. Only `Ready` entries (and TTL-resident `Failed` ones, which hold
/// no mapping) are victims: a `Building` entry is the single-flight
/// rendezvous for concurrent requesters, and an `Empty` entry belongs to
/// a requester that has looked it up but not yet locked it — evicting
/// either would detach an in-flight mapping from the cache
/// (the result would be built and then silently dropped, and a concurrent
/// same-key request would map a second time). Non-victims stay in the
/// index and are skipped. At capacity the map may therefore transiently
/// exceed its bound by the number of in-flight mappings — the insert path
/// loops eviction, so the overshoot is reclaimed as those entries turn
/// Ready. Use ticks are unique, so the victim is deterministic for a
/// given request history. Returns whether a victim was evicted.
fn evict_lru(inner: &mut CacheInner) -> bool {
    let victim = inner.by_tick.iter().find_map(|(&tick, key)| {
        let e = inner.map.get(key)?;
        match e.state.try_lock() {
            // The state mutex is only ever held briefly (never across a
            // mapping), so a contended entry is simply skipped this round.
            Ok(state)
                if matches!(&*state, EntryState::Ready(_) | EntryState::Failed { .. }) =>
            {
                Some((tick, key.clone()))
            }
            _ => None,
        }
    });
    match victim {
        Some((tick, key)) => {
            inner.by_tick.remove(&tick);
            inner.map.remove(&key);
            true
        }
        None => false,
    }
}

// ---------------------------------------------------------------------------
// Shared mapping builders (serve paths + warm-start pre-build)

/// Cache key for a solo block's mapping. The key carries the mask's
/// content fingerprint — name and shape alone would silently alias two
/// differently-pruned blocks onto one mapping.
pub(crate) fn solo_cache_key(block: &SparseBlock) -> String {
    let fp = block.mask_fingerprint();
    format!("{}#{}x{}@{fp:016x}", block.name, block.c, block.k)
}

/// Cache key for a registered bundle's shared fused mapping.
pub(crate) fn bundle_cache_key(bundle: &FusedBundle) -> String {
    format!("{}@bundle:{:016x}", bundle.name, bundle.fingerprint())
}

/// Build a solo block's serving mapping (the `get_or_map` build closure).
pub(crate) fn build_solo_mapping(
    block: &Arc<SparseBlock>,
    key: &str,
    cgra: &StreamingCgra,
    opts: &MapperOptions,
    backend: SimBackend,
) -> Result<ServingMapping> {
    crate::fail_point_error!("coordinator::map", |msg: String| Err(Error::Runtime(msg)));
    let outcome = map_unit(MapUnit::Single(block), cgra, opts)?;
    let plan = compile_serving_plan(key, &outcome, cgra, backend);
    Ok(ServingMapping { outcome, bundle: None, plan })
}

/// Build a bundle's shared fused serving mapping (the `get_or_map` build
/// closure for window traffic and warm-start bundle pre-builds).
pub(crate) fn build_bundle_mapping(
    bundle: &Arc<FusedBundle>,
    key: &str,
    cgra: &StreamingCgra,
    opts: &MapperOptions,
    backend: SimBackend,
) -> Result<ServingMapping> {
    crate::fail_point_error!("coordinator::map", |msg: String| Err(Error::Runtime(msg)));
    // A bundle's combined MII sits far above the members' own MIIs and
    // the slot-offset composition needs II headroom: widen the slack
    // to the fused operating point unless the config is already wider.
    let mut bopts = opts.clone();
    bopts.ii_slack = bopts.ii_slack.max(MapperOptions::fused().ii_slack);
    let outcome = map_unit(MapUnit::Bundle(bundle), cgra, &bopts)?;
    let plan = compile_serving_plan(key, &outcome, cgra, backend);
    Ok(ServingMapping { outcome, bundle: Some(Arc::clone(bundle)), plan })
}

/// Compile the execution plan for a freshly built cache entry, honouring
/// the backend knob. Compilation failure is survivable by design: log
/// loudly and serve the entry off the scalar interpreter instead — a
/// degraded-throughput entry, never a lost ticket.
fn compile_serving_plan(
    key: &str,
    outcome: &MapOutcome,
    cgra: &StreamingCgra,
    backend: SimBackend,
) -> Option<ExecPlan> {
    if backend != SimBackend::Compiled {
        return None;
    }
    match try_compile_plan(outcome, cgra) {
        Ok(plan) => Some(plan),
        Err(e) => {
            crate::log_warn!(
                "execution-plan compile failed for {key} ({e}); serving falls back to the scalar interpreter"
            );
            None
        }
    }
}

/// The fallible half of plan compilation, isolated so the
/// `coordinator::plan` failpoint can early-return an `Err` without
/// touching the caller's fallback handling.
fn try_compile_plan(outcome: &MapOutcome, cgra: &StreamingCgra) -> Result<ExecPlan> {
    crate::fail_point_error!("coordinator::plan", |msg: String| Err(Error::Runtime(msg)));
    ExecPlan::for_outcome(outcome, cgra)
}

// ---------------------------------------------------------------------------
// Poison quarantine

/// Panic counts per job identity — a solo block's mask fingerprint or a
/// bundle's combined fingerprint. A job that keeps killing its worker is
/// quarantined (resolved [`ServeError::Poisoned`], never retried) once
/// its count reaches `[coordinator] poison_threshold`, so one poison
/// request cannot burn the whole restart budget. One registry per shard
/// pool: quarantine state never leaks across fabric instances.
pub(crate) struct PoisonRegistry {
    counts: Mutex<HashMap<u64, u32>>,
}

impl PoisonRegistry {
    pub(crate) fn new() -> Self {
        PoisonRegistry { counts: Mutex::new(HashMap::new()) }
    }

    /// Record one panic against `identity`; returns the new count. The
    /// lock is poison-recovered: panic bookkeeping must keep working on
    /// the very code paths panics unwind through.
    fn record(&self, identity: u64) -> u32 {
        let mut counts = self.counts.lock().unwrap_or_else(|p| p.into_inner());
        let c = counts.entry(identity).or_insert(0);
        *c += 1;
        *c
    }

    fn count(&self, identity: u64) -> u32 {
        let counts = self.counts.lock().unwrap_or_else(|p| p.into_inner());
        counts.get(&identity).copied().unwrap_or(0)
    }
}

// ---------------------------------------------------------------------------
// Workers and supervision

/// Everything a worker thread needs, bundled into one cloneable value so
/// the supervisor can respawn workers after the constructor returned.
#[derive(Clone)]
pub(crate) struct WorkerCtx {
    pub(crate) rx: Arc<Mutex<Receiver<Job>>>,
    pub(crate) queue_len: Arc<AtomicUsize>,
    pub(crate) cache: Arc<MappingCache>,
    pub(crate) bundles: Arc<BundleRoutes>,
    pub(crate) metrics: Arc<Metrics>,
    /// This pool's per-shard counter block (global counters keep their
    /// pre-sharding semantics; these split the same events by shard).
    pub(crate) shard: Arc<ShardMetrics>,
    pub(crate) shard_id: usize,
    pub(crate) opts: MapperOptions,
    pub(crate) cgra: StreamingCgra,
    pub(crate) poison: Arc<PoisonRegistry>,
    pub(crate) poison_threshold: u32,
    /// Which simulation backend freshly built cache entries compile for.
    /// Resolved once at construction (config knob + env override).
    pub(crate) backend: SimBackend,
    /// Resolved `[coordinator] sim_lanes`: lane width of the compiled
    /// backend's vectorized sweep (`0` auto, `1` scalar).
    pub(crate) lanes: usize,
}

/// Drop guard a worker thread holds for its whole life: tells the
/// supervisor the worker exited and whether it exited by panic. Running
/// in `Drop`, the notification survives any unwind path out of the
/// worker.
struct ExitGuard {
    id: usize,
    tx: Sender<(usize, bool)>,
}

impl Drop for ExitGuard {
    fn drop(&mut self) {
        let _ = self.tx.send((self.id, std::thread::panicking()));
    }
}

pub(crate) fn spawn_worker(
    wid: usize,
    ctx: WorkerCtx,
    exit_tx: Sender<(usize, bool)>,
) -> std::io::Result<std::thread::JoinHandle<()>> {
    std::thread::Builder::new()
        .name(format!("sparsemap-worker-{}-{wid}", ctx.shard_id))
        .spawn(move || {
            let _exit = ExitGuard { id: wid, tx: exit_tx };
            worker_loop(&ctx);
        })
}

/// Supervision loop (one per shard): collect worker exits, respawn
/// panicked workers while the shard's restart budget lasts (the pool
/// never shrinks silently — every shrink logs), and once the last worker
/// is gone keep draining the shard's queue, resolving every stranded
/// ticket, until the coordinator closes it. The drain is what makes
/// "every enqueued ticket resolves" hold even when persistent faults burn
/// the whole budget mid-traffic — and because budgets are per shard, a
/// dead pool drains its own queue while sibling shards keep serving.
pub(crate) fn supervisor_loop(
    exit_rx: Receiver<(usize, bool)>,
    exit_tx: Sender<(usize, bool)>,
    mut handles: Vec<Option<std::thread::JoinHandle<()>>>,
    ctx: WorkerCtx,
    restart_budget: usize,
) {
    let mut live = handles.len();
    let mut budget = restart_budget;
    let sid = ctx.shard_id;
    while live > 0 {
        // Cannot disconnect while this thread holds `exit_tx`; defensive.
        let Ok((wid, panicked)) = exit_rx.recv() else { break };
        if let Some(h) = handles[wid].take() {
            let _ = h.join();
        }
        if !panicked {
            // Clean exit: the queue closed and the worker drained out.
            live -= 1;
            continue;
        }
        // Per-job catch_unwind makes a worker-killing panic rare (only a
        // fault outside the guarded region reaches the thread boundary),
        // but the pool must survive it regardless.
        if budget == 0 {
            live -= 1;
            crate::log_warn!(
                "shard {sid} worker {wid} died with the restart budget exhausted; pool \
                 shrinks to {live} workers"
            );
            continue;
        }
        budget -= 1;
        match spawn_worker(wid, ctx.clone(), exit_tx.clone()) {
            Ok(h) => {
                ctx.metrics.worker_restarts.fetch_add(1, Ordering::Relaxed);
                ctx.shard.worker_restarts.fetch_add(1, Ordering::Relaxed);
                crate::log_warn!(
                    "shard {sid} worker {wid} died by panic; respawned ({budget} restarts \
                     left)"
                );
                handles[wid] = Some(h);
            }
            Err(e) => {
                live -= 1;
                crate::log_error!(
                    "respawning shard {sid} worker {wid} failed ({e}); pool shrinks"
                );
            }
        }
    }
    // Whole pool gone — restart budget exhausted under persistent faults,
    // or plain shutdown. Resolve everything queued (and everything still
    // arriving from senders that raced the pool's death) until the
    // coordinator closes the queue, so no ticket ever hangs.
    loop {
        let job = {
            let guard = ctx.rx.lock().unwrap_or_else(|p| p.into_inner());
            guard.recv()
        };
        match job {
            Ok(job) => {
                ctx.queue_len.fetch_sub(1, Ordering::Relaxed);
                ctx.metrics.failures.fetch_add(job_width(&job) as u64, Ordering::Relaxed);
                resolve_worker_gone(job);
            }
            Err(_) => return,
        }
    }
}

fn worker_loop(ctx: &WorkerCtx) {
    // Plan-execution scratch, owned by this worker thread for its whole
    // life: steady-state windows reuse the grown buffers instead of
    // allocating per window. It lives here — not in `WorkerCtx`, which
    // is shared (cloned) with the supervisor for respawns — so each
    // worker mutates its own scratch without synchronization; a respawn
    // simply starts a fresh one.
    let mut scratch = ExecScratch::new();
    loop {
        let job = {
            // Poison-recover: a panicking peer must not wedge the whole
            // pool on this lock — the receiver behind it is just data.
            let guard = ctx.rx.lock().unwrap_or_else(|p| p.into_inner());
            guard.recv()
        };
        match job {
            Ok(job) => {
                ctx.queue_len.fetch_sub(1, Ordering::Relaxed);
                // Hard-death site: a panic here is OUTSIDE the per-job
                // catch_unwind, so it kills the worker thread itself and
                // exercises supervisor respawn. The job's completers
                // resolve `WorkerGone` as the unwind drops them.
                crate::fail_point!("coordinator::worker_hard");
                match job {
                    Job::Single(job) => execute_single(job, ctx, &mut scratch),
                    Job::Window(job) => execute_window(job, ctx, &mut scratch),
                }
            }
            Err(_) => return,
        }
    }
}

/// Serve one solo request end to end and fulfill its ticket: deadline
/// check at pickup, then mapping + simulation under a per-job
/// `catch_unwind`, retried in place until the job identity's poison
/// quarantine trips.
pub(crate) fn execute_single(job: SingleJob, ctx: &WorkerCtx, scratch: &mut ExecScratch) {
    let picked = Instant::now();
    ctx.metrics.jobs.fetch_add(1, Ordering::Relaxed);
    let SingleJob { id, block, xs, done, deadline, enqueued_at } = job;
    if deadline.is_some_and(|d| picked >= d) {
        ctx.metrics.deadline_expired.fetch_add(1, Ordering::Relaxed);
        done.fulfill(Err(ServeError::DeadlineExceeded));
        return;
    }
    let identity = block.mask_fingerprint();
    let queue_ns = picked.saturating_duration_since(enqueued_at).as_nanos() as u64;
    loop {
        if ctx.poison.count(identity) >= ctx.poison_threshold {
            ctx.metrics.poisoned.fetch_add(1, Ordering::Relaxed);
            ctx.shard.poisoned.fetch_add(1, Ordering::Relaxed);
            ctx.metrics.failures.fetch_add(1, Ordering::Relaxed);
            done.fulfill(Err(ServeError::Poisoned));
            return;
        }
        // The closure borrows the payload and owns no completer: a panic
        // unwinds out of it without resolving (or double-resolving) the
        // ticket — fulfillment happens below, outside the guard.
        let attempt = catch_unwind(AssertUnwindSafe(|| {
            crate::fail_point!("coordinator::serve");
            crate::fail_point!("coordinator::delay");
            serve_solo(&block, &xs, ctx, &mut *scratch)
        }));
        match attempt {
            Ok(Ok(solo)) => {
                let SoloServe { outputs, cycles, ii, fresh, lanes, cops, mcids } = solo;
                if lanes {
                    // A solo request runs as a one-member window; count
                    // its lockstep pass like a batched one.
                    ctx.metrics.lane_windows.fetch_add(1, Ordering::Relaxed);
                }
                ctx.metrics.total_cycles.fetch_add(cycles, Ordering::Relaxed);
                let service_ns = picked.elapsed().as_nanos() as u64;
                let latency_ns = queue_ns + service_ns;
                ctx.metrics.total_latency_ns.fetch_add(latency_ns, Ordering::Relaxed);
                ctx.metrics.observe_latency(queue_ns, service_ns);
                ctx.shard.observe_queue(queue_ns);
                done.fulfill(Ok(InferResult {
                    id,
                    block_name: block.name.clone(),
                    outputs,
                    cycles,
                    ii,
                    cops,
                    mcids,
                    mapped_fresh: fresh,
                    fused_members: 1,
                    latency_ns,
                    queue_ns,
                    service_ns,
                }));
                return;
            }
            Ok(Err(e)) => {
                ctx.metrics.failures.fetch_add(1, Ordering::Relaxed);
                done.fulfill(Err(e));
                return;
            }
            Err(_) => {
                // The worker survived the panic (caught in place): count
                // a restart, record the poison strike, retry the job.
                ctx.metrics.worker_restarts.fetch_add(1, Ordering::Relaxed);
                ctx.shard.worker_restarts.fetch_add(1, Ordering::Relaxed);
                let strikes = ctx.poison.record(identity);
                crate::log_warn!(
                    "serving {} panicked (strike {strikes}); {}",
                    block.name,
                    if strikes >= ctx.poison_threshold {
                        "quarantining"
                    } else {
                        "retrying in place"
                    }
                );
            }
        }
    }
}

/// One served solo request, as `serve_solo` hands it back for ticket
/// fulfillment.
struct SoloServe {
    outputs: Vec<Vec<f32>>,
    cycles: u64,
    ii: usize,
    /// Whether this request built the mapping (cache miss).
    fresh: bool,
    /// Whether the lane-vectorized sweep served the request (feeds the
    /// `lane_windows` counter).
    lanes: bool,
    /// Caching operations of the mapping that served the request.
    cops: usize,
    /// Multi-cycle internal dependencies routed through GRF/LRF.
    mcids: usize,
}

/// Solo path: compile-once mapping keyed by block identity.
fn serve_solo(
    block: &Arc<SparseBlock>,
    xs: &[Vec<f32>],
    ctx: &WorkerCtx,
    scratch: &mut ExecScratch,
) -> std::result::Result<SoloServe, ServeError> {
    let key = solo_cache_key(block);
    let (serving, fresh) = ctx
        .cache
        .get_or_map(&key, &ctx.metrics, || {
            build_solo_mapping(block, &key, &ctx.cgra, &ctx.opts, ctx.backend)
        })
        .map_err(|e| ServeError::MappingFailed(e.to_string()))?;
    crate::fail_point_error!("coordinator::sim", |msg: String| Err(ServeError::Sim(msg)));
    match serving.plan.as_ref() {
        Some(plan) => {
            // Solo block as a one-member window: same compiled inner loop
            // the batched path runs, same bit-exact results.
            let batches = vec![vec![MemberSegment { block: block.as_ref(), xs }]];
            let (res, width) =
                execute_plan_lanes_with(plan, &[block.as_ref()], &batches, ctx.lanes, scratch)
                    .map_err(|e| ServeError::Sim(e.to_string()))?;
            let cycles = res.cycles;
            let (outputs, cops, mcids) = res
                .per_member
                .into_iter()
                .next()
                .map(|m| {
                    let outputs = m
                        .segments
                        .into_iter()
                        .next()
                        .map(|s| s.outputs)
                        .unwrap_or_default();
                    (outputs, m.cops, m.mcids)
                })
                .unwrap_or_default();
            Ok(SoloServe {
                outputs,
                cycles,
                ii: serving.outcome.mapping.ii,
                fresh,
                lanes: width > 1,
                cops,
                mcids,
            })
        }
        None => {
            let res = simulate(&serving.outcome.mapping, block, &ctx.cgra, xs)
                .map_err(|e| ServeError::Sim(e.to_string()))?;
            Ok(SoloServe {
                outputs: res.outputs,
                cycles: res.cycles,
                ii: serving.outcome.mapping.ii,
                fresh,
                lanes: false,
                cops: serving.outcome.mapping.cops(),
                mcids: serving.outcome.mapping.mcids(),
            })
        }
    }
}

/// Serve one batching window: shed expired members at pickup, then fetch
/// the bundle's shared fused mapping and run ONE lockstep pass for the
/// whole window, under the same `catch_unwind` + poison-quarantine
/// discipline as solo serving (quarantine keyed by the bundle
/// fingerprint). An unmappable bundle deregisters loudly and its live
/// members fall back to solo serving.
pub(crate) fn execute_window(job: WindowJob, ctx: &WorkerCtx, scratch: &mut ExecScratch) {
    let picked = Instant::now();
    let WindowJob { bundle, requests } = job;
    let mut live = Vec::with_capacity(requests.len());
    for r in requests {
        if r.deadline.is_some_and(|d| picked >= d) {
            ctx.metrics.jobs.fetch_add(1, Ordering::Relaxed);
            ctx.metrics.deadline_expired.fetch_add(1, Ordering::Relaxed);
            r.done.fulfill(Err(ServeError::DeadlineExceeded));
        } else {
            live.push(r);
        }
    }
    if live.is_empty() {
        return;
    }
    let identity = bundle.fingerprint();
    let w = live.len() as u64;
    loop {
        if ctx.poison.count(identity) >= ctx.poison_threshold {
            ctx.metrics.jobs.fetch_add(w, Ordering::Relaxed);
            ctx.metrics.poisoned.fetch_add(w, Ordering::Relaxed);
            ctx.shard.poisoned.fetch_add(w, Ordering::Relaxed);
            ctx.metrics.failures.fetch_add(w, Ordering::Relaxed);
            for r in live {
                r.done.fulfill(Err(ServeError::Poisoned));
            }
            return;
        }
        let attempt = catch_unwind(AssertUnwindSafe(|| {
            crate::fail_point!("coordinator::serve");
            crate::fail_point!("coordinator::delay");
            attempt_window(&bundle, &live, ctx, &mut *scratch)
        }));
        match attempt {
            Ok(WindowAttempt::Served { segments, pass_cycles, ii, fresh, members, lanes }) => {
                ctx.metrics.jobs.fetch_add(w, Ordering::Relaxed);
                ctx.metrics.windows.fetch_add(1, Ordering::Relaxed);
                ctx.shard.windows.fetch_add(1, Ordering::Relaxed);
                if lanes {
                    ctx.metrics.lane_windows.fetch_add(1, Ordering::Relaxed);
                }
                // The window pays for the resident configuration ONCE —
                // this is the fused double-count fix: W member requests
                // never charge W whole-bundle passes.
                ctx.metrics.total_cycles.fetch_add(pass_cycles, Ordering::Relaxed);
                let service_ns = picked.elapsed().as_nanos() as u64;
                for (ri, (r, (seg, cops, mcids))) in live.into_iter().zip(segments).enumerate() {
                    let queue_ns =
                        picked.saturating_duration_since(r.enqueued_at).as_nanos() as u64;
                    let latency_ns = queue_ns + service_ns;
                    ctx.metrics.total_latency_ns.fetch_add(latency_ns, Ordering::Relaxed);
                    ctx.metrics.observe_latency(queue_ns, service_ns);
                    ctx.shard.observe_queue(queue_ns);
                    r.done.fulfill(Ok(InferResult {
                        id: r.id,
                        block_name: r.block.name.clone(),
                        outputs: seg.outputs,
                        cycles: seg.cycles,
                        ii,
                        cops,
                        mcids,
                        mapped_fresh: fresh && ri == 0,
                        fused_members: members,
                        latency_ns,
                        queue_ns,
                        service_ns,
                    }));
                }
                return;
            }
            Ok(WindowAttempt::SimFailed(err)) => {
                ctx.metrics.jobs.fetch_add(w, Ordering::Relaxed);
                ctx.metrics.failures.fetch_add(w, Ordering::Relaxed);
                for r in live {
                    r.done.fulfill(Err(err.clone()));
                }
                return;
            }
            // The planner admits bundles by the MII estimate, not bind
            // feasibility, so a registered bundle can turn out unmappable.
            // The mapper is deterministic — it would fail (and re-pay the
            // whole attempt lattice) on every member window forever — so
            // drop the registration and serve this window's and all
            // future member traffic through the working solo path.
            // Loudly: the silently-lost residency win would otherwise be
            // undiagnosable (requests succeed, failures stays 0).
            Ok(WindowAttempt::Unmappable(e)) => {
                crate::log_warn!(
                    "bundle {} is unmappable ({e}); deregistering — its {} members fall \
                     back to solo serving",
                    bundle.name,
                    bundle.len()
                );
                ctx.bundles.deregister(&bundle);
                for r in live {
                    execute_single(
                        SingleJob {
                            id: r.id,
                            block: r.block,
                            xs: r.xs,
                            done: r.done,
                            deadline: r.deadline,
                            enqueued_at: r.enqueued_at,
                        },
                        ctx,
                        &mut *scratch,
                    );
                }
                return;
            }
            Err(_) => {
                ctx.metrics.worker_restarts.fetch_add(1, Ordering::Relaxed);
                ctx.shard.worker_restarts.fetch_add(1, Ordering::Relaxed);
                let strikes = ctx.poison.record(identity);
                crate::log_warn!(
                    "window for bundle {} panicked (strike {strikes}); {}",
                    bundle.name,
                    if strikes >= ctx.poison_threshold {
                        "quarantining"
                    } else {
                        "retrying in place"
                    }
                );
            }
        }
    }
}

/// Outcome of one fused window attempt, computed inside the per-job
/// unwind guard (borrowing the live requests) and consumed outside it —
/// ticket fulfillment never happens under `catch_unwind`.
enum WindowAttempt {
    Served {
        /// One `(segment, cops, mcids)` per live request, in window order —
        /// the COP/MCID counts are the serving member's own (static per
        /// mapping, attributed to every request that member carried).
        segments: Vec<(SegmentSim, usize, usize)>,
        pass_cycles: u64,
        ii: usize,
        fresh: bool,
        members: usize,
        /// Whether the lane-vectorized sweep ran the pass (feeds the
        /// `lane_windows` counter at the fulfillment site).
        lanes: bool,
    },
    /// The bundle's shared fused mapping failed to build: the caller
    /// deregisters the bundle and falls back to solo serving.
    Unmappable(Error),
    /// The lockstep pass faulted: every member request fails.
    SimFailed(ServeError),
}

/// Fetch (or build) the fused mapping and run the window's single
/// lockstep pass. Borrows the requests — the caller keeps ownership (and
/// the completers) outside the unwind guard.
fn attempt_window(
    bundle: &Arc<FusedBundle>,
    requests: &[WindowRequest],
    ctx: &WorkerCtx,
    scratch: &mut ExecScratch,
) -> WindowAttempt {
    let (serving, fresh) = match fused_serving(bundle, ctx) {
        Ok(sf) => sf,
        Err(e) => return WindowAttempt::Unmappable(e),
    };
    // One cache access served the whole window: count the other member
    // requests as hits so `jobs == hits + misses` keeps holding for
    // successful traffic.
    ctx.metrics.cache_hits.fetch_add(requests.len() as u64 - 1, Ordering::Relaxed);
    crate::fail_point_error!("coordinator::sim", |msg: String| WindowAttempt::SimFailed(
        ServeError::Sim(msg)
    ));
    let resident = serving.bundle.as_ref().expect("fused entry carries its bundle");
    // Member → request indices, in window order (the per-member segment
    // order the batched pass preserves).
    let mut member_reqs: Vec<Vec<usize>> = vec![Vec::new(); resident.len()];
    for (ri, r) in requests.iter().enumerate() {
        debug_assert!(r.member < resident.len(), "routed member index in range");
        member_reqs[r.member].push(ri);
    }
    // The member's weights come from each request (same mask structure —
    // that is what the fingerprint routing matched); members absent from
    // the window stream zeros via padding.
    let blocks: Vec<&SparseBlock> = resident.blocks.iter().map(|b| b.as_ref()).collect();
    let batches: Vec<Vec<MemberSegment<'_>>> = member_reqs
        .iter()
        .map(|idxs| {
            idxs.iter()
                .map(|&ri| MemberSegment {
                    block: requests[ri].block.as_ref(),
                    xs: requests[ri].xs.as_slice(),
                })
                .collect()
        })
        .collect();
    let sim = match serving.plan.as_ref() {
        Some(plan) => execute_plan_lanes_with(plan, &blocks, &batches, ctx.lanes, scratch)
            .map(|(res, width)| (res, width > 1)),
        None => simulate_fused_batch(
            &serving.outcome.mapping,
            &serving.outcome.tags,
            &blocks,
            &ctx.cgra,
            &batches,
        )
        .map(|res| (res, false)),
    };
    match sim {
        Ok((res, lanes)) => {
            let w = requests.len();
            let mut per_request: Vec<Option<(SegmentSim, usize, usize)>> = Vec::new();
            per_request.resize_with(w, || None);
            for (mi, m) in res.per_member.into_iter().enumerate() {
                let (cops, mcids) = (m.cops, m.mcids);
                for (seg, &ri) in m.segments.into_iter().zip(&member_reqs[mi]) {
                    per_request[ri] = Some((seg, cops, mcids));
                }
            }
            let segments = per_request
                .into_iter()
                .map(|s| s.expect("one segment per request"))
                .collect();
            WindowAttempt::Served {
                segments,
                pass_cycles: res.cycles,
                ii: serving.outcome.mapping.ii,
                fresh,
                members: resident.len(),
                lanes,
            }
        }
        Err(e) => WindowAttempt::SimFailed(ServeError::Sim(e.to_string())),
    }
}

/// Map (or fetch from cache) a registered bundle's shared fused mapping.
/// A mapping error here means the bundle cannot map on this fabric at
/// all — the caller falls back to solo serving; request-specific errors
/// never originate here.
fn fused_serving(
    bundle: &Arc<FusedBundle>,
    ctx: &WorkerCtx,
) -> Result<(Arc<ServingMapping>, bool)> {
    let key = bundle_cache_key(bundle);
    ctx.cache.get_or_map(&key, &ctx.metrics, || {
        build_bundle_mapping(bundle, &key, &ctx.cgra, &ctx.opts, ctx.backend)
    })
}
