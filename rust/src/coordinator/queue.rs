//! The bounded job queue and the job envelopes that travel it. Each shard
//! owns one `JobQueue`; a batching window occupies a single queue slot
//! however many requests it carries.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{SendError, SyncSender, TrySendError};
use std::sync::Arc;
use std::time::Instant;

use crate::sparse::fuse::FusedBundle;
use crate::sparse::SparseBlock;

use super::window::{TicketCompleter, WindowRequest};
use super::ServeError;

pub(crate) enum Job {
    Single(SingleJob),
    Window(WindowJob),
}

pub(crate) struct SingleJob {
    pub(crate) id: u64,
    pub(crate) block: Arc<SparseBlock>,
    pub(crate) xs: Vec<Vec<f32>>,
    pub(crate) done: TicketCompleter,
    /// Shed (as `DeadlineExceeded`) at worker pickup once passed.
    pub(crate) deadline: Option<Instant>,
    /// Enqueue timestamp, for queue-span latency attribution.
    pub(crate) enqueued_at: Instant,
}

pub(crate) struct WindowJob {
    pub(crate) bundle: Arc<FusedBundle>,
    /// Member requests in window (global enqueue) order.
    pub(crate) requests: Vec<WindowRequest>,
}

/// Ticket count aboard a job.
pub(crate) fn job_width(job: &Job) -> usize {
    match job {
        Job::Single(_) => 1,
        Job::Window(w) => w.requests.len(),
    }
}

/// Resolve every ticket aboard `job` to [`ServeError::WorkerGone`] (the
/// pool died with the job still queued).
pub(crate) fn resolve_worker_gone(job: Job) {
    match job {
        Job::Single(j) => j.done.fulfill(Err(ServeError::WorkerGone)),
        Job::Window(w) => {
            for r in w.requests {
                r.done.fulfill(Err(ServeError::WorkerGone));
            }
        }
    }
}

/// Resolve every ticket aboard `job` to [`ServeError::QueueClosed`]
/// (dispatch against a closed queue).
pub(crate) fn resolve_queue_closed(job: Job) {
    match job {
        Job::Single(j) => j.done.fulfill(Err(ServeError::QueueClosed)),
        Job::Window(w) => {
            for r in w.requests {
                r.done.fulfill(Err(ServeError::QueueClosed));
            }
        }
    }
}

/// The bounded job queue plus an occupancy gauge for admission control.
/// The gauge counts enqueued-but-not-picked-up jobs: it is incremented
/// *before* the underlying send (and rolled back on failure) and
/// decremented by a worker at pickup — so it can transiently over-count
/// by the number of in-flight senders but never underflows (a wrap would
/// make the shed watermark reject everything).
pub(crate) struct JobQueue {
    pub(crate) tx: SyncSender<Job>,
    pub(crate) len: Arc<AtomicUsize>,
}

impl JobQueue {
    /// Blocking send (backpressure). On a closed queue the job is handed
    /// back so the caller can resolve its tickets.
    pub(crate) fn send(&self, job: Job) -> std::result::Result<(), Job> {
        self.len.fetch_add(1, Ordering::Relaxed);
        match self.tx.send(job) {
            Ok(()) => Ok(()),
            Err(SendError(job)) => {
                self.len.fetch_sub(1, Ordering::Relaxed);
                Err(job)
            }
        }
    }

    /// Non-blocking send, for admission control.
    pub(crate) fn try_send(&self, job: Job) -> std::result::Result<(), TrySendError<Job>> {
        self.len.fetch_add(1, Ordering::Relaxed);
        match self.tx.try_send(job) {
            Ok(()) => Ok(()),
            Err(e) => {
                self.len.fetch_sub(1, Ordering::Relaxed);
                Err(e)
            }
        }
    }

    /// Jobs currently queued (approximate under concurrent traffic, exact
    /// when quiescent).
    pub(crate) fn occupancy(&self) -> usize {
        self.len.load(Ordering::Relaxed)
    }
}
