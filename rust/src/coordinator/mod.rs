//! Streaming inference coordinator (L3 runtime).
//!
//! Owns the request path of the system: a bounded job queue (backpressure),
//! a worker-thread pool that maps blocks (with a compile-once mapping
//! cache) and executes them on the cycle-accurate CGRA simulator, and
//! aggregate metrics. The PJRT cross-check (`crate::runtime`) runs on the
//! caller's thread — XLA executables stay off the worker pool.
//!
//! ## Mapping cache
//!
//! The cache is single-flight and LRU-bounded: one entry per mapping key,
//! the first requester builds (maps) while concurrent requesters for the
//! same key sleep on the entry's `Condvar` — the cache's outer mutex is
//! never held across a mapping, so unrelated blocks proceed in parallel
//! and waiters block on nothing but their own entry. Capacity comes from
//! `[coordinator] cache_capacity` (`0` = unbounded); at capacity the
//! least-recently-used entry is evicted (in-flight holders keep their
//! `Arc`).
//!
//! ## Multi-block fusion
//!
//! Small blocks can be registered as a [`FusedBundle`]
//! ([`Coordinator::register_bundle`] / [`Coordinator::register_fused`]):
//! a request for *any* member block routes to the bundle's shared fused
//! mapping — one cache entry keyed by the bundle's combined mask
//! fingerprint, mapped once, no reconfiguration between member requests.
//! Unregistered blocks serve solo through the same cache, so fused and
//! unfused traffic mix freely.
//!
//! tokio is unavailable offline; the pool is built on std threads +
//! `std::sync::mpsc::sync_channel`, which gives exactly the bounded-queue
//! semantics the backpressure design needs.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, Sender, SyncSender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

use crate::arch::StreamingCgra;
use crate::config::SparsemapConfig;
use crate::error::{Error, Result};
use crate::mapper::{map_unit, MapOutcome, MapUnit, MapperOptions};
use crate::sim::{simulate, simulate_fused};
use crate::sparse::fuse::{plan_bundles, FusedBundle, FusionOptions};
use crate::sparse::SparseBlock;

/// One inference job: run `xs` (iteration-major input vectors) through a
/// sparse block on the CGRA.
pub struct InferRequest {
    pub id: u64,
    pub block: Arc<SparseBlock>,
    pub xs: Vec<Vec<f32>>,
}

/// The coordinator's answer.
#[derive(Clone, Debug)]
pub struct InferResult {
    pub id: u64,
    pub block_name: String,
    pub outputs: Vec<Vec<f32>>,
    /// CGRA cycles consumed.
    pub cycles: u64,
    /// II of the mapping used.
    pub ii: usize,
    /// Whether this job triggered a fresh mapping (cache miss).
    pub mapped_fresh: bool,
    /// Member blocks resident in the configuration that served this
    /// request (`1` = unfused).
    pub fused_members: usize,
    /// End-to-end latency in nanoseconds.
    pub latency_ns: u64,
}

/// Aggregate counters (lock-free reads).
#[derive(Default)]
pub struct Metrics {
    pub jobs: AtomicU64,
    pub failures: AtomicU64,
    pub cache_hits: AtomicU64,
    pub cache_misses: AtomicU64,
    pub total_cycles: AtomicU64,
    pub total_latency_ns: AtomicU64,
}

impl Metrics {
    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            jobs: self.jobs.load(Ordering::Relaxed),
            failures: self.failures.load(Ordering::Relaxed),
            cache_hits: self.cache_hits.load(Ordering::Relaxed),
            cache_misses: self.cache_misses.load(Ordering::Relaxed),
            total_cycles: self.total_cycles.load(Ordering::Relaxed),
            total_latency_ns: self.total_latency_ns.load(Ordering::Relaxed),
        }
    }
}

#[derive(Clone, Copy, Debug)]
pub struct MetricsSnapshot {
    pub jobs: u64,
    pub failures: u64,
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub total_cycles: u64,
    pub total_latency_ns: u64,
}

/// A cached, servable mapping: a solo block's or a whole fused bundle's.
struct ServingMapping {
    outcome: MapOutcome,
    /// `Some` when the mapping hosts a bundle — carries the member blocks
    /// the simulator needs for the co-resident streams.
    bundle: Option<Arc<FusedBundle>>,
}

/// State of one cache entry. `Building` marks a mapping in flight; waiters
/// sleep on the entry's condvar instead of holding any mutex the builder
/// needs.
enum EntryState {
    /// No mapping and no builder in flight.
    Empty,
    Building,
    Ready(Arc<ServingMapping>),
    /// The build failed. The entry is already detached from the cache map
    /// (so new requesters get a fresh entry and their own retry); the
    /// sticky error lets queued waiters fail fast instead of serially
    /// re-running a deterministically failing mapping.
    Failed(String),
}

struct CacheEntry {
    state: Mutex<EntryState>,
    ready: Condvar,
    /// Monotonic use tick for LRU eviction (unique per touch; assigned
    /// under the cache-map lock so eviction order is race-free).
    last_use: AtomicU64,
}

/// Unwind guard for the build phase: if the build closure fails or panics
/// (a mapper invariant violation), mark the entry `Failed`, wake waiters
/// so they fail fast instead of deadlocking on a forever-`Building` entry
/// (or serially re-running a deterministically failing mapping), and drop
/// the entry from the cache map — `Failed` entries must not be found by
/// new requesters, and a dead entry would otherwise pin capacity forever
/// (only `Ready` entries are LRU victims, see [`evict_lru`]). The removal
/// is pointer-compared so a newer same-key entry created by a later
/// requester is never clobbered.
struct BuildGuard<'a> {
    cache: &'a MappingCache,
    key: &'a str,
    entry: &'a Arc<CacheEntry>,
    armed: bool,
}

impl BuildGuard<'_> {
    fn disarm(&mut self) {
        self.armed = false;
    }

    /// Mark the entry failed with `reason`, wake waiters, and detach the
    /// entry from the cache map.
    fn fail(&mut self, reason: &str) {
        self.armed = false;
        {
            let mut state = self.entry.state.lock().expect("cache entry");
            *state = EntryState::Failed(reason.to_string());
            self.entry.ready.notify_all();
        }
        // Entry lock released before the map lock — the same order as
        // every other path (the map lock is never held while waiting
        // on an entry, and evict_lru only try_locks entry states).
        let mut map = self.cache.inner.lock().expect("cache map");
        if map.get(self.key).is_some_and(|e| Arc::ptr_eq(e, self.entry)) {
            map.remove(self.key);
        }
    }
}

impl Drop for BuildGuard<'_> {
    fn drop(&mut self) {
        if self.armed {
            // Panic unwind path; the error path calls `fail` explicitly
            // with the builder's own message.
            self.fail("mapping build panicked");
        }
    }
}

/// Single-flight, LRU-bounded mapping cache. The outer map is only ever
/// locked for entry lookup/insert/evict — mapping happens against the
/// entry's own state mutex, and waiters for an in-flight mapping sleep on
/// the entry's `Condvar`.
struct MappingCache {
    inner: Mutex<HashMap<String, Arc<CacheEntry>>>,
    tick: AtomicU64,
    /// `0` = unbounded.
    capacity: usize,
}

impl MappingCache {
    fn new(capacity: usize) -> Self {
        MappingCache { inner: Mutex::new(HashMap::new()), tick: AtomicU64::new(0), capacity }
    }

    /// Fetch `key`'s mapping, building it via `build` on a miss. Exactly
    /// one requester builds; concurrent requesters for the same key wait
    /// on the entry and share the result (counted as cache hits). On a
    /// build failure the entry turns sticky-`Failed` and leaves the map —
    /// the builder and every queued waiter report the error without
    /// re-running the (deterministic) mapping, while a later fresh
    /// requester gets a new entry and its own retry.
    fn get_or_map<F>(
        &self,
        key: &str,
        metrics: &Metrics,
        build: F,
    ) -> Result<(Arc<ServingMapping>, bool)>
    where
        F: FnOnce() -> Result<ServingMapping>,
    {
        let entry = {
            let mut map = self.inner.lock().expect("cache map");
            // The use tick is assigned while the map is locked, so a
            // concurrent inserter can never observe (and evict) an entry
            // that has not been stamped yet.
            let tick = self.tick.fetch_add(1, Ordering::Relaxed) + 1;
            match map.get(key) {
                Some(e) => {
                    e.last_use.store(tick, Ordering::Relaxed);
                    Arc::clone(e)
                }
                None => {
                    // Loop, not a single evict: overshoot accumulated
                    // while entries were mid-build (unevictable) is
                    // reclaimed here once those entries turn Ready.
                    while self.capacity > 0
                        && map.len() >= self.capacity
                        && evict_lru(&mut map)
                    {}
                    let e = Arc::new(CacheEntry {
                        state: Mutex::new(EntryState::Empty),
                        ready: Condvar::new(),
                        last_use: AtomicU64::new(tick),
                    });
                    map.insert(key.to_string(), Arc::clone(&e));
                    e
                }
            }
        };

        let mut state = entry.state.lock().expect("cache entry");
        loop {
            match &*state {
                EntryState::Ready(m) => {
                    metrics.cache_hits.fetch_add(1, Ordering::Relaxed);
                    return Ok((Arc::clone(m), false));
                }
                EntryState::Building => {
                    state = entry.ready.wait(state).expect("cache entry");
                }
                // The builder failed; the mapping is deterministic, so
                // re-running it here would pay the whole attempt lattice
                // again for the same error — fail fast with the builder's
                // reason instead.
                EntryState::Failed(reason) => {
                    return Err(Error::Runtime(format!(
                        "mapping failed in a concurrent request: {reason}"
                    )));
                }
                EntryState::Empty => break,
            }
        }
        *state = EntryState::Building;
        drop(state);

        let mut unwind = BuildGuard { cache: self, key, entry: &entry, armed: true };
        let built = build();
        match built {
            Ok(m) => {
                // A miss is counted only when a fresh mapping actually
                // lands: a failed build followed by a fallback (e.g. the
                // fused → solo path) must not report two misses for one
                // request — failures have their own counter.
                metrics.cache_misses.fetch_add(1, Ordering::Relaxed);
                let m = Arc::new(m);
                let mut state = entry.state.lock().expect("cache entry");
                unwind.disarm();
                *state = EntryState::Ready(Arc::clone(&m));
                entry.ready.notify_all();
                Ok((m, true))
            }
            // Waiters fail fast on the sticky error; the detached entry
            // leaves the map so a *new* requester gets a fresh entry and
            // its own (deterministic) retry.
            Err(e) => {
                unwind.fail(&e.to_string());
                Err(e)
            }
        }
    }
}

/// Evict the least-recently-used *evictable* entry. Only `Ready` entries
/// are victims: a `Building` entry is the single-flight rendezvous for
/// concurrent requesters, and an `Empty` entry belongs to a requester
/// that has looked it up but not yet locked it — evicting either would
/// detach an in-flight mapping from the cache (the result would be built
/// and then silently dropped, and a concurrent same-key request would map
/// a second time). At capacity the map may therefore transiently exceed
/// its bound by the number of in-flight mappings — the insert path loops
/// eviction, so the overshoot is reclaimed as those entries turn Ready.
/// Use ticks are unique (every touch bumps a shared counter under the map
/// lock), so the victim is deterministic for a given request history.
/// Returns whether a victim was evicted.
fn evict_lru(map: &mut HashMap<String, Arc<CacheEntry>>) -> bool {
    let victim = map
        .iter()
        .filter(|(_, e)| match e.state.try_lock() {
            // The state mutex is only ever held briefly (never across a
            // mapping), so a contended entry is simply skipped this round.
            Ok(state) => matches!(&*state, EntryState::Ready(_)),
            Err(_) => false,
        })
        .min_by_key(|(_, e)| e.last_use.load(Ordering::Relaxed))
        .map(|(k, _)| k.clone());
    match victim {
        Some(key) => {
            map.remove(&key);
            true
        }
        None => false,
    }
}

/// Member-fingerprint → bundle routing table.
type BundleRegistry = Arc<Mutex<HashMap<u64, Arc<FusedBundle>>>>;

enum Job {
    Infer(InferRequest),
}

/// The streaming coordinator.
pub struct Coordinator {
    tx: Option<SyncSender<Job>>,
    results: Receiver<Result<InferResult>>,
    workers: Vec<std::thread::JoinHandle<()>>,
    pub metrics: Arc<Metrics>,
    bundles: BundleRegistry,
    fusion: FusionOptions,
    cgra: StreamingCgra,
}

impl Coordinator {
    /// Spawn `cfg.workers` worker threads with a queue of depth
    /// `cfg.queue_depth`.
    pub fn new(cfg: &SparsemapConfig) -> Self {
        let (tx, rx) = sync_channel::<Job>(cfg.queue_depth);
        let rx = Arc::new(Mutex::new(rx));
        let (res_tx, results) = std::sync::mpsc::channel::<Result<InferResult>>();
        let cache = Arc::new(MappingCache::new(cfg.cache_capacity));
        let bundles: BundleRegistry = Arc::new(Mutex::new(HashMap::new()));
        let metrics = Arc::new(Metrics::default());
        let mut opts = MapperOptions::from_config(cfg);
        if opts.parallelism == 0 {
            // Auto portfolio width: split the machine between the worker
            // pool and each worker's mapping portfolio, so a burst of
            // cache misses doesn't oversubscribe cores. The mapping itself
            // is width-independent (deterministic portfolio), so this only
            // shapes latency.
            let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
            opts.parallelism = (cores / cfg.workers.max(1)).clamp(1, 8);
        }
        let fusion = opts.fusion;
        let cgra = cfg.cgra.clone();

        let workers = (0..cfg.workers)
            .map(|wid| {
                let rx = Arc::clone(&rx);
                let res_tx = res_tx.clone();
                let cache = Arc::clone(&cache);
                let bundles = Arc::clone(&bundles);
                let metrics = Arc::clone(&metrics);
                let opts = opts.clone();
                let cgra = cgra.clone();
                std::thread::Builder::new()
                    .name(format!("sparsemap-worker-{wid}"))
                    .spawn(move || worker_loop(rx, res_tx, cache, bundles, metrics, opts, cgra))
                    .expect("spawn worker")
            })
            .collect();

        Coordinator { tx: Some(tx), results, workers, metrics, bundles, fusion, cgra }
    }

    /// Register a fused bundle: from now on a request for *any* member
    /// block is served through the bundle's shared fused mapping (one
    /// cache entry keyed by the bundle's combined mask fingerprint).
    /// Requests already served solo keep their solo cache entries — fused
    /// and unfused traffic mix freely.
    pub fn register_bundle(&self, bundle: Arc<FusedBundle>) {
        let mut reg = self.bundles.lock().expect("bundle registry");
        for b in &bundle.blocks {
            reg.insert(b.mask_fingerprint(), Arc::clone(&bundle));
        }
    }

    /// Plan fusion over `blocks` with the configured knobs
    /// (`[mapper] max_fused_blocks` / `[mapper] fusion_max_ii`) and
    /// register every multi-block bundle. Returns the full plan
    /// (singletons included — they stay unregistered and serve solo).
    pub fn register_fused(&self, blocks: &[Arc<SparseBlock>]) -> Vec<FusedBundle> {
        let plan = plan_bundles(blocks, &self.cgra, &self.fusion);
        for bundle in &plan {
            if bundle.len() > 1 {
                self.register_bundle(Arc::new(bundle.clone()));
            }
        }
        plan
    }

    /// Submit a job; blocks when the queue is full (backpressure).
    pub fn submit(&self, req: InferRequest) -> Result<()> {
        self.tx
            .as_ref()
            .expect("coordinator live")
            .send(Job::Infer(req))
            .map_err(|_| Error::Runtime("coordinator shut down".into()))
    }

    /// Collect exactly `n` results (any order — jobs are tagged by id).
    /// If the worker pool exits before delivering them all (panic,
    /// shutdown), the remaining slots come back as `Err(Error::Runtime)`
    /// instead of poisoning the caller with a panic.
    pub fn collect(&self, n: usize) -> Vec<Result<InferResult>> {
        (0..n)
            .map(|_| {
                self.results.recv().unwrap_or_else(|_| {
                    Err(Error::Runtime(
                        "worker pool exited before delivering all results".into(),
                    ))
                })
            })
            .collect()
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.tx.take(); // close the queue; workers drain and exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn worker_loop(
    rx: Arc<Mutex<Receiver<Job>>>,
    res_tx: Sender<Result<InferResult>>,
    cache: Arc<MappingCache>,
    bundles: BundleRegistry,
    metrics: Arc<Metrics>,
    opts: MapperOptions,
    cgra: StreamingCgra,
) {
    loop {
        let job = {
            let guard = rx.lock().expect("queue lock");
            guard.recv()
        };
        let Ok(Job::Infer(req)) = job else { return };
        let started = Instant::now();
        let outcome = run_one(&req, &cache, &bundles, &metrics, &opts, &cgra);
        metrics.jobs.fetch_add(1, Ordering::Relaxed);
        let out = match outcome {
            Ok((outputs, cycles, ii, fresh, fused_members)) => {
                metrics.total_cycles.fetch_add(cycles, Ordering::Relaxed);
                let latency_ns = started.elapsed().as_nanos() as u64;
                metrics.total_latency_ns.fetch_add(latency_ns, Ordering::Relaxed);
                Ok(InferResult {
                    id: req.id,
                    block_name: req.block.name.clone(),
                    outputs,
                    cycles,
                    ii,
                    mapped_fresh: fresh,
                    fused_members,
                    latency_ns,
                })
            }
            Err(e) => {
                metrics.failures.fetch_add(1, Ordering::Relaxed);
                Err(e)
            }
        };
        if res_tx.send(out).is_err() {
            return; // caller gone
        }
    }
}

fn run_one(
    req: &InferRequest,
    cache: &MappingCache,
    bundles: &BundleRegistry,
    metrics: &Metrics,
    opts: &MapperOptions,
    cgra: &StreamingCgra,
) -> Result<(Vec<Vec<f32>>, u64, usize, bool, usize)> {
    let fp = req.block.mask_fingerprint();
    let bundle = bundles.lock().expect("bundle registry").get(&fp).cloned();
    if let Some(bundle) = bundle {
        match fused_serving(&bundle, cache, metrics, opts, cgra) {
            Ok((serving, fresh)) => return run_fused(req, fp, &serving, fresh, cgra),
            // The planner admits bundles by the MII estimate, not bind
            // feasibility, so a registered bundle can turn out unmappable.
            // The mapper is deterministic — it would fail (and re-pay the
            // whole attempt lattice) on every member request forever —
            // so drop the registration and serve this and all future
            // member traffic through the working solo path below. Loudly:
            // the silently-lost residency win would otherwise be
            // undiagnosable (requests succeed, failures stays 0).
            Err(e) => {
                crate::log_warn!(
                    "bundle {} is unmappable ({e}); deregistering — its {} members fall \
                     back to solo serving",
                    bundle.name,
                    bundle.len()
                );
                deregister_bundle(bundles, &bundle);
            }
        }
    }

    // Solo path: compile-once mapping keyed by block identity. The key
    // carries the mask's content fingerprint — name and shape alone would
    // silently alias two differently-pruned blocks onto one mapping.
    let key = format!("{}#{}x{}@{fp:016x}", req.block.name, req.block.c, req.block.k);
    let (serving, fresh) = cache.get_or_map(&key, metrics, || {
        let outcome = map_unit(MapUnit::Single(&req.block), cgra, opts)?;
        Ok(ServingMapping { outcome, bundle: None })
    })?;
    let res = simulate(&serving.outcome.mapping, &req.block, cgra, &req.xs)?;
    Ok((res.outputs, res.cycles, serving.outcome.mapping.ii, fresh, 1))
}

/// Map (or fetch from cache) a registered bundle's shared fused mapping.
/// A mapping error here means the bundle cannot map on this fabric at
/// all — the caller falls back to solo serving; request-specific errors
/// never originate here.
fn fused_serving(
    bundle: &Arc<FusedBundle>,
    cache: &MappingCache,
    metrics: &Metrics,
    opts: &MapperOptions,
    cgra: &StreamingCgra,
) -> Result<(Arc<ServingMapping>, bool)> {
    let key = format!("{}@bundle:{:016x}", bundle.name, bundle.fingerprint());
    cache.get_or_map(&key, metrics, || {
        // A bundle's combined MII sits far above the members' own MIIs and
        // the slot-offset composition needs II headroom: widen the slack
        // to the fused operating point unless the config is already wider.
        let mut bopts = opts.clone();
        bopts.ii_slack = bopts.ii_slack.max(MapperOptions::fused().ii_slack);
        let outcome = map_unit(MapUnit::Bundle(bundle), cgra, &bopts)?;
        Ok(ServingMapping { outcome, bundle: Some(Arc::clone(bundle)) })
    })
}

/// Drop `bundle`'s member routes from the registry, pointer-compared so a
/// newer bundle that re-claimed a member fingerprint is left alone.
/// Idempotent — the mapper is deterministic, so every worker that sees
/// the bundle fail converges on the same deregistered state.
fn deregister_bundle(bundles: &BundleRegistry, bundle: &Arc<FusedBundle>) {
    let mut reg = bundles.lock().expect("bundle registry");
    for b in &bundle.blocks {
        if reg.get(&b.mask_fingerprint()).is_some_and(|r| Arc::ptr_eq(r, bundle)) {
            reg.remove(&b.mask_fingerprint());
        }
    }
}

/// Serve a member request through its bundle's shared fused mapping: the
/// whole bundle maps once (cache keyed by the combined mask fingerprint);
/// the member's stream runs with zero inputs on the co-resident blocks and
/// the member's output plane is returned.
fn run_fused(
    req: &InferRequest,
    fp: u64,
    serving: &ServingMapping,
    fresh: bool,
    cgra: &StreamingCgra,
) -> Result<(Vec<Vec<f32>>, u64, usize, bool, usize)> {
    let resident = serving.bundle.as_ref().expect("fused entry carries its bundle");
    let member = resident
        .member_index_of(fp)
        .expect("registry routes only to bundles holding the member");
    let n_iters = req.xs.len();
    // The member's weights come from the request (same mask structure —
    // that is what the fingerprint matched); co-residents stream zeros.
    let blocks: Vec<&SparseBlock> = resident
        .blocks
        .iter()
        .enumerate()
        .map(|(i, b)| if i == member { req.block.as_ref() } else { b.as_ref() })
        .collect();
    let zeros: Vec<Vec<Vec<f32>>> = resident
        .blocks
        .iter()
        .enumerate()
        .map(|(i, b)| {
            if i == member {
                Vec::new()
            } else {
                vec![vec![0.0; b.c]; n_iters]
            }
        })
        .collect();
    let xs: Vec<&[Vec<f32>]> = zeros
        .iter()
        .enumerate()
        .map(|(i, z)| if i == member { req.xs.as_slice() } else { z.as_slice() })
        .collect();
    let res =
        simulate_fused(&serving.outcome.mapping, &serving.outcome.tags, &blocks, cgra, &xs)?;
    let outputs = res
        .per_block
        .into_iter()
        .nth(member)
        .expect("member output plane")
        .outputs;
    Ok((outputs, res.cycles, serving.outcome.mapping.ii, fresh, resident.blocks.len()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen::paper_blocks;

    fn small_cfg() -> SparsemapConfig {
        let mut cfg = SparsemapConfig::default();
        cfg.workers = 2;
        cfg.queue_depth = 4;
        cfg.mis_iterations = 20_000;
        cfg
    }

    fn stream_for(block: &SparseBlock, n: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = crate::util::rng::Pcg64::seeded(seed);
        (0..n)
            .map(|_| (0..block.c).map(|_| rng.next_normal() as f32).collect())
            .collect()
    }

    #[test]
    fn processes_jobs_and_caches_mappings() {
        let cfg = small_cfg();
        let coord = Coordinator::new(&cfg);
        let block = Arc::new(paper_blocks()[1].block.clone());
        for id in 0..6 {
            let xs = stream_for(&block, 8, id);
            coord
                .submit(InferRequest { id, block: Arc::clone(&block), xs })
                .unwrap();
        }
        let results = coord.collect(6);
        assert_eq!(results.len(), 6);
        for r in &results {
            let r = r.as_ref().expect("job ok");
            assert_eq!(r.outputs.len(), 8);
        }
        let m = coord.metrics.snapshot();
        assert_eq!(m.jobs, 6);
        assert_eq!(m.failures, 0);
        assert_eq!(m.cache_misses, 1, "one block → one mapping");
        assert_eq!(m.cache_hits, 5);
    }

    #[test]
    fn outputs_match_reference_forward() {
        let cfg = small_cfg();
        let coord = Coordinator::new(&cfg);
        let block = Arc::new(paper_blocks()[2].block.clone());
        let xs = stream_for(&block, 12, 9);
        coord
            .submit(InferRequest { id: 0, block: Arc::clone(&block), xs: xs.clone() })
            .unwrap();
        let r = coord.collect(1).pop().unwrap().unwrap();
        for (x, y) in xs.iter().zip(&r.outputs) {
            let want = block.forward(x);
            for (a, b) in y.iter().zip(&want) {
                assert!((a - b).abs() < 1e-4 * (1.0 + b.abs()), "{a} vs {b}");
            }
        }
    }

    #[test]
    fn same_shape_different_masks_do_not_share_mappings() {
        // Regression: the cache used to key by name#CxK only, so two blocks
        // with equal name and shape but different sparsity patterns shared
        // one mapping and returned wrong outputs for the second.
        let cfg = small_cfg();
        let coord = Coordinator::new(&cfg);
        let a = Arc::new(
            SparseBlock::from_mask(
                "twin",
                3,
                3,
                vec![true, true, false, false, true, true, true, false, true],
            )
            .unwrap(),
        );
        let b = Arc::new(
            SparseBlock::from_mask(
                "twin",
                3,
                3,
                vec![true, false, true, true, true, false, false, true, true],
            )
            .unwrap(),
        );
        let xs = stream_for(&a, 6, 3);
        coord.submit(InferRequest { id: 0, block: Arc::clone(&a), xs: xs.clone() }).unwrap();
        coord.submit(InferRequest { id: 1, block: Arc::clone(&b), xs: xs.clone() }).unwrap();
        let results = coord.collect(2);
        assert_eq!(coord.metrics.snapshot().cache_misses, 2, "one mapping per mask");
        for r in results {
            let r = r.expect("job ok");
            let block = if r.id == 0 { &a } else { &b };
            for (x, y) in xs.iter().zip(&r.outputs) {
                let want = block.forward(x);
                for (got, w) in y.iter().zip(&want) {
                    assert!(
                        (got - w).abs() < 1e-4 * (1.0 + w.abs()),
                        "id {}: {got} vs {w}",
                        r.id
                    );
                }
            }
        }
    }

    #[test]
    fn collect_returns_errors_when_workers_gone() {
        let cfg = small_cfg();
        let mut coord = Coordinator::new(&cfg);
        // Shut the pool down out from under collect(): close the queue and
        // join every worker, exactly the state a panicked pool leaves.
        coord.tx.take();
        for w in coord.workers.drain(..) {
            w.join().unwrap();
        }
        let results = coord.collect(3);
        assert_eq!(results.len(), 3);
        for r in results {
            match r {
                Err(Error::Runtime(msg)) => assert!(msg.contains("worker pool"), "{msg}"),
                other => panic!("expected Runtime error, got {other:?}"),
            }
        }
    }

    fn tiny(name: &str, c: usize, k: usize, mask: Vec<bool>) -> Arc<SparseBlock> {
        Arc::new(SparseBlock::from_mask(name, c, k, mask).unwrap())
    }

    fn tiny_members() -> Vec<Arc<SparseBlock>> {
        vec![
            tiny("f1", 2, 2, vec![true, false, true, true]),
            tiny("f2", 3, 2, vec![true, true, false, true, true, false]),
            tiny("f3", 2, 3, vec![true, false, true, false, true, true]),
        ]
    }

    #[test]
    fn fused_bundle_serves_member_requests_through_one_mapping() {
        let cfg = small_cfg();
        let coord = Coordinator::new(&cfg);
        let members = tiny_members();
        let bundle = Arc::new(FusedBundle::new(members.clone()).unwrap());
        coord.register_bundle(Arc::clone(&bundle));

        let mut id = 0u64;
        let mut streams = Vec::new();
        for member in &members {
            let xs = stream_for(member, 5, 100 + id);
            coord
                .submit(InferRequest { id, block: Arc::clone(member), xs: xs.clone() })
                .unwrap();
            streams.push(xs);
            id += 1;
        }
        let results = coord.collect(id as usize);
        for r in results {
            let r = r.expect("fused job ok");
            let member = &members[r.id as usize];
            assert_eq!(r.block_name, member.name);
            assert_eq!(r.fused_members, 3, "served through the bundle");
            for (x, y) in streams[r.id as usize].iter().zip(&r.outputs) {
                let want = member.forward(x);
                assert_eq!(y.len(), want.len());
                for (a, w) in y.iter().zip(&want) {
                    assert!((a - w).abs() < 1e-4 * (1.0 + w.abs()), "{}: {a} vs {w}", r.id);
                }
            }
        }
        let m = coord.metrics.snapshot();
        assert_eq!(m.jobs, 3);
        assert_eq!(m.failures, 0);
        assert_eq!(m.cache_misses, 1, "three member blocks → one fused mapping");
        assert_eq!(m.cache_hits, 2);
    }

    #[test]
    fn mixed_fused_and_unfused_traffic() {
        let cfg = small_cfg();
        let coord = Coordinator::new(&cfg);
        let members = tiny_members();
        let bundle = Arc::new(FusedBundle::new(members[..2].to_vec()).unwrap());
        coord.register_bundle(bundle);
        let solo = Arc::clone(&members[2]); // unregistered → serves solo

        let mut streams = Vec::new();
        for (id, block) in members.iter().enumerate() {
            let xs = stream_for(block, 4, 7 + id as u64);
            coord
                .submit(InferRequest { id: id as u64, block: Arc::clone(block), xs: xs.clone() })
                .unwrap();
            streams.push(xs);
        }
        let results = coord.collect(3);
        for r in results {
            let r = r.expect("mixed job ok");
            let member = &members[r.id as usize];
            let want_members = if r.id < 2 { 2 } else { 1 };
            assert_eq!(r.fused_members, want_members, "{}", member.name);
            for (x, y) in streams[r.id as usize].iter().zip(&r.outputs) {
                let want = member.forward(x);
                for (a, w) in y.iter().zip(&want) {
                    assert!((a - w).abs() < 1e-4 * (1.0 + w.abs()), "{}: {a} vs {w}", r.id);
                }
            }
        }
        let m = coord.metrics.snapshot();
        assert_eq!(m.cache_misses, 2, "one fused + one solo mapping");
        assert_eq!(solo.name, "f3");
    }

    #[test]
    fn lru_evicts_least_recently_used_mapping() {
        // Serialized single-worker traffic so the use order is exact:
        // A, B fill a capacity-2 cache; touching A makes B the LRU victim
        // when C arrives; B then re-maps on its next request.
        let mut cfg = small_cfg();
        cfg.workers = 1;
        cfg.cache_capacity = 2;
        let coord = Coordinator::new(&cfg);
        let blocks = tiny_members(); // a, b, c stand-ins
        let mut id = 0u64;
        let mut run = |bi: usize| -> InferResult {
            let block = &blocks[bi];
            let xs = stream_for(block, 2, id);
            coord.submit(InferRequest { id, block: Arc::clone(block), xs }).unwrap();
            id += 1;
            coord.collect(1).pop().unwrap().expect("job ok")
        };
        assert!(run(0).mapped_fresh); // A miss
        assert!(run(1).mapped_fresh); // B miss
        assert!(!run(0).mapped_fresh); // A hit (bumps A)
        assert!(run(2).mapped_fresh); // C miss → evicts B (LRU)
        assert!(!run(0).mapped_fresh); // A survived
        assert!(run(1).mapped_fresh, "B was evicted and must re-map");
        let m = coord.metrics.snapshot();
        assert_eq!(m.cache_misses, 4);
        assert_eq!(m.cache_hits, 2);
    }

    #[test]
    fn concurrent_cold_start_maps_once() {
        // Many concurrent requests for one cold block: single-flight must
        // map exactly once while waiters sleep on the entry's condvar
        // (not on the cache map), then share the result.
        let mut cfg = small_cfg();
        cfg.workers = 4;
        cfg.queue_depth = 8;
        let coord = Coordinator::new(&cfg);
        let block = Arc::new(paper_blocks()[0].block.clone());
        for id in 0..8u64 {
            let xs = stream_for(&block, 4, id);
            coord.submit(InferRequest { id, block: Arc::clone(&block), xs }).unwrap();
        }
        let results = coord.collect(8);
        assert!(results.iter().all(|r| r.is_ok()));
        let m = coord.metrics.snapshot();
        assert_eq!(m.cache_misses, 1, "one mapping for 8 concurrent requests");
        assert_eq!(m.cache_hits, 7);
    }

    #[test]
    fn failed_build_leaves_no_dead_cache_entry() {
        // A failed (deterministically re-failing) mapping must not leave a
        // permanent Empty entry behind: Empty entries are not LRU victims,
        // so a dead one would pin cache_capacity forever.
        let cache = MappingCache::new(1);
        let metrics = Metrics::default();
        let err = cache.get_or_map("dead", &metrics, || {
            Err(Error::Workload("unmappable".into()))
        });
        assert!(err.is_err());
        assert_eq!(
            cache.inner.lock().unwrap().len(),
            0,
            "failed build must remove its cache entry"
        );
        // The capacity-1 cache is free again: a successful build for the
        // same key caches normally and subsequent requests hit.
        let block = tiny("cachetest", 2, 2, vec![true, false, true, true]);
        let cgra = StreamingCgra::paper_default();
        let opts = MapperOptions::sparsemap();
        let build = || {
            let outcome = map_unit(MapUnit::Single(&block), &cgra, &opts)?;
            Ok(ServingMapping { outcome, bundle: None })
        };
        let (_, fresh) = cache.get_or_map("dead", &metrics, build).unwrap();
        assert!(fresh);
        let (_, fresh) =
            cache.get_or_map("dead", &metrics, || unreachable!("second request must hit")).unwrap();
        assert!(!fresh);
        assert_eq!(cache.inner.lock().unwrap().len(), 1);
    }

    #[test]
    fn deregister_bundle_removes_only_its_own_routes() {
        // The unmappable-bundle fallback must not clobber routes a newer
        // bundle has re-claimed for a shared member (latest wins).
        let reg: BundleRegistry = Arc::new(Mutex::new(HashMap::new()));
        let members = tiny_members();
        let b1 = Arc::new(FusedBundle::new(members[..2].to_vec()).unwrap());
        let b2 = Arc::new(FusedBundle::new(members[1..].to_vec()).unwrap());
        {
            let mut r = reg.lock().unwrap();
            for b in &b1.blocks {
                r.insert(b.mask_fingerprint(), Arc::clone(&b1));
            }
            for b in &b2.blocks {
                r.insert(b.mask_fingerprint(), Arc::clone(&b2));
            }
        }
        deregister_bundle(&reg, &b1);
        let r = reg.lock().unwrap();
        assert!(
            !r.contains_key(&members[0].mask_fingerprint()),
            "b1's exclusive route is removed"
        );
        assert!(
            r.get(&members[1].mask_fingerprint()).is_some_and(|x| Arc::ptr_eq(x, &b2)),
            "the shared member stays routed to the newer bundle"
        );
        assert!(r.contains_key(&members[2].mask_fingerprint()));
        // Idempotent.
        drop(r);
        deregister_bundle(&reg, &b1);
        assert_eq!(reg.lock().unwrap().len(), 2);
    }

    #[test]
    fn register_fused_plans_with_configured_knobs() {
        let mut cfg = small_cfg();
        cfg.max_fused_blocks = 2;
        cfg.fusion_max_ii = 12;
        let coord = Coordinator::new(&cfg);
        let members = tiny_members();
        let plan = coord.register_fused(&members);
        assert!(plan.iter().all(|b| b.len() <= 2));
        assert_eq!(plan.iter().map(|b| b.len()).sum::<usize>(), members.len());
        // First planned pair is registered: a member request serves fused.
        let first = &plan[0];
        assert!(first.len() == 2, "tiny blocks must pack in pairs");
        let member = Arc::clone(&first.blocks[0]);
        let xs = stream_for(&member, 2, 3);
        coord.submit(InferRequest { id: 0, block: member, xs }).unwrap();
        let r = coord.collect(1).pop().unwrap().expect("fused job ok");
        assert_eq!(r.fused_members, 2);
    }

    #[test]
    fn multiple_blocks_in_flight() {
        let cfg = small_cfg();
        let coord = Coordinator::new(&cfg);
        let blocks: Vec<Arc<SparseBlock>> = paper_blocks()
            .into_iter()
            .take(3)
            .map(|nb| Arc::new(nb.block))
            .collect();
        let mut id = 0;
        for block in &blocks {
            for _ in 0..2 {
                let xs = stream_for(block, 4, id);
                coord.submit(InferRequest { id, block: Arc::clone(block), xs }).unwrap();
                id += 1;
            }
        }
        let results = coord.collect(id as usize);
        assert!(results.iter().all(|r| r.is_ok()));
        let m = coord.metrics.snapshot();
        assert_eq!(m.cache_misses, 3);
    }
}
