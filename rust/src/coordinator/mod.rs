//! Streaming inference coordinator (L3 runtime).
//!
//! Owns the request path of the system: a typed **session API** over a
//! global dispatch layer that forms batching windows *across sessions*,
//! a **sharded** worker tier — `[coordinator] shards` independent fabric
//! pools, each with its own bounded job queue, mapping cache, supervisor
//! and poison quarantine — and aggregate metrics. The PJRT cross-check
//! (`crate::runtime`) runs on the caller's thread — XLA executables stay
//! off the worker pools.
//!
//! The module is layered into submodules:
//!
//! - [`window`] — tickets, batching windows, and the global
//!   [`DispatchState`] every enqueue funnels through;
//! - [`queue`] — the bounded per-shard job queue and job envelopes;
//! - [`shard`] — shard assignment (deterministic, capacity-constrained
//!   over estimated PE/bus demand) and the warm-start manifest;
//! - [`pool`] — the mapping cache, worker loops and supervision (one
//!   pool instance per shard);
//! - [`metrics`] — global counters, latency percentiles and per-shard
//!   counter blocks.
//!
//! ## Sessions and tickets
//!
//! [`Coordinator::session`] opens a [`ServeSession`];
//! [`ServeSession::enqueue`] hands in one request (a block plus its
//! iteration-major input vectors) and returns a [`Ticket`] — the handle
//! the result is retrieved by ([`Ticket::wait`] / [`Ticket::try_wait`]),
//! in any order, independent of completion order. Per-request failures
//! come back as a structured [`ServeError`] (queue closed / mapping
//! failed / simulator fault / worker gone) instead of a stringly runtime
//! error. The pre-session `submit`/`collect` fire-hose survives one
//! release as `#[deprecated]` thin wrappers over an internal session.
//!
//! ## Cross-session batching windows
//!
//! Requests targeting members of the same registered [`FusedBundle`]
//! aggregate into a **batching window**. Windows form in the
//! coordinator-global dispatch state, so requests from *different*
//! sessions share windows — the millions-of-users shape (many short
//! sessions, few requests each) shares lockstep passes it never could
//! when each session formed its own windows. A window seals once it
//! holds `[coordinator] batch_window_requests` requests (or its lockstep
//! iteration count reaches `[coordinator] batch_window_max`), when
//! `[coordinator] dispatch_lookahead` total riding requests force the
//! oldest open window shut, on [`ServeSession::flush`] /
//! [`ServeSession::drain`], or when a member ticket is waited on — and
//! the whole window is dispatched as ONE job running ONE lockstep
//! simulation pass ([`crate::sim::simulate_fused_batch`]) with a real
//! iteration stream per member (zero inputs only for members absent from
//! the window). The window is charged for the resident configuration
//! once: `Metrics::total_cycles` grows by the pass total, the `windows`
//! counter by one, and each request's `InferResult::cycles` is its
//! proportional share of the pass. Window contents are a pure function
//! of the global enqueue/cancel sequence (plus the knobs), so serving is
//! deterministic — bit-identical — at any worker count and any shard
//! count.
//!
//! ## Sharded serving
//!
//! The worker tier is partitioned into `[coordinator] shards` pools
//! (env override [`SHARDS_ENV`], warn-and-keep like
//! `SPARSEMAP_SIM_BACKEND`). Registered blocks and bundles are pinned to
//! shards by a deterministic greedy assigner that admits each unit to
//! the shard whose post-admission MII over accumulated PE/bus demand
//! stays lowest (registration order decides — never timing);
//! unregistered ad-hoc traffic hashes its mask fingerprint onto a shard.
//! Each shard owns its mapping cache, bounded queue, worker pool,
//! supervisor (restart budget and poison registry scoped per pool) and
//! admission watermark, so a dying or overloaded fabric pool never takes
//! its siblings down — and per-shard counters make the imbalance
//! observable ([`MetricsSnapshot::shards`]).
//!
//! ## Mapping cache
//!
//! Each shard's cache is single-flight and LRU-bounded: one entry per
//! mapping key, the first requester builds (maps) while concurrent
//! requesters for the same key sleep on the entry's `Condvar` — the
//! cache's outer mutex is never held across a mapping, so unrelated
//! blocks proceed in parallel and waiters block on nothing but their own
//! entry. Capacity comes from `[coordinator] cache_capacity` (`0` =
//! unbounded); at capacity the least-recently-used entry is evicted
//! through a tick-ordered `BTreeMap` index maintained on the touch path
//! (no full-map scans; in-flight holders keep their `Arc`).
//!
//! ## Warm start
//!
//! With `[coordinator] warm_start_path` set, every
//! [`Coordinator::register_block`] / [`Coordinator::register_bundle`]
//! persists the registered fingerprints to an on-disk manifest, and
//! construction replays it: registrations (and therefore shard
//! assignments) are restored in file order and mappings are pre-built
//! through the normal single-flight cache path before the first request
//! lands. Mapping cache entries depend only on mask structure — weights
//! arrive per-request — so a warm-started mapping is serving-identical
//! to a cold-built one. A missing or corrupt manifest degrades to a cold
//! start, never a failed constructor.
//!
//! ## Multi-block fusion
//!
//! Small blocks can be registered as a [`FusedBundle`]
//! ([`Coordinator::register_bundle`] / [`Coordinator::register_fused`]):
//! a request for *any* member block routes — at enqueue time, through
//! [`BundleRoutes`] — into the bundle's batching window and is served by
//! the bundle's shared fused mapping (one cache entry keyed by the
//! bundle's combined mask fingerprint). Unregistered blocks serve solo
//! through the same cache, so fused and unfused traffic mix freely.
//!
//! ## Failure model
//!
//! The serving tier treats failure as a first-class input (CGRA mapping
//! attempts *can* fail; workers *can* die): job execution runs under a
//! per-job `catch_unwind` with in-place retry, a supervisor thread per
//! shard respawns hard-dead workers up to `[coordinator] restart_budget`,
//! and a job identity that keeps panicking is quarantined after
//! `[coordinator] poison_threshold` attempts (its tickets resolve
//! [`ServeError::Poisoned`]). Requests carry optional deadlines
//! ([`ServeSession::enqueue_with_deadline`]) checked at worker pickup —
//! expired work is shed as [`ServeError::DeadlineExceeded`] without
//! simulating — and dropping an unwaited [`Ticket`] withdraws its request
//! from a still-forming window. [`ServeSession::try_enqueue`] sheds
//! instead of blocking ([`ServeError::Overloaded`]) when the target
//! shard's queue is full or above `[coordinator] shed_watermark`. Failed
//! mapping-cache entries retry after `[coordinator] failure_ttl` further
//! requests (`0` = sticky forever). If a whole shard pool dies with
//! budget exhausted, its supervisor drains that shard's queue resolving
//! every ticket [`ServeError::WorkerGone`] while sibling shards keep
//! serving — the invariant throughout is that *every enqueued ticket
//! resolves*. All of it is exercised deterministically by
//! `util::failpoint` sites (`coordinator::serve` / `worker_hard` / `map`
//! / `sim` / `delay` / `plan`) under the `failpoints` feature
//! (`tests/fault_tolerance.rs`, `tests/sharded_serving.rs`).
//!
//! tokio is unavailable offline; the pools are built on std threads +
//! `std::sync::mpsc::sync_channel`, which gives exactly the bounded-queue
//! semantics the backpressure design needs. A batching window occupies a
//! single queue slot however many requests it carries.

mod metrics;
pub mod network;
mod pool;
mod queue;
mod shard;
mod window;

pub use metrics::{Metrics, MetricsSnapshot, ShardSnapshot};
pub use network::{LayerMetrics, NetworkResult, NetworkTicket, ServingNetwork};
pub use shard::SHARDS_ENV;
pub use window::{BatchOptions, Ticket};

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, sync_channel, TrySendError};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::arch::StreamingCgra;
use crate::config::{SimBackend, SparsemapConfig};
use crate::error::{Error, Result};
use crate::mapper::MapperOptions;
use crate::sparse::fuse::{plan_bundles, BundleRoutes, FusedBundle, FusionOptions};
use crate::sparse::SparseBlock;

use metrics::ShardMetrics;
use pool::{spawn_worker, supervisor_loop, MappingCache, PoisonRegistry, WorkerCtx};
use queue::{resolve_queue_closed, Job, JobQueue, SingleJob};
use shard::{ManifestUnit, Shard, ShardAssigner};
use window::{DispatchState, TicketCompleter, TicketState, WindowHandle, WindowRequest};

#[cfg(test)]
use crate::mapper::{map_unit, MapUnit};
#[cfg(test)]
use pool::ServingMapping;

/// One inference job: run `xs` (iteration-major input vectors) through a
/// sparse block on the CGRA. Legacy envelope of the deprecated
/// `submit`/`collect` path — the session API takes the block and inputs
/// directly and allocates ids itself.
pub struct InferRequest {
    pub id: u64,
    pub block: Arc<SparseBlock>,
    pub xs: Vec<Vec<f32>>,
}

/// The coordinator's answer.
#[derive(Clone, Debug)]
pub struct InferResult {
    /// Request id: the session-scoped enqueue sequence number (or the
    /// caller-chosen id on the deprecated `submit` path).
    pub id: u64,
    pub block_name: String,
    /// CGRA cycles this request is charged for. A request served through a
    /// batching window is charged its proportional share of the window's
    /// single pass — the shares of a window sum exactly to the pass total.
    pub cycles: u64,
    pub outputs: Vec<Vec<f32>>,
    /// II of the mapping used.
    pub ii: usize,
    /// Caching operations (COPs) of the mapping that served this request —
    /// a member request carries its own member's count, not the window's.
    pub cops: usize,
    /// Multi-cycle internal dependencies (MCIDs) routed through GRF/LRF in
    /// the mapping that served this request.
    pub mcids: usize,
    /// Whether this job triggered a fresh mapping (cache miss). In a
    /// batching window, the window's first request carries the flag.
    pub mapped_fresh: bool,
    /// Member blocks resident in the configuration that served this
    /// request (`1` = unfused).
    pub fused_members: usize,
    /// End-to-end latency in nanoseconds, from enqueue to resolution:
    /// `queue_ns + service_ns`. Per-ticket — batched members share the
    /// window's service span but each carries its own queueing span.
    pub latency_ns: u64,
    /// Nanoseconds from enqueue to worker pickup: queue residency plus any
    /// time spent riding an open batching window.
    pub queue_ns: u64,
    /// Worker-side nanoseconds (mapping-cache fetch + simulation). Window
    /// members share their window's single pass, so they share this value.
    pub service_ns: u64,
}

/// Structured per-request serving failure, delivered through [`Ticket`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ServeError {
    /// The job queue closed (worker pool shut down) before the request
    /// could be dispatched or delivered.
    QueueClosed,
    /// Mapping the request's block — or its bundle's shared fused mapping
    /// with no solo fallback left — failed. Carries the mapper's reason;
    /// concurrent requests for the same key fail fast on the cache's
    /// sticky error without re-running the deterministic mapping.
    MappingFailed(String),
    /// The simulator faulted while serving the request (a mapping-stack
    /// bug detector firing, or malformed request inputs).
    Sim(String),
    /// The worker pool dropped the request without completing it (worker
    /// panic or teardown mid-flight).
    WorkerGone,
    /// The request's deadline passed before a worker began serving it: it
    /// was shed at pickup without simulating. A deadline never interrupts
    /// a request already being served.
    DeadlineExceeded,
    /// The request targets a quarantined "poison" job: executing that
    /// block (or its bundle) has panicked `[coordinator] poison_threshold`
    /// times, so the pool refuses to retry it.
    Poisoned,
    /// Admission control shed the request: `try_enqueue` found the bounded
    /// queue full, or its occupancy at/above `[coordinator]
    /// shed_watermark`. The blocking `enqueue` never returns this.
    Overloaded,
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::QueueClosed => {
                write!(f, "serving queue closed before the request was dispatched")
            }
            ServeError::MappingFailed(msg) => write!(f, "mapping failed: {msg}"),
            ServeError::Sim(msg) => write!(f, "simulation failed: {msg}"),
            ServeError::WorkerGone => {
                write!(f, "worker pool dropped the request without completing it")
            }
            ServeError::DeadlineExceeded => {
                write!(f, "deadline passed before a worker picked the request up")
            }
            ServeError::Poisoned => {
                write!(f, "request targets a quarantined poison job (repeated worker panics)")
            }
            ServeError::Overloaded => {
                write!(f, "request shed by admission control (queue over watermark)")
            }
        }
    }
}

impl std::error::Error for ServeError {}

impl From<ServeError> for Error {
    /// The deprecated `collect` shim (and other legacy surfaces) report
    /// serve errors the way the old API did: as stringly runtime errors.
    fn from(e: ServeError) -> Self {
        Error::Runtime(e.to_string())
    }
}

// ---------------------------------------------------------------------------
// Sessions

/// Session bookkeeping shared by [`ServeSession`] and the deprecated
/// `submit`/`collect` shims: id allocation plus the windows this
/// session's requests have joined, in join order. Windows themselves form
/// in the coordinator-global [`DispatchState`]; the session only
/// remembers which ones carry its requests so `flush`/`drain`/drop can
/// seal them — in join order, keeping flush-driven window formation a
/// pure function of the global enqueue sequence.
struct SessionCore {
    next_id: u64,
    /// Windows joined by this session's in-flight requests, keyed by
    /// bundle fingerprint (small linear list; entries are deduplicated by
    /// cell identity and pruned of sealed windows amortized).
    joined: Vec<(u64, WindowHandle)>,
}

impl SessionCore {
    fn new() -> Self {
        SessionCore { next_id: 0, joined: Vec::new() }
    }

    fn enqueue(
        &mut self,
        coord: &Coordinator,
        id: u64,
        block: Arc<SparseBlock>,
        xs: Vec<Vec<f32>>,
        deadline: Option<Instant>,
    ) -> Ticket {
        let uid = coord.next_uid.fetch_add(1, Ordering::Relaxed);
        let state = TicketState::new();
        let done = TicketCompleter { state: Arc::clone(&state) };
        let block_name = block.name.clone();
        let enqueued_at = Instant::now();
        let fp = block.mask_fingerprint();
        let window = match coord.bundles.route(fp) {
            None => {
                match coord.sender(coord.shard_for(fp)) {
                    None => done.fulfill(Err(ServeError::QueueClosed)),
                    Some(queue) => {
                        let job = Job::Single(SingleJob {
                            id,
                            block,
                            xs,
                            done,
                            deadline,
                            enqueued_at,
                        });
                        if let Err(job) = queue.send(job) {
                            resolve_queue_closed(job);
                        }
                    }
                }
                None
            }
            Some((bundle, member)) => {
                let bfp = bundle.fingerprint();
                match coord.sender(coord.shard_for(bfp)) {
                    None => {
                        done.fulfill(Err(ServeError::QueueClosed));
                        None
                    }
                    Some(queue) => {
                        let handle = {
                            let mut dispatch = coord.dispatch();
                            dispatch.window_enqueue(
                                &queue,
                                &coord.batching,
                                coord.lookahead,
                                bundle,
                                WindowRequest {
                                    id,
                                    uid,
                                    member,
                                    block,
                                    xs,
                                    done,
                                    deadline,
                                    enqueued_at,
                                },
                            )
                        };
                        self.track_window(bfp, &handle);
                        Some(handle)
                    }
                }
            }
        };
        Ticket { id, uid, block_name, state, window }
    }

    /// Shedding admission for `try_enqueue`: a request for a registered
    /// bundle member always joins its batching window (a window occupies
    /// one queue slot for the whole batch, so members are the cheapest
    /// traffic to admit — "non-bundle singles are shed first"); a solo
    /// request is shed with [`ServeError::Overloaded`] when its shard's
    /// queue occupancy is at/above the watermark or the bounded queue is
    /// full. Sheds count against both the global and the shard's `shed`.
    fn try_enqueue(
        &mut self,
        coord: &Coordinator,
        id: u64,
        block: Arc<SparseBlock>,
        xs: Vec<Vec<f32>>,
        deadline: Option<Instant>,
    ) -> std::result::Result<Ticket, ServeError> {
        let uid = coord.next_uid.fetch_add(1, Ordering::Relaxed);
        let enqueued_at = Instant::now();
        let fp = block.mask_fingerprint();
        if let Some((bundle, member)) = coord.bundles.route(fp) {
            let bfp = bundle.fingerprint();
            let Some(queue) = coord.sender(coord.shard_for(bfp)) else {
                return Err(ServeError::QueueClosed);
            };
            let state = TicketState::new();
            let done = TicketCompleter { state: Arc::clone(&state) };
            let block_name = block.name.clone();
            let handle = {
                let mut dispatch = coord.dispatch();
                dispatch.window_enqueue(
                    &queue,
                    &coord.batching,
                    coord.lookahead,
                    bundle,
                    WindowRequest { id, uid, member, block, xs, done, deadline, enqueued_at },
                )
            };
            self.track_window(bfp, &handle);
            return Ok(Ticket { id, uid, block_name, state, window: Some(handle) });
        }
        let sid = coord.shard_for(fp);
        let Some(queue) = coord.sender(sid) else {
            return Err(ServeError::QueueClosed);
        };
        if coord.shed_watermark > 0 && queue.occupancy() >= coord.shed_watermark {
            coord.metrics.shed.fetch_add(1, Ordering::Relaxed);
            coord.shards[sid].metrics.shed.fetch_add(1, Ordering::Relaxed);
            return Err(ServeError::Overloaded);
        }
        let state = TicketState::new();
        let done = TicketCompleter { state: Arc::clone(&state) };
        let block_name = block.name.clone();
        match queue.try_send(Job::Single(SingleJob { id, block, xs, done, deadline, enqueued_at }))
        {
            Ok(()) => Ok(Ticket { id, uid, block_name, state, window: None }),
            // The rejected job drops here: its completer resolves the
            // (never-issued) ticket state, which dies with it.
            Err(TrySendError::Full(_)) => {
                coord.metrics.shed.fetch_add(1, Ordering::Relaxed);
                coord.shards[sid].metrics.shed.fetch_add(1, Ordering::Relaxed);
                Err(ServeError::Overloaded)
            }
            Err(TrySendError::Disconnected(_)) => Err(ServeError::QueueClosed),
        }
    }

    /// Remember that one of this session's requests rides `handle`, so
    /// `flush_all` can seal it. Deduplicated by cell identity (a session
    /// enqueueing many members of one bundle joins the same cell
    /// repeatedly); sealed windows are pruned amortized before the list
    /// would grow, so bookkeeping stays proportional to *open* windows.
    fn track_window(&mut self, fp: u64, handle: &WindowHandle) {
        if self.joined.iter().any(|(k, h)| *k == fp && Arc::ptr_eq(&h.cell, &handle.cell)) {
            return;
        }
        if self.joined.len() == self.joined.capacity() {
            self.joined.retain(|(_, h)| !h.is_sealed());
        }
        self.joined.push((fp, handle.clone()));
    }

    /// Seal and dispatch every window this session joined, in join order.
    /// (Sealing an already-sealed window is a no-op, so racing another
    /// session's flush of a shared window is harmless.)
    fn flush_all(&mut self) {
        for (_, h) in self.joined.drain(..) {
            h.flush();
        }
    }
}

/// A serving session: the enqueue side of the coordinator's typed API.
/// Dropping the session seals the batching windows its requests joined
/// (requests are never stranded); issued [`Ticket`]s stay valid past the
/// session.
pub struct ServeSession<'a> {
    coord: &'a Coordinator,
    core: SessionCore,
    /// Weak handles to every issued ticket, for `drain`. Weak (the
    /// worker-side completer keeps in-flight states alive, a resolved and
    /// dropped ticket's state dies) and pruned amortized on enqueue, so a
    /// long-lived session's bookkeeping stays proportional to its *live*
    /// tickets, not its lifetime request count.
    issued: Vec<std::sync::Weak<TicketState>>,
}

impl ServeSession<'_> {
    /// Enqueue one request; blocks when the target shard's job queue is
    /// full (backpressure). The returned [`Ticket`] is the result handle.
    ///
    /// A request for a member of a registered bundle joins the bundle's
    /// open batching window — windows form globally, so requests from
    /// other sessions share it; it is dispatched when the window seals
    /// (see the module docs) — at the latest when its ticket is waited on
    /// or the session flushes, drains or drops.
    pub fn enqueue(&mut self, block: Arc<SparseBlock>, xs: Vec<Vec<f32>>) -> Ticket {
        self.enqueue_opt(block, xs, None)
    }

    /// Like [`ServeSession::enqueue`], with a latency budget: if `budget`
    /// elapses before a worker picks the request up, it is shed unserved
    /// and its ticket resolves [`ServeError::DeadlineExceeded`]. A request
    /// already being served is never interrupted — the deadline bounds
    /// queue residency (including time riding an open batching window),
    /// not service. A budget so large the deadline overflows the clock is
    /// treated as no deadline.
    pub fn enqueue_with_deadline(
        &mut self,
        block: Arc<SparseBlock>,
        xs: Vec<Vec<f32>>,
        budget: Duration,
    ) -> Ticket {
        self.enqueue_opt(block, xs, Instant::now().checked_add(budget))
    }

    /// Non-blocking enqueue (admission control): sheds the request with
    /// [`ServeError::Overloaded`] — instead of blocking like `enqueue` —
    /// when the target shard's job queue is full or its occupancy is
    /// at/above `[coordinator] shed_watermark` (`0` disables the
    /// watermark). Requests for registered bundle members are always
    /// admitted into their batching window: a window rides one queue slot
    /// for the whole batch, so solo singles are shed first. A shed
    /// request consumes no ticket id — window formation stays a pure
    /// function of the *admitted* enqueue sequence.
    pub fn try_enqueue(
        &mut self,
        block: Arc<SparseBlock>,
        xs: Vec<Vec<f32>>,
    ) -> std::result::Result<Ticket, ServeError> {
        self.try_enqueue_opt(block, xs, None)
    }

    /// [`ServeSession::try_enqueue`] with a latency budget (see
    /// [`ServeSession::enqueue_with_deadline`]).
    pub fn try_enqueue_with_deadline(
        &mut self,
        block: Arc<SparseBlock>,
        xs: Vec<Vec<f32>>,
        budget: Duration,
    ) -> std::result::Result<Ticket, ServeError> {
        self.try_enqueue_opt(block, xs, Instant::now().checked_add(budget))
    }

    fn enqueue_opt(
        &mut self,
        block: Arc<SparseBlock>,
        xs: Vec<Vec<f32>>,
        deadline: Option<Instant>,
    ) -> Ticket {
        let id = self.core.next_id;
        self.core.next_id += 1;
        let ticket = self.core.enqueue(self.coord, id, block, xs, deadline);
        self.track(&ticket);
        ticket
    }

    fn try_enqueue_opt(
        &mut self,
        block: Arc<SparseBlock>,
        xs: Vec<Vec<f32>>,
        deadline: Option<Instant>,
    ) -> std::result::Result<Ticket, ServeError> {
        let id = self.core.next_id;
        let ticket = self.core.try_enqueue(self.coord, id, block, xs, deadline)?;
        self.core.next_id += 1;
        self.track(&ticket);
        Ok(ticket)
    }

    fn track(&mut self, ticket: &Ticket) {
        if self.issued.len() == self.issued.capacity() {
            // Amortized prune before the Vec would grow: drop bookkeeping
            // for tickets that have resolved and been discarded.
            self.issued.retain(|w| w.strong_count() > 0);
        }
        self.issued.push(Arc::downgrade(&ticket.state));
    }

    /// Seal and dispatch every batching window this session's requests
    /// joined, without waiting. Other sessions' requests riding a shared
    /// window dispatch with it.
    pub fn flush(&mut self) {
        self.core.flush_all();
    }

    /// Seal and dispatch every window this session joined, then block
    /// until every ticket issued by this session has resolved. Results
    /// stay claimable through their tickets.
    pub fn drain(&mut self) {
        self.core.flush_all();
        for state in self.issued.drain(..) {
            // In-flight states are kept alive by the worker-side
            // completer; a dead Weak means the request already resolved
            // and its ticket is gone.
            if let Some(state) = state.upgrade() {
                state.wait_done();
            }
        }
    }
}

impl<'a> ServeSession<'a> {
    /// Run one input through a registered network
    /// ([`Coordinator::register_network`]), layer by layer: each stage's
    /// partitioned blocks are enqueued as ordinary requests (batching
    /// windows form normally within a stage), their outputs assemble into
    /// the stage's activation vector, and that vector streams into the
    /// next stage. The first stage is enqueued before this returns; the
    /// returned [`NetworkTicket`] drives the remaining stages when
    /// waited on and resolves a [`NetworkResult`] with per-layer
    /// cycle/COP/MCID attribution.
    pub fn enqueue_network(
        &self,
        network: &str,
        x: &[f32],
    ) -> Result<NetworkTicket<'a>> {
        let net = self
            .coord
            .network(network)
            .ok_or_else(|| Error::Workload(format!("network '{network}' is not registered")))?;
        if x.len() != net.input_width() {
            return Err(Error::Workload(format!(
                "network '{network}': input has {} channels, first layer expects {}",
                x.len(),
                net.input_width()
            )));
        }
        Ok(NetworkTicket::start(self.coord, net, x))
    }
}

impl Drop for ServeSession<'_> {
    fn drop(&mut self) {
        self.core.flush_all();
    }
}

// ---------------------------------------------------------------------------
// The coordinator

/// Registration state behind one lock: the deterministic shard assigner
/// plus the registered units in registration order (what the warm-start
/// manifest persists — replaying the manifest replays the assignments).
struct Registry {
    assigner: ShardAssigner,
    blocks: Vec<Arc<SparseBlock>>,
    bundles: Vec<Arc<FusedBundle>>,
    networks: Vec<Arc<ServingNetwork>>,
}

/// Legacy `submit`/`collect` shim state: an internal session core plus the
/// submission-order ticket queue `collect` drains.
struct LegacyState {
    core: SessionCore,
    fifo: VecDeque<Ticket>,
}

/// The streaming coordinator.
pub struct Coordinator {
    /// The only strong references to the per-shard job queues: taking the
    /// vector (in [`Coordinator::shutdown`], also run by drop) closes
    /// every queue. Sessions and tickets hold weak refs only, so stray
    /// handles can never keep a pool alive. Behind a mutex so shutdown
    /// works through `&self`.
    tx: Mutex<Option<Vec<Arc<JobQueue>>>>,
    /// One supervision thread per shard (see [`supervisor_loop`]); all
    /// joined on shutdown.
    supervisors: Mutex<Vec<std::thread::JoinHandle<()>>>,
    pub metrics: Arc<Metrics>,
    bundles: Arc<BundleRoutes>,
    fusion: FusionOptions,
    batching: BatchOptions,
    cgra: StreamingCgra,
    shed_watermark: usize,
    /// `[coordinator] dispatch_lookahead`: bound on requests riding open
    /// windows before the oldest is force-sealed (`0` = unbounded).
    lookahead: usize,
    nshards: usize,
    /// Per-shard handles (cache for warm start, counter block), indexed
    /// by shard id.
    shards: Vec<Shard>,
    /// Registered units and their shard assignments.
    registry: Mutex<Registry>,
    /// The global window-forming state every session enqueues through.
    dispatch: Mutex<DispatchState>,
    /// Coordinator-global request uid allocator (windows span sessions,
    /// so session-scoped ids are not unique inside a window).
    next_uid: AtomicU64,
    /// Mapper knobs, retained for warm-start pre-builds (workers carry
    /// their own copy in `WorkerCtx`).
    opts: MapperOptions,
    backend: SimBackend,
    /// Resolved `[coordinator] sim_lanes` (env override applied): lane
    /// width of the compiled backend's vectorized sweep.
    lanes: usize,
    /// `[coordinator] warm_start_path`, `None` when unset.
    warm_start_path: Option<String>,
    legacy: Mutex<LegacyState>,
}

impl Coordinator {
    /// Spawn the sharded worker tier per `cfg`: `effective_shards`
    /// resolves `[coordinator] shards` against the [`SHARDS_ENV`]
    /// override, then each shard gets `cfg.workers` worker threads over a
    /// queue of depth `cfg.queue_depth` (a batching window occupies one
    /// slot), plus a supervisor thread that keeps its pool at strength.
    pub fn new(cfg: &SparsemapConfig) -> Self {
        Self::with_shard_count(cfg, shard::effective_shards(cfg.shards))
    }

    /// Like [`Coordinator::new`] with an explicit shard count, bypassing
    /// both `[coordinator] shards` and the [`SHARDS_ENV`] override.
    /// Benchmarks and tests pin topology with this so an ambient env
    /// override cannot skew a pinned measurement.
    pub fn with_shard_count(cfg: &SparsemapConfig, shards: usize) -> Self {
        let nshards = shards.max(1);
        let bundles = Arc::new(BundleRoutes::new());
        let metrics = Arc::new(Metrics::default());
        let mut opts = MapperOptions::from_config(cfg);
        if opts.parallelism == 0 {
            // Auto portfolio width: split the machine between the worker
            // pools of ALL shards and each worker's mapping portfolio, so
            // a burst of cache misses doesn't oversubscribe cores. The
            // mapping itself is width-independent (deterministic
            // portfolio), so this only shapes latency.
            let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
            opts.parallelism = (cores / (cfg.workers.max(1) * nshards)).clamp(1, 8);
        }
        let fusion = opts.fusion;
        let batching = BatchOptions::from_config(cfg);
        let cgra = cfg.cgra.clone();
        let backend = SimBackend::effective(cfg.sim_backend);
        let lanes = crate::config::effective_sim_lanes(cfg.sim_lanes);

        let mut queues = Vec::with_capacity(nshards);
        let mut shard_list = Vec::with_capacity(nshards);
        let mut supervisors = Vec::with_capacity(nshards);
        for sid in 0..nshards {
            let (tx, rx) = sync_channel::<Job>(cfg.queue_depth);
            let queue_len = Arc::new(AtomicUsize::new(0));
            let queue = Arc::new(JobQueue { tx, len: Arc::clone(&queue_len) });
            let rx = Arc::new(Mutex::new(rx));
            let cache = Arc::new(MappingCache::new(cfg.cache_capacity, cfg.failure_ttl));
            let shard_metrics = Arc::new(ShardMetrics::default());
            let ctx = WorkerCtx {
                rx,
                queue_len,
                cache: Arc::clone(&cache),
                bundles: Arc::clone(&bundles),
                metrics: Arc::clone(&metrics),
                shard: Arc::clone(&shard_metrics),
                shard_id: sid,
                opts: opts.clone(),
                cgra: cgra.clone(),
                poison: Arc::new(PoisonRegistry::new()),
                poison_threshold: cfg.poison_threshold as u32,
                backend,
                lanes,
            };
            let (exit_tx, exit_rx) = channel();
            let handles: Vec<Option<std::thread::JoinHandle<()>>> = (0..cfg.workers)
                .map(|wid| {
                    Some(spawn_worker(wid, ctx.clone(), exit_tx.clone()).expect("spawn worker"))
                })
                .collect();
            let restart_budget = cfg.restart_budget;
            let supervisor = std::thread::Builder::new()
                .name(format!("sparsemap-supervisor-{sid}"))
                .spawn(move || supervisor_loop(exit_rx, exit_tx, handles, ctx, restart_budget))
                .expect("spawn supervisor");
            queues.push(queue);
            shard_list.push(Shard { cache, metrics: shard_metrics });
            supervisors.push(supervisor);
        }
        metrics.attach_shards(shard_list.iter().map(|s| Arc::clone(&s.metrics)).collect());

        let warm_start_path =
            if cfg.warm_start_path.is_empty() { None } else { Some(cfg.warm_start_path.clone()) };
        let coord = Coordinator {
            tx: Mutex::new(Some(queues)),
            supervisors: Mutex::new(supervisors),
            metrics,
            bundles,
            fusion,
            batching,
            cgra,
            shed_watermark: cfg.shed_watermark,
            lookahead: cfg.dispatch_lookahead,
            nshards,
            shards: shard_list,
            registry: Mutex::new(Registry {
                assigner: ShardAssigner::new(nshards),
                blocks: Vec::new(),
                bundles: Vec::new(),
                networks: Vec::new(),
            }),
            dispatch: Mutex::new(DispatchState::new()),
            next_uid: AtomicU64::new(0),
            opts,
            backend,
            lanes,
            warm_start_path,
            legacy: Mutex::new(LegacyState { core: SessionCore::new(), fifo: VecDeque::new() }),
        };
        coord.warm_start();
        coord
    }

    /// Open a serving session: the enqueue side of the ticket API. A
    /// coordinator serves any number of sessions; their requests share
    /// batching windows through the global dispatch state.
    pub fn session(&self) -> ServeSession<'_> {
        ServeSession { coord: self, core: SessionCore::new(), issued: Vec::new() }
    }

    /// Number of worker-pool shards this coordinator runs.
    pub fn shard_count(&self) -> usize {
        self.nshards
    }

    /// The resolved simulation backend workers serve on (config knob
    /// plus `SPARSEMAP_SIM_BACKEND` override, fixed at construction).
    pub fn sim_backend(&self) -> SimBackend {
        self.backend
    }

    /// The resolved `[coordinator] sim_lanes` knob (plus
    /// `SPARSEMAP_SIM_LANES` override): `0` = auto width per window,
    /// `1` = the scalar plan sweep, otherwise a fixed lane width. Only
    /// meaningful on the compiled backend.
    pub fn sim_lanes(&self) -> usize {
        self.lanes
    }

    fn sender(&self, sid: usize) -> Option<Arc<JobQueue>> {
        self.tx
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .as_ref()
            .map(|queues| Arc::clone(&queues[sid]))
    }

    /// The global dispatch state, poison-recovered (window bookkeeping is
    /// plain data; a panicking enqueuer must not wedge every session).
    fn dispatch(&self) -> std::sync::MutexGuard<'_, DispatchState> {
        self.dispatch.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Owning shard for a fingerprint: the assigner's pin for registered
    /// units, a fingerprint hash for ad-hoc traffic. Total — every
    /// request has a home shard.
    fn shard_for(&self, fp: u64) -> usize {
        let reg = self.registry.lock().unwrap_or_else(|p| p.into_inner());
        reg.assigner.shard_of(fp).unwrap_or((fp % self.nshards as u64) as usize)
    }

    /// Tear the worker tier down: seal any open legacy batching windows,
    /// seal and dispatch every window still forming in the global
    /// dispatch state, close every shard's job queue, and join the
    /// supervisors — which join the workers and resolve anything still
    /// queued (`WorkerGone`). Idempotent, and also run by drop. Tickets
    /// issued before shutdown stay valid: every one of them resolves, and
    /// enqueues after shutdown resolve [`ServeError::QueueClosed`]
    /// immediately.
    pub fn shutdown(&self) {
        if let Ok(mut legacy) = self.legacy.lock() {
            legacy.core.flush_all();
        }
        // Flush outside the dispatch lock: flush takes the cell lock and
        // may send on a queue, and the lock order everywhere else is
        // dispatch → cell → queue.
        let open = self.dispatch().drain_open();
        for h in open {
            h.flush();
        }
        self.tx.lock().unwrap_or_else(|p| p.into_inner()).take();
        let sups =
            std::mem::take(&mut *self.supervisors.lock().unwrap_or_else(|p| p.into_inner()));
        for sup in sups {
            let _ = sup.join();
        }
    }

    /// Register a solo block with the serving tier: pins its shard
    /// assignment (deterministic greedy, capacity-constrained over
    /// estimated PE/bus demand) and persists it to the warm-start
    /// manifest when one is configured. Returns the owning shard id.
    /// Registration is optional for solo traffic — an unregistered block
    /// hashes onto a shard — but registered blocks get demand-balanced
    /// placement and warm starts.
    pub fn register_block(&self, block: Arc<SparseBlock>) -> usize {
        self.register_block_at(&block, true)
    }

    /// Register a fused bundle: from now on a request for *any* member
    /// block batches into the bundle's windows and is served through the
    /// bundle's shared fused mapping (one cache entry keyed by the
    /// bundle's combined mask fingerprint) on the bundle's assigned
    /// shard. Requests already served solo keep their solo cache entries
    /// — fused and unfused traffic mix freely.
    pub fn register_bundle(&self, bundle: Arc<FusedBundle>) {
        self.register_bundle_at(&bundle, true);
        self.bundles.register(bundle);
    }

    /// Plan fusion over `blocks` with the configured knobs
    /// (`[mapper] max_fused_blocks` / `[mapper] fusion_max_ii`) and
    /// register every multi-block bundle. Returns the full plan
    /// (singletons included — they stay unregistered and serve solo).
    pub fn register_fused(&self, blocks: &[Arc<SparseBlock>]) -> Vec<FusedBundle> {
        let plan = plan_bundles(blocks, &self.cgra, &self.fusion);
        for bundle in &plan {
            if bundle.len() > 1 {
                self.register_bundle(Arc::new(bundle.clone()));
            }
        }
        plan
    }

    /// Register a whole [`NetworkGraph`] for pipeline serving
    /// ([`ServeSession::enqueue_network`]): every partitioned tile block
    /// is registered (demand-balanced shard pins, warm starts), the
    /// network's tile population is packed into fused bundles by the
    /// fusion planner, and the network itself joins the registry (and the
    /// warm-start manifest, when one is configured) under its name.
    /// Registering an already-registered name returns the existing
    /// serving form unchanged.
    pub fn register_network(&self, graph: crate::model::NetworkGraph) -> Result<Arc<ServingNetwork>> {
        self.register_network_at(Arc::new(graph), true)
    }

    fn register_network_at(
        &self,
        graph: Arc<crate::model::NetworkGraph>,
        persist: bool,
    ) -> Result<Arc<ServingNetwork>> {
        if graph.layers.is_empty() {
            return Err(Error::Workload(format!("network '{}': no layers", graph.name)));
        }
        if let Some(existing) = self.network(&graph.name) {
            return Ok(existing);
        }
        let serving = Arc::new(ServingNetwork::build(&graph));
        let tiles = serving.all_blocks();
        for block in &tiles {
            self.register_block_at(block, false);
        }
        // Pack the network's tile population into resident fused
        // configurations — this is the realistic small-layer population
        // the planner exists for; wide tiles exceed the bundle II cap and
        // stay solo.
        for bundle in plan_bundles(&tiles, &self.cgra, &self.fusion) {
            if bundle.len() > 1 {
                let bundle = Arc::new(bundle);
                self.register_bundle_at(&bundle, false);
                self.bundles.register(bundle);
            }
        }
        let mut reg = self.registry.lock().unwrap_or_else(|p| p.into_inner());
        // Re-check under the lock: a racing registration of the same name
        // wins and this serving form is discarded.
        if let Some(existing) = reg.networks.iter().find(|n| n.name == serving.name) {
            return Ok(Arc::clone(existing));
        }
        reg.networks.push(Arc::clone(&serving));
        if persist {
            self.persist_manifest(&reg);
        }
        Ok(serving)
    }

    /// Look up a registered network by name.
    pub fn network(&self, name: &str) -> Option<Arc<ServingNetwork>> {
        let reg = self.registry.lock().unwrap_or_else(|p| p.into_inner());
        reg.networks.iter().find(|n| n.name == name).map(Arc::clone)
    }

    /// Names of registered networks, in registration order.
    pub fn network_names(&self) -> Vec<String> {
        let reg = self.registry.lock().unwrap_or_else(|p| p.into_inner());
        reg.networks.iter().map(|n| n.name.clone()).collect()
    }

    fn register_block_at(&self, block: &Arc<SparseBlock>, persist: bool) -> usize {
        let fp = block.mask_fingerprint();
        let mut reg = self.registry.lock().unwrap_or_else(|p| p.into_inner());
        if let Some(sid) = reg.assigner.shard_of(fp) {
            return sid;
        }
        let sid = reg.assigner.assign(fp, shard::block_demand(block), &self.cgra);
        reg.blocks.push(Arc::clone(block));
        if persist {
            self.persist_manifest(&reg);
        }
        sid
    }

    fn register_bundle_at(&self, bundle: &Arc<FusedBundle>, persist: bool) -> usize {
        let fp = bundle.fingerprint();
        let mut reg = self.registry.lock().unwrap_or_else(|p| p.into_inner());
        if let Some(sid) = reg.assigner.shard_of(fp) {
            return sid;
        }
        let sid = reg.assigner.assign(fp, shard::bundle_demand(bundle), &self.cgra);
        reg.bundles.push(Arc::clone(bundle));
        if persist {
            self.persist_manifest(&reg);
        }
        sid
    }

    /// Rewrite the warm-start manifest from the registry (registration is
    /// rare and the manifest is small, so wholesale rewrite beats
    /// appending + compaction). A write failure degrades the *next* start
    /// to cold; it never fails the registration.
    fn persist_manifest(&self, reg: &Registry) {
        let Some(path) = &self.warm_start_path else { return };
        let graphs: Vec<Arc<crate::model::NetworkGraph>> =
            reg.networks.iter().map(|n| Arc::clone(&n.graph)).collect();
        if let Err(e) = shard::write_manifest(path, &reg.blocks, &reg.bundles, &graphs) {
            crate::log_warn!("writing warm-start manifest {path} failed: {e}");
        }
    }

    /// Replay the warm-start manifest (if configured and present):
    /// re-register every unit in file order — restoring the shard
    /// assignments — and pre-build its mapping through the normal
    /// single-flight cache path on its owning shard. Mapping cache
    /// entries depend only on mask structure (weights arrive
    /// per-request), so a pre-built mapping is serving-identical to a
    /// cold-built one. A missing or corrupt manifest degrades to a cold
    /// start, never a failed constructor.
    fn warm_start(&self) {
        let Some(path) = self.warm_start_path.clone() else { return };
        if !std::path::Path::new(&path).exists() {
            return;
        }
        let units = match shard::load_manifest(&path) {
            Ok(units) => units,
            Err(e) => {
                crate::log_warn!("reading warm-start manifest {path} failed ({e}); cold start");
                return;
            }
        };
        for unit in units {
            match unit {
                ManifestUnit::Block(block) => {
                    let sid = self.register_block_at(&block, false);
                    let key = pool::solo_cache_key(&block);
                    let built = self.shards[sid].cache.get_or_map(&key, &self.metrics, || {
                        pool::build_solo_mapping(&block, &key, &self.cgra, &self.opts, self.backend)
                    });
                    if let Err(e) = built {
                        crate::log_warn!("warm-start mapping for {key} failed: {e}");
                    }
                }
                ManifestUnit::Bundle(bundle) => {
                    let sid = self.register_bundle_at(&bundle, false);
                    self.bundles.register(Arc::clone(&bundle));
                    let key = pool::bundle_cache_key(&bundle);
                    let built = self.shards[sid].cache.get_or_map(&key, &self.metrics, || {
                        pool::build_bundle_mapping(
                            &bundle,
                            &key,
                            &self.cgra,
                            &self.opts,
                            self.backend,
                        )
                    });
                    if let Err(e) = built {
                        crate::log_warn!("warm-start mapping for {key} failed: {e}");
                    }
                }
                // A network's tile blocks and bundles ride their own
                // manifest lines (written by the same registration), so
                // their shard pins and mappings are already replayed by
                // the arms above — the network unit only restores the
                // registry entry the pipeline driver looks up by name.
                ManifestUnit::Network(graph) => {
                    let graph = Arc::new(graph);
                    let serving = Arc::new(ServingNetwork::build(&graph));
                    let mut reg = self.registry.lock().unwrap_or_else(|p| p.into_inner());
                    if reg.networks.iter().all(|n| n.name != serving.name) {
                        reg.networks.push(serving);
                    }
                }
            }
        }
    }

    /// Submit a job; blocks when the queue is full (backpressure).
    #[deprecated(
        since = "0.2.0",
        note = "use Coordinator::session(): enqueue() returns a Ticket to wait on"
    )]
    pub fn submit(&self, req: InferRequest) -> Result<()> {
        let mut legacy = self.legacy.lock().expect("legacy serve state");
        let ticket = legacy.core.enqueue(self, req.id, req.block, req.xs, None);
        // Preserve the old contract: a queue that is already closed at
        // submission time surfaces here, not only at collect.
        if matches!(ticket.state.peek(), Some(Err(ServeError::QueueClosed))) {
            return Err(Error::Runtime("coordinator shut down".into()));
        }
        legacy.fifo.push_back(ticket);
        Ok(())
    }

    /// Collect exactly `n` results, in submission order (jobs are tagged
    /// by id). Waiting seals any batching window a pending submission sits
    /// in; slots beyond the outstanding submissions come back as
    /// `Err(Error::Runtime)`.
    #[deprecated(
        since = "0.2.0",
        note = "use Coordinator::session(): enqueue() returns a Ticket to wait on"
    )]
    pub fn collect(&self, n: usize) -> Vec<Result<InferResult>> {
        (0..n)
            .map(|_| {
                let ticket =
                    self.legacy.lock().expect("legacy serve state").fifo.pop_front();
                match ticket {
                    Some(t) => t.wait().map_err(Error::from),
                    None => Err(Error::Runtime(
                        "worker pool exited before delivering all results".into(),
                    )),
                }
            })
            .collect()
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.shutdown();
        if let Ok(mut legacy) = self.legacy.lock() {
            legacy.fifo.clear();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen::paper_blocks;

    fn small_cfg() -> SparsemapConfig {
        let mut cfg = SparsemapConfig::default();
        cfg.workers = 2;
        cfg.queue_depth = 4;
        cfg.mis_iterations = 20_000;
        cfg
    }

    fn stream_for(block: &SparseBlock, n: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = crate::util::rng::Pcg64::seeded(seed);
        (0..n)
            .map(|_| (0..block.c).map(|_| rng.next_normal() as f32).collect())
            .collect()
    }

    #[test]
    fn processes_jobs_and_caches_mappings() {
        let cfg = small_cfg();
        let coord = Coordinator::new(&cfg);
        let mut session = coord.session();
        let block = Arc::new(paper_blocks()[1].block.clone());
        let tickets: Vec<Ticket> = (0..6u64)
            .map(|seed| session.enqueue(Arc::clone(&block), stream_for(&block, 8, seed)))
            .collect();
        for (i, t) in tickets.into_iter().enumerate() {
            assert_eq!(t.id(), i as u64);
            assert_eq!(t.block_name(), block.name);
            let r = t.wait().expect("job ok");
            assert_eq!(r.outputs.len(), 8);
            assert_eq!(r.fused_members, 1);
        }
        let m = coord.metrics.snapshot();
        assert_eq!(m.jobs, 6);
        assert_eq!(m.failures, 0);
        assert_eq!(m.cache_misses, 1, "one block → one mapping");
        assert_eq!(m.cache_hits, 5);
        assert_eq!(m.windows, 0, "solo traffic forms no windows");
    }

    #[test]
    fn outputs_match_reference_forward() {
        let cfg = small_cfg();
        let coord = Coordinator::new(&cfg);
        let mut session = coord.session();
        let block = Arc::new(paper_blocks()[2].block.clone());
        let xs = stream_for(&block, 12, 9);
        let r = session.enqueue(Arc::clone(&block), xs.clone()).wait().unwrap();
        for (x, y) in xs.iter().zip(&r.outputs) {
            let want = block.forward(x);
            for (a, b) in y.iter().zip(&want) {
                assert!((a - b).abs() < 1e-4 * (1.0 + b.abs()), "{a} vs {b}");
            }
        }
    }

    #[test]
    fn same_shape_different_masks_do_not_share_mappings() {
        // Regression: the cache used to key by name#CxK only, so two blocks
        // with equal name and shape but different sparsity patterns shared
        // one mapping and returned wrong outputs for the second.
        let cfg = small_cfg();
        let coord = Coordinator::new(&cfg);
        let mut session = coord.session();
        let a = Arc::new(
            SparseBlock::from_mask(
                "twin",
                3,
                3,
                vec![true, true, false, false, true, true, true, false, true],
            )
            .unwrap(),
        );
        let b = Arc::new(
            SparseBlock::from_mask(
                "twin",
                3,
                3,
                vec![true, false, true, true, true, false, false, true, true],
            )
            .unwrap(),
        );
        let xs = stream_for(&a, 6, 3);
        let ta = session.enqueue(Arc::clone(&a), xs.clone());
        let tb = session.enqueue(Arc::clone(&b), xs.clone());
        for (block, ticket) in [(&a, ta), (&b, tb)] {
            let r = ticket.wait().expect("job ok");
            for (x, y) in xs.iter().zip(&r.outputs) {
                let want = block.forward(x);
                for (got, w) in y.iter().zip(&want) {
                    assert!(
                        (got - w).abs() < 1e-4 * (1.0 + w.abs()),
                        "{}: {got} vs {w}",
                        block.name
                    );
                }
            }
        }
        assert_eq!(coord.metrics.snapshot().cache_misses, 2, "one mapping per mask");
    }

    fn tiny(name: &str, c: usize, k: usize, mask: Vec<bool>) -> Arc<SparseBlock> {
        Arc::new(SparseBlock::from_mask(name, c, k, mask).unwrap())
    }

    fn tiny_members() -> Vec<Arc<SparseBlock>> {
        vec![
            tiny("f1", 2, 2, vec![true, false, true, true]),
            tiny("f2", 3, 2, vec![true, true, false, true, true, false]),
            tiny("f3", 2, 3, vec![true, false, true, false, true, true]),
        ]
    }

    #[test]
    fn tickets_resolve_queue_closed_when_pool_is_shut_down() {
        let cfg = small_cfg();
        let coord = Coordinator::new(&cfg);
        // Tear the pool down out from under the session: exactly the
        // state a late enqueue races against.
        coord.shutdown();
        let mut session = coord.session();
        let block = tiny("late", 2, 2, vec![true, false, true, true]);
        let t = session.enqueue(Arc::clone(&block), stream_for(&block, 2, 1));
        match t.wait() {
            Err(ServeError::QueueClosed) => {}
            other => panic!("expected QueueClosed, got {other:?}"),
        }
    }

    #[test]
    fn wait_timeout_expires_then_result_stays_claimable() {
        let state = TicketState::new();
        let done = TicketCompleter { state: Arc::clone(&state) };
        let mut t = Ticket { id: 1, uid: 0, block_name: "x".into(), state, window: None };
        assert!(
            t.wait_timeout(Duration::from_millis(5)).is_none(),
            "pending ticket times out with None"
        );
        done.fulfill(Err(ServeError::QueueClosed));
        assert!(matches!(
            t.wait_timeout(Duration::ZERO),
            Some(Err(ServeError::QueueClosed))
        ));
        // The timed wait clones — the result stays claimable by `wait`.
        assert!(matches!(t.wait(), Err(ServeError::QueueClosed)));
    }

    #[test]
    fn dropping_a_ticket_cancels_its_window_request() {
        // An unwaited ticket dropped while its request still rides an
        // open window withdraws the request: the window serves without
        // it, and abandoned work is never simulated.
        let mut cfg = small_cfg();
        cfg.batch_window_requests = 100; // only an explicit flush seals
        let coord = Coordinator::new(&cfg);
        let members = tiny_members();
        coord.register_bundle(Arc::new(FusedBundle::new(members.clone()).unwrap()));
        let mut session = coord.session();
        let keep = session.enqueue(Arc::clone(&members[0]), stream_for(&members[0], 2, 1));
        let cancel =
            session.enqueue(Arc::clone(&members[1]), stream_for(&members[1], 2, 2));
        drop(cancel);
        session.drain();
        let r = keep.wait().expect("survivor ok");
        assert_eq!(r.fused_members, 3, "still served through the bundle");
        let m = coord.metrics.snapshot();
        assert_eq!(m.jobs, 1, "the cancelled request was never dispatched");
        assert_eq!(m.windows, 1);
    }

    #[test]
    fn zero_deadline_requests_shed_at_pickup() {
        let cfg = small_cfg();
        let coord = Coordinator::new(&cfg);
        let mut session = coord.session();
        let block = tiny("rush", 2, 2, vec![true, false, true, true]);
        let t = session.enqueue_with_deadline(
            Arc::clone(&block),
            stream_for(&block, 2, 1),
            Duration::ZERO,
        );
        match t.wait() {
            Err(ServeError::DeadlineExceeded) => {}
            other => panic!("expected DeadlineExceeded, got {other:?}"),
        }
        let m = coord.metrics.snapshot();
        assert_eq!(m.deadline_expired, 1);
        assert_eq!(m.jobs, 1, "the request was picked up (then shed)");
        assert_eq!(m.failures, 0, "a deadline shed is not a serving fault");
    }

    #[test]
    fn failure_ttl_retries_after_budget() {
        // failure_ttl = 3: after a failed build the entry stays resident;
        // the next two requests fail fast, the third rebuilds in place.
        let cache = MappingCache::new(4, 3);
        let metrics = Metrics::default();
        let err = cache
            .get_or_map("flaky", &metrics, || Err(Error::Workload("transient".into())));
        assert!(err.is_err());
        {
            let inner = cache.inner.lock().unwrap();
            assert_eq!(inner.map.len(), 1, "failed entry stays resident under a TTL");
        }
        for _ in 0..2 {
            match cache.get_or_map("flaky", &metrics, || unreachable!("fail-fast window")) {
                Err(e) => assert!(e.to_string().contains("transient"), "{e}"),
                Ok(_) => panic!("request inside the fail-fast window must error"),
            }
        }
        // TTL exhausted: the next request re-runs the build.
        let block = tiny("flaky", 2, 2, vec![true, false, true, true]);
        let cgra = StreamingCgra::paper_default();
        let opts = MapperOptions::sparsemap();
        let (_, fresh) = cache
            .get_or_map("flaky", &metrics, || {
                let outcome = map_unit(MapUnit::Single(&block), &cgra, &opts)?;
                Ok(ServingMapping { outcome, bundle: None, plan: None })
            })
            .unwrap();
        assert!(fresh, "the post-TTL request rebuilds");
        let (_, fresh) = cache
            .get_or_map("flaky", &metrics, || unreachable!("now cached"))
            .unwrap();
        assert!(!fresh);
    }

    #[test]
    fn dropped_completer_resolves_worker_gone() {
        // A worker that dies mid-job (panic/teardown) drops the completer
        // unfulfilled: the ticket must resolve instead of hanging.
        let state = TicketState::new();
        let done = TicketCompleter { state: Arc::clone(&state) };
        let mut t = Ticket { id: 7, uid: 0, block_name: "x".into(), state, window: None };
        assert!(t.try_wait().is_none(), "pending ticket polls None");
        drop(done);
        assert!(matches!(t.try_wait(), Some(Err(ServeError::WorkerGone))));
        assert!(matches!(t.wait(), Err(ServeError::WorkerGone)));
    }

    #[test]
    fn completion_is_first_wins() {
        let state = TicketState::new();
        let done = TicketCompleter { state: Arc::clone(&state) };
        done.fulfill(Err(ServeError::QueueClosed));
        // The drop guard ran after fulfill and must not overwrite.
        let t = Ticket { id: 0, uid: 0, block_name: "x".into(), state, window: None };
        assert!(matches!(t.wait(), Err(ServeError::QueueClosed)));
    }

    #[test]
    fn fused_bundle_serves_member_requests_through_one_window() {
        let cfg = small_cfg();
        let coord = Coordinator::new(&cfg);
        let members = tiny_members();
        let bundle = Arc::new(FusedBundle::new(members.clone()).unwrap());
        coord.register_bundle(Arc::clone(&bundle));

        let mut session = coord.session();
        let mut tickets = Vec::new();
        let mut streams = Vec::new();
        for (i, member) in members.iter().enumerate() {
            let xs = stream_for(member, 5, 100 + i as u64);
            tickets.push(session.enqueue(Arc::clone(member), xs.clone()));
            streams.push(xs);
        }
        session.drain();
        for (i, t) in tickets.into_iter().enumerate() {
            let r = t.wait().expect("fused job ok");
            let member = &members[i];
            assert_eq!(r.block_name, member.name);
            assert_eq!(r.fused_members, 3, "served through the bundle");
            for (x, y) in streams[i].iter().zip(&r.outputs) {
                let want = member.forward(x);
                assert_eq!(y.len(), want.len());
                for (a, w) in y.iter().zip(&want) {
                    assert!((a - w).abs() < 1e-4 * (1.0 + w.abs()), "{i}: {a} vs {w}");
                }
            }
        }
        let m = coord.metrics.snapshot();
        assert_eq!(m.jobs, 3);
        assert_eq!(m.failures, 0);
        assert_eq!(m.cache_misses, 1, "three member blocks → one fused mapping");
        assert_eq!(m.cache_hits, 2);
        assert_eq!(m.windows, 1, "three member requests → ONE lockstep pass");
    }

    #[test]
    fn mixed_fused_and_unfused_traffic() {
        let cfg = small_cfg();
        let coord = Coordinator::new(&cfg);
        let members = tiny_members();
        let bundle = Arc::new(FusedBundle::new(members[..2].to_vec()).unwrap());
        coord.register_bundle(bundle);

        let mut session = coord.session();
        let mut tickets = Vec::new();
        let mut streams = Vec::new();
        for (i, block) in members.iter().enumerate() {
            let xs = stream_for(block, 4, 7 + i as u64);
            tickets.push(session.enqueue(Arc::clone(block), xs.clone()));
            streams.push(xs);
        }
        session.drain();
        for (i, t) in tickets.into_iter().enumerate() {
            let r = t.wait().expect("mixed job ok");
            let member = &members[i];
            let want_members = if i < 2 { 2 } else { 1 };
            assert_eq!(r.fused_members, want_members, "{}", member.name);
            for (x, y) in streams[i].iter().zip(&r.outputs) {
                let want = member.forward(x);
                for (a, w) in y.iter().zip(&want) {
                    assert!((a - w).abs() < 1e-4 * (1.0 + w.abs()), "{i}: {a} vs {w}");
                }
            }
        }
        let m = coord.metrics.snapshot();
        assert_eq!(m.cache_misses, 2, "one fused + one solo mapping");
        assert_eq!(m.windows, 1, "the two member requests share one window");
    }

    #[test]
    fn windows_form_deterministically_from_enqueue_order() {
        // Window contents are a pure function of the global enqueue order
        // and the knobs — no timing involved.
        let run = |window_requests: usize, window_max: usize, n: usize| -> (u64, u64) {
            let mut cfg = small_cfg();
            cfg.batch_window_requests = window_requests;
            cfg.batch_window_max = window_max;
            let coord = Coordinator::new(&cfg);
            let members = tiny_members();
            coord.register_bundle(Arc::new(FusedBundle::new(members.clone()).unwrap()));
            let mut session = coord.session();
            let tickets: Vec<Ticket> = (0..n)
                .map(|i| {
                    let b = &members[i % members.len()];
                    session.enqueue(Arc::clone(b), stream_for(b, 2, i as u64))
                })
                .collect();
            session.drain();
            for t in tickets {
                t.wait().expect("windowed job ok");
            }
            let m = coord.metrics.snapshot();
            (m.windows, m.jobs)
        };
        // 7 requests at window size 3 → 3 + 3 + 1 (trailing flush).
        assert_eq!(run(3, 0, 7), (3, 7));
        assert_eq!(run(3, 0, 7), (3, 7), "repeat runs form identical windows");
        // Window size 1 disables aggregation: one pass per request.
        assert_eq!(run(1, 0, 5), (5, 5));
        // The iteration cap seals windows too: requests bring 2 iterations
        // each, round-robin over 3 members, so a cap of 4 seals a window
        // every time some member's total reaches 4 — the request-count
        // knob (100) never triggers. 12 requests must split into several
        // windows, identically on every run.
        let first = run(100, 4, 12);
        assert_eq!(first.1, 12);
        assert!(
            first.0 > 1,
            "the iteration cap must split an under-count window (got {})",
            first.0
        );
        assert_eq!(run(100, 4, 12), first, "cap-driven windows are deterministic too");
    }

    #[test]
    fn iteration_cap_never_pads_an_earlier_short_request() {
        // A short request aboard an open window must not share a lockstep
        // pass with a later rider that would blow the iteration cap: the
        // window seals *before* the oversized request is admitted.
        let mut cfg = small_cfg();
        cfg.batch_window_requests = 100;
        cfg.batch_window_max = 8;
        let coord = Coordinator::new(&cfg);
        let members = tiny_members();
        coord.register_bundle(Arc::new(FusedBundle::new(members.clone()).unwrap()));
        let mut session = coord.session();
        let short = session.enqueue(Arc::clone(&members[0]), stream_for(&members[0], 2, 1));
        let long = session.enqueue(Arc::clone(&members[1]), stream_for(&members[1], 20, 2));
        session.drain();
        let short = short.wait().expect("short request ok");
        let long = long.wait().expect("long request ok");
        assert_eq!(
            coord.metrics.snapshot().windows,
            2,
            "the oversized rider opens (and immediately seals) its own window"
        );
        assert!(
            short.cycles < long.cycles,
            "the short request ({} cycles) must not be charged the rider's \
             padded pass ({} cycles)",
            short.cycles,
            long.cycles
        );
    }

    #[test]
    fn cross_session_requests_share_one_window() {
        // The tentpole property: windows form in the global dispatch
        // state, so two sessions' member requests batch into ONE lockstep
        // pass — the multi-user serving shape.
        let mut cfg = small_cfg();
        cfg.batch_window_requests = 100; // only an explicit flush seals
        let coord = Coordinator::new(&cfg);
        let members = tiny_members();
        coord.register_bundle(Arc::new(FusedBundle::new(members.clone()).unwrap()));
        let mut s1 = coord.session();
        let mut s2 = coord.session();
        let t1 = s1.enqueue(Arc::clone(&members[0]), stream_for(&members[0], 2, 1));
        let t2 = s2.enqueue(Arc::clone(&members[1]), stream_for(&members[1], 2, 2));
        // Either session's flush seals the SHARED window.
        s1.flush();
        let r1 = t1.wait().expect("session 1 ok");
        let r2 = t2.wait().expect("session 2 ok");
        assert_eq!(r1.fused_members, 3);
        assert_eq!(r2.fused_members, 3);
        let m = coord.metrics.snapshot();
        assert_eq!(m.windows, 1, "two sessions, ONE cross-session window");
        assert_eq!(m.jobs, 2);
    }

    #[test]
    fn dispatch_lookahead_seals_oldest_window() {
        // With dispatch_lookahead = 2, a third riding request must force
        // the (oldest) open window shut — the request backlog riding open
        // windows is bounded WITHOUT any flush or wait. The request-count
        // seal (100) never triggers, so the window only dispatches if the
        // bound sealed it at enqueue time.
        let mut cfg = small_cfg();
        cfg.batch_window_requests = 100;
        cfg.dispatch_lookahead = 2;
        let coord = Coordinator::new(&cfg);
        let members = tiny_members();
        coord.register_bundle(Arc::new(FusedBundle::new(members.clone()).unwrap()));
        let mut session = coord.session();
        let tickets: Vec<Ticket> = (0..3)
            .map(|i| {
                let b = &members[i % members.len()];
                session.enqueue(Arc::clone(b), stream_for(b, 2, i as u64))
            })
            .collect();
        // No flush, no drain, tickets unwaited: only the lookahead bound
        // can have dispatched the window. Workers process it async.
        let deadline = Instant::now() + Duration::from_secs(60);
        while coord.metrics.snapshot().jobs < 3 {
            assert!(
                Instant::now() < deadline,
                "lookahead-sealed window never dispatched"
            );
            std::thread::sleep(Duration::from_millis(5));
        }
        for t in tickets {
            t.wait().expect("lookahead job ok");
        }
        let m = coord.metrics.snapshot();
        assert_eq!(m.jobs, 3);
        assert_eq!(m.windows, 1, "all three riders shared the one sealed window");
    }

    #[test]
    fn lru_evicts_least_recently_used_mapping() {
        // Serialized single-worker traffic so the use order is exact:
        // A, B fill a capacity-2 cache; touching A makes B the LRU victim
        // when C arrives; B then re-maps on its next request. Pinned to
        // one shard (bypassing SPARSEMAP_SHARDS) — the three blocks must
        // share one cache for the eviction order to be observable.
        let mut cfg = small_cfg();
        cfg.workers = 1;
        cfg.cache_capacity = 2;
        let coord = Coordinator::with_shard_count(&cfg, 1);
        let blocks = tiny_members(); // a, b, c stand-ins
        let mut session = coord.session();
        let mut seed = 0u64;
        let mut run = |session: &mut ServeSession<'_>, bi: usize| -> InferResult {
            let block = &blocks[bi];
            let xs = stream_for(block, 2, seed);
            seed += 1;
            session.enqueue(Arc::clone(block), xs).wait().expect("job ok")
        };
        assert!(run(&mut session, 0).mapped_fresh); // A miss
        assert!(run(&mut session, 1).mapped_fresh); // B miss
        assert!(!run(&mut session, 0).mapped_fresh); // A hit (bumps A)
        assert!(run(&mut session, 2).mapped_fresh); // C miss → evicts B (LRU)
        assert!(!run(&mut session, 0).mapped_fresh); // A survived
        assert!(run(&mut session, 1).mapped_fresh, "B was evicted and must re-map");
        let m = coord.metrics.snapshot();
        assert_eq!(m.cache_misses, 4);
        assert_eq!(m.cache_hits, 2);
    }

    #[test]
    fn eviction_order_follows_tick_index_at_capacity_64() {
        // The tick-ordered BTreeMap index must reproduce exact LRU order
        // at a capacity where the retired full-map scan was the cost
        // concern. One cheap real mapping is cloned into every entry.
        let capacity = 64usize;
        let cache = MappingCache::new(capacity, 0);
        let metrics = Metrics::default();
        let block = tiny("evict", 2, 2, vec![true, false, true, true]);
        let cgra = StreamingCgra::paper_default();
        let opts = MapperOptions::sparsemap();
        let outcome = map_unit(MapUnit::Single(&block), &cgra, &opts).unwrap();
        let fill = |key: &str| {
            cache
                .get_or_map(key, &metrics, || {
                    Ok(ServingMapping { outcome: outcome.clone(), bundle: None, plan: None })
                })
                .unwrap()
        };
        for i in 0..capacity {
            fill(&format!("k{i:02}"));
        }
        // Touch the even keys (in order): odd keys become the LRU tail.
        for i in (0..capacity).step_by(2) {
            let (_, fresh) = cache
                .get_or_map(&format!("k{i:02}"), &metrics, || {
                    unreachable!("touch must hit")
                })
                .unwrap();
            assert!(!fresh);
        }
        // Each insert beyond capacity evicts exactly the next odd key.
        for j in 0..capacity / 2 {
            fill(&format!("n{j:02}"));
            let inner = cache.inner.lock().unwrap();
            assert_eq!(inner.map.len(), capacity);
            assert_eq!(inner.by_tick.len(), capacity, "index tracks the map");
            let victim = format!("k{:02}", 2 * j + 1);
            assert!(!inner.map.contains_key(&victim), "{victim} evicted at step {j}");
            if 2 * (j + 1) + 1 < capacity {
                let next = format!("k{:02}", 2 * (j + 1) + 1);
                assert!(inner.map.contains_key(&next), "{next} not yet evicted");
            }
        }
        // Every touched (even) key survived the whole sweep.
        let inner = cache.inner.lock().unwrap();
        for i in (0..capacity).step_by(2) {
            assert!(inner.map.contains_key(&format!("k{i:02}")));
        }
    }

    #[test]
    fn concurrent_cold_start_maps_once() {
        // Many concurrent requests for one cold block: single-flight must
        // map exactly once while waiters sleep on the entry's condvar
        // (not on the cache map), then share the result.
        let mut cfg = small_cfg();
        cfg.workers = 4;
        cfg.queue_depth = 8;
        let coord = Coordinator::new(&cfg);
        let block = Arc::new(paper_blocks()[0].block.clone());
        let mut session = coord.session();
        let tickets: Vec<Ticket> = (0..8u64)
            .map(|seed| session.enqueue(Arc::clone(&block), stream_for(&block, 4, seed)))
            .collect();
        for t in tickets {
            t.wait().expect("job ok");
        }
        let m = coord.metrics.snapshot();
        assert_eq!(m.cache_misses, 1, "one mapping for 8 concurrent requests");
        assert_eq!(m.cache_hits, 7);
    }

    #[test]
    fn failed_build_leaves_no_dead_cache_entry() {
        // A failed (deterministically re-failing) mapping must not leave a
        // permanent Empty entry behind: Empty entries are not LRU victims,
        // so a dead one would pin cache_capacity forever.
        let cache = MappingCache::new(1, 0);
        let metrics = Metrics::default();
        let err = cache.get_or_map("dead", &metrics, || {
            Err(Error::Workload("unmappable".into()))
        });
        assert!(err.is_err());
        {
            let inner = cache.inner.lock().unwrap();
            assert_eq!(inner.map.len(), 0, "failed build must remove its cache entry");
            assert_eq!(inner.by_tick.len(), 0, "and its tick-index row");
        }
        // The capacity-1 cache is free again: a successful build for the
        // same key caches normally and subsequent requests hit.
        let block = tiny("cachetest", 2, 2, vec![true, false, true, true]);
        let cgra = StreamingCgra::paper_default();
        let opts = MapperOptions::sparsemap();
        let build = || {
            let outcome = map_unit(MapUnit::Single(&block), &cgra, &opts)?;
            Ok(ServingMapping { outcome, bundle: None, plan: None })
        };
        let (_, fresh) = cache.get_or_map("dead", &metrics, build).unwrap();
        assert!(fresh);
        let (_, fresh) = cache
            .get_or_map("dead", &metrics, || unreachable!("second request must hit"))
            .unwrap();
        assert!(!fresh);
        let inner = cache.inner.lock().unwrap();
        assert_eq!(inner.map.len(), 1);
        assert_eq!(inner.by_tick.len(), 1);
    }

    #[test]
    fn register_fused_plans_with_configured_knobs() {
        let mut cfg = small_cfg();
        cfg.max_fused_blocks = 2;
        cfg.fusion_max_ii = 12;
        let coord = Coordinator::new(&cfg);
        let members = tiny_members();
        let plan = coord.register_fused(&members);
        assert!(plan.iter().all(|b| b.len() <= 2));
        assert_eq!(plan.iter().map(|b| b.len()).sum::<usize>(), members.len());
        // First planned pair is registered: a member request serves fused.
        let first = &plan[0];
        assert!(first.len() == 2, "tiny blocks must pack in pairs");
        let member = Arc::clone(&first.blocks[0]);
        let xs = stream_for(&member, 2, 3);
        let mut session = coord.session();
        let r = session.enqueue(member, xs).wait().expect("fused job ok");
        assert_eq!(r.fused_members, 2);
    }

    #[test]
    fn multiple_blocks_in_flight() {
        let cfg = small_cfg();
        let coord = Coordinator::new(&cfg);
        let blocks: Vec<Arc<SparseBlock>> = paper_blocks()
            .into_iter()
            .take(3)
            .map(|nb| Arc::new(nb.block))
            .collect();
        let mut session = coord.session();
        let mut tickets = Vec::new();
        let mut seed = 0u64;
        for block in &blocks {
            for _ in 0..2 {
                tickets.push(session.enqueue(Arc::clone(block), stream_for(block, 4, seed)));
                seed += 1;
            }
        }
        session.drain();
        for t in tickets {
            t.wait().expect("job ok");
        }
        let m = coord.metrics.snapshot();
        assert_eq!(m.cache_misses, 3);
    }
}
