//! Streaming inference coordinator (L3 runtime).
//!
//! Owns the request path of the system: a typed **session API** over a
//! bounded job queue (backpressure), a worker-thread pool that maps blocks
//! (with a compile-once mapping cache) and executes them on the
//! cycle-accurate CGRA simulator, and aggregate metrics. The PJRT
//! cross-check (`crate::runtime`) runs on the caller's thread — XLA
//! executables stay off the worker pool.
//!
//! ## Sessions and tickets
//!
//! [`Coordinator::session`] opens a [`ServeSession`];
//! [`ServeSession::enqueue`] hands in one request (a block plus its
//! iteration-major input vectors) and returns a [`Ticket`] — the handle
//! the result is retrieved by ([`Ticket::wait`] / [`Ticket::try_wait`]),
//! in any order, independent of completion order. Per-request failures
//! come back as a structured [`ServeError`] (queue closed / mapping
//! failed / simulator fault / worker gone) instead of a stringly runtime
//! error. The pre-session `submit`/`collect` fire-hose survives one
//! release as `#[deprecated]` thin wrappers over an internal session.
//!
//! ## Batching windows
//!
//! Requests targeting members of the same registered [`FusedBundle`]
//! aggregate into a **batching window**: the window seals once it holds
//! `[coordinator] batch_window_requests` requests (or its lockstep
//! iteration count reaches `[coordinator] batch_window_max`), on
//! [`ServeSession::flush`]/[`ServeSession::drain`], or when a member
//! ticket is waited on — and the whole window is dispatched as ONE job
//! running ONE lockstep simulation pass ([`crate::sim::simulate_fused_batch`])
//! with a real iteration stream per member (zero inputs only for members
//! absent from the window). The window is charged for the resident
//! configuration once: `Metrics::total_cycles` grows by the pass total,
//! the `windows` counter by one, and each request's `InferResult::cycles`
//! is its proportional share of the pass. Window contents are a pure
//! function of the session's enqueue order (plus the two knobs), so
//! serving is deterministic at any worker count.
//!
//! ## Mapping cache
//!
//! The cache is single-flight and LRU-bounded: one entry per mapping key,
//! the first requester builds (maps) while concurrent requesters for the
//! same key sleep on the entry's `Condvar` — the cache's outer mutex is
//! never held across a mapping, so unrelated blocks proceed in parallel
//! and waiters block on nothing but their own entry. Capacity comes from
//! `[coordinator] cache_capacity` (`0` = unbounded); at capacity the
//! least-recently-used entry is evicted through a tick-ordered
//! `BTreeMap` index maintained on the touch path (no full-map scans;
//! in-flight holders keep their `Arc`).
//!
//! ## Multi-block fusion
//!
//! Small blocks can be registered as a [`FusedBundle`]
//! ([`Coordinator::register_bundle`] / [`Coordinator::register_fused`]):
//! a request for *any* member block routes — at enqueue time, through
//! [`BundleRoutes`] — into the bundle's batching window and is served by
//! the bundle's shared fused mapping (one cache entry keyed by the
//! bundle's combined mask fingerprint). Unregistered blocks serve solo
//! through the same cache, so fused and unfused traffic mix freely.
//!
//! ## Failure model
//!
//! The serving tier treats failure as a first-class input (CGRA mapping
//! attempts *can* fail; workers *can* die): job execution runs under a
//! per-job `catch_unwind` with in-place retry, a supervisor thread
//! respawns hard-dead workers up to `[coordinator] restart_budget`, and a
//! job identity that keeps panicking is quarantined after
//! `[coordinator] poison_threshold` attempts (its tickets resolve
//! [`ServeError::Poisoned`]). Requests carry optional deadlines
//! ([`ServeSession::enqueue_with_deadline`]) checked at worker pickup —
//! expired work is shed as [`ServeError::DeadlineExceeded`] without
//! simulating — and dropping an unwaited [`Ticket`] withdraws its request
//! from a still-forming window. [`ServeSession::try_enqueue`] sheds
//! instead of blocking ([`ServeError::Overloaded`]) on a full queue or
//! above `[coordinator] shed_watermark`. Failed mapping-cache entries
//! retry after `[coordinator] failure_ttl` further requests (`0` = sticky
//! forever). If the whole pool dies with budget exhausted, the supervisor
//! drains the queue resolving every ticket [`ServeError::WorkerGone`] —
//! the invariant throughout is that *every enqueued ticket resolves*.
//! All of it is exercised deterministically by `util::failpoint` sites
//! (`coordinator::serve` / `worker_hard` / `map` / `sim` / `delay`) under
//! the `failpoints` feature (`tests/fault_tolerance.rs`).
//!
//! tokio is unavailable offline; the pool is built on std threads +
//! `std::sync::mpsc::sync_channel`, which gives exactly the bounded-queue
//! semantics the backpressure design needs. A batching window occupies a
//! single queue slot however many requests it carries.

use std::collections::{BTreeMap, HashMap, VecDeque};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{
    channel, sync_channel, Receiver, SendError, Sender, SyncSender, TrySendError,
};
use std::sync::{Arc, Condvar, Mutex, Weak};
use std::time::{Duration, Instant};

use crate::arch::StreamingCgra;
use crate::config::{SimBackend, SparsemapConfig};
use crate::error::{Error, Result};
use crate::mapper::{map_unit, MapOutcome, MapUnit, MapperOptions};
use crate::sim::{execute_plan_batch, simulate, simulate_fused_batch, ExecPlan, MemberSegment, SegmentSim};
use crate::sparse::fuse::{plan_bundles, BundleRoutes, FusedBundle, FusionOptions};
use crate::sparse::SparseBlock;
use crate::util::stats::Summary;

/// One inference job: run `xs` (iteration-major input vectors) through a
/// sparse block on the CGRA. Legacy envelope of the deprecated
/// `submit`/`collect` path — the session API takes the block and inputs
/// directly and allocates ids itself.
pub struct InferRequest {
    pub id: u64,
    pub block: Arc<SparseBlock>,
    pub xs: Vec<Vec<f32>>,
}

/// The coordinator's answer.
#[derive(Clone, Debug)]
pub struct InferResult {
    /// Request id: the session-scoped enqueue sequence number (or the
    /// caller-chosen id on the deprecated `submit` path).
    pub id: u64,
    pub block_name: String,
    /// CGRA cycles this request is charged for. A request served through a
    /// batching window is charged its proportional share of the window's
    /// single pass — the shares of a window sum exactly to the pass total.
    pub cycles: u64,
    pub outputs: Vec<Vec<f32>>,
    /// II of the mapping used.
    pub ii: usize,
    /// Whether this job triggered a fresh mapping (cache miss). In a
    /// batching window, the window's first request carries the flag.
    pub mapped_fresh: bool,
    /// Member blocks resident in the configuration that served this
    /// request (`1` = unfused).
    pub fused_members: usize,
    /// End-to-end latency in nanoseconds, from enqueue to resolution:
    /// `queue_ns + service_ns`. Per-ticket — batched members share the
    /// window's service span but each carries its own queueing span.
    pub latency_ns: u64,
    /// Nanoseconds from enqueue to worker pickup: queue residency plus any
    /// time spent riding an open batching window.
    pub queue_ns: u64,
    /// Worker-side nanoseconds (mapping-cache fetch + simulation). Window
    /// members share their window's single pass, so they share this value.
    pub service_ns: u64,
}

/// Structured per-request serving failure, delivered through [`Ticket`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ServeError {
    /// The job queue closed (worker pool shut down) before the request
    /// could be dispatched or delivered.
    QueueClosed,
    /// Mapping the request's block — or its bundle's shared fused mapping
    /// with no solo fallback left — failed. Carries the mapper's reason;
    /// concurrent requests for the same key fail fast on the cache's
    /// sticky error without re-running the deterministic mapping.
    MappingFailed(String),
    /// The simulator faulted while serving the request (a mapping-stack
    /// bug detector firing, or malformed request inputs).
    Sim(String),
    /// The worker pool dropped the request without completing it (worker
    /// panic or teardown mid-flight).
    WorkerGone,
    /// The request's deadline passed before a worker began serving it: it
    /// was shed at pickup without simulating. A deadline never interrupts
    /// a request already being served.
    DeadlineExceeded,
    /// The request targets a quarantined "poison" job: executing that
    /// block (or its bundle) has panicked `[coordinator] poison_threshold`
    /// times, so the pool refuses to retry it.
    Poisoned,
    /// Admission control shed the request: `try_enqueue` found the bounded
    /// queue full, or its occupancy at/above `[coordinator]
    /// shed_watermark`. The blocking `enqueue` never returns this.
    Overloaded,
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::QueueClosed => {
                write!(f, "serving queue closed before the request was dispatched")
            }
            ServeError::MappingFailed(msg) => write!(f, "mapping failed: {msg}"),
            ServeError::Sim(msg) => write!(f, "simulation failed: {msg}"),
            ServeError::WorkerGone => {
                write!(f, "worker pool dropped the request without completing it")
            }
            ServeError::DeadlineExceeded => {
                write!(f, "deadline passed before a worker picked the request up")
            }
            ServeError::Poisoned => {
                write!(f, "request targets a quarantined poison job (repeated worker panics)")
            }
            ServeError::Overloaded => {
                write!(f, "request shed by admission control (queue over watermark)")
            }
        }
    }
}

impl std::error::Error for ServeError {}

impl From<ServeError> for Error {
    /// The deprecated `collect` shim (and other legacy surfaces) report
    /// serve errors the way the old API did: as stringly runtime errors.
    fn from(e: ServeError) -> Self {
        Error::Runtime(e.to_string())
    }
}

/// Aggregate counters (lock-free reads).
#[derive(Default)]
pub struct Metrics {
    /// Requests processed by the worker pool (each window member counts).
    pub jobs: AtomicU64,
    pub failures: AtomicU64,
    pub cache_hits: AtomicU64,
    pub cache_misses: AtomicU64,
    /// CGRA cycles charged: per-request pass totals for solo serving, ONE
    /// pass total per batching window for fused serving.
    pub total_cycles: AtomicU64,
    pub total_latency_ns: AtomicU64,
    /// Batching windows simulated (one fused lockstep pass each).
    pub windows: AtomicU64,
    /// Requests shed by admission control (`try_enqueue` → `Overloaded`);
    /// they never entered the queue, so they do not count as `jobs`.
    pub shed: AtomicU64,
    /// Requests whose deadline passed before a worker picked them up
    /// (resolved `DeadlineExceeded`; not counted as `failures` — a shed is
    /// a policy outcome, not a serving fault).
    pub deadline_expired: AtomicU64,
    /// Worker restarts: per-job `catch_unwind` recoveries plus supervisor
    /// thread respawns.
    pub worker_restarts: AtomicU64,
    /// Requests resolved `Poisoned` (their job identity crossed the panic
    /// quarantine threshold); also counted in `failures`.
    pub poisoned: AtomicU64,
    /// Per-request latency attribution, sampled at successful resolution.
    latency: Mutex<LatencyStats>,
}

/// Queue/service span samples behind `Metrics` (percentiles need retained
/// samples, so these live under a mutex rather than atomics).
#[derive(Default)]
struct LatencyStats {
    queue: Summary,
    service: Summary,
}

/// Percentile of a possibly-empty summary (`0` before the first sample —
/// `Summary::percentile` itself panics on empty input).
fn pct(s: &Summary, q: f64) -> f64 {
    if s.count() == 0 {
        0.0
    } else {
        s.percentile(q)
    }
}

impl Metrics {
    /// Record one resolved request's queueing and service spans.
    fn observe_latency(&self, queue_ns: u64, service_ns: u64) {
        if let Ok(mut l) = self.latency.lock() {
            l.queue.add(queue_ns as f64);
            l.service.add(service_ns as f64);
        }
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let (queue_ns_p50, queue_ns_p99, service_ns_p50, service_ns_p99) =
            match self.latency.lock() {
                Ok(l) => (
                    pct(&l.queue, 50.0),
                    pct(&l.queue, 99.0),
                    pct(&l.service, 50.0),
                    pct(&l.service, 99.0),
                ),
                Err(_) => (0.0, 0.0, 0.0, 0.0),
            };
        MetricsSnapshot {
            jobs: self.jobs.load(Ordering::Relaxed),
            failures: self.failures.load(Ordering::Relaxed),
            cache_hits: self.cache_hits.load(Ordering::Relaxed),
            cache_misses: self.cache_misses.load(Ordering::Relaxed),
            total_cycles: self.total_cycles.load(Ordering::Relaxed),
            total_latency_ns: self.total_latency_ns.load(Ordering::Relaxed),
            windows: self.windows.load(Ordering::Relaxed),
            shed: self.shed.load(Ordering::Relaxed),
            deadline_expired: self.deadline_expired.load(Ordering::Relaxed),
            worker_restarts: self.worker_restarts.load(Ordering::Relaxed),
            poisoned: self.poisoned.load(Ordering::Relaxed),
            queue_ns_p50,
            queue_ns_p99,
            service_ns_p50,
            service_ns_p99,
        }
    }
}

#[derive(Clone, Copy, Debug)]
pub struct MetricsSnapshot {
    pub jobs: u64,
    pub failures: u64,
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub total_cycles: u64,
    pub total_latency_ns: u64,
    pub windows: u64,
    pub shed: u64,
    pub deadline_expired: u64,
    pub worker_restarts: u64,
    pub poisoned: u64,
    /// p50/p99 over per-request queueing spans (ns); `0.0` with no samples.
    pub queue_ns_p50: f64,
    pub queue_ns_p99: f64,
    /// p50/p99 over per-request service spans (ns); `0.0` with no samples.
    pub service_ns_p50: f64,
    pub service_ns_p99: f64,
}

/// Fused request batching knobs (see `[coordinator] batch_window_requests`
/// / `batch_window_max`).
#[derive(Clone, Copy, Debug)]
pub struct BatchOptions {
    /// A window seals once it holds this many member requests (`0`/`1` =
    /// every member request is its own window).
    pub window_requests: usize,
    /// Cap on a window's lockstep iteration count (max over members of
    /// the summed request stream lengths): a request that would push the
    /// window to the cap seals it *first* and starts a fresh one, so
    /// requests already aboard never pay an oversized rider's padding.
    /// `0` = uncapped.
    pub window_max_iters: usize,
}

impl BatchOptions {
    pub fn from_config(cfg: &SparsemapConfig) -> Self {
        BatchOptions {
            window_requests: cfg.batch_window_requests,
            window_max_iters: cfg.batch_window_max,
        }
    }
}

// ---------------------------------------------------------------------------
// Tickets

/// Resolution state shared between a [`Ticket`] and its worker-side
/// completer.
enum TicketInner {
    Pending,
    Done(std::result::Result<InferResult, ServeError>),
    /// `wait` consumed the result (tombstone — unreachable through the
    /// public API afterwards, since `wait` takes the ticket by value).
    Taken,
}

struct TicketState {
    inner: Mutex<TicketInner>,
    ready: Condvar,
}

impl TicketState {
    fn new() -> Arc<Self> {
        Arc::new(TicketState { inner: Mutex::new(TicketInner::Pending), ready: Condvar::new() })
    }

    /// First completion wins; later calls (e.g. the completer's drop guard
    /// after an explicit fulfill) are no-ops.
    fn complete(&self, res: std::result::Result<InferResult, ServeError>) {
        let mut inner = self.inner.lock().expect("ticket state");
        if matches!(&*inner, TicketInner::Pending) {
            *inner = TicketInner::Done(res);
            self.ready.notify_all();
        }
    }

    /// Block until the ticket is resolved (without consuming the result).
    fn wait_done(&self) {
        let mut inner = self.inner.lock().expect("ticket state");
        while matches!(&*inner, TicketInner::Pending) {
            inner = self.ready.wait(inner).expect("ticket state");
        }
    }

    /// Block until resolved, then take the result.
    fn take(&self) -> std::result::Result<InferResult, ServeError> {
        let mut inner = self.inner.lock().expect("ticket state");
        while matches!(&*inner, TicketInner::Pending) {
            inner = self.ready.wait(inner).expect("ticket state");
        }
        match std::mem::replace(&mut *inner, TicketInner::Taken) {
            TicketInner::Done(res) => res,
            // `wait` consumes the ticket, so a taken state cannot be
            // observed again through the public API.
            _ => Err(ServeError::WorkerGone),
        }
    }

    /// Non-blocking peek (clones the result, leaving it claimable).
    fn peek(&self) -> Option<std::result::Result<InferResult, ServeError>> {
        let inner = self.inner.lock().expect("ticket state");
        match &*inner {
            TicketInner::Done(res) => Some(res.clone()),
            _ => None,
        }
    }

    /// Block until resolved or `deadline`, whichever comes first. `Some`
    /// clones the result (leaving it claimable, like `peek`); `None`
    /// means the request is still in flight at the deadline.
    fn wait_until(
        &self,
        deadline: Instant,
    ) -> Option<std::result::Result<InferResult, ServeError>> {
        let mut inner = self.inner.lock().expect("ticket state");
        loop {
            if let TicketInner::Done(res) = &*inner {
                return Some(res.clone());
            }
            let left = deadline.checked_duration_since(Instant::now())?;
            let (guard, _) = self.ready.wait_timeout(inner, left).expect("ticket state");
            inner = guard;
        }
    }
}

/// Worker-side handle to a pending ticket: fulfills it exactly once, and
/// resolves it to [`ServeError::WorkerGone`] if dropped unfulfilled
/// (worker panic, queue teardown with jobs still aboard) so a `wait` can
/// never hang on a request the pool lost.
struct TicketCompleter {
    state: Arc<TicketState>,
}

impl TicketCompleter {
    fn fulfill(self, res: std::result::Result<InferResult, ServeError>) {
        self.state.complete(res);
        // Drop runs next and no-ops: completion is first-wins.
    }
}

impl Drop for TicketCompleter {
    fn drop(&mut self) {
        self.state.complete(Err(ServeError::WorkerGone));
    }
}

/// Handle to one enqueued request. Results are retrieved by ticket, in any
/// order — waiting also seals the request's batching window (if it is
/// still open) so a ticket can never block on a window nobody else would
/// close.
pub struct Ticket {
    id: u64,
    block_name: String,
    state: Arc<TicketState>,
    window: Option<WindowHandle>,
}

impl Ticket {
    /// The request's id (session-scoped enqueue sequence number).
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Name of the block the request targets.
    pub fn block_name(&self) -> &str {
        &self.block_name
    }

    /// Block until the request resolves and take the result. Seals the
    /// request's batching window first if it is still open.
    pub fn wait(mut self) -> std::result::Result<InferResult, ServeError> {
        self.flush_window();
        self.state.take()
    }

    /// Non-blocking poll: `None` while the request is in flight, a clone
    /// of the result once resolved (the result stays claimable by `wait`).
    /// Also seals the request's still-open batching window — the poll
    /// would otherwise never turn `Some`.
    pub fn try_wait(&mut self) -> Option<std::result::Result<InferResult, ServeError>> {
        self.flush_window();
        self.state.peek()
    }

    /// Bounded wait: block until the request resolves or `timeout`
    /// elapses. Seals the request's still-open batching window first (like
    /// `wait`). `Some` clones the result, leaving it claimable by a later
    /// `wait`/`try_wait`; `None` means the request is still in flight —
    /// the ticket stays live and can be waited again.
    pub fn wait_timeout(
        &mut self,
        timeout: Duration,
    ) -> Option<std::result::Result<InferResult, ServeError>> {
        self.flush_window();
        let deadline = Instant::now().checked_add(timeout)?;
        self.state.wait_until(deadline)
    }

    fn flush_window(&mut self) {
        if let Some(w) = self.window.take() {
            w.flush();
        }
    }
}

impl Drop for Ticket {
    /// Dropping an unwaited ticket cancels its request if that request is
    /// still riding an open batching window: the request is withdrawn
    /// before the window seals, so abandoned work is never simulated.
    /// (A sealed or dispatched request rides along; its result is simply
    /// discarded.) `wait`/`try_wait`/`wait_timeout` take the window handle
    /// first, so a waited ticket never cancels.
    fn drop(&mut self) {
        if let Some(w) = self.window.take() {
            w.cancel(self.id);
        }
    }
}

// ---------------------------------------------------------------------------
// Batching windows

/// A not-yet-dispatched batching window for one registered bundle.
struct WindowCell {
    bundle: Arc<FusedBundle>,
    requests: Vec<WindowRequest>,
    sealed: bool,
}

struct WindowRequest {
    id: u64,
    /// Member index inside the bundle (resolved at enqueue time).
    member: usize,
    block: Arc<SparseBlock>,
    xs: Vec<Vec<f32>>,
    done: TicketCompleter,
    /// Shed (as `DeadlineExceeded`) at worker pickup once passed.
    deadline: Option<Instant>,
    /// Enqueue timestamp, for queue-span latency attribution.
    enqueued_at: Instant,
}

/// Shared handle to an open window: the session and every member ticket
/// hold one, and whoever seals first dispatches. The queue is held weakly
/// so stray tickets can never keep the worker pool alive past the
/// coordinator's drop.
#[derive(Clone)]
struct WindowHandle {
    cell: Arc<Mutex<WindowCell>>,
    tx: Weak<JobQueue>,
}

impl WindowHandle {
    /// Seal the window (if still open and non-empty) and dispatch it as
    /// one job; on a closed queue every member ticket resolves to
    /// [`ServeError::QueueClosed`] instead of hanging.
    fn flush(&self) {
        let job = {
            let mut cell = self.cell.lock().expect("window cell");
            if cell.sealed || cell.requests.is_empty() {
                return;
            }
            cell.sealed = true;
            WindowJob {
                bundle: Arc::clone(&cell.bundle),
                requests: std::mem::take(&mut cell.requests),
            }
        };
        match self.tx.upgrade() {
            Some(queue) => {
                if let Err(job) = queue.send(Job::Window(job)) {
                    resolve_queue_closed(job);
                }
            }
            None => resolve_queue_closed(Job::Window(job)),
        }
    }

    /// Withdraw request `id` if the window has not sealed yet (the
    /// cancellation path of a dropped unwaited [`Ticket`]). A sealed
    /// window is immutable: the request rides along and its result is
    /// discarded. Window contents stay a pure function of the session's
    /// enqueue/cancel sequence.
    fn cancel(&self, id: u64) {
        let mut cell = self.cell.lock().expect("window cell");
        if !cell.sealed {
            // The withdrawn completer resolves its (otherwise
            // unobservable) ticket state on drop.
            cell.requests.retain(|r| r.id != id);
        }
    }
}

/// Resolve every ticket aboard `job` to [`ServeError::QueueClosed`]
/// (dispatch against a closed queue).
fn resolve_queue_closed(job: Job) {
    match job {
        Job::Single(j) => j.done.fulfill(Err(ServeError::QueueClosed)),
        Job::Window(w) => {
            for r in w.requests {
                r.done.fulfill(Err(ServeError::QueueClosed));
            }
        }
    }
}

/// Lockstep iteration count of the window's current contents, optionally
/// with one more candidate request aboard.
fn lockstep_len(cell: &WindowCell, extra: Option<&WindowRequest>) -> usize {
    let mut totals = vec![0usize; cell.bundle.len()];
    for r in cell.requests.iter().chain(extra) {
        totals[r.member] += r.xs.len();
    }
    totals.into_iter().max().unwrap_or(0)
}

/// Whether admitting `request` would push the window's lockstep iteration
/// count to (or past) `batch_window_max` — checked *before* admission so
/// requests already aboard never pay the oversized rider's padding.
fn would_exceed_cap(cell: &WindowCell, request: &WindowRequest, batching: &BatchOptions) -> bool {
    batching.window_max_iters > 0
        && lockstep_len(cell, Some(request)) >= batching.window_max_iters
}

/// Whether the window should seal now that its contents are final for
/// this enqueue: the request-count knob, or (for a window whose sole
/// request alone reaches it — a cap breach no split can avoid) the
/// iteration cap.
fn window_full(cell: &WindowCell, batching: &BatchOptions) -> bool {
    if cell.requests.len() >= batching.window_requests.max(1) {
        return true;
    }
    batching.window_max_iters > 0
        && lockstep_len(cell, None) >= batching.window_max_iters
}

// ---------------------------------------------------------------------------
// Sessions

/// Session bookkeeping shared by [`ServeSession`] and the deprecated
/// `submit`/`collect` shims: id allocation plus the open windows, in
/// creation order (so flush order — and therefore window formation — is a
/// pure function of enqueue order).
struct SessionCore {
    next_id: u64,
    /// Open windows keyed by bundle fingerprint (small linear map).
    open: Vec<(u64, WindowHandle)>,
}

impl SessionCore {
    fn new() -> Self {
        SessionCore { next_id: 0, open: Vec::new() }
    }

    fn enqueue(
        &mut self,
        coord: &Coordinator,
        id: u64,
        block: Arc<SparseBlock>,
        xs: Vec<Vec<f32>>,
        deadline: Option<Instant>,
    ) -> Ticket {
        let state = TicketState::new();
        let done = TicketCompleter { state: Arc::clone(&state) };
        let block_name = block.name.clone();
        let enqueued_at = Instant::now();
        let route = coord.bundles.route(block.mask_fingerprint());
        let window = match (route, coord.sender()) {
            (_, None) => {
                done.fulfill(Err(ServeError::QueueClosed));
                None
            }
            (None, Some(queue)) => {
                let job =
                    Job::Single(SingleJob { id, block, xs, done, deadline, enqueued_at });
                if let Err(job) = queue.send(job) {
                    resolve_queue_closed(job);
                }
                None
            }
            (Some((bundle, member)), Some(queue)) => Some(self.window_enqueue(
                &queue,
                &coord.batching,
                bundle,
                WindowRequest { id, member, block, xs, done, deadline, enqueued_at },
            )),
        };
        Ticket { id, block_name, state, window }
    }

    /// Shedding admission for `try_enqueue`: a request for a registered
    /// bundle member always joins its batching window (a window occupies
    /// one queue slot for the whole batch, so members are the cheapest
    /// traffic to admit — "non-bundle singles are shed first"); a solo
    /// request is shed with [`ServeError::Overloaded`] when the queue
    /// occupancy is at/above the watermark or the bounded queue is full.
    fn try_enqueue(
        &mut self,
        coord: &Coordinator,
        id: u64,
        block: Arc<SparseBlock>,
        xs: Vec<Vec<f32>>,
        deadline: Option<Instant>,
    ) -> std::result::Result<Ticket, ServeError> {
        let Some(queue) = coord.sender() else {
            return Err(ServeError::QueueClosed);
        };
        let enqueued_at = Instant::now();
        let route = coord.bundles.route(block.mask_fingerprint());
        if let Some((bundle, member)) = route {
            let state = TicketState::new();
            let done = TicketCompleter { state: Arc::clone(&state) };
            let block_name = block.name.clone();
            let window = self.window_enqueue(
                &queue,
                &coord.batching,
                bundle,
                WindowRequest { id, member, block, xs, done, deadline, enqueued_at },
            );
            return Ok(Ticket { id, block_name, state, window: Some(window) });
        }
        if coord.shed_watermark > 0 && queue.occupancy() >= coord.shed_watermark {
            coord.metrics.shed.fetch_add(1, Ordering::Relaxed);
            return Err(ServeError::Overloaded);
        }
        let state = TicketState::new();
        let done = TicketCompleter { state: Arc::clone(&state) };
        let block_name = block.name.clone();
        match queue.try_send(Job::Single(SingleJob { id, block, xs, done, deadline, enqueued_at }))
        {
            Ok(()) => Ok(Ticket { id, block_name, state, window: None }),
            // The rejected job drops here: its completer resolves the
            // (never-issued) ticket state, which dies with it.
            Err(TrySendError::Full(_)) => {
                coord.metrics.shed.fetch_add(1, Ordering::Relaxed);
                Err(ServeError::Overloaded)
            }
            Err(TrySendError::Disconnected(_)) => Err(ServeError::QueueClosed),
        }
    }

    /// Append a member request to its bundle's open window (creating one
    /// if none is open), sealing and dispatching the window when it fills.
    /// A request that would push the window's lockstep iteration count
    /// past `batch_window_max` seals the window *first* and starts a fresh
    /// one — members already aboard never pay unbounded padding for a
    /// late oversized rider.
    fn window_enqueue(
        &mut self,
        tx: &Arc<JobQueue>,
        batching: &BatchOptions,
        bundle: Arc<FusedBundle>,
        request: WindowRequest,
    ) -> WindowHandle {
        let fp = bundle.fingerprint();
        loop {
            let handle = match self.open.iter().find(|(k, _)| *k == fp) {
                Some((_, h)) => h.clone(),
                None => {
                    let h = WindowHandle {
                        cell: Arc::new(Mutex::new(WindowCell {
                            bundle: Arc::clone(&bundle),
                            requests: Vec::new(),
                            sealed: false,
                        })),
                        tx: Arc::downgrade(tx),
                    };
                    self.open.push((fp, h.clone()));
                    h
                }
            };
            let full = {
                let mut cell = handle.cell.lock().expect("window cell");
                if cell.sealed {
                    // A concurrent `Ticket::wait` (tickets are `Send` and
                    // may be waited from any thread) sealed and dispatched
                    // this window between our lookup and this lock: forget
                    // the stale handle and open a fresh window. The seal
                    // decision and the push share one critical section, so
                    // a request can never land in an already-dispatched
                    // cell.
                    drop(cell);
                    self.open.retain(|(k, _)| *k != fp);
                    continue;
                }
                if !cell.requests.is_empty() && would_exceed_cap(&cell, &request, batching) {
                    drop(cell);
                    handle.flush();
                    self.open.retain(|(k, _)| *k != fp);
                    continue;
                }
                cell.requests.push(request);
                window_full(&cell, batching)
            };
            if full {
                handle.flush();
            }
            // `request` is moved only on this returning path; every
            // `continue` above runs before the move, so the loop re-enters
            // with the request still in hand.
            return handle;
        }
    }

    /// Seal and dispatch every open window, in creation order.
    fn flush_all(&mut self) {
        for (_, h) in self.open.drain(..) {
            h.flush();
        }
    }
}

/// A serving session: the enqueue side of the coordinator's typed API.
/// Dropping the session seals its open batching windows (requests are
/// never stranded); issued [`Ticket`]s stay valid past the session.
pub struct ServeSession<'a> {
    coord: &'a Coordinator,
    core: SessionCore,
    /// Weak handles to every issued ticket, for `drain`. Weak (the
    /// worker-side completer keeps in-flight states alive, a resolved and
    /// dropped ticket's state dies) and pruned amortized on enqueue, so a
    /// long-lived session's bookkeeping stays proportional to its *live*
    /// tickets, not its lifetime request count.
    issued: Vec<std::sync::Weak<TicketState>>,
}

impl ServeSession<'_> {
    /// Enqueue one request; blocks when the job queue is full
    /// (backpressure). The returned [`Ticket`] is the result handle.
    ///
    /// A request for a member of a registered bundle joins the bundle's
    /// open batching window; it is dispatched when the window seals (see
    /// the module docs) — at the latest when its ticket is waited on or
    /// the session flushes, drains or drops.
    pub fn enqueue(&mut self, block: Arc<SparseBlock>, xs: Vec<Vec<f32>>) -> Ticket {
        self.enqueue_opt(block, xs, None)
    }

    /// Like [`ServeSession::enqueue`], with a latency budget: if `budget`
    /// elapses before a worker picks the request up, it is shed unserved
    /// and its ticket resolves [`ServeError::DeadlineExceeded`]. A request
    /// already being served is never interrupted — the deadline bounds
    /// queue residency (including time riding an open batching window),
    /// not service. A budget so large the deadline overflows the clock is
    /// treated as no deadline.
    pub fn enqueue_with_deadline(
        &mut self,
        block: Arc<SparseBlock>,
        xs: Vec<Vec<f32>>,
        budget: Duration,
    ) -> Ticket {
        self.enqueue_opt(block, xs, Instant::now().checked_add(budget))
    }

    /// Non-blocking enqueue (admission control): sheds the request with
    /// [`ServeError::Overloaded`] — instead of blocking like `enqueue` —
    /// when the job queue is full or its occupancy is at/above
    /// `[coordinator] shed_watermark` (`0` disables the watermark).
    /// Requests for registered bundle members are always admitted into
    /// their batching window: a window rides one queue slot for the whole
    /// batch, so solo singles are shed first. A shed request consumes no
    /// ticket id — window formation stays a pure function of the
    /// *admitted* enqueue sequence.
    pub fn try_enqueue(
        &mut self,
        block: Arc<SparseBlock>,
        xs: Vec<Vec<f32>>,
    ) -> std::result::Result<Ticket, ServeError> {
        self.try_enqueue_opt(block, xs, None)
    }

    /// [`ServeSession::try_enqueue`] with a latency budget (see
    /// [`ServeSession::enqueue_with_deadline`]).
    pub fn try_enqueue_with_deadline(
        &mut self,
        block: Arc<SparseBlock>,
        xs: Vec<Vec<f32>>,
        budget: Duration,
    ) -> std::result::Result<Ticket, ServeError> {
        self.try_enqueue_opt(block, xs, Instant::now().checked_add(budget))
    }

    fn enqueue_opt(
        &mut self,
        block: Arc<SparseBlock>,
        xs: Vec<Vec<f32>>,
        deadline: Option<Instant>,
    ) -> Ticket {
        let id = self.core.next_id;
        self.core.next_id += 1;
        let ticket = self.core.enqueue(self.coord, id, block, xs, deadline);
        self.track(&ticket);
        ticket
    }

    fn try_enqueue_opt(
        &mut self,
        block: Arc<SparseBlock>,
        xs: Vec<Vec<f32>>,
        deadline: Option<Instant>,
    ) -> std::result::Result<Ticket, ServeError> {
        let id = self.core.next_id;
        let ticket = self.core.try_enqueue(self.coord, id, block, xs, deadline)?;
        self.core.next_id += 1;
        self.track(&ticket);
        Ok(ticket)
    }

    fn track(&mut self, ticket: &Ticket) {
        if self.issued.len() == self.issued.capacity() {
            // Amortized prune before the Vec would grow: drop bookkeeping
            // for tickets that have resolved and been discarded.
            self.issued.retain(|w| w.strong_count() > 0);
        }
        self.issued.push(Arc::downgrade(&ticket.state));
    }

    /// Seal and dispatch every open batching window without waiting.
    pub fn flush(&mut self) {
        self.core.flush_all();
    }

    /// Seal and dispatch every open batching window, then block until
    /// every ticket issued by this session has resolved. Results stay
    /// claimable through their tickets.
    pub fn drain(&mut self) {
        self.core.flush_all();
        for state in self.issued.drain(..) {
            // In-flight states are kept alive by the worker-side
            // completer; a dead Weak means the request already resolved
            // and its ticket is gone.
            if let Some(state) = state.upgrade() {
                state.wait_done();
            }
        }
    }
}

impl Drop for ServeSession<'_> {
    fn drop(&mut self) {
        self.core.flush_all();
    }
}

// ---------------------------------------------------------------------------
// Mapping cache

/// A cached, servable mapping: a solo block's or a whole fused bundle's.
struct ServingMapping {
    outcome: MapOutcome,
    /// `Some` when the mapping hosts a bundle — carries the member blocks
    /// the simulator needs for the co-resident streams.
    bundle: Option<Arc<FusedBundle>>,
    /// Compiled execution plan for the mapping, built once under the same
    /// single-flight guard as the mapping itself and evicted with it.
    /// `None` when the backend knob selects the interpreter or when plan
    /// compilation failed (a loud, logged fallback — never a lost ticket).
    plan: Option<ExecPlan>,
}

/// State of one cache entry. `Building` marks a mapping in flight; waiters
/// sleep on the entry's condvar instead of holding any mutex the builder
/// needs.
enum EntryState {
    /// No mapping and no builder in flight.
    Empty,
    Building,
    Ready(Arc<ServingMapping>),
    /// The build failed; the sticky error lets queued waiters fail fast
    /// instead of serially re-running a deterministically failing mapping.
    /// With `failure_ttl = 0` the entry is already detached from the cache
    /// map (new requesters get a fresh entry and their own retry); under a
    /// TTL it stays resident and `retry_in` counts down the remaining
    /// fast-fails — the request that finds it at `1` rebuilds in place.
    Failed { reason: String, retry_in: u64 },
}

struct CacheEntry {
    state: Mutex<EntryState>,
    ready: Condvar,
    /// Monotonic use tick for LRU eviction (unique per touch; assigned
    /// under the cache-map lock so eviction order is race-free and the
    /// tick index can be maintained in lockstep).
    last_use: AtomicU64,
}

/// Unwind guard for the build phase: if the build closure fails or panics
/// (a mapper invariant violation), mark the entry `Failed`, wake waiters
/// so they fail fast instead of deadlocking on a forever-`Building` entry
/// (or serially re-running a deterministically failing mapping), and drop
/// the entry from the cache map — `Failed` entries must not be found by
/// new requesters, and a dead entry would otherwise pin capacity forever
/// (only `Ready` entries are LRU victims, see [`evict_lru`]). The removal
/// is pointer-compared so a newer same-key entry created by a later
/// requester is never clobbered.
struct BuildGuard<'a> {
    cache: &'a MappingCache,
    key: &'a str,
    entry: &'a Arc<CacheEntry>,
    armed: bool,
}

impl BuildGuard<'_> {
    fn disarm(&mut self) {
        self.armed = false;
    }

    /// Mark the entry failed with `reason` and wake waiters. Under a
    /// failure TTL the entry stays resident (the next requests fail fast
    /// while `retry_in` counts down, then one rebuilds in place; LRU can
    /// evict it meanwhile); with TTL `0` the failure is sticky and the
    /// entry detaches from the cache (map and tick index).
    fn fail(&mut self, reason: &str) {
        self.armed = false;
        let ttl = self.cache.failure_ttl;
        {
            let mut state = self.entry.state.lock().expect("cache entry");
            *state = EntryState::Failed {
                reason: reason.to_string(),
                retry_in: if ttl == 0 { u64::MAX } else { ttl },
            };
            self.entry.ready.notify_all();
        }
        if ttl > 0 {
            return;
        }
        // Entry lock released before the map lock — the same order as
        // every other path (the map lock is never held while waiting
        // on an entry, and evict_lru only try_locks entry states).
        let mut inner = self.cache.inner.lock().expect("cache map");
        if inner.map.get(self.key).is_some_and(|e| Arc::ptr_eq(e, self.entry)) {
            inner.map.remove(self.key);
            // The entry's latest tick is authoritative: every touch
            // restamps it under the map lock we are holding.
            let tick = self.entry.last_use.load(Ordering::Relaxed);
            let removed = inner.by_tick.remove(&tick);
            debug_assert_eq!(removed.as_deref(), Some(self.key));
        }
    }
}

impl Drop for BuildGuard<'_> {
    fn drop(&mut self) {
        if self.armed {
            // Panic unwind path; the error path calls `fail` explicitly
            // with the builder's own message.
            self.fail("mapping build panicked");
        }
    }
}

/// The cache's locked state: the key → entry map plus the tick-ordered
/// LRU index. Both are maintained together under one mutex — every touch
/// restamps the entry's tick and moves its index row, so eviction walks
/// the index in use order instead of scanning the whole map.
struct CacheInner {
    map: HashMap<String, Arc<CacheEntry>>,
    /// Use tick → key. Ticks are unique (assigned under this lock), so
    /// this is a total LRU order over the resident entries.
    by_tick: BTreeMap<u64, String>,
}

/// Single-flight, LRU-bounded mapping cache. The outer map is only ever
/// locked for entry lookup/insert/evict — mapping happens against the
/// entry's own state mutex, and waiters for an in-flight mapping sleep on
/// the entry's `Condvar`.
struct MappingCache {
    inner: Mutex<CacheInner>,
    tick: AtomicU64,
    /// `0` = unbounded.
    capacity: usize,
    /// Retry-after budget for failed builds (`[coordinator] failure_ttl`):
    /// a `Failed` entry fast-fails the next `failure_ttl - 1` requests for
    /// its key, then the next one rebuilds in place. `0` = sticky forever
    /// (failures detach; only a fresh requester retries).
    failure_ttl: u64,
}

impl MappingCache {
    fn new(capacity: usize, failure_ttl: u64) -> Self {
        MappingCache {
            inner: Mutex::new(CacheInner { map: HashMap::new(), by_tick: BTreeMap::new() }),
            tick: AtomicU64::new(0),
            capacity,
            failure_ttl,
        }
    }

    /// Fetch `key`'s mapping, building it via `build` on a miss. Exactly
    /// one requester builds; concurrent requesters for the same key wait
    /// on the entry and share the result (counted as cache hits). On a
    /// build failure the entry turns sticky-`Failed` and leaves the map —
    /// the builder and every queued waiter report the error without
    /// re-running the (deterministic) mapping, while a later fresh
    /// requester gets a new entry and its own retry.
    fn get_or_map<F>(
        &self,
        key: &str,
        metrics: &Metrics,
        build: F,
    ) -> Result<(Arc<ServingMapping>, bool)>
    where
        F: FnOnce() -> Result<ServingMapping>,
    {
        let entry = {
            let mut inner = self.inner.lock().expect("cache map");
            // The use tick is assigned while the map is locked, so a
            // concurrent inserter can never observe (and evict) an entry
            // that has not been stamped yet — and the tick index moves in
            // the same critical section.
            let tick = self.tick.fetch_add(1, Ordering::Relaxed) + 1;
            match inner.map.get(key) {
                Some(e) => {
                    let e = Arc::clone(e);
                    let prev = e.last_use.swap(tick, Ordering::Relaxed);
                    // Reuse the removed key String — the hit path stays
                    // allocation-free.
                    let moved =
                        inner.by_tick.remove(&prev).unwrap_or_else(|| key.to_string());
                    debug_assert_eq!(moved, key);
                    inner.by_tick.insert(tick, moved);
                    e
                }
                None => {
                    // Loop, not a single evict: overshoot accumulated
                    // while entries were mid-build (unevictable) is
                    // reclaimed here once those entries turn Ready.
                    while self.capacity > 0
                        && inner.map.len() >= self.capacity
                        && evict_lru(&mut inner)
                    {}
                    let e = Arc::new(CacheEntry {
                        state: Mutex::new(EntryState::Empty),
                        ready: Condvar::new(),
                        last_use: AtomicU64::new(tick),
                    });
                    inner.map.insert(key.to_string(), Arc::clone(&e));
                    inner.by_tick.insert(tick, key.to_string());
                    e
                }
            }
        };

        let mut state = entry.state.lock().expect("cache entry");
        loop {
            match &mut *state {
                EntryState::Ready(m) => {
                    metrics.cache_hits.fetch_add(1, Ordering::Relaxed);
                    return Ok((Arc::clone(m), false));
                }
                EntryState::Building => {
                    state = entry.ready.wait(state).expect("cache entry");
                }
                // The builder failed; the mapping is deterministic, so
                // re-running it immediately would pay the whole attempt
                // lattice again for the same error — fail fast with the
                // builder's reason while the retry budget lasts. The
                // request that finds the budget at 1 falls through to
                // `Building` and rebuilds in place (failure TTL expired).
                EntryState::Failed { reason, retry_in } => {
                    if *retry_in <= 1 {
                        break;
                    }
                    *retry_in -= 1;
                    return Err(Error::Runtime(format!(
                        "mapping failed in a concurrent request: {reason}"
                    )));
                }
                EntryState::Empty => break,
            }
        }
        *state = EntryState::Building;
        drop(state);

        let mut unwind = BuildGuard { cache: self, key, entry: &entry, armed: true };
        let built = build();
        match built {
            Ok(m) => {
                // A miss is counted only when a fresh mapping actually
                // lands: a failed build followed by a fallback (e.g. the
                // fused → solo path) must not report two misses for one
                // request — failures have their own counter.
                metrics.cache_misses.fetch_add(1, Ordering::Relaxed);
                let m = Arc::new(m);
                let mut state = entry.state.lock().expect("cache entry");
                unwind.disarm();
                *state = EntryState::Ready(Arc::clone(&m));
                entry.ready.notify_all();
                Ok((m, true))
            }
            // Waiters fail fast on the sticky error; the detached entry
            // leaves the map so a *new* requester gets a fresh entry and
            // its own (deterministic) retry.
            Err(e) => {
                unwind.fail(&e.to_string());
                Err(e)
            }
        }
    }
}

/// Evict the least-recently-used *evictable* entry by walking the tick
/// index in use order — O(victim position in the index), not a full-map
/// scan. Only `Ready` entries (and TTL-resident `Failed` ones, which hold
/// no mapping) are victims: a `Building` entry is the single-flight
/// rendezvous for concurrent requesters, and an `Empty` entry belongs to
/// a requester that has looked it up but not yet locked it — evicting
/// either would detach an in-flight mapping from the cache
/// (the result would be built and then silently dropped, and a concurrent
/// same-key request would map a second time). Non-victims stay in the
/// index and are skipped. At capacity the map may therefore transiently
/// exceed its bound by the number of in-flight mappings — the insert path
/// loops eviction, so the overshoot is reclaimed as those entries turn
/// Ready. Use ticks are unique, so the victim is deterministic for a
/// given request history. Returns whether a victim was evicted.
fn evict_lru(inner: &mut CacheInner) -> bool {
    let victim = inner.by_tick.iter().find_map(|(&tick, key)| {
        let e = inner.map.get(key)?;
        match e.state.try_lock() {
            // The state mutex is only ever held briefly (never across a
            // mapping), so a contended entry is simply skipped this round.
            Ok(state)
                if matches!(&*state, EntryState::Ready(_) | EntryState::Failed { .. }) =>
            {
                Some((tick, key.clone()))
            }
            _ => None,
        }
    });
    match victim {
        Some((tick, key)) => {
            inner.by_tick.remove(&tick);
            inner.map.remove(&key);
            true
        }
        None => false,
    }
}

// ---------------------------------------------------------------------------
// The coordinator

enum Job {
    Single(SingleJob),
    Window(WindowJob),
}

struct SingleJob {
    id: u64,
    block: Arc<SparseBlock>,
    xs: Vec<Vec<f32>>,
    done: TicketCompleter,
    /// Shed (as `DeadlineExceeded`) at worker pickup once passed.
    deadline: Option<Instant>,
    /// Enqueue timestamp, for queue-span latency attribution.
    enqueued_at: Instant,
}

struct WindowJob {
    bundle: Arc<FusedBundle>,
    /// Member requests in window (enqueue) order.
    requests: Vec<WindowRequest>,
}

/// Ticket count aboard a job.
fn job_width(job: &Job) -> usize {
    match job {
        Job::Single(_) => 1,
        Job::Window(w) => w.requests.len(),
    }
}

/// Resolve every ticket aboard `job` to [`ServeError::WorkerGone`] (the
/// pool died with the job still queued).
fn resolve_worker_gone(job: Job) {
    match job {
        Job::Single(j) => j.done.fulfill(Err(ServeError::WorkerGone)),
        Job::Window(w) => {
            for r in w.requests {
                r.done.fulfill(Err(ServeError::WorkerGone));
            }
        }
    }
}

/// The bounded job queue plus an occupancy gauge for admission control.
/// The gauge counts enqueued-but-not-picked-up jobs: it is incremented
/// *before* the underlying send (and rolled back on failure) and
/// decremented by a worker at pickup — so it can transiently over-count
/// by the number of in-flight senders but never underflows (a wrap would
/// make the shed watermark reject everything).
struct JobQueue {
    tx: SyncSender<Job>,
    len: Arc<AtomicUsize>,
}

impl JobQueue {
    /// Blocking send (backpressure). On a closed queue the job is handed
    /// back so the caller can resolve its tickets.
    fn send(&self, job: Job) -> std::result::Result<(), Job> {
        self.len.fetch_add(1, Ordering::Relaxed);
        match self.tx.send(job) {
            Ok(()) => Ok(()),
            Err(SendError(job)) => {
                self.len.fetch_sub(1, Ordering::Relaxed);
                Err(job)
            }
        }
    }

    /// Non-blocking send, for admission control.
    fn try_send(&self, job: Job) -> std::result::Result<(), TrySendError<Job>> {
        self.len.fetch_add(1, Ordering::Relaxed);
        match self.tx.try_send(job) {
            Ok(()) => Ok(()),
            Err(e) => {
                self.len.fetch_sub(1, Ordering::Relaxed);
                Err(e)
            }
        }
    }

    /// Jobs currently queued (approximate under concurrent traffic, exact
    /// when quiescent).
    fn occupancy(&self) -> usize {
        self.len.load(Ordering::Relaxed)
    }
}

/// Panic counts per job identity — a solo block's mask fingerprint or a
/// bundle's combined fingerprint. A job that keeps killing its worker is
/// quarantined (resolved [`ServeError::Poisoned`], never retried) once
/// its count reaches `[coordinator] poison_threshold`, so one poison
/// request cannot burn the whole restart budget.
struct PoisonRegistry {
    counts: Mutex<HashMap<u64, u32>>,
}

impl PoisonRegistry {
    fn new() -> Self {
        PoisonRegistry { counts: Mutex::new(HashMap::new()) }
    }

    /// Record one panic against `identity`; returns the new count. The
    /// lock is poison-recovered: panic bookkeeping must keep working on
    /// the very code paths panics unwind through.
    fn record(&self, identity: u64) -> u32 {
        let mut counts = self.counts.lock().unwrap_or_else(|p| p.into_inner());
        let c = counts.entry(identity).or_insert(0);
        *c += 1;
        *c
    }

    fn count(&self, identity: u64) -> u32 {
        let counts = self.counts.lock().unwrap_or_else(|p| p.into_inner());
        counts.get(&identity).copied().unwrap_or(0)
    }
}

/// Everything a worker thread needs, bundled into one cloneable value so
/// the supervisor can respawn workers after the constructor returned.
#[derive(Clone)]
struct WorkerCtx {
    rx: Arc<Mutex<Receiver<Job>>>,
    queue_len: Arc<AtomicUsize>,
    cache: Arc<MappingCache>,
    bundles: Arc<BundleRoutes>,
    metrics: Arc<Metrics>,
    opts: MapperOptions,
    cgra: StreamingCgra,
    poison: Arc<PoisonRegistry>,
    poison_threshold: u32,
    /// Which simulation backend freshly built cache entries compile for.
    /// Resolved once at construction (config knob + env override).
    backend: SimBackend,
}

/// Legacy `submit`/`collect` shim state: an internal session core plus the
/// submission-order ticket queue `collect` drains.
struct LegacyState {
    core: SessionCore,
    fifo: VecDeque<Ticket>,
}

/// The streaming coordinator.
pub struct Coordinator {
    /// The only strong reference to the job queue: taking it (in
    /// [`Coordinator::shutdown`], also run by drop) closes the queue.
    /// Sessions and tickets hold weak refs only, so stray handles can
    /// never keep the pool alive. Behind a mutex so shutdown works
    /// through `&self`.
    tx: Mutex<Option<Arc<JobQueue>>>,
    /// The supervision thread that owns the worker pool (see
    /// [`supervisor_loop`]); joined on shutdown.
    supervisor: Mutex<Option<std::thread::JoinHandle<()>>>,
    pub metrics: Arc<Metrics>,
    bundles: Arc<BundleRoutes>,
    fusion: FusionOptions,
    batching: BatchOptions,
    cgra: StreamingCgra,
    shed_watermark: usize,
    legacy: Mutex<LegacyState>,
}

impl Coordinator {
    /// Spawn `cfg.workers` worker threads with a queue of depth
    /// `cfg.queue_depth` (a batching window occupies one slot), plus the
    /// supervisor thread that keeps the pool at strength.
    pub fn new(cfg: &SparsemapConfig) -> Self {
        let (tx, rx) = sync_channel::<Job>(cfg.queue_depth);
        let queue_len = Arc::new(AtomicUsize::new(0));
        let queue = Arc::new(JobQueue { tx, len: Arc::clone(&queue_len) });
        let rx = Arc::new(Mutex::new(rx));
        let cache = Arc::new(MappingCache::new(cfg.cache_capacity, cfg.failure_ttl));
        let bundles = Arc::new(BundleRoutes::new());
        let metrics = Arc::new(Metrics::default());
        let mut opts = MapperOptions::from_config(cfg);
        if opts.parallelism == 0 {
            // Auto portfolio width: split the machine between the worker
            // pool and each worker's mapping portfolio, so a burst of
            // cache misses doesn't oversubscribe cores. The mapping itself
            // is width-independent (deterministic portfolio), so this only
            // shapes latency.
            let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
            opts.parallelism = (cores / cfg.workers.max(1)).clamp(1, 8);
        }
        let fusion = opts.fusion;
        let batching = BatchOptions::from_config(cfg);
        let cgra = cfg.cgra.clone();

        let ctx = WorkerCtx {
            rx,
            queue_len,
            cache,
            bundles: Arc::clone(&bundles),
            metrics: Arc::clone(&metrics),
            opts,
            cgra: cgra.clone(),
            poison: Arc::new(PoisonRegistry::new()),
            poison_threshold: cfg.poison_threshold as u32,
            backend: SimBackend::effective(cfg.sim_backend),
        };
        let (exit_tx, exit_rx) = channel();
        let handles: Vec<Option<std::thread::JoinHandle<()>>> = (0..cfg.workers)
            .map(|wid| {
                Some(spawn_worker(wid, ctx.clone(), exit_tx.clone()).expect("spawn worker"))
            })
            .collect();
        let restart_budget = cfg.restart_budget;
        let supervisor = std::thread::Builder::new()
            .name("sparsemap-supervisor".into())
            .spawn(move || supervisor_loop(exit_rx, exit_tx, handles, ctx, restart_budget))
            .expect("spawn supervisor");

        Coordinator {
            tx: Mutex::new(Some(queue)),
            supervisor: Mutex::new(Some(supervisor)),
            metrics,
            bundles,
            fusion,
            batching,
            cgra,
            shed_watermark: cfg.shed_watermark,
            legacy: Mutex::new(LegacyState { core: SessionCore::new(), fifo: VecDeque::new() }),
        }
    }

    /// Open a serving session: the enqueue side of the ticket API. A
    /// coordinator serves any number of sessions (each forms its own
    /// batching windows).
    pub fn session(&self) -> ServeSession<'_> {
        ServeSession { coord: self, core: SessionCore::new(), issued: Vec::new() }
    }

    fn sender(&self) -> Option<Arc<JobQueue>> {
        self.tx.lock().unwrap_or_else(|p| p.into_inner()).clone()
    }

    /// Tear the worker pool down: seal any open legacy batching windows,
    /// close the job queue, and join the supervisor — which joins the
    /// workers and resolves anything still queued (`WorkerGone`).
    /// Idempotent, and also run by drop. Tickets issued before shutdown
    /// stay valid: every one of them resolves, and enqueues after
    /// shutdown resolve [`ServeError::QueueClosed`] immediately.
    pub fn shutdown(&self) {
        if let Ok(mut legacy) = self.legacy.lock() {
            legacy.core.flush_all();
        }
        self.tx.lock().unwrap_or_else(|p| p.into_inner()).take();
        let sup = self.supervisor.lock().unwrap_or_else(|p| p.into_inner()).take();
        if let Some(sup) = sup {
            let _ = sup.join();
        }
    }

    /// Register a fused bundle: from now on a request for *any* member
    /// block batches into the bundle's windows and is served through the
    /// bundle's shared fused mapping (one cache entry keyed by the
    /// bundle's combined mask fingerprint). Requests already served solo
    /// keep their solo cache entries — fused and unfused traffic mix
    /// freely.
    pub fn register_bundle(&self, bundle: Arc<FusedBundle>) {
        self.bundles.register(bundle);
    }

    /// Plan fusion over `blocks` with the configured knobs
    /// (`[mapper] max_fused_blocks` / `[mapper] fusion_max_ii`) and
    /// register every multi-block bundle. Returns the full plan
    /// (singletons included — they stay unregistered and serve solo).
    pub fn register_fused(&self, blocks: &[Arc<SparseBlock>]) -> Vec<FusedBundle> {
        let plan = plan_bundles(blocks, &self.cgra, &self.fusion);
        for bundle in &plan {
            if bundle.len() > 1 {
                self.register_bundle(Arc::new(bundle.clone()));
            }
        }
        plan
    }

    /// Submit a job; blocks when the queue is full (backpressure).
    #[deprecated(
        since = "0.2.0",
        note = "use Coordinator::session(): enqueue() returns a Ticket to wait on"
    )]
    pub fn submit(&self, req: InferRequest) -> Result<()> {
        let mut legacy = self.legacy.lock().expect("legacy serve state");
        let ticket = legacy.core.enqueue(self, req.id, req.block, req.xs, None);
        // Preserve the old contract: a queue that is already closed at
        // submission time surfaces here, not only at collect.
        if matches!(ticket.state.peek(), Some(Err(ServeError::QueueClosed))) {
            return Err(Error::Runtime("coordinator shut down".into()));
        }
        legacy.fifo.push_back(ticket);
        Ok(())
    }

    /// Collect exactly `n` results, in submission order (jobs are tagged
    /// by id). Waiting seals any batching window a pending submission sits
    /// in; slots beyond the outstanding submissions come back as
    /// `Err(Error::Runtime)`.
    #[deprecated(
        since = "0.2.0",
        note = "use Coordinator::session(): enqueue() returns a Ticket to wait on"
    )]
    pub fn collect(&self, n: usize) -> Vec<Result<InferResult>> {
        (0..n)
            .map(|_| {
                let ticket =
                    self.legacy.lock().expect("legacy serve state").fifo.pop_front();
                match ticket {
                    Some(t) => t.wait().map_err(Error::from),
                    None => Err(Error::Runtime(
                        "worker pool exited before delivering all results".into(),
                    )),
                }
            })
            .collect()
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.shutdown();
        if let Ok(mut legacy) = self.legacy.lock() {
            legacy.fifo.clear();
        }
    }
}

// ---------------------------------------------------------------------------
// Workers and supervision

/// Drop guard a worker thread holds for its whole life: tells the
/// supervisor the worker exited and whether it exited by panic. Running
/// in `Drop`, the notification survives any unwind path out of the
/// worker.
struct ExitGuard {
    id: usize,
    tx: Sender<(usize, bool)>,
}

impl Drop for ExitGuard {
    fn drop(&mut self) {
        let _ = self.tx.send((self.id, std::thread::panicking()));
    }
}

fn spawn_worker(
    wid: usize,
    ctx: WorkerCtx,
    exit_tx: Sender<(usize, bool)>,
) -> std::io::Result<std::thread::JoinHandle<()>> {
    std::thread::Builder::new()
        .name(format!("sparsemap-worker-{wid}"))
        .spawn(move || {
            let _exit = ExitGuard { id: wid, tx: exit_tx };
            worker_loop(&ctx);
        })
}

/// Supervision loop: collect worker exits, respawn panicked workers while
/// the restart budget lasts (the pool never shrinks silently — every
/// shrink logs), and once the last worker is gone keep draining the
/// queue, resolving every stranded ticket, until the coordinator closes
/// it. The drain is what makes "every enqueued ticket resolves" hold even
/// when persistent faults burn the whole budget mid-traffic.
fn supervisor_loop(
    exit_rx: Receiver<(usize, bool)>,
    exit_tx: Sender<(usize, bool)>,
    mut handles: Vec<Option<std::thread::JoinHandle<()>>>,
    ctx: WorkerCtx,
    restart_budget: usize,
) {
    let mut live = handles.len();
    let mut budget = restart_budget;
    while live > 0 {
        // Cannot disconnect while this thread holds `exit_tx`; defensive.
        let Ok((wid, panicked)) = exit_rx.recv() else { break };
        if let Some(h) = handles[wid].take() {
            let _ = h.join();
        }
        if !panicked {
            // Clean exit: the queue closed and the worker drained out.
            live -= 1;
            continue;
        }
        // Per-job catch_unwind makes a worker-killing panic rare (only a
        // fault outside the guarded region reaches the thread boundary),
        // but the pool must survive it regardless.
        if budget == 0 {
            live -= 1;
            crate::log_warn!(
                "worker {wid} died with the restart budget exhausted; pool shrinks to \
                 {live} workers"
            );
            continue;
        }
        budget -= 1;
        match spawn_worker(wid, ctx.clone(), exit_tx.clone()) {
            Ok(h) => {
                ctx.metrics.worker_restarts.fetch_add(1, Ordering::Relaxed);
                crate::log_warn!(
                    "worker {wid} died by panic; respawned ({budget} restarts left)"
                );
                handles[wid] = Some(h);
            }
            Err(e) => {
                live -= 1;
                crate::log_error!("respawning worker {wid} failed ({e}); pool shrinks");
            }
        }
    }
    // Whole pool gone — restart budget exhausted under persistent faults,
    // or plain shutdown. Resolve everything queued (and everything still
    // arriving from senders that raced the pool's death) until the
    // coordinator closes the queue, so no ticket ever hangs.
    loop {
        let job = {
            let guard = ctx.rx.lock().unwrap_or_else(|p| p.into_inner());
            guard.recv()
        };
        match job {
            Ok(job) => {
                ctx.queue_len.fetch_sub(1, Ordering::Relaxed);
                ctx.metrics.failures.fetch_add(job_width(&job) as u64, Ordering::Relaxed);
                resolve_worker_gone(job);
            }
            Err(_) => return,
        }
    }
}

fn worker_loop(ctx: &WorkerCtx) {
    loop {
        let job = {
            // Poison-recover: a panicking peer must not wedge the whole
            // pool on this lock — the receiver behind it is just data.
            let guard = ctx.rx.lock().unwrap_or_else(|p| p.into_inner());
            guard.recv()
        };
        match job {
            Ok(job) => {
                ctx.queue_len.fetch_sub(1, Ordering::Relaxed);
                // Hard-death site: a panic here is OUTSIDE the per-job
                // catch_unwind, so it kills the worker thread itself and
                // exercises supervisor respawn. The job's completers
                // resolve `WorkerGone` as the unwind drops them.
                crate::fail_point!("coordinator::worker_hard");
                match job {
                    Job::Single(job) => execute_single(job, ctx),
                    Job::Window(job) => execute_window(job, ctx),
                }
            }
            Err(_) => return,
        }
    }
}

/// Serve one solo request end to end and fulfill its ticket: deadline
/// check at pickup, then mapping + simulation under a per-job
/// `catch_unwind`, retried in place until the job identity's poison
/// quarantine trips.
fn execute_single(job: SingleJob, ctx: &WorkerCtx) {
    let picked = Instant::now();
    ctx.metrics.jobs.fetch_add(1, Ordering::Relaxed);
    let SingleJob { id, block, xs, done, deadline, enqueued_at } = job;
    if deadline.is_some_and(|d| picked >= d) {
        ctx.metrics.deadline_expired.fetch_add(1, Ordering::Relaxed);
        done.fulfill(Err(ServeError::DeadlineExceeded));
        return;
    }
    let identity = block.mask_fingerprint();
    let queue_ns = picked.saturating_duration_since(enqueued_at).as_nanos() as u64;
    loop {
        if ctx.poison.count(identity) >= ctx.poison_threshold {
            ctx.metrics.poisoned.fetch_add(1, Ordering::Relaxed);
            ctx.metrics.failures.fetch_add(1, Ordering::Relaxed);
            done.fulfill(Err(ServeError::Poisoned));
            return;
        }
        // The closure borrows the payload and owns no completer: a panic
        // unwinds out of it without resolving (or double-resolving) the
        // ticket — fulfillment happens below, outside the guard.
        let attempt = catch_unwind(AssertUnwindSafe(|| {
            crate::fail_point!("coordinator::serve");
            crate::fail_point!("coordinator::delay");
            serve_solo(&block, &xs, ctx)
        }));
        match attempt {
            Ok(Ok((outputs, cycles, ii, fresh))) => {
                ctx.metrics.total_cycles.fetch_add(cycles, Ordering::Relaxed);
                let service_ns = picked.elapsed().as_nanos() as u64;
                let latency_ns = queue_ns + service_ns;
                ctx.metrics.total_latency_ns.fetch_add(latency_ns, Ordering::Relaxed);
                ctx.metrics.observe_latency(queue_ns, service_ns);
                done.fulfill(Ok(InferResult {
                    id,
                    block_name: block.name.clone(),
                    outputs,
                    cycles,
                    ii,
                    mapped_fresh: fresh,
                    fused_members: 1,
                    latency_ns,
                    queue_ns,
                    service_ns,
                }));
                return;
            }
            Ok(Err(e)) => {
                ctx.metrics.failures.fetch_add(1, Ordering::Relaxed);
                done.fulfill(Err(e));
                return;
            }
            Err(_) => {
                // The worker survived the panic (caught in place): count
                // a restart, record the poison strike, retry the job.
                ctx.metrics.worker_restarts.fetch_add(1, Ordering::Relaxed);
                let strikes = ctx.poison.record(identity);
                crate::log_warn!(
                    "serving {} panicked (strike {strikes}); {}",
                    block.name,
                    if strikes >= ctx.poison_threshold {
                        "quarantining"
                    } else {
                        "retrying in place"
                    }
                );
            }
        }
    }
}

/// Solo path: compile-once mapping keyed by block identity. The key
/// carries the mask's content fingerprint — name and shape alone would
/// silently alias two differently-pruned blocks onto one mapping.
fn serve_solo(
    block: &Arc<SparseBlock>,
    xs: &[Vec<f32>],
    ctx: &WorkerCtx,
) -> std::result::Result<(Vec<Vec<f32>>, u64, usize, bool), ServeError> {
    let fp = block.mask_fingerprint();
    let key = format!("{}#{}x{}@{fp:016x}", block.name, block.c, block.k);
    let (serving, fresh) = ctx
        .cache
        .get_or_map(&key, &ctx.metrics, || {
            crate::fail_point_error!("coordinator::map", |msg: String| Err(Error::Runtime(
                msg
            )));
            let outcome = map_unit(MapUnit::Single(block), &ctx.cgra, &ctx.opts)?;
            let plan = compile_serving_plan(&key, &outcome, ctx);
            Ok(ServingMapping { outcome, bundle: None, plan })
        })
        .map_err(|e| ServeError::MappingFailed(e.to_string()))?;
    crate::fail_point_error!("coordinator::sim", |msg: String| Err(ServeError::Sim(msg)));
    match serving.plan.as_ref() {
        Some(plan) => {
            // Solo block as a one-member window: same compiled inner loop
            // the batched path runs, same bit-exact results.
            let batches = vec![vec![MemberSegment { block: block.as_ref(), xs }]];
            let res = execute_plan_batch(plan, &[block.as_ref()], &batches)
                .map_err(|e| ServeError::Sim(e.to_string()))?;
            let outputs = res
                .per_member
                .into_iter()
                .next()
                .and_then(|m| m.segments.into_iter().next())
                .map(|s| s.outputs)
                .unwrap_or_default();
            Ok((outputs, res.cycles, serving.outcome.mapping.ii, fresh))
        }
        None => {
            let res = simulate(&serving.outcome.mapping, block, &ctx.cgra, xs)
                .map_err(|e| ServeError::Sim(e.to_string()))?;
            Ok((res.outputs, res.cycles, serving.outcome.mapping.ii, fresh))
        }
    }
}

/// Compile the execution plan for a freshly built cache entry, honouring
/// the backend knob. Compilation failure is survivable by design: log
/// loudly and serve the entry off the scalar interpreter instead — a
/// degraded-throughput entry, never a lost ticket.
fn compile_serving_plan(key: &str, outcome: &MapOutcome, ctx: &WorkerCtx) -> Option<ExecPlan> {
    if ctx.backend != SimBackend::Compiled {
        return None;
    }
    match try_compile_plan(outcome, &ctx.cgra) {
        Ok(plan) => Some(plan),
        Err(e) => {
            crate::log_warn!(
                "execution-plan compile failed for {key} ({e}); serving falls back to the scalar interpreter"
            );
            None
        }
    }
}

/// The fallible half of plan compilation, isolated so the
/// `coordinator::plan` failpoint can early-return an `Err` without
/// touching the caller's fallback handling.
fn try_compile_plan(outcome: &MapOutcome, cgra: &StreamingCgra) -> Result<ExecPlan> {
    crate::fail_point_error!("coordinator::plan", |msg: String| Err(Error::Runtime(msg)));
    ExecPlan::for_outcome(outcome, cgra)
}

/// Serve one batching window: shed expired members at pickup, then fetch
/// the bundle's shared fused mapping and run ONE lockstep pass for the
/// whole window, under the same `catch_unwind` + poison-quarantine
/// discipline as solo serving (quarantine keyed by the bundle
/// fingerprint). An unmappable bundle deregisters loudly and its live
/// members fall back to solo serving.
fn execute_window(job: WindowJob, ctx: &WorkerCtx) {
    let picked = Instant::now();
    let WindowJob { bundle, requests } = job;
    let mut live = Vec::with_capacity(requests.len());
    for r in requests {
        if r.deadline.is_some_and(|d| picked >= d) {
            ctx.metrics.jobs.fetch_add(1, Ordering::Relaxed);
            ctx.metrics.deadline_expired.fetch_add(1, Ordering::Relaxed);
            r.done.fulfill(Err(ServeError::DeadlineExceeded));
        } else {
            live.push(r);
        }
    }
    if live.is_empty() {
        return;
    }
    let identity = bundle.fingerprint();
    let w = live.len() as u64;
    loop {
        if ctx.poison.count(identity) >= ctx.poison_threshold {
            ctx.metrics.jobs.fetch_add(w, Ordering::Relaxed);
            ctx.metrics.poisoned.fetch_add(w, Ordering::Relaxed);
            ctx.metrics.failures.fetch_add(w, Ordering::Relaxed);
            for r in live {
                r.done.fulfill(Err(ServeError::Poisoned));
            }
            return;
        }
        let attempt = catch_unwind(AssertUnwindSafe(|| {
            crate::fail_point!("coordinator::serve");
            crate::fail_point!("coordinator::delay");
            attempt_window(&bundle, &live, ctx)
        }));
        match attempt {
            Ok(WindowAttempt::Served { segments, pass_cycles, ii, fresh, members }) => {
                ctx.metrics.jobs.fetch_add(w, Ordering::Relaxed);
                ctx.metrics.windows.fetch_add(1, Ordering::Relaxed);
                // The window pays for the resident configuration ONCE —
                // this is the fused double-count fix: W member requests
                // never charge W whole-bundle passes.
                ctx.metrics.total_cycles.fetch_add(pass_cycles, Ordering::Relaxed);
                let service_ns = picked.elapsed().as_nanos() as u64;
                for (ri, (r, seg)) in live.into_iter().zip(segments).enumerate() {
                    let queue_ns =
                        picked.saturating_duration_since(r.enqueued_at).as_nanos() as u64;
                    let latency_ns = queue_ns + service_ns;
                    ctx.metrics.total_latency_ns.fetch_add(latency_ns, Ordering::Relaxed);
                    ctx.metrics.observe_latency(queue_ns, service_ns);
                    r.done.fulfill(Ok(InferResult {
                        id: r.id,
                        block_name: r.block.name.clone(),
                        outputs: seg.outputs,
                        cycles: seg.cycles,
                        ii,
                        mapped_fresh: fresh && ri == 0,
                        fused_members: members,
                        latency_ns,
                        queue_ns,
                        service_ns,
                    }));
                }
                return;
            }
            Ok(WindowAttempt::SimFailed(err)) => {
                ctx.metrics.jobs.fetch_add(w, Ordering::Relaxed);
                ctx.metrics.failures.fetch_add(w, Ordering::Relaxed);
                for r in live {
                    r.done.fulfill(Err(err.clone()));
                }
                return;
            }
            // The planner admits bundles by the MII estimate, not bind
            // feasibility, so a registered bundle can turn out unmappable.
            // The mapper is deterministic — it would fail (and re-pay the
            // whole attempt lattice) on every member window forever — so
            // drop the registration and serve this window's and all
            // future member traffic through the working solo path.
            // Loudly: the silently-lost residency win would otherwise be
            // undiagnosable (requests succeed, failures stays 0).
            Ok(WindowAttempt::Unmappable(e)) => {
                crate::log_warn!(
                    "bundle {} is unmappable ({e}); deregistering — its {} members fall \
                     back to solo serving",
                    bundle.name,
                    bundle.len()
                );
                ctx.bundles.deregister(&bundle);
                for r in live {
                    execute_single(
                        SingleJob {
                            id: r.id,
                            block: r.block,
                            xs: r.xs,
                            done: r.done,
                            deadline: r.deadline,
                            enqueued_at: r.enqueued_at,
                        },
                        ctx,
                    );
                }
                return;
            }
            Err(_) => {
                ctx.metrics.worker_restarts.fetch_add(1, Ordering::Relaxed);
                let strikes = ctx.poison.record(identity);
                crate::log_warn!(
                    "window for bundle {} panicked (strike {strikes}); {}",
                    bundle.name,
                    if strikes >= ctx.poison_threshold {
                        "quarantining"
                    } else {
                        "retrying in place"
                    }
                );
            }
        }
    }
}

/// Outcome of one fused window attempt, computed inside the per-job
/// unwind guard (borrowing the live requests) and consumed outside it —
/// ticket fulfillment never happens under `catch_unwind`.
enum WindowAttempt {
    Served {
        /// One simulated segment per live request, in window order.
        segments: Vec<SegmentSim>,
        pass_cycles: u64,
        ii: usize,
        fresh: bool,
        members: usize,
    },
    /// The bundle's shared fused mapping failed to build: the caller
    /// deregisters the bundle and falls back to solo serving.
    Unmappable(Error),
    /// The lockstep pass faulted: every member request fails.
    SimFailed(ServeError),
}

/// Fetch (or build) the fused mapping and run the window's single
/// lockstep pass. Borrows the requests — the caller keeps ownership (and
/// the completers) outside the unwind guard.
fn attempt_window(
    bundle: &Arc<FusedBundle>,
    requests: &[WindowRequest],
    ctx: &WorkerCtx,
) -> WindowAttempt {
    let (serving, fresh) = match fused_serving(bundle, ctx) {
        Ok(sf) => sf,
        Err(e) => return WindowAttempt::Unmappable(e),
    };
    // One cache access served the whole window: count the other member
    // requests as hits so `jobs == hits + misses` keeps holding for
    // successful traffic.
    ctx.metrics.cache_hits.fetch_add(requests.len() as u64 - 1, Ordering::Relaxed);
    crate::fail_point_error!("coordinator::sim", |msg: String| WindowAttempt::SimFailed(
        ServeError::Sim(msg)
    ));
    let resident = serving.bundle.as_ref().expect("fused entry carries its bundle");
    // Member → request indices, in window order (the per-member segment
    // order the batched pass preserves).
    let mut member_reqs: Vec<Vec<usize>> = vec![Vec::new(); resident.len()];
    for (ri, r) in requests.iter().enumerate() {
        debug_assert!(r.member < resident.len(), "routed member index in range");
        member_reqs[r.member].push(ri);
    }
    // The member's weights come from each request (same mask structure —
    // that is what the fingerprint routing matched); members absent from
    // the window stream zeros via padding.
    let blocks: Vec<&SparseBlock> = resident.blocks.iter().map(|b| b.as_ref()).collect();
    let batches: Vec<Vec<MemberSegment<'_>>> = member_reqs
        .iter()
        .map(|idxs| {
            idxs.iter()
                .map(|&ri| MemberSegment {
                    block: requests[ri].block.as_ref(),
                    xs: requests[ri].xs.as_slice(),
                })
                .collect()
        })
        .collect();
    let sim = match serving.plan.as_ref() {
        Some(plan) => execute_plan_batch(plan, &blocks, &batches),
        None => simulate_fused_batch(
            &serving.outcome.mapping,
            &serving.outcome.tags,
            &blocks,
            &ctx.cgra,
            &batches,
        ),
    };
    match sim {
        Ok(res) => {
            let w = requests.len();
            let mut per_request: Vec<Option<SegmentSim>> = Vec::new();
            per_request.resize_with(w, || None);
            for (mi, m) in res.per_member.into_iter().enumerate() {
                for (seg, &ri) in m.segments.into_iter().zip(&member_reqs[mi]) {
                    per_request[ri] = Some(seg);
                }
            }
            let segments = per_request
                .into_iter()
                .map(|s| s.expect("one segment per request"))
                .collect();
            WindowAttempt::Served {
                segments,
                pass_cycles: res.cycles,
                ii: serving.outcome.mapping.ii,
                fresh,
                members: resident.len(),
            }
        }
        Err(e) => WindowAttempt::SimFailed(ServeError::Sim(e.to_string())),
    }
}

/// Map (or fetch from cache) a registered bundle's shared fused mapping.
/// A mapping error here means the bundle cannot map on this fabric at
/// all — the caller falls back to solo serving; request-specific errors
/// never originate here.
fn fused_serving(
    bundle: &Arc<FusedBundle>,
    ctx: &WorkerCtx,
) -> Result<(Arc<ServingMapping>, bool)> {
    let key = format!("{}@bundle:{:016x}", bundle.name, bundle.fingerprint());
    ctx.cache.get_or_map(&key, &ctx.metrics, || {
        crate::fail_point_error!("coordinator::map", |msg: String| Err(Error::Runtime(msg)));
        // A bundle's combined MII sits far above the members' own MIIs and
        // the slot-offset composition needs II headroom: widen the slack
        // to the fused operating point unless the config is already wider.
        let mut bopts = ctx.opts.clone();
        bopts.ii_slack = bopts.ii_slack.max(MapperOptions::fused().ii_slack);
        let outcome = map_unit(MapUnit::Bundle(bundle), &ctx.cgra, &bopts)?;
        let plan = compile_serving_plan(&key, &outcome, ctx);
        Ok(ServingMapping { outcome, bundle: Some(Arc::clone(bundle)), plan })
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen::paper_blocks;

    fn small_cfg() -> SparsemapConfig {
        let mut cfg = SparsemapConfig::default();
        cfg.workers = 2;
        cfg.queue_depth = 4;
        cfg.mis_iterations = 20_000;
        cfg
    }

    fn stream_for(block: &SparseBlock, n: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = crate::util::rng::Pcg64::seeded(seed);
        (0..n)
            .map(|_| (0..block.c).map(|_| rng.next_normal() as f32).collect())
            .collect()
    }

    #[test]
    fn processes_jobs_and_caches_mappings() {
        let cfg = small_cfg();
        let coord = Coordinator::new(&cfg);
        let mut session = coord.session();
        let block = Arc::new(paper_blocks()[1].block.clone());
        let tickets: Vec<Ticket> = (0..6u64)
            .map(|seed| session.enqueue(Arc::clone(&block), stream_for(&block, 8, seed)))
            .collect();
        for (i, t) in tickets.into_iter().enumerate() {
            assert_eq!(t.id(), i as u64);
            assert_eq!(t.block_name(), block.name);
            let r = t.wait().expect("job ok");
            assert_eq!(r.outputs.len(), 8);
            assert_eq!(r.fused_members, 1);
        }
        let m = coord.metrics.snapshot();
        assert_eq!(m.jobs, 6);
        assert_eq!(m.failures, 0);
        assert_eq!(m.cache_misses, 1, "one block → one mapping");
        assert_eq!(m.cache_hits, 5);
        assert_eq!(m.windows, 0, "solo traffic forms no windows");
    }

    #[test]
    fn outputs_match_reference_forward() {
        let cfg = small_cfg();
        let coord = Coordinator::new(&cfg);
        let mut session = coord.session();
        let block = Arc::new(paper_blocks()[2].block.clone());
        let xs = stream_for(&block, 12, 9);
        let r = session.enqueue(Arc::clone(&block), xs.clone()).wait().unwrap();
        for (x, y) in xs.iter().zip(&r.outputs) {
            let want = block.forward(x);
            for (a, b) in y.iter().zip(&want) {
                assert!((a - b).abs() < 1e-4 * (1.0 + b.abs()), "{a} vs {b}");
            }
        }
    }

    #[test]
    fn same_shape_different_masks_do_not_share_mappings() {
        // Regression: the cache used to key by name#CxK only, so two blocks
        // with equal name and shape but different sparsity patterns shared
        // one mapping and returned wrong outputs for the second.
        let cfg = small_cfg();
        let coord = Coordinator::new(&cfg);
        let mut session = coord.session();
        let a = Arc::new(
            SparseBlock::from_mask(
                "twin",
                3,
                3,
                vec![true, true, false, false, true, true, true, false, true],
            )
            .unwrap(),
        );
        let b = Arc::new(
            SparseBlock::from_mask(
                "twin",
                3,
                3,
                vec![true, false, true, true, true, false, false, true, true],
            )
            .unwrap(),
        );
        let xs = stream_for(&a, 6, 3);
        let ta = session.enqueue(Arc::clone(&a), xs.clone());
        let tb = session.enqueue(Arc::clone(&b), xs.clone());
        for (block, ticket) in [(&a, ta), (&b, tb)] {
            let r = ticket.wait().expect("job ok");
            for (x, y) in xs.iter().zip(&r.outputs) {
                let want = block.forward(x);
                for (got, w) in y.iter().zip(&want) {
                    assert!(
                        (got - w).abs() < 1e-4 * (1.0 + w.abs()),
                        "{}: {got} vs {w}",
                        block.name
                    );
                }
            }
        }
        assert_eq!(coord.metrics.snapshot().cache_misses, 2, "one mapping per mask");
    }

    fn tiny(name: &str, c: usize, k: usize, mask: Vec<bool>) -> Arc<SparseBlock> {
        Arc::new(SparseBlock::from_mask(name, c, k, mask).unwrap())
    }

    fn tiny_members() -> Vec<Arc<SparseBlock>> {
        vec![
            tiny("f1", 2, 2, vec![true, false, true, true]),
            tiny("f2", 3, 2, vec![true, true, false, true, true, false]),
            tiny("f3", 2, 3, vec![true, false, true, false, true, true]),
        ]
    }

    #[test]
    fn tickets_resolve_queue_closed_when_pool_is_shut_down() {
        let cfg = small_cfg();
        let coord = Coordinator::new(&cfg);
        // Tear the pool down out from under the session: exactly the
        // state a late enqueue races against.
        coord.shutdown();
        let mut session = coord.session();
        let block = tiny("late", 2, 2, vec![true, false, true, true]);
        let t = session.enqueue(Arc::clone(&block), stream_for(&block, 2, 1));
        match t.wait() {
            Err(ServeError::QueueClosed) => {}
            other => panic!("expected QueueClosed, got {other:?}"),
        }
    }

    #[test]
    fn wait_timeout_expires_then_result_stays_claimable() {
        let state = TicketState::new();
        let done = TicketCompleter { state: Arc::clone(&state) };
        let mut t = Ticket { id: 1, block_name: "x".into(), state, window: None };
        assert!(
            t.wait_timeout(Duration::from_millis(5)).is_none(),
            "pending ticket times out with None"
        );
        done.fulfill(Err(ServeError::QueueClosed));
        assert!(matches!(
            t.wait_timeout(Duration::ZERO),
            Some(Err(ServeError::QueueClosed))
        ));
        // The timed wait clones — the result stays claimable by `wait`.
        assert!(matches!(t.wait(), Err(ServeError::QueueClosed)));
    }

    #[test]
    fn dropping_a_ticket_cancels_its_window_request() {
        // An unwaited ticket dropped while its request still rides an
        // open window withdraws the request: the window serves without
        // it, and abandoned work is never simulated.
        let mut cfg = small_cfg();
        cfg.batch_window_requests = 100; // only an explicit flush seals
        let coord = Coordinator::new(&cfg);
        let members = tiny_members();
        coord.register_bundle(Arc::new(FusedBundle::new(members.clone()).unwrap()));
        let mut session = coord.session();
        let keep = session.enqueue(Arc::clone(&members[0]), stream_for(&members[0], 2, 1));
        let cancel =
            session.enqueue(Arc::clone(&members[1]), stream_for(&members[1], 2, 2));
        drop(cancel);
        session.drain();
        let r = keep.wait().expect("survivor ok");
        assert_eq!(r.fused_members, 3, "still served through the bundle");
        let m = coord.metrics.snapshot();
        assert_eq!(m.jobs, 1, "the cancelled request was never dispatched");
        assert_eq!(m.windows, 1);
    }

    #[test]
    fn zero_deadline_requests_shed_at_pickup() {
        let cfg = small_cfg();
        let coord = Coordinator::new(&cfg);
        let mut session = coord.session();
        let block = tiny("rush", 2, 2, vec![true, false, true, true]);
        let t = session.enqueue_with_deadline(
            Arc::clone(&block),
            stream_for(&block, 2, 1),
            Duration::ZERO,
        );
        match t.wait() {
            Err(ServeError::DeadlineExceeded) => {}
            other => panic!("expected DeadlineExceeded, got {other:?}"),
        }
        let m = coord.metrics.snapshot();
        assert_eq!(m.deadline_expired, 1);
        assert_eq!(m.jobs, 1, "the request was picked up (then shed)");
        assert_eq!(m.failures, 0, "a deadline shed is not a serving fault");
    }

    #[test]
    fn failure_ttl_retries_after_budget() {
        // failure_ttl = 3: after a failed build the entry stays resident;
        // the next two requests fail fast, the third rebuilds in place.
        let cache = MappingCache::new(4, 3);
        let metrics = Metrics::default();
        let err = cache
            .get_or_map("flaky", &metrics, || Err(Error::Workload("transient".into())));
        assert!(err.is_err());
        {
            let inner = cache.inner.lock().unwrap();
            assert_eq!(inner.map.len(), 1, "failed entry stays resident under a TTL");
        }
        for _ in 0..2 {
            match cache.get_or_map("flaky", &metrics, || unreachable!("fail-fast window")) {
                Err(e) => assert!(e.to_string().contains("transient"), "{e}"),
                Ok(_) => panic!("request inside the fail-fast window must error"),
            }
        }
        // TTL exhausted: the next request re-runs the build.
        let block = tiny("flaky", 2, 2, vec![true, false, true, true]);
        let cgra = StreamingCgra::paper_default();
        let opts = MapperOptions::sparsemap();
        let (_, fresh) = cache
            .get_or_map("flaky", &metrics, || {
                let outcome = map_unit(MapUnit::Single(&block), &cgra, &opts)?;
                Ok(ServingMapping { outcome, bundle: None, plan: None })
            })
            .unwrap();
        assert!(fresh, "the post-TTL request rebuilds");
        let (_, fresh) = cache
            .get_or_map("flaky", &metrics, || unreachable!("now cached"))
            .unwrap();
        assert!(!fresh);
    }

    #[test]
    fn dropped_completer_resolves_worker_gone() {
        // A worker that dies mid-job (panic/teardown) drops the completer
        // unfulfilled: the ticket must resolve instead of hanging.
        let state = TicketState::new();
        let done = TicketCompleter { state: Arc::clone(&state) };
        let mut t = Ticket { id: 7, block_name: "x".into(), state, window: None };
        assert!(t.try_wait().is_none(), "pending ticket polls None");
        drop(done);
        assert!(matches!(t.try_wait(), Some(Err(ServeError::WorkerGone))));
        assert!(matches!(t.wait(), Err(ServeError::WorkerGone)));
    }

    #[test]
    fn completion_is_first_wins() {
        let state = TicketState::new();
        let done = TicketCompleter { state: Arc::clone(&state) };
        done.fulfill(Err(ServeError::QueueClosed));
        // The drop guard ran after fulfill and must not overwrite.
        let t = Ticket { id: 0, block_name: "x".into(), state, window: None };
        assert!(matches!(t.wait(), Err(ServeError::QueueClosed)));
    }

    #[test]
    fn fused_bundle_serves_member_requests_through_one_window() {
        let cfg = small_cfg();
        let coord = Coordinator::new(&cfg);
        let members = tiny_members();
        let bundle = Arc::new(FusedBundle::new(members.clone()).unwrap());
        coord.register_bundle(Arc::clone(&bundle));

        let mut session = coord.session();
        let mut tickets = Vec::new();
        let mut streams = Vec::new();
        for (i, member) in members.iter().enumerate() {
            let xs = stream_for(member, 5, 100 + i as u64);
            tickets.push(session.enqueue(Arc::clone(member), xs.clone()));
            streams.push(xs);
        }
        session.drain();
        for (i, t) in tickets.into_iter().enumerate() {
            let r = t.wait().expect("fused job ok");
            let member = &members[i];
            assert_eq!(r.block_name, member.name);
            assert_eq!(r.fused_members, 3, "served through the bundle");
            for (x, y) in streams[i].iter().zip(&r.outputs) {
                let want = member.forward(x);
                assert_eq!(y.len(), want.len());
                for (a, w) in y.iter().zip(&want) {
                    assert!((a - w).abs() < 1e-4 * (1.0 + w.abs()), "{i}: {a} vs {w}");
                }
            }
        }
        let m = coord.metrics.snapshot();
        assert_eq!(m.jobs, 3);
        assert_eq!(m.failures, 0);
        assert_eq!(m.cache_misses, 1, "three member blocks → one fused mapping");
        assert_eq!(m.cache_hits, 2);
        assert_eq!(m.windows, 1, "three member requests → ONE lockstep pass");
    }

    #[test]
    fn mixed_fused_and_unfused_traffic() {
        let cfg = small_cfg();
        let coord = Coordinator::new(&cfg);
        let members = tiny_members();
        let bundle = Arc::new(FusedBundle::new(members[..2].to_vec()).unwrap());
        coord.register_bundle(bundle);

        let mut session = coord.session();
        let mut tickets = Vec::new();
        let mut streams = Vec::new();
        for (i, block) in members.iter().enumerate() {
            let xs = stream_for(block, 4, 7 + i as u64);
            tickets.push(session.enqueue(Arc::clone(block), xs.clone()));
            streams.push(xs);
        }
        session.drain();
        for (i, t) in tickets.into_iter().enumerate() {
            let r = t.wait().expect("mixed job ok");
            let member = &members[i];
            let want_members = if i < 2 { 2 } else { 1 };
            assert_eq!(r.fused_members, want_members, "{}", member.name);
            for (x, y) in streams[i].iter().zip(&r.outputs) {
                let want = member.forward(x);
                for (a, w) in y.iter().zip(&want) {
                    assert!((a - w).abs() < 1e-4 * (1.0 + w.abs()), "{i}: {a} vs {w}");
                }
            }
        }
        let m = coord.metrics.snapshot();
        assert_eq!(m.cache_misses, 2, "one fused + one solo mapping");
        assert_eq!(m.windows, 1, "the two member requests share one window");
    }

    #[test]
    fn windows_form_deterministically_from_enqueue_order() {
        // Window contents are a pure function of enqueue order and the
        // two knobs — no timing involved.
        let run = |window_requests: usize, window_max: usize, n: usize| -> (u64, u64) {
            let mut cfg = small_cfg();
            cfg.batch_window_requests = window_requests;
            cfg.batch_window_max = window_max;
            let coord = Coordinator::new(&cfg);
            let members = tiny_members();
            coord.register_bundle(Arc::new(FusedBundle::new(members.clone()).unwrap()));
            let mut session = coord.session();
            let tickets: Vec<Ticket> = (0..n)
                .map(|i| {
                    let b = &members[i % members.len()];
                    session.enqueue(Arc::clone(b), stream_for(b, 2, i as u64))
                })
                .collect();
            session.drain();
            for t in tickets {
                t.wait().expect("windowed job ok");
            }
            let m = coord.metrics.snapshot();
            (m.windows, m.jobs)
        };
        // 7 requests at window size 3 → 3 + 3 + 1 (trailing flush).
        assert_eq!(run(3, 0, 7), (3, 7));
        assert_eq!(run(3, 0, 7), (3, 7), "repeat runs form identical windows");
        // Window size 1 disables aggregation: one pass per request.
        assert_eq!(run(1, 0, 5), (5, 5));
        // The iteration cap seals windows too: requests bring 2 iterations
        // each, round-robin over 3 members, so a cap of 4 seals a window
        // every time some member's total reaches 4 — the request-count
        // knob (100) never triggers. 12 requests must split into several
        // windows, identically on every run.
        let first = run(100, 4, 12);
        assert_eq!(first.1, 12);
        assert!(
            first.0 > 1,
            "the iteration cap must split an under-count window (got {})",
            first.0
        );
        assert_eq!(run(100, 4, 12), first, "cap-driven windows are deterministic too");
    }

    #[test]
    fn iteration_cap_never_pads_an_earlier_short_request() {
        // A short request aboard an open window must not share a lockstep
        // pass with a later rider that would blow the iteration cap: the
        // window seals *before* the oversized request is admitted.
        let mut cfg = small_cfg();
        cfg.batch_window_requests = 100;
        cfg.batch_window_max = 8;
        let coord = Coordinator::new(&cfg);
        let members = tiny_members();
        coord.register_bundle(Arc::new(FusedBundle::new(members.clone()).unwrap()));
        let mut session = coord.session();
        let short = session.enqueue(Arc::clone(&members[0]), stream_for(&members[0], 2, 1));
        let long = session.enqueue(Arc::clone(&members[1]), stream_for(&members[1], 20, 2));
        session.drain();
        let short = short.wait().expect("short request ok");
        let long = long.wait().expect("long request ok");
        assert_eq!(
            coord.metrics.snapshot().windows,
            2,
            "the oversized rider opens (and immediately seals) its own window"
        );
        assert!(
            short.cycles < long.cycles,
            "the short request ({} cycles) must not be charged the rider's \
             padded pass ({} cycles)",
            short.cycles,
            long.cycles
        );
    }

    #[test]
    fn lru_evicts_least_recently_used_mapping() {
        // Serialized single-worker traffic so the use order is exact:
        // A, B fill a capacity-2 cache; touching A makes B the LRU victim
        // when C arrives; B then re-maps on its next request.
        let mut cfg = small_cfg();
        cfg.workers = 1;
        cfg.cache_capacity = 2;
        let coord = Coordinator::new(&cfg);
        let blocks = tiny_members(); // a, b, c stand-ins
        let mut session = coord.session();
        let mut seed = 0u64;
        let mut run = |session: &mut ServeSession<'_>, bi: usize| -> InferResult {
            let block = &blocks[bi];
            let xs = stream_for(block, 2, seed);
            seed += 1;
            session.enqueue(Arc::clone(block), xs).wait().expect("job ok")
        };
        assert!(run(&mut session, 0).mapped_fresh); // A miss
        assert!(run(&mut session, 1).mapped_fresh); // B miss
        assert!(!run(&mut session, 0).mapped_fresh); // A hit (bumps A)
        assert!(run(&mut session, 2).mapped_fresh); // C miss → evicts B (LRU)
        assert!(!run(&mut session, 0).mapped_fresh); // A survived
        assert!(run(&mut session, 1).mapped_fresh, "B was evicted and must re-map");
        let m = coord.metrics.snapshot();
        assert_eq!(m.cache_misses, 4);
        assert_eq!(m.cache_hits, 2);
    }

    #[test]
    fn eviction_order_follows_tick_index_at_capacity_64() {
        // The tick-ordered BTreeMap index must reproduce exact LRU order
        // at a capacity where the retired full-map scan was the cost
        // concern. One cheap real mapping is cloned into every entry.
        let capacity = 64usize;
        let cache = MappingCache::new(capacity, 0);
        let metrics = Metrics::default();
        let block = tiny("evict", 2, 2, vec![true, false, true, true]);
        let cgra = StreamingCgra::paper_default();
        let opts = MapperOptions::sparsemap();
        let outcome = map_unit(MapUnit::Single(&block), &cgra, &opts).unwrap();
        let fill = |key: &str| {
            cache
                .get_or_map(key, &metrics, || {
                    Ok(ServingMapping { outcome: outcome.clone(), bundle: None, plan: None })
                })
                .unwrap()
        };
        for i in 0..capacity {
            fill(&format!("k{i:02}"));
        }
        // Touch the even keys (in order): odd keys become the LRU tail.
        for i in (0..capacity).step_by(2) {
            let (_, fresh) = cache
                .get_or_map(&format!("k{i:02}"), &metrics, || {
                    unreachable!("touch must hit")
                })
                .unwrap();
            assert!(!fresh);
        }
        // Each insert beyond capacity evicts exactly the next odd key.
        for j in 0..capacity / 2 {
            fill(&format!("n{j:02}"));
            let inner = cache.inner.lock().unwrap();
            assert_eq!(inner.map.len(), capacity);
            assert_eq!(inner.by_tick.len(), capacity, "index tracks the map");
            let victim = format!("k{:02}", 2 * j + 1);
            assert!(!inner.map.contains_key(&victim), "{victim} evicted at step {j}");
            if 2 * (j + 1) + 1 < capacity {
                let next = format!("k{:02}", 2 * (j + 1) + 1);
                assert!(inner.map.contains_key(&next), "{next} not yet evicted");
            }
        }
        // Every touched (even) key survived the whole sweep.
        let inner = cache.inner.lock().unwrap();
        for i in (0..capacity).step_by(2) {
            assert!(inner.map.contains_key(&format!("k{i:02}")));
        }
    }

    #[test]
    fn concurrent_cold_start_maps_once() {
        // Many concurrent requests for one cold block: single-flight must
        // map exactly once while waiters sleep on the entry's condvar
        // (not on the cache map), then share the result.
        let mut cfg = small_cfg();
        cfg.workers = 4;
        cfg.queue_depth = 8;
        let coord = Coordinator::new(&cfg);
        let block = Arc::new(paper_blocks()[0].block.clone());
        let mut session = coord.session();
        let tickets: Vec<Ticket> = (0..8u64)
            .map(|seed| session.enqueue(Arc::clone(&block), stream_for(&block, 4, seed)))
            .collect();
        for t in tickets {
            t.wait().expect("job ok");
        }
        let m = coord.metrics.snapshot();
        assert_eq!(m.cache_misses, 1, "one mapping for 8 concurrent requests");
        assert_eq!(m.cache_hits, 7);
    }

    #[test]
    fn failed_build_leaves_no_dead_cache_entry() {
        // A failed (deterministically re-failing) mapping must not leave a
        // permanent Empty entry behind: Empty entries are not LRU victims,
        // so a dead one would pin cache_capacity forever.
        let cache = MappingCache::new(1, 0);
        let metrics = Metrics::default();
        let err = cache.get_or_map("dead", &metrics, || {
            Err(Error::Workload("unmappable".into()))
        });
        assert!(err.is_err());
        {
            let inner = cache.inner.lock().unwrap();
            assert_eq!(inner.map.len(), 0, "failed build must remove its cache entry");
            assert_eq!(inner.by_tick.len(), 0, "and its tick-index row");
        }
        // The capacity-1 cache is free again: a successful build for the
        // same key caches normally and subsequent requests hit.
        let block = tiny("cachetest", 2, 2, vec![true, false, true, true]);
        let cgra = StreamingCgra::paper_default();
        let opts = MapperOptions::sparsemap();
        let build = || {
            let outcome = map_unit(MapUnit::Single(&block), &cgra, &opts)?;
            Ok(ServingMapping { outcome, bundle: None, plan: None })
        };
        let (_, fresh) = cache.get_or_map("dead", &metrics, build).unwrap();
        assert!(fresh);
        let (_, fresh) = cache
            .get_or_map("dead", &metrics, || unreachable!("second request must hit"))
            .unwrap();
        assert!(!fresh);
        let inner = cache.inner.lock().unwrap();
        assert_eq!(inner.map.len(), 1);
        assert_eq!(inner.by_tick.len(), 1);
    }

    #[test]
    fn register_fused_plans_with_configured_knobs() {
        let mut cfg = small_cfg();
        cfg.max_fused_blocks = 2;
        cfg.fusion_max_ii = 12;
        let coord = Coordinator::new(&cfg);
        let members = tiny_members();
        let plan = coord.register_fused(&members);
        assert!(plan.iter().all(|b| b.len() <= 2));
        assert_eq!(plan.iter().map(|b| b.len()).sum::<usize>(), members.len());
        // First planned pair is registered: a member request serves fused.
        let first = &plan[0];
        assert!(first.len() == 2, "tiny blocks must pack in pairs");
        let member = Arc::clone(&first.blocks[0]);
        let xs = stream_for(&member, 2, 3);
        let mut session = coord.session();
        let r = session.enqueue(member, xs).wait().expect("fused job ok");
        assert_eq!(r.fused_members, 2);
    }

    #[test]
    fn multiple_blocks_in_flight() {
        let cfg = small_cfg();
        let coord = Coordinator::new(&cfg);
        let blocks: Vec<Arc<SparseBlock>> = paper_blocks()
            .into_iter()
            .take(3)
            .map(|nb| Arc::new(nb.block))
            .collect();
        let mut session = coord.session();
        let mut tickets = Vec::new();
        let mut seed = 0u64;
        for block in &blocks {
            for _ in 0..2 {
                tickets.push(session.enqueue(Arc::clone(block), stream_for(block, 4, seed)));
                seed += 1;
            }
        }
        session.drain();
        for t in tickets {
            t.wait().expect("job ok");
        }
        let m = coord.metrics.snapshot();
        assert_eq!(m.cache_misses, 3);
    }
}
