//! Streaming inference coordinator (L3 runtime).
//!
//! Owns the request path of the system: a bounded job queue (backpressure),
//! a worker-thread pool that maps blocks (with a compile-once mapping
//! cache) and executes them on the cycle-accurate CGRA simulator, and
//! aggregate metrics. The PJRT cross-check (`crate::runtime`) runs on the
//! caller's thread — XLA executables stay off the worker pool.
//!
//! tokio is unavailable offline; the pool is built on std threads +
//! `std::sync::mpsc::sync_channel`, which gives exactly the bounded-queue
//! semantics the backpressure design needs.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, Sender, SyncSender};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::arch::StreamingCgra;
use crate::config::SparsemapConfig;
use crate::error::{Error, Result};
use crate::mapper::{map_block, MapOutcome, MapperOptions};
use crate::sim::simulate;
use crate::sparse::SparseBlock;

/// One inference job: run `xs` (iteration-major input vectors) through a
/// sparse block on the CGRA.
pub struct InferRequest {
    pub id: u64,
    pub block: Arc<SparseBlock>,
    pub xs: Vec<Vec<f32>>,
}

/// The coordinator's answer.
#[derive(Clone, Debug)]
pub struct InferResult {
    pub id: u64,
    pub block_name: String,
    pub outputs: Vec<Vec<f32>>,
    /// CGRA cycles consumed.
    pub cycles: u64,
    /// II of the mapping used.
    pub ii: usize,
    /// Whether this job triggered a fresh mapping (cache miss).
    pub mapped_fresh: bool,
    /// End-to-end latency in nanoseconds.
    pub latency_ns: u64,
}

/// Aggregate counters (lock-free reads).
#[derive(Default)]
pub struct Metrics {
    pub jobs: AtomicU64,
    pub failures: AtomicU64,
    pub cache_hits: AtomicU64,
    pub cache_misses: AtomicU64,
    pub total_cycles: AtomicU64,
    pub total_latency_ns: AtomicU64,
}

impl Metrics {
    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            jobs: self.jobs.load(Ordering::Relaxed),
            failures: self.failures.load(Ordering::Relaxed),
            cache_hits: self.cache_hits.load(Ordering::Relaxed),
            cache_misses: self.cache_misses.load(Ordering::Relaxed),
            total_cycles: self.total_cycles.load(Ordering::Relaxed),
            total_latency_ns: self.total_latency_ns.load(Ordering::Relaxed),
        }
    }
}

#[derive(Clone, Copy, Debug)]
pub struct MetricsSnapshot {
    pub jobs: u64,
    pub failures: u64,
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub total_cycles: u64,
    pub total_latency_ns: u64,
}

/// Single-flight mapping cache: the outer map hands out one slot per block
/// key; the slot's own mutex serializes mapping of that block while other
/// blocks proceed in parallel.
type CacheSlot = Arc<Mutex<Option<Arc<MapOutcome>>>>;
type Cache = Arc<Mutex<std::collections::HashMap<String, CacheSlot>>>;

enum Job {
    Infer(InferRequest),
}

/// The streaming coordinator.
pub struct Coordinator {
    tx: Option<SyncSender<Job>>,
    results: Receiver<Result<InferResult>>,
    workers: Vec<std::thread::JoinHandle<()>>,
    pub metrics: Arc<Metrics>,
}

impl Coordinator {
    /// Spawn `cfg.workers` worker threads with a queue of depth
    /// `cfg.queue_depth`.
    pub fn new(cfg: &SparsemapConfig) -> Self {
        let (tx, rx) = sync_channel::<Job>(cfg.queue_depth);
        let rx = Arc::new(Mutex::new(rx));
        let (res_tx, results) = std::sync::mpsc::channel::<Result<InferResult>>();
        let cache: Cache = Arc::new(Mutex::new(std::collections::HashMap::new()));
        let metrics = Arc::new(Metrics::default());
        let mut opts = MapperOptions::from_config(cfg);
        if opts.parallelism == 0 {
            // Auto portfolio width: split the machine between the worker
            // pool and each worker's mapping portfolio, so a burst of
            // cache misses doesn't oversubscribe cores. The mapping itself
            // is width-independent (deterministic portfolio), so this only
            // shapes latency.
            let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
            opts.parallelism = (cores / cfg.workers.max(1)).clamp(1, 8);
        }
        let cgra = cfg.cgra.clone();

        let workers = (0..cfg.workers)
            .map(|wid| {
                let rx = Arc::clone(&rx);
                let res_tx = res_tx.clone();
                let cache = Arc::clone(&cache);
                let metrics = Arc::clone(&metrics);
                let opts = opts.clone();
                let cgra = cgra.clone();
                std::thread::Builder::new()
                    .name(format!("sparsemap-worker-{wid}"))
                    .spawn(move || worker_loop(rx, res_tx, cache, metrics, opts, cgra))
                    .expect("spawn worker")
            })
            .collect();

        Coordinator { tx: Some(tx), results, workers, metrics }
    }

    /// Submit a job; blocks when the queue is full (backpressure).
    pub fn submit(&self, req: InferRequest) -> Result<()> {
        self.tx
            .as_ref()
            .expect("coordinator live")
            .send(Job::Infer(req))
            .map_err(|_| Error::Runtime("coordinator shut down".into()))
    }

    /// Collect exactly `n` results (any order — jobs are tagged by id).
    /// If the worker pool exits before delivering them all (panic,
    /// shutdown), the remaining slots come back as `Err(Error::Runtime)`
    /// instead of poisoning the caller with a panic.
    pub fn collect(&self, n: usize) -> Vec<Result<InferResult>> {
        (0..n)
            .map(|_| {
                self.results.recv().unwrap_or_else(|_| {
                    Err(Error::Runtime(
                        "worker pool exited before delivering all results".into(),
                    ))
                })
            })
            .collect()
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.tx.take(); // close the queue; workers drain and exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(
    rx: Arc<Mutex<Receiver<Job>>>,
    res_tx: Sender<Result<InferResult>>,
    cache: Cache,
    metrics: Arc<Metrics>,
    opts: MapperOptions,
    cgra: StreamingCgra,
) {
    loop {
        let job = {
            let guard = rx.lock().expect("queue lock");
            guard.recv()
        };
        let Ok(Job::Infer(req)) = job else { return };
        let started = Instant::now();
        let outcome = run_one(&req, &cache, &metrics, &opts, &cgra);
        metrics.jobs.fetch_add(1, Ordering::Relaxed);
        let out = match outcome {
            Ok((outputs, cycles, ii, fresh)) => {
                metrics.total_cycles.fetch_add(cycles, Ordering::Relaxed);
                let latency_ns = started.elapsed().as_nanos() as u64;
                metrics.total_latency_ns.fetch_add(latency_ns, Ordering::Relaxed);
                Ok(InferResult {
                    id: req.id,
                    block_name: req.block.name.clone(),
                    outputs,
                    cycles,
                    ii,
                    mapped_fresh: fresh,
                    latency_ns,
                })
            }
            Err(e) => {
                metrics.failures.fetch_add(1, Ordering::Relaxed);
                Err(e)
            }
        };
        if res_tx.send(out).is_err() {
            return; // caller gone
        }
    }
}

fn run_one(
    req: &InferRequest,
    cache: &Cache,
    metrics: &Metrics,
    opts: &MapperOptions,
    cgra: &StreamingCgra,
) -> Result<(Vec<Vec<f32>>, u64, usize, bool)> {
    // Mapping with a compile-once, single-flight cache keyed by block
    // identity: concurrent requests for the same block wait on its slot
    // instead of mapping twice. The key carries the mask's content
    // fingerprint — name and shape alone would silently alias two
    // differently-pruned blocks onto one mapping.
    let key = format!(
        "{}#{}x{}@{:016x}",
        req.block.name,
        req.block.c,
        req.block.k,
        req.block.mask_fingerprint()
    );
    let slot: CacheSlot = {
        let mut guard = cache.lock().expect("cache lock");
        Arc::clone(guard.entry(key).or_default())
    };
    let (outcome, fresh) = {
        let mut slot_guard = slot.lock().expect("slot lock");
        match slot_guard.as_ref() {
            Some(o) => {
                metrics.cache_hits.fetch_add(1, Ordering::Relaxed);
                (Arc::clone(o), false)
            }
            None => {
                metrics.cache_misses.fetch_add(1, Ordering::Relaxed);
                let o = Arc::new(map_block(&req.block, cgra, opts)?);
                *slot_guard = Some(Arc::clone(&o));
                (o, true)
            }
        }
    };
    let res = simulate(&outcome.mapping, &req.block, cgra, &req.xs)?;
    Ok((res.outputs, res.cycles, outcome.mapping.ii, fresh))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen::paper_blocks;

    fn small_cfg() -> SparsemapConfig {
        let mut cfg = SparsemapConfig::default();
        cfg.workers = 2;
        cfg.queue_depth = 4;
        cfg.mis_iterations = 20_000;
        cfg
    }

    fn stream_for(block: &SparseBlock, n: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = crate::util::rng::Pcg64::seeded(seed);
        (0..n)
            .map(|_| (0..block.c).map(|_| rng.next_normal() as f32).collect())
            .collect()
    }

    #[test]
    fn processes_jobs_and_caches_mappings() {
        let cfg = small_cfg();
        let coord = Coordinator::new(&cfg);
        let block = Arc::new(paper_blocks()[1].block.clone());
        for id in 0..6 {
            let xs = stream_for(&block, 8, id);
            coord
                .submit(InferRequest { id, block: Arc::clone(&block), xs })
                .unwrap();
        }
        let results = coord.collect(6);
        assert_eq!(results.len(), 6);
        for r in &results {
            let r = r.as_ref().expect("job ok");
            assert_eq!(r.outputs.len(), 8);
        }
        let m = coord.metrics.snapshot();
        assert_eq!(m.jobs, 6);
        assert_eq!(m.failures, 0);
        assert_eq!(m.cache_misses, 1, "one block → one mapping");
        assert_eq!(m.cache_hits, 5);
    }

    #[test]
    fn outputs_match_reference_forward() {
        let cfg = small_cfg();
        let coord = Coordinator::new(&cfg);
        let block = Arc::new(paper_blocks()[2].block.clone());
        let xs = stream_for(&block, 12, 9);
        coord
            .submit(InferRequest { id: 0, block: Arc::clone(&block), xs: xs.clone() })
            .unwrap();
        let r = coord.collect(1).pop().unwrap().unwrap();
        for (x, y) in xs.iter().zip(&r.outputs) {
            let want = block.forward(x);
            for (a, b) in y.iter().zip(&want) {
                assert!((a - b).abs() < 1e-4 * (1.0 + b.abs()), "{a} vs {b}");
            }
        }
    }

    #[test]
    fn same_shape_different_masks_do_not_share_mappings() {
        // Regression: the cache used to key by name#CxK only, so two blocks
        // with equal name and shape but different sparsity patterns shared
        // one mapping and returned wrong outputs for the second.
        let cfg = small_cfg();
        let coord = Coordinator::new(&cfg);
        let a = Arc::new(
            SparseBlock::from_mask(
                "twin",
                3,
                3,
                vec![true, true, false, false, true, true, true, false, true],
            )
            .unwrap(),
        );
        let b = Arc::new(
            SparseBlock::from_mask(
                "twin",
                3,
                3,
                vec![true, false, true, true, true, false, false, true, true],
            )
            .unwrap(),
        );
        let xs = stream_for(&a, 6, 3);
        coord.submit(InferRequest { id: 0, block: Arc::clone(&a), xs: xs.clone() }).unwrap();
        coord.submit(InferRequest { id: 1, block: Arc::clone(&b), xs: xs.clone() }).unwrap();
        let results = coord.collect(2);
        assert_eq!(coord.metrics.snapshot().cache_misses, 2, "one mapping per mask");
        for r in results {
            let r = r.expect("job ok");
            let block = if r.id == 0 { &a } else { &b };
            for (x, y) in xs.iter().zip(&r.outputs) {
                let want = block.forward(x);
                for (got, w) in y.iter().zip(&want) {
                    assert!(
                        (got - w).abs() < 1e-4 * (1.0 + w.abs()),
                        "id {}: {got} vs {w}",
                        r.id
                    );
                }
            }
        }
    }

    #[test]
    fn collect_returns_errors_when_workers_gone() {
        let cfg = small_cfg();
        let mut coord = Coordinator::new(&cfg);
        // Shut the pool down out from under collect(): close the queue and
        // join every worker, exactly the state a panicked pool leaves.
        coord.tx.take();
        for w in coord.workers.drain(..) {
            w.join().unwrap();
        }
        let results = coord.collect(3);
        assert_eq!(results.len(), 3);
        for r in results {
            match r {
                Err(Error::Runtime(msg)) => assert!(msg.contains("worker pool"), "{msg}"),
                other => panic!("expected Runtime error, got {other:?}"),
            }
        }
    }

    #[test]
    fn multiple_blocks_in_flight() {
        let cfg = small_cfg();
        let coord = Coordinator::new(&cfg);
        let blocks: Vec<Arc<SparseBlock>> = paper_blocks()
            .into_iter()
            .take(3)
            .map(|nb| Arc::new(nb.block))
            .collect();
        let mut id = 0;
        for block in &blocks {
            for _ in 0..2 {
                let xs = stream_for(block, 4, id);
                coord.submit(InferRequest { id, block: Arc::clone(block), xs }).unwrap();
                id += 1;
            }
        }
        let results = coord.collect(id as usize);
        assert!(results.iter().all(|r| r.is_ok()));
        let m = coord.metrics.snapshot();
        assert_eq!(m.cache_misses, 3);
    }
}
