//! Streaming inference coordinator (L3 runtime).
//!
//! Owns the request path of the system: a typed **session API** over a
//! bounded job queue (backpressure), a worker-thread pool that maps blocks
//! (with a compile-once mapping cache) and executes them on the
//! cycle-accurate CGRA simulator, and aggregate metrics. The PJRT
//! cross-check (`crate::runtime`) runs on the caller's thread — XLA
//! executables stay off the worker pool.
//!
//! ## Sessions and tickets
//!
//! [`Coordinator::session`] opens a [`ServeSession`];
//! [`ServeSession::enqueue`] hands in one request (a block plus its
//! iteration-major input vectors) and returns a [`Ticket`] — the handle
//! the result is retrieved by ([`Ticket::wait`] / [`Ticket::try_wait`]),
//! in any order, independent of completion order. Per-request failures
//! come back as a structured [`ServeError`] (queue closed / mapping
//! failed / simulator fault / worker gone) instead of a stringly runtime
//! error. The pre-session `submit`/`collect` fire-hose survives one
//! release as `#[deprecated]` thin wrappers over an internal session.
//!
//! ## Batching windows
//!
//! Requests targeting members of the same registered [`FusedBundle`]
//! aggregate into a **batching window**: the window seals once it holds
//! `[coordinator] batch_window_requests` requests (or its lockstep
//! iteration count reaches `[coordinator] batch_window_max`), on
//! [`ServeSession::flush`]/[`ServeSession::drain`], or when a member
//! ticket is waited on — and the whole window is dispatched as ONE job
//! running ONE lockstep simulation pass ([`crate::sim::simulate_fused_batch`])
//! with a real iteration stream per member (zero inputs only for members
//! absent from the window). The window is charged for the resident
//! configuration once: `Metrics::total_cycles` grows by the pass total,
//! the `windows` counter by one, and each request's `InferResult::cycles`
//! is its proportional share of the pass. Window contents are a pure
//! function of the session's enqueue order (plus the two knobs), so
//! serving is deterministic at any worker count.
//!
//! ## Mapping cache
//!
//! The cache is single-flight and LRU-bounded: one entry per mapping key,
//! the first requester builds (maps) while concurrent requesters for the
//! same key sleep on the entry's `Condvar` — the cache's outer mutex is
//! never held across a mapping, so unrelated blocks proceed in parallel
//! and waiters block on nothing but their own entry. Capacity comes from
//! `[coordinator] cache_capacity` (`0` = unbounded); at capacity the
//! least-recently-used entry is evicted through a tick-ordered
//! `BTreeMap` index maintained on the touch path (no full-map scans;
//! in-flight holders keep their `Arc`).
//!
//! ## Multi-block fusion
//!
//! Small blocks can be registered as a [`FusedBundle`]
//! ([`Coordinator::register_bundle`] / [`Coordinator::register_fused`]):
//! a request for *any* member block routes — at enqueue time, through
//! [`BundleRoutes`] — into the bundle's batching window and is served by
//! the bundle's shared fused mapping (one cache entry keyed by the
//! bundle's combined mask fingerprint). Unregistered blocks serve solo
//! through the same cache, so fused and unfused traffic mix freely.
//!
//! tokio is unavailable offline; the pool is built on std threads +
//! `std::sync::mpsc::sync_channel`, which gives exactly the bounded-queue
//! semantics the backpressure design needs. A batching window occupies a
//! single queue slot however many requests it carries.

use std::collections::{BTreeMap, HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SendError, SyncSender};
use std::sync::{Arc, Condvar, Mutex, Weak};
use std::time::Instant;

use crate::arch::StreamingCgra;
use crate::config::SparsemapConfig;
use crate::error::{Error, Result};
use crate::mapper::{map_unit, MapOutcome, MapUnit, MapperOptions};
use crate::sim::{simulate, simulate_fused_batch, MemberSegment, SegmentSim};
use crate::sparse::fuse::{plan_bundles, BundleRoutes, FusedBundle, FusionOptions};
use crate::sparse::SparseBlock;

/// One inference job: run `xs` (iteration-major input vectors) through a
/// sparse block on the CGRA. Legacy envelope of the deprecated
/// `submit`/`collect` path — the session API takes the block and inputs
/// directly and allocates ids itself.
pub struct InferRequest {
    pub id: u64,
    pub block: Arc<SparseBlock>,
    pub xs: Vec<Vec<f32>>,
}

/// The coordinator's answer.
#[derive(Clone, Debug)]
pub struct InferResult {
    /// Request id: the session-scoped enqueue sequence number (or the
    /// caller-chosen id on the deprecated `submit` path).
    pub id: u64,
    pub block_name: String,
    /// CGRA cycles this request is charged for. A request served through a
    /// batching window is charged its proportional share of the window's
    /// single pass — the shares of a window sum exactly to the pass total.
    pub cycles: u64,
    pub outputs: Vec<Vec<f32>>,
    /// II of the mapping used.
    pub ii: usize,
    /// Whether this job triggered a fresh mapping (cache miss). In a
    /// batching window, the window's first request carries the flag.
    pub mapped_fresh: bool,
    /// Member blocks resident in the configuration that served this
    /// request (`1` = unfused).
    pub fused_members: usize,
    /// End-to-end latency in nanoseconds, measured from worker pickup
    /// (window members share their window's value).
    pub latency_ns: u64,
}

/// Structured per-request serving failure, delivered through [`Ticket`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ServeError {
    /// The job queue closed (worker pool shut down) before the request
    /// could be dispatched or delivered.
    QueueClosed,
    /// Mapping the request's block — or its bundle's shared fused mapping
    /// with no solo fallback left — failed. Carries the mapper's reason;
    /// concurrent requests for the same key fail fast on the cache's
    /// sticky error without re-running the deterministic mapping.
    MappingFailed(String),
    /// The simulator faulted while serving the request (a mapping-stack
    /// bug detector firing, or malformed request inputs).
    Sim(String),
    /// The worker pool dropped the request without completing it (worker
    /// panic or teardown mid-flight).
    WorkerGone,
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::QueueClosed => {
                write!(f, "serving queue closed before the request was dispatched")
            }
            ServeError::MappingFailed(msg) => write!(f, "mapping failed: {msg}"),
            ServeError::Sim(msg) => write!(f, "simulation failed: {msg}"),
            ServeError::WorkerGone => {
                write!(f, "worker pool dropped the request without completing it")
            }
        }
    }
}

impl std::error::Error for ServeError {}

impl From<ServeError> for Error {
    /// The deprecated `collect` shim (and other legacy surfaces) report
    /// serve errors the way the old API did: as stringly runtime errors.
    fn from(e: ServeError) -> Self {
        Error::Runtime(e.to_string())
    }
}

/// Aggregate counters (lock-free reads).
#[derive(Default)]
pub struct Metrics {
    /// Requests processed by the worker pool (each window member counts).
    pub jobs: AtomicU64,
    pub failures: AtomicU64,
    pub cache_hits: AtomicU64,
    pub cache_misses: AtomicU64,
    /// CGRA cycles charged: per-request pass totals for solo serving, ONE
    /// pass total per batching window for fused serving.
    pub total_cycles: AtomicU64,
    pub total_latency_ns: AtomicU64,
    /// Batching windows simulated (one fused lockstep pass each).
    pub windows: AtomicU64,
}

impl Metrics {
    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            jobs: self.jobs.load(Ordering::Relaxed),
            failures: self.failures.load(Ordering::Relaxed),
            cache_hits: self.cache_hits.load(Ordering::Relaxed),
            cache_misses: self.cache_misses.load(Ordering::Relaxed),
            total_cycles: self.total_cycles.load(Ordering::Relaxed),
            total_latency_ns: self.total_latency_ns.load(Ordering::Relaxed),
            windows: self.windows.load(Ordering::Relaxed),
        }
    }
}

#[derive(Clone, Copy, Debug)]
pub struct MetricsSnapshot {
    pub jobs: u64,
    pub failures: u64,
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub total_cycles: u64,
    pub total_latency_ns: u64,
    pub windows: u64,
}

/// Fused request batching knobs (see `[coordinator] batch_window_requests`
/// / `batch_window_max`).
#[derive(Clone, Copy, Debug)]
pub struct BatchOptions {
    /// A window seals once it holds this many member requests (`0`/`1` =
    /// every member request is its own window).
    pub window_requests: usize,
    /// Cap on a window's lockstep iteration count (max over members of
    /// the summed request stream lengths): a request that would push the
    /// window to the cap seals it *first* and starts a fresh one, so
    /// requests already aboard never pay an oversized rider's padding.
    /// `0` = uncapped.
    pub window_max_iters: usize,
}

impl BatchOptions {
    pub fn from_config(cfg: &SparsemapConfig) -> Self {
        BatchOptions {
            window_requests: cfg.batch_window_requests,
            window_max_iters: cfg.batch_window_max,
        }
    }
}

// ---------------------------------------------------------------------------
// Tickets

/// Resolution state shared between a [`Ticket`] and its worker-side
/// completer.
enum TicketInner {
    Pending,
    Done(std::result::Result<InferResult, ServeError>),
    /// `wait` consumed the result (tombstone — unreachable through the
    /// public API afterwards, since `wait` takes the ticket by value).
    Taken,
}

struct TicketState {
    inner: Mutex<TicketInner>,
    ready: Condvar,
}

impl TicketState {
    fn new() -> Arc<Self> {
        Arc::new(TicketState { inner: Mutex::new(TicketInner::Pending), ready: Condvar::new() })
    }

    /// First completion wins; later calls (e.g. the completer's drop guard
    /// after an explicit fulfill) are no-ops.
    fn complete(&self, res: std::result::Result<InferResult, ServeError>) {
        let mut inner = self.inner.lock().expect("ticket state");
        if matches!(&*inner, TicketInner::Pending) {
            *inner = TicketInner::Done(res);
            self.ready.notify_all();
        }
    }

    /// Block until the ticket is resolved (without consuming the result).
    fn wait_done(&self) {
        let mut inner = self.inner.lock().expect("ticket state");
        while matches!(&*inner, TicketInner::Pending) {
            inner = self.ready.wait(inner).expect("ticket state");
        }
    }

    /// Block until resolved, then take the result.
    fn take(&self) -> std::result::Result<InferResult, ServeError> {
        let mut inner = self.inner.lock().expect("ticket state");
        while matches!(&*inner, TicketInner::Pending) {
            inner = self.ready.wait(inner).expect("ticket state");
        }
        match std::mem::replace(&mut *inner, TicketInner::Taken) {
            TicketInner::Done(res) => res,
            // `wait` consumes the ticket, so a taken state cannot be
            // observed again through the public API.
            _ => Err(ServeError::WorkerGone),
        }
    }

    /// Non-blocking peek (clones the result, leaving it claimable).
    fn peek(&self) -> Option<std::result::Result<InferResult, ServeError>> {
        let inner = self.inner.lock().expect("ticket state");
        match &*inner {
            TicketInner::Done(res) => Some(res.clone()),
            _ => None,
        }
    }
}

/// Worker-side handle to a pending ticket: fulfills it exactly once, and
/// resolves it to [`ServeError::WorkerGone`] if dropped unfulfilled
/// (worker panic, queue teardown with jobs still aboard) so a `wait` can
/// never hang on a request the pool lost.
struct TicketCompleter {
    state: Arc<TicketState>,
}

impl TicketCompleter {
    fn fulfill(self, res: std::result::Result<InferResult, ServeError>) {
        self.state.complete(res);
        // Drop runs next and no-ops: completion is first-wins.
    }
}

impl Drop for TicketCompleter {
    fn drop(&mut self) {
        self.state.complete(Err(ServeError::WorkerGone));
    }
}

/// Handle to one enqueued request. Results are retrieved by ticket, in any
/// order — waiting also seals the request's batching window (if it is
/// still open) so a ticket can never block on a window nobody else would
/// close.
pub struct Ticket {
    id: u64,
    block_name: String,
    state: Arc<TicketState>,
    window: Option<WindowHandle>,
}

impl Ticket {
    /// The request's id (session-scoped enqueue sequence number).
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Name of the block the request targets.
    pub fn block_name(&self) -> &str {
        &self.block_name
    }

    /// Block until the request resolves and take the result. Seals the
    /// request's batching window first if it is still open.
    pub fn wait(mut self) -> std::result::Result<InferResult, ServeError> {
        self.flush_window();
        self.state.take()
    }

    /// Non-blocking poll: `None` while the request is in flight, a clone
    /// of the result once resolved (the result stays claimable by `wait`).
    /// Also seals the request's still-open batching window — the poll
    /// would otherwise never turn `Some`.
    pub fn try_wait(&mut self) -> Option<std::result::Result<InferResult, ServeError>> {
        self.flush_window();
        self.state.peek()
    }

    fn flush_window(&mut self) {
        if let Some(w) = self.window.take() {
            w.flush();
        }
    }
}

// ---------------------------------------------------------------------------
// Batching windows

/// A not-yet-dispatched batching window for one registered bundle.
struct WindowCell {
    bundle: Arc<FusedBundle>,
    requests: Vec<WindowRequest>,
    sealed: bool,
}

struct WindowRequest {
    id: u64,
    /// Member index inside the bundle (resolved at enqueue time).
    member: usize,
    block: Arc<SparseBlock>,
    xs: Vec<Vec<f32>>,
    done: TicketCompleter,
}

/// Shared handle to an open window: the session and every member ticket
/// hold one, and whoever seals first dispatches. The queue sender is held
/// weakly so stray tickets can never keep the worker pool alive past the
/// coordinator's drop.
#[derive(Clone)]
struct WindowHandle {
    cell: Arc<Mutex<WindowCell>>,
    tx: Weak<SyncSender<Job>>,
}

impl WindowHandle {
    /// Seal the window (if still open and non-empty) and dispatch it as
    /// one job; on a closed queue every member ticket resolves to
    /// [`ServeError::QueueClosed`] instead of hanging.
    fn flush(&self) {
        let job = {
            let mut cell = self.cell.lock().expect("window cell");
            if cell.sealed || cell.requests.is_empty() {
                return;
            }
            cell.sealed = true;
            WindowJob {
                bundle: Arc::clone(&cell.bundle),
                requests: std::mem::take(&mut cell.requests),
            }
        };
        let Some(tx) = self.tx.upgrade() else {
            for r in job.requests {
                r.done.fulfill(Err(ServeError::QueueClosed));
            }
            return;
        };
        if let Err(SendError(sent)) = tx.send(Job::Window(job)) {
            if let Job::Window(w) = sent {
                for r in w.requests {
                    r.done.fulfill(Err(ServeError::QueueClosed));
                }
            }
        }
    }
}

/// Lockstep iteration count of the window's current contents, optionally
/// with one more candidate request aboard.
fn lockstep_len(cell: &WindowCell, extra: Option<&WindowRequest>) -> usize {
    let mut totals = vec![0usize; cell.bundle.len()];
    for r in cell.requests.iter().chain(extra) {
        totals[r.member] += r.xs.len();
    }
    totals.into_iter().max().unwrap_or(0)
}

/// Whether admitting `request` would push the window's lockstep iteration
/// count to (or past) `batch_window_max` — checked *before* admission so
/// requests already aboard never pay the oversized rider's padding.
fn would_exceed_cap(cell: &WindowCell, request: &WindowRequest, batching: &BatchOptions) -> bool {
    batching.window_max_iters > 0
        && lockstep_len(cell, Some(request)) >= batching.window_max_iters
}

/// Whether the window should seal now that its contents are final for
/// this enqueue: the request-count knob, or (for a window whose sole
/// request alone reaches it — a cap breach no split can avoid) the
/// iteration cap.
fn window_full(cell: &WindowCell, batching: &BatchOptions) -> bool {
    if cell.requests.len() >= batching.window_requests.max(1) {
        return true;
    }
    batching.window_max_iters > 0
        && lockstep_len(cell, None) >= batching.window_max_iters
}

// ---------------------------------------------------------------------------
// Sessions

/// Session bookkeeping shared by [`ServeSession`] and the deprecated
/// `submit`/`collect` shims: id allocation plus the open windows, in
/// creation order (so flush order — and therefore window formation — is a
/// pure function of enqueue order).
struct SessionCore {
    next_id: u64,
    /// Open windows keyed by bundle fingerprint (small linear map).
    open: Vec<(u64, WindowHandle)>,
}

impl SessionCore {
    fn new() -> Self {
        SessionCore { next_id: 0, open: Vec::new() }
    }

    fn enqueue(
        &mut self,
        coord: &Coordinator,
        id: u64,
        block: Arc<SparseBlock>,
        xs: Vec<Vec<f32>>,
    ) -> Ticket {
        let state = TicketState::new();
        let done = TicketCompleter { state: Arc::clone(&state) };
        let block_name = block.name.clone();
        let route = coord.bundles.route(block.mask_fingerprint());
        let window = match (route, coord.sender()) {
            (_, None) => {
                done.fulfill(Err(ServeError::QueueClosed));
                None
            }
            (None, Some(tx)) => {
                if let Err(SendError(sent)) =
                    tx.send(Job::Single(SingleJob { id, block, xs, done }))
                {
                    if let Job::Single(j) = sent {
                        j.done.fulfill(Err(ServeError::QueueClosed));
                    }
                }
                None
            }
            (Some((bundle, member)), Some(tx)) => Some(self.window_enqueue(
                &tx,
                &coord.batching,
                bundle,
                WindowRequest { id, member, block, xs, done },
            )),
        };
        Ticket { id, block_name, state, window }
    }

    /// Append a member request to its bundle's open window (creating one
    /// if none is open), sealing and dispatching the window when it fills.
    /// A request that would push the window's lockstep iteration count
    /// past `batch_window_max` seals the window *first* and starts a fresh
    /// one — members already aboard never pay unbounded padding for a
    /// late oversized rider.
    fn window_enqueue(
        &mut self,
        tx: &Arc<SyncSender<Job>>,
        batching: &BatchOptions,
        bundle: Arc<FusedBundle>,
        request: WindowRequest,
    ) -> WindowHandle {
        let fp = bundle.fingerprint();
        loop {
            let handle = match self.open.iter().find(|(k, _)| *k == fp) {
                Some((_, h)) => h.clone(),
                None => {
                    let h = WindowHandle {
                        cell: Arc::new(Mutex::new(WindowCell {
                            bundle: Arc::clone(&bundle),
                            requests: Vec::new(),
                            sealed: false,
                        })),
                        tx: Arc::downgrade(tx),
                    };
                    self.open.push((fp, h.clone()));
                    h
                }
            };
            let full = {
                let mut cell = handle.cell.lock().expect("window cell");
                if cell.sealed {
                    // A concurrent `Ticket::wait` (tickets are `Send` and
                    // may be waited from any thread) sealed and dispatched
                    // this window between our lookup and this lock: forget
                    // the stale handle and open a fresh window. The seal
                    // decision and the push share one critical section, so
                    // a request can never land in an already-dispatched
                    // cell.
                    drop(cell);
                    self.open.retain(|(k, _)| *k != fp);
                    continue;
                }
                if !cell.requests.is_empty() && would_exceed_cap(&cell, &request, batching) {
                    drop(cell);
                    handle.flush();
                    self.open.retain(|(k, _)| *k != fp);
                    continue;
                }
                cell.requests.push(request);
                window_full(&cell, batching)
            };
            if full {
                handle.flush();
            }
            // `request` is moved only on this returning path; every
            // `continue` above runs before the move, so the loop re-enters
            // with the request still in hand.
            return handle;
        }
    }

    /// Seal and dispatch every open window, in creation order.
    fn flush_all(&mut self) {
        for (_, h) in self.open.drain(..) {
            h.flush();
        }
    }
}

/// A serving session: the enqueue side of the coordinator's typed API.
/// Dropping the session seals its open batching windows (requests are
/// never stranded); issued [`Ticket`]s stay valid past the session.
pub struct ServeSession<'a> {
    coord: &'a Coordinator,
    core: SessionCore,
    /// Weak handles to every issued ticket, for `drain`. Weak (the
    /// worker-side completer keeps in-flight states alive, a resolved and
    /// dropped ticket's state dies) and pruned amortized on enqueue, so a
    /// long-lived session's bookkeeping stays proportional to its *live*
    /// tickets, not its lifetime request count.
    issued: Vec<std::sync::Weak<TicketState>>,
}

impl ServeSession<'_> {
    /// Enqueue one request; blocks when the job queue is full
    /// (backpressure). The returned [`Ticket`] is the result handle.
    ///
    /// A request for a member of a registered bundle joins the bundle's
    /// open batching window; it is dispatched when the window seals (see
    /// the module docs) — at the latest when its ticket is waited on or
    /// the session flushes, drains or drops.
    pub fn enqueue(&mut self, block: Arc<SparseBlock>, xs: Vec<Vec<f32>>) -> Ticket {
        let id = self.core.next_id;
        self.core.next_id += 1;
        let ticket = self.core.enqueue(self.coord, id, block, xs);
        if self.issued.len() == self.issued.capacity() {
            // Amortized prune before the Vec would grow: drop bookkeeping
            // for tickets that have resolved and been discarded.
            self.issued.retain(|w| w.strong_count() > 0);
        }
        self.issued.push(Arc::downgrade(&ticket.state));
        ticket
    }

    /// Seal and dispatch every open batching window without waiting.
    pub fn flush(&mut self) {
        self.core.flush_all();
    }

    /// Seal and dispatch every open batching window, then block until
    /// every ticket issued by this session has resolved. Results stay
    /// claimable through their tickets.
    pub fn drain(&mut self) {
        self.core.flush_all();
        for state in self.issued.drain(..) {
            // In-flight states are kept alive by the worker-side
            // completer; a dead Weak means the request already resolved
            // and its ticket is gone.
            if let Some(state) = state.upgrade() {
                state.wait_done();
            }
        }
    }
}

impl Drop for ServeSession<'_> {
    fn drop(&mut self) {
        self.core.flush_all();
    }
}

// ---------------------------------------------------------------------------
// Mapping cache

/// A cached, servable mapping: a solo block's or a whole fused bundle's.
struct ServingMapping {
    outcome: MapOutcome,
    /// `Some` when the mapping hosts a bundle — carries the member blocks
    /// the simulator needs for the co-resident streams.
    bundle: Option<Arc<FusedBundle>>,
}

/// State of one cache entry. `Building` marks a mapping in flight; waiters
/// sleep on the entry's condvar instead of holding any mutex the builder
/// needs.
enum EntryState {
    /// No mapping and no builder in flight.
    Empty,
    Building,
    Ready(Arc<ServingMapping>),
    /// The build failed. The entry is already detached from the cache map
    /// (so new requesters get a fresh entry and their own retry); the
    /// sticky error lets queued waiters fail fast instead of serially
    /// re-running a deterministically failing mapping.
    Failed(String),
}

struct CacheEntry {
    state: Mutex<EntryState>,
    ready: Condvar,
    /// Monotonic use tick for LRU eviction (unique per touch; assigned
    /// under the cache-map lock so eviction order is race-free and the
    /// tick index can be maintained in lockstep).
    last_use: AtomicU64,
}

/// Unwind guard for the build phase: if the build closure fails or panics
/// (a mapper invariant violation), mark the entry `Failed`, wake waiters
/// so they fail fast instead of deadlocking on a forever-`Building` entry
/// (or serially re-running a deterministically failing mapping), and drop
/// the entry from the cache map — `Failed` entries must not be found by
/// new requesters, and a dead entry would otherwise pin capacity forever
/// (only `Ready` entries are LRU victims, see [`evict_lru`]). The removal
/// is pointer-compared so a newer same-key entry created by a later
/// requester is never clobbered.
struct BuildGuard<'a> {
    cache: &'a MappingCache,
    key: &'a str,
    entry: &'a Arc<CacheEntry>,
    armed: bool,
}

impl BuildGuard<'_> {
    fn disarm(&mut self) {
        self.armed = false;
    }

    /// Mark the entry failed with `reason`, wake waiters, and detach the
    /// entry (map and tick index) from the cache.
    fn fail(&mut self, reason: &str) {
        self.armed = false;
        {
            let mut state = self.entry.state.lock().expect("cache entry");
            *state = EntryState::Failed(reason.to_string());
            self.entry.ready.notify_all();
        }
        // Entry lock released before the map lock — the same order as
        // every other path (the map lock is never held while waiting
        // on an entry, and evict_lru only try_locks entry states).
        let mut inner = self.cache.inner.lock().expect("cache map");
        if inner.map.get(self.key).is_some_and(|e| Arc::ptr_eq(e, self.entry)) {
            inner.map.remove(self.key);
            // The entry's latest tick is authoritative: every touch
            // restamps it under the map lock we are holding.
            let tick = self.entry.last_use.load(Ordering::Relaxed);
            let removed = inner.by_tick.remove(&tick);
            debug_assert_eq!(removed.as_deref(), Some(self.key));
        }
    }
}

impl Drop for BuildGuard<'_> {
    fn drop(&mut self) {
        if self.armed {
            // Panic unwind path; the error path calls `fail` explicitly
            // with the builder's own message.
            self.fail("mapping build panicked");
        }
    }
}

/// The cache's locked state: the key → entry map plus the tick-ordered
/// LRU index. Both are maintained together under one mutex — every touch
/// restamps the entry's tick and moves its index row, so eviction walks
/// the index in use order instead of scanning the whole map.
struct CacheInner {
    map: HashMap<String, Arc<CacheEntry>>,
    /// Use tick → key. Ticks are unique (assigned under this lock), so
    /// this is a total LRU order over the resident entries.
    by_tick: BTreeMap<u64, String>,
}

/// Single-flight, LRU-bounded mapping cache. The outer map is only ever
/// locked for entry lookup/insert/evict — mapping happens against the
/// entry's own state mutex, and waiters for an in-flight mapping sleep on
/// the entry's `Condvar`.
struct MappingCache {
    inner: Mutex<CacheInner>,
    tick: AtomicU64,
    /// `0` = unbounded.
    capacity: usize,
}

impl MappingCache {
    fn new(capacity: usize) -> Self {
        MappingCache {
            inner: Mutex::new(CacheInner { map: HashMap::new(), by_tick: BTreeMap::new() }),
            tick: AtomicU64::new(0),
            capacity,
        }
    }

    /// Fetch `key`'s mapping, building it via `build` on a miss. Exactly
    /// one requester builds; concurrent requesters for the same key wait
    /// on the entry and share the result (counted as cache hits). On a
    /// build failure the entry turns sticky-`Failed` and leaves the map —
    /// the builder and every queued waiter report the error without
    /// re-running the (deterministic) mapping, while a later fresh
    /// requester gets a new entry and its own retry.
    fn get_or_map<F>(
        &self,
        key: &str,
        metrics: &Metrics,
        build: F,
    ) -> Result<(Arc<ServingMapping>, bool)>
    where
        F: FnOnce() -> Result<ServingMapping>,
    {
        let entry = {
            let mut inner = self.inner.lock().expect("cache map");
            // The use tick is assigned while the map is locked, so a
            // concurrent inserter can never observe (and evict) an entry
            // that has not been stamped yet — and the tick index moves in
            // the same critical section.
            let tick = self.tick.fetch_add(1, Ordering::Relaxed) + 1;
            match inner.map.get(key) {
                Some(e) => {
                    let e = Arc::clone(e);
                    let prev = e.last_use.swap(tick, Ordering::Relaxed);
                    // Reuse the removed key String — the hit path stays
                    // allocation-free.
                    let moved =
                        inner.by_tick.remove(&prev).unwrap_or_else(|| key.to_string());
                    debug_assert_eq!(moved, key);
                    inner.by_tick.insert(tick, moved);
                    e
                }
                None => {
                    // Loop, not a single evict: overshoot accumulated
                    // while entries were mid-build (unevictable) is
                    // reclaimed here once those entries turn Ready.
                    while self.capacity > 0
                        && inner.map.len() >= self.capacity
                        && evict_lru(&mut inner)
                    {}
                    let e = Arc::new(CacheEntry {
                        state: Mutex::new(EntryState::Empty),
                        ready: Condvar::new(),
                        last_use: AtomicU64::new(tick),
                    });
                    inner.map.insert(key.to_string(), Arc::clone(&e));
                    inner.by_tick.insert(tick, key.to_string());
                    e
                }
            }
        };

        let mut state = entry.state.lock().expect("cache entry");
        loop {
            match &*state {
                EntryState::Ready(m) => {
                    metrics.cache_hits.fetch_add(1, Ordering::Relaxed);
                    return Ok((Arc::clone(m), false));
                }
                EntryState::Building => {
                    state = entry.ready.wait(state).expect("cache entry");
                }
                // The builder failed; the mapping is deterministic, so
                // re-running it here would pay the whole attempt lattice
                // again for the same error — fail fast with the builder's
                // reason instead.
                EntryState::Failed(reason) => {
                    return Err(Error::Runtime(format!(
                        "mapping failed in a concurrent request: {reason}"
                    )));
                }
                EntryState::Empty => break,
            }
        }
        *state = EntryState::Building;
        drop(state);

        let mut unwind = BuildGuard { cache: self, key, entry: &entry, armed: true };
        let built = build();
        match built {
            Ok(m) => {
                // A miss is counted only when a fresh mapping actually
                // lands: a failed build followed by a fallback (e.g. the
                // fused → solo path) must not report two misses for one
                // request — failures have their own counter.
                metrics.cache_misses.fetch_add(1, Ordering::Relaxed);
                let m = Arc::new(m);
                let mut state = entry.state.lock().expect("cache entry");
                unwind.disarm();
                *state = EntryState::Ready(Arc::clone(&m));
                entry.ready.notify_all();
                Ok((m, true))
            }
            // Waiters fail fast on the sticky error; the detached entry
            // leaves the map so a *new* requester gets a fresh entry and
            // its own (deterministic) retry.
            Err(e) => {
                unwind.fail(&e.to_string());
                Err(e)
            }
        }
    }
}

/// Evict the least-recently-used *evictable* entry by walking the tick
/// index in use order — O(victim position in the index), not a full-map
/// scan. Only `Ready` entries are victims: a `Building` entry is the
/// single-flight rendezvous for concurrent requesters, and an `Empty`
/// entry belongs to a requester that has looked it up but not yet locked
/// it — evicting either would detach an in-flight mapping from the cache
/// (the result would be built and then silently dropped, and a concurrent
/// same-key request would map a second time). Non-victims stay in the
/// index and are skipped. At capacity the map may therefore transiently
/// exceed its bound by the number of in-flight mappings — the insert path
/// loops eviction, so the overshoot is reclaimed as those entries turn
/// Ready. Use ticks are unique, so the victim is deterministic for a
/// given request history. Returns whether a victim was evicted.
fn evict_lru(inner: &mut CacheInner) -> bool {
    let victim = inner.by_tick.iter().find_map(|(&tick, key)| {
        let e = inner.map.get(key)?;
        match e.state.try_lock() {
            // The state mutex is only ever held briefly (never across a
            // mapping), so a contended entry is simply skipped this round.
            Ok(state) if matches!(&*state, EntryState::Ready(_)) => Some((tick, key.clone())),
            _ => None,
        }
    });
    match victim {
        Some((tick, key)) => {
            inner.by_tick.remove(&tick);
            inner.map.remove(&key);
            true
        }
        None => false,
    }
}

// ---------------------------------------------------------------------------
// The coordinator

enum Job {
    Single(SingleJob),
    Window(WindowJob),
}

struct SingleJob {
    id: u64,
    block: Arc<SparseBlock>,
    xs: Vec<Vec<f32>>,
    done: TicketCompleter,
}

struct WindowJob {
    bundle: Arc<FusedBundle>,
    /// Member requests in window (enqueue) order.
    requests: Vec<WindowRequest>,
}

/// Legacy `submit`/`collect` shim state: an internal session core plus the
/// submission-order ticket queue `collect` drains.
struct LegacyState {
    core: SessionCore,
    fifo: VecDeque<Ticket>,
}

/// The streaming coordinator.
pub struct Coordinator {
    /// The only strong reference to the job-queue sender: dropping it (in
    /// `Drop`) closes the queue. Sessions and tickets hold weak refs only,
    /// so stray handles can never keep the pool alive.
    tx: Option<Arc<SyncSender<Job>>>,
    workers: Vec<std::thread::JoinHandle<()>>,
    pub metrics: Arc<Metrics>,
    bundles: Arc<BundleRoutes>,
    fusion: FusionOptions,
    batching: BatchOptions,
    cgra: StreamingCgra,
    legacy: Mutex<LegacyState>,
}

impl Coordinator {
    /// Spawn `cfg.workers` worker threads with a queue of depth
    /// `cfg.queue_depth` (a batching window occupies one slot).
    pub fn new(cfg: &SparsemapConfig) -> Self {
        let (tx, rx) = sync_channel::<Job>(cfg.queue_depth);
        let rx = Arc::new(Mutex::new(rx));
        let cache = Arc::new(MappingCache::new(cfg.cache_capacity));
        let bundles = Arc::new(BundleRoutes::new());
        let metrics = Arc::new(Metrics::default());
        let mut opts = MapperOptions::from_config(cfg);
        if opts.parallelism == 0 {
            // Auto portfolio width: split the machine between the worker
            // pool and each worker's mapping portfolio, so a burst of
            // cache misses doesn't oversubscribe cores. The mapping itself
            // is width-independent (deterministic portfolio), so this only
            // shapes latency.
            let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
            opts.parallelism = (cores / cfg.workers.max(1)).clamp(1, 8);
        }
        let fusion = opts.fusion;
        let batching = BatchOptions::from_config(cfg);
        let cgra = cfg.cgra.clone();

        let workers = (0..cfg.workers)
            .map(|wid| {
                let rx = Arc::clone(&rx);
                let cache = Arc::clone(&cache);
                let bundles = Arc::clone(&bundles);
                let metrics = Arc::clone(&metrics);
                let opts = opts.clone();
                let cgra = cgra.clone();
                std::thread::Builder::new()
                    .name(format!("sparsemap-worker-{wid}"))
                    .spawn(move || worker_loop(rx, cache, bundles, metrics, opts, cgra))
                    .expect("spawn worker")
            })
            .collect();

        Coordinator {
            tx: Some(Arc::new(tx)),
            workers,
            metrics,
            bundles,
            fusion,
            batching,
            cgra,
            legacy: Mutex::new(LegacyState { core: SessionCore::new(), fifo: VecDeque::new() }),
        }
    }

    /// Open a serving session: the enqueue side of the ticket API. A
    /// coordinator serves any number of sessions (each forms its own
    /// batching windows).
    pub fn session(&self) -> ServeSession<'_> {
        ServeSession { coord: self, core: SessionCore::new(), issued: Vec::new() }
    }

    fn sender(&self) -> Option<Arc<SyncSender<Job>>> {
        self.tx.clone()
    }

    /// Register a fused bundle: from now on a request for *any* member
    /// block batches into the bundle's windows and is served through the
    /// bundle's shared fused mapping (one cache entry keyed by the
    /// bundle's combined mask fingerprint). Requests already served solo
    /// keep their solo cache entries — fused and unfused traffic mix
    /// freely.
    pub fn register_bundle(&self, bundle: Arc<FusedBundle>) {
        self.bundles.register(bundle);
    }

    /// Plan fusion over `blocks` with the configured knobs
    /// (`[mapper] max_fused_blocks` / `[mapper] fusion_max_ii`) and
    /// register every multi-block bundle. Returns the full plan
    /// (singletons included — they stay unregistered and serve solo).
    pub fn register_fused(&self, blocks: &[Arc<SparseBlock>]) -> Vec<FusedBundle> {
        let plan = plan_bundles(blocks, &self.cgra, &self.fusion);
        for bundle in &plan {
            if bundle.len() > 1 {
                self.register_bundle(Arc::new(bundle.clone()));
            }
        }
        plan
    }

    /// Submit a job; blocks when the queue is full (backpressure).
    #[deprecated(
        since = "0.2.0",
        note = "use Coordinator::session(): enqueue() returns a Ticket to wait on"
    )]
    pub fn submit(&self, req: InferRequest) -> Result<()> {
        let mut legacy = self.legacy.lock().expect("legacy serve state");
        let ticket = legacy.core.enqueue(self, req.id, req.block, req.xs);
        // Preserve the old contract: a queue that is already closed at
        // submission time surfaces here, not only at collect.
        if matches!(ticket.state.peek(), Some(Err(ServeError::QueueClosed))) {
            return Err(Error::Runtime("coordinator shut down".into()));
        }
        legacy.fifo.push_back(ticket);
        Ok(())
    }

    /// Collect exactly `n` results, in submission order (jobs are tagged
    /// by id). Waiting seals any batching window a pending submission sits
    /// in; slots beyond the outstanding submissions come back as
    /// `Err(Error::Runtime)`.
    #[deprecated(
        since = "0.2.0",
        note = "use Coordinator::session(): enqueue() returns a Ticket to wait on"
    )]
    pub fn collect(&self, n: usize) -> Vec<Result<InferResult>> {
        (0..n)
            .map(|_| {
                let ticket =
                    self.legacy.lock().expect("legacy serve state").fifo.pop_front();
                match ticket {
                    Some(t) => t.wait().map_err(Error::from),
                    None => Err(Error::Runtime(
                        "worker pool exited before delivering all results".into(),
                    )),
                }
            })
            .collect()
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        // Dispatch legacy windows still open (their tickets hold weak
        // senders only), then close the queue; workers drain and exit.
        if let Ok(mut legacy) = self.legacy.lock() {
            legacy.core.flush_all();
            legacy.fifo.clear();
        }
        self.tx.take();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

// ---------------------------------------------------------------------------
// Workers

fn worker_loop(
    rx: Arc<Mutex<Receiver<Job>>>,
    cache: Arc<MappingCache>,
    bundles: Arc<BundleRoutes>,
    metrics: Arc<Metrics>,
    opts: MapperOptions,
    cgra: StreamingCgra,
) {
    loop {
        let job = {
            let guard = rx.lock().expect("queue lock");
            guard.recv()
        };
        match job {
            Ok(Job::Single(job)) => serve_single(job, &cache, &metrics, &opts, &cgra),
            Ok(Job::Window(job)) => {
                serve_window(job, &cache, &bundles, &metrics, &opts, &cgra)
            }
            Err(_) => return,
        }
    }
}

/// Serve one solo request end to end and fulfill its ticket.
fn serve_single(
    job: SingleJob,
    cache: &MappingCache,
    metrics: &Metrics,
    opts: &MapperOptions,
    cgra: &StreamingCgra,
) {
    let started = Instant::now();
    metrics.jobs.fetch_add(1, Ordering::Relaxed);
    let SingleJob { id, block, xs, done } = job;
    match serve_solo(&block, &xs, cache, metrics, opts, cgra) {
        Ok((outputs, cycles, ii, fresh)) => {
            metrics.total_cycles.fetch_add(cycles, Ordering::Relaxed);
            let latency_ns = started.elapsed().as_nanos() as u64;
            metrics.total_latency_ns.fetch_add(latency_ns, Ordering::Relaxed);
            done.fulfill(Ok(InferResult {
                id,
                block_name: block.name.clone(),
                outputs,
                cycles,
                ii,
                mapped_fresh: fresh,
                fused_members: 1,
                latency_ns,
            }));
        }
        Err(e) => {
            metrics.failures.fetch_add(1, Ordering::Relaxed);
            done.fulfill(Err(e));
        }
    }
}

/// Solo path: compile-once mapping keyed by block identity. The key
/// carries the mask's content fingerprint — name and shape alone would
/// silently alias two differently-pruned blocks onto one mapping.
fn serve_solo(
    block: &Arc<SparseBlock>,
    xs: &[Vec<f32>],
    cache: &MappingCache,
    metrics: &Metrics,
    opts: &MapperOptions,
    cgra: &StreamingCgra,
) -> std::result::Result<(Vec<Vec<f32>>, u64, usize, bool), ServeError> {
    let fp = block.mask_fingerprint();
    let key = format!("{}#{}x{}@{fp:016x}", block.name, block.c, block.k);
    let (serving, fresh) = cache
        .get_or_map(&key, metrics, || {
            let outcome = map_unit(MapUnit::Single(block), cgra, opts)?;
            Ok(ServingMapping { outcome, bundle: None })
        })
        .map_err(|e| ServeError::MappingFailed(e.to_string()))?;
    let res = simulate(&serving.outcome.mapping, block, cgra, xs)
        .map_err(|e| ServeError::Sim(e.to_string()))?;
    Ok((res.outputs, res.cycles, serving.outcome.mapping.ii, fresh))
}

/// Serve one batching window: fetch (or build) the bundle's shared fused
/// mapping, run ONE lockstep pass for the whole window, and split results
/// back per request. An unmappable bundle deregisters loudly and its
/// member requests fall back to solo serving.
fn serve_window(
    job: WindowJob,
    cache: &MappingCache,
    bundles: &BundleRoutes,
    metrics: &Metrics,
    opts: &MapperOptions,
    cgra: &StreamingCgra,
) {
    let started = Instant::now();
    match fused_serving(&job.bundle, cache, metrics, opts, cgra) {
        Ok((serving, fresh)) => {
            // One cache access served the whole window: count the other
            // member requests as hits so `jobs == hits + misses` keeps
            // holding for successful traffic.
            metrics
                .cache_hits
                .fetch_add(job.requests.len() as u64 - 1, Ordering::Relaxed);
            run_window(job.requests, &serving, fresh, started, metrics, cgra);
        }
        // The planner admits bundles by the MII estimate, not bind
        // feasibility, so a registered bundle can turn out unmappable.
        // The mapper is deterministic — it would fail (and re-pay the
        // whole attempt lattice) on every member window forever — so drop
        // the registration and serve this window's and all future member
        // traffic through the working solo path. Loudly: the silently-lost
        // residency win would otherwise be undiagnosable (requests
        // succeed, failures stays 0).
        Err(e) => {
            crate::log_warn!(
                "bundle {} is unmappable ({e}); deregistering — its {} members fall \
                 back to solo serving",
                job.bundle.name,
                job.bundle.len()
            );
            bundles.deregister(&job.bundle);
            for r in job.requests {
                serve_single(
                    SingleJob { id: r.id, block: r.block, xs: r.xs, done: r.done },
                    cache,
                    metrics,
                    opts,
                    cgra,
                );
            }
        }
    }
}

/// Map (or fetch from cache) a registered bundle's shared fused mapping.
/// A mapping error here means the bundle cannot map on this fabric at
/// all — the caller falls back to solo serving; request-specific errors
/// never originate here.
fn fused_serving(
    bundle: &Arc<FusedBundle>,
    cache: &MappingCache,
    metrics: &Metrics,
    opts: &MapperOptions,
    cgra: &StreamingCgra,
) -> Result<(Arc<ServingMapping>, bool)> {
    let key = format!("{}@bundle:{:016x}", bundle.name, bundle.fingerprint());
    cache.get_or_map(&key, metrics, || {
        // A bundle's combined MII sits far above the members' own MIIs and
        // the slot-offset composition needs II headroom: widen the slack
        // to the fused operating point unless the config is already wider.
        let mut bopts = opts.clone();
        bopts.ii_slack = bopts.ii_slack.max(MapperOptions::fused().ii_slack);
        let outcome = map_unit(MapUnit::Bundle(bundle), cgra, &bopts)?;
        Ok(ServingMapping { outcome, bundle: Some(Arc::clone(bundle)) })
    })
}

/// Run one sealed window through the fused mapping and fulfill every
/// member ticket with its own output slice and cycle share.
fn run_window(
    requests: Vec<WindowRequest>,
    serving: &ServingMapping,
    fresh: bool,
    started: Instant,
    metrics: &Metrics,
    cgra: &StreamingCgra,
) {
    let resident = serving.bundle.as_ref().expect("fused entry carries its bundle");
    let w = requests.len();
    metrics.jobs.fetch_add(w as u64, Ordering::Relaxed);
    // Member → request indices, in window order (the per-member segment
    // order the batched pass preserves).
    let mut member_reqs: Vec<Vec<usize>> = vec![Vec::new(); resident.len()];
    for (ri, r) in requests.iter().enumerate() {
        debug_assert!(r.member < resident.len(), "routed member index in range");
        member_reqs[r.member].push(ri);
    }
    let sim = {
        // The member's weights come from each request (same mask
        // structure — that is what the fingerprint routing matched);
        // members absent from the window stream zeros via padding.
        let blocks: Vec<&SparseBlock> =
            resident.blocks.iter().map(|b| b.as_ref()).collect();
        let batches: Vec<Vec<MemberSegment<'_>>> = member_reqs
            .iter()
            .map(|idxs| {
                idxs.iter()
                    .map(|&ri| MemberSegment {
                        block: requests[ri].block.as_ref(),
                        xs: requests[ri].xs.as_slice(),
                    })
                    .collect()
            })
            .collect();
        simulate_fused_batch(
            &serving.outcome.mapping,
            &serving.outcome.tags,
            &blocks,
            cgra,
            &batches,
        )
    };
    match sim {
        Ok(res) => {
            metrics.windows.fetch_add(1, Ordering::Relaxed);
            // The window pays for the resident configuration ONCE — this
            // is the fused double-count fix: W member requests no longer
            // charge W whole-bundle passes.
            metrics.total_cycles.fetch_add(res.cycles, Ordering::Relaxed);
            let latency_ns = started.elapsed().as_nanos() as u64;
            let ii = serving.outcome.mapping.ii;
            let mut per_request: Vec<Option<SegmentSim>> = Vec::new();
            per_request.resize_with(w, || None);
            for (mi, m) in res.per_member.into_iter().enumerate() {
                for (seg, &ri) in m.segments.into_iter().zip(&member_reqs[mi]) {
                    per_request[ri] = Some(seg);
                }
            }
            for (ri, r) in requests.into_iter().enumerate() {
                let seg = per_request[ri].take().expect("one segment per request");
                metrics.total_latency_ns.fetch_add(latency_ns, Ordering::Relaxed);
                r.done.fulfill(Ok(InferResult {
                    id: r.id,
                    block_name: r.block.name.clone(),
                    outputs: seg.outputs,
                    cycles: seg.cycles,
                    ii,
                    mapped_fresh: fresh && ri == 0,
                    fused_members: resident.len(),
                    latency_ns,
                }));
            }
        }
        Err(e) => {
            metrics.failures.fetch_add(w as u64, Ordering::Relaxed);
            let err = ServeError::Sim(e.to_string());
            for r in requests {
                r.done.fulfill(Err(err.clone()));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen::paper_blocks;

    fn small_cfg() -> SparsemapConfig {
        let mut cfg = SparsemapConfig::default();
        cfg.workers = 2;
        cfg.queue_depth = 4;
        cfg.mis_iterations = 20_000;
        cfg
    }

    fn stream_for(block: &SparseBlock, n: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = crate::util::rng::Pcg64::seeded(seed);
        (0..n)
            .map(|_| (0..block.c).map(|_| rng.next_normal() as f32).collect())
            .collect()
    }

    #[test]
    fn processes_jobs_and_caches_mappings() {
        let cfg = small_cfg();
        let coord = Coordinator::new(&cfg);
        let mut session = coord.session();
        let block = Arc::new(paper_blocks()[1].block.clone());
        let tickets: Vec<Ticket> = (0..6u64)
            .map(|seed| session.enqueue(Arc::clone(&block), stream_for(&block, 8, seed)))
            .collect();
        for (i, t) in tickets.into_iter().enumerate() {
            assert_eq!(t.id(), i as u64);
            assert_eq!(t.block_name(), block.name);
            let r = t.wait().expect("job ok");
            assert_eq!(r.outputs.len(), 8);
            assert_eq!(r.fused_members, 1);
        }
        let m = coord.metrics.snapshot();
        assert_eq!(m.jobs, 6);
        assert_eq!(m.failures, 0);
        assert_eq!(m.cache_misses, 1, "one block → one mapping");
        assert_eq!(m.cache_hits, 5);
        assert_eq!(m.windows, 0, "solo traffic forms no windows");
    }

    #[test]
    fn outputs_match_reference_forward() {
        let cfg = small_cfg();
        let coord = Coordinator::new(&cfg);
        let mut session = coord.session();
        let block = Arc::new(paper_blocks()[2].block.clone());
        let xs = stream_for(&block, 12, 9);
        let r = session.enqueue(Arc::clone(&block), xs.clone()).wait().unwrap();
        for (x, y) in xs.iter().zip(&r.outputs) {
            let want = block.forward(x);
            for (a, b) in y.iter().zip(&want) {
                assert!((a - b).abs() < 1e-4 * (1.0 + b.abs()), "{a} vs {b}");
            }
        }
    }

    #[test]
    fn same_shape_different_masks_do_not_share_mappings() {
        // Regression: the cache used to key by name#CxK only, so two blocks
        // with equal name and shape but different sparsity patterns shared
        // one mapping and returned wrong outputs for the second.
        let cfg = small_cfg();
        let coord = Coordinator::new(&cfg);
        let mut session = coord.session();
        let a = Arc::new(
            SparseBlock::from_mask(
                "twin",
                3,
                3,
                vec![true, true, false, false, true, true, true, false, true],
            )
            .unwrap(),
        );
        let b = Arc::new(
            SparseBlock::from_mask(
                "twin",
                3,
                3,
                vec![true, false, true, true, true, false, false, true, true],
            )
            .unwrap(),
        );
        let xs = stream_for(&a, 6, 3);
        let ta = session.enqueue(Arc::clone(&a), xs.clone());
        let tb = session.enqueue(Arc::clone(&b), xs.clone());
        for (block, ticket) in [(&a, ta), (&b, tb)] {
            let r = ticket.wait().expect("job ok");
            for (x, y) in xs.iter().zip(&r.outputs) {
                let want = block.forward(x);
                for (got, w) in y.iter().zip(&want) {
                    assert!(
                        (got - w).abs() < 1e-4 * (1.0 + w.abs()),
                        "{}: {got} vs {w}",
                        block.name
                    );
                }
            }
        }
        assert_eq!(coord.metrics.snapshot().cache_misses, 2, "one mapping per mask");
    }

    fn tiny(name: &str, c: usize, k: usize, mask: Vec<bool>) -> Arc<SparseBlock> {
        Arc::new(SparseBlock::from_mask(name, c, k, mask).unwrap())
    }

    fn tiny_members() -> Vec<Arc<SparseBlock>> {
        vec![
            tiny("f1", 2, 2, vec![true, false, true, true]),
            tiny("f2", 3, 2, vec![true, true, false, true, true, false]),
            tiny("f3", 2, 3, vec![true, false, true, false, true, true]),
        ]
    }

    #[test]
    fn tickets_resolve_queue_closed_when_pool_is_shut_down() {
        let cfg = small_cfg();
        let mut coord = Coordinator::new(&cfg);
        // Shut the pool down out from under the session: close the queue
        // and join every worker, exactly the state a torn-down pool leaves.
        coord.tx.take();
        for w in coord.workers.drain(..) {
            w.join().unwrap();
        }
        let mut session = coord.session();
        let block = tiny("late", 2, 2, vec![true, false, true, true]);
        let t = session.enqueue(Arc::clone(&block), stream_for(&block, 2, 1));
        match t.wait() {
            Err(ServeError::QueueClosed) => {}
            other => panic!("expected QueueClosed, got {other:?}"),
        }
    }

    #[test]
    fn dropped_completer_resolves_worker_gone() {
        // A worker that dies mid-job (panic/teardown) drops the completer
        // unfulfilled: the ticket must resolve instead of hanging.
        let state = TicketState::new();
        let done = TicketCompleter { state: Arc::clone(&state) };
        let mut t = Ticket { id: 7, block_name: "x".into(), state, window: None };
        assert!(t.try_wait().is_none(), "pending ticket polls None");
        drop(done);
        assert!(matches!(t.try_wait(), Some(Err(ServeError::WorkerGone))));
        assert!(matches!(t.wait(), Err(ServeError::WorkerGone)));
    }

    #[test]
    fn completion_is_first_wins() {
        let state = TicketState::new();
        let done = TicketCompleter { state: Arc::clone(&state) };
        done.fulfill(Err(ServeError::QueueClosed));
        // The drop guard ran after fulfill and must not overwrite.
        let t = Ticket { id: 0, block_name: "x".into(), state, window: None };
        assert!(matches!(t.wait(), Err(ServeError::QueueClosed)));
    }

    #[test]
    fn fused_bundle_serves_member_requests_through_one_window() {
        let cfg = small_cfg();
        let coord = Coordinator::new(&cfg);
        let members = tiny_members();
        let bundle = Arc::new(FusedBundle::new(members.clone()).unwrap());
        coord.register_bundle(Arc::clone(&bundle));

        let mut session = coord.session();
        let mut tickets = Vec::new();
        let mut streams = Vec::new();
        for (i, member) in members.iter().enumerate() {
            let xs = stream_for(member, 5, 100 + i as u64);
            tickets.push(session.enqueue(Arc::clone(member), xs.clone()));
            streams.push(xs);
        }
        session.drain();
        for (i, t) in tickets.into_iter().enumerate() {
            let r = t.wait().expect("fused job ok");
            let member = &members[i];
            assert_eq!(r.block_name, member.name);
            assert_eq!(r.fused_members, 3, "served through the bundle");
            for (x, y) in streams[i].iter().zip(&r.outputs) {
                let want = member.forward(x);
                assert_eq!(y.len(), want.len());
                for (a, w) in y.iter().zip(&want) {
                    assert!((a - w).abs() < 1e-4 * (1.0 + w.abs()), "{i}: {a} vs {w}");
                }
            }
        }
        let m = coord.metrics.snapshot();
        assert_eq!(m.jobs, 3);
        assert_eq!(m.failures, 0);
        assert_eq!(m.cache_misses, 1, "three member blocks → one fused mapping");
        assert_eq!(m.cache_hits, 2);
        assert_eq!(m.windows, 1, "three member requests → ONE lockstep pass");
    }

    #[test]
    fn mixed_fused_and_unfused_traffic() {
        let cfg = small_cfg();
        let coord = Coordinator::new(&cfg);
        let members = tiny_members();
        let bundle = Arc::new(FusedBundle::new(members[..2].to_vec()).unwrap());
        coord.register_bundle(bundle);

        let mut session = coord.session();
        let mut tickets = Vec::new();
        let mut streams = Vec::new();
        for (i, block) in members.iter().enumerate() {
            let xs = stream_for(block, 4, 7 + i as u64);
            tickets.push(session.enqueue(Arc::clone(block), xs.clone()));
            streams.push(xs);
        }
        session.drain();
        for (i, t) in tickets.into_iter().enumerate() {
            let r = t.wait().expect("mixed job ok");
            let member = &members[i];
            let want_members = if i < 2 { 2 } else { 1 };
            assert_eq!(r.fused_members, want_members, "{}", member.name);
            for (x, y) in streams[i].iter().zip(&r.outputs) {
                let want = member.forward(x);
                for (a, w) in y.iter().zip(&want) {
                    assert!((a - w).abs() < 1e-4 * (1.0 + w.abs()), "{i}: {a} vs {w}");
                }
            }
        }
        let m = coord.metrics.snapshot();
        assert_eq!(m.cache_misses, 2, "one fused + one solo mapping");
        assert_eq!(m.windows, 1, "the two member requests share one window");
    }

    #[test]
    fn windows_form_deterministically_from_enqueue_order() {
        // Window contents are a pure function of enqueue order and the
        // two knobs — no timing involved.
        let run = |window_requests: usize, window_max: usize, n: usize| -> (u64, u64) {
            let mut cfg = small_cfg();
            cfg.batch_window_requests = window_requests;
            cfg.batch_window_max = window_max;
            let coord = Coordinator::new(&cfg);
            let members = tiny_members();
            coord.register_bundle(Arc::new(FusedBundle::new(members.clone()).unwrap()));
            let mut session = coord.session();
            let tickets: Vec<Ticket> = (0..n)
                .map(|i| {
                    let b = &members[i % members.len()];
                    session.enqueue(Arc::clone(b), stream_for(b, 2, i as u64))
                })
                .collect();
            session.drain();
            for t in tickets {
                t.wait().expect("windowed job ok");
            }
            let m = coord.metrics.snapshot();
            (m.windows, m.jobs)
        };
        // 7 requests at window size 3 → 3 + 3 + 1 (trailing flush).
        assert_eq!(run(3, 0, 7), (3, 7));
        assert_eq!(run(3, 0, 7), (3, 7), "repeat runs form identical windows");
        // Window size 1 disables aggregation: one pass per request.
        assert_eq!(run(1, 0, 5), (5, 5));
        // The iteration cap seals windows too: requests bring 2 iterations
        // each, round-robin over 3 members, so a cap of 4 seals a window
        // every time some member's total reaches 4 — the request-count
        // knob (100) never triggers. 12 requests must split into several
        // windows, identically on every run.
        let first = run(100, 4, 12);
        assert_eq!(first.1, 12);
        assert!(
            first.0 > 1,
            "the iteration cap must split an under-count window (got {})",
            first.0
        );
        assert_eq!(run(100, 4, 12), first, "cap-driven windows are deterministic too");
    }

    #[test]
    fn iteration_cap_never_pads_an_earlier_short_request() {
        // A short request aboard an open window must not share a lockstep
        // pass with a later rider that would blow the iteration cap: the
        // window seals *before* the oversized request is admitted.
        let mut cfg = small_cfg();
        cfg.batch_window_requests = 100;
        cfg.batch_window_max = 8;
        let coord = Coordinator::new(&cfg);
        let members = tiny_members();
        coord.register_bundle(Arc::new(FusedBundle::new(members.clone()).unwrap()));
        let mut session = coord.session();
        let short = session.enqueue(Arc::clone(&members[0]), stream_for(&members[0], 2, 1));
        let long = session.enqueue(Arc::clone(&members[1]), stream_for(&members[1], 20, 2));
        session.drain();
        let short = short.wait().expect("short request ok");
        let long = long.wait().expect("long request ok");
        assert_eq!(
            coord.metrics.snapshot().windows,
            2,
            "the oversized rider opens (and immediately seals) its own window"
        );
        assert!(
            short.cycles < long.cycles,
            "the short request ({} cycles) must not be charged the rider's \
             padded pass ({} cycles)",
            short.cycles,
            long.cycles
        );
    }

    #[test]
    fn lru_evicts_least_recently_used_mapping() {
        // Serialized single-worker traffic so the use order is exact:
        // A, B fill a capacity-2 cache; touching A makes B the LRU victim
        // when C arrives; B then re-maps on its next request.
        let mut cfg = small_cfg();
        cfg.workers = 1;
        cfg.cache_capacity = 2;
        let coord = Coordinator::new(&cfg);
        let blocks = tiny_members(); // a, b, c stand-ins
        let mut session = coord.session();
        let mut seed = 0u64;
        let mut run = |session: &mut ServeSession<'_>, bi: usize| -> InferResult {
            let block = &blocks[bi];
            let xs = stream_for(block, 2, seed);
            seed += 1;
            session.enqueue(Arc::clone(block), xs).wait().expect("job ok")
        };
        assert!(run(&mut session, 0).mapped_fresh); // A miss
        assert!(run(&mut session, 1).mapped_fresh); // B miss
        assert!(!run(&mut session, 0).mapped_fresh); // A hit (bumps A)
        assert!(run(&mut session, 2).mapped_fresh); // C miss → evicts B (LRU)
        assert!(!run(&mut session, 0).mapped_fresh); // A survived
        assert!(run(&mut session, 1).mapped_fresh, "B was evicted and must re-map");
        let m = coord.metrics.snapshot();
        assert_eq!(m.cache_misses, 4);
        assert_eq!(m.cache_hits, 2);
    }

    #[test]
    fn eviction_order_follows_tick_index_at_capacity_64() {
        // The tick-ordered BTreeMap index must reproduce exact LRU order
        // at a capacity where the retired full-map scan was the cost
        // concern. One cheap real mapping is cloned into every entry.
        let capacity = 64usize;
        let cache = MappingCache::new(capacity);
        let metrics = Metrics::default();
        let block = tiny("evict", 2, 2, vec![true, false, true, true]);
        let cgra = StreamingCgra::paper_default();
        let opts = MapperOptions::sparsemap();
        let outcome = map_unit(MapUnit::Single(&block), &cgra, &opts).unwrap();
        let fill = |key: &str| {
            cache
                .get_or_map(key, &metrics, || {
                    Ok(ServingMapping { outcome: outcome.clone(), bundle: None })
                })
                .unwrap()
        };
        for i in 0..capacity {
            fill(&format!("k{i:02}"));
        }
        // Touch the even keys (in order): odd keys become the LRU tail.
        for i in (0..capacity).step_by(2) {
            let (_, fresh) = cache
                .get_or_map(&format!("k{i:02}"), &metrics, || {
                    unreachable!("touch must hit")
                })
                .unwrap();
            assert!(!fresh);
        }
        // Each insert beyond capacity evicts exactly the next odd key.
        for j in 0..capacity / 2 {
            fill(&format!("n{j:02}"));
            let inner = cache.inner.lock().unwrap();
            assert_eq!(inner.map.len(), capacity);
            assert_eq!(inner.by_tick.len(), capacity, "index tracks the map");
            let victim = format!("k{:02}", 2 * j + 1);
            assert!(!inner.map.contains_key(&victim), "{victim} evicted at step {j}");
            if 2 * (j + 1) + 1 < capacity {
                let next = format!("k{:02}", 2 * (j + 1) + 1);
                assert!(inner.map.contains_key(&next), "{next} not yet evicted");
            }
        }
        // Every touched (even) key survived the whole sweep.
        let inner = cache.inner.lock().unwrap();
        for i in (0..capacity).step_by(2) {
            assert!(inner.map.contains_key(&format!("k{i:02}")));
        }
    }

    #[test]
    fn concurrent_cold_start_maps_once() {
        // Many concurrent requests for one cold block: single-flight must
        // map exactly once while waiters sleep on the entry's condvar
        // (not on the cache map), then share the result.
        let mut cfg = small_cfg();
        cfg.workers = 4;
        cfg.queue_depth = 8;
        let coord = Coordinator::new(&cfg);
        let block = Arc::new(paper_blocks()[0].block.clone());
        let mut session = coord.session();
        let tickets: Vec<Ticket> = (0..8u64)
            .map(|seed| session.enqueue(Arc::clone(&block), stream_for(&block, 4, seed)))
            .collect();
        for t in tickets {
            t.wait().expect("job ok");
        }
        let m = coord.metrics.snapshot();
        assert_eq!(m.cache_misses, 1, "one mapping for 8 concurrent requests");
        assert_eq!(m.cache_hits, 7);
    }

    #[test]
    fn failed_build_leaves_no_dead_cache_entry() {
        // A failed (deterministically re-failing) mapping must not leave a
        // permanent Empty entry behind: Empty entries are not LRU victims,
        // so a dead one would pin cache_capacity forever.
        let cache = MappingCache::new(1);
        let metrics = Metrics::default();
        let err = cache.get_or_map("dead", &metrics, || {
            Err(Error::Workload("unmappable".into()))
        });
        assert!(err.is_err());
        {
            let inner = cache.inner.lock().unwrap();
            assert_eq!(inner.map.len(), 0, "failed build must remove its cache entry");
            assert_eq!(inner.by_tick.len(), 0, "and its tick-index row");
        }
        // The capacity-1 cache is free again: a successful build for the
        // same key caches normally and subsequent requests hit.
        let block = tiny("cachetest", 2, 2, vec![true, false, true, true]);
        let cgra = StreamingCgra::paper_default();
        let opts = MapperOptions::sparsemap();
        let build = || {
            let outcome = map_unit(MapUnit::Single(&block), &cgra, &opts)?;
            Ok(ServingMapping { outcome, bundle: None })
        };
        let (_, fresh) = cache.get_or_map("dead", &metrics, build).unwrap();
        assert!(fresh);
        let (_, fresh) = cache
            .get_or_map("dead", &metrics, || unreachable!("second request must hit"))
            .unwrap();
        assert!(!fresh);
        let inner = cache.inner.lock().unwrap();
        assert_eq!(inner.map.len(), 1);
        assert_eq!(inner.by_tick.len(), 1);
    }

    #[test]
    fn register_fused_plans_with_configured_knobs() {
        let mut cfg = small_cfg();
        cfg.max_fused_blocks = 2;
        cfg.fusion_max_ii = 12;
        let coord = Coordinator::new(&cfg);
        let members = tiny_members();
        let plan = coord.register_fused(&members);
        assert!(plan.iter().all(|b| b.len() <= 2));
        assert_eq!(plan.iter().map(|b| b.len()).sum::<usize>(), members.len());
        // First planned pair is registered: a member request serves fused.
        let first = &plan[0];
        assert!(first.len() == 2, "tiny blocks must pack in pairs");
        let member = Arc::clone(&first.blocks[0]);
        let xs = stream_for(&member, 2, 3);
        let mut session = coord.session();
        let r = session.enqueue(member, xs).wait().expect("fused job ok");
        assert_eq!(r.fused_members, 2);
    }

    #[test]
    fn multiple_blocks_in_flight() {
        let cfg = small_cfg();
        let coord = Coordinator::new(&cfg);
        let blocks: Vec<Arc<SparseBlock>> = paper_blocks()
            .into_iter()
            .take(3)
            .map(|nb| Arc::new(nb.block))
            .collect();
        let mut session = coord.session();
        let mut tickets = Vec::new();
        let mut seed = 0u64;
        for block in &blocks {
            for _ in 0..2 {
                tickets.push(session.enqueue(Arc::clone(block), stream_for(block, 4, seed)));
                seed += 1;
            }
        }
        session.drain();
        for t in tickets {
            t.wait().expect("job ok");
        }
        let m = coord.metrics.snapshot();
        assert_eq!(m.cache_misses, 3);
    }
}
