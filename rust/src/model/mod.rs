//! Model ingestion: pruned-layer dumps → [`SparseLayer`]s →
//! [`NetworkGraph`]s the coordinator serves end-to-end.
//!
//! - [`dump`] — the self-describing layer-dump format (loader + writer,
//!   bit-identical round trip, garbage-tolerant parse).
//! - [`graph`] — [`NetworkGraph`]: ordered pruned layers partitioned into
//!   mapper-sized blocks, plus the `vgg_head` / `resnet_tail` presets.
//! - [`SparsityProfile`] — per-layer characterization (overall sparsity,
//!   per-channel fanout histogram, per-kernel size histogram), the
//!   fpgaconvnet-style summary `report::sparsity_table` renders and
//!   `cli ingest` prints.

pub mod dump;
pub mod graph;

pub use dump::{dump_to_string, load_dump, load_dump_file, write_dump_file, ModelDump};
pub use graph::{resnet_tail, vgg_head, NetworkGraph, NetworkLayer};

use crate::sparse::partition::SparseLayer;

/// Per-layer sparsity characterization.
///
/// The histograms are indexed by value: `fanout_hist[f]` counts channels
/// whose weights reach `f` kernels; `kernel_hist[s]` counts kernels with
/// `s` live channels. Both always have at least one entry (index 0).
#[derive(Clone, Debug)]
pub struct SparsityProfile {
    pub name: String,
    pub c_total: usize,
    pub k_total: usize,
    pub nonzeros: usize,
    /// Fraction of zero weights.
    pub sparsity: f64,
    /// `fanout_hist[f]` = number of channels with fanout `f`.
    pub fanout_hist: Vec<usize>,
    /// `kernel_hist[s]` = number of kernels of size `s`.
    pub kernel_hist: Vec<usize>,
}

impl SparsityProfile {
    /// (min, median, max) channel fanout over channels with any weight.
    pub fn fanout_spread(&self) -> (usize, usize, usize) {
        spread(&self.fanout_hist)
    }

    /// (min, median, max) kernel size over kernels with any weight.
    pub fn kernel_spread(&self) -> (usize, usize, usize) {
        spread(&self.kernel_hist)
    }
}

/// Characterize one layer.
pub fn profile(layer: &SparseLayer) -> SparsityProfile {
    let (c, k) = (layer.c_total, layer.k_total);
    let mut fanout = vec![0usize; c];
    let mut ksize = vec![0usize; k];
    let mut nonzeros = 0usize;
    for ch in 0..c {
        for kr in 0..k {
            if layer.mask[ch * k + kr] {
                fanout[ch] += 1;
                ksize[kr] += 1;
                nonzeros += 1;
            }
        }
    }
    SparsityProfile {
        name: layer.name.clone(),
        c_total: c,
        k_total: k,
        nonzeros,
        sparsity: 1.0 - nonzeros as f64 / (c * k) as f64,
        fanout_hist: histogram(&fanout),
        kernel_hist: histogram(&ksize),
    }
}

/// Characterize every layer of a network.
pub fn profile_network(net: &NetworkGraph) -> Vec<SparsityProfile> {
    net.layers.iter().map(|nl| profile(&nl.layer)).collect()
}

fn histogram(values: &[usize]) -> Vec<usize> {
    let max = values.iter().copied().max().unwrap_or(0);
    let mut hist = vec![0usize; max + 1];
    for &v in values {
        hist[v] += 1;
    }
    hist
}

/// (min, median, max) over the nonzero-valued entries of a histogram
/// (index 0 — dead channels/kernels — excluded).
fn spread(hist: &[usize]) -> (usize, usize, usize) {
    let total: usize = hist.iter().skip(1).sum();
    if total == 0 {
        return (0, 0, 0);
    }
    let min = hist.iter().enumerate().skip(1).find(|(_, &n)| n > 0).map(|(i, _)| i).unwrap();
    let max = hist
        .iter()
        .enumerate()
        .skip(1)
        .rev()
        .find(|(_, &n)| n > 0)
        .map(|(i, _)| i)
        .unwrap();
    let mut seen = 0usize;
    let mut median = min;
    for (i, &n) in hist.iter().enumerate().skip(1) {
        seen += n;
        if seen * 2 >= total {
            median = i;
            break;
        }
    }
    (min, median, max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn profile_counts_histograms() {
        // 2x3 layer: channel 0 reaches kernels {0,2}; channel 1 reaches {0}.
        let mask = vec![true, false, true, true, false, false];
        let weights = vec![1.0, 0.0, 2.0, 3.0, 0.0, 0.0];
        let l = SparseLayer::new("p", 2, 3, weights, mask).unwrap();
        let p = profile(&l);
        assert_eq!(p.nonzeros, 3);
        assert!((p.sparsity - 0.5).abs() < 1e-9);
        // Fanouts: [2, 1] → hist [0, 1, 1].
        assert_eq!(p.fanout_hist, vec![0, 1, 1]);
        // Kernel sizes: [2, 0, 1] → hist [1, 1, 1].
        assert_eq!(p.kernel_hist, vec![1, 1, 1]);
        assert_eq!(p.fanout_spread(), (1, 1, 2));
        assert_eq!(p.kernel_spread(), (1, 1, 2));
    }

    #[test]
    fn profile_matches_prune_sparsity() {
        use crate::sparse::prune::{sparsity, synthetic_pruned_layer};
        let l = synthetic_pruned_layer("s", 16, 12, 0.7, 5).unwrap();
        let p = profile(&l);
        assert!((p.sparsity - sparsity(&l)).abs() < 1e-12);
        let total_by_fanout: usize =
            p.fanout_hist.iter().enumerate().map(|(f, &n)| f * n).sum();
        assert_eq!(total_by_fanout, p.nonzeros);
    }
}
