//! `NetworkGraph`: an ordered chain of pruned [`SparseLayer`]s, each
//! pre-partitioned into mapper-sized blocks. This is the unit the
//! coordinator registers and serves end-to-end — layer L's assembled
//! outputs stream into layer L+1's partitioned-block requests.
//!
//! Construction validates the chain shape (`layers[i].k_total ==
//! layers[i+1].c_total` — the im2col-flattened view where a layer's
//! kernels are the next layer's channels) and partitions every layer up
//! front, so a registered network's block population is fixed and the
//! fusion planner can pack the small-layer tiles into bundles.
//!
//! The [`vgg_head`] / [`resnet_tail`] presets build synthetic pruned
//! networks at real layer widths via
//! [`crate::sparse::prune::synthetic_pruned_layer`]; their k ≥ 96 layers
//! tile into the wide-block class (PR 3) the mapper's `wide` operating
//! point exists for.

use crate::error::{Error, Result};
use crate::sparse::partition::{LayerBlock, SparseLayer};

/// One layer of a network: the layer itself, its tile caps, and the
/// partitioned blocks (fixed at construction).
#[derive(Clone, Debug)]
pub struct NetworkLayer {
    pub layer: SparseLayer,
    pub max_c: usize,
    pub max_k: usize,
    pub blocks: Vec<LayerBlock>,
}

/// An ordered chain of pruned layers, partitioned and ready to register.
#[derive(Clone, Debug)]
pub struct NetworkGraph {
    pub name: String,
    pub layers: Vec<NetworkLayer>,
}

/// Default per-layer tile caps: k ≥ 96 layers tile into the proven
/// wide-block class (`32 × 128`); small layers tile into paper-block-sized
/// pieces the fusion planner can bundle.
pub fn tile_caps(layer: &SparseLayer) -> (usize, usize) {
    if layer.k_total >= 96 {
        (32, 128)
    } else {
        (8, 8)
    }
}

impl NetworkGraph {
    pub fn new(name: &str) -> Self {
        NetworkGraph { name: name.to_string(), layers: Vec::new() }
    }

    /// Append a layer with explicit tile caps. Validates the chain shape
    /// and that the layer partitions into at least one block.
    pub fn push_layer(&mut self, layer: SparseLayer, max_c: usize, max_k: usize) -> Result<()> {
        if let Some(prev) = self.layers.last() {
            if prev.layer.k_total != layer.c_total {
                return Err(Error::Workload(format!(
                    "network '{}': layer '{}' expects {} input channels but '{}' \
                     produces {} kernels",
                    self.name, layer.name, layer.c_total, prev.layer.name, prev.layer.k_total
                )));
            }
        }
        let blocks = layer.partition(max_c, max_k);
        if blocks.is_empty() {
            return Err(Error::Workload(format!(
                "network '{}': layer '{}' is entirely zero — nothing to serve",
                self.name, layer.name
            )));
        }
        self.layers.push(NetworkLayer { layer, max_c, max_k, blocks });
        Ok(())
    }

    /// Build a network from layers in order, using [`tile_caps`] per layer.
    pub fn from_layers(name: &str, layers: Vec<SparseLayer>) -> Result<Self> {
        let mut net = NetworkGraph::new(name);
        for layer in layers {
            let (max_c, max_k) = tile_caps(&layer);
            net.push_layer(layer, max_c, max_k)?;
        }
        if net.layers.is_empty() {
            return Err(Error::Workload(format!("network '{name}': no layers")));
        }
        Ok(net)
    }

    /// Input width (channels of the first layer).
    pub fn input_width(&self) -> usize {
        self.layers.first().map_or(0, |l| l.layer.c_total)
    }

    /// Output width (kernels of the last layer).
    pub fn output_width(&self) -> usize {
        self.layers.last().map_or(0, |l| l.layer.k_total)
    }

    /// Total partitioned blocks across all layers.
    pub fn block_count(&self) -> usize {
        self.layers.iter().map(|l| l.blocks.len()).sum()
    }

    /// Dense reference forward: chain every layer's
    /// [`SparseLayer::forward`]. The serving path
    /// (`ServeSession::enqueue_network`) is held bit-identical to this.
    pub fn forward(&self, x: &[f32]) -> Vec<f32> {
        let mut cur = x.to_vec();
        for nl in &self.layers {
            cur = nl.layer.forward(&cur);
        }
        cur
    }
}

/// Synthetic pruned VGG-16 head at real layer widths: conv1_1 … conv2_2.
/// The k = 128 layers tile into the wide_k128 class (`32 × 128` tiles at
/// ~0.92 sparsity — the exact shape `sparse::gen::wide_blocks` benches).
pub fn vgg_head() -> NetworkGraph {
    use crate::sparse::prune::synthetic_pruned_layer;
    let layers = vec![
        // Early layers prune little; deep layers prune hard (paper §1).
        synthetic_pruned_layer("conv1_1", 3, 64, 0.30, 1101).unwrap(),
        synthetic_pruned_layer("conv1_2", 64, 64, 0.80, 1102).unwrap(),
        synthetic_pruned_layer("conv2_1", 64, 128, 0.92, 1103).unwrap(),
        synthetic_pruned_layer("conv2_2", 128, 128, 0.92, 1104).unwrap(),
    ];
    NetworkGraph::from_layers("vgg_head", layers).expect("vgg_head preset")
}

/// Synthetic pruned ResNet-18 tail at real layer widths: the deep,
/// hard-pruned end of the network plus the narrow projection into the
/// classifier head.
pub fn resnet_tail() -> NetworkGraph {
    use crate::sparse::prune::synthetic_pruned_layer;
    let layers = vec![
        synthetic_pruned_layer("layer4_conv1", 128, 128, 0.92, 2101).unwrap(),
        synthetic_pruned_layer("layer4_conv2", 128, 256, 0.94, 2102).unwrap(),
        synthetic_pruned_layer("layer4_conv3", 256, 256, 0.94, 2103).unwrap(),
        synthetic_pruned_layer("fc_proj", 256, 64, 0.90, 2104).unwrap(),
    ];
    NetworkGraph::from_layers("resnet_tail", layers).expect("resnet_tail preset")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::partition::SparseLayer;
    use crate::sparse::prune::synthetic_pruned_layer;
    use crate::util::rng::Pcg64;

    #[test]
    fn rejects_shape_mismatch_between_layers() {
        let a = synthetic_pruned_layer("a", 4, 6, 0.4, 1).unwrap();
        let b = synthetic_pruned_layer("b", 5, 4, 0.4, 2).unwrap();
        let err = NetworkGraph::from_layers("bad", vec![a, b]).unwrap_err();
        assert!(err.to_string().contains("expects 5 input channels"), "{err}");
    }

    #[test]
    fn rejects_all_zero_layer() {
        let z = SparseLayer::new("z", 4, 4, vec![0.0; 16], vec![false; 16]).unwrap();
        assert!(NetworkGraph::from_layers("zero", vec![z]).is_err());
    }

    #[test]
    fn forward_chains_layers() {
        let a = synthetic_pruned_layer("a", 6, 8, 0.5, 3).unwrap();
        let b = synthetic_pruned_layer("b", 8, 5, 0.5, 4).unwrap();
        let want_a = a.clone();
        let want_b = b.clone();
        let net = NetworkGraph::from_layers("two", vec![a, b]).unwrap();
        assert_eq!(net.input_width(), 6);
        assert_eq!(net.output_width(), 5);
        let mut rng = Pcg64::seeded(9);
        let x: Vec<f32> = (0..6).map(|_| rng.next_normal() as f32).collect();
        let got = net.forward(&x);
        let want = want_b.forward(&want_a.forward(&x));
        assert_eq!(got, want);
    }

    #[test]
    fn presets_build_with_wide_class_tiles() {
        for net in [vgg_head(), resnet_tail()] {
            assert!(net.layers.len() >= 4, "{}", net.name);
            assert!(net.block_count() > 0);
            // At least one layer tiles into the wide-block class.
            let wide = net
                .layers
                .iter()
                .any(|nl| nl.max_k >= 96 && nl.blocks.iter().any(|lb| lb.block.k >= 96));
            assert!(wide, "{}: no wide_k128-class tiles", net.name);
            // Chain shape holds end to end.
            for pair in net.layers.windows(2) {
                assert_eq!(pair[0].layer.k_total, pair[1].layer.c_total);
            }
        }
    }
}
