//! The pruned-layer dump format: a self-describing, line-oriented text
//! format carrying a network's layers (name, `c_total × k_total`, dense
//! f32 weights, optional 0/1 mask). This is the ingestion path for real
//! pruned models — the stand-in for npz/ONNX-style layer dumps until a
//! binary front-end lands (ROADMAP follow-on).
//!
//! ```text
//! # sparsemap model dump v1
//! network tiny_cnn
//! layer 2 3 conv1
//! weights 0x3f800000 0x00000000 0xbf000000
//! weights 0x00000000 0x40200000 0x3e800000
//! mask 101011
//! end
//! ```
//!
//! Rules, chosen to mirror the warm-start manifest's garbage tolerance:
//!
//! - The first non-empty line must be the [`DUMP_HEADER`]; later `#` lines
//!   are comments.
//! - Weights are written as f32 bit patterns (`0x{:08x}` of
//!   [`f32::to_bits`]) so a loader↔writer round trip is bit-identical;
//!   the parser also accepts plain decimal floats for hand-written dumps.
//! - `mask` is optional — absent, it derives as `weight != 0.0`. Present,
//!   weights outside the mask are forced to zero (pruned semantics).
//! - Unknown keywords are tolerated with a warning (a newer writer may
//!   emit fields this parser predates); structural damage — truncated
//!   payload, weight-count or mask-length mismatch against the declared
//!   shape — is an [`Error::Workload`].

use crate::error::{Error, Result};
use crate::sparse::partition::SparseLayer;

/// Required first line of a dump file.
pub const DUMP_HEADER: &str = "# sparsemap model dump v1";

/// A loaded dump: the network name plus its layers in file order.
#[derive(Debug)]
pub struct ModelDump {
    pub name: String,
    pub layers: Vec<SparseLayer>,
}

/// Serialize layers into the dump format. Weights are emitted as bit
/// patterns, so `load_dump(&dump_to_string(n, &ls))` reproduces every
/// layer bit-identically.
pub fn dump_to_string(name: &str, layers: &[SparseLayer]) -> String {
    let mut out = String::new();
    out.push_str(DUMP_HEADER);
    out.push('\n');
    out.push_str(&format!("network {name}\n"));
    for layer in layers {
        out.push_str(&format!("layer {} {} {}\n", layer.c_total, layer.k_total, layer.name));
        for chunk in layer.weights.chunks(16) {
            out.push_str("weights");
            for w in chunk {
                out.push_str(&format!(" 0x{:08x}", w.to_bits()));
            }
            out.push('\n');
        }
        out.push_str("mask ");
        out.extend(layer.mask.iter().map(|&m| if m { '1' } else { '0' }));
        out.push('\n');
        out.push_str("end\n");
    }
    out
}

/// Write a dump file (see [`dump_to_string`]).
pub fn write_dump_file(path: &str, name: &str, layers: &[SparseLayer]) -> Result<()> {
    std::fs::write(path, dump_to_string(name, layers))?;
    Ok(())
}

/// Parse a dump. Unknown keywords warn and skip; structural damage errors.
pub fn load_dump(text: &str) -> Result<ModelDump> {
    let mut lines = text.lines();
    let header = loop {
        match lines.next() {
            Some(l) if l.trim().is_empty() => continue,
            Some(l) => break l.trim(),
            None => {
                return Err(Error::Workload("model dump: empty input".into()));
            }
        }
    };
    if header != DUMP_HEADER {
        return Err(Error::Workload(format!(
            "model dump: bad header '{header}' (want '{DUMP_HEADER}')"
        )));
    }

    let mut name = String::from("model");
    let mut layers: Vec<SparseLayer> = Vec::new();
    // Open layer being assembled: (name, c, k, weights, mask).
    let mut open: Option<(String, usize, usize, Vec<f32>, Option<Vec<bool>>)> = None;

    for raw in lines {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let (kw, rest) = match line.split_once(' ') {
            Some((kw, rest)) => (kw, rest.trim()),
            None => (line, ""),
        };
        match kw {
            "network" => {
                if rest.is_empty() {
                    crate::log_warn!("model dump: 'network' line without a name; keeping '{name}'");
                } else {
                    name = rest.to_string();
                }
            }
            "layer" => {
                if let Some((lname, ..)) = &open {
                    return Err(Error::Workload(format!(
                        "model dump: layer '{lname}' not terminated before next 'layer'"
                    )));
                }
                let mut parts = rest.splitn(3, ' ');
                let c = parse_dim(parts.next(), "c_total", rest)?;
                let k = parse_dim(parts.next(), "k_total", rest)?;
                let lname = parts.next().map(str::trim).unwrap_or("");
                if lname.is_empty() {
                    return Err(Error::Workload(format!(
                        "model dump: layer line '{rest}' missing a name"
                    )));
                }
                open = Some((lname.to_string(), c, k, Vec::new(), None));
            }
            "weights" => match &mut open {
                Some((lname, c, k, weights, _)) => {
                    for tok in rest.split_whitespace() {
                        weights.push(parse_weight(tok, lname)?);
                    }
                    if weights.len() > *c * *k {
                        return Err(Error::Workload(format!(
                            "model dump: layer '{lname}': {} weights exceed {c}x{k}",
                            weights.len()
                        )));
                    }
                }
                None => crate::log_warn!("model dump: 'weights' outside a layer; skipping"),
            },
            "mask" => match &mut open {
                Some((lname, c, k, _, mask)) => {
                    if rest.len() != *c * *k || !rest.bytes().all(|b| b == b'0' || b == b'1') {
                        return Err(Error::Workload(format!(
                            "model dump: layer '{lname}': mask is not {c}x{k} 0/1 chars"
                        )));
                    }
                    *mask = Some(rest.bytes().map(|b| b == b'1').collect());
                }
                None => crate::log_warn!("model dump: 'mask' outside a layer; skipping"),
            },
            "end" => match open.take() {
                Some((lname, c, k, mut weights, mask)) => {
                    if weights.len() != c * k {
                        return Err(Error::Workload(format!(
                            "model dump: layer '{lname}': {} weights for {c}x{k}",
                            weights.len()
                        )));
                    }
                    let mask = match mask {
                        Some(m) => {
                            // Pruned semantics: the mask is authoritative.
                            for (w, &m) in weights.iter_mut().zip(&m) {
                                if !m {
                                    *w = 0.0;
                                }
                            }
                            m
                        }
                        None => weights.iter().map(|&w| w != 0.0).collect(),
                    };
                    layers.push(SparseLayer::new(&lname, c, k, weights, mask)?);
                }
                None => crate::log_warn!("model dump: stray 'end'; skipping"),
            },
            other => {
                crate::log_warn!("model dump: unknown keyword '{other}'; skipping line");
            }
        }
    }
    if let Some((lname, ..)) = open {
        return Err(Error::Workload(format!(
            "model dump: truncated — layer '{lname}' has no 'end'"
        )));
    }
    if layers.is_empty() {
        return Err(Error::Workload(format!("model dump '{name}': no layers")));
    }
    Ok(ModelDump { name, layers })
}

/// Load a dump file (see [`load_dump`]).
pub fn load_dump_file(path: &str) -> Result<ModelDump> {
    load_dump(&std::fs::read_to_string(path)?)
}

fn parse_dim(tok: Option<&str>, what: &str, line: &str) -> Result<usize> {
    let tok = tok
        .ok_or_else(|| Error::Workload(format!("model dump: layer line '{line}' missing {what}")))?;
    let dim: usize = tok.parse().map_err(|_| {
        Error::Workload(format!("model dump: bad {what} '{tok}' in layer line '{line}'"))
    })?;
    if dim == 0 {
        return Err(Error::Workload(format!("model dump: {what} = 0 in layer line '{line}'")));
    }
    Ok(dim)
}

fn parse_weight(tok: &str, lname: &str) -> Result<f32> {
    if let Some(hex) = tok.strip_prefix("0x").or_else(|| tok.strip_prefix("0X")) {
        return u32::from_str_radix(hex, 16)
            .map(f32::from_bits)
            .map_err(|_| {
                Error::Workload(format!("model dump: layer '{lname}': bad weight bits '{tok}'"))
            });
    }
    tok.parse().map_err(|_| {
        Error::Workload(format!("model dump: layer '{lname}': bad weight '{tok}'"))
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::prune::synthetic_pruned_layer;

    fn layers() -> Vec<SparseLayer> {
        vec![
            synthetic_pruned_layer("conv1", 6, 8, 0.45, 31).unwrap(),
            synthetic_pruned_layer("conv2", 8, 5, 0.60, 32).unwrap(),
        ]
    }

    #[test]
    fn round_trip_is_bit_identical() {
        let ls = layers();
        let text = dump_to_string("tiny", &ls);
        let dump = load_dump(&text).unwrap();
        assert_eq!(dump.name, "tiny");
        assert_eq!(dump.layers.len(), ls.len());
        for (got, want) in dump.layers.iter().zip(&ls) {
            assert_eq!(got.name, want.name);
            assert_eq!((got.c_total, got.k_total), (want.c_total, want.k_total));
            assert_eq!(got.mask, want.mask);
            let gb: Vec<u32> = got.weights.iter().map(|w| w.to_bits()).collect();
            let wb: Vec<u32> = want.weights.iter().map(|w| w.to_bits()).collect();
            assert_eq!(gb, wb, "weights must round-trip bit-identically");
        }
    }

    #[test]
    fn rejects_missing_or_bad_header() {
        assert!(load_dump("").is_err());
        assert!(load_dump("network x\n").is_err());
        assert!(load_dump("# sparsemap model dump v2\nnetwork x\n").is_err());
    }

    #[test]
    fn rejects_truncated_payload() {
        // Cut the dump off mid-layer: declared 6x8 but the file ends
        // before `end`.
        let full = dump_to_string("t", &layers());
        let cut = full.find("end").unwrap();
        let err = load_dump(&full[..cut]).unwrap_err();
        assert!(err.to_string().contains("truncated"), "{err}");
    }

    #[test]
    fn rejects_weight_count_mismatch() {
        let text = format!("{DUMP_HEADER}\nlayer 2 2 l\nweights 0x3f800000 1.0 2.0\nend\n");
        let err = load_dump(&text).unwrap_err();
        assert!(err.to_string().contains("3 weights for 2x2"), "{err}");
        let text = format!("{DUMP_HEADER}\nlayer 2 2 l\nweights 1 2 3 4 5\nend\n");
        assert!(load_dump(&text).is_err());
    }

    #[test]
    fn rejects_mask_shape_mismatch() {
        let text = format!("{DUMP_HEADER}\nlayer 2 2 l\nweights 1 2 3 4\nmask 101\nend\n");
        assert!(load_dump(&text).is_err());
        let text = format!("{DUMP_HEADER}\nlayer 2 2 l\nweights 1 2 3 4\nmask 10x1\nend\n");
        assert!(load_dump(&text).is_err());
    }

    #[test]
    fn tolerates_unknown_fields_and_comments() {
        let text = format!(
            "{DUMP_HEADER}\n# a comment\nnetwork n\nframework torch-prune 2.1\n\
             layer 2 2 l\nquantization none\nweights 1.0 0.0 2.0 3.0\nend\n"
        );
        let dump = load_dump(&text).unwrap();
        assert_eq!(dump.name, "n");
        assert_eq!(dump.layers.len(), 1);
        // No mask line: derived from nonzero weights.
        assert_eq!(dump.layers[0].mask, vec![true, false, true, true]);
    }

    #[test]
    fn mask_is_authoritative_over_weights() {
        let text = format!("{DUMP_HEADER}\nlayer 2 2 l\nweights 1 2 3 4\nmask 1010\nend\n");
        let dump = load_dump(&text).unwrap();
        assert_eq!(dump.layers[0].weights, vec![1.0, 0.0, 3.0, 0.0]);
    }

    #[test]
    fn accepts_decimal_weights() {
        let text = format!("{DUMP_HEADER}\nlayer 1 3 l\nweights 1.5 -0.25 0\nend\n");
        let dump = load_dump(&text).unwrap();
        assert_eq!(dump.layers[0].weights, vec![1.5, -0.25, 0.0]);
    }
}
