//! Reference ("oracle") implementations retired from the binding hot path,
//! kept alive verbatim so the optimized rewrites stay provably equivalent.
//!
//! This is the required workflow for hot-path rewrites in this crate: the
//! old implementation moves here unchanged, and a differential suite
//! (`tests/conflict_equivalence.rs`) asserts byte-identical behavior on
//! all paper blocks plus randomized instances before the fast path ships.
//!
//! * [`build_naive`] — the original all-pairs `O(nc²)` conflict-graph edge
//!   loop, oracle for the bucketed [`crate::bind::conflict::build_into`].
//! * [`HashBusCostModel`] — the original `HashMap`-backed incremental
//!   bus-collision model, oracle for the dense slot-major
//!   [`crate::bind::BusCostModel`].
//!
//! Nothing here is on the mapper's search path; allocation and hashing
//! costs are irrelevant.

use crate::arch::StreamingCgra;
use crate::bind::conflict::{Candidate, ConflictGraph};
use crate::bind::mis::SecondaryCost;
use crate::bind::route::{Route, RoutePlan};
use crate::bind::{claims_of_edge, BusAt, EdgeClaims, Placement};
use crate::dfg::{EdgeKind, NodeId};
use crate::sched::ScheduledSDfg;
use crate::util::BitSet;

/// The original conflict-graph build: every candidate pair tested against
/// the full rule set. `O(nc²)` in candidate count — superseded by the
/// bucketed [`crate::bind::conflict::build_into`], equivalent by the
/// differential suite.
pub fn build_naive(s: &ScheduledSDfg, cgra: &StreamingCgra, plan: &RoutePlan) -> ConflictGraph {
    let mut cg = ConflictGraph::empty();
    build_naive_into(s, cgra, plan, &mut cg);
    cg
}

/// [`build_naive`] into reusable storage (kept for bench comparability
/// with the bucketed reuse path).
pub fn build_naive_into(
    s: &ScheduledSDfg,
    cgra: &StreamingCgra,
    _plan: &RoutePlan,
    cg: &mut ConflictGraph,
) {
    let g = &s.g;
    let n_nodes = g.len();

    // ---- candidates -------------------------------------------------------
    cg.candidates.clear();
    cg.of_node.resize_with(n_nodes, Vec::new);
    for v in cg.of_node.iter_mut() {
        v.clear();
    }
    let (candidates, of_node) = (&mut cg.candidates, &mut cg.of_node);
    for v in g.nodes() {
        match g.kind(v) {
            k if k.is_read() => {
                for ibus in 0..cgra.m {
                    of_node[v].push(candidates.len());
                    candidates.push(Candidate::Read { node: v, ibus });
                }
            }
            k if k.is_write() => {
                for obus in 0..cgra.n {
                    of_node[v].push(candidates.len());
                    candidates.push(Candidate::Write { node: v, obus });
                }
            }
            _ => {
                for pe in cgra.pes() {
                    of_node[v].push(candidates.len());
                    candidates.push(Candidate::Op { node: v, pe });
                }
            }
        }
    }

    // ---- edges: all candidate pairs against the full rule set -------------
    let nc = candidates.len();
    for b in cg.adj.iter_mut() {
        b.reset(nc);
    }
    cg.adj.resize_with(nc, || BitSet::new(nc));
    let (candidates, adj) = (&cg.candidates, &mut cg.adj);

    let input_src = |op: NodeId| -> Option<NodeId> {
        g.in_edges(op)
            .find(|(_, e)| e.kind == EdgeKind::Input)
            .map(|(_, e)| e.src)
    };
    let output_producer = |w: NodeId| -> NodeId {
        g.predecessors(w).next().expect("write has a producer")
    };

    for a in 0..nc {
        for b in (a + 1)..nc {
            let conflict = {
                use Candidate::*;
                let (ca, cb) = (&candidates[a], &candidates[b]);
                if ca.node() == cb.node() {
                    true // pick-one clique
                } else {
                    let slot = |v: NodeId| s.m(v);
                    match (*ca, *cb) {
                        // R1: I/O bus exclusiveness.
                        (Read { node: r1, ibus: i1 }, Read { node: r2, ibus: i2 }) => {
                            i1 == i2 && slot(r1) == slot(r2)
                        }
                        (Write { node: w1, obus: o1 }, Write { node: w2, obus: o2 }) => {
                            o1 == o2 && slot(w1) == slot(w2)
                        }
                        (Read { .. }, Write { .. }) | (Write { .. }, Read { .. }) => false,
                        // R2(1): consumers of a reading sit in its column.
                        (Read { node: r, ibus }, Op { node: op, pe })
                        | (Op { node: op, pe }, Read { node: r, ibus }) => {
                            input_src(op) == Some(r) && pe.col != ibus
                        }
                        // R2(1): the producer of a writing sits in its row.
                        (Write { node: w, obus }, Op { node: op, pe })
                        | (Op { node: op, pe }, Write { node: w, obus }) => {
                            output_producer(w) == op && pe.row != obus
                        }
                        (Op { node: v1, pe: p1 }, Op { node: v2, pe: p2 }) => {
                            // One PE, one op per modulo slot.
                            p1 == p2 && slot(v1) == slot(v2)
                        }
                    }
                }
            };
            if conflict {
                adj[a].insert(b);
                adj[b].insert(a);
            }
        }
    }

    cg.num_nodes = n_nodes;
}

/// The original incremental bus-collision model: per-bus claim multisets in
/// `HashMap`s keyed by [`BusAt`]. Superseded by the dense slot-major
/// [`crate::bind::BusCostModel`]; kept as the oracle the dense model is
/// differentially tested against (identical totals, claims and hot-node
/// sets over arbitrary claim/release sequences).
pub struct HashBusCostModel<'a> {
    s: &'a ScheduledSDfg,
    cg: &'a ConflictGraph,
    routes: &'a [Option<Route>],
    /// Claim-relevant edge indices incident to each node (whose placement
    /// affects the edge's claims).
    incident: Vec<Vec<usize>>,
    /// Per bus: value -> multiplicity.
    claims: std::collections::HashMap<BusAt, std::collections::HashMap<NodeId, usize>>,
    /// Per bus: claiming edge indices (multiset).
    bus_edges: std::collections::HashMap<BusAt, Vec<usize>>,
    /// Buses currently carrying more than one distinct value.
    hot: std::collections::HashSet<BusAt>,
    total: usize,
}

impl<'a> HashBusCostModel<'a> {
    pub fn new(s: &'a ScheduledSDfg, cg: &'a ConflictGraph, routes: &'a [Option<Route>]) -> Self {
        let mut incident: Vec<Vec<usize>> = vec![Vec::new(); s.g.len()];
        for (idx, e) in s.g.edges().iter().enumerate() {
            match e.kind {
                EdgeKind::Input => incident[e.src].push(idx),
                EdgeKind::Output => incident[e.dst].push(idx),
                EdgeKind::Internal => {
                    // Bus and LRF routes both ride the interconnect.
                    if matches!(routes[idx], Some(Route::Bus) | Some(Route::Lrf)) {
                        incident[e.src].push(idx);
                        incident[e.dst].push(idx);
                    }
                }
            }
        }
        HashBusCostModel {
            s,
            cg,
            routes,
            incident,
            claims: std::collections::HashMap::new(),
            bus_edges: std::collections::HashMap::new(),
            hot: std::collections::HashSet::new(),
            total: 0,
        }
    }

    fn placement_of(&self, cand: usize) -> Placement {
        match self.cg.candidates[cand] {
            Candidate::Read { ibus, .. } => Placement::InputBus(ibus),
            Candidate::Write { obus, .. } => Placement::OutputBus(obus),
            Candidate::Op { pe, .. } => Placement::Pe(pe),
        }
    }

    fn edge_claims(&self, idx: usize, assign: &[usize]) -> EdgeClaims {
        let place = |v: NodeId| self.placement_of(assign[v]);
        claims_of_edge(self.s, self.routes, &place, idx)
    }

    fn bus_contrib(values: &std::collections::HashMap<NodeId, usize>) -> usize {
        values.len().saturating_sub(1)
    }

    fn add_claim(&mut self, bus: BusAt, value: NodeId, edge_idx: usize, delta: isize) {
        let entry = self.claims.entry(bus).or_default();
        self.total -= Self::bus_contrib(entry);
        if delta > 0 {
            *entry.entry(value).or_insert(0) += 1;
        } else {
            let c = entry.get_mut(&value).expect("claim present");
            *c -= 1;
            if *c == 0 {
                entry.remove(&value);
            }
        }
        self.total += Self::bus_contrib(entry);
        if Self::bus_contrib(entry) > 0 {
            self.hot.insert(bus);
        } else {
            self.hot.remove(&bus);
        }
        if entry.is_empty() {
            self.claims.remove(&bus);
        }
        let edges = self.bus_edges.entry(bus).or_default();
        if delta > 0 {
            edges.push(edge_idx);
        } else if let Some(pos) = edges.iter().position(|&e| e == edge_idx) {
            edges.swap_remove(pos);
            if edges.is_empty() {
                self.bus_edges.remove(&bus);
            }
        }
    }

    /// Canonical claim state — the differential suite compares this
    /// against the dense model's snapshot.
    pub fn claims_snapshot(&self) -> crate::bind::ClaimsSnapshot {
        let mut out: crate::bind::ClaimsSnapshot = self
            .claims
            .iter()
            .map(|(&bus, values)| {
                let mut vals: Vec<(NodeId, usize)> =
                    values.iter().map(|(&v, &c)| (v, c)).collect();
                vals.sort_unstable();
                (bus, vals)
            })
            .collect();
        out.sort_unstable_by_key(|e| e.0);
        out
    }
}

impl<'a> SecondaryCost for HashBusCostModel<'a> {
    fn reset(&mut self, assign: &[usize]) {
        self.claims.clear();
        self.bus_edges.clear();
        self.hot.clear();
        self.total = 0;
        for idx in 0..self.s.g.edges().len() {
            let claims = self.edge_claims(idx, assign);
            for &(bus, value) in claims.as_slice() {
                self.add_claim(bus, value, idx, 1);
            }
        }
    }

    fn detach(&mut self, v: usize, assign: &[usize]) {
        let edges = std::mem::take(&mut self.incident[v]);
        for &idx in &edges {
            let claims = self.edge_claims(idx, assign);
            for &(bus, value) in claims.as_slice() {
                self.add_claim(bus, value, idx, -1);
            }
        }
        self.incident[v] = edges;
    }

    fn attach(&mut self, v: usize, assign: &[usize]) {
        let edges = std::mem::take(&mut self.incident[v]);
        for &idx in &edges {
            let claims = self.edge_claims(idx, assign);
            for &(bus, value) in claims.as_slice() {
                self.add_claim(bus, value, idx, 1);
            }
        }
        self.incident[v] = edges;
    }

    fn total(&self) -> usize {
        self.total
    }

    fn hot_nodes_into(&self, _assign: &[usize], out: &mut Vec<usize>) {
        // Endpoints of the edges claiming any colliding bus; sorted +
        // deduped so HashSet iteration order never leaks out.
        if self.total == 0 {
            return;
        }
        for bus in &self.hot {
            if let Some(edges) = self.bus_edges.get(bus) {
                for &idx in edges {
                    let e = self.s.g.edge(idx);
                    out.push(e.src);
                    out.push(e.dst);
                }
            }
        }
        out.sort_unstable();
        out.dedup();
    }
}
