//! SBTS-style tabu/local search for the binding problem (Jin & Hao [24],
//! as used by the paper's binding phase §4.2).
//!
//! The conflict graph has structure the generic MIS problem lacks:
//! candidates of one s-DFG node form a clique, so an independent set holds
//! at most one candidate per node and the optimum is exactly `|V_D|`. The
//! solver therefore works on *assignments* (one candidate per node, always)
//! and minimizes `hard_conflicts · K + secondary_cost` — the assignment
//! view of SBTS's (1, k)-swap neighborhood, where re-assigning a node
//! inserts one vertex and implicitly evicts every conflicting sibling
//! choice. The secondary cost hook carries the derived-bus-collision count
//! (see `crate::bind::BusCostModel` — a dense slot-major bus array, so no
//! hashing happens inside the solve), so routing quality is optimized in
//! the same search instead of a post-hoc repair. The solver trajectory is
//! a pure function of `(cg, seed, cost)`; swapping a [`SecondaryCost`]
//! implementation for a behaviorally identical one (e.g. the `HashMap`
//! oracle in `crate::bind::oracle`) reproduces it move for move — the
//! property the differential suite leans on.
//!
//! The inner loop is allocation-free: all solver state lives in a reusable
//! [`SolverScratch`], move candidates fill a recycled buffer, the
//! hard-conflict counter is maintained incrementally, and the per-move
//! conflict deltas are computed word-level over the adjacency bitsets
//! (`adj[old] ∩ chosen` / `adj[new] ∩ chosen`) instead of scanning every
//! node.

use crate::bind::conflict::ConflictGraph;
use crate::util::rng::Pcg64;
use crate::util::BitSet;

/// Secondary (soft) objective evaluated incrementally during the search.
pub trait SecondaryCost {
    /// (Re)initialize from a full assignment.
    fn reset(&mut self, assign: &[usize]);
    /// Remove node `v`'s contribution (its incident claims), given the
    /// current assignment.
    fn detach(&mut self, v: usize, assign: &[usize]);
    /// Add node `v`'s contribution back.
    fn attach(&mut self, v: usize, assign: &[usize]);
    /// Current total cost.
    fn total(&self) -> usize;
    /// Append the nodes currently contributing to the cost (move candidates
    /// once the hard constraints are satisfied) to `out`, in ascending node
    /// order without duplicates. `out` arrives cleared; implementations
    /// must not allocate beyond growing `out`.
    fn hot_nodes_into(&self, assign: &[usize], out: &mut Vec<usize>);
}

/// A no-op secondary cost (pure MIS).
pub struct NoCost;

impl SecondaryCost for NoCost {
    fn reset(&mut self, _: &[usize]) {}
    fn detach(&mut self, _: usize, _: &[usize]) {}
    fn attach(&mut self, _: usize, _: &[usize]) {}
    fn total(&self) -> usize {
        0
    }
    fn hot_nodes_into(&self, _: &[usize], _: &mut Vec<usize>) {}
}

/// Result of a solve.
#[derive(Clone, Debug)]
pub struct MisResult {
    /// Best assignment's conflict-free subset (all nodes when the solve
    /// fully succeeded — check `size() == cg.num_nodes`).
    pub chosen: Vec<usize>,
    /// The full best assignment (one candidate per node), conflicts and
    /// all — what `chosen` was extracted from.
    pub assignment: Vec<usize>,
    /// Whether both hard and secondary objectives reached zero.
    pub clean: bool,
    /// Iterations actually spent.
    pub iterations: usize,
}

impl MisResult {
    pub fn size(&self) -> usize {
        self.chosen.len()
    }
}

/// Reusable solver state: every vector and bitset the SBTS search needs,
/// recycled across solves so the mapper's retry lattice allocates nothing
/// in the 60k-iteration hot loop. Owned per thread (one per portfolio
/// worker); never shared.
#[derive(Default)]
pub struct SolverScratch {
    order: Vec<usize>,
    assign: Vec<usize>,
    best_assign: Vec<usize>,
    conf: Vec<usize>,
    tabu_until: Vec<usize>,
    pool: Vec<usize>,
    chosen: BitSet,
    kept: BitSet,
}

impl SolverScratch {
    pub fn new() -> Self {
        Self::default()
    }
}

/// Solve MIS (`cost = NoCost`) or the full binding problem with an
/// iteration budget. Deterministic for a fixed seed.
pub fn solve(cg: &ConflictGraph, max_iterations: usize, seed: u64) -> MisResult {
    solve_with(cg, max_iterations, seed, &mut NoCost)
}

/// [`solve_with_scratch`] with one-shot scratch (tests / one-off callers).
pub fn solve_with(
    cg: &ConflictGraph,
    max_iterations: usize,
    seed: u64,
    cost: &mut dyn SecondaryCost,
) -> MisResult {
    let mut scratch = SolverScratch::new();
    solve_with_scratch(cg, max_iterations, seed, cost, &mut scratch)
}

/// The SBTS solve. Identical trajectory for identical `(cg, seed, cost)`
/// regardless of what the scratch was previously used for.
pub fn solve_with_scratch(
    cg: &ConflictGraph,
    max_iterations: usize,
    seed: u64,
    cost: &mut dyn SecondaryCost,
    scratch: &mut SolverScratch,
) -> MisResult {
    let nc = cg.num_candidates();
    let n_nodes = cg.of_node.len();
    let mut rng = Pcg64::seeded(seed);
    let SolverScratch { order, assign, best_assign, conf, tabu_until, pool, chosen, kept } =
        scratch;

    // ---- greedy init: nodes with fewest candidates first.
    order.clear();
    order.extend(0..n_nodes);
    order.sort_by_key(|&v| cg.of_node[v].len());
    assign.clear();
    assign.resize(n_nodes, usize::MAX);
    chosen.reset(nc);
    for &v in order.iter() {
        let best = cg.of_node[v]
            .iter()
            .copied()
            .min_by_key(|&c| (cg.adj[c].intersection_len(chosen), cg.adj[c].len()))
            .expect("every node has candidates");
        assign[v] = best;
        chosen.insert(best);
    }
    cost.reset(assign);

    conf.clear();
    conf.extend((0..n_nodes).map(|v| cg.adj[assign[v]].intersection_len(chosen)));
    let mut hard: usize = conf.iter().sum::<usize>() / 2;

    best_assign.clear();
    best_assign.extend_from_slice(assign);
    let mut best_score = hard * 1_000_000 + cost.total();
    tabu_until.clear();
    tabu_until.resize(n_nodes, 0);
    let mut iter = 0usize;

    let mut stagnant = 0usize;
    // Bail out early on hopeless instances: past this many moves without
    // improving the best score, further search rarely converges and the
    // caller's II-escalation is the better spend.
    let stagnation_cutoff = (max_iterations / 4).max(8000);
    let mut since_best = 0usize;
    while (hard > 0 || cost.total() > 0) && iter < max_iterations {
        if since_best > stagnation_cutoff {
            break;
        }
        iter += 1;
        since_best += 1;
        // Plateau kick: after a long stretch without improving the best,
        // shake a random handful of nodes (large-neighbourhood restart).
        if stagnant > 800 {
            stagnant = 0;
            for _ in 0..4 {
                let v = rng.index(n_nodes);
                let cur = assign[v];
                chosen.remove(cur);
                cost.detach(v, assign);
                let c = cg.of_node[v][rng.index(cg.of_node[v].len())];
                assign[v] = c;
                chosen.insert(c);
                cost.attach(v, assign);
            }
            for v in 0..n_nodes {
                conf[v] = cg.adj[assign[v]].intersection_len(chosen);
            }
            hard = conf.iter().sum::<usize>() / 2;
        }
        // Pick a node to move: hard-conflicted first, else a bus-hot node.
        pool.clear();
        if hard > 0 {
            pool.extend((0..n_nodes).filter(|&v| conf[v] > 0));
        } else {
            cost.hot_nodes_into(assign, pool);
        }
        if pool.is_empty() {
            break; // nothing movable contributes — stuck
        }
        let v = if rng.chance(0.25) {
            pool[rng.index(pool.len())]
        } else {
            *pool
                .iter()
                .filter(|&&v| tabu_until[v] <= iter)
                .max_by_key(|&&v| (conf[v], rng.next_below(8)))
                .unwrap_or(&pool[rng.index(pool.len())])
        };

        // Evaluate every candidate of v under (hard, secondary).
        let cur = assign[v];
        chosen.remove(cur);
        cost.detach(v, assign);
        let noise = rng.chance(0.05);
        let mut best_c = cur;
        let mut best_local = (usize::MAX, u64::MAX);
        if noise {
            best_c = cg.of_node[v][rng.index(cg.of_node[v].len())];
        } else {
            for &c in &cg.of_node[v] {
                let h = cg.adj[c].intersection_len(chosen);
                assign[v] = c;
                cost.attach(v, assign);
                let s = h * 1_000_000 + cost.total();
                cost.detach(v, assign);
                let key = (s, rng.next_below(8));
                if key < best_local {
                    best_local = key;
                    best_c = c;
                }
            }
        }
        assign[v] = best_c;
        if best_c != cur {
            // Word-level incremental conflict update: only owners of chosen
            // candidates adjacent to the old/new placement are affected
            // (`chosen` here is exactly {assign[u] : u ≠ v}).
            let conf_v_old = conf[v];
            for c in cg.adj[cur].iter_intersection(chosen) {
                conf[cg.candidates[c].node()] -= 1;
            }
            let mut conf_v_new = 0usize;
            for c in cg.adj[best_c].iter_intersection(chosen) {
                conf[cg.candidates[c].node()] += 1;
                conf_v_new += 1;
            }
            chosen.insert(best_c);
            cost.attach(v, assign);
            tabu_until[v] = iter + 3 + rng.index(5);
            conf[v] = conf_v_new;
            // Each (v, u) conflict is counted in both conf[v] and conf[u],
            // so the total moves by exactly the conf[v] delta.
            hard = hard - conf_v_old + conf_v_new;
            debug_assert_eq!(hard, conf.iter().sum::<usize>() / 2);
            let score = hard * 1_000_000 + cost.total();
            if score < best_score {
                best_score = score;
                best_assign.copy_from_slice(assign);
                stagnant = 0;
                since_best = 0;
            } else {
                stagnant += 1;
            }
        } else {
            chosen.insert(best_c);
            cost.attach(v, assign);
            stagnant += 1;
        }
    }

    let clean = hard == 0 && cost.total() == 0;
    let final_assign: &[usize] = if clean { assign } else { best_assign };
    let mut chosen_list = Vec::with_capacity(n_nodes);
    kept.reset(nc);
    for &c in final_assign.iter() {
        if kept.is_disjoint(&cg.adj[c]) {
            kept.insert(c);
            chosen_list.push(c);
        }
    }
    MisResult {
        chosen: chosen_list,
        assignment: final_assign.to_vec(),
        clean,
        iterations: iter,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::StreamingCgra;
    use crate::bind::conflict::build;
    use crate::bind::route::preallocate;
    use crate::config::Techniques;
    use crate::dfg::analysis::mii;
    use crate::dfg::build::build_sdfg;
    use crate::sched::sparsemap::schedule_at;
    use crate::sparse::gen::paper_blocks;

    #[test]
    fn solves_paper_blocks_to_full_mis() {
        let cgra = StreamingCgra::paper_default();
        for nb in paper_blocks() {
            let (g, _) = build_sdfg(&nb.block);
            let base = mii(&g, &cgra);
            let Some((s, plan)) = (base..base + 3).find_map(|ii| {
                let s = schedule_at(&g, &cgra, Techniques::all(), ii).ok()?;
                let plan = preallocate(&s, &cgra).ok()?;
                Some((s, plan))
            }) else {
                panic!("{}: no routable schedule", nb.label);
            };
            let cg = build(&s, &cgra, &plan);
            let res = solve(&cg, 60_000, 1);
            assert_eq!(
                res.size(),
                cg.num_nodes,
                "{}: bound {} of {} nodes at II={}",
                nb.label,
                res.size(),
                cg.num_nodes,
                s.ii
            );
        }
    }

    #[test]
    fn result_is_independent_and_one_per_node() {
        let cgra = StreamingCgra::paper_default();
        let nb = &paper_blocks()[6];
        let (g, _) = build_sdfg(&nb.block);
        let s = schedule_at(&g, &cgra, Techniques::all(), mii(&g, &cgra) + 1).unwrap();
        let plan = preallocate(&s, &cgra).unwrap();
        let cg = build(&s, &cgra, &plan);
        let res = solve(&cg, 60_000, 2);
        for (i, &a) in res.chosen.iter().enumerate() {
            for &b in res.chosen.iter().skip(i + 1) {
                assert!(!cg.adj[a].contains(b), "conflicting pair in MIS");
            }
        }
        let mut seen = std::collections::HashSet::new();
        for &c in &res.chosen {
            assert!(seen.insert(cg.candidates[c].node()), "node bound twice");
        }
    }

    #[test]
    fn deterministic_for_seed() {
        let cgra = StreamingCgra::paper_default();
        let nb = &paper_blocks()[0];
        let (g, _) = build_sdfg(&nb.block);
        let s = schedule_at(&g, &cgra, Techniques::all(), mii(&g, &cgra) + 1).unwrap();
        let plan = preallocate(&s, &cgra).unwrap();
        let cg = build(&s, &cgra, &plan);
        let a = solve(&cg, 10_000, 7);
        let b = solve(&cg, 10_000, 7);
        assert_eq!(a.chosen, b.chosen);
    }

    #[test]
    fn scratch_reuse_is_deterministic() {
        // A shared scratch recycled across differently-sized solves must
        // reproduce the fresh-scratch result exactly.
        let cgra = StreamingCgra::paper_default();
        let mut shared = SolverScratch::new();
        for idx in [4usize, 0, 6] {
            let nb = &paper_blocks()[idx];
            let (g, _) = build_sdfg(&nb.block);
            let s = schedule_at(&g, &cgra, Techniques::all(), mii(&g, &cgra) + 1).unwrap();
            let plan = preallocate(&s, &cgra).unwrap();
            let cg = build(&s, &cgra, &plan);
            let reused = solve_with_scratch(&cg, 10_000, 11, &mut NoCost, &mut shared);
            let fresh = solve_with_scratch(&cg, 10_000, 11, &mut NoCost, &mut SolverScratch::new());
            assert_eq!(reused.chosen, fresh.chosen, "{}", nb.label);
            assert_eq!(reused.assignment, fresh.assignment);
            assert_eq!(reused.clean, fresh.clean);
            assert_eq!(reused.iterations, fresh.iterations);
        }
    }

    #[test]
    fn respects_iteration_budget_on_infeasible_graphs() {
        // Infeasible on purpose: two reads at the same slot on a
        // 1-input-bus machine.
        let cgra = StreamingCgra::new(2, 1, 8, 8);
        use crate::dfg::{EdgeKind, NodeKind, SDfg};
        let mut g = SDfg::new("infeasible");
        let r1 = g.add_node(NodeKind::Read { ch: 0, replica: 0 });
        let r2 = g.add_node(NodeKind::Read { ch: 1, replica: 0 });
        let m1 = g.add_node(NodeKind::Mul { ch: 0, kr: 0 });
        let m2 = g.add_node(NodeKind::Mul { ch: 1, kr: 0 });
        g.add_edge(r1, m1, EdgeKind::Input);
        g.add_edge(r2, m2, EdgeKind::Input);
        let a = g.add_node(NodeKind::Add { kr: 0 });
        g.add_edge(m1, a, EdgeKind::Internal);
        g.add_edge(m2, a, EdgeKind::Internal);
        let w = g.add_node(NodeKind::Write { kr: 0 });
        g.add_edge(a, w, EdgeKind::Output);
        let s = crate::sched::ScheduledSDfg { g, ii: 2, t: vec![0, 0, 0, 0, 1, 2] };
        let plan = preallocate(&s, &cgra).unwrap();
        let cg = build(&s, &cgra, &plan);
        let res = solve(&cg, 500, 3);
        assert!(res.size() < cg.num_nodes, "cannot bind an infeasible schedule");
        assert!(res.iterations <= 500);
    }
}
