//! Routing-resource pre-allocation for internal dependencies (mapping
//! phase ② — the BusMap mechanism the paper reuses).
//!
//! Every internal dependency is assigned a route class before binding:
//!
//! * distance 1 — **bus hop**: producer drives its row/column bus during
//!   the consumer's cycle; the conflict graph enforces adjacency and bus
//!   exclusivity.
//! * COP-sourced (any distance < II) — **bus hop from the cache**: the COP
//!   holds the value precisely so it can re-drive its buses in later
//!   cycles; same conflict rules as distance 1.
//! * MCID with `m(src) != m(dst)` — **LRF route**: the value stays in the
//!   producer PE's local register file and the consumer is bound to the
//!   same PE (REGIMap-style). A consumer can sit on only one PE, so at
//!   most one of its incoming MCIDs may take the LRF; the rest fall to the
//!   GRF.
//! * MCID with `m(src) == m(dst)` — **GRF route** (forced): LRF routing is
//!   forbidden because the producer PE is re-executing the producer at the
//!   consumer's modulo slot (paper Fig. 3 discussion). GRF writes are
//!   limited to `grf_write_ports` per modulo slot and `grf_capacity`
//!   concurrently-live values; exceeding either fails the mapping attempt
//!   at this II — this is exactly how the paper's "Failed" rows arise.

use crate::arch::StreamingCgra;
use crate::dfg::{EdgeKind, NodeKind};
use crate::error::{Error, Result};
use crate::sched::ScheduledSDfg;

/// Route class of one internal dependency (edge index keyed).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Route {
    /// Producer→consumer over a row/column bus at the consumer's slot
    /// (distance-1 deps and all COP-sourced deps).
    Bus,
    /// Consumer pinned to the producer's PE; value lives in that PE's LRF.
    Lrf,
    /// Via the global register file (crossbar write at `m(src)+1`).
    Grf,
}

/// Pre-allocated routing: `routes[edge_idx]` for every internal edge.
#[derive(Clone, Debug)]
pub struct RoutePlan {
    routes: Vec<Option<Route>>,
    /// GRF writes per modulo slot (diagnostics / tests).
    pub grf_writes_per_slot: Vec<usize>,
    /// Peak concurrently-live GRF values.
    pub grf_peak_live: usize,
}

impl RoutePlan {
    pub fn route(&self, edge_idx: usize) -> Option<Route> {
        self.routes[edge_idx]
    }

    /// Copy the per-edge route table into a reusable buffer — the
    /// [`crate::bind::ScratchPool`] path, which recycles one route vector
    /// across the mapper's whole attempt lattice.
    pub fn fill_routes(&self, out: &mut Vec<Option<Route>>) {
        out.clear();
        out.extend_from_slice(&self.routes);
    }

    /// Number of GRF-routed dependencies.
    pub fn grf_count(&self) -> usize {
        self.routes.iter().filter(|r| **r == Some(Route::Grf)).count()
    }

    pub fn lrf_count(&self) -> usize {
        self.routes.iter().filter(|r| **r == Some(Route::Lrf)).count()
    }
}

/// GRF writes per modulo slot implied by a schedule's GRF-forced MCIDs —
/// the exact classification [`preallocate`] applies (internal dependency,
/// not COP-sourced, distance > 1, same modulo slot ⇒ one GRF write at
/// `(t(src) + 1) mod II`). The fusion composition's offset search
/// (`crate::mapper`) uses this to keep a bundle's combined write-port
/// demand feasible; `grf_writes_matches_preallocate` pins it to the table
/// `preallocate` itself computes, so the two can never drift apart.
pub fn grf_writes_per_slot(s: &ScheduledSDfg) -> Vec<usize> {
    let ii = s.ii;
    let mut writes = vec![0usize; ii];
    for e in s.g.edges() {
        if e.kind != EdgeKind::Internal {
            continue;
        }
        let (t1, t2) = (s.t[e.src], s.t[e.dst]);
        if t2 - t1 <= 1 || matches!(s.g.kind(e.src), NodeKind::Cop { .. }) {
            continue;
        }
        if t1 % ii == t2 % ii {
            writes[(t1 + 1) % ii] += 1;
        }
    }
    writes
}

/// Compute the route plan, or fail when GRF ports/capacity are exceeded.
pub fn preallocate(s: &ScheduledSDfg, cgra: &StreamingCgra) -> Result<RoutePlan> {
    let ii = s.ii;
    let mut routes: Vec<Option<Route>> = vec![None; s.g.edges().len()];
    let mut grf_edges: Vec<(usize, usize, usize)> = Vec::new(); // (edge, t1, t2)

    for (idx, e) in s.g.edges().iter().enumerate() {
        if e.kind != EdgeKind::Internal {
            continue;
        }
        let (t1, t2) = (s.t[e.src], s.t[e.dst]);
        let dist = t2 - t1;
        let from_cop = matches!(s.g.kind(e.src), NodeKind::Cop { .. });
        if dist == 1 || from_cop {
            routes[idx] = Some(Route::Bus);
            continue;
        }
        // A genuine MCID. LRF routing (value parked in the producer PE's
        // local register file, forwarded over the interconnect in the
        // consumer's cycle) works whenever producer and consumer occupy
        // different modulo slots; otherwise the producer PE is re-executing
        // the producer in the consumer's slot and the GRF must carry the
        // value (paper Fig. 3 discussion).
        if t1 % ii != t2 % ii {
            routes[idx] = Some(Route::Lrf);
        } else {
            routes[idx] = Some(Route::Grf);
            grf_edges.push((idx, t1, t2));
        }
    }

    // GRF feasibility: per-slot write ports and concurrent liveness.
    let mut writes = vec![0usize; ii];
    for &(_, t1, _) in &grf_edges {
        writes[(t1 + 1) % ii] += 1;
    }
    if let Some((slot, &w)) = writes.iter().enumerate().find(|(_, &w)| w > cgra.grf_write_ports)
    {
        return Err(Error::RouteFailed {
            ii,
            reason: format!(
                "GRF write ports exceeded at modulo slot {slot}: {w} > {}",
                cgra.grf_write_ports
            ),
        });
    }
    // Liveness: a GRF value written at t1+1 is read at t2; in steady state
    // the modulo pipeline overlaps iterations, so a value spanning d cycles
    // occupies ⌈d / II⌉ registers concurrently.
    let peak: usize = grf_edges
        .iter()
        .map(|&(_, t1, t2)| (t2 - t1 - 1).div_ceil(ii).max(1))
        .sum();
    if peak > cgra.grf_capacity {
        return Err(Error::RouteFailed {
            ii,
            reason: format!("GRF capacity exceeded: {peak} live > {}", cgra.grf_capacity),
        });
    }
    Ok(RoutePlan { routes, grf_writes_per_slot: writes, grf_peak_live: peak })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Techniques;
    use crate::dfg::analysis::mii;
    use crate::dfg::build::build_sdfg;
    use crate::sched::sparsemap::schedule_at;
    use crate::sparse::gen::paper_blocks;

    fn cgra() -> StreamingCgra {
        StreamingCgra::paper_default()
    }

    #[test]
    fn every_internal_edge_routed_for_paper_blocks() {
        for nb in paper_blocks() {
            let (g, _) = build_sdfg(&nb.block);
            let base = mii(&g, &cgra());
            // First II whose schedule routes (tight-II schedules of dense
            // blocks may exceed the single GRF write port).
            let Some((s, plan)) = (base..base + 3).find_map(|ii| {
                let s = schedule_at(&g, &cgra(), Techniques::all(), ii).ok()?;
                let plan = preallocate(&s, &cgra()).ok()?;
                Some((s, plan))
            }) else {
                panic!("{}: no routable schedule", nb.label);
            };
            for (idx, e) in s.g.edges().iter().enumerate() {
                if e.kind == EdgeKind::Internal {
                    assert!(plan.route(idx).is_some(), "{} edge {idx}", nb.label);
                } else {
                    assert!(plan.route(idx).is_none());
                }
            }
        }
    }

    #[test]
    fn distance_one_routes_via_bus() {
        let nb = &paper_blocks()[1];
        let (g, _) = build_sdfg(&nb.block);
        let s = schedule_at(&g, &cgra(), Techniques::all(), mii(&g, &cgra())).unwrap();
        let plan = preallocate(&s, &cgra()).unwrap();
        for (idx, e) in s.g.edges().iter().enumerate() {
            if e.kind == EdgeKind::Internal && s.t[e.dst] - s.t[e.src] == 1 {
                assert_eq!(plan.route(idx), Some(Route::Bus));
            }
        }
    }

    #[test]
    fn grf_writes_matches_preallocate() {
        // The standalone per-slot GRF-write table must equal the one
        // preallocate derives while routing — this is what lets the
        // fusion offset search pre-check write-port feasibility without
        // re-running the router.
        for nb in paper_blocks() {
            let (g, _) = build_sdfg(&nb.block);
            let base = mii(&g, &cgra());
            for ii in base..base + 4 {
                let Ok(s) = schedule_at(&g, &cgra(), Techniques::all(), ii) else { continue };
                let Ok(plan) = preallocate(&s, &cgra()) else { continue };
                assert_eq!(
                    grf_writes_per_slot(&s),
                    plan.grf_writes_per_slot,
                    "{} II={ii}",
                    nb.label
                );
            }
        }
    }

    #[test]
    fn grf_write_port_overflow_fails() {
        use crate::dfg::{EdgeKind, NodeKind, SDfg};
        let mut g = SDfg::new("m");
        let r = g.add_node(NodeKind::Read { ch: 0, replica: 0 });
        let m1 = g.add_node(NodeKind::Mul { ch: 0, kr: 0 });
        let m2 = g.add_node(NodeKind::Mul { ch: 0, kr: 1 });
        g.add_edge(r, m1, EdgeKind::Input);
        g.add_edge(r, m2, EdgeKind::Input);
        let a = g.add_node(NodeKind::Add { kr: 0 });
        g.add_edge(m1, a, EdgeKind::Internal);
        g.add_edge(m2, a, EdgeKind::Internal);
        let w = g.add_node(NodeKind::Write { kr: 0 });
        g.add_edge(a, w, EdgeKind::Output);
        // Both mul→add deps have dist 2 at II=2 (same modulo → GRF), both
        // writing the GRF at slot 1 → exceeds the single write port.
        let s = ScheduledSDfg { g, ii: 2, t: vec![0, 0, 0, 2, 3] };
        let err = preallocate(&s, &cgra()).unwrap_err();
        assert!(err.to_string().contains("GRF write ports"), "{err}");
    }

    #[test]
    fn lrf_then_grf_for_multi_mcid_consumer() {
        use crate::dfg::{EdgeKind, NodeKind, SDfg};
        let mut g = SDfg::new("m");
        let r = g.add_node(NodeKind::Read { ch: 0, replica: 0 });
        let m1 = g.add_node(NodeKind::Mul { ch: 0, kr: 0 });
        let m2 = g.add_node(NodeKind::Mul { ch: 0, kr: 1 });
        g.add_edge(r, m1, EdgeKind::Input);
        g.add_edge(r, m2, EdgeKind::Input);
        let a = g.add_node(NodeKind::Add { kr: 0 });
        let e1 = g.add_edge(m1, a, EdgeKind::Internal);
        let e2 = g.add_edge(m2, a, EdgeKind::Internal);
        let w = g.add_node(NodeKind::Write { kr: 0 });
        g.add_edge(a, w, EdgeKind::Output);
        // dist 2 and 3 at II=3: different modulo slots → LRF for the first,
        // GRF for the second.
        let s = ScheduledSDfg { g, ii: 3, t: vec![0, 0, 1, 3, 4] };
        let plan = preallocate(&s, &cgra()).unwrap();
        let routes: Vec<_> = [e1, e2].iter().map(|&e| plan.route(e).unwrap()).collect();
        assert!(routes.contains(&Route::Lrf));
        assert!(routes.contains(&Route::Grf));
        assert_eq!(plan.grf_count(), 1);
        assert_eq!(plan.lrf_count(), 1);
    }
}
