//! Conflict-graph construction (paper §4.2).
//!
//! Vertices (`V_CG`):
//! * `(r^m, ibus_i^m)` — input reading `r` on input bus `i`;
//! * `(w^m, obus_j^m)` — output writing `w` on output bus `j`;
//! * `(pe^m, op^m)` — PE operation on a PE. The BusMap quadruple's
//!   `bus_x/bus_y` components are *derived* from the chosen placements
//!   (canonical two-hop routing: producer's row bus → junction →
//!   consumer's column bus) and checked by [`crate::bind::Mapping::verify`]
//!   after the MIS solve — binding retries with a fresh seed if a bus
//!   collision survives, which is rare because a 4×4 PEA offers 8 buses
//!   per slot.
//!
//! Edges are the hard resource conflicts: R1 (I/O bus exclusiveness),
//! R2(1) (readers sit in their bus's column / writers' producers in their
//! bus's row), PE exclusiveness per modulo slot, and LRF pinning of
//! same-PE MCID consumers.

use crate::arch::{PeId, StreamingCgra};
use crate::bind::route::RoutePlan;
use crate::dfg::{EdgeKind, NodeId};
use crate::sched::ScheduledSDfg;
use crate::util::BitSet;

/// One binding candidate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Candidate {
    /// Reading `node` allocated to input bus `ibus`.
    Read { node: NodeId, ibus: usize },
    /// Writing `node` allocated to output bus `obus`.
    Write { node: NodeId, obus: usize },
    /// PE op `node` on `pe`.
    Op { node: NodeId, pe: PeId },
}

impl Candidate {
    pub fn node(&self) -> NodeId {
        match *self {
            Candidate::Read { node, .. }
            | Candidate::Write { node, .. }
            | Candidate::Op { node, .. } => node,
        }
    }
}

/// The conflict graph: candidates + bitset adjacency.
pub struct ConflictGraph {
    pub candidates: Vec<Candidate>,
    /// Adjacency as bitsets over candidate indices.
    pub adj: Vec<BitSet>,
    /// Candidate indices per s-DFG node.
    pub of_node: Vec<Vec<usize>>,
    /// Number of s-DFG nodes (the MIS target size).
    pub num_nodes: usize,
}

impl ConflictGraph {
    /// An empty graph holding no storage yet — the ScratchPool seed; filled
    /// (and refilled, reusing allocations) by [`build_into`].
    pub fn empty() -> Self {
        ConflictGraph { candidates: Vec::new(), adj: Vec::new(), of_node: Vec::new(), num_nodes: 0 }
    }

    pub fn num_candidates(&self) -> usize {
        self.candidates.len()
    }

    pub fn num_edges(&self) -> usize {
        self.adj.iter().map(|b| b.len()).sum::<usize>() / 2
    }
}

/// Build the conflict graph for a scheduled s-DFG + route plan.
pub fn build(s: &ScheduledSDfg, cgra: &StreamingCgra, plan: &RoutePlan) -> ConflictGraph {
    let mut cg = ConflictGraph::empty();
    build_into(s, cgra, plan, &mut cg);
    cg
}

/// [`build`] into reusable storage: every `Vec` and adjacency `BitSet` of a
/// previous build is recycled, so the per-attempt cost of the mapper's
/// retry lattice is the fill, not the allocation.
pub fn build_into(s: &ScheduledSDfg, cgra: &StreamingCgra, _plan: &RoutePlan, cg: &mut ConflictGraph) {
    let g = &s.g;
    let n_nodes = g.len();

    // ---- candidates -------------------------------------------------------
    cg.candidates.clear();
    cg.of_node.resize_with(n_nodes, Vec::new);
    for v in cg.of_node.iter_mut() {
        v.clear();
    }
    let (candidates, of_node) = (&mut cg.candidates, &mut cg.of_node);
    for v in g.nodes() {
        match g.kind(v) {
            k if k.is_read() => {
                for ibus in 0..cgra.m {
                    of_node[v].push(candidates.len());
                    candidates.push(Candidate::Read { node: v, ibus });
                }
            }
            k if k.is_write() => {
                for obus in 0..cgra.n {
                    of_node[v].push(candidates.len());
                    candidates.push(Candidate::Write { node: v, obus });
                }
            }
            _ => {
                for pe in cgra.pes() {
                    of_node[v].push(candidates.len());
                    candidates.push(Candidate::Op { node: v, pe });
                }
            }
        }
    }

    // ---- edges ------------------------------------------------------------
    let nc = candidates.len();
    for b in cg.adj.iter_mut() {
        b.reset(nc);
    }
    cg.adj.resize_with(nc, || BitSet::new(nc));
    let (candidates, adj) = (&cg.candidates, &mut cg.adj);

    let input_src = |op: NodeId| -> Option<NodeId> {
        g.in_edges(op)
            .find(|(_, e)| e.kind == EdgeKind::Input)
            .map(|(_, e)| e.src)
    };
    let output_producer = |w: NodeId| -> NodeId {
        g.predecessors(w).next().expect("write has a producer")
    };

    for a in 0..nc {
        for b in (a + 1)..nc {
            let conflict = {
                use Candidate::*;
                let (ca, cb) = (&candidates[a], &candidates[b]);
                if ca.node() == cb.node() {
                    true // pick-one clique
                } else {
                    let slot = |v: NodeId| s.m(v);
                    match (*ca, *cb) {
                        // R1: I/O bus exclusiveness.
                        (Read { node: r1, ibus: i1 }, Read { node: r2, ibus: i2 }) => {
                            i1 == i2 && slot(r1) == slot(r2)
                        }
                        (Write { node: w1, obus: o1 }, Write { node: w2, obus: o2 }) => {
                            o1 == o2 && slot(w1) == slot(w2)
                        }
                        (Read { .. }, Write { .. }) | (Write { .. }, Read { .. }) => false,
                        // R2(1): consumers of a reading sit in its column.
                        (Read { node: r, ibus }, Op { node: op, pe })
                        | (Op { node: op, pe }, Read { node: r, ibus }) => {
                            input_src(op) == Some(r) && pe.col != ibus
                        }
                        // R2(1): the producer of a writing sits in its row.
                        (Write { node: w, obus }, Op { node: op, pe })
                        | (Op { node: op, pe }, Write { node: w, obus }) => {
                            output_producer(w) == op && pe.row != obus
                        }
                        (Op { node: v1, pe: p1 }, Op { node: v2, pe: p2 }) => {
                            // One PE, one op per modulo slot.
                            p1 == p2 && slot(v1) == slot(v2)
                        }
                    }
                }
            };
            if conflict {
                adj[a].insert(b);
                adj[b].insert(a);
            }
        }
    }

    cg.num_nodes = n_nodes;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bind::route::preallocate;
    use crate::config::Techniques;
    use crate::dfg::analysis::mii;
    use crate::dfg::build::build_sdfg;
    use crate::dfg::NodeKind;
    use crate::sched::sparsemap::schedule_at;
    use crate::sparse::gen::paper_blocks;

    fn cg_for(label_idx: usize, ii_extra: usize) -> (ScheduledSDfg, ConflictGraph) {
        let cgra = StreamingCgra::paper_default();
        let nb = &paper_blocks()[label_idx];
        let (g, _) = build_sdfg(&nb.block);
        let s = schedule_at(&g, &cgra, Techniques::all(), mii(&g, &cgra) + ii_extra).unwrap();
        let plan = preallocate(&s, &cgra).unwrap();
        let cg = build(&s, &cgra, &plan);
        (s, cg)
    }

    #[test]
    fn build_into_reuse_matches_fresh() {
        // Growing and shrinking through the same scratch graph must give
        // byte-identical results to a fresh build every time.
        let cgra = StreamingCgra::paper_default();
        let mut scratch = ConflictGraph::empty();
        for idx in [0usize, 4, 2] {
            let nb = &paper_blocks()[idx];
            let (g, _) = build_sdfg(&nb.block);
            let s = schedule_at(&g, &cgra, Techniques::all(), mii(&g, &cgra) + 1).unwrap();
            let plan = preallocate(&s, &cgra).unwrap();
            build_into(&s, &cgra, &plan, &mut scratch);
            let fresh = build(&s, &cgra, &plan);
            assert_eq!(scratch.candidates, fresh.candidates, "{}", nb.label);
            assert_eq!(scratch.of_node, fresh.of_node);
            assert_eq!(scratch.num_nodes, fresh.num_nodes);
            assert_eq!(scratch.adj.len(), fresh.adj.len());
            for (a, b) in scratch.adj.iter().zip(&fresh.adj) {
                assert_eq!(a, b);
            }
        }
    }

    #[test]
    fn candidate_counts() {
        let (s, cg) = cg_for(0, 0);
        for v in s.g.nodes() {
            let k = cg.of_node[v].len();
            match s.g.kind(v) {
                NodeKind::Read { .. } | NodeKind::Write { .. } => assert_eq!(k, 4),
                _ => assert_eq!(k, 16),
            }
        }
    }

    #[test]
    fn same_node_candidates_conflict() {
        let (_, cg) = cg_for(1, 0);
        for v in 0..cg.of_node.len() {
            let c = &cg.of_node[v];
            for i in 0..c.len() {
                for j in (i + 1)..c.len() {
                    assert!(cg.adj[c[i]].contains(c[j]));
                }
            }
        }
    }

    #[test]
    fn r1_same_bus_same_slot_conflicts() {
        let (s, cg) = cg_for(0, 0);
        for (i, ca) in cg.candidates.iter().enumerate() {
            if let Candidate::Read { node: r1, ibus: 0 } = *ca {
                for (j, cb) in cg.candidates.iter().enumerate() {
                    if let Candidate::Read { node: r2, ibus: 0 } = *cb {
                        if r1 != r2 && s.m(r1) == s.m(r2) {
                            assert!(cg.adj[i].contains(j), "{r1} vs {r2} on ibus0");
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn input_consumer_must_be_in_bus_column() {
        let (s, cg) = cg_for(2, 0);
        for e in s.g.edges() {
            if e.kind != EdgeKind::Input {
                continue;
            }
            if !matches!(s.g.kind(e.dst), NodeKind::Mul { .. }) {
                continue;
            }
            let rc = cg.of_node[e.src].clone();
            let oc = cg.of_node[e.dst].clone();
            for &i in &rc {
                let Candidate::Read { ibus, .. } = cg.candidates[i] else { unreachable!() };
                for &j in &oc {
                    let Candidate::Op { pe, .. } = cg.candidates[j] else { unreachable!() };
                    if pe.col != ibus {
                        assert!(cg.adj[i].contains(j));
                    }
                }
            }
        }
    }

    #[test]
    fn graph_sizes_are_sane() {
        let (s, cg) = cg_for(4, 1); // block5
        assert_eq!(cg.num_nodes, s.g.len());
        // reads*4 + writes*4 + ops*16.
        let want: usize = s
            .g
            .nodes()
            .map(|v| match s.g.kind(v) {
                NodeKind::Read { .. } | NodeKind::Write { .. } => 4,
                _ => 16,
            })
            .sum();
        assert_eq!(cg.num_candidates(), want);
        assert!(cg.num_edges() > 0);
    }
}
