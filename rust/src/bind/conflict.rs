//! Conflict-graph construction (paper §4.2), bucketed.
//!
//! Vertices (`V_CG`):
//! * `(r^m, ibus_i^m)` — input reading `r` on input bus `i`;
//! * `(w^m, obus_j^m)` — output writing `w` on output bus `j`;
//! * `(pe^m, op^m)` — PE operation on a PE. The BusMap quadruple's
//!   `bus_x/bus_y` components are *derived* from the chosen placements
//!   (canonical two-hop routing: producer's row bus → junction →
//!   consumer's column bus) and checked by [`crate::bind::Mapping::verify`]
//!   after the MIS solve — binding retries with a fresh seed if a bus
//!   collision survives, which is rare because a 4×4 PEA offers 8 buses
//!   per slot.
//!
//! Edges are the hard resource conflicts: R1 (I/O bus exclusiveness),
//! R2(1) (readers sit in their bus's column / writers' producers in their
//! bus's row), PE exclusiveness per modulo slot, and the per-node pick-one
//! cliques.
//!
//! ## Bucketed build
//!
//! Every conflict rule is local to either one s-DFG node (cliques), one
//! dependency edge (R2(1)), or one `(modulo slot, physical resource)`
//! bucket (R1 / PE exclusiveness): two candidates on *different* buses,
//! different PEs or different slots can never conflict through R1/PE
//! rules. [`build_into`] therefore groups candidates into dense slot-major
//! buckets — `(slot, ibus)`, `(slot, obus)`, `(slot, pe)` — and emits
//! edges only among bucket-local pairs plus the per-edge R2(1) pairs,
//! replacing the naive all-pairs `O(nc²)` candidate loop (kept verbatim as
//! [`crate::bind::oracle::build_naive`], the differential-test oracle in
//! `tests/conflict_equivalence.rs`). Bucket storage lives in a reusable
//! [`BucketScratch`] carried by [`crate::bind::ScratchPool`], so portfolio
//! attempts recycle it along with the graph storage itself.

use crate::arch::{PeId, StreamingCgra};
use crate::bind::route::RoutePlan;
use crate::dfg::{EdgeKind, NodeId};
use crate::sched::ScheduledSDfg;
use crate::util::BitSet;

/// One binding candidate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Candidate {
    /// Reading `node` allocated to input bus `ibus`.
    Read { node: NodeId, ibus: usize },
    /// Writing `node` allocated to output bus `obus`.
    Write { node: NodeId, obus: usize },
    /// PE op `node` on `pe`.
    Op { node: NodeId, pe: PeId },
}

impl Candidate {
    pub fn node(&self) -> NodeId {
        match *self {
            Candidate::Read { node, .. }
            | Candidate::Write { node, .. }
            | Candidate::Op { node, .. } => node,
        }
    }
}

/// The conflict graph: candidates + bitset adjacency.
pub struct ConflictGraph {
    pub candidates: Vec<Candidate>,
    /// Adjacency as bitsets over candidate indices.
    pub adj: Vec<BitSet>,
    /// Candidate indices per s-DFG node.
    pub of_node: Vec<Vec<usize>>,
    /// Number of s-DFG nodes (the MIS target size).
    pub num_nodes: usize,
}

impl ConflictGraph {
    /// An empty graph holding no storage yet — the ScratchPool seed; filled
    /// (and refilled, reusing allocations) by [`build_into`].
    pub fn empty() -> Self {
        ConflictGraph { candidates: Vec::new(), adj: Vec::new(), of_node: Vec::new(), num_nodes: 0 }
    }

    pub fn num_candidates(&self) -> usize {
        self.candidates.len()
    }

    pub fn num_edges(&self) -> usize {
        self.adj.iter().map(|b| b.len()).sum::<usize>() / 2
    }
}

/// Reusable slot-major candidate buckets for [`build_into`] — one `Vec`
/// per `(modulo slot, input bus)`, `(slot, output bus)` and `(slot, PE)`.
/// Carried by [`crate::bind::ScratchPool`] so the mapper's retry lattice
/// recycles the bucket allocations together with the graph storage.
pub struct BucketScratch {
    /// `slot * m + ibus` → read candidates.
    read: Vec<Vec<usize>>,
    /// `slot * n + obus` → write candidates.
    write: Vec<Vec<usize>>,
    /// `(slot * n + row) * m + col` → op candidates.
    op: Vec<Vec<usize>>,
}

impl BucketScratch {
    pub fn new() -> Self {
        BucketScratch { read: Vec::new(), write: Vec::new(), op: Vec::new() }
    }

    /// Size the bucket tables for `(ii, cgra)` and empty them, keeping the
    /// inner allocations of a previous build alive.
    fn reset(&mut self, ii: usize, cgra: &StreamingCgra) {
        self.read.resize_with(ii * cgra.m, Vec::new);
        self.write.resize_with(ii * cgra.n, Vec::new);
        self.op.resize_with(ii * cgra.n * cgra.m, Vec::new);
        for b in self.read.iter_mut().chain(&mut self.write).chain(&mut self.op) {
            b.clear();
        }
    }
}

impl Default for BucketScratch {
    fn default() -> Self {
        Self::new()
    }
}

/// Build the conflict graph for a scheduled s-DFG + route plan.
pub fn build(s: &ScheduledSDfg, cgra: &StreamingCgra, plan: &RoutePlan) -> ConflictGraph {
    let mut cg = ConflictGraph::empty();
    build_into(s, cgra, plan, &mut cg, &mut BucketScratch::new());
    cg
}

#[inline]
fn link(adj: &mut [BitSet], a: usize, b: usize) {
    adj[a].insert(b);
    adj[b].insert(a);
}

/// [`build`] into reusable storage: every `Vec`, adjacency `BitSet` and
/// candidate bucket of a previous build is recycled, so the per-attempt
/// cost of the mapper's retry lattice is the fill, not the allocation.
/// Produces a graph byte-identical to the naive all-pairs oracle
/// ([`crate::bind::oracle::build_naive`]).
pub fn build_into(
    s: &ScheduledSDfg,
    cgra: &StreamingCgra,
    _plan: &RoutePlan,
    cg: &mut ConflictGraph,
    bk: &mut BucketScratch,
) {
    let g = &s.g;
    let n_nodes = g.len();

    // ---- candidates (bucketed as they are enumerated) ---------------------
    cg.candidates.clear();
    cg.of_node.resize_with(n_nodes, Vec::new);
    for v in cg.of_node.iter_mut() {
        v.clear();
    }
    bk.reset(s.ii, cgra);
    let (candidates, of_node) = (&mut cg.candidates, &mut cg.of_node);
    for v in g.nodes() {
        let slot = s.m(v);
        match g.kind(v) {
            k if k.is_read() => {
                for ibus in 0..cgra.m {
                    let idx = candidates.len();
                    of_node[v].push(idx);
                    candidates.push(Candidate::Read { node: v, ibus });
                    bk.read[slot * cgra.m + ibus].push(idx);
                }
            }
            k if k.is_write() => {
                for obus in 0..cgra.n {
                    let idx = candidates.len();
                    of_node[v].push(idx);
                    candidates.push(Candidate::Write { node: v, obus });
                    bk.write[slot * cgra.n + obus].push(idx);
                }
            }
            _ => {
                for pe in cgra.pes() {
                    let idx = candidates.len();
                    of_node[v].push(idx);
                    candidates.push(Candidate::Op { node: v, pe });
                    bk.op[(slot * cgra.n + pe.row) * cgra.m + pe.col].push(idx);
                }
            }
        }
    }

    // ---- edges ------------------------------------------------------------
    let nc = cg.candidates.len();
    for b in cg.adj.iter_mut() {
        b.reset(nc);
    }
    cg.adj.resize_with(nc, || BitSet::new(nc));
    let (candidates, of_node, adj) = (&cg.candidates, &cg.of_node, &mut cg.adj);

    // Pick-one cliques: a node takes exactly one of its candidates.
    for v in g.nodes() {
        let c = &of_node[v];
        for (i, &ca) in c.iter().enumerate() {
            for &cb in c.iter().skip(i + 1) {
                link(adj, ca, cb);
            }
        }
    }

    // R1 / PE exclusiveness: same physical resource, same modulo slot —
    // exactly the bucket-local pairs (one candidate per node per bucket,
    // so every pair is a genuine cross-node conflict).
    for bucket in bk.read.iter().chain(&bk.write).chain(&bk.op) {
        for (i, &ca) in bucket.iter().enumerate() {
            for &cb in bucket.iter().skip(i + 1) {
                link(adj, ca, cb);
            }
        }
    }

    // R2(1), input side: the consumers of a reading sit in its bus column.
    for v in g.nodes() {
        let k = g.kind(v);
        if k.is_read() || k.is_write() {
            continue;
        }
        let Some(r) = g
            .in_edges(v)
            .find(|(_, e)| e.kind == EdgeKind::Input)
            .map(|(_, e)| e.src)
        else {
            continue;
        };
        if !g.kind(r).is_read() {
            continue; // non-read Input source never yields Read candidates
        }
        for &ci in &of_node[r] {
            let Candidate::Read { ibus, .. } = candidates[ci] else { unreachable!() };
            for &cj in &of_node[v] {
                let Candidate::Op { pe, .. } = candidates[cj] else { unreachable!() };
                if pe.col != ibus {
                    link(adj, ci, cj);
                }
            }
        }
    }

    // R2(1), output side: the producer of a writing sits in its bus row.
    for w in g.nodes() {
        if !g.kind(w).is_write() {
            continue;
        }
        let Some(p) = g.predecessors(w).next() else { continue };
        let pk = g.kind(p);
        if pk.is_read() || pk.is_write() {
            continue;
        }
        for &ci in &of_node[w] {
            let Candidate::Write { obus, .. } = candidates[ci] else { unreachable!() };
            for &cj in &of_node[p] {
                let Candidate::Op { pe, .. } = candidates[cj] else { unreachable!() };
                if pe.row != obus {
                    link(adj, ci, cj);
                }
            }
        }
    }

    cg.num_nodes = n_nodes;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bind::oracle::build_naive;
    use crate::bind::route::preallocate;
    use crate::config::Techniques;
    use crate::dfg::analysis::mii;
    use crate::dfg::build::build_sdfg;
    use crate::dfg::NodeKind;
    use crate::sched::sparsemap::schedule_at;
    use crate::sparse::gen::paper_blocks;

    fn cg_for(label_idx: usize, ii_extra: usize) -> (ScheduledSDfg, ConflictGraph) {
        let cgra = StreamingCgra::paper_default();
        let nb = &paper_blocks()[label_idx];
        let (g, _) = build_sdfg(&nb.block);
        let s = schedule_at(&g, &cgra, Techniques::all(), mii(&g, &cgra) + ii_extra).unwrap();
        let plan = preallocate(&s, &cgra).unwrap();
        let cg = build(&s, &cgra, &plan);
        (s, cg)
    }

    #[test]
    fn build_into_reuse_matches_fresh() {
        // Growing and shrinking through the same scratch graph (and bucket
        // scratch) must give byte-identical results to a fresh build every
        // time.
        let cgra = StreamingCgra::paper_default();
        let mut scratch = ConflictGraph::empty();
        let mut buckets = BucketScratch::new();
        for idx in [0usize, 4, 2] {
            let nb = &paper_blocks()[idx];
            let (g, _) = build_sdfg(&nb.block);
            let s = schedule_at(&g, &cgra, Techniques::all(), mii(&g, &cgra) + 1).unwrap();
            let plan = preallocate(&s, &cgra).unwrap();
            build_into(&s, &cgra, &plan, &mut scratch, &mut buckets);
            let fresh = build(&s, &cgra, &plan);
            assert_eq!(scratch.candidates, fresh.candidates, "{}", nb.label);
            assert_eq!(scratch.of_node, fresh.of_node);
            assert_eq!(scratch.num_nodes, fresh.num_nodes);
            assert_eq!(scratch.adj.len(), fresh.adj.len());
            for (a, b) in scratch.adj.iter().zip(&fresh.adj) {
                assert_eq!(a, b);
            }
        }
    }

    #[test]
    fn bucketed_matches_naive_oracle_smoke() {
        // Full differential coverage (random schedules, varying II) lives
        // in tests/conflict_equivalence.rs; this is the in-module smoke.
        let cgra = StreamingCgra::paper_default();
        for idx in [0usize, 3, 6] {
            let nb = &paper_blocks()[idx];
            let (g, _) = build_sdfg(&nb.block);
            let s = schedule_at(&g, &cgra, Techniques::all(), mii(&g, &cgra) + 1).unwrap();
            let plan = preallocate(&s, &cgra).unwrap();
            let fast = build(&s, &cgra, &plan);
            let slow = build_naive(&s, &cgra, &plan);
            assert_eq!(fast.candidates, slow.candidates, "{}", nb.label);
            assert_eq!(fast.of_node, slow.of_node);
            assert_eq!(fast.num_nodes, slow.num_nodes);
            for (i, (a, b)) in fast.adj.iter().zip(&slow.adj).enumerate() {
                assert_eq!(a, b, "{}: adjacency of candidate {i}", nb.label);
            }
        }
    }

    #[test]
    fn candidate_counts() {
        let (s, cg) = cg_for(0, 0);
        for v in s.g.nodes() {
            let k = cg.of_node[v].len();
            match s.g.kind(v) {
                NodeKind::Read { .. } | NodeKind::Write { .. } => assert_eq!(k, 4),
                _ => assert_eq!(k, 16),
            }
        }
    }

    #[test]
    fn same_node_candidates_conflict() {
        let (_, cg) = cg_for(1, 0);
        for v in 0..cg.of_node.len() {
            let c = &cg.of_node[v];
            for i in 0..c.len() {
                for j in (i + 1)..c.len() {
                    assert!(cg.adj[c[i]].contains(c[j]));
                }
            }
        }
    }

    #[test]
    fn r1_same_bus_same_slot_conflicts() {
        let (s, cg) = cg_for(0, 0);
        for (i, ca) in cg.candidates.iter().enumerate() {
            if let Candidate::Read { node: r1, ibus: 0 } = *ca {
                for (j, cb) in cg.candidates.iter().enumerate() {
                    if let Candidate::Read { node: r2, ibus: 0 } = *cb {
                        if r1 != r2 && s.m(r1) == s.m(r2) {
                            assert!(cg.adj[i].contains(j), "{r1} vs {r2} on ibus0");
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn input_consumer_must_be_in_bus_column() {
        let (s, cg) = cg_for(2, 0);
        for e in s.g.edges() {
            if e.kind != EdgeKind::Input {
                continue;
            }
            if !matches!(s.g.kind(e.dst), NodeKind::Mul { .. }) {
                continue;
            }
            let rc = cg.of_node[e.src].clone();
            let oc = cg.of_node[e.dst].clone();
            for &i in &rc {
                let Candidate::Read { ibus, .. } = cg.candidates[i] else { unreachable!() };
                for &j in &oc {
                    let Candidate::Op { pe, .. } = cg.candidates[j] else { unreachable!() };
                    if pe.col != ibus {
                        assert!(cg.adj[i].contains(j));
                    }
                }
            }
        }
    }

    #[test]
    fn graph_sizes_are_sane() {
        let (s, cg) = cg_for(4, 1); // block5
        assert_eq!(cg.num_nodes, s.g.len());
        // reads*4 + writes*4 + ops*16.
        let want: usize = s
            .g
            .nodes()
            .map(|v| match s.g.kind(v) {
                NodeKind::Read { .. } | NodeKind::Write { .. } => 4,
                _ => 16,
            })
            .sum();
        assert_eq!(cg.num_candidates(), want);
        assert!(cg.num_edges() > 0);
    }
}
