//! Binding (paper §4.2): routing pre-allocation → conflict-graph
//! construction → SBTS MIS solve → bus-routing check → verified
//! [`Mapping`].
//!
//! `|MIS| == |V_D|` means every s-DFG node got a physical resource without
//! hard conflicts; a post-pass then derives the BusMap `bus_x`/`bus_y`
//! assignments (canonical two-hop routes: producer's row bus → junction →
//! consumer's column bus) and re-solves with a fresh seed in the rare case
//! of a bus collision. Anything less is an incomplete mapping; the mapper
//! escalates II (see `crate::mapper`).
//!
//! The attempt path is allocation-conscious: [`ScratchPool`] carries the
//! conflict-graph storage (including the slot-major candidate buckets of
//! the bucketed build), the route table and the SBTS solver state across
//! attempts, so the mapper's `(II, retry)` lattice reuses one arena per
//! worker instead of rebuilding every buffer per attempt. The secondary
//! objective itself is hash-free: [`BusCostModel`] indexes the
//! `II × (n + m)` physical buses with a dense slot-major array. The
//! retired implementations (all-pairs conflict build, `HashMap` cost
//! model) live on in [`oracle`] as differential-test oracles.

pub mod conflict;
pub mod mis;
pub mod oracle;
pub mod route;

use crate::arch::{PeId, StreamingCgra};
use crate::dfg::{EdgeKind, NodeId, NodeKind};
use crate::error::{Error, Result};
use crate::sched::ScheduledSDfg;

pub use conflict::{BucketScratch, Candidate, ConflictGraph};
pub use mis::{SecondaryCost, SolverScratch};
pub use route::{Route, RoutePlan};

/// Where one s-DFG node landed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Placement {
    InputBus(usize),
    OutputBus(usize),
    Pe(PeId),
}

/// A physical bus at a modulo slot — the unit of exclusiveness for data
/// transfers. Row buses are the output buses; column buses the input buses.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum BusAt {
    Row { slot: usize, row: usize },
    Col { slot: usize, col: usize },
}

/// Canonical bus-claim state: every claimed bus with its sorted
/// `(value, multiplicity)` list, ordered by bus — the form the dense cost
/// model and the `HashMap` oracle are compared in.
pub type ClaimsSnapshot = Vec<(BusAt, Vec<(NodeId, usize)>)>;

/// A complete, verified mapping of a scheduled s-DFG onto the CGRA.
#[derive(Clone, Debug)]
pub struct Mapping {
    pub s: ScheduledSDfg,
    pub placements: Vec<Placement>,
    pub plan_routes: Vec<Option<Route>>,
    /// SBTS iterations spent (across retries).
    pub mis_iterations: usize,
    pub ii: usize,
}

impl Mapping {
    pub fn cops(&self) -> usize {
        self.s.cops()
    }

    pub fn mcids(&self) -> usize {
        self.s.mcids().len()
    }

    pub fn pe_of(&self, v: NodeId) -> Option<PeId> {
        match self.placements[v] {
            Placement::Pe(pe) => Some(pe),
            _ => None,
        }
    }

    pub fn ibus_of(&self, v: NodeId) -> Option<usize> {
        match self.placements[v] {
            Placement::InputBus(i) => Some(i),
            _ => None,
        }
    }

    pub fn obus_of(&self, v: NodeId) -> Option<usize> {
        match self.placements[v] {
            Placement::OutputBus(i) => Some(i),
            _ => None,
        }
    }

    pub fn route_of_edge(&self, edge_idx: usize) -> Option<Route> {
        self.plan_routes[edge_idx]
    }

    /// The bus claims of one dependency edge under canonical two-hop
    /// routing, with the value id (producer) that rides the bus. The
    /// simulator uses the same function to drive its interconnect.
    pub fn bus_claims_of_edge(&self, idx: usize) -> Vec<(BusAt, NodeId)> {
        let place = |v: NodeId| self.placements[v];
        claims_of_edge(&self.s, &self.plan_routes, &place, idx).as_slice().to_vec()
    }

    /// Re-check every binding constraint from first principles (independent
    /// of the conflict-graph encoding). Used by tests and the simulator.
    pub fn verify(&self, cgra: &StreamingCgra) -> Result<()> {
        let g = &self.s.g;
        let fail = |msg: String| -> Result<()> {
            Err(Error::RouteFailed { ii: self.ii, reason: msg })
        };
        // Kind-appropriate placements.
        for v in g.nodes() {
            let ok = match (g.kind(v), self.placements[v]) {
                (NodeKind::Read { .. }, Placement::InputBus(i)) => i < cgra.m,
                (NodeKind::Write { .. }, Placement::OutputBus(i)) => i < cgra.n,
                (k, Placement::Pe(pe)) if k.is_pe_op() => pe.row < cgra.n && pe.col < cgra.m,
                _ => false,
            };
            if !ok {
                return fail(format!("node {v} has ill-typed placement"));
            }
        }
        // Exclusivity per modulo slot.
        let mut seen_pe = std::collections::HashMap::new();
        let mut seen_ibus = std::collections::HashMap::new();
        let mut seen_obus = std::collections::HashMap::new();
        for v in g.nodes() {
            let m = self.s.m(v);
            match self.placements[v] {
                Placement::Pe(pe) => {
                    if let Some(prev) = seen_pe.insert((m, pe), v) {
                        return fail(format!("PE {pe} slot {m}: nodes {prev} and {v}"));
                    }
                }
                Placement::InputBus(i) => {
                    if let Some(prev) = seen_ibus.insert((m, i), v) {
                        return fail(format!("ibus {i} slot {m}: nodes {prev} and {v}"));
                    }
                }
                Placement::OutputBus(i) => {
                    if let Some(prev) = seen_obus.insert((m, i), v) {
                        return fail(format!("obus {i} slot {m}: nodes {prev} and {v}"));
                    }
                }
            }
        }
        // Dependency constraints.
        for (idx, e) in g.edges().iter().enumerate() {
            match e.kind {
                EdgeKind::Input => {
                    let ibus = self.ibus_of(e.src).expect("read on input bus");
                    let pe = self.pe_of(e.dst).expect("consumer on PE");
                    if pe.col != ibus {
                        return fail(format!(
                            "input dep {}→{}: consumer col {} != ibus {ibus}",
                            e.src, e.dst, pe.col
                        ));
                    }
                }
                EdgeKind::Output => {
                    let obus = self.obus_of(e.dst).expect("write on output bus");
                    let pe = self.pe_of(e.src).expect("producer on PE");
                    if pe.row != obus {
                        return fail(format!(
                            "output dep {}→{}: producer row {} != obus {obus}",
                            e.src, e.dst, pe.row
                        ));
                    }
                }
                EdgeKind::Internal => match self.plan_routes[idx] {
                    Some(Route::Lrf) => {
                        // Forwarding from the producer's LRF is impossible
                        // while the producer PE re-executes the producer.
                        if self.s.m(e.src) == self.s.m(e.dst) {
                            return fail(format!(
                                "LRF dep {}→{}: same modulo slot",
                                e.src, e.dst
                            ));
                        }
                    }
                    Some(Route::Bus) | Some(Route::Grf) => {}
                    None => {
                        return fail(format!("internal dep {}→{} unrouted", e.src, e.dst));
                    }
                },
            }
        }
        // Bus exclusiveness: every claim keyed by (bus, slot) must carry a
        // single value (broadcast of one producer is fine). Covers R2(2):
        // a reading's column bus and a writing's row bus are claimed with
        // the reading's / producer's value id.
        let mut claims: std::collections::HashMap<BusAt, NodeId> = std::collections::HashMap::new();
        for idx in 0..g.edges().len() {
            for (bus, value) in self.bus_claims_of_edge(idx) {
                match claims.entry(bus) {
                    std::collections::hash_map::Entry::Vacant(en) => {
                        en.insert(value);
                    }
                    std::collections::hash_map::Entry::Occupied(en) => {
                        if *en.get() != value {
                            return fail(format!(
                                "bus collision on {bus:?}: values {} and {value}",
                                en.get()
                            ));
                        }
                    }
                }
            }
        }
        Ok(())
    }
}

/// Up to two bus claims of one edge, as a fixed-size value — the SBTS
/// inner loop asks for claims on every candidate evaluation, so this must
/// not allocate.
#[derive(Clone, Copy, Debug)]
pub(crate) struct EdgeClaims {
    items: [(BusAt, NodeId); 2],
    len: usize,
}

impl EdgeClaims {
    const NONE: EdgeClaims =
        EdgeClaims { items: [(BusAt::Row { slot: 0, row: 0 }, 0); 2], len: 0 };

    fn one(c: (BusAt, NodeId)) -> Self {
        EdgeClaims { items: [c, c], len: 1 }
    }

    fn two(a: (BusAt, NodeId), b: (BusAt, NodeId)) -> Self {
        EdgeClaims { items: [a, b], len: 2 }
    }

    pub(crate) fn as_slice(&self) -> &[(BusAt, NodeId)] {
        &self.items[..self.len]
    }
}

/// Claim set of one dependency edge under an arbitrary placement lookup —
/// shared by [`Mapping::bus_claims_of_edge`] and the in-search bus cost.
fn claims_of_edge(
    s: &ScheduledSDfg,
    routes: &[Option<Route>],
    place: &dyn Fn(NodeId) -> Placement,
    idx: usize,
) -> EdgeClaims {
    let e = s.g.edge(idx);
    match e.kind {
        EdgeKind::Input => {
            let Placement::InputBus(ibus) = place(e.src) else { return EdgeClaims::NONE };
            EdgeClaims::one((BusAt::Col { slot: s.m(e.dst), col: ibus }, e.src))
        }
        EdgeKind::Output => {
            let Placement::OutputBus(obus) = place(e.dst) else { return EdgeClaims::NONE };
            EdgeClaims::one((BusAt::Row { slot: s.m(e.dst), row: obus }, e.src))
        }
        EdgeKind::Internal => {
            // Bus-routed deps and LRF-routed MCIDs (value parked in the
            // producer's LRF, forwarded at the consumer's cycle) both ride
            // the interconnect; only GRF routes bypass the PEA buses.
            if routes[idx] == Some(Route::Grf) || routes[idx].is_none() {
                return EdgeClaims::NONE;
            }
            let (Placement::Pe(ps), Placement::Pe(pd)) = (place(e.src), place(e.dst)) else {
                return EdgeClaims::NONE;
            };
            let slot = s.m(e.dst);
            let mesh = ps.row.abs_diff(pd.row) + ps.col.abs_diff(pd.col) == 1;
            if ps == pd || mesh {
                // Same PE or dedicated mesh-neighbour link: no shared bus.
                EdgeClaims::NONE
            } else if ps.row == pd.row {
                EdgeClaims::one((BusAt::Row { slot, row: ps.row }, e.src))
            } else if ps.col == pd.col {
                EdgeClaims::one((BusAt::Col { slot, col: ps.col }, e.src))
            } else if (e.src ^ e.dst) & 1 == 0 {
                // Two hops, variant A: producer's row bus → junction
                // (ps.row, pd.col) → consumer's column bus.
                EdgeClaims::two(
                    (BusAt::Row { slot, row: ps.row }, e.src),
                    (BusAt::Col { slot, col: pd.col }, e.src),
                )
            } else {
                // Two hops, variant B: producer's column bus → junction
                // (pd.row, ps.col) → consumer's row bus. Alternating the
                // junction corner per edge spreads transfer load over both
                // bus planes.
                EdgeClaims::two(
                    (BusAt::Col { slot, col: ps.col }, e.src),
                    (BusAt::Row { slot, row: pd.row }, e.src),
                )
            }
        }
    }
}

/// Incremental bus-collision model plugged into the SBTS solve as the
/// secondary objective (realizes BusMap's `bus_x`/`bus_y` consistency).
///
/// Hash-free: with `II × (n + m)` possible buses the model keys a dense
/// slot-major array — bus id `slot·(n+m) + row` for row buses,
/// `slot·(n+m) + n + col` for column buses — so every claim mutation on
/// the SBTS inner loop is an indexed array update. Per-bus state is a
/// small `(value, multiplicity)` list plus the claiming edge multiset
/// (the hot-node tracker's input). The set of *hot* buses (two or more
/// distinct values) is maintained incrementally on claim/release, so the
/// per-iteration hot-node query costs O(|hot|) instead of rescanning all
/// `II × (n + m)` bus states — on wide-class blocks (II ≈ k/N) the scan
/// dwarfed the usually tiny hot set. Differentially tested against the
/// retired `HashMap` implementation, [`oracle::HashBusCostModel`], and
/// the from-scratch recompute ([`Self::hot_nodes_naive`]).
pub struct BusCostModel<'a> {
    s: &'a ScheduledSDfg,
    cg: &'a ConflictGraph,
    routes: &'a [Option<Route>],
    /// Claim-relevant edge indices incident to each node (whose placement
    /// affects the edge's claims).
    incident: Vec<Vec<usize>>,
    /// Row-bus count (`cgra.n`) — column buses start at this offset within
    /// a slot's stripe.
    rows: usize,
    /// Buses per modulo slot (`cgra.n + cgra.m`).
    stride: usize,
    /// Dense per-bus claim state, slot-major.
    buses: Vec<BusState>,
    /// Incremental hot-bus index: exactly the bus ids whose state carries
    /// two or more distinct values (unordered — consumers sort).
    hot: Vec<usize>,
    total: usize,
}

/// Claim state of one physical bus at one modulo slot.
#[derive(Default)]
struct BusState {
    /// Distinct values riding the bus, with multiplicities.
    values: Vec<(NodeId, u32)>,
    /// Claiming edge indices (multiset) — lets the hot-node tracker find
    /// the movable endpoints of colliding buses without a full edge scan.
    edges: Vec<usize>,
}

impl BusState {
    #[inline]
    fn contrib(&self) -> usize {
        self.values.len().saturating_sub(1)
    }
}

impl<'a> BusCostModel<'a> {
    pub fn new(
        s: &'a ScheduledSDfg,
        cg: &'a ConflictGraph,
        routes: &'a [Option<Route>],
        cgra: &StreamingCgra,
    ) -> Self {
        let mut incident: Vec<Vec<usize>> = vec![Vec::new(); s.g.len()];
        for (idx, e) in s.g.edges().iter().enumerate() {
            match e.kind {
                EdgeKind::Input => incident[e.src].push(idx),
                EdgeKind::Output => incident[e.dst].push(idx),
                EdgeKind::Internal => {
                    // Bus and LRF routes both ride the interconnect.
                    if matches!(routes[idx], Some(Route::Bus) | Some(Route::Lrf)) {
                        incident[e.src].push(idx);
                        incident[e.dst].push(idx);
                    }
                }
            }
        }
        let stride = cgra.n + cgra.m;
        let mut buses = Vec::new();
        buses.resize_with(s.ii * stride, BusState::default);
        BusCostModel {
            s,
            cg,
            routes,
            incident,
            rows: cgra.n,
            stride,
            buses,
            hot: Vec::new(),
            total: 0,
        }
    }

    #[inline]
    fn bus_index(&self, bus: BusAt) -> usize {
        match bus {
            BusAt::Row { slot, row } => slot * self.stride + row,
            BusAt::Col { slot, col } => slot * self.stride + self.rows + col,
        }
    }

    /// Inverse of [`Self::bus_index`] (snapshot/diagnostics only).
    fn bus_at(&self, idx: usize) -> BusAt {
        let (slot, off) = (idx / self.stride, idx % self.stride);
        if off < self.rows {
            BusAt::Row { slot, row: off }
        } else {
            BusAt::Col { slot, col: off - self.rows }
        }
    }

    fn placement_of(&self, cand: usize) -> Placement {
        match self.cg.candidates[cand] {
            Candidate::Read { ibus, .. } => Placement::InputBus(ibus),
            Candidate::Write { obus, .. } => Placement::OutputBus(obus),
            Candidate::Op { pe, .. } => Placement::Pe(pe),
        }
    }

    fn edge_claims(&self, idx: usize, assign: &[usize]) -> EdgeClaims {
        let place = |v: NodeId| self.placement_of(assign[v]);
        claims_of_edge(self.s, self.routes, &place, idx)
    }

    fn add_claim(&mut self, bus: BusAt, value: NodeId, edge_idx: usize, delta: isize) {
        let idx = self.bus_index(bus);
        let b = &mut self.buses[idx];
        self.total -= b.contrib();
        let was_hot = b.values.len() > 1;
        if delta > 0 {
            match b.values.iter_mut().find(|(v, _)| *v == value) {
                Some(e) => e.1 += 1,
                None => b.values.push((value, 1)),
            }
            b.edges.push(edge_idx);
        } else {
            let pos = b
                .values
                .iter()
                .position(|(v, _)| *v == value)
                .expect("claim present");
            b.values[pos].1 -= 1;
            if b.values[pos].1 == 0 {
                b.values.swap_remove(pos);
            }
            if let Some(ep) = b.edges.iter().position(|&e| e == edge_idx) {
                b.edges.swap_remove(ep);
            }
        }
        self.total += b.contrib();
        // Maintain the hot-bus index on the 1 ↔ 2 distinct-value boundary.
        // The membership scan is over the hot list itself, which stays a
        // handful of entries on the search path.
        let is_hot = b.values.len() > 1;
        if is_hot != was_hot {
            if is_hot {
                self.hot.push(idx);
            } else {
                let pos = self
                    .hot
                    .iter()
                    .position(|&h| h == idx)
                    .expect("cooling bus is indexed hot");
                self.hot.swap_remove(pos);
            }
        }
    }

    /// Reference implementation of the hot-node set, recomputed from
    /// scratch — the oracle the incremental tracker is property-tested
    /// against. Allocates; never called on the search path.
    pub fn hot_nodes_naive(&self, assign: &[usize]) -> Vec<usize> {
        use std::collections::{BTreeMap, BTreeSet};
        let mut by_bus: BTreeMap<BusAt, (BTreeSet<NodeId>, Vec<usize>)> = BTreeMap::new();
        for idx in 0..self.s.g.edges().len() {
            for &(bus, value) in self.edge_claims(idx, assign).as_slice() {
                let slot = by_bus.entry(bus).or_default();
                slot.0.insert(value);
                slot.1.push(idx);
            }
        }
        let mut nodes = BTreeSet::new();
        for (values, edges) in by_bus.values() {
            if values.len() > 1 {
                for &idx in edges {
                    let e = self.s.g.edge(idx);
                    nodes.insert(e.src);
                    nodes.insert(e.dst);
                }
            }
        }
        nodes.into_iter().collect()
    }

    /// Canonical claim state — the differential suite compares this
    /// against the `HashMap` oracle's snapshot; not on the search path.
    pub fn claims_snapshot(&self) -> ClaimsSnapshot {
        let mut out: ClaimsSnapshot = self
            .buses
            .iter()
            .enumerate()
            .filter(|(_, b)| !b.values.is_empty())
            .map(|(idx, b)| {
                let mut vals: Vec<(NodeId, usize)> =
                    b.values.iter().map(|&(v, c)| (v, c as usize)).collect();
                vals.sort_unstable();
                (self.bus_at(idx), vals)
            })
            .collect();
        out.sort_unstable_by_key(|e| e.0);
        out
    }
}

impl<'a> SecondaryCost for BusCostModel<'a> {
    fn reset(&mut self, assign: &[usize]) {
        for b in &mut self.buses {
            b.values.clear();
            b.edges.clear();
        }
        self.total = 0;
        self.hot.clear();
        for idx in 0..self.s.g.edges().len() {
            let claims = self.edge_claims(idx, assign);
            for &(bus, value) in claims.as_slice() {
                self.add_claim(bus, value, idx, 1);
            }
        }
    }

    fn detach(&mut self, v: usize, assign: &[usize]) {
        // mem::take sidesteps the self-borrow without cloning the edge
        // list; the incident sets are static for the model's lifetime.
        let edges = std::mem::take(&mut self.incident[v]);
        for &idx in &edges {
            let claims = self.edge_claims(idx, assign);
            for &(bus, value) in claims.as_slice() {
                self.add_claim(bus, value, idx, -1);
            }
        }
        self.incident[v] = edges;
    }

    fn attach(&mut self, v: usize, assign: &[usize]) {
        let edges = std::mem::take(&mut self.incident[v]);
        for &idx in &edges {
            let claims = self.edge_claims(idx, assign);
            for &(bus, value) in claims.as_slice() {
                self.add_claim(bus, value, idx, 1);
            }
        }
        self.incident[v] = edges;
    }

    fn total(&self) -> usize {
        self.total
    }

    fn hot_nodes_into(&self, _assign: &[usize], out: &mut Vec<usize>) {
        // Endpoints of the edges claiming any colliding bus, read off the
        // incrementally maintained hot-bus index — O(|hot|) instead of a
        // full `II × (n + m)` bus scan. The hot list is unordered (claims
        // push, releases swap_remove), but the caller-visible node list is
        // sorted + deduped, so determinism is unaffected.
        if self.total == 0 {
            return;
        }
        for &idx in &self.hot {
            let b = &self.buses[idx];
            debug_assert!(b.values.len() > 1, "hot index holds only colliding buses");
            for &e_idx in &b.edges {
                let e = self.s.g.edge(e_idx);
                out.push(e.src);
                out.push(e.dst);
            }
        }
        out.sort_unstable();
        out.dedup();
    }
}

/// Reusable per-worker binding arena: conflict-graph storage, the bucketed
/// build's candidate buckets, the route table and the SBTS solver state.
/// One per portfolio thread; reuse across attempts is behavior-neutral
/// (asserted by tests) — only the allocations are recycled.
pub struct ScratchPool {
    cg: ConflictGraph,
    buckets: BucketScratch,
    routes: Vec<Option<Route>>,
    solver: SolverScratch,
}

impl ScratchPool {
    pub fn new() -> Self {
        ScratchPool {
            cg: ConflictGraph::empty(),
            buckets: BucketScratch::new(),
            routes: Vec::new(),
            solver: SolverScratch::new(),
        }
    }
}

impl Default for ScratchPool {
    fn default() -> Self {
        Self::new()
    }
}

/// Bind a scheduled s-DFG: pre-allocate routes, build the conflict graph,
/// solve hard conflicts + bus collisions in one SBTS search (fresh seeds on
/// failure), and assemble a verified [`Mapping`].
pub fn bind(
    s: &ScheduledSDfg,
    cgra: &StreamingCgra,
    mis_iterations: usize,
    seed: u64,
) -> Result<Mapping> {
    bind_with(s, cgra, mis_iterations, seed, &mut ScratchPool::new())
}

/// [`bind`] against a reusable [`ScratchPool`] — the mapper's hot path.
pub fn bind_with(
    s: &ScheduledSDfg,
    cgra: &StreamingCgra,
    mis_iterations: usize,
    seed: u64,
    scratch: &mut ScratchPool,
) -> Result<Mapping> {
    let plan = route::preallocate(s, cgra)?;
    let ScratchPool { cg, buckets, routes, solver } = scratch;
    conflict::build_into(s, cgra, &plan, cg, buckets);
    plan.fill_routes(routes);
    let cg: &ConflictGraph = cg;
    let routes: &[Option<Route>] = routes;
    let mut cost = BusCostModel::new(s, cg, routes, cgra);
    let mut spent = 0usize;
    let mut best_bound = 0usize;
    for attempt in 0..3u64 {
        let res = mis::solve_with_scratch(
            cg,
            mis_iterations,
            seed.wrapping_add(attempt * 0x9e37),
            &mut cost,
            solver,
        );
        spent += res.iterations;
        best_bound = best_bound.max(res.size());
        if !res.clean {
            continue;
        }
        let placements: Vec<Placement> = res
            .assignment
            .iter()
            .map(|&c| match cg.candidates[c] {
                Candidate::Read { ibus, .. } => Placement::InputBus(ibus),
                Candidate::Write { obus, .. } => Placement::OutputBus(obus),
                Candidate::Op { pe, .. } => Placement::Pe(pe),
            })
            .collect();
        let mapping = Mapping {
            s: s.clone(),
            placements,
            plan_routes: routes.to_vec(),
            mis_iterations: spent,
            ii: s.ii,
        };
        mapping.verify(cgra)?;
        return Ok(mapping);
    }
    Err(Error::BindFailed { ii: s.ii, bound: best_bound, total: cg.num_nodes })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Techniques;
    use crate::dfg::analysis::{mii, AssociationMatrix};
    use crate::dfg::build::build_sdfg;
    use crate::sched::sparsemap::schedule_at;
    use crate::sparse::gen::paper_blocks;

    #[test]
    fn binds_and_verifies_all_paper_blocks() {
        let cgra = StreamingCgra::paper_default();
        for nb in paper_blocks() {
            let (g, _) = build_sdfg(&nb.block);
            let am = AssociationMatrix::build(&g);
            let base = mii(&g, &cgra);
            // First (II, perturbation) whose schedule binds — the mapper's
            // phase-④ search, inlined. blocks 5/7 need up to MII+2.
            let (s, m) = (base..base + 4)
                .find_map(|ii| {
                    (0..8u64).find_map(|p| {
                        let s = crate::sched::sparsemap::schedule_at_perturbed(
                            &g,
                            &cgra,
                            Techniques::all(),
                            ii,
                            p,
                            &am,
                        )
                        .ok()?;
                        let m = bind(&s, &cgra, 60_000, 42 ^ p).ok()?;
                        Some((s, m))
                    })
                })
                .unwrap_or_else(|| panic!("{}: no binding", nb.label));
            m.verify(&cgra).unwrap();
            assert_eq!(m.ii, s.ii);
        }
    }

    #[test]
    fn bind_with_scratch_reuse_matches_fresh() {
        // One pool carried across blocks of different sizes must yield the
        // same mappings as fresh pools.
        let cgra = StreamingCgra::paper_default();
        let mut pool = ScratchPool::new();
        for idx in [1usize, 4, 0] {
            let nb = &paper_blocks()[idx];
            let (g, _) = build_sdfg(&nb.block);
            let s = schedule_at(&g, &cgra, Techniques::all(), mii(&g, &cgra) + 1).unwrap();
            let reused = bind_with(&s, &cgra, 60_000, 42, &mut pool);
            let fresh = bind(&s, &cgra, 60_000, 42);
            match (reused, fresh) {
                (Ok(a), Ok(b)) => {
                    assert_eq!(a.placements, b.placements, "{}", nb.label);
                    assert_eq!(a.plan_routes, b.plan_routes);
                    assert_eq!(a.mis_iterations, b.mis_iterations);
                }
                (Err(_), Err(_)) => {}
                (a, b) => panic!(
                    "{}: scratch reuse changed the outcome: {:?} vs {:?}",
                    nb.label,
                    a.is_ok(),
                    b.is_ok()
                ),
            }
        }
    }

    #[test]
    fn verify_catches_corrupted_placement() {
        let cgra = StreamingCgra::paper_default();
        let nb = &paper_blocks()[0];
        let (g, _) = build_sdfg(&nb.block);
        let s = schedule_at(&g, &cgra, Techniques::all(), mii(&g, &cgra) + 1).unwrap();
        let m = bind(&s, &cgra, 60_000, 42).unwrap();

        // Corrupt: move a mul out of its read's column.
        let mut bad = m.clone();
        let (edge_src, edge_dst) = bad
            .s
            .g
            .edges()
            .iter()
            .find(|e| {
                e.kind == EdgeKind::Input
                    && matches!(bad.s.g.kind(e.dst), NodeKind::Mul { .. })
            })
            .map(|e| (e.src, e.dst))
            .unwrap();
        let ibus = bad.ibus_of(edge_src).unwrap();
        let wrong_col = (ibus + 1) % cgra.m;
        bad.placements[edge_dst] = Placement::Pe(PeId { row: 0, col: wrong_col });
        assert!(bad.verify(&cgra).is_err(), "verify must catch bad column");
    }

    #[test]
    fn verify_catches_pe_double_booking() {
        let cgra = StreamingCgra::paper_default();
        let nb = &paper_blocks()[1];
        let (g, _) = build_sdfg(&nb.block);
        let s = schedule_at(&g, &cgra, Techniques::all(), mii(&g, &cgra)).unwrap();
        let m = bind(&s, &cgra, 60_000, 42).unwrap();
        let ops: Vec<usize> = m.s.g.nodes().filter(|&v| m.s.g.kind(v).is_pe_op()).collect();
        let mut bad = m.clone();
        let mut corrupted = false;
        'outer: for (i, &a) in ops.iter().enumerate() {
            for &b in ops.iter().skip(i + 1) {
                if bad.s.m(a) == bad.s.m(b) {
                    bad.placements[b] = bad.placements[a];
                    corrupted = true;
                    break 'outer;
                }
            }
        }
        assert!(corrupted);
        assert!(bad.verify(&cgra).is_err());
    }

    #[test]
    fn bus_claims_cover_two_hop_routes() {
        let cgra = StreamingCgra::paper_default();
        let nb = &paper_blocks()[2];
        let (g, _) = build_sdfg(&nb.block);
        let s = schedule_at(&g, &cgra, Techniques::all(), mii(&g, &cgra)).unwrap();
        let m = bind(&s, &cgra, 60_000, 42).unwrap();
        for (idx, e) in m.s.g.edges().iter().enumerate() {
            if e.kind == EdgeKind::Internal && m.route_of_edge(idx) == Some(Route::Bus) {
                let ps = m.pe_of(e.src).unwrap();
                let pd = m.pe_of(e.dst).unwrap();
                let claims = m.bus_claims_of_edge(idx);
                let mesh = ps.row.abs_diff(pd.row) + ps.col.abs_diff(pd.col) == 1;
                let want = if ps == pd || mesh {
                    0 // same PE or dedicated mesh link
                } else if ps.row == pd.row || ps.col == pd.col {
                    1 // single bus hop
                } else {
                    2 // two-hop via a junction
                };
                assert_eq!(claims.len(), want, "edge {}→{} {ps} {pd}", e.src, e.dst);
            }
        }
    }
}
