//! Top-level mapping driver (paper Fig. 2): scheduling (phase ①) → routing
//! pre-allocation (②) → conflict-graph binding (③) → incomplete-mapping
//! handling (④).
//!
//! Phase ④ is realized as bounded re-scheduling: when routing or binding
//! fails at an II, the scheduler is re-run with a perturbed read-selection
//! order (BusMap's incomplete-mapping processing re-maps with modified
//! priorities); only when every perturbation at an II fails does the II
//! escalate — Algorithm 1's `II ← II + 1`. An II past `MII + ii_slack` is
//! the paper's "Failed".
//!
//! ## Parallel portfolio search
//!
//! Every `(II, retry)` attempt is independent (schedule + bind from the
//! pristine s-DFG with a per-attempt seed), so the lattice is explored as
//! a **deterministic parallel portfolio**: scoped worker threads claim
//! attempt indices in order, each with its own [`ScratchPool`], and the
//! winner is the lowest-index success — exactly the sequential order's
//! answer, byte-identical placements included, for any worker count.
//! Workers stop claiming once an index beyond the current winner would be
//! next (attempts after the winner cannot matter; attempts before it must
//! still finish, since a lower-index success would supersede).
//!
//! Per attempt, the bind stage runs the bucketed conflict-graph build and
//! the dense slot-major bus cost model (see `crate::bind`); both recycle
//! their storage through the worker's [`ScratchPool`], and both are locked
//! to their retired naive implementations by `tests/conflict_equivalence.rs`
//! and the golden snapshots in `tests/golden_mappings.rs`.

use crate::arch::StreamingCgra;
use crate::bind::{bind_with, Mapping, ScratchPool};
use crate::config::{SchedulerKind, SparsemapConfig, Techniques};
use crate::dfg::analysis::{mii, AssociationMatrix};
use crate::dfg::build::build_sdfg;
use crate::dfg::SDfg;
use crate::error::{Error, Result};
use crate::sched::{baseline, sparsemap, ScheduledSDfg};
use crate::sparse::SparseBlock;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Mapper configuration (a view over [`SparsemapConfig`]).
#[derive(Clone, Debug)]
pub struct MapperOptions {
    pub scheduler: SchedulerKind,
    pub techniques: Techniques,
    /// Give up beyond `MII + ii_slack`.
    pub ii_slack: usize,
    /// SBTS budget per MIS solve.
    pub mis_iterations: usize,
    /// Scheduling perturbations tried per II before escalating (phase ④).
    pub sched_retries: u64,
    pub seed: u64,
    /// Portfolio width for the `(II, retry)` attempt lattice. `0` = auto
    /// (available hardware parallelism, capped at 8); `1` = sequential.
    /// The result is identical for every value — only latency changes.
    pub parallelism: usize,
}

impl MapperOptions {
    /// The paper's full pipeline.
    pub fn sparsemap() -> Self {
        MapperOptions {
            scheduler: SchedulerKind::SparseMap,
            techniques: Techniques::all(),
            ii_slack: 3,
            mis_iterations: 60_000,
            sched_retries: 8,
            seed: 42,
            parallelism: 0,
        }
    }

    /// The wide-block (k > 64 / c > 64) operating point: the paper
    /// pipeline with a wider II slack (occupancy at MII is ceil-tight for
    /// wide shapes, so the first few IIs rarely schedule) and a reduced
    /// SBTS budget (wide conflict graphs are an order of magnitude larger
    /// per solve). The wide tests, the golden snapshot's `wide_k128` line,
    /// the `wide_*` bench rows and the design-space example all pin this
    /// exact configuration — retune it here, then re-bless the snapshot.
    pub fn wide() -> Self {
        MapperOptions { ii_slack: 8, mis_iterations: 15_000, ..Self::sparsemap() }
    }

    /// The BusMap [6] / Zhao [12] baseline pipeline (one schedule per II —
    /// heuristic [23] is deterministic and has no remap phase).
    pub fn baseline() -> Self {
        MapperOptions {
            scheduler: SchedulerKind::Baseline,
            techniques: Techniques::all(), // ignored by the baseline scheduler
            ii_slack: 3,
            mis_iterations: 60_000,
            sched_retries: 1,
            seed: 42,
            parallelism: 0,
        }
    }

    pub fn with_techniques(mut self, t: Techniques) -> Self {
        self.techniques = t;
        self
    }

    /// Fix the portfolio width (`1` forces the sequential path).
    pub fn with_parallelism(mut self, parallelism: usize) -> Self {
        self.parallelism = parallelism;
        self
    }

    pub fn from_config(cfg: &SparsemapConfig) -> Self {
        MapperOptions {
            scheduler: cfg.scheduler,
            techniques: cfg.techniques,
            ii_slack: cfg.ii_slack,
            mis_iterations: cfg.mis_iterations,
            sched_retries: if cfg.scheduler == SchedulerKind::Baseline { 1 } else { 8 },
            seed: cfg.seed,
            parallelism: cfg.parallelism,
        }
    }

    /// The effective portfolio width for a lattice of `lattice_len`
    /// attempts.
    fn width(&self, lattice_len: usize) -> usize {
        let auto = || {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
                .min(8)
        };
        let w = if self.parallelism == 0 { auto() } else { self.parallelism };
        w.clamp(1, lattice_len.max(1))
    }
}

/// Statistics of the *first mapping attempt* — the `II₀ / |C| / |M| /
/// Success?` columns of Table 3.
#[derive(Clone, Copy, Debug)]
pub struct FirstAttempt {
    pub ii0: usize,
    pub cops: usize,
    pub mcids: usize,
    pub success: bool,
}

/// A successful mapping plus its attempt history.
#[derive(Clone, Debug)]
pub struct MapOutcome {
    pub mapping: Mapping,
    pub first_attempt: FirstAttempt,
    /// (ii, retry) pairs attempted before success.
    pub attempts: Vec<(usize, u64)>,
    pub mii: usize,
}

impl MapOutcome {
    /// Speedup vs accelerating the corresponding dense block (Table 3 `S`):
    /// `MII_dense / II`, where the dense block's MII honours the same
    /// resource bounds (PEs, input buses, output buses) as §4.1's formula.
    pub fn speedup(&self, block: &SparseBlock, cgra: &StreamingCgra) -> f64 {
        let dense_mii = cgra
            .mii(block.dense_ops(), block.c, block.k)
            .max(1);
        dense_mii as f64 / self.mapping.ii as f64
    }
}

/// Schedule one attempt with the configured scheduler.
fn schedule_attempt(
    g: &SDfg,
    cgra: &StreamingCgra,
    opts: &MapperOptions,
    ii: usize,
    retry: u64,
    am: &AssociationMatrix,
) -> Result<ScheduledSDfg> {
    match opts.scheduler {
        SchedulerKind::SparseMap => {
            sparsemap::schedule_at_perturbed(g, cgra, opts.techniques, ii, retry, am)
        }
        SchedulerKind::Baseline => baseline::schedule_at(g, cgra, ii),
    }
}

/// What one `(II, retry)` attempt produced. Identical for a given index
/// no matter which thread (or scratch) ran it.
struct AttemptResult {
    /// `Some((cops, mcids))` when the schedule succeeded.
    sched: Option<(usize, usize)>,
    /// `Some` when schedule + bind both succeeded.
    mapping: Option<Mapping>,
}

fn run_attempt(
    g: &SDfg,
    cgra: &StreamingCgra,
    opts: &MapperOptions,
    ii: usize,
    retry: u64,
    am: &AssociationMatrix,
    scratch: &mut ScratchPool,
) -> AttemptResult {
    let Ok(s) = schedule_attempt(g, cgra, opts, ii, retry, am) else {
        return AttemptResult { sched: None, mapping: None };
    };
    let sched = Some((s.cops(), s.mcids().len()));
    let mapping = bind_with(&s, cgra, opts.mis_iterations, opts.seed ^ retry, scratch).ok();
    AttemptResult { sched, mapping }
}

// Retry order interleaves the packed (bit-2 clear) and spread (bit-2
// set) scheduling variants so both I/O policies are probed early.
const RETRY_ORDER: [u64; 8] = [0, 4, 1, 5, 2, 6, 3, 7];

/// Map a sparse block onto the CGRA. Returns the first fully bound mapping
/// (lowest II, then lowest perturbation), plus first-attempt statistics.
///
/// Runs the attempt lattice as a parallel portfolio by default
/// (`opts.parallelism`); the outcome is byte-identical to the sequential
/// order for every width.
pub fn map_block(
    block: &SparseBlock,
    cgra: &StreamingCgra,
    opts: &MapperOptions,
) -> Result<MapOutcome> {
    let (g, _) = build_sdfg(block);
    let base_ii = mii(&g, cgra);
    // The association matrix depends only on the pristine s-DFG: build it
    // once per block, share it across the whole attempt lattice.
    let am = AssociationMatrix::build(&g);

    let retries = opts.sched_retries.clamp(1, RETRY_ORDER.len() as u64) as usize;
    let lattice: Vec<(usize, u64)> = (base_ii..=base_ii + opts.ii_slack)
        .flat_map(|ii| RETRY_ORDER.iter().take(retries).map(move |&r| (ii, r)))
        .collect();

    let width = opts.width(lattice.len());
    let results = if width <= 1 {
        run_lattice_sequential(&g, cgra, opts, &am, &lattice)
    } else {
        run_lattice_portfolio(&g, cgra, opts, &am, &lattice, width)
    };

    // Fold in lattice order — both execution modes fill a prefix that
    // covers at least everything up to and including the winner.
    let mut first: Option<FirstAttempt> = None;
    let mut attempts = Vec::new();
    for (i, res) in results.into_iter().enumerate() {
        let Some(res) = res else { break };
        let (ii, retry) = lattice[i];
        attempts.push((ii, retry));
        if let Some((cops, mcids)) = res.sched {
            if first.is_none() {
                first = Some(FirstAttempt {
                    ii0: ii,
                    cops,
                    mcids,
                    success: res.mapping.is_some(),
                });
            }
            if let Some(mapping) = res.mapping {
                return Ok(MapOutcome {
                    mapping,
                    first_attempt: first.unwrap(),
                    attempts,
                    mii: base_ii,
                });
            }
        }
    }
    Err(Error::ScheduleFailed {
        block: block.name.clone(),
        reason: format!(
            "no valid mapping up to II={} (first attempt: {:?})",
            base_ii + opts.ii_slack,
            first
        ),
        ii_cap: base_ii + opts.ii_slack,
    })
}

/// Sequential reference order: attempt 0, 1, … until the first success.
fn run_lattice_sequential(
    g: &SDfg,
    cgra: &StreamingCgra,
    opts: &MapperOptions,
    am: &AssociationMatrix,
    lattice: &[(usize, u64)],
) -> Vec<Option<AttemptResult>> {
    let mut scratch = ScratchPool::new();
    let mut results: Vec<Option<AttemptResult>> = Vec::with_capacity(lattice.len());
    for &(ii, retry) in lattice {
        let res = run_attempt(g, cgra, opts, ii, retry, am, &mut scratch);
        let won = res.mapping.is_some();
        results.push(Some(res));
        if won {
            break;
        }
    }
    results.resize_with(lattice.len(), || None);
    results
}

/// Portfolio order: `width` scoped workers claim indices in sequence; the
/// lowest successful index wins, later claims are cancelled.
fn run_lattice_portfolio(
    g: &SDfg,
    cgra: &StreamingCgra,
    opts: &MapperOptions,
    am: &AssociationMatrix,
    lattice: &[(usize, u64)],
    width: usize,
) -> Vec<Option<AttemptResult>> {
    let next = AtomicUsize::new(0);
    let best = AtomicUsize::new(usize::MAX);
    let slots: Vec<Mutex<Option<AttemptResult>>> =
        lattice.iter().map(|_| Mutex::new(None)).collect();

    std::thread::scope(|scope| {
        for _ in 0..width {
            scope.spawn(|| {
                let mut scratch = ScratchPool::new();
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    // Indices are claimed in order, so everything at or
                    // below the final winner is guaranteed to be claimed
                    // (and completed) before the scope joins; anything
                    // past the current winner can never win.
                    if i >= lattice.len() || i > best.load(Ordering::Acquire) {
                        break;
                    }
                    let (ii, retry) = lattice[i];
                    let res = run_attempt(g, cgra, opts, ii, retry, am, &mut scratch);
                    if res.mapping.is_some() {
                        best.fetch_min(i, Ordering::AcqRel);
                    }
                    *slots[i].lock().expect("portfolio slot") = Some(res);
                }
            });
        }
    });

    slots
        .into_iter()
        .map(|m| m.into_inner().expect("portfolio slot"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen::paper_blocks;

    #[test]
    fn sparsemap_maps_every_paper_block() {
        let cgra = StreamingCgra::paper_default();
        for nb in paper_blocks() {
            let out = map_block(&nb.block, &cgra, &MapperOptions::sparsemap())
                .unwrap_or_else(|e| panic!("{}: {e}", nb.label));
            // blocks 5/7 (58 ops, 91% PE occupancy at MII) may take up to
            // MII+2 depending on the SBTS seed; everything else binds at
            // MII or MII+1.
            assert!(out.mapping.ii <= out.mii + 2, "{}: II {} vs MII {}",
                    nb.label, out.mapping.ii, out.mii);
            out.mapping.verify(&cgra).unwrap();
        }
    }

    #[test]
    fn speedups_match_paper_when_ii_equals_mii() {
        // Table 3 speedups: 1.5, 1.5, 1.67, 1.5, 2, 2.67, 2 at the paper's
        // final IIs. Check the formula against blocks where we hit MII.
        let cgra = StreamingCgra::paper_default();
        let want = [1.5, 1.5, 1.67, 1.5, 2.0, 2.67, 2.0];
        for (nb, &s_want) in paper_blocks().iter().zip(&want) {
            let out = map_block(&nb.block, &cgra, &MapperOptions::sparsemap()).unwrap();
            if out.mapping.ii == out.mii {
                let s = out.speedup(&nb.block, &cgra);
                assert!((s - s_want).abs() < 0.02, "{}: {s} vs {s_want}", nb.label);
            }
        }
    }

    #[test]
    fn baseline_underperforms_sparsemap() {
        let cgra = StreamingCgra::paper_default();
        let (mut base_fail, mut base_cops, mut sm_cops) = (0usize, 0usize, 0usize);
        for nb in paper_blocks() {
            match map_block(&nb.block, &cgra, &MapperOptions::baseline()) {
                Ok(out) => base_cops += out.mapping.cops(),
                Err(_) => base_fail += 1,
            }
            let sm = map_block(&nb.block, &cgra, &MapperOptions::sparsemap()).unwrap();
            sm_cops += sm.mapping.cops();
        }
        // The paper: baselines fail 2 of 7 blocks and pay 40 COPs vs 3.
        assert!(base_fail >= 1 || base_cops > 4 * sm_cops.max(1),
                "baseline should visibly underperform: fails={base_fail} cops={base_cops} vs {sm_cops}");
    }

    #[test]
    fn portfolio_matches_sequential_on_block1() {
        // Smoke-level determinism check (the full 7-block × width sweep
        // lives in tests/parallel_determinism.rs).
        let cgra = StreamingCgra::paper_default();
        let nb = &paper_blocks()[0];
        let seq = map_block(&nb.block, &cgra, &MapperOptions::sparsemap().with_parallelism(1))
            .unwrap();
        let par = map_block(&nb.block, &cgra, &MapperOptions::sparsemap().with_parallelism(3))
            .unwrap();
        assert_eq!(seq.mapping.ii, par.mapping.ii);
        assert_eq!(seq.mapping.placements, par.mapping.placements);
        assert_eq!(seq.attempts, par.attempts);
    }

    #[test]
    fn width_resolution() {
        let mut o = MapperOptions::sparsemap();
        o.parallelism = 1;
        assert_eq!(o.width(32), 1);
        o.parallelism = 4;
        assert_eq!(o.width(32), 4);
        assert_eq!(o.width(2), 2, "width never exceeds the lattice");
        o.parallelism = 0;
        assert!(o.width(32) >= 1);
        assert!(o.width(32) <= 8);
    }
}
