//! Top-level mapping driver (paper Fig. 2): scheduling (phase ①) → routing
//! pre-allocation (②) → conflict-graph binding (③) → incomplete-mapping
//! handling (④).
//!
//! Phase ④ is realized as bounded re-scheduling: when routing or binding
//! fails at an II, the scheduler is re-run with a perturbed read-selection
//! order (BusMap's incomplete-mapping processing re-maps with modified
//! priorities); only when every perturbation at an II fails does the II
//! escalate — Algorithm 1's `II ← II + 1`. An II past `MII + ii_slack` is
//! the paper's "Failed".

use crate::arch::StreamingCgra;
use crate::bind::{bind, Mapping};
use crate::config::{SchedulerKind, SparsemapConfig, Techniques};
use crate::dfg::analysis::mii;
use crate::dfg::build::build_sdfg;
use crate::error::{Error, Result};
use crate::sched::{baseline, sparsemap, ScheduledSDfg};
use crate::sparse::SparseBlock;

/// Mapper configuration (a view over [`SparsemapConfig`]).
#[derive(Clone, Debug)]
pub struct MapperOptions {
    pub scheduler: SchedulerKind,
    pub techniques: Techniques,
    /// Give up beyond `MII + ii_slack`.
    pub ii_slack: usize,
    /// SBTS budget per MIS solve.
    pub mis_iterations: usize,
    /// Scheduling perturbations tried per II before escalating (phase ④).
    pub sched_retries: u64,
    pub seed: u64,
}

impl MapperOptions {
    /// The paper's full pipeline.
    pub fn sparsemap() -> Self {
        MapperOptions {
            scheduler: SchedulerKind::SparseMap,
            techniques: Techniques::all(),
            ii_slack: 3,
            mis_iterations: 60_000,
            sched_retries: 8,
            seed: 42,
        }
    }

    /// The BusMap [6] / Zhao [12] baseline pipeline (one schedule per II —
    /// heuristic [23] is deterministic and has no remap phase).
    pub fn baseline() -> Self {
        MapperOptions {
            scheduler: SchedulerKind::Baseline,
            techniques: Techniques::all(), // ignored by the baseline scheduler
            ii_slack: 3,
            mis_iterations: 60_000,
            sched_retries: 1,
            seed: 42,
        }
    }

    pub fn with_techniques(mut self, t: Techniques) -> Self {
        self.techniques = t;
        self
    }

    pub fn from_config(cfg: &SparsemapConfig) -> Self {
        MapperOptions {
            scheduler: cfg.scheduler,
            techniques: cfg.techniques,
            ii_slack: cfg.ii_slack,
            mis_iterations: cfg.mis_iterations,
            sched_retries: if cfg.scheduler == SchedulerKind::Baseline { 1 } else { 8 },
            seed: cfg.seed,
        }
    }
}

/// Statistics of the *first mapping attempt* — the `II₀ / |C| / |M| /
/// Success?` columns of Table 3.
#[derive(Clone, Copy, Debug)]
pub struct FirstAttempt {
    pub ii0: usize,
    pub cops: usize,
    pub mcids: usize,
    pub success: bool,
}

/// A successful mapping plus its attempt history.
#[derive(Clone, Debug)]
pub struct MapOutcome {
    pub mapping: Mapping,
    pub first_attempt: FirstAttempt,
    /// (ii, retry) pairs attempted before success.
    pub attempts: Vec<(usize, u64)>,
    pub mii: usize,
}

impl MapOutcome {
    /// Speedup vs accelerating the corresponding dense block (Table 3 `S`):
    /// `MII_dense / II`, where the dense block's MII honours the same
    /// resource bounds (PEs, input buses, output buses) as §4.1's formula.
    pub fn speedup(&self, block: &SparseBlock, cgra: &StreamingCgra) -> f64 {
        let dense_mii = cgra
            .mii(block.dense_ops(), block.c, block.k)
            .max(1);
        dense_mii as f64 / self.mapping.ii as f64
    }
}

/// Schedule one attempt with the configured scheduler.
fn schedule_attempt(
    g: &crate::dfg::SDfg,
    cgra: &StreamingCgra,
    opts: &MapperOptions,
    ii: usize,
    retry: u64,
) -> Result<ScheduledSDfg> {
    match opts.scheduler {
        SchedulerKind::SparseMap => {
            sparsemap::schedule_at_perturbed(g, cgra, opts.techniques, ii, retry)
        }
        SchedulerKind::Baseline => baseline::schedule_at(g, cgra, ii),
    }
}

/// Map a sparse block onto the CGRA. Returns the first fully bound mapping
/// (lowest II, then lowest perturbation), plus first-attempt statistics.
pub fn map_block(
    block: &SparseBlock,
    cgra: &StreamingCgra,
    opts: &MapperOptions,
) -> Result<MapOutcome> {
    let (g, _) = build_sdfg(block);
    let base_ii = mii(&g, cgra);
    let mut first: Option<FirstAttempt> = None;
    let mut attempts = Vec::new();

    // Retry order interleaves the packed (bit-2 clear) and spread (bit-2
    // set) scheduling variants so both I/O policies are probed early.
    const RETRY_ORDER: [u64; 8] = [0, 4, 1, 5, 2, 6, 3, 7];
    for ii in base_ii..=base_ii + opts.ii_slack {
        for &retry in RETRY_ORDER.iter().take(opts.sched_retries.max(1) as usize) {
            attempts.push((ii, retry));
            let Ok(s) = schedule_attempt(&g, cgra, opts, ii, retry) else { continue };
            let bound = bind(&s, cgra, opts.mis_iterations, opts.seed ^ retry);
            if first.is_none() {
                first = Some(FirstAttempt {
                    ii0: ii,
                    cops: s.cops(),
                    mcids: s.mcids().len(),
                    success: bound.is_ok(),
                });
            }
            if let Ok(mapping) = bound {
                return Ok(MapOutcome {
                    mapping,
                    first_attempt: first.unwrap(),
                    attempts,
                    mii: base_ii,
                });
            }
        }
    }
    Err(Error::ScheduleFailed {
        block: block.name.clone(),
        reason: format!(
            "no valid mapping up to II={} (first attempt: {:?})",
            base_ii + opts.ii_slack,
            first
        ),
        ii_cap: base_ii + opts.ii_slack,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen::paper_blocks;

    #[test]
    fn sparsemap_maps_every_paper_block() {
        let cgra = StreamingCgra::paper_default();
        for nb in paper_blocks() {
            let out = map_block(&nb.block, &cgra, &MapperOptions::sparsemap())
                .unwrap_or_else(|e| panic!("{}: {e}", nb.label));
            // blocks 5/7 (58 ops, 91% PE occupancy at MII) may take up to
            // MII+2 depending on the SBTS seed; everything else binds at
            // MII or MII+1.
            assert!(out.mapping.ii <= out.mii + 2, "{}: II {} vs MII {}",
                    nb.label, out.mapping.ii, out.mii);
            out.mapping.verify(&cgra).unwrap();
        }
    }

    #[test]
    fn speedups_match_paper_when_ii_equals_mii() {
        // Table 3 speedups: 1.5, 1.5, 1.67, 1.5, 2, 2.67, 2 at the paper's
        // final IIs. Check the formula against blocks where we hit MII.
        let cgra = StreamingCgra::paper_default();
        let want = [1.5, 1.5, 1.67, 1.5, 2.0, 2.67, 2.0];
        for (nb, &s_want) in paper_blocks().iter().zip(&want) {
            let out = map_block(&nb.block, &cgra, &MapperOptions::sparsemap()).unwrap();
            if out.mapping.ii == out.mii {
                let s = out.speedup(&nb.block, &cgra);
                assert!((s - s_want).abs() < 0.02, "{}: {s} vs {s_want}", nb.label);
            }
        }
    }

    #[test]
    fn baseline_underperforms_sparsemap() {
        let cgra = StreamingCgra::paper_default();
        let (mut base_fail, mut base_cops, mut sm_cops) = (0usize, 0usize, 0usize);
        for nb in paper_blocks() {
            match map_block(&nb.block, &cgra, &MapperOptions::baseline()) {
                Ok(out) => base_cops += out.mapping.cops(),
                Err(_) => base_fail += 1,
            }
            let sm = map_block(&nb.block, &cgra, &MapperOptions::sparsemap()).unwrap();
            sm_cops += sm.mapping.cops();
        }
        // The paper: baselines fail 2 of 7 blocks and pay 40 COPs vs 3.
        assert!(base_fail >= 1 || base_cops > 4 * sm_cops.max(1),
                "baseline should visibly underperform: fails={base_fail} cops={base_cops} vs {sm_cops}");
    }
}
