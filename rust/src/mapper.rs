//! Top-level mapping driver (paper Fig. 2): scheduling (phase ①) → routing
//! pre-allocation (②) → conflict-graph binding (③) → incomplete-mapping
//! handling (④).
//!
//! Phase ④ is realized as bounded re-scheduling: when routing or binding
//! fails at an II, the scheduler is re-run with a perturbed read-selection
//! order (BusMap's incomplete-mapping processing re-maps with modified
//! priorities); only when every perturbation at an II fails does the II
//! escalate — Algorithm 1's `II ← II + 1`. An II past `MII + ii_slack` is
//! the paper's "Failed".
//!
//! ## Parallel portfolio search
//!
//! Every `(II, retry)` attempt is independent (schedule + bind from the
//! pristine s-DFG with a per-attempt seed), so the lattice is explored as
//! a **deterministic parallel portfolio**: scoped worker threads claim
//! attempt indices in order, each with its own [`ScratchPool`], and the
//! winner is the lowest-index success — exactly the sequential order's
//! answer, byte-identical placements included, for any worker count.
//! Workers stop claiming once an index beyond the current winner would be
//! next (attempts after the winner cannot matter; attempts before it must
//! still finish, since a lower-index success would supersede).
//!
//! Per attempt, the bind stage runs the bucketed conflict-graph build and
//! the dense slot-major bus cost model (see `crate::bind`); both recycle
//! their storage through the worker's [`ScratchPool`], and both are locked
//! to their retired naive implementations by `tests/conflict_equivalence.rs`
//! and the golden snapshots in `tests/golden_mappings.rs`.
//!
//! ## Mapping units and multi-block fusion
//!
//! The lattice operates on a [`MapUnit`]: a single block or a
//! [`FusedBundle`] of small blocks destined for one fabric configuration.
//! Per attempt, every bundle member is scheduled *solo* at the shared
//! `(II, retry)`; the solo schedules are then composed by per-member
//! modulo-slot time shifts (greedy smallest-fit offsets over the combined
//! reads/writes/PE/GRF-port occupancy — `compose_scheduled`). A constant
//! shift changes no dependency distance and no modulo-slot equality, so
//! each member's COPs, MCIDs and route classes inside the bundle are
//! byte-identical to its solo schedule at that attempt
//! (`tests/fusion_equivalence.rs` asserts this). The composed graph then
//! binds exactly like a single block: the conflict-graph's
//! `(slot, resource)` buckets span members, so cross-block exclusiveness
//! falls out of the existing machinery and SBTS needs no structural
//! changes. [`map_block`] is a thin wrapper over [`map_unit`] and its
//! results are unchanged by the refactor.

use crate::arch::StreamingCgra;
use crate::bind::{bind_with, Mapping, ScratchPool};
use crate::config::{SchedulerKind, SparsemapConfig, Techniques};
use crate::dfg::analysis::AssociationMatrix;
use crate::dfg::build::build_sdfg;
use crate::dfg::fuse::{compose, BlockTags};
use crate::dfg::{EdgeKind, NodeKind, SDfg};
use crate::error::{Error, Result};
use crate::sched::{baseline, sparsemap, ScheduledSDfg};
use crate::sparse::fuse::{FusedBundle, FusionOptions};
use crate::sparse::SparseBlock;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Mapper configuration (a view over [`SparsemapConfig`]).
#[derive(Clone, Debug)]
pub struct MapperOptions {
    pub scheduler: SchedulerKind,
    pub techniques: Techniques,
    /// Give up beyond `MII + ii_slack`.
    pub ii_slack: usize,
    /// SBTS budget per MIS solve.
    pub mis_iterations: usize,
    /// Scheduling perturbations tried per II before escalating (phase ④).
    pub sched_retries: u64,
    pub seed: u64,
    /// Portfolio width for the `(II, retry)` attempt lattice. `0` = auto
    /// (available hardware parallelism, capped at 8); `1` = sequential.
    /// The result is identical for every value — only latency changes.
    pub parallelism: usize,
    /// Multi-block fusion knobs (consumed by the fusion planner — see
    /// [`crate::sparse::fuse::plan_bundles`] and the coordinator's
    /// `register_fused`); `map_unit` itself maps whatever bundle it is
    /// handed.
    pub fusion: FusionOptions,
}

impl MapperOptions {
    /// The paper's full pipeline.
    pub fn sparsemap() -> Self {
        MapperOptions {
            scheduler: SchedulerKind::SparseMap,
            techniques: Techniques::all(),
            ii_slack: 3,
            mis_iterations: 60_000,
            sched_retries: 8,
            seed: 42,
            parallelism: 0,
            fusion: FusionOptions::default(),
        }
    }

    /// The wide-block (k > 64 / c > 64) operating point: the paper
    /// pipeline with a wider II slack (occupancy at MII is ceil-tight for
    /// wide shapes, so the first few IIs rarely schedule) and a reduced
    /// SBTS budget (wide conflict graphs are an order of magnitude larger
    /// per solve). The wide tests, the golden snapshot's `wide_k128` line,
    /// the `wide_*` bench rows and the design-space example all pin this
    /// exact configuration — retune it here, then re-bless the snapshot.
    pub fn wide() -> Self {
        MapperOptions { ii_slack: 8, mis_iterations: 15_000, ..Self::sparsemap() }
    }

    /// The fused-bundle operating point: the paper pipeline with a much
    /// wider II slack. A bundle's combined MII sits well above each
    /// member's own MII, and the slot-offset composition (see
    /// `compose_scheduled`) needs enough II headroom to interleave the
    /// members' occupancy profiles — once `II ≥ Σ member makespans` a
    /// fully disjoint offset assignment exists, so a generous slack makes
    /// the lattice's success a matter of *when*, not *if* (the lattice is
    /// lazy: unused slack costs nothing once an earlier attempt wins).
    /// The fused golden line, `tests/fusion_equivalence.rs` and the
    /// `fused3/*` bench rows all pin this configuration.
    pub fn fused() -> Self {
        MapperOptions { ii_slack: 16, ..Self::sparsemap() }
    }

    /// The BusMap [6] / Zhao [12] baseline pipeline (one schedule per II —
    /// heuristic [23] is deterministic and has no remap phase).
    pub fn baseline() -> Self {
        MapperOptions {
            scheduler: SchedulerKind::Baseline,
            techniques: Techniques::all(), // ignored by the baseline scheduler
            ii_slack: 3,
            mis_iterations: 60_000,
            sched_retries: 1,
            seed: 42,
            parallelism: 0,
            fusion: FusionOptions::default(),
        }
    }

    pub fn with_techniques(mut self, t: Techniques) -> Self {
        self.techniques = t;
        self
    }

    /// Fix the portfolio width (`1` forces the sequential path).
    pub fn with_parallelism(mut self, parallelism: usize) -> Self {
        self.parallelism = parallelism;
        self
    }

    pub fn from_config(cfg: &SparsemapConfig) -> Self {
        MapperOptions {
            scheduler: cfg.scheduler,
            techniques: cfg.techniques,
            ii_slack: cfg.ii_slack,
            mis_iterations: cfg.mis_iterations,
            sched_retries: if cfg.scheduler == SchedulerKind::Baseline { 1 } else { 8 },
            seed: cfg.seed,
            parallelism: cfg.parallelism,
            fusion: FusionOptions { max_blocks: cfg.max_fused_blocks, max_ii: cfg.fusion_max_ii },
        }
    }

    /// The effective portfolio width for a lattice of `lattice_len`
    /// attempts.
    fn width(&self, lattice_len: usize) -> usize {
        let auto = || {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
                .min(8)
        };
        let w = if self.parallelism == 0 { auto() } else { self.parallelism };
        w.clamp(1, lattice_len.max(1))
    }
}

/// Statistics of the *first mapping attempt* — the `II₀ / |C| / |M| /
/// Success?` columns of Table 3.
#[derive(Clone, Copy, Debug)]
pub struct FirstAttempt {
    pub ii0: usize,
    pub cops: usize,
    pub mcids: usize,
    pub success: bool,
}

/// A successful mapping plus its attempt history.
#[derive(Clone, Debug)]
pub struct MapOutcome {
    pub mapping: Mapping,
    /// Node → member-block provenance (trivial single-member tags for an
    /// unfused block) — the key to per-block reporting out of a fused
    /// mapping.
    pub tags: BlockTags,
    pub first_attempt: FirstAttempt,
    /// (ii, retry) pairs attempted before success.
    pub attempts: Vec<(usize, u64)>,
    pub mii: usize,
}

/// Per-member scheduling statistics of a (possibly fused) mapping.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BlockStats {
    pub cops: usize,
    pub mcids: usize,
}

/// Split a scheduled graph's COPs and MCIDs by member-block provenance.
/// For trivial tags this returns one entry equal to the global counts.
pub fn per_block_stats(s: &ScheduledSDfg, tags: &BlockTags) -> Vec<BlockStats> {
    let mut out = vec![BlockStats { cops: 0, mcids: 0 }; tags.members()];
    for v in s.g.nodes() {
        if matches!(s.g.kind(v), NodeKind::Cop { .. }) {
            out[tags.block_of(v)].cops += 1;
        }
    }
    for e in s.g.edges() {
        if e.kind == EdgeKind::Internal && s.t[e.dst] - s.t[e.src] > 1 {
            out[tags.block_of(e.src)].mcids += 1;
        }
    }
    out
}

impl MapOutcome {
    /// Speedup vs accelerating the corresponding dense block (Table 3 `S`):
    /// `MII_dense / II`, where the dense block's MII honours the same
    /// resource bounds (PEs, input buses, output buses) as §4.1's formula.
    pub fn speedup(&self, block: &SparseBlock, cgra: &StreamingCgra) -> f64 {
        let dense_mii = cgra
            .mii(block.dense_ops(), block.c, block.k)
            .max(1);
        dense_mii as f64 / self.mapping.ii as f64
    }

    /// COPs / MCIDs split by member block. Inside a bundle each member's
    /// values equal its solo schedule at the winning `(II, retry)` — the
    /// slot-offset composition preserves member schedules exactly
    /// (asserted by `tests/fusion_equivalence.rs`).
    pub fn per_block_stats(&self) -> Vec<BlockStats> {
        per_block_stats(&self.mapping.s, &self.tags)
    }

    /// Everything compiling an execution plan needs from an outcome: the
    /// verified mapping plus its node → member provenance. This is the
    /// compiled simulation backend's contract with the mapper
    /// (`crate::sim::ExecPlan::for_outcome` consumes it).
    pub fn plan_inputs(&self) -> (&Mapping, &BlockTags) {
        (&self.mapping, &self.tags)
    }

    /// The `(II, retry)` pair that produced the winning mapping.
    pub fn winning_attempt(&self) -> (usize, u64) {
        *self.attempts.last().expect("a successful outcome records its winning attempt")
    }
}

/// Schedule one attempt with the configured scheduler.
fn schedule_attempt(
    g: &SDfg,
    cgra: &StreamingCgra,
    opts: &MapperOptions,
    ii: usize,
    retry: u64,
    am: &AssociationMatrix,
) -> Result<ScheduledSDfg> {
    match opts.scheduler {
        SchedulerKind::SparseMap => {
            sparsemap::schedule_at_perturbed(g, cgra, opts.techniques, ii, retry, am)
        }
        SchedulerKind::Baseline => baseline::schedule_at(g, cgra, ii),
    }
}

/// The unit a mapping attempt operates on: one sparse block, or a fused
/// bundle of blocks destined for a single fabric configuration.
pub enum MapUnit<'a> {
    Single(&'a SparseBlock),
    Bundle(&'a FusedBundle),
}

/// Per-unit state shared across the whole attempt lattice: each member's
/// pristine s-DFG and its association matrix (both depend only on block
/// structure).
struct UnitCtx {
    name: String,
    parts: Vec<(SDfg, AssociationMatrix)>,
}

impl UnitCtx {
    fn build(unit: &MapUnit<'_>) -> Self {
        let (name, blocks): (String, Vec<&SparseBlock>) = match unit {
            MapUnit::Single(b) => (b.name.clone(), vec![*b]),
            MapUnit::Bundle(bu) => {
                (bu.name.clone(), bu.blocks.iter().map(|b| b.as_ref()).collect())
            }
        };
        let parts = blocks
            .into_iter()
            .map(|b| {
                let (g, _) = build_sdfg(b);
                // The fusion planner budgets bundles by feature-derived
                // node counts (`FusedBundle::mii`) while the lattice below
                // starts from graph-derived ones; pin the two count
                // sources together so any future build_sdfg/features drift
                // fails loudly here instead of skewing planner admission
                // against the mapper's base II.
                debug_assert_eq!(g.v_op().len(), b.features().v_op, "{}: v_op drift", b.name);
                debug_assert_eq!(g.reads().len(), b.features().v_r, "{}: v_r drift", b.name);
                debug_assert_eq!(g.writes().len(), b.features().v_w, "{}: v_w drift", b.name);
                let am = AssociationMatrix::build(&g);
                (g, am)
            })
            .collect();
        UnitCtx { name, parts }
    }

    /// Combined MII (§4.1 bound over the members' summed node counts —
    /// identical to the per-graph MII for a single block).
    fn mii(&self, cgra: &StreamingCgra) -> usize {
        let (ops, reads, writes) = self.parts.iter().fold((0, 0, 0), |acc, (g, _)| {
            (acc.0 + g.v_op().len(), acc.1 + g.reads().len(), acc.2 + g.writes().len())
        });
        cgra.mii(ops, reads, writes)
    }
}

/// What one `(II, retry)` attempt produced. Identical for a given index
/// no matter which thread (or scratch) ran it.
struct AttemptResult {
    /// `Some((cops, mcids))` when every member scheduled and (for bundles)
    /// the slot-offset composition fit the fabric.
    sched: Option<(usize, usize)>,
    /// `Some` when schedule + bind both succeeded.
    mapping: Option<(Mapping, BlockTags)>,
}

const ATTEMPT_FAILED: AttemptResult = AttemptResult { sched: None, mapping: None };

fn run_attempt(
    ctx: &UnitCtx,
    cgra: &StreamingCgra,
    opts: &MapperOptions,
    ii: usize,
    retry: u64,
    scratch: &mut ScratchPool,
) -> AttemptResult {
    // Every member schedules solo at the shared (ii, retry): a bundle
    // shares the II but each block keeps exactly the schedule it would get
    // alone at that attempt.
    let mut parts = Vec::with_capacity(ctx.parts.len());
    for (g, am) in &ctx.parts {
        match schedule_attempt(g, cgra, opts, ii, retry, am) {
            Ok(s) => parts.push(s),
            Err(_) => return ATTEMPT_FAILED,
        }
    }
    let (s, tags) = if parts.len() == 1 {
        let s = parts.pop().expect("one part");
        let tags = BlockTags::single(s.g.len());
        (s, tags)
    } else {
        match compose_scheduled(&ctx.name, &parts, cgra) {
            Some(st) => st,
            None => return ATTEMPT_FAILED,
        }
    };
    let sched = Some((s.cops(), s.mcids().len()));
    let mapping = bind_with(&s, cgra, opts.mis_iterations, opts.seed ^ retry, scratch).ok();
    AttemptResult { sched, mapping: mapping.map(|m| (m, tags)) }
}

/// Compose solo member schedules into one fused schedule at the shared II.
///
/// Each member is time-shifted by a per-member slot offset (greedy
/// smallest-fit, fixed member order) so the combined per-slot occupancy —
/// input buses, output buses, PEs and GRF write ports — fits the fabric. A
/// constant time shift leaves every dependency distance and every
/// modulo-slot equality untouched, so a member's COPs, MCIDs and route
/// classes inside the bundle are byte-identical to its solo schedule;
/// only the modulo phase moves. Returns `None` when no offset assignment
/// fits (the attempt fails and the mapper escalates the lattice).
fn compose_scheduled(
    name: &str,
    parts: &[ScheduledSDfg],
    cgra: &StreamingCgra,
) -> Option<(ScheduledSDfg, BlockTags)> {
    let ii = parts[0].ii;
    debug_assert!(parts.iter().all(|s| s.ii == ii), "bundle members share the II");
    let mut reads = vec![0usize; ii];
    let mut writes = vec![0usize; ii];
    let mut pe_ops = vec![0usize; ii];
    let mut grf_w = vec![0usize; ii];
    let mut shifts = Vec::with_capacity(parts.len());
    for s in parts {
        let occ = s.occupancy();
        // Same GRF-forced-MCID classification the route pre-allocator
        // applies (pinned together by `route::tests`).
        let grf = crate::bind::route::grf_writes_per_slot(s);
        let off = (0..ii).find(|&off| {
            (0..ii).all(|slot| {
                let src = (slot + ii - off) % ii;
                reads[slot] + occ.reads[src] <= cgra.m
                    && writes[slot] + occ.writes[src] <= cgra.n
                    && pe_ops[slot] + occ.pe_ops[src] <= cgra.num_pes()
                    && grf_w[slot] + grf[src] <= cgra.grf_write_ports
            })
        })?;
        for slot in 0..ii {
            let src = (slot + ii - off) % ii;
            reads[slot] += occ.reads[src];
            writes[slot] += occ.writes[src];
            pe_ops[slot] += occ.pe_ops[src];
            grf_w[slot] += grf[src];
        }
        shifts.push(off);
    }
    let gs: Vec<&SDfg> = parts.iter().map(|s| &s.g).collect();
    let (g, tags) = compose(name, &gs);
    let mut t = Vec::with_capacity(g.len());
    for (s, &off) in parts.iter().zip(&shifts) {
        t.extend(s.t.iter().map(|&x| x + off));
    }
    let s = ScheduledSDfg { g, ii, t };
    // The offset search already guarantees constraint (2); this re-checks
    // (1)+(2) from first principles and refuses rather than binding an
    // inconsistent composition.
    if let Err(e) = s.verify(cgra) {
        if cfg!(debug_assertions) {
            panic!("offset-composed schedule must verify: {e}");
        }
        return None;
    }
    Some((s, tags))
}

// Retry order interleaves the packed (bit-2 clear) and spread (bit-2
// set) scheduling variants so both I/O policies are probed early.
const RETRY_ORDER: [u64; 8] = [0, 4, 1, 5, 2, 6, 3, 7];

/// Map a sparse block onto the CGRA — a thin wrapper over [`map_unit`].
/// Returns the first fully bound mapping (lowest II, then lowest
/// perturbation), plus first-attempt statistics.
pub fn map_block(
    block: &SparseBlock,
    cgra: &StreamingCgra,
    opts: &MapperOptions,
) -> Result<MapOutcome> {
    map_unit(MapUnit::Single(block), cgra, opts)
}

/// Map a fused bundle onto one fabric configuration — a thin wrapper over
/// [`map_unit`]. See [`MapperOptions::fused`] for the recommended
/// operating point.
pub fn map_bundle(
    bundle: &FusedBundle,
    cgra: &StreamingCgra,
    opts: &MapperOptions,
) -> Result<MapOutcome> {
    map_unit(MapUnit::Bundle(bundle), cgra, opts)
}

/// Map one unit (a single block or a fused bundle) onto the CGRA.
///
/// The `(II, retry)` attempt lattice starts at the unit's combined MII and
/// runs as a deterministic parallel portfolio (`opts.parallelism`); the
/// outcome is byte-identical to the sequential order for every width, and
/// `map_block`'s results are bit-for-bit what they were before fusion
/// existed (a single-member unit takes exactly the old code path).
pub fn map_unit(
    unit: MapUnit<'_>,
    cgra: &StreamingCgra,
    opts: &MapperOptions,
) -> Result<MapOutcome> {
    // Pristine graphs + association matrices depend only on the block
    // structures: build them once, share them across the whole lattice.
    let ctx = UnitCtx::build(&unit);
    let base_ii = ctx.mii(cgra);

    let retries = opts.sched_retries.clamp(1, RETRY_ORDER.len() as u64) as usize;
    let lattice: Vec<(usize, u64)> = (base_ii..=base_ii + opts.ii_slack)
        .flat_map(|ii| RETRY_ORDER.iter().take(retries).map(move |&r| (ii, r)))
        .collect();

    let width = opts.width(lattice.len());
    let results = if width <= 1 {
        run_lattice_sequential(&ctx, cgra, opts, &lattice)
    } else {
        run_lattice_portfolio(&ctx, cgra, opts, &lattice, width)
    };

    // Fold in lattice order — both execution modes fill a prefix that
    // covers at least everything up to and including the winner.
    let mut first: Option<FirstAttempt> = None;
    let mut attempts = Vec::new();
    for (i, res) in results.into_iter().enumerate() {
        let Some(res) = res else { break };
        let (ii, retry) = lattice[i];
        attempts.push((ii, retry));
        if let Some((cops, mcids)) = res.sched {
            if first.is_none() {
                first = Some(FirstAttempt {
                    ii0: ii,
                    cops,
                    mcids,
                    success: res.mapping.is_some(),
                });
            }
            if let Some((mapping, tags)) = res.mapping {
                return Ok(MapOutcome {
                    mapping,
                    tags,
                    first_attempt: first.unwrap(),
                    attempts,
                    mii: base_ii,
                });
            }
        }
    }
    Err(Error::ScheduleFailed {
        block: ctx.name.clone(),
        reason: format!(
            "no valid mapping up to II={} (first attempt: {:?})",
            base_ii + opts.ii_slack,
            first
        ),
        ii_cap: base_ii + opts.ii_slack,
    })
}

/// Sequential reference order: attempt 0, 1, … until the first success.
fn run_lattice_sequential(
    ctx: &UnitCtx,
    cgra: &StreamingCgra,
    opts: &MapperOptions,
    lattice: &[(usize, u64)],
) -> Vec<Option<AttemptResult>> {
    let mut scratch = ScratchPool::new();
    let mut results: Vec<Option<AttemptResult>> = Vec::with_capacity(lattice.len());
    for &(ii, retry) in lattice {
        let res = run_attempt(ctx, cgra, opts, ii, retry, &mut scratch);
        let won = res.mapping.is_some();
        results.push(Some(res));
        if won {
            break;
        }
    }
    results.resize_with(lattice.len(), || None);
    results
}

/// Portfolio order: `width` scoped workers claim indices in sequence; the
/// lowest successful index wins, later claims are cancelled.
fn run_lattice_portfolio(
    ctx: &UnitCtx,
    cgra: &StreamingCgra,
    opts: &MapperOptions,
    lattice: &[(usize, u64)],
    width: usize,
) -> Vec<Option<AttemptResult>> {
    let next = AtomicUsize::new(0);
    let best = AtomicUsize::new(usize::MAX);
    let slots: Vec<Mutex<Option<AttemptResult>>> =
        lattice.iter().map(|_| Mutex::new(None)).collect();

    std::thread::scope(|scope| {
        for _ in 0..width {
            scope.spawn(|| {
                let mut scratch = ScratchPool::new();
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    // Indices are claimed in order, so everything at or
                    // below the final winner is guaranteed to be claimed
                    // (and completed) before the scope joins; anything
                    // past the current winner can never win.
                    if i >= lattice.len() || i > best.load(Ordering::Acquire) {
                        break;
                    }
                    let (ii, retry) = lattice[i];
                    let res = run_attempt(ctx, cgra, opts, ii, retry, &mut scratch);
                    if res.mapping.is_some() {
                        best.fetch_min(i, Ordering::AcqRel);
                    }
                    *slots[i].lock().expect("portfolio slot") = Some(res);
                }
            });
        }
    });

    slots
        .into_iter()
        .map(|m| m.into_inner().expect("portfolio slot"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen::paper_blocks;

    #[test]
    fn sparsemap_maps_every_paper_block() {
        let cgra = StreamingCgra::paper_default();
        for nb in paper_blocks() {
            let out = map_block(&nb.block, &cgra, &MapperOptions::sparsemap())
                .unwrap_or_else(|e| panic!("{}: {e}", nb.label));
            // blocks 5/7 (58 ops, 91% PE occupancy at MII) may take up to
            // MII+2 depending on the SBTS seed; everything else binds at
            // MII or MII+1.
            assert!(out.mapping.ii <= out.mii + 2, "{}: II {} vs MII {}",
                    nb.label, out.mapping.ii, out.mii);
            out.mapping.verify(&cgra).unwrap();
        }
    }

    #[test]
    fn speedups_match_paper_when_ii_equals_mii() {
        // Table 3 speedups: 1.5, 1.5, 1.67, 1.5, 2, 2.67, 2 at the paper's
        // final IIs. Check the formula against blocks where we hit MII.
        let cgra = StreamingCgra::paper_default();
        let want = [1.5, 1.5, 1.67, 1.5, 2.0, 2.67, 2.0];
        for (nb, &s_want) in paper_blocks().iter().zip(&want) {
            let out = map_block(&nb.block, &cgra, &MapperOptions::sparsemap()).unwrap();
            if out.mapping.ii == out.mii {
                let s = out.speedup(&nb.block, &cgra);
                assert!((s - s_want).abs() < 0.02, "{}: {s} vs {s_want}", nb.label);
            }
        }
    }

    #[test]
    fn baseline_underperforms_sparsemap() {
        let cgra = StreamingCgra::paper_default();
        let (mut base_fail, mut base_cops, mut sm_cops) = (0usize, 0usize, 0usize);
        for nb in paper_blocks() {
            match map_block(&nb.block, &cgra, &MapperOptions::baseline()) {
                Ok(out) => base_cops += out.mapping.cops(),
                Err(_) => base_fail += 1,
            }
            let sm = map_block(&nb.block, &cgra, &MapperOptions::sparsemap()).unwrap();
            sm_cops += sm.mapping.cops();
        }
        // The paper: baselines fail 2 of 7 blocks and pay 40 COPs vs 3.
        assert!(base_fail >= 1 || base_cops > 4 * sm_cops.max(1),
                "baseline should visibly underperform: fails={base_fail} cops={base_cops} vs {sm_cops}");
    }

    #[test]
    fn portfolio_matches_sequential_on_block1() {
        // Smoke-level determinism check (the full 7-block × width sweep
        // lives in tests/parallel_determinism.rs).
        let cgra = StreamingCgra::paper_default();
        let nb = &paper_blocks()[0];
        let seq = map_block(&nb.block, &cgra, &MapperOptions::sparsemap().with_parallelism(1))
            .unwrap();
        let par = map_block(&nb.block, &cgra, &MapperOptions::sparsemap().with_parallelism(3))
            .unwrap();
        assert_eq!(seq.mapping.ii, par.mapping.ii);
        assert_eq!(seq.mapping.placements, par.mapping.placements);
        assert_eq!(seq.attempts, par.attempts);
    }

    fn tiny_bundle() -> FusedBundle {
        use std::sync::Arc;
        let blocks = [
            ("t1", 2, 2, vec![true, false, true, true]),
            ("t2", 3, 2, vec![true, true, false, true, true, false]),
            ("t3", 2, 3, vec![true, false, true, false, true, true]),
        ]
        .into_iter()
        .map(|(name, c, k, mask)| {
            Arc::new(SparseBlock::from_mask(name, c, k, mask).unwrap())
        })
        .collect();
        FusedBundle::new(blocks).unwrap()
    }

    #[test]
    fn tiny_bundle_maps_onto_one_configuration() {
        let cgra = StreamingCgra::paper_default();
        let bundle = tiny_bundle();
        let out = map_bundle(&bundle, &cgra, &MapperOptions::fused())
            .unwrap_or_else(|e| panic!("tiny bundle must map: {e}"));
        out.mapping.verify(&cgra).unwrap();
        assert_eq!(out.tags.members(), 3);
        assert!(out.mapping.ii >= bundle.mii(&cgra));
        // Per-block stats partition the global counts.
        let stats = out.per_block_stats();
        assert_eq!(stats.len(), 3);
        assert_eq!(stats.iter().map(|s| s.cops).sum::<usize>(), out.mapping.cops());
        assert_eq!(stats.iter().map(|s| s.mcids).sum::<usize>(), out.mapping.mcids());
        // The winning attempt is recorded last.
        assert_eq!(out.winning_attempt().0, out.mapping.ii);
    }

    #[test]
    fn fused_portfolio_matches_sequential() {
        let cgra = StreamingCgra::paper_default();
        let bundle = tiny_bundle();
        let seq = map_bundle(&bundle, &cgra, &MapperOptions::fused().with_parallelism(1))
            .unwrap();
        let par = map_bundle(&bundle, &cgra, &MapperOptions::fused().with_parallelism(4))
            .unwrap();
        assert_eq!(seq.mapping.ii, par.mapping.ii);
        assert_eq!(seq.mapping.placements, par.mapping.placements);
        assert_eq!(seq.attempts, par.attempts);
        assert_eq!(seq.tags, par.tags);
    }

    #[test]
    fn width_resolution() {
        let mut o = MapperOptions::sparsemap();
        o.parallelism = 1;
        assert_eq!(o.width(32), 1);
        o.parallelism = 4;
        assert_eq!(o.width(32), 4);
        assert_eq!(o.width(2), 2, "width never exceeds the lattice");
        o.parallelism = 0;
        assert!(o.width(32) >= 1);
        assert!(o.width(32) <= 8);
    }
}
