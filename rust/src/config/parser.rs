//! TOML-subset parser: `[section]` headers, `key = value` lines, `#`
//! comments, values of type string (double-quoted), integer, float, bool.
//! No arrays/tables-of-tables — the config surface doesn't need them.

use crate::error::{Error, Result};

/// A parsed scalar value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
}

impl Value {
    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            other => Err(Error::Config(format!("expected string, got {other:?}"))),
        }
    }

    pub fn as_int(&self) -> Result<i64> {
        match self {
            Value::Int(i) => Ok(*i),
            other => Err(Error::Config(format!("expected integer, got {other:?}"))),
        }
    }

    pub fn as_float(&self) -> Result<f64> {
        match self {
            Value::Float(f) => Ok(*f),
            Value::Int(i) => Ok(*i as f64),
            other => Err(Error::Config(format!("expected float, got {other:?}"))),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Value::Bool(b) => Ok(*b),
            other => Err(Error::Config(format!("expected bool, got {other:?}"))),
        }
    }
}

/// Parsed file: ordered `(section, key, value)` triples.
#[derive(Clone, Debug, Default)]
pub struct ParsedConfig {
    entries: Vec<(String, String, Value)>,
}

impl ParsedConfig {
    pub fn parse(text: &str) -> Result<Self> {
        let mut section = String::new();
        let mut entries = Vec::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let Some(name) = rest.strip_suffix(']') else {
                    return Err(Error::Config(format!("line {}: unterminated section", lineno + 1)));
                };
                section = name.trim().to_string();
                if section.is_empty() {
                    return Err(Error::Config(format!("line {}: empty section name", lineno + 1)));
                }
                continue;
            }
            let Some(eq) = line.find('=') else {
                return Err(Error::Config(format!("line {}: expected 'key = value'", lineno + 1)));
            };
            let key = line[..eq].trim();
            let value = line[eq + 1..].trim();
            if key.is_empty() || value.is_empty() {
                return Err(Error::Config(format!("line {}: empty key or value", lineno + 1)));
            }
            entries.push((section.clone(), key.to_string(), parse_value(value, lineno + 1)?));
        }
        Ok(ParsedConfig { entries })
    }

    pub fn entries(&self) -> impl Iterator<Item = &(String, String, Value)> {
        self.entries.iter()
    }

    /// Look up a single key.
    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.entries
            .iter()
            .find(|(s, k, _)| s == section && k == key)
            .map(|(_, _, v)| v)
    }
}

fn strip_comment(line: &str) -> &str {
    // A '#' inside a double-quoted string is not a comment.
    let mut in_str = false;
    for (i, ch) in line.char_indices() {
        match ch {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str, lineno: usize) -> Result<Value> {
    if let Some(rest) = s.strip_prefix('"') {
        let Some(inner) = rest.strip_suffix('"') else {
            return Err(Error::Config(format!("line {lineno}: unterminated string")));
        };
        return Ok(Value::Str(inner.to_string()));
    }
    match s {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(Error::Config(format!("line {lineno}: cannot parse value '{s}'")))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalar_types() {
        let p = ParsedConfig::parse(
            "a = 1\nb = 2.5\nc = \"hi # there\"\nd = true\n[sec]\ne = false\n",
        )
        .unwrap();
        assert_eq!(p.get("", "a"), Some(&Value::Int(1)));
        assert_eq!(p.get("", "b"), Some(&Value::Float(2.5)));
        assert_eq!(p.get("", "c"), Some(&Value::Str("hi # there".into())));
        assert_eq!(p.get("", "d"), Some(&Value::Bool(true)));
        assert_eq!(p.get("sec", "e"), Some(&Value::Bool(false)));
    }

    #[test]
    fn comments_and_blank_lines() {
        let p = ParsedConfig::parse("# hello\n\nx = 3 # trailing\n").unwrap();
        assert_eq!(p.get("", "x"), Some(&Value::Int(3)));
    }

    #[test]
    fn errors_are_line_numbered() {
        let e = ParsedConfig::parse("x = 1\noops\n").unwrap_err();
        assert!(e.to_string().contains("line 2"), "{e}");
        let e = ParsedConfig::parse("[open\n").unwrap_err();
        assert!(e.to_string().contains("line 1"), "{e}");
        let e = ParsedConfig::parse("v = \"unterminated\n").unwrap_err();
        assert!(e.to_string().contains("line 1"), "{e}");
    }

    #[test]
    fn value_conversions() {
        assert_eq!(Value::Int(3).as_float().unwrap(), 3.0);
        assert!(Value::Int(3).as_str().is_err());
        assert!(Value::Str("x".into()).as_int().is_err());
        assert!(Value::Bool(true).as_bool().unwrap());
    }
}
