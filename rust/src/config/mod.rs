//! Configuration system: a TOML-subset parser (sections, string / int /
//! float / bool scalars, comments) plus the typed `SparsemapConfig` the
//! launcher consumes. serde/toml are unavailable offline, so the parser is
//! a substrate of this repo.

mod parser;

pub use parser::{ParsedConfig, Value};

use crate::arch::StreamingCgra;
use crate::error::{Error, Result};

/// Which scheduling pipeline to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SchedulerKind {
    /// The paper's contribution: AIBA + Mul-CI + RID-AT.
    SparseMap,
    /// Lifetime-sensitive modulo scheduling (Llosa [23]) as used by the
    /// BusMap [6] / Zhao [12] baselines.
    Baseline,
}

impl std::str::FromStr for SchedulerKind {
    type Err = Error;
    fn from_str(s: &str) -> Result<Self> {
        match s {
            "sparsemap" => Ok(SchedulerKind::SparseMap),
            "baseline" => Ok(SchedulerKind::Baseline),
            other => Err(Error::Config(format!("unknown scheduler '{other}'"))),
        }
    }
}

/// Which simulation backend the serving tier runs windows on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimBackend {
    /// Execute off a pre-compiled [`crate::sim::ExecPlan`] built once at
    /// mapping time (the default; bit-identical to the interpreter —
    /// `tests/sim_equivalence.rs` holds the two together).
    Compiled,
    /// The scalar lockstep interpreter
    /// ([`crate::sim::simulate_fused_batch`]) — the differential oracle,
    /// kept as the escape hatch.
    Interpreter,
}

impl std::str::FromStr for SimBackend {
    type Err = Error;
    fn from_str(s: &str) -> Result<Self> {
        match s {
            "compiled" => Ok(SimBackend::Compiled),
            "interpreter" => Ok(SimBackend::Interpreter),
            other => Err(Error::Config(format!(
                "unknown sim backend '{other}' (expected 'compiled' or 'interpreter')"
            ))),
        }
    }
}

impl SimBackend {
    /// Environment override honoured by the coordinator: CI runs the full
    /// suite once per backend by exporting this instead of patching every
    /// test's config.
    pub const ENV: &'static str = "SPARSEMAP_SIM_BACKEND";

    /// Resolve the effective backend: [`Self::ENV`] wins over the config
    /// knob when set; an unparsable value is ignored with a warning (the
    /// override is an operational escape hatch — it must never brick a
    /// coordinator that has a valid config).
    pub fn effective(configured: SimBackend) -> SimBackend {
        match std::env::var(Self::ENV) {
            Ok(raw) => match raw.parse::<SimBackend>() {
                Ok(b) => b,
                Err(_) => {
                    crate::log_warn!(
                        "ignoring {}='{raw}': expected 'compiled' or 'interpreter'",
                        Self::ENV
                    );
                    configured
                }
            },
            Err(_) => configured,
        }
    }
}

/// Environment override for `[coordinator] sim_lanes`, honoured at
/// coordinator construction like [`SimBackend::ENV`]: CI pins the scalar
/// plan sweep suite-wide by exporting `SPARSEMAP_SIM_LANES=1`.
pub const SIM_LANES_ENV: &str = "SPARSEMAP_SIM_LANES";

/// Whether `v` is a legal `[coordinator] sim_lanes` value: `0` (auto
/// width from the window size), `1` (the scalar plan sweep) or a
/// supported lane width.
pub fn valid_sim_lanes(v: usize) -> bool {
    matches!(v, 0 | 1 | 2 | 4 | 8)
}

/// Resolve the effective lane knob: [`SIM_LANES_ENV`] wins over the
/// config value when set; an unparsable or unsupported value is ignored
/// with a warning (warn-and-keep, mirroring [`SimBackend::effective`] —
/// an operational override must never brick a valid config).
pub fn effective_sim_lanes(configured: usize) -> usize {
    match std::env::var(SIM_LANES_ENV) {
        Ok(raw) => match raw.parse::<usize>() {
            Ok(v) if valid_sim_lanes(v) => v,
            _ => {
                crate::log_warn!(
                    "ignoring {SIM_LANES_ENV}='{raw}': expected 0 (auto), 1 (scalar) \
                     or a lane width in {{2, 4, 8}}"
                );
                configured
            }
        },
        Err(_) => configured,
    }
}

/// Ablation switches (Table 4): each of the paper's three techniques can be
/// disabled independently.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Techniques {
    pub aiba: bool,
    pub mul_ci: bool,
    pub rid_at: bool,
}

impl Techniques {
    pub fn all() -> Self {
        Techniques { aiba: true, mul_ci: true, rid_at: true }
    }

    pub fn aiba_only() -> Self {
        Techniques { aiba: true, mul_ci: false, rid_at: false }
    }

    pub fn aiba_mulci() -> Self {
        Techniques { aiba: true, mul_ci: true, rid_at: false }
    }
}

/// Full launcher configuration.
#[derive(Clone, Debug)]
pub struct SparsemapConfig {
    pub cgra: StreamingCgra,
    pub scheduler: SchedulerKind,
    pub techniques: Techniques,
    /// Give up when II exceeds `MII + ii_slack` (the paper's "Failed").
    pub ii_slack: usize,
    /// SBTS iteration budget per MIS solve.
    pub mis_iterations: usize,
    /// Portfolio width of the mapper's `(II, retry)` attempt lattice.
    /// `0` = auto (hardware parallelism), `1` = sequential; the mapping is
    /// identical for every value (deterministic portfolio).
    pub parallelism: usize,
    /// Artifacts directory for the PJRT runtime.
    pub artifacts_dir: String,
    /// Coordinator worker threads **per shard**.
    pub workers: usize,
    /// Coordinator bounded-queue depth (backpressure), per shard.
    pub queue_depth: usize,
    /// Worker-pool shards: independent fabric pools, each with its own
    /// queue, mapping cache, supervisor and poison registry. Registered
    /// blocks/bundles are pinned to shards by a deterministic
    /// demand-balancing assigner; ad-hoc traffic hashes onto a shard.
    /// Must be >= 1. The `SPARSEMAP_SHARDS` env var overrides this at
    /// coordinator construction (warn-and-keep on invalid values).
    pub shards: usize,
    /// Bound on requests riding open batching windows before the global
    /// dispatch layer force-seals the oldest open window. `0` = unbounded
    /// (windows wait for their seal triggers).
    pub dispatch_lookahead: usize,
    /// Warm-start manifest path: when non-empty, registrations persist
    /// their block/bundle fingerprints here and construction replays the
    /// file, pre-building every mapping through the normal single-flight
    /// cache path. Empty (the default) disables warm starts.
    pub warm_start_path: String,
    /// Coordinator mapping-cache capacity (entries). `0` = unbounded (the
    /// pre-LRU behavior); production serving should bound it.
    pub cache_capacity: usize,
    /// Fused request batching: a bundle's open window seals (and is
    /// dispatched as ONE lockstep simulation pass) once it holds this many
    /// member requests. `0` or `1` disables aggregation — every member
    /// request becomes its own window.
    pub batch_window_requests: usize,
    /// Cap on a window's lockstep iteration count (the maximum, over
    /// members, of the summed request stream lengths): a request that
    /// would push the window to the cap seals it first and opens a fresh
    /// window, bounding the zero-padding cost a short request pays for
    /// riding with long ones. `0` = uncapped.
    pub batch_window_max: usize,
    /// Worker-thread respawns the supervisor will perform over the
    /// coordinator's lifetime before letting the pool shrink (a hard panic
    /// that escapes the per-job `catch_unwind` kills the thread; the
    /// supervisor respawns it while budget remains). `0` = never respawn.
    pub restart_budget: usize,
    /// Panics tolerated for one job identity (block / bundle fingerprint)
    /// before it is quarantined and its requests resolve
    /// `ServeError::Poisoned` instead of being retried. Must be >= 1.
    pub poison_threshold: usize,
    /// Queue-occupancy high watermark for `try_enqueue`: at or above this
    /// many queued jobs, non-bundle singles are shed (`Overloaded`) even
    /// though the bounded queue still has room. `0` disables the watermark
    /// (only a full queue sheds).
    pub shed_watermark: usize,
    /// Retry-after budget for failed mapping-cache entries: a `Failed`
    /// entry fails the next `failure_ttl - 1` requests for its key fast,
    /// then the next request retries the build. `0` = sticky forever (the
    /// pre-failure-TTL behavior).
    pub failure_ttl: u64,
    /// Simulation backend workers serve windows on: `compiled` (default —
    /// a pre-compiled `ExecPlan` cached with the mapping) or
    /// `interpreter` (the scalar oracle, the escape hatch). The
    /// `SPARSEMAP_SIM_BACKEND` env var overrides this at coordinator
    /// construction.
    pub sim_backend: SimBackend,
    /// Lane width of the compiled backend's vectorized sweep: `0`
    /// (default) picks a width per window from its lockstep iteration
    /// count, `1` pins the scalar plan sweep, `2`/`4`/`8` force a fixed
    /// width. Ignored by the interpreter backend. The
    /// `SPARSEMAP_SIM_LANES` env var overrides this at coordinator
    /// construction (invalid values warn and keep the config).
    pub sim_lanes: usize,
    /// Maximum member blocks per fused bundle (`1` disables fusion).
    pub max_fused_blocks: usize,
    /// Combined-MII budget for the fusion planner.
    pub fusion_max_ii: usize,
    /// Seed for workload generation.
    pub seed: u64,
}

impl Default for SparsemapConfig {
    fn default() -> Self {
        SparsemapConfig {
            cgra: StreamingCgra::paper_default(),
            scheduler: SchedulerKind::SparseMap,
            techniques: Techniques::all(),
            ii_slack: 2,
            mis_iterations: 20_000,
            parallelism: 0,
            artifacts_dir: "artifacts".into(),
            workers: 4,
            queue_depth: 16,
            shards: 1,
            dispatch_lookahead: 0,
            warm_start_path: String::new(),
            cache_capacity: 0,
            batch_window_requests: 8,
            batch_window_max: 1024,
            restart_budget: 8,
            poison_threshold: 3,
            shed_watermark: 0,
            failure_ttl: 0,
            sim_backend: SimBackend::Compiled,
            sim_lanes: 0,
            max_fused_blocks: 4,
            fusion_max_ii: 12,
            seed: 42,
        }
    }
}

impl SparsemapConfig {
    /// Load from a TOML-subset file; unknown keys are rejected so typos
    /// fail loudly.
    pub fn from_file(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Self::from_str_cfg(&text)
    }

    pub fn from_str_cfg(text: &str) -> Result<Self> {
        let parsed = ParsedConfig::parse(text)?;
        let mut cfg = SparsemapConfig::default();
        for (section, key, value) in parsed.entries() {
            match (section.as_str(), key.as_str()) {
                ("cgra", "rows") => cfg.cgra.n = value.as_int()? as usize,
                ("cgra", "cols") => cfg.cgra.m = value.as_int()? as usize,
                ("cgra", "lrf_capacity") => cfg.cgra.lrf_capacity = value.as_int()? as usize,
                ("cgra", "grf_capacity") => cfg.cgra.grf_capacity = value.as_int()? as usize,
                ("cgra", "grf_write_ports") => {
                    cfg.cgra.grf_write_ports = value.as_int()? as usize
                }
                ("mapper", "scheduler") => cfg.scheduler = value.as_str()?.parse()?,
                ("mapper", "aiba") => cfg.techniques.aiba = value.as_bool()?,
                ("mapper", "mul_ci") => cfg.techniques.mul_ci = value.as_bool()?,
                ("mapper", "rid_at") => cfg.techniques.rid_at = value.as_bool()?,
                ("mapper", "ii_slack") => cfg.ii_slack = value.as_int()? as usize,
                ("mapper", "mis_iterations") => cfg.mis_iterations = value.as_int()? as usize,
                ("mapper", "parallelism") => cfg.parallelism = value.as_int()? as usize,
                ("mapper", "max_fused_blocks") => {
                    cfg.max_fused_blocks = value.as_int()? as usize
                }
                ("mapper", "fusion_max_ii") => cfg.fusion_max_ii = value.as_int()? as usize,
                ("runtime", "artifacts_dir") => cfg.artifacts_dir = value.as_str()?.to_string(),
                ("coordinator", "workers") => cfg.workers = value.as_int()? as usize,
                ("coordinator", "queue_depth") => cfg.queue_depth = value.as_int()? as usize,
                ("coordinator", "shards") => cfg.shards = value.as_int()? as usize,
                ("coordinator", "dispatch_lookahead") => {
                    cfg.dispatch_lookahead = value.as_int()? as usize
                }
                ("coordinator", "warm_start_path") => {
                    cfg.warm_start_path = value.as_str()?.to_string()
                }
                ("coordinator", "cache_capacity") => {
                    cfg.cache_capacity = value.as_int()? as usize
                }
                ("coordinator", "batch_window_requests") => {
                    cfg.batch_window_requests = value.as_int()? as usize
                }
                ("coordinator", "batch_window_max") => {
                    cfg.batch_window_max = value.as_int()? as usize
                }
                ("coordinator", "restart_budget") => {
                    cfg.restart_budget = value.as_int()? as usize
                }
                ("coordinator", "poison_threshold") => {
                    cfg.poison_threshold = value.as_int()? as usize
                }
                ("coordinator", "shed_watermark") => {
                    cfg.shed_watermark = value.as_int()? as usize
                }
                ("coordinator", "failure_ttl") => cfg.failure_ttl = value.as_int()? as u64,
                ("coordinator", "sim_backend") => {
                    cfg.sim_backend = value.as_str()?.parse()?
                }
                ("coordinator", "sim_lanes") => cfg.sim_lanes = value.as_int()? as usize,
                ("workload", "seed") => cfg.seed = value.as_int()? as u64,
                (s, k) => {
                    return Err(Error::Config(format!("unknown config key [{s}] {k}")));
                }
            }
        }
        if cfg.cgra.n == 0 || cfg.cgra.m == 0 {
            return Err(Error::Config("cgra geometry must be positive".into()));
        }
        if cfg.workers == 0 {
            return Err(Error::Config("coordinator.workers must be >= 1".into()));
        }
        if cfg.shards == 0 {
            return Err(Error::Config("coordinator.shards must be >= 1".into()));
        }
        if cfg.poison_threshold == 0 {
            return Err(Error::Config(
                "coordinator.poison_threshold must be >= 1".into(),
            ));
        }
        if cfg.max_fused_blocks == 0 {
            return Err(Error::Config(
                "mapper.max_fused_blocks must be >= 1 (1 disables fusion)".into(),
            ));
        }
        if !valid_sim_lanes(cfg.sim_lanes) {
            return Err(Error::Config(format!(
                "coordinator.sim_lanes must be 0 (auto), 1 (scalar) or a lane width \
                 in {{2, 4, 8}}, got {}",
                cfg.sim_lanes
            )));
        }
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_paper_setup() {
        let c = SparsemapConfig::default();
        assert_eq!(c.cgra, StreamingCgra::paper_default());
        assert_eq!(c.scheduler, SchedulerKind::SparseMap);
        assert!(c.techniques.aiba && c.techniques.mul_ci && c.techniques.rid_at);
    }

    #[test]
    fn parse_full_config() {
        let text = r#"
# paper setup
[cgra]
rows = 4
cols = 4
lrf_capacity = 8
grf_capacity = 8

[mapper]
scheduler = "baseline"
rid_at = false
ii_slack = 3
parallelism = 2

[coordinator]
workers = 2
queue_depth = 4
cache_capacity = 64

[workload]
seed = 7
"#;
        let c = SparsemapConfig::from_str_cfg(text).unwrap();
        assert_eq!(c.scheduler, SchedulerKind::Baseline);
        assert!(!c.techniques.rid_at);
        assert!(c.techniques.aiba);
        assert_eq!(c.ii_slack, 3);
        assert_eq!(c.parallelism, 2);
        assert_eq!(c.workers, 2);
        assert_eq!(c.cache_capacity, 64);
        assert_eq!(c.seed, 7);
    }

    #[test]
    fn batching_knobs_parse() {
        let c = SparsemapConfig::from_str_cfg(
            "[coordinator]\nbatch_window_requests = 3\nbatch_window_max = 64\n",
        )
        .unwrap();
        assert_eq!(c.batch_window_requests, 3);
        assert_eq!(c.batch_window_max, 64);
        // Defaults batch; 0/1 are the documented opt-outs, not errors.
        let d = SparsemapConfig::default();
        assert!(d.batch_window_requests > 1);
        assert!(d.batch_window_max > 0);
        assert!(SparsemapConfig::from_str_cfg(
            "[coordinator]\nbatch_window_requests = 0\nbatch_window_max = 0\n"
        )
        .is_ok());
    }

    #[test]
    fn fusion_knobs_parse_and_validate() {
        let c = SparsemapConfig::from_str_cfg(
            "[mapper]\nmax_fused_blocks = 3\nfusion_max_ii = 9\n",
        )
        .unwrap();
        assert_eq!(c.max_fused_blocks, 3);
        assert_eq!(c.fusion_max_ii, 9);
        // Defaults are fusion-ready, capacity unbounded.
        let d = SparsemapConfig::default();
        assert_eq!(d.cache_capacity, 0);
        assert!(d.max_fused_blocks >= 1);
        assert!(SparsemapConfig::from_str_cfg("[mapper]\nmax_fused_blocks = 0\n").is_err());
    }

    #[test]
    fn robustness_knobs_parse_and_validate() {
        let c = SparsemapConfig::from_str_cfg(
            "[coordinator]\nrestart_budget = 2\npoison_threshold = 1\n\
             shed_watermark = 12\nfailure_ttl = 5\n",
        )
        .unwrap();
        assert_eq!(c.restart_budget, 2);
        assert_eq!(c.poison_threshold, 1);
        assert_eq!(c.shed_watermark, 12);
        assert_eq!(c.failure_ttl, 5);
        // Defaults: sticky failures, no watermark — PR 5 behavior.
        let d = SparsemapConfig::default();
        assert_eq!(d.failure_ttl, 0);
        assert_eq!(d.shed_watermark, 0);
        assert!(d.poison_threshold >= 1);
        assert!(SparsemapConfig::from_str_cfg("[coordinator]\npoison_threshold = 0\n").is_err());
    }

    #[test]
    fn sharding_knobs_parse_and_validate() {
        let c = SparsemapConfig::from_str_cfg(
            "[coordinator]\nshards = 3\ndispatch_lookahead = 16\n\
             warm_start_path = \"/tmp/warm.manifest\"\n",
        )
        .unwrap();
        assert_eq!(c.shards, 3);
        assert_eq!(c.dispatch_lookahead, 16);
        assert_eq!(c.warm_start_path, "/tmp/warm.manifest");
        // Defaults: one shard, unbounded look-ahead, warm start off —
        // exactly the pre-sharding serving tier.
        let d = SparsemapConfig::default();
        assert_eq!(d.shards, 1);
        assert_eq!(d.dispatch_lookahead, 0);
        assert!(d.warm_start_path.is_empty());
        assert!(SparsemapConfig::from_str_cfg("[coordinator]\nshards = 0\n").is_err());
    }

    #[test]
    fn sim_backend_knob_parses_and_validates() {
        let c = SparsemapConfig::from_str_cfg("[coordinator]\nsim_backend = \"interpreter\"\n")
            .unwrap();
        assert_eq!(c.sim_backend, SimBackend::Interpreter);
        let c = SparsemapConfig::from_str_cfg("[coordinator]\nsim_backend = \"compiled\"\n")
            .unwrap();
        assert_eq!(c.sim_backend, SimBackend::Compiled);
        // Default is the compiled plan; the interpreter stays the oracle.
        assert_eq!(SparsemapConfig::default().sim_backend, SimBackend::Compiled);
        // Typos fail loudly, like every other knob.
        let err =
            SparsemapConfig::from_str_cfg("[coordinator]\nsim_backend = \"vectorized\"\n")
                .unwrap_err();
        assert!(err.to_string().contains("vectorized"), "{err}");
    }

    #[test]
    fn sim_lanes_knob_parses_and_validates() {
        for (text, want) in [
            ("[coordinator]\nsim_lanes = 0\n", 0usize),
            ("[coordinator]\nsim_lanes = 1\n", 1),
            ("[coordinator]\nsim_lanes = 4\n", 4),
            ("[coordinator]\nsim_lanes = 8\n", 8),
        ] {
            assert_eq!(SparsemapConfig::from_str_cfg(text).unwrap().sim_lanes, want);
        }
        // Default is auto width — the vectorized path on by default.
        assert_eq!(SparsemapConfig::default().sim_lanes, 0);
        // Unsupported widths fail loudly in a config file ...
        let err = SparsemapConfig::from_str_cfg("[coordinator]\nsim_lanes = 3\n").unwrap_err();
        assert!(err.to_string().contains("sim_lanes"), "{err}");
        // ... while the env override is warn-and-keep (exercised via the
        // helper directly — tests must not mutate process-global env, and
        // a CI leg may legitimately export the override suite-wide).
        if std::env::var(SIM_LANES_ENV).is_err() {
            assert_eq!(effective_sim_lanes(4), 4);
        }
        assert!(valid_sim_lanes(2));
        assert!(!valid_sim_lanes(16));
    }

    #[test]
    fn unknown_key_rejected() {
        let err = SparsemapConfig::from_str_cfg("[cgra]\nrowz = 4\n").unwrap_err();
        assert!(err.to_string().contains("rowz"));
    }

    #[test]
    fn bad_values_rejected() {
        assert!(SparsemapConfig::from_str_cfg("[cgra]\nrows = 0\ncols = 0\n").is_err());
        assert!(SparsemapConfig::from_str_cfg("[coordinator]\nworkers = 0\n").is_err());
        assert!(SparsemapConfig::from_str_cfg("[mapper]\nscheduler = \"magic\"\n").is_err());
    }
}
