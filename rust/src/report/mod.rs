//! Experiment reporting: regenerates every table of the paper's evaluation
//! (§5) — shared by the CLI, the benches and EXPERIMENTS.md.

use crate::arch::StreamingCgra;
use crate::config::Techniques;
use crate::mapper::{map_block, MapperOptions};
use crate::model::SparsityProfile;
use crate::sparse::gen::{paper_blocks, NamedBlock};
use crate::util::table::Table;

/// Table 2 — features of the evaluation blocks.
pub fn table2() -> Table {
    let mut t = Table::new(["blocks", "sparsity", "CnKm", "|V_OP|", "|V_R|", "|V_W|", "N_FG4"]);
    for nb in paper_blocks() {
        let f = nb.block.features();
        t.row([
            nb.label.to_string(),
            format!("{:.2}", f.sparsity),
            format!("C{}K{}", f.c, f.k),
            f.v_op.to_string(),
            f.v_r.to_string(),
            f.v_w.to_string(),
            f.n_fg4.to_string(),
        ]);
    }
    t
}

/// One Table-3 half-row (per scheduler).
#[derive(Clone, Debug)]
pub struct MappingRow {
    pub label: &'static str,
    pub mii: usize,
    pub ii0: Option<usize>,
    pub cops0: Option<usize>,
    pub mcids0: Option<usize>,
    pub success0: Option<bool>,
    pub final_ii: Option<usize>,
    pub speedup: Option<f64>,
}

/// Run one scheduler over every paper block.
pub fn mapping_rows(cgra: &StreamingCgra, opts: &MapperOptions) -> Vec<MappingRow> {
    paper_blocks()
        .iter()
        .map(|nb| mapping_row(nb, cgra, opts))
        .collect()
}

fn mapping_row(nb: &NamedBlock, cgra: &StreamingCgra, opts: &MapperOptions) -> MappingRow {
    let (g, _) = crate::dfg::build::build_sdfg(&nb.block);
    let mii = crate::dfg::analysis::mii(&g, cgra);
    match map_block(&nb.block, cgra, opts) {
        Ok(out) => MappingRow {
            label: nb.label,
            mii,
            ii0: Some(out.first_attempt.ii0),
            cops0: Some(out.first_attempt.cops),
            mcids0: Some(out.first_attempt.mcids),
            success0: Some(out.first_attempt.success),
            final_ii: Some(out.mapping.ii),
            speedup: Some(out.speedup(&nb.block, cgra)),
        },
        Err(e) => {
            // Recover the first-attempt statistics from the error message
            // is fragile; recompute them directly instead.
            let first = first_attempt_stats(nb, cgra, opts);
            crate::log_debug!("{}: mapping failed: {e}", nb.label);
            MappingRow {
                label: nb.label,
                mii,
                ii0: first.map(|f| f.0),
                cops0: first.map(|f| f.1),
                mcids0: first.map(|f| f.2),
                success0: Some(false),
                final_ii: None,
                speedup: None,
            }
        }
    }
}

/// First scheduling attempt's (II0, cops, mcids) even when mapping fails.
fn first_attempt_stats(
    nb: &NamedBlock,
    cgra: &StreamingCgra,
    opts: &MapperOptions,
) -> Option<(usize, usize, usize)> {
    let (g, _) = crate::dfg::build::build_sdfg(&nb.block);
    let base = crate::dfg::analysis::mii(&g, cgra);
    for ii in base..=base + opts.ii_slack {
        let s = match opts.scheduler {
            crate::config::SchedulerKind::SparseMap => {
                crate::sched::sparsemap::schedule_at(&g, cgra, opts.techniques, ii).ok()
            }
            crate::config::SchedulerKind::Baseline => {
                crate::sched::baseline::schedule_at(&g, cgra, ii).ok()
            }
        };
        if let Some(s) = s {
            return Some((ii, s.cops(), s.mcids().len()));
        }
    }
    None
}

/// Table 3 — mapping comparison, baselines [6][12] vs SparseMap.
pub fn table3(cgra: &StreamingCgra) -> (Table, Vec<MappingRow>, Vec<MappingRow>) {
    let base_rows = mapping_rows(cgra, &MapperOptions::baseline());
    let sm_rows = mapping_rows(cgra, &MapperOptions::sparsemap());
    let mut t = Table::new([
        "block", "MII", "B:II0", "B:|C|", "B:|M|", "B:ok?", "B:II", "B:S",
        "S:II0", "S:|C|", "S:|M|", "S:ok?", "S:II", "S:S",
    ]);
    let fmt_opt = |o: Option<usize>| o.map_or("-".into(), |v| v.to_string());
    let fmt_ok = |o: Option<bool>| o.map_or("-".into(), |v| if v { "Y".into() } else { "N".to_string() });
    let fmt_ii = |o: Option<usize>| o.map_or("Failed".into(), |v| v.to_string());
    let fmt_s = |o: Option<f64>| o.map_or("-".into(), |v| format!("{v:.2}"));
    for (b, s) in base_rows.iter().zip(&sm_rows) {
        t.row([
            b.label.to_string(),
            b.mii.to_string(),
            fmt_opt(b.ii0),
            fmt_opt(b.cops0),
            fmt_opt(b.mcids0),
            fmt_ok(b.success0),
            fmt_ii(b.final_ii),
            fmt_s(b.speedup),
            fmt_opt(s.ii0),
            fmt_opt(s.cops0),
            fmt_opt(s.mcids0),
            fmt_ok(s.success0),
            fmt_ii(s.final_ii),
            fmt_s(s.speedup),
        ]);
    }
    (t, base_rows, sm_rows)
}

/// Totals row helper for Table 3 (the paper's ↓92.5 % / ↓46.0 % line).
pub fn totals(rows: &[MappingRow]) -> (usize, usize) {
    (
        rows.iter().filter_map(|r| r.cops0).sum(),
        rows.iter().filter_map(|r| r.mcids0).sum(),
    )
}

/// Table 4 — ablation: AIBA / +Mul-CI / +RID-AT.
pub fn table4(cgra: &StreamingCgra) -> (Table, Vec<Vec<MappingRow>>) {
    let combos: [(&str, Techniques); 3] = [
        ("AIBA", Techniques::aiba_only()),
        ("AIBA+Mul-CI", Techniques::aiba_mulci()),
        ("AIBA+Mul-CI+RID-AT", Techniques::all()),
    ];
    let mut all_rows = Vec::new();
    let mut t = Table::new([
        "block",
        "A:II0", "A:|C|", "A:|M|", "A:II",
        "AM:II0", "AM:|C|", "AM:|M|", "AM:II",
        "AMR:II0", "AMR:|C|", "AMR:|M|", "AMR:II",
    ]);
    for (_, tech) in &combos {
        let opts = MapperOptions::sparsemap().with_techniques(*tech);
        all_rows.push(mapping_rows(cgra, &opts));
    }
    let fmt_opt = |o: Option<usize>| o.map_or("-".to_string(), |v| v.to_string());
    let fmt_ii = |o: Option<usize>| o.map_or("Failed".to_string(), |v| v.to_string());
    for i in 0..all_rows[0].len() {
        let mut cells = vec![all_rows[0][i].label.to_string()];
        for rows in &all_rows {
            let r = &rows[i];
            cells.push(fmt_opt(r.ii0));
            cells.push(fmt_opt(r.cops0));
            cells.push(fmt_opt(r.mcids0));
            cells.push(fmt_ii(r.final_ii));
        }
        t.row(cells);
    }
    (t, all_rows)
}

/// Per-layer sparsity characterization table (the `cli ingest` report):
/// shape, nonzeros, overall sparsity, and the channel-fanout / kernel-size
/// spreads that predict how well each layer's tiles map.
pub fn sparsity_table(profiles: &[SparsityProfile]) -> Table {
    let mut t = Table::new([
        "layer", "CxK", "nnz", "sparsity", "fanout(min/med/max)", "kernel(min/med/max)",
    ]);
    for p in profiles {
        let (fmin, fmed, fmax) = p.fanout_spread();
        let (kmin, kmed, kmax) = p.kernel_spread();
        t.row([
            p.name.clone(),
            format!("{}x{}", p.c_total, p.k_total),
            p.nonzeros.to_string(),
            format!("{:.3}", p.sparsity),
            format!("{fmin}/{fmed}/{fmax}"),
            format!("{kmin}/{kmed}/{kmax}"),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_matches_paper() {
        let t = table2();
        let s = t.render();
        // Spot-check the exact published feature rows.
        assert!(s.contains("block1") && s.contains("C4K6"), "{s}");
        assert!(s.contains("block5") && s.contains("C8K8"));
        assert_eq!(t.num_rows(), 7);
    }

    #[test]
    fn sparsity_table_renders_every_layer() {
        let net = crate::model::vgg_head();
        let profiles = crate::model::profile_network(&net);
        let t = sparsity_table(&profiles);
        assert_eq!(t.num_rows(), net.layers.len());
        let s = t.render();
        assert!(s.contains("conv1_1") && s.contains("3x64"), "{s}");
        assert!(s.contains("conv2_2") && s.contains("128x128"), "{s}");
    }

    #[test]
    fn table3_shape_holds() {
        // The paper's headline: SparseMap reduces COPs ≥ 4× and MCIDs vs
        // the baselines, and maps blocks the baselines cannot.
        let cgra = StreamingCgra::paper_default();
        let (_, base_rows, sm_rows) = table3(&cgra);
        let sm_success = sm_rows.iter().filter(|r| r.final_ii.is_some()).count();
        let base_success = base_rows.iter().filter(|r| r.final_ii.is_some()).count();
        assert_eq!(sm_success, 7, "SparseMap must map all blocks");
        assert!(base_success < 7, "baseline must fail at least one block");
        let (bc, bm) = totals(&base_rows);
        let (sc, sm) = totals(&sm_rows);
        assert!(sc * 4 <= bc, "COPs: {sc} vs {bc}");
        assert!(sm < bm, "MCIDs: {sm} vs {bm}");
        // SparseMap's final II never exceeds the baseline's.
        for (b, s) in base_rows.iter().zip(&sm_rows) {
            if let (Some(bi), Some(si)) = (b.final_ii, s.final_ii) {
                assert!(si <= bi, "{}: {si} vs {bi}", s.label);
            }
        }
    }
}
