//! Time-extended CGRA (TEC, §3.1 def. 4): the streaming CGRA replicated
//! over `0..II` modulo time layers, with directed edges from each resource
//! at layer `t` to the connected resources at layer `(t + 1) % II`.
//!
//! The binder does not materialize TEC edges as an explicit graph — the
//! conflict rules consult [`TimeExtendedCgra::connects`] — but the type also
//! exposes the explicit edge list for tests and for the paper-faithful
//! definition.

use crate::arch::{PeId, StreamingCgra};

/// A resource node within one time layer of the TEC.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Resource {
    Pe(PeId),
    /// Input bus `j` (feeds column `j`).
    InputBus(usize),
    /// Output bus `i` (drains row `i`).
    OutputBus(usize),
    /// The shared global register file.
    Grf,
}

/// A resource replicated at a modulo time layer (`v^m` in the paper).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct TecNode {
    pub resource: Resource,
    pub layer: usize,
}

/// The time-extended CGRA.
#[derive(Clone, Debug)]
pub struct TimeExtendedCgra {
    pub cgra: StreamingCgra,
    pub ii: usize,
}

impl TimeExtendedCgra {
    pub fn new(cgra: StreamingCgra, ii: usize) -> Self {
        assert!(ii >= 1, "II must be >= 1");
        TimeExtendedCgra { cgra, ii }
    }

    /// Successor layer with wraparound (`m2 = m1 + 1`, or `0` from `II-1`).
    pub fn next_layer(&self, layer: usize) -> usize {
        (layer + 1) % self.ii
    }

    /// All resource nodes at one layer.
    pub fn layer_nodes(&self, layer: usize) -> Vec<TecNode> {
        assert!(layer < self.ii);
        let mut v: Vec<TecNode> = Vec::new();
        for pe in self.cgra.pes() {
            v.push(TecNode { resource: Resource::Pe(pe), layer });
        }
        for j in 0..self.cgra.m {
            v.push(TecNode { resource: Resource::InputBus(j), layer });
        }
        for i in 0..self.cgra.n {
            v.push(TecNode { resource: Resource::OutputBus(i), layer });
        }
        v.push(TecNode { resource: Resource::Grf, layer });
        v
    }

    /// Total node count (`(N·M + M + N + 1) · II`).
    pub fn num_nodes(&self) -> usize {
        (self.cgra.num_pes() + self.cgra.m + self.cgra.n + 1) * self.ii
    }

    /// Whether data produced on `from.resource` during `from.layer` can be
    /// consumed on `to.resource` during `to.layer` (single-hop, one cycle):
    /// `to.layer` must be the wraparound successor of `from.layer`, and the
    /// physical resources must be connected:
    /// * input bus `j` → PEs of column `j` (operand delivery);
    /// * PE → PE in the same row or column (internal bus hop);
    /// * PE (row `i`) → output bus `i` (result write-out);
    /// * PE ↔ GRF via the crossbar (MCID routing);
    /// * PE → same PE (value held in its LRF).
    pub fn connects(&self, from: TecNode, to: TecNode) -> bool {
        if to.layer != self.next_layer(from.layer) {
            return false;
        }
        match (from.resource, to.resource) {
            (Resource::InputBus(j), Resource::Pe(pe)) => pe.col == j,
            (Resource::Pe(a), Resource::Pe(b)) => self.cgra.bus_reachable(a, b),
            (Resource::Pe(pe), Resource::OutputBus(i)) => pe.row == i,
            (Resource::Pe(_), Resource::Grf) => true,
            (Resource::Grf, Resource::Pe(_)) => true,
            _ => false,
        }
    }

    /// Explicit directed edge list (paper-faithful `E_T`; tests only — the
    /// hot path uses [`Self::connects`]).
    pub fn edges(&self) -> Vec<(TecNode, TecNode)> {
        let mut out = Vec::new();
        for layer in 0..self.ii {
            let next = self.next_layer(layer);
            for a in self.layer_nodes(layer) {
                for b in self.layer_nodes(next) {
                    if self.connects(a, b) {
                        out.push((a, b));
                    }
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tec(ii: usize) -> TimeExtendedCgra {
        TimeExtendedCgra::new(StreamingCgra::paper_default(), ii)
    }

    #[test]
    fn layer_wraparound() {
        let t = tec(3);
        assert_eq!(t.next_layer(0), 1);
        assert_eq!(t.next_layer(2), 0);
    }

    #[test]
    fn node_count() {
        let t = tec(2);
        assert_eq!(t.num_nodes(), (16 + 4 + 4 + 1) * 2);
        assert_eq!(t.layer_nodes(0).len(), 25);
    }

    #[test]
    fn connectivity_rules() {
        let t = tec(2);
        let pe12 = TecNode { resource: Resource::Pe(PeId { row: 1, col: 2 }), layer: 0 };
        let pe32 = TecNode { resource: Resource::Pe(PeId { row: 3, col: 2 }), layer: 1 };
        let pe00 = TecNode { resource: Resource::Pe(PeId { row: 0, col: 0 }), layer: 1 };
        assert!(t.connects(pe12, pe32), "same column, next layer");
        assert!(!t.connects(pe12, pe00), "diagonal unreachable in one hop");

        let ib2 = TecNode { resource: Resource::InputBus(2), layer: 0 };
        assert!(t.connects(ib2, TecNode { resource: Resource::Pe(PeId { row: 0, col: 2 }), layer: 1 }));
        assert!(!t.connects(ib2, TecNode { resource: Resource::Pe(PeId { row: 0, col: 1 }), layer: 1 }));

        let ob1 = TecNode { resource: Resource::OutputBus(1), layer: 1 };
        assert!(t.connects(TecNode { resource: Resource::Pe(PeId { row: 1, col: 3 }), layer: 0 }, ob1));
        assert!(!t.connects(TecNode { resource: Resource::Pe(PeId { row: 2, col: 3 }), layer: 0 }, ob1));

        // Same layer never connects.
        assert!(!t.connects(pe12, TecNode { resource: Resource::Pe(PeId { row: 3, col: 2 }), layer: 0 }));
    }

    #[test]
    fn edges_match_connects() {
        let t = tec(2);
        let edges = t.edges();
        assert!(!edges.is_empty());
        assert!(edges.iter().all(|&(a, b)| t.connects(a, b)));
        // Every PE reaches the GRF each layer: 16 PEs * 2 layers edges to GRF.
        let grf_in = edges
            .iter()
            .filter(|(_, b)| matches!(b.resource, Resource::Grf))
            .count();
        assert_eq!(grf_in, 32);
    }
}
