//! Streaming CGRA architecture model (paper §1, Fig. 1).
//!
//! The fabric is an `N × M` PE array (PEA) fed by `M` **input buses** (one
//! per column — a bus fans out to the `N` PEs of its column) and drained by
//! `N` **output buses** (one per row), a crossbar between the data memories
//! and the input buses (which provides the multi-cast used by Mul-CI), a
//! shared global register file (GRF) and per-PE local register files (LRF).
//! PEs have **no load/store units**: all I/O data arrives on buses at
//! compiler-chosen times, which is exactly why the mapper must manage I/O
//! data explicitly (COPs / MCIDs).
//!
//! The same row/column buses carry internal (PE→PE) traffic, so I/O
//! allocation and internal routing contend — conflict rule R2(2) in §4.2.

pub mod tec;

pub use tec::TimeExtendedCgra;

/// A PE coordinate: row `i` in `0..n`, column `j` in `0..m`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct PeId {
    pub row: usize,
    pub col: usize,
}

impl std::fmt::Display for PeId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "pe({},{})", self.row, self.col)
    }
}

/// Streaming CGRA configuration (the paper evaluates N = M = 4, LRF 8,
/// GRF 8).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct StreamingCgra {
    /// PEA rows == number of output buses (`N` in the paper).
    pub n: usize,
    /// PEA columns == number of input buses (`M` in the paper).
    pub m: usize,
    /// Per-PE local register file capacity.
    pub lrf_capacity: usize,
    /// Global register file capacity (shared, crossbar-reachable).
    pub grf_capacity: usize,
    /// GRF write ports per cycle. The paper's Fig. 3 discussion ("routing
    /// via GRF ... is able for 1 MCID at most" per modulo slot) pins this
    /// to 1.
    pub grf_write_ports: usize,
}

impl StreamingCgra {
    /// The paper's evaluation target: 4×4 PEA, LRF 8, GRF 8.
    pub fn paper_default() -> Self {
        StreamingCgra { n: 4, m: 4, lrf_capacity: 8, grf_capacity: 8, grf_write_ports: 1 }
    }

    /// Custom geometry (used by tests and the config system).
    pub fn new(n: usize, m: usize, lrf: usize, grf: usize) -> Self {
        assert!(n > 0 && m > 0, "degenerate PEA");
        StreamingCgra { n, m, lrf_capacity: lrf, grf_capacity: grf, grf_write_ports: 1 }
    }

    /// Total PEs (`N × M` — the per-modulo-slot operation capacity).
    pub fn num_pes(&self) -> usize {
        self.n * self.m
    }

    /// Number of input buses (`M`).
    pub fn num_input_buses(&self) -> usize {
        self.m
    }

    /// Number of output buses (`N`).
    pub fn num_output_buses(&self) -> usize {
        self.n
    }

    /// PEs directly reachable from one input bus (its column): `N`.
    pub fn input_bus_fanout(&self) -> usize {
        self.n
    }

    /// Iterate all PE coordinates row-major.
    pub fn pes(&self) -> impl Iterator<Item = PeId> + '_ {
        (0..self.n).flat_map(move |row| (0..self.m).map(move |col| PeId { row, col }))
    }

    /// Flat index of a PE (row-major), for table lookups.
    pub fn pe_index(&self, pe: PeId) -> usize {
        debug_assert!(pe.row < self.n && pe.col < self.m);
        pe.row * self.m + pe.col
    }

    /// Inverse of [`Self::pe_index`].
    pub fn pe_at(&self, idx: usize) -> PeId {
        debug_assert!(idx < self.num_pes());
        PeId { row: idx / self.m, col: idx % self.m }
    }

    /// PEs fed by input bus `ibus` (the whole column).
    pub fn input_bus_pes(&self, ibus: usize) -> impl Iterator<Item = PeId> + '_ {
        debug_assert!(ibus < self.m);
        (0..self.n).map(move |row| PeId { row, col: ibus })
    }

    /// PEs drained by output bus `obus` (the whole row).
    pub fn output_bus_pes(&self, obus: usize) -> impl Iterator<Item = PeId> + '_ {
        debug_assert!(obus < self.n);
        (0..self.m).map(move |col| PeId { row: obus, col })
    }

    /// Whether two PEs can exchange a value over one bus hop (same row or
    /// same column).
    pub fn bus_reachable(&self, a: PeId, b: PeId) -> bool {
        a.row == b.row || a.col == b.col
    }

    /// Whether two PEs are mesh neighbours (dedicated point-to-point link,
    /// no contention — the classic CGRA nearest-neighbour interconnect that
    /// BusMap's row/column buses augment).
    pub fn mesh_adjacent(&self, a: PeId, b: PeId) -> bool {
        let dr = a.row.abs_diff(b.row);
        let dc = a.col.abs_diff(b.col);
        dr + dc == 1
    }

    /// Minimum initiation interval for an s-DFG with the given node counts
    /// (§4.1): `max(⌈|V_OP|/(N·M)⌉, ⌈|V_R|/M⌉, ⌈|V_W|/N⌉)`.
    pub fn mii(&self, n_ops: usize, n_reads: usize, n_writes: usize) -> usize {
        let by_pe = n_ops.div_ceil(self.num_pes());
        let by_in = n_reads.div_ceil(self.m);
        let by_out = n_writes.div_ceil(self.n);
        by_pe.max(by_in).max(by_out).max(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_default_geometry() {
        let c = StreamingCgra::paper_default();
        assert_eq!(c.num_pes(), 16);
        assert_eq!(c.num_input_buses(), 4);
        assert_eq!(c.num_output_buses(), 4);
        assert_eq!(c.input_bus_fanout(), 4);
        assert_eq!(c.lrf_capacity, 8);
        assert_eq!(c.grf_capacity, 8);
    }

    #[test]
    fn pe_index_roundtrip() {
        let c = StreamingCgra::new(3, 5, 8, 8);
        for (i, pe) in c.pes().enumerate() {
            assert_eq!(c.pe_index(pe), i);
            assert_eq!(c.pe_at(i), pe);
        }
        assert_eq!(c.pes().count(), 15);
    }

    #[test]
    fn bus_topology() {
        let c = StreamingCgra::paper_default();
        let col2: Vec<PeId> = c.input_bus_pes(2).collect();
        assert_eq!(col2.len(), 4);
        assert!(col2.iter().all(|pe| pe.col == 2));
        let row1: Vec<PeId> = c.output_bus_pes(1).collect();
        assert_eq!(row1.len(), 4);
        assert!(row1.iter().all(|pe| pe.row == 1));
    }

    #[test]
    fn reachability_is_row_or_col() {
        let c = StreamingCgra::paper_default();
        let a = PeId { row: 1, col: 2 };
        assert!(c.bus_reachable(a, PeId { row: 1, col: 0 }));
        assert!(c.bus_reachable(a, PeId { row: 3, col: 2 }));
        assert!(!c.bus_reachable(a, PeId { row: 0, col: 0 }));
        assert!(c.bus_reachable(a, a));
    }

    #[test]
    fn mii_matches_paper_blocks() {
        // Table 2 + §4.1 formula: block1 (26,4,6) → 2 … block7 (58,8,8) → 4.
        let c = StreamingCgra::paper_default();
        assert_eq!(c.mii(26, 4, 6), 2);
        assert_eq!(c.mii(26, 4, 6), 2);
        assert_eq!(c.mii(36, 6, 6), 3);
        assert_eq!(c.mii(32, 4, 6), 2);
        assert_eq!(c.mii(58, 8, 8), 4);
        assert_eq!(c.mii(40, 8, 8), 3);
        assert_eq!(c.mii(58, 8, 8), 4);
    }

    #[test]
    fn mii_never_zero() {
        let c = StreamingCgra::paper_default();
        assert_eq!(c.mii(0, 0, 0), 1);
    }
}
