//! Command-line interface (clap is unavailable offline; the parser is a
//! substrate of this repo).
//!
//! ```text
//! sparsemap <command> [--key value] ...
//!
//! commands:
//!   table2                      print Table 2 (block features)
//!   table3                      print Table 3 (mapping comparison)
//!   table4                      print Table 4 (ablation)
//!   map        --block <name>   map one paper block and print the result
//!   simulate   --block <name>   map + simulate + verify one block
//!   serve      --requests <n>   run the streaming coordinator demo
//!              --fuse <0|1>     register fused bundles (batching windows)
//!              --model <m>      serve a whole network end to end
//!   ingest     --dump <path>    load a pruned-model dump, print sparsity
//!              --preset <name>  …or characterize a preset network
//!   artifacts                   list AOT artifacts and smoke-run one
//! common flags:
//!   --config <path>             TOML-subset config file
//!   --scheduler <sparsemap|baseline>
//!   --iters <n>                 simulation iterations (default 64)
//!   --seed <n>
//! ```

use std::collections::HashMap;

use crate::config::SparsemapConfig;
use crate::coordinator::Coordinator;
use crate::error::{Error, Result};
use crate::mapper::{map_block, MapperOptions};
use crate::report;
use crate::sim::simulate_and_check;
use crate::sparse::gen::paper_blocks;

/// Parsed command line: a command plus `--key value` flags.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub command: String,
    flags: HashMap<String, String>,
}

impl Args {
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args> {
        let mut it = argv.into_iter();
        let command = it.next().unwrap_or_default();
        let mut flags = HashMap::new();
        while let Some(arg) = it.next() {
            let Some(key) = arg.strip_prefix("--") else {
                return Err(Error::Config(format!("unexpected positional argument '{arg}'")));
            };
            let value = it
                .next()
                .ok_or_else(|| Error::Config(format!("flag --{key} needs a value")))?;
            flags.insert(key.to_string(), value);
        }
        Ok(Args { command, flags })
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Config(format!("--{key} expects an integer, got '{v}'"))),
        }
    }
}

fn load_config(args: &Args) -> Result<SparsemapConfig> {
    let mut cfg = match args.get("config") {
        Some(path) => SparsemapConfig::from_file(path)?,
        None => SparsemapConfig::default(),
    };
    if let Some(s) = args.get("scheduler") {
        cfg.scheduler = s.parse()?;
    }
    if let Some(s) = args.get("seed") {
        cfg.seed = s
            .parse()
            .map_err(|_| Error::Config(format!("--seed expects an integer, got '{s}'")))?;
    }
    Ok(cfg)
}

fn find_block(name: &str) -> Result<crate::sparse::SparseBlock> {
    paper_blocks()
        .into_iter()
        .find(|nb| nb.label == name)
        .map(|nb| nb.block)
        .ok_or_else(|| {
            Error::Config(format!(
                "unknown block '{name}' (try block1..block7)"
            ))
        })
}

/// CLI entrypoint; returns the process exit code.
pub fn run<I: IntoIterator<Item = String>>(argv: I) -> i32 {
    crate::util::logging::init();
    let args = match Args::parse(argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            return 2;
        }
    };
    match dispatch(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    }
}

fn dispatch(args: &Args) -> Result<()> {
    match args.command.as_str() {
        "table2" => {
            println!("{}", report::table2());
            Ok(())
        }
        "table3" => cmd_table3(args),
        "table4" => cmd_table4(args),
        "map" => cmd_map(args),
        "simulate" => cmd_simulate(args),
        "serve" => cmd_serve(args),
        "ingest" => cmd_ingest(args),
        "artifacts" => cmd_artifacts(args),
        "" | "help" | "--help" | "-h" => {
            print!("{}", USAGE);
            Ok(())
        }
        other => Err(Error::Config(format!("unknown command '{other}' (try 'help')"))),
    }
}

const USAGE: &str = "\
sparsemap — loop mapping for sparse CNNs on streaming CGRAs

usage: sparsemap <command> [--key value]...

commands:
  table2                     block features (paper Table 2)
  table3                     mapping comparison (paper Table 3)
  table4                     technique ablation (paper Table 4)
  map      --block blockN    map one block, print II/COPs/MCIDs
  simulate --block blockN    map + cycle-accurate simulate + verify
  serve    --requests N      streaming coordinator demo
  ingest   --dump path       load a pruned-model dump, print per-layer sparsity
  artifacts                  list + smoke-run the AOT artifacts
flags:
  --config path  --scheduler sparsemap|baseline  --iters N  --seed N
  --shards N   (serve) worker-pool shards, overrides [coordinator] shards
  --model m    (serve) serve a network end to end: a preset name
               (vgg_head|resnet_tail) or a dump path
  --preset m   (ingest) characterize a preset instead of a dump
  --out path   (ingest) write the ingested network back out as a dump
";

/// Resolve a `--model` / `--preset` spec: a preset name, or (for
/// `--model`/`--dump`) a dump-file path.
fn resolve_network(spec: &str, allow_path: bool) -> Result<crate::model::NetworkGraph> {
    match spec {
        "vgg_head" => Ok(crate::model::vgg_head()),
        "resnet_tail" => Ok(crate::model::resnet_tail()),
        other if allow_path => {
            let dump = crate::model::load_dump_file(other)?;
            crate::model::NetworkGraph::from_layers(&dump.name, dump.layers)
        }
        other => Err(Error::Config(format!(
            "unknown preset '{other}' (try vgg_head|resnet_tail)"
        ))),
    }
}

fn cmd_ingest(args: &Args) -> Result<()> {
    let net = match (args.get("dump"), args.get("preset")) {
        (Some(path), None) => resolve_network(path, true)?,
        (None, Some(preset)) => resolve_network(preset, false)?,
        _ => {
            return Err(Error::Config(
                "ingest needs exactly one of --dump <path> or --preset <name>".into(),
            ))
        }
    };
    println!(
        "network {}: {} layer(s), {} partitioned block(s), {} channels in -> {} kernels out",
        net.name,
        net.layers.len(),
        net.block_count(),
        net.input_width(),
        net.output_width(),
    );
    println!("{}", report::sparsity_table(&crate::model::profile_network(&net)));
    if let Some(out) = args.get("out") {
        let layers: Vec<_> = net.layers.iter().map(|nl| nl.layer.clone()).collect();
        crate::model::write_dump_file(out, &net.name, &layers)?;
        println!("wrote dump to {out}");
    }
    Ok(())
}

fn cmd_table3(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let (t, base_rows, sm_rows) = report::table3(&cfg.cgra);
    println!("{t}");
    let (bc, bm) = report::totals(&base_rows);
    let (sc, sm) = report::totals(&sm_rows);
    println!(
        "\nTotals (first attempts): baseline |C|={bc} |M|={bm}  sparsemap |C|={sc} |M|={sm}  \
         (COPs ↓{:.1}%, MCIDs ↓{:.1}%)",
        100.0 * (1.0 - sc as f64 / bc.max(1) as f64),
        100.0 * (1.0 - sm as f64 / bm.max(1) as f64),
    );
    Ok(())
}

fn cmd_table4(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let (t, _) = report::table4(&cfg.cgra);
    println!("{t}");
    Ok(())
}

fn cmd_map(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let block = find_block(args.get("block").unwrap_or("block1"))?;
    let opts = MapperOptions::from_config(&cfg);
    let out = map_block(&block, &cfg.cgra, &opts)?;
    println!(
        "{}: MII={} first(II0={} C={} M={} ok={}) final II={} C={} M={} speedup={:.2} \
         attempts={} mis_iters={}",
        block.name,
        out.mii,
        out.first_attempt.ii0,
        out.first_attempt.cops,
        out.first_attempt.mcids,
        out.first_attempt.success,
        out.mapping.ii,
        out.mapping.cops(),
        out.mapping.mcids(),
        out.speedup(&block, &cfg.cgra),
        out.attempts.len(),
        out.mapping.mis_iterations,
    );
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let block = find_block(args.get("block").unwrap_or("block1"))?;
    let iters = args.get_usize("iters", 64)?;
    let opts = MapperOptions::from_config(&cfg);
    let out = map_block(&block, &cfg.cgra, &opts)?;
    let res = simulate_and_check(&out.mapping, &block, &cfg.cgra, iters, cfg.seed)?;
    println!(
        "{}: II={} iterations={} cycles={} throughput={:.4} it/cycle \
         (1/II={:.4}) PE-util={:.1}% lrf_peak={} grf_peak={} — outputs verified ✓",
        block.name,
        out.mapping.ii,
        res.iterations,
        res.cycles,
        res.throughput(),
        1.0 / out.mapping.ii as f64,
        100.0 * res.pe_utilization(),
        res.lrf_peak,
        res.grf_peak,
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let mut cfg = load_config(args)?;
    let n = args.get_usize("requests", 32)?;
    let iters = args.get_usize("iters", 16)?;
    let fuse = args.get_usize("fuse", 0)? != 0;
    let model = args.get("model").map(|m| resolve_network(m, true)).transpose()?;
    if model.is_some() {
        // Network layers with k >= 96 tile into the wide-block class,
        // which needs the wide operating point's II slack to map.
        cfg.ii_slack = cfg.ii_slack.max(MapperOptions::wide().ii_slack);
    }
    // --shards pins the topology explicitly (over both the config knob
    // and SPARSEMAP_SHARDS); without it Coordinator::new resolves those.
    let coord = match args.get_usize("shards", 0)? {
        0 => Coordinator::new(&cfg),
        n => Coordinator::with_shard_count(&cfg, n),
    };
    // Startup banner: the resolved serving stack (knobs + env overrides
    // applied), so a silently overridden backend or lane width is visible
    // before the first request.
    let backend = match coord.sim_backend() {
        crate::config::SimBackend::Compiled => "compiled",
        crate::config::SimBackend::Interpreter => "interpreter",
    };
    let lanes = match coord.sim_lanes() {
        0 => "auto".to_string(),
        1 => "scalar".to_string(),
        w => w.to_string(),
    };
    println!(
        "serving on {} shard(s) × {} worker(s), sim backend {backend}, lanes {lanes}",
        coord.shard_count(),
        cfg.workers,
    );
    if let Some(net) = model {
        return serve_network(&coord, net, n, cfg.seed);
    }
    let blocks: Vec<std::sync::Arc<crate::sparse::SparseBlock>> = paper_blocks()
        .into_iter()
        .take(4)
        .map(|nb| std::sync::Arc::new(nb.block))
        .collect();
    if fuse {
        let plan = coord.register_fused(&blocks);
        let fused = plan.iter().filter(|b| b.len() > 1).count();
        println!("fusion planned {} bundle(s); member traffic batches into windows", fused);
    }
    let mut rng = crate::util::rng::Pcg64::seeded(cfg.seed);
    let t0 = std::time::Instant::now();
    let mut session = coord.session();
    let mut tickets = Vec::with_capacity(n);
    for _ in 0..n {
        let block = std::sync::Arc::clone(&blocks[rng.index(blocks.len())]);
        let xs: Vec<Vec<f32>> = (0..iters)
            .map(|_| (0..block.c).map(|_| rng.next_normal() as f32).collect())
            .collect();
        tickets.push(session.enqueue(block, xs));
    }
    session.flush(); // seal any open batching windows
    let ok = tickets.into_iter().map(|t| t.wait()).filter(|r| r.is_ok()).count();
    let wall = t0.elapsed();
    let m = coord.metrics.snapshot();
    println!(
        "served {ok}/{n} requests in {wall:?}: cache hits {} misses {} windows {} \
         (lane passes {}) total CGRA cycles {}",
        m.cache_hits, m.cache_misses, m.windows, m.lane_windows, m.total_cycles
    );
    println!(
        "mean latency {:.2} ms, throughput {:.1} req/s",
        m.total_latency_ns as f64 / 1e6 / n as f64,
        n as f64 / wall.as_secs_f64()
    );
    for (sid, s) in m.shards.iter().enumerate() {
        println!(
            "shard {sid}: windows {} shed {} worker_restarts {} poisoned {} \
             queue p50 {:.1} us p99 {:.1} us",
            s.windows,
            s.shed,
            s.worker_restarts,
            s.poisoned,
            s.queue_ns_p50 / 1e3,
            s.queue_ns_p99 / 1e3,
        );
    }
    Ok(())
}

/// The `serve --model` path: register the network (tiles shard-pinned,
/// small tiles bundle-packed) and pump whole-network pipeline requests
/// through `enqueue_network`, then print per-layer attribution.
fn serve_network(
    coord: &Coordinator,
    net: crate::model::NetworkGraph,
    n: usize,
    seed: u64,
) -> Result<()> {
    let serving = coord.register_network(net)?;
    println!(
        "registered network {}: {} stage(s), {} tile block(s)",
        serving.name,
        serving.stages.len(),
        serving.block_count(),
    );
    let mut rng = crate::util::rng::Pcg64::seeded(seed);
    let session = coord.session();
    let t0 = std::time::Instant::now();
    let mut last = None;
    for _ in 0..n.max(1) {
        let x: Vec<f32> = (0..serving.input_width())
            .map(|_| rng.next_normal() as f32)
            .collect();
        let ticket = session.enqueue_network(&serving.name, &x)?;
        last = Some(ticket.wait().map_err(Error::from)?);
    }
    let wall = t0.elapsed();
    let res = last.expect("served at least one network request");
    println!("network {} served: {} outputs, {} total cycles", res.network, res.outputs.len(), res.cycles);
    for lm in &res.layers {
        println!(
            "  {}: {} block(s) cycles {} COPs {} MCIDs {} latency {:.2} ms fused_requests {}",
            lm.layer,
            lm.blocks,
            lm.cycles,
            lm.cops,
            lm.mcids,
            lm.latency_ns as f64 / 1e6,
            lm.fused_requests,
        );
    }
    let m = coord.metrics.snapshot();
    println!(
        "served {} network request(s) in {wall:?}: {} stage(s) assembled, cache hits {} misses {}",
        m.networks_served, m.network_stages, m.cache_hits, m.cache_misses
    );
    Ok(())
}

fn cmd_artifacts(args: &Args) -> Result<()> {
    let dir = args
        .get("dir")
        .map(str::to_string)
        .unwrap_or_else(crate::runtime::default_artifacts_dir);
    let mut rt = crate::runtime::Runtime::new(&dir)?;
    println!("platform: {}", rt.platform());
    for name in rt.artifact_names() {
        let spec = rt.spec(&name).unwrap().clone();
        println!("  {name}: in={:?} out={:?}", spec.in_shapes, spec.out_shape);
    }
    // Smoke-run the first sparse-block artifact.
    let name = "sb_c4k6".to_string();
    if let Some(spec) = rt.spec(&name).cloned() {
        let ins: Vec<Vec<f32>> = spec
            .in_shapes
            .iter()
            .map(|s| vec![1.0f32; s.iter().product()])
            .collect();
        let refs: Vec<&[f32]> = ins.iter().map(|v| v.as_slice()).collect();
        let y = rt.execute(&name, &refs)?;
        println!("smoke-ran {name}: output len {} sum {:.1}", y.len(), y.iter().sum::<f32>());
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(str::to_string).collect()
    }

    #[test]
    fn parses_flags() {
        let a = Args::parse(argv("map --block block3 --seed 7")).unwrap();
        assert_eq!(a.command, "map");
        assert_eq!(a.get("block"), Some("block3"));
        assert_eq!(a.get_usize("seed", 0).unwrap(), 7);
        assert_eq!(a.get_usize("iters", 64).unwrap(), 64);
    }

    #[test]
    fn rejects_malformed() {
        assert!(Args::parse(argv("map stray")).is_err());
        assert!(Args::parse(argv("map --block")).is_err());
        let a = Args::parse(argv("map --iters notanum")).unwrap();
        assert!(a.get_usize("iters", 1).is_err());
    }

    #[test]
    fn unknown_command_errors() {
        assert!(dispatch(&Args::parse(argv("frobnicate")).unwrap()).is_err());
    }

    #[test]
    fn table2_runs() {
        assert!(dispatch(&Args::parse(argv("table2")).unwrap()).is_ok());
    }

    #[test]
    fn unknown_block_errors() {
        assert!(find_block("block99").is_err());
        assert!(find_block("block2").is_ok());
    }

    #[test]
    fn ingest_preset_writes_and_reloads_dump() {
        let path = std::env::temp_dir()
            .join(format!("sparsemap-cli-ingest-{}.txt", std::process::id()));
        let path_s = path.to_str().unwrap().to_string();
        let write = format!("ingest --preset vgg_head --out {path_s}");
        assert!(dispatch(&Args::parse(argv(&write)).unwrap()).is_ok());
        let reread = format!("ingest --dump {path_s}");
        assert!(dispatch(&Args::parse(argv(&reread)).unwrap()).is_ok());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn ingest_rejects_bad_invocations() {
        assert!(dispatch(&Args::parse(argv("ingest")).unwrap()).is_err());
        assert!(dispatch(&Args::parse(argv("ingest --preset nope")).unwrap()).is_err());
        assert!(
            dispatch(&Args::parse(argv("ingest --dump /nonexistent/x.txt")).unwrap()).is_err()
        );
    }
}
