//! Command-line interface (clap is unavailable offline; the parser is a
//! substrate of this repo).
//!
//! ```text
//! sparsemap <command> [--key value] ...
//!
//! commands:
//!   table2                      print Table 2 (block features)
//!   table3                      print Table 3 (mapping comparison)
//!   table4                      print Table 4 (ablation)
//!   map        --block <name>   map one paper block and print the result
//!   simulate   --block <name>   map + simulate + verify one block
//!   serve      --requests <n>   run the streaming coordinator demo
//!              --fuse <0|1>     register fused bundles (batching windows)
//!   artifacts                   list AOT artifacts and smoke-run one
//! common flags:
//!   --config <path>             TOML-subset config file
//!   --scheduler <sparsemap|baseline>
//!   --iters <n>                 simulation iterations (default 64)
//!   --seed <n>
//! ```

use std::collections::HashMap;

use crate::config::SparsemapConfig;
use crate::coordinator::Coordinator;
use crate::error::{Error, Result};
use crate::mapper::{map_block, MapperOptions};
use crate::report;
use crate::sim::simulate_and_check;
use crate::sparse::gen::paper_blocks;

/// Parsed command line: a command plus `--key value` flags.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub command: String,
    flags: HashMap<String, String>,
}

impl Args {
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args> {
        let mut it = argv.into_iter();
        let command = it.next().unwrap_or_default();
        let mut flags = HashMap::new();
        while let Some(arg) = it.next() {
            let Some(key) = arg.strip_prefix("--") else {
                return Err(Error::Config(format!("unexpected positional argument '{arg}'")));
            };
            let value = it
                .next()
                .ok_or_else(|| Error::Config(format!("flag --{key} needs a value")))?;
            flags.insert(key.to_string(), value);
        }
        Ok(Args { command, flags })
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Config(format!("--{key} expects an integer, got '{v}'"))),
        }
    }
}

fn load_config(args: &Args) -> Result<SparsemapConfig> {
    let mut cfg = match args.get("config") {
        Some(path) => SparsemapConfig::from_file(path)?,
        None => SparsemapConfig::default(),
    };
    if let Some(s) = args.get("scheduler") {
        cfg.scheduler = s.parse()?;
    }
    if let Some(s) = args.get("seed") {
        cfg.seed = s
            .parse()
            .map_err(|_| Error::Config(format!("--seed expects an integer, got '{s}'")))?;
    }
    Ok(cfg)
}

fn find_block(name: &str) -> Result<crate::sparse::SparseBlock> {
    paper_blocks()
        .into_iter()
        .find(|nb| nb.label == name)
        .map(|nb| nb.block)
        .ok_or_else(|| {
            Error::Config(format!(
                "unknown block '{name}' (try block1..block7)"
            ))
        })
}

/// CLI entrypoint; returns the process exit code.
pub fn run<I: IntoIterator<Item = String>>(argv: I) -> i32 {
    crate::util::logging::init();
    let args = match Args::parse(argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            return 2;
        }
    };
    match dispatch(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    }
}

fn dispatch(args: &Args) -> Result<()> {
    match args.command.as_str() {
        "table2" => {
            println!("{}", report::table2());
            Ok(())
        }
        "table3" => cmd_table3(args),
        "table4" => cmd_table4(args),
        "map" => cmd_map(args),
        "simulate" => cmd_simulate(args),
        "serve" => cmd_serve(args),
        "artifacts" => cmd_artifacts(args),
        "" | "help" | "--help" | "-h" => {
            print!("{}", USAGE);
            Ok(())
        }
        other => Err(Error::Config(format!("unknown command '{other}' (try 'help')"))),
    }
}

const USAGE: &str = "\
sparsemap — loop mapping for sparse CNNs on streaming CGRAs

usage: sparsemap <command> [--key value]...

commands:
  table2                     block features (paper Table 2)
  table3                     mapping comparison (paper Table 3)
  table4                     technique ablation (paper Table 4)
  map      --block blockN    map one block, print II/COPs/MCIDs
  simulate --block blockN    map + cycle-accurate simulate + verify
  serve    --requests N      streaming coordinator demo
  artifacts                  list + smoke-run the AOT artifacts
flags:
  --config path  --scheduler sparsemap|baseline  --iters N  --seed N
  --shards N   (serve) worker-pool shards, overrides [coordinator] shards
";

fn cmd_table3(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let (t, base_rows, sm_rows) = report::table3(&cfg.cgra);
    println!("{t}");
    let (bc, bm) = report::totals(&base_rows);
    let (sc, sm) = report::totals(&sm_rows);
    println!(
        "\nTotals (first attempts): baseline |C|={bc} |M|={bm}  sparsemap |C|={sc} |M|={sm}  \
         (COPs ↓{:.1}%, MCIDs ↓{:.1}%)",
        100.0 * (1.0 - sc as f64 / bc.max(1) as f64),
        100.0 * (1.0 - sm as f64 / bm.max(1) as f64),
    );
    Ok(())
}

fn cmd_table4(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let (t, _) = report::table4(&cfg.cgra);
    println!("{t}");
    Ok(())
}

fn cmd_map(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let block = find_block(args.get("block").unwrap_or("block1"))?;
    let opts = MapperOptions::from_config(&cfg);
    let out = map_block(&block, &cfg.cgra, &opts)?;
    println!(
        "{}: MII={} first(II0={} C={} M={} ok={}) final II={} C={} M={} speedup={:.2} \
         attempts={} mis_iters={}",
        block.name,
        out.mii,
        out.first_attempt.ii0,
        out.first_attempt.cops,
        out.first_attempt.mcids,
        out.first_attempt.success,
        out.mapping.ii,
        out.mapping.cops(),
        out.mapping.mcids(),
        out.speedup(&block, &cfg.cgra),
        out.attempts.len(),
        out.mapping.mis_iterations,
    );
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let block = find_block(args.get("block").unwrap_or("block1"))?;
    let iters = args.get_usize("iters", 64)?;
    let opts = MapperOptions::from_config(&cfg);
    let out = map_block(&block, &cfg.cgra, &opts)?;
    let res = simulate_and_check(&out.mapping, &block, &cfg.cgra, iters, cfg.seed)?;
    println!(
        "{}: II={} iterations={} cycles={} throughput={:.4} it/cycle \
         (1/II={:.4}) PE-util={:.1}% lrf_peak={} grf_peak={} — outputs verified ✓",
        block.name,
        out.mapping.ii,
        res.iterations,
        res.cycles,
        res.throughput(),
        1.0 / out.mapping.ii as f64,
        100.0 * res.pe_utilization(),
        res.lrf_peak,
        res.grf_peak,
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let n = args.get_usize("requests", 32)?;
    let iters = args.get_usize("iters", 16)?;
    let fuse = args.get_usize("fuse", 0)? != 0;
    // --shards pins the topology explicitly (over both the config knob
    // and SPARSEMAP_SHARDS); without it Coordinator::new resolves those.
    let coord = match args.get_usize("shards", 0)? {
        0 => Coordinator::new(&cfg),
        n => Coordinator::with_shard_count(&cfg, n),
    };
    // Startup banner: the resolved serving stack (knobs + env overrides
    // applied), so a silently overridden backend or lane width is visible
    // before the first request.
    let backend = match coord.sim_backend() {
        crate::config::SimBackend::Compiled => "compiled",
        crate::config::SimBackend::Interpreter => "interpreter",
    };
    let lanes = match coord.sim_lanes() {
        0 => "auto".to_string(),
        1 => "scalar".to_string(),
        w => w.to_string(),
    };
    println!(
        "serving on {} shard(s) × {} worker(s), sim backend {backend}, lanes {lanes}",
        coord.shard_count(),
        cfg.workers,
    );
    let blocks: Vec<std::sync::Arc<crate::sparse::SparseBlock>> = paper_blocks()
        .into_iter()
        .take(4)
        .map(|nb| std::sync::Arc::new(nb.block))
        .collect();
    if fuse {
        let plan = coord.register_fused(&blocks);
        let fused = plan.iter().filter(|b| b.len() > 1).count();
        println!("fusion planned {} bundle(s); member traffic batches into windows", fused);
    }
    let mut rng = crate::util::rng::Pcg64::seeded(cfg.seed);
    let t0 = std::time::Instant::now();
    let mut session = coord.session();
    let mut tickets = Vec::with_capacity(n);
    for _ in 0..n {
        let block = std::sync::Arc::clone(&blocks[rng.index(blocks.len())]);
        let xs: Vec<Vec<f32>> = (0..iters)
            .map(|_| (0..block.c).map(|_| rng.next_normal() as f32).collect())
            .collect();
        tickets.push(session.enqueue(block, xs));
    }
    session.flush(); // seal any open batching windows
    let ok = tickets.into_iter().map(|t| t.wait()).filter(|r| r.is_ok()).count();
    let wall = t0.elapsed();
    let m = coord.metrics.snapshot();
    println!(
        "served {ok}/{n} requests in {wall:?}: cache hits {} misses {} windows {} \
         (lane passes {}) total CGRA cycles {}",
        m.cache_hits, m.cache_misses, m.windows, m.lane_windows, m.total_cycles
    );
    println!(
        "mean latency {:.2} ms, throughput {:.1} req/s",
        m.total_latency_ns as f64 / 1e6 / n as f64,
        n as f64 / wall.as_secs_f64()
    );
    for (sid, s) in m.shards.iter().enumerate() {
        println!(
            "shard {sid}: windows {} shed {} worker_restarts {} poisoned {} \
             queue p50 {:.1} us p99 {:.1} us",
            s.windows,
            s.shed,
            s.worker_restarts,
            s.poisoned,
            s.queue_ns_p50 / 1e3,
            s.queue_ns_p99 / 1e3,
        );
    }
    Ok(())
}

fn cmd_artifacts(args: &Args) -> Result<()> {
    let dir = args
        .get("dir")
        .map(str::to_string)
        .unwrap_or_else(crate::runtime::default_artifacts_dir);
    let mut rt = crate::runtime::Runtime::new(&dir)?;
    println!("platform: {}", rt.platform());
    for name in rt.artifact_names() {
        let spec = rt.spec(&name).unwrap().clone();
        println!("  {name}: in={:?} out={:?}", spec.in_shapes, spec.out_shape);
    }
    // Smoke-run the first sparse-block artifact.
    let name = "sb_c4k6".to_string();
    if let Some(spec) = rt.spec(&name).cloned() {
        let ins: Vec<Vec<f32>> = spec
            .in_shapes
            .iter()
            .map(|s| vec![1.0f32; s.iter().product()])
            .collect();
        let refs: Vec<&[f32]> = ins.iter().map(|v| v.as_slice()).collect();
        let y = rt.execute(&name, &refs)?;
        println!("smoke-ran {name}: output len {} sum {:.1}", y.len(), y.iter().sum::<f32>());
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(str::to_string).collect()
    }

    #[test]
    fn parses_flags() {
        let a = Args::parse(argv("map --block block3 --seed 7")).unwrap();
        assert_eq!(a.command, "map");
        assert_eq!(a.get("block"), Some("block3"));
        assert_eq!(a.get_usize("seed", 0).unwrap(), 7);
        assert_eq!(a.get_usize("iters", 64).unwrap(), 64);
    }

    #[test]
    fn rejects_malformed() {
        assert!(Args::parse(argv("map stray")).is_err());
        assert!(Args::parse(argv("map --block")).is_err());
        let a = Args::parse(argv("map --iters notanum")).unwrap();
        assert!(a.get_usize("iters", 1).is_err());
    }

    #[test]
    fn unknown_command_errors() {
        assert!(dispatch(&Args::parse(argv("frobnicate")).unwrap()).is_err());
    }

    #[test]
    fn table2_runs() {
        assert!(dispatch(&Args::parse(argv("table2")).unwrap()).is_ok());
    }

    #[test]
    fn unknown_block_errors() {
        assert!(find_block("block99").is_err());
        assert!(find_block("block2").is_ok());
    }
}
