//! `sparsemap` launcher — see `sparsemap help`.

fn main() {
    let code = sparsemap::cli::run(std::env::args().skip(1));
    std::process::exit(code);
}
