//! Dynamic bitset used for conflict-graph adjacency and MIS bookkeeping,
//! plus the kernel-axis mask of the association analysis.
//!
//! The SBTS solver's inner loop is dominated by neighbourhood queries;
//! a word-packed bitset keeps those at a few ns per vertex. [`KernelMask`]
//! serves the other hot set operation in the mapper — the per-read kernel
//! sets whose pairwise intersections form the association matrix — with an
//! inline single-word representation that only spills to heap storage for
//! blocks wider than 64 kernels.

/// Word-packed dynamic bitset with the set operations the binder needs.
#[derive(Clone, PartialEq, Eq)]
pub struct BitSet {
    words: Vec<u64>,
    nbits: usize,
}

impl BitSet {
    /// Empty set over a universe of `nbits` elements.
    pub fn new(nbits: usize) -> Self {
        BitSet { words: vec![0; nbits.div_ceil(64)], nbits }
    }

    /// Re-shape to an empty set over `nbits` elements, reusing the word
    /// buffer's allocation (the ScratchPool reuse primitive).
    pub fn reset(&mut self, nbits: usize) {
        let nwords = nbits.div_ceil(64);
        self.words.clear();
        self.words.resize(nwords, 0);
        self.nbits = nbits;
    }

    /// Universe size.
    pub fn capacity(&self) -> usize {
        self.nbits
    }

    #[inline]
    pub fn insert(&mut self, i: usize) {
        debug_assert!(i < self.nbits);
        self.words[i >> 6] |= 1u64 << (i & 63);
    }

    #[inline]
    pub fn remove(&mut self, i: usize) {
        debug_assert!(i < self.nbits);
        self.words[i >> 6] &= !(1u64 << (i & 63));
    }

    #[inline]
    pub fn contains(&self, i: usize) -> bool {
        debug_assert!(i < self.nbits);
        (self.words[i >> 6] >> (i & 63)) & 1 == 1
    }

    /// Number of set bits.
    pub fn len(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.words.iter().all(|&w| w == 0)
    }

    pub fn clear(&mut self) {
        self.words.iter_mut().for_each(|w| *w = 0);
    }

    /// `|self ∩ other|` — the SBTS move-evaluation primitive.
    pub fn intersection_len(&self, other: &BitSet) -> usize {
        self.words
            .iter()
            .zip(&other.words)
            .map(|(a, b)| (a & b).count_ones() as usize)
            .sum()
    }

    /// True iff the sets share no element.
    pub fn is_disjoint(&self, other: &BitSet) -> bool {
        self.words.iter().zip(&other.words).all(|(a, b)| a & b == 0)
    }

    /// In-place union.
    pub fn union_with(&mut self, other: &BitSet) {
        for (a, b) in self.words.iter_mut().zip(&other.words) {
            *a |= b;
        }
    }

    /// Iterate set bits in ascending order.
    pub fn iter(&self) -> impl Iterator<Item = usize> + '_ {
        self.words.iter().enumerate().flat_map(|(wi, &w)| {
            let mut bits = w;
            std::iter::from_fn(move || {
                if bits == 0 {
                    None
                } else {
                    let b = bits.trailing_zeros() as usize;
                    bits &= bits - 1;
                    Some(wi * 64 + b)
                }
            })
        })
    }

    /// Iterate `self ∩ other` in ascending order without allocating — the
    /// word-level conflict-delta primitive of the SBTS inner loop.
    pub fn iter_intersection<'a>(&'a self, other: &'a BitSet) -> impl Iterator<Item = usize> + 'a {
        self.words
            .iter()
            .zip(&other.words)
            .enumerate()
            .flat_map(|(wi, (a, b))| {
                let mut bits = a & b;
                std::iter::from_fn(move || {
                    if bits == 0 {
                        None
                    } else {
                        let b = bits.trailing_zeros() as usize;
                        bits &= bits - 1;
                        Some(wi * 64 + b)
                    }
                })
            })
    }

    /// Elements of `self ∩ other` (used to list conflicting neighbours).
    pub fn intersection(&self, other: &BitSet) -> Vec<usize> {
        self.words
            .iter()
            .zip(&other.words)
            .enumerate()
            .flat_map(|(wi, (a, b))| {
                let mut bits = a & b;
                std::iter::from_fn(move || {
                    if bits == 0 {
                        None
                    } else {
                        let b = bits.trailing_zeros() as usize;
                        bits &= bits - 1;
                        Some(wi * 64 + b)
                    }
                })
            })
            .collect()
    }
}

impl std::fmt::Debug for BitSet {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_set().entries(self.iter()).finish()
    }
}

/// Kernel-set mask: which kernels consume a given channel, the per-read
/// signal behind the association matrix (paper §2.1).
///
/// The representation is width-adaptive: kernels `0..64` live in one inline
/// word (the paper's evaluation blocks never leave it, so the common case
/// stays allocation-free and a single `AND`+`popcount` per pair), while
/// kernel indices `≥ 64` — real CNN layers carry 128–512 output kernels —
/// spill into a word vector that grows on demand. The hot operation is
/// [`KernelMask::intersection_count`].
#[derive(Clone, Debug, Default)]
pub struct KernelMask {
    /// Kernels `0..64` — the inline fast path, always present.
    word0: u64,
    /// Kernels `64..`: `spill[i]` holds kernels `64·(i+1) .. 64·(i+2)`.
    /// Empty until a kernel index ≥ 64 is inserted.
    spill: Vec<u64>,
}

/// Equality is over set *content*: trailing all-zero spill words (a
/// pre-sized but unused capacity) do not distinguish masks.
impl PartialEq for KernelMask {
    fn eq(&self, other: &Self) -> bool {
        if self.word0 != other.word0 {
            return false;
        }
        let n = self.spill.len().max(other.spill.len());
        (0..n).all(|i| {
            self.spill.get(i).copied().unwrap_or(0) == other.spill.get(i).copied().unwrap_or(0)
        })
    }
}

impl Eq for KernelMask {}

impl KernelMask {
    /// Empty mask (inline representation; spills lazily on wide inserts).
    pub fn new() -> Self {
        Self::default()
    }

    /// Empty mask pre-sized for kernel indices `0..nk`, so bulk builds over
    /// a known-width block never reallocate the spill vector.
    pub fn with_kernels(nk: usize) -> Self {
        KernelMask {
            word0: 0,
            spill: vec![0; nk.div_ceil(64).saturating_sub(1)],
        }
    }

    #[inline]
    pub fn insert(&mut self, kr: usize) {
        if kr < 64 {
            self.word0 |= 1u64 << kr;
        } else {
            let wi = kr / 64 - 1;
            if self.spill.len() <= wi {
                self.spill.resize(wi + 1, 0);
            }
            self.spill[wi] |= 1u64 << (kr & 63);
        }
    }

    #[inline]
    pub fn contains(&self, kr: usize) -> bool {
        if kr < 64 {
            (self.word0 >> kr) & 1 == 1
        } else {
            self.spill
                .get(kr / 64 - 1)
                .is_some_and(|w| (w >> (kr & 63)) & 1 == 1)
        }
    }

    /// Number of kernels in the set.
    pub fn count(&self) -> u32 {
        self.word0.count_ones() + self.spill.iter().map(|w| w.count_ones()).sum::<u32>()
    }

    pub fn is_empty(&self) -> bool {
        self.word0 == 0 && self.spill.iter().all(|&w| w == 0)
    }

    /// Whether the mask left the inline single-word representation (i.e. a
    /// kernel index ≥ 64 was inserted or capacity for one was reserved).
    pub fn spilled(&self) -> bool {
        !self.spill.is_empty()
    }

    /// `|self ∩ other|` — the association of two channels. Handles masks of
    /// different spill widths (missing words are empty).
    #[inline]
    pub fn intersection_count(&self, other: &KernelMask) -> u32 {
        let mut n = (self.word0 & other.word0).count_ones();
        for (a, b) in self.spill.iter().zip(&other.spill) {
            n += (a & b).count_ones();
        }
        n
    }

    /// Iterate set kernel indices in ascending order.
    pub fn iter(&self) -> impl Iterator<Item = usize> + '_ {
        std::iter::once(&self.word0)
            .chain(self.spill.iter())
            .enumerate()
            .flat_map(|(wi, &w)| {
                let mut bits = w;
                std::iter::from_fn(move || {
                    if bits == 0 {
                        None
                    } else {
                        let b = bits.trailing_zeros() as usize;
                        bits &= bits - 1;
                        Some(wi * 64 + b)
                    }
                })
            })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn insert_remove_contains() {
        let mut s = BitSet::new(200);
        assert!(s.is_empty());
        s.insert(0);
        s.insert(63);
        s.insert(64);
        s.insert(199);
        assert!(s.contains(0) && s.contains(63) && s.contains(64) && s.contains(199));
        assert!(!s.contains(1) && !s.contains(100));
        assert_eq!(s.len(), 4);
        s.remove(64);
        assert!(!s.contains(64));
        assert_eq!(s.len(), 3);
    }

    #[test]
    fn iter_ascending() {
        let mut s = BitSet::new(300);
        for i in [5usize, 64, 65, 128, 255, 299] {
            s.insert(i);
        }
        let v: Vec<usize> = s.iter().collect();
        assert_eq!(v, vec![5, 64, 65, 128, 255, 299]);
    }

    #[test]
    fn set_ops_match_naive() {
        let mut rng = Pcg64::seeded(17);
        for _ in 0..50 {
            let n = 1 + rng.index(500);
            let mut a = BitSet::new(n);
            let mut b = BitSet::new(n);
            let mut ha = std::collections::HashSet::new();
            let mut hb = std::collections::HashSet::new();
            for _ in 0..n / 2 {
                let i = rng.index(n);
                a.insert(i);
                ha.insert(i);
                let j = rng.index(n);
                b.insert(j);
                hb.insert(j);
            }
            assert_eq!(a.len(), ha.len());
            assert_eq!(a.intersection_len(&b), ha.intersection(&hb).count());
            assert_eq!(a.is_disjoint(&b), ha.is_disjoint(&hb));
            let mut inter = a.intersection(&b);
            inter.sort_unstable();
            let mut want: Vec<usize> = ha.intersection(&hb).copied().collect();
            want.sort_unstable();
            assert_eq!(inter, want);
            let lazy: Vec<usize> = a.iter_intersection(&b).collect();
            assert_eq!(lazy, inter, "iter_intersection must match intersection");
        }
    }

    #[test]
    fn reset_reuses_and_clears() {
        let mut s = BitSet::new(100);
        s.insert(5);
        s.insert(99);
        s.reset(300);
        assert_eq!(s.capacity(), 300);
        assert!(s.is_empty());
        s.insert(299);
        s.reset(10);
        assert_eq!(s.capacity(), 10);
        assert!(s.is_empty());
        s.insert(9);
        assert_eq!(s.len(), 1);
    }

    #[test]
    fn union_with() {
        let mut a = BitSet::new(100);
        let mut b = BitSet::new(100);
        a.insert(1);
        b.insert(99);
        a.union_with(&b);
        assert!(a.contains(1) && a.contains(99));
    }

    #[test]
    fn kernel_mask_inline_fast_path() {
        let mut m = KernelMask::new();
        for kr in [0usize, 7, 63] {
            m.insert(kr);
        }
        assert!(!m.spilled(), "k ≤ 64 must stay inline");
        assert!(m.contains(0) && m.contains(7) && m.contains(63));
        assert!(!m.contains(1) && !m.contains(64) && !m.contains(200));
        assert_eq!(m.count(), 3);
        assert_eq!(m.iter().collect::<Vec<_>>(), vec![0, 7, 63]);
    }

    #[test]
    fn kernel_mask_spills_across_word_boundary() {
        let mut m = KernelMask::new();
        for kr in [63usize, 64, 65, 127, 128, 255] {
            m.insert(kr);
        }
        assert!(m.spilled());
        assert_eq!(m.count(), 6);
        for kr in [63usize, 64, 65, 127, 128, 255] {
            assert!(m.contains(kr), "kr={kr}");
        }
        assert!(!m.contains(62) && !m.contains(66) && !m.contains(256));
        assert_eq!(m.iter().collect::<Vec<_>>(), vec![63, 64, 65, 127, 128, 255]);
    }

    #[test]
    fn kernel_mask_with_kernels_presizes() {
        assert!(!KernelMask::with_kernels(0).spilled());
        assert!(!KernelMask::with_kernels(64).spilled());
        assert!(KernelMask::with_kernels(65).spilled());
        let mut m = KernelMask::with_kernels(256);
        m.insert(255);
        assert!(m.contains(255));
        assert_eq!(m.count(), 1);
    }

    #[test]
    fn kernel_mask_equality_ignores_capacity() {
        assert_eq!(KernelMask::new(), KernelMask::with_kernels(200));
        let mut a = KernelMask::new();
        let mut b = KernelMask::with_kernels(256);
        a.insert(70);
        b.insert(70);
        assert_eq!(a, b);
        b.insert(130);
        assert_ne!(a, b);
        assert_ne!(KernelMask::new(), b);
    }

    #[test]
    fn kernel_mask_intersection_matches_naive() {
        let mut rng = Pcg64::seeded(23);
        for _ in 0..60 {
            let nk = 1 + rng.index(300);
            let mut a = KernelMask::new();
            let mut b = KernelMask::with_kernels(nk);
            let mut ha = std::collections::HashSet::new();
            let mut hb = std::collections::HashSet::new();
            for _ in 0..nk / 2 {
                let i = rng.index(nk);
                a.insert(i);
                ha.insert(i);
                let j = rng.index(nk);
                b.insert(j);
                hb.insert(j);
            }
            assert_eq!(a.count() as usize, ha.len());
            assert_eq!(
                a.intersection_count(&b) as usize,
                ha.intersection(&hb).count(),
                "nk={nk}"
            );
            // Mixed widths: an inline mask against a spilled one.
            assert_eq!(a.intersection_count(&b), b.intersection_count(&a));
            let mut sorted: Vec<usize> = ha.iter().copied().collect();
            sorted.sort_unstable();
            assert_eq!(a.iter().collect::<Vec<_>>(), sorted);
        }
    }
}
