//! Dynamic bitset used for conflict-graph adjacency and MIS bookkeeping.
//!
//! The SBTS solver's inner loop is dominated by neighbourhood queries;
//! a word-packed bitset keeps those at a few ns per vertex.

/// Word-packed dynamic bitset with the set operations the binder needs.
#[derive(Clone, PartialEq, Eq)]
pub struct BitSet {
    words: Vec<u64>,
    nbits: usize,
}

impl BitSet {
    /// Empty set over a universe of `nbits` elements.
    pub fn new(nbits: usize) -> Self {
        BitSet { words: vec![0; nbits.div_ceil(64)], nbits }
    }

    /// Re-shape to an empty set over `nbits` elements, reusing the word
    /// buffer's allocation (the ScratchPool reuse primitive).
    pub fn reset(&mut self, nbits: usize) {
        let nwords = nbits.div_ceil(64);
        self.words.clear();
        self.words.resize(nwords, 0);
        self.nbits = nbits;
    }

    /// Universe size.
    pub fn capacity(&self) -> usize {
        self.nbits
    }

    #[inline]
    pub fn insert(&mut self, i: usize) {
        debug_assert!(i < self.nbits);
        self.words[i >> 6] |= 1u64 << (i & 63);
    }

    #[inline]
    pub fn remove(&mut self, i: usize) {
        debug_assert!(i < self.nbits);
        self.words[i >> 6] &= !(1u64 << (i & 63));
    }

    #[inline]
    pub fn contains(&self, i: usize) -> bool {
        debug_assert!(i < self.nbits);
        (self.words[i >> 6] >> (i & 63)) & 1 == 1
    }

    /// Number of set bits.
    pub fn len(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.words.iter().all(|&w| w == 0)
    }

    pub fn clear(&mut self) {
        self.words.iter_mut().for_each(|w| *w = 0);
    }

    /// `|self ∩ other|` — the SBTS move-evaluation primitive.
    pub fn intersection_len(&self, other: &BitSet) -> usize {
        self.words
            .iter()
            .zip(&other.words)
            .map(|(a, b)| (a & b).count_ones() as usize)
            .sum()
    }

    /// True iff the sets share no element.
    pub fn is_disjoint(&self, other: &BitSet) -> bool {
        self.words.iter().zip(&other.words).all(|(a, b)| a & b == 0)
    }

    /// In-place union.
    pub fn union_with(&mut self, other: &BitSet) {
        for (a, b) in self.words.iter_mut().zip(&other.words) {
            *a |= b;
        }
    }

    /// Iterate set bits in ascending order.
    pub fn iter(&self) -> impl Iterator<Item = usize> + '_ {
        self.words.iter().enumerate().flat_map(|(wi, &w)| {
            let mut bits = w;
            std::iter::from_fn(move || {
                if bits == 0 {
                    None
                } else {
                    let b = bits.trailing_zeros() as usize;
                    bits &= bits - 1;
                    Some(wi * 64 + b)
                }
            })
        })
    }

    /// Iterate `self ∩ other` in ascending order without allocating — the
    /// word-level conflict-delta primitive of the SBTS inner loop.
    pub fn iter_intersection<'a>(&'a self, other: &'a BitSet) -> impl Iterator<Item = usize> + 'a {
        self.words
            .iter()
            .zip(&other.words)
            .enumerate()
            .flat_map(|(wi, (a, b))| {
                let mut bits = a & b;
                std::iter::from_fn(move || {
                    if bits == 0 {
                        None
                    } else {
                        let b = bits.trailing_zeros() as usize;
                        bits &= bits - 1;
                        Some(wi * 64 + b)
                    }
                })
            })
    }

    /// Elements of `self ∩ other` (used to list conflicting neighbours).
    pub fn intersection(&self, other: &BitSet) -> Vec<usize> {
        self.words
            .iter()
            .zip(&other.words)
            .enumerate()
            .flat_map(|(wi, (a, b))| {
                let mut bits = a & b;
                std::iter::from_fn(move || {
                    if bits == 0 {
                        None
                    } else {
                        let b = bits.trailing_zeros() as usize;
                        bits &= bits - 1;
                        Some(wi * 64 + b)
                    }
                })
            })
            .collect()
    }
}

impl std::fmt::Debug for BitSet {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_set().entries(self.iter()).finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn insert_remove_contains() {
        let mut s = BitSet::new(200);
        assert!(s.is_empty());
        s.insert(0);
        s.insert(63);
        s.insert(64);
        s.insert(199);
        assert!(s.contains(0) && s.contains(63) && s.contains(64) && s.contains(199));
        assert!(!s.contains(1) && !s.contains(100));
        assert_eq!(s.len(), 4);
        s.remove(64);
        assert!(!s.contains(64));
        assert_eq!(s.len(), 3);
    }

    #[test]
    fn iter_ascending() {
        let mut s = BitSet::new(300);
        for i in [5usize, 64, 65, 128, 255, 299] {
            s.insert(i);
        }
        let v: Vec<usize> = s.iter().collect();
        assert_eq!(v, vec![5, 64, 65, 128, 255, 299]);
    }

    #[test]
    fn set_ops_match_naive() {
        let mut rng = Pcg64::seeded(17);
        for _ in 0..50 {
            let n = 1 + rng.index(500);
            let mut a = BitSet::new(n);
            let mut b = BitSet::new(n);
            let mut ha = std::collections::HashSet::new();
            let mut hb = std::collections::HashSet::new();
            for _ in 0..n / 2 {
                let i = rng.index(n);
                a.insert(i);
                ha.insert(i);
                let j = rng.index(n);
                b.insert(j);
                hb.insert(j);
            }
            assert_eq!(a.len(), ha.len());
            assert_eq!(a.intersection_len(&b), ha.intersection(&hb).count());
            assert_eq!(a.is_disjoint(&b), ha.is_disjoint(&hb));
            let mut inter = a.intersection(&b);
            inter.sort_unstable();
            let mut want: Vec<usize> = ha.intersection(&hb).copied().collect();
            want.sort_unstable();
            assert_eq!(inter, want);
            let lazy: Vec<usize> = a.iter_intersection(&b).collect();
            assert_eq!(lazy, inter, "iter_intersection must match intersection");
        }
    }

    #[test]
    fn reset_reuses_and_clears() {
        let mut s = BitSet::new(100);
        s.insert(5);
        s.insert(99);
        s.reset(300);
        assert_eq!(s.capacity(), 300);
        assert!(s.is_empty());
        s.insert(299);
        s.reset(10);
        assert_eq!(s.capacity(), 10);
        assert!(s.is_empty());
        s.insert(9);
        assert_eq!(s.len(), 1);
    }

    #[test]
    fn union_with() {
        let mut a = BitSet::new(100);
        let mut b = BitSet::new(100);
        a.insert(1);
        b.insert(99);
        a.union_with(&b);
        assert!(a.contains(1) && a.contains(99));
    }
}
