//! Minimal property-testing loop (proptest/quickcheck are unavailable
//! offline): run a property over many seeded random cases and, on failure,
//! report the failing seed so the case can be replayed deterministically.
//!
//! Usage:
//! ```no_run
//! use sparsemap::util::proptest::check;
//! check("sum is commutative", 200, |rng| {
//!     let a = rng.next_below(1000) as i64;
//!     let b = rng.next_below(1000) as i64;
//!     assert_eq!(a + b, b + a);
//! });
//! ```

use crate::util::rng::Pcg64;

/// Run `prop` on `cases` independently-seeded RNGs. Panics (with the failing
/// case index and seed) if any case panics. Honors `SPARSEMAP_PROP_SEED` to
/// replay a single failing case.
pub fn check<F: Fn(&mut Pcg64) + std::panic::RefUnwindSafe>(name: &str, cases: u64, prop: F) {
    if let Ok(s) = std::env::var("SPARSEMAP_PROP_SEED") {
        let seed: u64 = s.parse().expect("SPARSEMAP_PROP_SEED must be u64");
        let mut rng = Pcg64::seeded(seed);
        prop(&mut rng);
        return;
    }
    for case in 0..cases {
        let seed = 0x5eed_0000u64 ^ (case.wrapping_mul(0x9e37_79b9_7f4a_7c15));
        let result = std::panic::catch_unwind(|| {
            let mut rng = Pcg64::seeded(seed);
            prop(&mut rng);
        });
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property '{name}' failed at case {case}/{cases} \
                 (replay with SPARSEMAP_PROP_SEED={seed}): {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("xor-involution", 64, |rng| {
            let x = rng.next_u64();
            let k = rng.next_u64();
            assert_eq!((x ^ k) ^ k, x);
        });
    }

    #[test]
    fn failing_property_reports_seed() {
        let r = std::panic::catch_unwind(|| {
            check("always-fails", 3, |_rng| panic!("boom"));
        });
        let msg = match r {
            Err(p) => p.downcast_ref::<String>().cloned().unwrap_or_default(),
            Ok(_) => panic!("property should have failed"),
        };
        assert!(msg.contains("SPARSEMAP_PROP_SEED="), "{msg}");
        assert!(msg.contains("always-fails"), "{msg}");
    }
}
