//! Deterministic fault injection ("failpoints").
//!
//! A failpoint is a named site in production code where a test can inject
//! a fault: a panic, an error, or a delay. Sites are compiled in only
//! under the `failpoints` cargo feature — the [`crate::fail_point!`] and
//! [`crate::fail_point_error!`] macros expand to nothing without it, so
//! release builds carry zero overhead and zero behavioral risk.
//!
//! Unlike the classic `fail` crate, triggers here are fully deterministic:
//! counted triggers ([`Trigger::Nth`], [`Trigger::FirstN`]) fire on exact
//! hit indices, and probabilistic triggers ([`Trigger::Prob`]) draw from a
//! per-site [`Pcg64`] stream seeded at configuration time, so a failing
//! fault schedule replays exactly from its seed.
//!
//! The registry itself is always compiled (it is plain data and lets the
//! trigger machinery be unit-tested in every configuration); only the call
//! sites are feature-gated. Tests that configure faults share one global
//! registry, so they serialize through [`FailScenario::setup`], which also
//! clears the registry on drop — a panicking test cannot leak its faults
//! into the next one.

use std::collections::HashMap;
use std::sync::{Mutex, MutexGuard, OnceLock};
use std::time::Duration;

use crate::util::rng::Pcg64;

/// What happens when a configured site fires.
#[derive(Clone, Debug, PartialEq)]
pub enum FaultKind {
    /// Panic at the site (exercises `catch_unwind` / supervision paths).
    Panic,
    /// Surface an injected error carrying this message; the site's
    /// `fail_point_error!` arm turns it into the site's native error type.
    Error(String),
    /// Sleep this many milliseconds, then continue normally.
    DelayMs(u64),
}

/// When a configured site fires, in terms of its hit counter (1-based).
#[derive(Clone, Debug, PartialEq)]
pub enum Trigger {
    /// Every hit.
    Always,
    /// Exactly the nth hit.
    Nth(u64),
    /// Hits 1..=n.
    FirstN(u64),
    /// Each hit independently with probability `p`, drawn from a per-site
    /// seeded stream (deterministic for a given seed and hit sequence).
    Prob(f64),
}

struct Site {
    kind: FaultKind,
    trigger: Trigger,
    hits: u64,
    rng: Pcg64,
}

impl Site {
    /// Count one hit and decide whether the fault fires.
    fn fire(&mut self) -> bool {
        self.hits += 1;
        match self.trigger {
            Trigger::Always => true,
            Trigger::Nth(n) => self.hits == n,
            Trigger::FirstN(n) => self.hits <= n,
            Trigger::Prob(p) => self.rng.chance(p),
        }
    }
}

fn registry() -> &'static Mutex<HashMap<String, Site>> {
    static REGISTRY: OnceLock<Mutex<HashMap<String, Site>>> = OnceLock::new();
    REGISTRY.get_or_init(|| Mutex::new(HashMap::new()))
}

fn lock_registry() -> MutexGuard<'static, HashMap<String, Site>> {
    // A panic injected *while* holding the lock is impossible (eval drops
    // the guard before panicking), but a poisoned map is still just data.
    registry()
        .lock()
        .unwrap_or_else(|poison| poison.into_inner())
}

/// Arm `site` with a fault. Replaces any previous configuration and resets
/// the site's hit counter. `seed` feeds the per-site RNG used by
/// [`Trigger::Prob`] (ignored by the counted triggers).
pub fn configure(site: &str, kind: FaultKind, trigger: Trigger, seed: u64) {
    lock_registry().insert(
        site.to_string(),
        Site {
            kind,
            trigger,
            hits: 0,
            rng: Pcg64::seeded(seed),
        },
    );
}

/// Disarm `site` (no-op if it was never configured).
pub fn remove(site: &str) {
    lock_registry().remove(site);
}

/// Disarm every site.
pub fn clear() {
    lock_registry().clear();
}

/// Hits recorded at `site` since it was configured (0 if unconfigured).
pub fn hits(site: &str) -> u64 {
    lock_registry().get(site).map_or(0, |s| s.hits)
}

/// Evaluate one hit at `site`. Unconfigured sites return `None` at the
/// cost of one map lookup. A firing [`FaultKind::Panic`] panics here (with
/// the registry lock released); a firing [`FaultKind::DelayMs`] sleeps and
/// returns `None`; a firing [`FaultKind::Error`] returns `Some(message)`
/// for the caller's error arm to consume.
pub fn eval(site: &str) -> Option<String> {
    let fired = {
        let mut reg = lock_registry();
        let s = reg.get_mut(site)?;
        if s.fire() {
            Some(s.kind.clone())
        } else {
            None
        }
    };
    match fired? {
        FaultKind::Panic => panic!("failpoint `{site}` injected panic"),
        FaultKind::DelayMs(ms) => {
            std::thread::sleep(Duration::from_millis(ms));
            None
        }
        FaultKind::Error(msg) => Some(msg),
    }
}

/// RAII scope for a fault-injection test: serializes tests that share the
/// global registry and guarantees a clean registry on entry and exit.
pub struct FailScenario {
    _guard: MutexGuard<'static, ()>,
}

impl FailScenario {
    /// Take the scenario lock (waiting out any concurrently running fault
    /// test) and clear the registry.
    pub fn setup() -> Self {
        static SCENARIO: OnceLock<Mutex<()>> = OnceLock::new();
        let guard = SCENARIO
            .get_or_init(|| Mutex::new(()))
            .lock()
            // A previous scenario that panicked mid-test poisons the lock;
            // the registry is cleared below either way.
            .unwrap_or_else(|poison| poison.into_inner());
        clear();
        FailScenario { _guard: guard }
    }
}

impl Drop for FailScenario {
    fn drop(&mut self) {
        clear();
    }
}

/// Evaluate a failpoint for its side effects (panic or delay). Compiles to
/// nothing without the `failpoints` feature.
#[macro_export]
macro_rules! fail_point {
    ($site:expr) => {{
        #[cfg(feature = "failpoints")]
        {
            let _ = $crate::util::failpoint::eval($site);
        }
    }};
}

/// Evaluate a failpoint that can inject an error: if the site fires a
/// [`crate::util::failpoint::FaultKind::Error`], `$on_err` maps the
/// injected message to the enclosing function's error value and the macro
/// `return`s it. Compiles to nothing without the `failpoints` feature.
#[macro_export]
macro_rules! fail_point_error {
    ($site:expr, $on_err:expr) => {{
        #[cfg(feature = "failpoints")]
        {
            if let Some(msg) = $crate::util::failpoint::eval($site) {
                #[allow(clippy::redundant_closure_call)]
                return ($on_err)(msg);
            }
        }
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unconfigured_site_is_silent() {
        let _s = FailScenario::setup();
        assert_eq!(eval("tests::nope"), None);
        assert_eq!(hits("tests::nope"), 0);
    }

    #[test]
    fn nth_fires_exactly_once() {
        let _s = FailScenario::setup();
        configure("tests::nth", FaultKind::Error("boom".into()), Trigger::Nth(3), 0);
        let fired: Vec<bool> = (0..6).map(|_| eval("tests::nth").is_some()).collect();
        assert_eq!(fired, [false, false, true, false, false, false]);
        assert_eq!(hits("tests::nth"), 6);
    }

    #[test]
    fn first_n_fires_prefix() {
        let _s = FailScenario::setup();
        configure("tests::first", FaultKind::Error("e".into()), Trigger::FirstN(2), 0);
        let fired: Vec<bool> = (0..4).map(|_| eval("tests::first").is_some()).collect();
        assert_eq!(fired, [true, true, false, false]);
    }

    #[test]
    fn prob_is_deterministic_per_seed() {
        let run = |seed| {
            let _s = FailScenario::setup();
            configure("tests::prob", FaultKind::Error("e".into()), Trigger::Prob(0.5), seed);
            (0..64)
                .map(|_| eval("tests::prob").is_some())
                .collect::<Vec<_>>()
        };
        assert_eq!(run(7), run(7));
        assert_ne!(run(7), run(8));
    }

    #[test]
    fn panic_kind_panics_and_scenario_cleans_up() {
        let _s = FailScenario::setup();
        configure("tests::panic", FaultKind::Panic, Trigger::Always, 0);
        let err = std::panic::catch_unwind(|| eval("tests::panic"));
        assert!(err.is_err());
        drop(_s);
        // Registry is clean after the scenario: the site no longer fires.
        assert_eq!(eval("tests::panic"), None);
    }

    #[test]
    fn delay_kind_sleeps_then_continues() {
        let _s = FailScenario::setup();
        configure("tests::delay", FaultKind::DelayMs(5), Trigger::Nth(1), 0);
        let t0 = std::time::Instant::now();
        assert_eq!(eval("tests::delay"), None);
        assert!(t0.elapsed() >= Duration::from_millis(5));
    }

    #[test]
    fn configure_resets_hit_counter() {
        let _s = FailScenario::setup();
        configure("tests::reset", FaultKind::Error("a".into()), Trigger::Nth(1), 0);
        assert!(eval("tests::reset").is_some());
        configure("tests::reset", FaultKind::Error("b".into()), Trigger::Nth(1), 0);
        assert_eq!(eval("tests::reset").as_deref(), Some("b"));
    }
}
