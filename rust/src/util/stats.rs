//! Summary statistics for bench results and coordinator metrics.

/// Online summary of a sample stream (count / mean / min / max / variance,
/// Welford's algorithm) plus percentile support via a retained buffer.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    samples: Vec<f64>,
    mean: f64,
    m2: f64,
}

impl Summary {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, x: f64) {
        self.samples.push(x);
        let n = self.samples.len() as f64;
        let delta = x - self.mean;
        self.mean += delta / n;
        self.m2 += delta * (x - self.mean);
    }

    pub fn count(&self) -> usize {
        self.samples.len()
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Sample standard deviation (n-1 denominator).
    pub fn stddev(&self) -> f64 {
        if self.samples.len() < 2 {
            0.0
        } else {
            (self.m2 / (self.samples.len() - 1) as f64).sqrt()
        }
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.samples.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    /// Linear-interpolated percentile. `q` is clamped into [0, 100];
    /// samples are ordered by `f64::total_cmp`, so NaN samples (e.g. a
    /// poisoned latency ratio) sort to the extremes instead of panicking
    /// mid-sort.
    pub fn percentile(&self, q: f64) -> f64 {
        assert!(!self.samples.is_empty(), "percentile of empty summary");
        let q = q.clamp(0.0, 100.0);
        let mut v = self.samples.clone();
        v.sort_by(f64::total_cmp);
        let pos = (q / 100.0) * (v.len() - 1) as f64;
        let lo = pos.floor() as usize;
        let hi = pos.ceil() as usize;
        if lo == hi {
            v[lo]
        } else {
            v[lo] + (pos - lo as f64) * (v[hi] - v[lo])
        }
    }

    pub fn median(&self) -> f64 {
        self.percentile(50.0)
    }
}

/// Format a nanosecond duration human-readably (criterion-style).
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_naive() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut s = Summary::new();
        xs.iter().for_each(|&x| s.add(x));
        assert!((s.mean() - 5.0).abs() < 1e-12);
        let naive_var =
            xs.iter().map(|x| (x - 5.0) * (x - 5.0)).sum::<f64>() / (xs.len() - 1) as f64;
        assert!((s.stddev() - naive_var.sqrt()).abs() < 1e-12);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
    }

    #[test]
    fn percentiles() {
        let mut s = Summary::new();
        (1..=100).for_each(|i| s.add(i as f64));
        assert!((s.median() - 50.5).abs() < 1e-9);
        assert!((s.percentile(0.0) - 1.0).abs() < 1e-9);
        assert!((s.percentile(100.0) - 100.0).abs() < 1e-9);
        assert!((s.percentile(99.0) - 99.01).abs() < 0.02);
    }

    #[test]
    fn percentile_survives_nan_samples() {
        let mut s = Summary::new();
        s.add(1.0);
        s.add(f64::NAN);
        s.add(2.0);
        // total_cmp sorts the (positive) NaN last: finite percentiles stay
        // meaningful and nothing panics.
        assert_eq!(s.percentile(0.0), 1.0);
        assert_eq!(s.median(), 2.0);
        assert!(s.percentile(100.0).is_nan());
    }

    #[test]
    fn percentile_clamps_q() {
        let mut s = Summary::new();
        (1..=10).for_each(|i| s.add(i as f64));
        assert_eq!(s.percentile(-25.0), 1.0);
        assert_eq!(s.percentile(250.0), 10.0);
    }

    #[test]
    fn fmt_ns_ranges() {
        assert!(fmt_ns(12.0).ends_with("ns"));
        assert!(fmt_ns(12e3).ends_with("µs"));
        assert!(fmt_ns(12e6).ends_with("ms"));
        assert!(fmt_ns(12e9).ends_with("s"));
    }
}
