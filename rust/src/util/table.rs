//! ASCII table formatter for the paper-table benchmark outputs.

/// Column-aligned ASCII table with a header row, markdown-ish style.
#[derive(Clone, Debug, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>, I: IntoIterator<Item = S>>(header: I) -> Self {
        Table { header: header.into_iter().map(Into::into).collect(), rows: vec![] }
    }

    pub fn row<S: Into<String>, I: IntoIterator<Item = S>>(&mut self, cells: I) -> &mut Self {
        let r: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(r.len(), self.header.len(), "row arity mismatch");
        self.rows.push(r);
        self
    }

    pub fn num_rows(&self) -> usize {
        self.rows.len()
    }

    /// Render with `|`-separated aligned columns and a rule under the header.
    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut width = vec![0usize; ncols];
        for (i, h) in self.header.iter().enumerate() {
            width[i] = h.chars().count();
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                width[i] = width[i].max(c.chars().count());
            }
        }
        let fmt_row = |cells: &[String]| -> String {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                let pad = width[i] - c.chars().count();
                s.push(' ');
                s.push_str(c);
                s.push_str(&" ".repeat(pad + 1));
                s.push('|');
            }
            s
        };
        let mut out = fmt_row(&self.header);
        out.push('\n');
        let mut rule = String::from("|");
        for w in &width {
            rule.push_str(&"-".repeat(w + 2));
            rule.push('|');
        }
        out.push_str(&rule);
        for row in &self.rows {
            out.push('\n');
            out.push_str(&fmt_row(row));
        }
        out
    }
}

impl std::fmt::Display for Table {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.render())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(["block", "II", "speedup"]);
        t.row(["block1", "2", "1.5"]);
        t.row(["block10", "4", "2.67"]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines.iter().all(|l| l.len() == lines[0].len()), "{s}");
        assert!(lines[0].contains("block") && lines[2].contains("block1"));
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn arity_checked() {
        let mut t = Table::new(["a", "b"]);
        t.row(["only-one"]);
    }
}
