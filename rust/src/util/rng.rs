//! PCG-XSL-RR 128/64 pseudo-random generator (O'Neill 2014).
//!
//! Deterministic, seedable, dependency-free. All randomness in the repo
//! (block generation, tabu tie-breaks, synthetic workloads) flows through
//! this so experiments are exactly reproducible.

/// PCG-XSL-RR 128/64: 128-bit LCG state, xorshift-low + random rotation
/// output. Passes BigCrush; far more than we need for workload generation.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Create a generator from a seed and stream id.
    pub fn new(seed: u64, stream: u64) -> Self {
        let inc = ((stream as u128) << 1) | 1;
        let mut rng = Pcg64 { state: 0, inc };
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng
    }

    /// Convenience constructor with the default stream.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xda3e_39cb_94b9_5bdb)
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xsl = ((self.state >> 64) as u64) ^ (self.state as u64);
        xsl.rotate_right(rot)
    }

    /// Uniform in `[0, bound)` via Lemire's multiply-shift (unbiased).
    pub fn next_below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "next_below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (bound as u128);
        let mut lo = m as u64;
        if lo < bound {
            let t = bound.wrapping_neg() % bound;
            while lo < t {
                x = self.next_u64();
                m = (x as u128) * (bound as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in `[0, bound)`.
    pub fn index(&mut self, bound: usize) -> usize {
        self.next_below(bound as u64) as usize
    }

    /// Uniform f64 in `[0, 1)` with 53-bit resolution.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Standard normal via Box–Muller (one value per call, simple > fast).
    pub fn next_normal(&mut self) -> f64 {
        let u1 = (self.next_f64()).max(f64::MIN_POSITIVE);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Bernoulli(p).
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Choose `k` distinct indices from `0..n` (Floyd's algorithm, sorted).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample_indices: k={k} > n={n}");
        let mut chosen = std::collections::BTreeSet::new();
        for j in (n - k)..n {
            let t = self.index(j + 1);
            if !chosen.insert(t) {
                chosen.insert(j);
            }
        }
        chosen.into_iter().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Pcg64::seeded(42);
        let mut b = Pcg64::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::seeded(1);
        let mut b = Pcg64::seeded(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn next_below_in_range_and_not_constant() {
        let mut r = Pcg64::seeded(7);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..1000 {
            let v = r.next_below(10);
            assert!(v < 10);
            seen.insert(v);
        }
        assert_eq!(seen.len(), 10, "all residues should appear");
    }

    #[test]
    fn f64_unit_interval_mean() {
        let mut r = Pcg64::seeded(3);
        let n = 20_000;
        let mean = (0..n).map(|_| r.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::seeded(5);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.next_normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.1, "var={var}");
    }

    #[test]
    fn sample_indices_distinct_sorted() {
        let mut r = Pcg64::seeded(9);
        for _ in 0..100 {
            let s = r.sample_indices(20, 7);
            assert_eq!(s.len(), 7);
            assert!(s.windows(2).all(|w| w[0] < w[1]));
            assert!(s.iter().all(|&i| i < 20));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::seeded(11);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
