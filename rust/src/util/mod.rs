//! Substrate utilities built from scratch (the offline environment ships no
//! rand/serde/criterion/proptest): a PCG-64 RNG, bitsets, summary
//! statistics, an ASCII table formatter, a criterion-style micro-bench
//! harness, a minimal property-testing loop and a tiny logger.

pub mod bench;
pub mod bitset;
pub mod failpoint;
pub mod fnv;
pub mod logging;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod table;

pub use bitset::{BitSet, KernelMask};
pub use fnv::Fnv64;
pub use rng::Pcg64;
