//! Minimal leveled logger: level from `SPARSEMAP_LOG` (error..trace),
//! timestamped stderr output. Fully in-crate — the offline build carries
//! neither `log` nor `env_logger`; call sites use the `log_debug!` /
//! `log_info!` / `log_warn!` / `log_error!` crate macros.

use std::sync::atomic::{AtomicBool, AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

/// Log severity, ordered so that `level <= max_level` means "emit".
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Off = 0,
    Error = 1,
    Warn = 2,
    Info = 3,
    Debug = 4,
    Trace = 5,
}

static MAX_LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);
static INSTALLED: AtomicBool = AtomicBool::new(false);

fn start() -> &'static Instant {
    static START: OnceLock<Instant> = OnceLock::new();
    START.get_or_init(Instant::now)
}

/// The current maximum emitted level.
pub fn max_level() -> u8 {
    MAX_LEVEL.load(Ordering::Relaxed)
}

pub fn set_max_level(level: Level) {
    MAX_LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Whether a record at `level` would be emitted.
#[inline]
pub fn enabled(level: Level) -> bool {
    level as u8 <= max_level()
}

/// Emit one record (used via the `log_*!` macros).
pub fn log(level: Level, target: &str, args: std::fmt::Arguments<'_>) {
    if !enabled(level) {
        return;
    }
    let t = start().elapsed();
    let lvl = match level {
        Level::Off => return,
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
        Level::Trace => "TRACE",
    };
    eprintln!("[{:>8.3}s {} {}] {}", t.as_secs_f64(), lvl, target, args);
}

/// Install the logger (idempotent). Level comes from `SPARSEMAP_LOG`
/// (default `info`).
pub fn init() {
    if INSTALLED.swap(true, Ordering::SeqCst) {
        return;
    }
    let _ = start(); // anchor the timestamp origin
    let level = match std::env::var("SPARSEMAP_LOG").as_deref() {
        Ok("error") => Level::Error,
        Ok("warn") => Level::Warn,
        Ok("debug") => Level::Debug,
        Ok("trace") => Level::Trace,
        Ok("off") => Level::Off,
        _ => Level::Info,
    };
    set_max_level(level);
}

#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Error, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Warn, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Info, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Debug, module_path!(), format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_is_idempotent() {
        init();
        init();
        crate::log_info!("logging works");
    }

    #[test]
    fn levels_filter() {
        init();
        set_max_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_max_level(Level::Info);
        assert!(enabled(Level::Info));
        assert!(!enabled(Level::Debug));
    }
}
