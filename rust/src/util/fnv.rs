//! FNV-1a 64 — the repo's fingerprint primitive (coordinator cache keys,
//! golden-snapshot placement fingerprints). One implementation, so the
//! producers can never drift apart on constants or byte order.

/// Incremental FNV-1a 64 hasher.
#[derive(Clone, Debug)]
pub struct Fnv64(u64);

impl Fnv64 {
    const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME: u64 = 0x0000_0100_0000_01b3;

    pub fn new() -> Self {
        Fnv64(Self::OFFSET)
    }

    #[inline]
    pub fn eat(&mut self, byte: u8) {
        self.0 = (self.0 ^ byte as u64).wrapping_mul(Self::PRIME);
    }

    /// Eat a `u64` as its 8 little-endian bytes.
    #[inline]
    pub fn eat_u64(&mut self, x: u64) {
        for b in x.to_le_bytes() {
            self.eat(b);
        }
    }

    pub fn finish(&self) -> u64 {
        self.0
    }
}

impl Default for Fnv64 {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // FNV-1a 64 reference values.
        assert_eq!(Fnv64::new().finish(), 0xcbf2_9ce4_8422_2325);
        let mut h = Fnv64::new();
        for b in b"a" {
            h.eat(*b);
        }
        assert_eq!(h.finish(), 0xaf63_dc4c_8601_ec8c);
        let mut h = Fnv64::new();
        for b in b"foobar" {
            h.eat(*b);
        }
        assert_eq!(h.finish(), 0x85944171f73967e8);
    }

    #[test]
    fn eat_u64_is_le_bytes() {
        let mut a = Fnv64::new();
        a.eat_u64(0x0102_0304_0506_0708);
        let mut b = Fnv64::new();
        for byte in [0x08, 0x07, 0x06, 0x05, 0x04, 0x03, 0x02, 0x01u8] {
            b.eat(byte);
        }
        assert_eq!(a.finish(), b.finish());
    }
}
