//! Criterion-style micro-benchmark harness (criterion itself is not
//! available offline). Provides warmup, calibrated iteration counts, and
//! mean/σ/percentile reporting. Used by `rust/benches/*.rs` (which are
//! `harness = false` bench targets) and by the perf pass.

use std::time::Instant;

use crate::util::stats::{fmt_ns, Summary};

/// One benchmark's configuration.
#[derive(Clone, Debug)]
pub struct BenchConfig {
    /// Wall-clock budget for warmup.
    pub warmup_ns: u64,
    /// Wall-clock budget for measurement.
    pub measure_ns: u64,
    /// Number of sample batches.
    pub samples: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig { warmup_ns: 200_000_000, measure_ns: 1_000_000_000, samples: 30 }
    }
}

/// Result of one benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    /// Per-iteration nanoseconds.
    pub summary: Summary,
    pub iters_per_sample: u64,
}

impl BenchResult {
    pub fn mean_ns(&self) -> f64 {
        self.summary.mean()
    }

    pub fn report_line(&self) -> String {
        format!(
            "{:<44} {:>12} / iter  (σ {:>10}, p95 {:>10}, {} iters/sample)",
            self.name,
            fmt_ns(self.summary.mean()),
            fmt_ns(self.summary.stddev()),
            fmt_ns(self.summary.percentile(95.0)),
            self.iters_per_sample,
        )
    }
}

/// Prevent the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Harness collecting named benchmark results.
#[derive(Default)]
pub struct Bencher {
    pub config: BenchConfig,
    pub results: Vec<BenchResult>,
}

impl Bencher {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_config(config: BenchConfig) -> Self {
        Bencher { config, results: vec![] }
    }

    /// Run `f` repeatedly; calibrates iterations/sample from the warmup.
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> &BenchResult {
        // Warmup + calibration.
        let start = Instant::now();
        let mut warm_iters = 0u64;
        while (start.elapsed().as_nanos() as u64) < self.config.warmup_ns {
            f();
            warm_iters += 1;
        }
        let per_iter = self.config.warmup_ns.max(1) / warm_iters.max(1);
        let budget_per_sample = self.config.measure_ns / self.config.samples as u64;
        let iters = (budget_per_sample / per_iter.max(1)).clamp(1, 1_000_000);

        let mut summary = Summary::new();
        for _ in 0..self.config.samples {
            let t0 = Instant::now();
            for _ in 0..iters {
                f();
            }
            summary.add(t0.elapsed().as_nanos() as f64 / iters as f64);
        }
        self.results.push(BenchResult {
            name: name.to_string(),
            summary,
            iters_per_sample: iters,
        });
        let r = self.results.last().unwrap();
        println!("{}", r.report_line());
        r
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let mut b = Bencher::with_config(BenchConfig {
            warmup_ns: 2_000_000,
            measure_ns: 10_000_000,
            samples: 5,
        });
        let mut acc = 0u64;
        let r = b.bench("spin", || {
            for i in 0..100u64 {
                acc = black_box(acc.wrapping_add(i * i));
            }
        });
        assert!(r.mean_ns() > 0.0);
        assert_eq!(r.summary.count(), 5);
    }
}
