//! Criterion-style micro-benchmark harness (criterion itself is not
//! available offline). Provides warmup, calibrated iteration counts, and
//! mean/σ/percentile reporting. Used by `rust/benches/*.rs` (which are
//! `harness = false` bench targets) and by the perf pass.

use std::time::Instant;

use crate::util::stats::{fmt_ns, Summary};

/// One benchmark's configuration.
#[derive(Clone, Debug)]
pub struct BenchConfig {
    /// Wall-clock budget for warmup.
    pub warmup_ns: u64,
    /// Wall-clock budget for measurement.
    pub measure_ns: u64,
    /// Number of sample batches.
    pub samples: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig { warmup_ns: 200_000_000, measure_ns: 1_000_000_000, samples: 30 }
    }
}

/// Result of one benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    /// Per-iteration nanoseconds.
    pub summary: Summary,
    pub iters_per_sample: u64,
}

impl BenchResult {
    pub fn mean_ns(&self) -> f64 {
        self.summary.mean()
    }

    /// One flat JSON object per result — the exact line format
    /// [`write_json_merged`] parses back, so keep the two in sync.
    pub fn json_line(&self) -> String {
        format!(
            "{{\"name\": {:?}, \"ns_per_iter\": {:.1}, \"stddev_ns\": {:.1}, \
             \"p95_ns\": {:.1}, \"samples\": {}, \"iters_per_sample\": {}}}",
            self.name,
            self.summary.mean(),
            self.summary.stddev(),
            self.summary.percentile(95.0),
            self.summary.count(),
            self.iters_per_sample,
        )
    }

    pub fn report_line(&self) -> String {
        format!(
            "{:<44} {:>12} / iter  (σ {:>10}, p95 {:>10}, {} iters/sample)",
            self.name,
            fmt_ns(self.summary.mean()),
            fmt_ns(self.summary.stddev()),
            fmt_ns(self.summary.percentile(95.0)),
            self.iters_per_sample,
        )
    }
}

/// Prevent the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Harness collecting named benchmark results.
#[derive(Default)]
pub struct Bencher {
    pub config: BenchConfig,
    pub results: Vec<BenchResult>,
}

impl Bencher {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_config(config: BenchConfig) -> Self {
        Bencher { config, results: vec![] }
    }

    /// Run `f` repeatedly; calibrates iterations/sample from the warmup.
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> &BenchResult {
        // Warmup + calibration.
        let start = Instant::now();
        let mut warm_iters = 0u64;
        while (start.elapsed().as_nanos() as u64) < self.config.warmup_ns {
            f();
            warm_iters += 1;
        }
        let per_iter = self.config.warmup_ns.max(1) / warm_iters.max(1);
        let budget_per_sample = self.config.measure_ns / self.config.samples as u64;
        let iters = (budget_per_sample / per_iter.max(1)).clamp(1, 1_000_000);

        let mut summary = Summary::new();
        for _ in 0..self.config.samples {
            let t0 = Instant::now();
            for _ in 0..iters {
                f();
            }
            summary.add(t0.elapsed().as_nanos() as f64 / iters as f64);
        }
        self.results.push(BenchResult {
            name: name.to_string(),
            summary,
            iters_per_sample: iters,
        });
        let r = self.results.last().unwrap();
        println!("{}", r.report_line());
        r
    }

    /// Merge this run's results into the machine-readable trajectory file
    /// (see [`write_json_merged`]).
    pub fn write_json(&self, path: &str) -> std::io::Result<()> {
        write_json_merged(path, &self.results)
    }
}

/// Path of `file` at the repository root (one above the crate root), so
/// benches and tests agree on where `BENCH_mapper.json` lives regardless
/// of the working directory cargo gave them.
pub fn repo_root_path(file: &str) -> String {
    format!("{}/../{}", env!("CARGO_MANIFEST_DIR"), file)
}

/// The `name` of one merged bench-row line (`BenchResult::json_line`
/// format), if the line is a row. The single authority for reading the
/// row format back — used by [`write_json_merged`]'s merge scan and the
/// schema checks in `tests/bench_snapshot.rs`.
pub fn row_name(line: &str) -> Option<&str> {
    let t = line.trim().trim_end_matches(',');
    t.strip_prefix("{\"name\": \"")
        .and_then(|rest| rest.find('"').map(|end| &rest[..end]))
}

/// A named scalar field of one merged bench-row line, as its raw text
/// (`row_field(r, "ns_per_iter")` → `"12.3"`). Companion of [`row_name`].
pub fn row_field<'a>(line: &'a str, key: &str) -> Option<&'a str> {
    let pat = format!("\"{key}\": ");
    let start = line.find(&pat)? + pat.len();
    let rest = &line[start..];
    let end = rest.find(|c| c == ',' || c == '}').unwrap_or(rest.len());
    Some(rest[..end].trim())
}

/// Merge bench results into a JSON array file, one object per line
/// (`BenchResult::json_line` format). Entries whose `name` matches a new
/// result are replaced in place; everything else is preserved, so several
/// bench binaries (mapper_micro, serving_throughput) accumulate into one
/// `BENCH_mapper.json` that tracks the perf trajectory across PRs. The
/// line-oriented format is parsed back with plain string handling
/// ([`row_name`] / [`row_field`]) — this file is only ever written by this
/// function, never by hand.
pub fn write_json_merged(path: &str, results: &[BenchResult]) -> std::io::Result<()> {
    let mut entries: Vec<(String, String)> = Vec::new();
    if let Ok(text) = std::fs::read_to_string(path) {
        for line in text.lines() {
            if let Some(name) = row_name(line) {
                entries.push((name.to_string(), line.trim().trim_end_matches(',').to_string()));
            }
        }
    }
    for r in results {
        let line = r.json_line();
        match entries.iter_mut().find(|(n, _)| *n == r.name) {
            Some(e) => e.1 = line,
            None => entries.push((r.name.clone(), line)),
        }
    }
    let body: Vec<String> = entries.iter().map(|(_, l)| format!("  {l}")).collect();
    std::fs::write(path, format!("[\n{}\n]\n", body.join(",\n")))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let mut b = Bencher::with_config(BenchConfig {
            warmup_ns: 2_000_000,
            measure_ns: 10_000_000,
            samples: 5,
        });
        let mut acc = 0u64;
        let r = b.bench("spin", || {
            for i in 0..100u64 {
                acc = black_box(acc.wrapping_add(i * i));
            }
        });
        assert!(r.mean_ns() > 0.0);
        assert_eq!(r.summary.count(), 5);
    }

    fn result_named(name: &str, ns: f64) -> BenchResult {
        let mut summary = crate::util::stats::Summary::new();
        summary.add(ns);
        BenchResult { name: name.into(), summary, iters_per_sample: 1 }
    }

    #[test]
    fn row_parsers_read_back_json_line() {
        let line = result_named("a/x", 12.5).json_line();
        assert_eq!(row_name(&line), Some("a/x"));
        assert_eq!(row_field(&line, "ns_per_iter"), Some("12.5"));
        assert_eq!(row_field(&line, "samples"), Some("1"));
        assert_eq!(row_field(&line, "iters_per_sample"), Some("1"));
        assert_eq!(row_field(&line, "nope"), None);
        assert_eq!(row_name("  ]"), None);
        assert_eq!(row_name("["), None);
    }

    #[test]
    fn json_merge_replaces_and_preserves() {
        let path = std::env::temp_dir().join(format!(
            "sparsemap_bench_merge_{}.json",
            std::process::id()
        ));
        let path = path.to_str().unwrap().to_string();
        let _ = std::fs::remove_file(&path);

        write_json_merged(&path, &[result_named("a/x", 10.0), result_named("b/y", 20.0)])
            .unwrap();
        // Second writer: replaces a/x, adds c/z, must preserve b/y.
        write_json_merged(&path, &[result_named("a/x", 30.0), result_named("c/z", 5.0)])
            .unwrap();

        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("[\n") && text.ends_with("]\n"), "{text}");
        assert!(text.contains("\"name\": \"a/x\", \"ns_per_iter\": 30.0"), "{text}");
        assert!(text.contains("\"name\": \"b/y\", \"ns_per_iter\": 20.0"), "{text}");
        assert!(text.contains("\"name\": \"c/z\", \"ns_per_iter\": 5.0"), "{text}");
        assert_eq!(text.matches("\"name\"").count(), 3);
        let _ = std::fs::remove_file(&path);
    }
}
