//! # SparseMap — loop mapping for sparse CNNs on streaming CGRAs
//!
//! Production-quality reproduction of *SparseMap: Loop Mapping for Sparse
//! CNNs on Streaming Coarse-grained Reconfigurable Array* (Ni et al., 2024)
//! as a three-layer rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the paper's contribution and every substrate it
//!   depends on: the streaming-CGRA architecture model ([`arch`]), sparse
//!   block workloads ([`sparse`]), the s-DFG IR ([`dfg`]), the SparseMap and
//!   baseline modulo schedulers ([`sched`]), conflict-graph + SBTS-MIS
//!   binding ([`bind`]), a cycle-accurate functional simulator ([`sim`]),
//!   the PJRT runtime that executes AOT-compiled JAX/Pallas artifacts
//!   ([`runtime`]) and a streaming inference coordinator ([`coordinator`]).
//! * **L2** — `python/compile/model.py`: the sparse-block / conv-layer
//!   compute in JAX, lowered once to HLO text in `artifacts/`.
//! * **L1** — `python/compile/kernels/sparse_block.py`: the Pallas MAC
//!   kernel embedded in the L2 model.
//!
//! Python never runs on the request path; the binary is self-contained once
//! `make artifacts` has produced the HLO modules (the XLA executor is
//! gated behind the `pjrt` cargo feature; the default offline build ships
//! an API-compatible stub and exercises the full mapping/simulation path).
//!
//! ## Quick tour
//!
//! ```no_run
//! use sparsemap::arch::StreamingCgra;
//! use sparsemap::sparse::gen::paper_blocks;
//! use sparsemap::mapper::{map_block, MapperOptions};
//!
//! let cgra = StreamingCgra::paper_default(); // 4x4 PEA, LRF 8, GRF 8
//! let block = &paper_blocks()[0].block;      // "block1" from Table 2
//!
//! // map_block explores the (II, retry) attempt lattice as a deterministic
//! // parallel portfolio: scoped workers race attempts, the lowest-index
//! // success wins, and the result is byte-identical to the sequential
//! // order for every width (0 = auto, 1 = sequential).
//! let opts = MapperOptions::sparsemap().with_parallelism(4);
//! let out = map_block(block, &cgra, &opts).unwrap();
//! println!("II = {}, COPs = {}, MCIDs = {}",
//!          out.mapping.ii, out.mapping.cops(), out.mapping.mcids());
//! ```
//!
//! The per-attempt hot path (schedule → route → conflict graph → SBTS
//! bind) is allocation-conscious and hash-free: each portfolio worker owns
//! a [`bind::ScratchPool`] that recycles the conflict-graph storage, the
//! bucketed build's candidate buckets, the route table and the SBTS solver
//! state across attempts; the conflict graph is built bucket-locally
//! (`(slot, bus)` / `(slot, pe)` groups instead of the naive all-pairs
//! candidate loop); the bus cost model indexes the `II × (n + m)` physical
//! buses with a dense slot-major array; and the SBTS inner loop itself is
//! allocation-free (incremental hot-node tracking, reused move buffers,
//! word-level conflict deltas). Bench trajectory lives in
//! `BENCH_mapper.json` at the repo root (written by `cargo bench --bench
//! mapper_micro` / `--bench serving_throughput`).
//!
//! ## Wide blocks (k > 64, c > 64) are a supported workload class
//!
//! The kernel axis carries no width limit: the association analysis keys
//! its per-read kernel sets on [`util::KernelMask`] — an inline single-word
//! fast path for k ≤ 64 that spills to multi-word masks for the 96/128/256
//! kernel counts real ResNet/VGG layers carry — and the s-DFG index
//! resolves `(channel, kernel)` lookups through dense tables instead of
//! linear scans. `sparse::gen::wide_blocks()` generates the class,
//! `tests/wide_blocks.rs` drives a k = 128 block through map → simulate →
//! serve, and the `wide_k128/*` bench rows track the spill cost.
//!
//! ## Serving: sessions, tickets and batching windows
//!
//! The [`coordinator`] exposes a typed serving API:
//! `Coordinator::session()` opens a `ServeSession`; `enqueue(block, xs)`
//! returns a `Ticket`, and results are retrieved **by handle** —
//! `Ticket::wait()` / `try_wait()`, in any order — with per-request
//! failures as a structured `ServeError` (queue closed / mapping failed /
//! simulator fault / worker gone). Requests targeting members of a
//! registered fused bundle aggregate into **batching windows** that span
//! sessions (`[coordinator] batch_window_requests` / `batch_window_max`;
//! deterministic — window contents are a pure function of the global
//! enqueue/cancel order, independent of worker or shard count):
//! one window runs ONE lockstep simulation pass
//! ([`sim::simulate_fused_batch`]) with a real iteration stream per
//! member, and outputs plus a proportional share of the pass's cycles
//! come back per request — the configuration residency is charged once
//! per window (`Metrics::windows` counts the passes). The pre-session
//! `submit`/`collect` fire-hose survives one release as `#[deprecated]`
//! shims over an internal session; the crate itself compiles with
//! `deny(deprecated)`, so only the shims reference them
//! (`tests/serving_api.rs` locks shim-vs-ticket bit-identity).
//!
//! ## Sharded serving: worker pools per shard, one global dispatch order
//!
//! The coordinator partitions registered blocks and bundles across
//! `[coordinator] shards` worker pools (`SPARSEMAP_SHARDS` overrides the
//! knob; `cli serve --shards N` pins it over both): a deterministic
//! capacity-constrained assigner places each unit on the shard whose
//! post-admission combined MII over estimated PE/bus demand stays lowest
//! (ties to the lowest index), so the placement is a pure function of the
//! registration order. Each shard owns its mapping cache, job queue,
//! supervisor, restart budget and poison registry — one pool's death
//! drains only its own queue while siblings keep serving — and per-shard
//! `windows`/`shed`/`worker_restarts`/`poisoned` counters plus queue-wait
//! p50/p99 ride along in `MetricsSnapshot::shards`. Batching windows form
//! ABOVE the shard layer in a single global dispatch loop, so window
//! contents (and therefore outputs) are bit-identical for any shard and
//! worker count; `shards = 1` (the default) is bit-identical to the
//! pre-sharding coordinator. An optional warm-start manifest
//! (`[coordinator] warm_start_path`, off by default) persists the
//! registered units and pre-builds their mappings through the normal
//! cache path at construction, so a restarted server takes no cold-start
//! misses. `tests/sharded_serving.rs` locks all of it.
//!
//! ## Model ingestion & network serving: serve a pruned CNN, not a block
//!
//! The [`model`] layer turns pruned layer dumps into something the
//! coordinator serves end to end. [`model::dump`] is the ingestion
//! format — a self-describing text dump (name, `c_total × k_total`,
//! dense f32 weights as bit patterns, optional 0/1 mask) whose
//! loader↔writer round trip is bit-identical and whose parser tolerates
//! unknown fields but rejects structural damage; `cli ingest` loads one
//! and prints the per-layer [`model::SparsityProfile`] table
//! ([`report::sparsity_table`]: sparsity, channel-fanout and kernel-size
//! spreads). [`model::NetworkGraph`] chains pruned layers
//! (`layers[i].k_total == layers[i+1].c_total`) and partitions each via
//! [`sparse::partition`] — k ≥ 96 layers tile into the wide-block class,
//! small layers into bundle-sized pieces; the `vgg_head()` /
//! `resnet_tail()` presets build synthetic pruned networks at real layer
//! widths. `Coordinator::register_network` registers every tile
//! (demand-balanced shard pins), packs the tile population into fused
//! bundles, and adds the network to the warm-start manifest; then
//! `ServeSession::enqueue_network(name, x)` returns a `NetworkTicket`
//! that streams each stage's assembled outputs into the next stage's
//! block requests (gather live channels → serve through the normal
//! request path, batching windows included → scatter-accumulate at each
//! block's kernel offset). The resolved `NetworkResult` carries the
//! final activation vector plus per-layer cycle/COP/MCID attribution
//! (`LayerMetrics`), and `tests/network_serving.rs` locks the pipeline
//! bit-identical to serving each tile solo and ~1e-3-close to the dense
//! [`model::NetworkGraph::forward`] chain, across shard counts and lane
//! widths.
//!
//! ## Failure model: the serving tier survives its workers
//!
//! The worker pool is supervised, and the contract is simple: **every
//! enqueued ticket resolves** — with outputs or a structured
//! `ServeError` — never a hang. Per-job panics are caught in place and
//! retried; a panic that kills a worker thread is detected by a
//! supervisor that respawns it (`[coordinator] restart_budget`), and a
//! job that keeps panicking is quarantined after `[coordinator]
//! poison_threshold` strikes (`ServeError::Poisoned`). Latency is
//! bounded end to end: `enqueue_with_deadline` sheds requests whose
//! budget expires before pickup (`DeadlineExceeded` — a request already
//! being served is never interrupted), `Ticket::wait_timeout` bounds the
//! caller's wait, and dropping an unwaited ticket withdraws its request
//! from a still-open batching window. `try_enqueue` is admission
//! control: it sheds with `Overloaded` instead of blocking when the
//! queue is full or over `[coordinator] shed_watermark` (bundle members
//! always join their window — solo singles shed first). Failed mapping
//! cache entries fail identical requests fast and retry the build after
//! `[coordinator] failure_ttl` requests (`0` = sticky forever, the
//! default). `Metrics` counts all of it (`shed`, `deadline_expired`,
//! `worker_restarts`, `poisoned`) and attributes per-request latency as
//! `queue_ns + service_ns` with p50/p99 summaries. The whole model is
//! exercised deterministically by `tests/fault_tolerance.rs` through
//! [`util::failpoint`] (`--features failpoints`; the sites compile to
//! nothing otherwise, and fault-free behavior is identical either way).
//!
//! ## Multi-block fusion: bundles of small blocks on one configuration
//!
//! Real pruned networks are dominated by small blocks that leave most of
//! the fabric idle; reconfiguring per block wastes streaming throughput.
//! The fusion pipeline maps a whole bundle onto **one** configuration:
//!
//! * [`sparse::fuse`] plans bundles (`plan_bundles`: deterministic greedy
//!   first-fit over estimated PE/bus demand, capped by a combined-MII
//!   budget — `MapperOptions::fusion` / `[mapper] max_fused_blocks`,
//!   `fusion_max_ii`) and routes member traffic
//!   (`sparse::fuse::BundleRoutes`: mask fingerprint → bundle + member
//!   index, the lookup window formation keys on);
//! * [`mapper::map_unit`] maps a [`sparse::fuse::FusedBundle`] exactly
//!   like a block: every member is scheduled *solo* at the shared
//!   `(II, retry)` and the solo schedules are composed by per-member
//!   modulo-slot time shifts, so each member's COPs/MCIDs/routes inside
//!   the bundle are byte-identical to its solo schedule
//!   (`tests/fusion_equivalence.rs` locks this, `golden_mappings` pins
//!   the canonical `fused3` bundle);
//! * [`bind`] needs no fusion awareness — the conflict graph's
//!   `(slot, resource)` buckets span members, so cross-block
//!   exclusiveness is the same machinery that separates nodes of one
//!   block ([`dfg::fuse::BlockTags`] carries node → member provenance);
//! * [`sim::simulate_fused_batch`] runs all members in lockstep over a
//!   whole request window (per-member segments, zero-input padding) and
//!   reports per-segment outputs/cycles and per-block COPs/MCIDs;
//!   [`sim::simulate_fused`] is the one-segment wrapper;
//! * the [`coordinator`] batches requests for *any* registered member
//!   block into the bundle's windows against the shared fused mapping
//!   (`register_bundle` / `register_fused`; one LRU cache entry keyed by
//!   the bundle's combined mask fingerprint) and serves mixed
//!   fused/unfused traffic.
//!
//! ## Simulation backends: interpreter → scalar plan → lanes
//!
//! The serving tier's per-window hot path is cycle-level simulation, and
//! it runs on one of three tiers with **identical semantics**:
//!
//! * **Interpreter** — the scalar lockstep pass
//!   ([`sim::simulate_fused_batch`]), the reference semantics, retained
//!   per the hot-path-rewrite workflow below as the root differential
//!   oracle.
//! * **Compiled plan** — [`sim::ExecPlan`] is compiled ONCE per cached
//!   mapping (`ExecPlan::for_outcome`, under the mapping cache's
//!   single-flight guard, evicted with the entry): a flattened slot-major
//!   op array with pre-resolved operand sources (LRF slot / GRF index /
//!   bus hop), precomputed weight indices and structure-of-arrays
//!   per-iteration state. Every hazard the interpreter checks per cycle
//!   (PE/bus exclusiveness, GRF write ports, register pressure) is a
//!   static property of the modulo schedule, so compilation verifies them
//!   all up front and [`sim::execute_plan_batch`] is pure arithmetic —
//!   windows execute as tight inner loops with no per-cycle HashMap
//!   dispatch. `fused3/plan_compile` benches the one-time cost; the
//!   `*_compiled` serving rows measure the payoff.
//! * **Vectorized lanes** (the serving default, on top of the compiled
//!   plan) — [`sim::lanes`] regroups the plan's SoA state lane-major so
//!   ONE sweep over the op array evaluates a whole chunk of a window's
//!   lockstep iterations: per-lane loops over contiguous `f32` rows that
//!   LLVM auto-vectorizes, per-lane write masks for ragged/padded tails,
//!   and a per-worker pooled [`sim::ExecScratch`] so steady-state windows
//!   allocate nothing. The `*_lanes` serving rows and the
//!   `fused3/plan_sweep_lanes{1,8}` micro rows measure the payoff.
//!
//! Each tier is the oracle for the next: `tests/sim_equivalence.rs`
//! holds interpreter vs scalar plan vs lanes (at widths 1/2/4/8/auto)
//! **bit-identical** (outputs, cycles, per-segment shares, COPs/MCIDs,
//! `pe_busy`) across the paper blocks, the canonical bundle, wide blocks
//! and randomized instances, and plan compilation deterministic — lane
//! independence means any width replays the interpreter's exact
//! per-iteration f32 operand order.
//!
//! The `[coordinator] sim_backend` knob (`compiled` | `interpreter`)
//! selects the backend and `[coordinator] sim_lanes` the lane width
//! (`0` auto per window, `1` the scalar plan sweep, `2`/`4`/`8` fixed);
//! the `SPARSEMAP_SIM_BACKEND` / `SPARSEMAP_SIM_LANES` env vars override
//! the config (CI runs the whole suite once per backend and once with
//! the scalar sweep pinned). A mapping whose plan fails to compile
//! serves off the interpreter instead — a loud, logged fallback
//! (`coordinator::plan` failpoint locks it), never a lost ticket — and
//! the `lane_windows` counter in `MetricsSnapshot` makes a silent
//! scalar fallback observable.
//!
//! ## Hot-path rewrites are oracle-tested
//!
//! The required workflow for optimizing any mapper hot path: move the old
//! implementation verbatim into an oracle module ([`bind::oracle`]:
//! `build_naive`, the all-pairs conflict build, and `HashBusCostModel`,
//! the HashMap cost model; [`dfg::oracle`]: `build_naive`, the set-based
//! association builder), then lock old and new together with a
//! differential suite (`rust/tests/conflict_equivalence.rs` —
//! byte-identical graphs, claim states and solver trajectories;
//! `rust/tests/association_equivalence.rs` — byte-identical association
//! matrices across the 64-kernel boundary — each over all paper blocks
//! plus randomized instances) and pin end-to-end results with golden
//! snapshots (`rust/tests/golden_mappings.rs`). A rewrite ships only once
//! the oracle suite proves it behavior-preserving.

// The serving API redesign keeps `submit`/`collect` alive as deprecated
// shims for one release — deny in-crate use so only the shims themselves
// (definitions, not uses) reference the old surface. CI additionally
// compiles the lib target with `-D deprecated`.
#![deny(deprecated)]

pub mod arch;
pub mod bind;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod dfg;
pub mod error;
pub mod mapper;
pub mod model;
pub mod report;
pub mod runtime;
pub mod sched;
pub mod sim;
pub mod sparse;
pub mod util;

pub use error::{Error, Result};
