//! # SparseMap — loop mapping for sparse CNNs on streaming CGRAs
//!
//! Production-quality reproduction of *SparseMap: Loop Mapping for Sparse
//! CNNs on Streaming Coarse-grained Reconfigurable Array* (Ni et al., 2024)
//! as a three-layer rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the paper's contribution and every substrate it
//!   depends on: the streaming-CGRA architecture model ([`arch`]), sparse
//!   block workloads ([`sparse`]), the s-DFG IR ([`dfg`]), the SparseMap and
//!   baseline modulo schedulers ([`sched`]), conflict-graph + SBTS-MIS
//!   binding ([`bind`]), a cycle-accurate functional simulator ([`sim`]),
//!   the PJRT runtime that executes AOT-compiled JAX/Pallas artifacts
//!   ([`runtime`]) and a streaming inference coordinator ([`coordinator`]).
//! * **L2** — `python/compile/model.py`: the sparse-block / conv-layer
//!   compute in JAX, lowered once to HLO text in `artifacts/`.
//! * **L1** — `python/compile/kernels/sparse_block.py`: the Pallas MAC
//!   kernel embedded in the L2 model.
//!
//! Python never runs on the request path; the binary is self-contained once
//! `make artifacts` has produced the HLO modules.
//!
//! ## Quick tour
//!
//! ```no_run
//! use sparsemap::arch::StreamingCgra;
//! use sparsemap::sparse::gen::paper_blocks;
//! use sparsemap::mapper::{map_block, MapperOptions};
//!
//! let cgra = StreamingCgra::paper_default(); // 4x4 PEA, LRF 8, GRF 8
//! let block = &paper_blocks()[0].block;      // "block1" from Table 2
//! let out = map_block(block, &cgra, &MapperOptions::sparsemap()).unwrap();
//! println!("II = {}, COPs = {}, MCIDs = {}",
//!          out.mapping.ii, out.mapping.cops(), out.mapping.mcids());
//! ```

pub mod arch;
pub mod bind;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod dfg;
pub mod error;
pub mod mapper;
pub mod report;
pub mod runtime;
pub mod sched;
pub mod sim;
pub mod sparse;
pub mod util;

pub use error::{Error, Result};
