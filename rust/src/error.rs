//! Crate-wide error type (hand-rolled `Display`/`Error` impls — the offline
//! build carries no `thiserror`).

/// Unified error for the sparsemap crate.
#[derive(Debug)]
pub enum Error {
    /// Scheduling could not satisfy the resource/dependency constraints at
    /// any II up to the configured cap (paper: "Failed" rows of Table 3).
    ScheduleFailed {
        block: String,
        reason: String,
        ii_cap: usize,
    },

    /// Binding (MIS on the conflict graph) left nodes unbound and the
    /// incomplete-mapping handler could not repair it.
    BindFailed { ii: usize, bound: usize, total: usize },

    /// Routing (GRF/LRF for MCIDs) infeasible at this II.
    RouteFailed { ii: usize, reason: String },

    /// Config file / CLI problems.
    Config(String),

    /// Artifact manifest / HLO loading problems.
    Runtime(String),

    /// Simulator detected an illegal mapping (resource collision, wrong
    /// value, dependency violation) — this is a *bug detector*, not a
    /// recoverable condition.
    SimFault { cycle: u64, reason: String },

    /// Workload construction problems (bad block features, empty kernels…).
    Workload(String),

    Io(std::io::Error),

    /// Errors bubbled out of the PJRT runtime (`xla` crate, `pjrt` feature).
    Xla(String),
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::ScheduleFailed { block, reason, ii_cap } => {
                write!(f, "scheduling failed for '{block}': {reason} (II cap {ii_cap})")
            }
            Error::BindFailed { ii, bound, total } => {
                write!(f, "binding failed at II={ii}: {bound} of {total} nodes bound")
            }
            Error::RouteFailed { ii, reason } => {
                write!(f, "routing failed at II={ii}: {reason}")
            }
            Error::Config(msg) => write!(f, "config error: {msg}"),
            Error::Runtime(msg) => write!(f, "runtime error: {msg}"),
            Error::SimFault { cycle, reason } => {
                write!(f, "simulation fault at cycle {cycle}: {reason}")
            }
            Error::Workload(msg) => write!(f, "workload error: {msg}"),
            Error::Io(e) => write!(f, "{e}"),
            Error::Xla(msg) => write!(f, "xla error: {msg}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

#[cfg(feature = "pjrt-xla")]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;
