//! Crate-wide error type.

use thiserror::Error;

/// Unified error for the sparsemap crate.
#[derive(Error, Debug)]
pub enum Error {
    /// Scheduling could not satisfy the resource/dependency constraints at
    /// any II up to the configured cap (paper: "Failed" rows of Table 3).
    #[error("scheduling failed for '{block}': {reason} (II cap {ii_cap})")]
    ScheduleFailed {
        block: String,
        reason: String,
        ii_cap: usize,
    },

    /// Binding (MIS on the conflict graph) left nodes unbound and the
    /// incomplete-mapping handler could not repair it.
    #[error("binding failed at II={ii}: {bound} of {total} nodes bound")]
    BindFailed { ii: usize, bound: usize, total: usize },

    /// Routing (GRF/LRF for MCIDs) infeasible at this II.
    #[error("routing failed at II={ii}: {reason}")]
    RouteFailed { ii: usize, reason: String },

    /// Config file / CLI problems.
    #[error("config error: {0}")]
    Config(String),

    /// Artifact manifest / HLO loading problems.
    #[error("runtime error: {0}")]
    Runtime(String),

    /// Simulator detected an illegal mapping (resource collision, wrong
    /// value, dependency violation) — this is a *bug detector*, not a
    /// recoverable condition.
    #[error("simulation fault at cycle {cycle}: {reason}")]
    SimFault { cycle: u64, reason: String },

    /// Workload construction problems (bad block features, empty kernels…).
    #[error("workload error: {0}")]
    Workload(String),

    #[error(transparent)]
    Io(#[from] std::io::Error),

    /// Errors bubbled out of the PJRT runtime (`xla` crate).
    #[error("xla error: {0}")]
    Xla(String),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;
